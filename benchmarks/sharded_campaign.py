"""Weak-scaling benchmark for the mesh-sharded campaign/NE engines.

Runs on faked CPU devices (``--xla_force_host_platform_device_count=8``,
set by this module itself when launched as a script): a single process
builds meshes over device subsets (1 → 8) and measures, per device count,

* the **campaign engine** — ``run_campaigns(mesh=...)`` at a fixed
  per-device scenario load (weak scaling: B grows with the mesh);
* the **NE engine** — ``solve_heterogeneous(mesh=...)`` scaled up to a
  ≥10⁵-scenario sweep on the full mesh;
* the **equivalence contract** — on the full mesh, with a batch size that
  does *not* divide the device count: ledgers/masks bitwise vs the
  single-device engine, merged model params within 2e-6.

Per device count the artifact records campaigns-or-scenarios/s, the
per-device rate, and weak-scaling efficiency vs the 1-device run. Faked
CPU devices share the host's cores, so efficiency here validates the
*partitioning harness* (no cross-scenario collectives, no replicated
work), not accelerator speedup — on real multi-chip meshes the same
program shards the same way.

Emits ``BENCH_sharded_campaign.json`` (``repro.obs/v1``); rendered into
the README scaling table by ``tools/obs_report.py --readme``.

Run:  PYTHONPATH=src:. python benchmarks/sharded_campaign.py
"""
from __future__ import annotations

import os
import sys

if "jax" not in sys.modules:  # must precede jax import to take effect
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

import repro.core  # noqa: F401  (enables x64)
from repro.core.asymmetric_batched import solve_heterogeneous
from repro.core.duration import paper_duration_model
from repro.federated.campaign import build_campaign, run_campaigns
from repro.federated.simulation import FLConfig
from repro.federated.tasks import synthetic_mlp_task
from repro.obs.export import write_artifact
from repro.optim import sgd
from benchmarks.common import header, record


def _mesh(k: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:k]), ("data",))


def _device_counts() -> list[int]:
    return [k for k in (1, 2, 4, 8) if k <= jax.device_count()]


def _timed(fn) -> float:
    jax.block_until_ready(fn())          # warmup (compile + cache)
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def _scaling_row(k: int, scenarios: int, warm_s: float,
                 base_rate: float | None) -> dict:
    rate = scenarios / warm_s
    return {
        "devices": k,
        "scenarios": scenarios,
        "warm_s": round(warm_s, 3),
        "throughput_per_s": round(rate, 1),
        "per_device_per_s": round(rate / k, 1),
        "efficiency": (1.0 if base_rate is None
                       else round(rate / (k * base_rate), 3)),
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--campaigns-per-device", type=int, default=16)
    ap.add_argument("--ne-scenarios", type=int, default=100_000,
                    help="NE sweep size on the full mesh (scaled down "
                         "proportionally for smaller meshes)")
    ap.add_argument("--json", default="BENCH_sharded_campaign.json")
    args = ap.parse_args(argv)

    counts = _device_counts()
    full = counts[-1]
    header()
    print(f"# devices: {jax.device_count()} "
          f"(weak-scaling over {counts})", flush=True)

    # -- campaign engine weak scaling ---------------------------------------
    task = synthetic_mlp_task()
    fl = FLConfig(n_clients=6, local_steps=1, batch_per_client=8,
                  max_rounds=16, target_acc=0.73, seed=1)
    opt = sgd(0.15)
    campaign_rows = []
    base_rate = None
    for k in counts:
        b = args.campaigns_per_device * k
        ps = jnp.asarray(np.linspace(0.15, 0.9, b), jnp.float32)
        warm = _timed(lambda: run_campaigns(
            fl, *task.campaign_args(), opt, ps, mesh=_mesh(k)).energy_wh)
        row = _scaling_row(k, b, warm, base_rate)
        base_rate = base_rate or row["throughput_per_s"]
        campaign_rows.append(row)
        record(f"sharded_campaign.campaigns[{k}dev]", warm * 1e6,
               f"{b} campaigns x {fl.max_rounds} rounds; "
               f"{row['throughput_per_s']:.1f}/s, "
               f"eff {row['efficiency']:.2f}")

    # -- NE engine scaling to >= 1e5 scenarios ------------------------------
    n_nodes = 8
    dur = dataclasses.replace(paper_duration_model(), n_nodes=n_nodes)
    rng = np.random.default_rng(0)
    ne_rows = []
    base_rate = None
    for k in counts:
        b = max(1, args.ne_scenarios * k // full)
        costs = jnp.asarray(rng.uniform(0.3, 3.0, (b, n_nodes)))
        gammas = jnp.asarray(rng.uniform(0.0, 2.0, (b, n_nodes)))
        warm = _timed(lambda: solve_heterogeneous(
            costs, gammas, dur, mesh=_mesh(k)).p)
        row = _scaling_row(k, b, warm, base_rate)
        base_rate = base_rate or row["throughput_per_s"]
        ne_rows.append(row)
        record(f"sharded_campaign.ne_solve[{k}dev]", warm * 1e6,
               f"{b} scenarios N={n_nodes}; "
               f"{row['throughput_per_s']:.0f}/s, "
               f"eff {row['efficiency']:.2f}")

    # -- equivalence: full mesh vs single device, non-divisible B -----------
    b_eq = args.campaigns_per_device * full + 3   # deliberately indivisible
    ps = jnp.asarray(np.linspace(0.2, 0.85, b_eq), jnp.float32)
    ref = run_campaigns(fl, *task.campaign_args(), opt, ps)
    sh = run_campaigns(fl, *task.campaign_args(), opt, ps, mesh=_mesh(full))
    ledger_bitwise = all(
        bool(jnp.array_equal(a, c)) for a, c in
        zip(jax.tree.leaves(ref.ledger), jax.tree.leaves(sh.ledger)))
    masks_bitwise = bool(jnp.array_equal(ref.k_history, sh.k_history))
    assert ledger_bitwise and masks_bitwise, \
        "sharded engine diverged from single-device accounting"

    b_par = args.campaigns_per_device * full
    pmat = jnp.broadcast_to(
        jnp.linspace(0.3, 0.8, b_par, dtype=jnp.float32)[:, None],
        (b_par, fl.n_clients))
    seeds = jnp.full((b_par,), fl.seed, jnp.uint32)
    rates = (jnp.full((b_par,), 1.0), jnp.full((b_par,), 0.1))
    bench_args = (fl, *task.campaign_args(), opt)
    ref_params = build_campaign(*bench_args)(pmat, seeds, *rates)["params"]
    sh_params = build_campaign(*bench_args, mesh=_mesh(full))(
        pmat, seeds, *rates)["params"]
    params_diff = max(
        float(jnp.max(jnp.abs(a - c))) for a, c in
        zip(jax.tree.leaves(ref_params), jax.tree.leaves(sh_params)))
    assert params_diff <= 2e-6, f"params diverged: {params_diff}"
    record("sharded_campaign.equivalence", 0.0,
           f"B={b_eq} on {full} devices: ledger bitwise={ledger_bitwise}, "
           f"masks bitwise={masks_bitwise}, "
           f"params max|diff|={params_diff:.1e} (bar 2e-6)")

    write_artifact(args.json, "sharded_campaign", {
        "devices": jax.device_count(),
        "device_counts": counts,
        "campaign": {
            "n_clients": fl.n_clients,
            "max_rounds": fl.max_rounds,
            "campaigns_per_device": args.campaigns_per_device,
            "scaling": campaign_rows,
        },
        "ne": {
            "n_nodes": n_nodes,
            "scaling": ne_rows,
            "total_scenarios": ne_rows[-1]["scenarios"],
        },
        "equivalence": {
            "scenarios": b_eq,
            "ledger_bitwise": ledger_bitwise,
            "masks_bitwise": masks_bitwise,
            "params_max_abs_diff": params_diff,
            "params_tolerance": 2e-6,
        },
    }, seed=fl.seed, backend="ref")
    print(f"\nNE sweep: {ne_rows[-1]['scenarios']:,} scenarios on "
          f"{counts[-1]} device(s) in {ne_rows[-1]['warm_s']:.1f}s "
          f"({ne_rows[-1]['throughput_per_s']:,.0f}/s) -> {args.json}")


if __name__ == "__main__":
    main()
