"""Closed-loop load generator for the scenario-sweep service.

Drives :class:`repro.serve.SweepService` with a seeded mixed workload
(NE solves + γ* calibrations + FedAvg campaigns + a few malformed
payloads) in closed-loop waves — each wave submits a slice of the
workload, polls to completion, then submits the next, so queue depth and
per-request latency reflect a live service rather than one giant batch —
and writes a ``repro.obs/v1`` ``BENCH_serve.json`` artifact with the
serving headline numbers: p50/p95/mean latency, throughput, cache-hit
rate, padding overhead, and the per-bucket compile table. CI validates it
with ``tools/obs_report.py --check`` and uploads it next to the other
benchmark artifacts.

Run:  PYTHONPATH=src:. python benchmarks/serve_load.py
"""
from __future__ import annotations

import argparse
import pathlib
import time

from repro.obs import EventSink
from repro.obs.export import write_artifact
from repro.serve import SweepService
from repro.serve.workload import synthetic_workload


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json")
    ap.add_argument("--events", default="OBS_serve_events.jsonl")
    ap.add_argument("--requests", type=int, default=520)
    ap.add_argument("--wave", type=int, default=64,
                    help="closed-loop wave size (submit, drain, repeat)")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    payloads = synthetic_workload(args.requests, seed=args.seed)
    # the sink appends (two-sink interleave safety); start a fresh stream
    pathlib.Path(args.events).unlink(missing_ok=True)

    ok = rejected = 0
    by_kind: dict[str, int] = {}
    t0 = time.perf_counter()
    with EventSink(args.events) as sink:
        with SweepService(max_batch=args.max_batch, sink=sink) as svc:
            for start in range(0, len(payloads), args.wave):
                for resp in svc.serve(payloads[start:start + args.wave]):
                    ok += resp.ok
                    rejected += not resp.ok
                    by_kind[resp.kind] = by_kind.get(resp.kind, 0) + 1
            stats = svc.stats()
        sink.flush()
        n_events = len(sink)
    elapsed = time.perf_counter() - t0

    data = {
        "requests": len(payloads),
        "ok": ok,
        "rejected": rejected,
        "by_kind": by_kind,
        "wave": args.wave,
        "max_batch": args.max_batch,
        "elapsed_s": round(elapsed, 3),
        "throughput_rps": round(len(payloads) / max(elapsed, 1e-9), 2),
        "latency_us": stats["latency"],
        "cache_hit_rate": stats["cache"]["hit_rate"],
        "cache": stats["cache"],
        "padding_overhead": stats["padding_overhead"],
        "dispatches": stats["dispatches"],
        "rows": stats["rows"],
        "buckets": stats["compile"],
        "kernel_dispatch": stats["kernel_dispatch"],
        "events": n_events,
    }
    write_artifact(args.json, "serve_load", data, seed=args.seed,
                   backend="ref")
    lat = stats["latency"]
    print(f"serve load: {len(payloads)} requests ({ok} ok, {rejected} "
          f"rejected) in {elapsed:.1f}s -> "
          f"{data['throughput_rps']:.1f} req/s; p50 "
          f"{lat['p50_us'] / 1e3:.1f} ms / p95 {lat['p95_us'] / 1e3:.1f} ms; "
          f"cache hit rate {data['cache_hit_rate']:.0%}; padding overhead "
          f"{data['padding_overhead']:.1%}; artifact -> {args.json}")


if __name__ == "__main__":
    main()
