"""Table II-style campaign sweep: scan-fused engine vs. Python round loop.

The paper's Table II / Figs. 4-5 sweep full FedAvg campaigns over
participation probabilities. This benchmark runs a B >= 32 scenario sweep
two ways over the identical task:

* ``reference`` — loop :func:`run_simulation_reference` (the seed
  Python-per-round simulator) over scenarios. Each call re-traces its round
  program and pays per-round dispatch + eager ledger/tracker updates — the
  cost of the unfused design. A ``--sample`` subset is timed and
  extrapolated (pass ``--full-reference`` to loop every scenario).
* ``scan-fused`` — one :func:`repro.federated.campaign.run_campaigns`
  call: ``lax.scan`` over rounds, ``vmap`` over scenarios, one jitted XLA
  program (compile reported separately, then a warm timed run).

Equivalence of the two engines is asserted in
``tests/test_federated.py::test_campaign_engine_matches_reference``; here we
only measure. Emits ``name,us_per_call,derived`` CSV rows, a ``speedup``
row (acceptance bar: >= 50x), and ``BENCH_campaign.json`` for the perf
trajectory.

Run:  PYTHONPATH=src:. python benchmarks/campaign_sweep.py
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core  # noqa: F401  (enables x64)
from repro.federated.campaign import build_campaign, run_campaigns
from repro.federated.simulation import FLConfig, run_simulation_reference
from repro.federated.tasks import synthetic_mlp_task
from repro.obs import ObsConfig
from repro.obs.export import write_artifact
from repro.optim import sgd
from benchmarks.common import header, record


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", type=int, default=32)
    ap.add_argument("--sample", type=int, default=3,
                    help="reference scenarios to time (extrapolated to all)")
    ap.add_argument("--full-reference", action="store_true",
                    help="loop the reference simulator over every scenario")
    ap.add_argument("--json", default="BENCH_campaign.json")
    args = ap.parse_args(argv)

    task = synthetic_mlp_task()
    fl = FLConfig(n_clients=10, local_steps=1, batch_per_client=8,
                  max_rounds=50, target_acc=0.73, seed=1)
    opt = sgd(0.15)
    ps = jnp.asarray(np.linspace(0.1, 0.9, args.scenarios), jnp.float32)
    header()

    # -- scan-fused: compile once per backend, then warm timed sweeps --------
    # backend="ref" is the bitwise-reproducible program the speedup and the
    # engine-equals-oracle assertions below run on; backend="pallas" routes
    # the FedAvg merge through the fused kernel (interpret mode on CPU, so
    # its wall time is a harness check, not a TPU projection).
    backend_s, compile_s = {}, {}
    for backend in ("ref", "pallas"):
        engine = build_campaign(fl, *task.campaign_args(), opt,
                                backend=backend)
        t0 = time.perf_counter()
        res_b = run_campaigns(fl, *task.campaign_args(), opt, ps,
                              engine=engine)
        jax.block_until_ready(res_b.energy_wh)
        compile_s[backend] = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_b = run_campaigns(fl, *task.campaign_args(), opt, ps,
                              engine=engine)
        jax.block_until_ready(res_b.energy_wh)
        backend_s[backend] = time.perf_counter() - t0
        record(f"campaign_sweep.fused_total[{backend}]",
               backend_s[backend] * 1e6,
               f"{args.scenarios} campaigns x {fl.max_rounds} rounds; "
               f"{int(jnp.sum(res_b.converged))} converged; "
               f"compile {compile_s[backend]:.1f}s")
        if backend == "ref":
            res = res_b
        else:
            # merge-kernel parity: the pallas merge is fp32, so a scenario
            # whose accuracy grazes the target can converge one round off —
            # anything more is backend drift.
            assert int(jnp.max(jnp.abs(res_b.rounds - res.rounds))) <= 1, \
                (res_b.rounds, res.rounds)
    t_fused = backend_s["ref"]
    t_cold = compile_s["ref"]
    n_conv = int(jnp.sum(res.converged))

    # -- observability overhead ----------------------------------------------
    # the in-carry metric stream rides the scan; acceptance bar: <= 5%
    # overhead on the warm sweep (and bitwise-equal outputs, asserted here).
    obs_engine = build_campaign(fl, *task.campaign_args(), opt,
                                backend="ref",
                                obs=ObsConfig(enabled=True))
    res_obs = run_campaigns(fl, *task.campaign_args(), opt, ps,
                            engine=obs_engine)
    jax.block_until_ready(res_obs.energy_wh)
    t0 = time.perf_counter()
    res_obs = run_campaigns(fl, *task.campaign_args(), opt, ps,
                            engine=obs_engine)
    jax.block_until_ready(res_obs.energy_wh)
    t_obs = time.perf_counter() - t0
    np.testing.assert_array_equal(np.asarray(res_obs.acc_history),
                                  np.asarray(res.acc_history))
    obs_overhead = t_obs / t_fused - 1.0
    record("campaign_sweep.obs_overhead", t_obs * 1e6,
           f"metric-stream sweep; {obs_overhead * 100:+.1f}% vs "
           f"uninstrumented (bar <= 5%); outputs bitwise-equal")

    # -- reference loop ------------------------------------------------------
    if args.full_reference:
        idx = np.arange(args.scenarios)
    else:
        idx = np.linspace(0, args.scenarios - 1,
                          min(args.sample, args.scenarios)).astype(int)
    t0 = time.perf_counter()
    ref_rounds = {}
    for i in idx:
        r = run_simulation_reference(fl, *task.campaign_args(), opt,
                                     p=float(ps[i]))
        ref_rounds[int(i)] = r.rounds
    t_ref_sample = time.perf_counter() - t0
    t_ref = t_ref_sample * (args.scenarios / len(idx))
    tag = ("measured" if args.full_reference
           else f"extrapolated from {len(idx)}")
    record("campaign_sweep.reference_total", t_ref * 1e6,
           f"{args.scenarios} campaigns ({tag})")

    # sanity: realized rounds agree wherever the reference actually ran
    fused_rounds = {i: int(res.rounds[i]) for i in ref_rounds}
    assert fused_rounds == ref_rounds, (fused_rounds, ref_rounds)

    speedup = t_ref / t_fused
    record("campaign_sweep.speedup", speedup,
           f"target >= 50x; fused {t_fused:.2f}s vs reference {t_ref:.1f}s")

    write_artifact(args.json, "campaign_sweep", {
        "scenarios": args.scenarios,
        "max_rounds": fl.max_rounds,
        "n_clients": fl.n_clients,
        "converged": n_conv,
        "fused_s": round(t_fused, 4),
        "fused_s_by_backend": {k: round(v, 4)
                               for k, v in backend_s.items()},
        "fused_compile_s": round(t_cold, 2),
        "obs_instrumented_s": round(t_obs, 4),
        "obs_overhead_pct": round(obs_overhead * 100, 2),
        "reference_s": round(t_ref, 2),
        "reference_timing": tag,
        "speedup": round(speedup, 1),
        "rounds_by_p": {f"{float(ps[i]):.3f}": int(res.rounds[i])
                        for i in range(args.scenarios)},
        "energy_wh_by_p": {f"{float(ps[i]):.3f}": float(res.energy_wh[i])
                           for i in range(args.scenarios)},
        "mean_aoi_by_p": {f"{float(ps[i]):.3f}": float(res.mean_aoi[i])
                          for i in range(args.scenarios)},
    }, seed=fl.seed, backend="ref")
    print(f"\nfused sweep: {t_fused:.2f}s for {args.scenarios} campaigns "
          f"({t_fused / args.scenarios * 1e3:.1f} ms/campaign)")
    print(f"reference:   {t_ref:.1f}s ({tag})")
    print(f"speedup: {speedup:.1f}x  -> {args.json}")


if __name__ == "__main__":
    main()
