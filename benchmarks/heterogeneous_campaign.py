"""Stratified-fleet campaign sweep: heterogeneous engine vs per-node loop.

The heterogeneous campaign engine replays ``(B, N)`` per-node equilibrium
profiles — here the certified (often spontaneously *stratified*) NEs of an
identical-node fleet across a cost sweep — through full FedAvg campaigns
with per-node energy rates and fleet churn, as one jitted scan+vmap
program. The oracle is :func:`run_heterogeneous_reference`, the per-node
Python round loop the engine is bitwise-regression-tested against
(``tests/test_hetero_campaign.py``); a ``--sample`` subset of it is timed
and extrapolated (pass ``--full-reference`` to loop every scenario).

Emits ``name,us_per_call,derived`` CSV rows, a ``speedup`` row (acceptance
bar: >= 50x), and ``BENCH_hetero_campaign.json`` with per-node energy/AoI
splits (worker vs free-rider strata) for the perf trajectory.

Run:  PYTHONPATH=src:. python benchmarks/heterogeneous_campaign.py
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core  # noqa: F401  (enables x64)
from repro.core.controller import ParticipationController
from repro.core.duration import theoretical_duration
from repro.core.energy import EnergyParams, per_node_energy_rates
from repro.federated.campaign import ChurnConfig, build_campaign, run_campaigns
from repro.federated.simulation import (FLConfig,
                                        run_heterogeneous_reference)
from repro.federated.tasks import synthetic_mlp_task
from repro.obs.export import write_artifact
from repro.optim import sgd
from benchmarks.common import header, record

N_NODES = 10
GAMMA = 0.2


def solve_fleet_profiles(scenarios: int) -> tuple[np.ndarray, jnp.ndarray]:
    """Certified asymmetric NEs of identical fleets across a cost sweep.

    Costs span the stable->stratified transition, so the sweep mixes
    symmetric and spontaneously stratified equilibria — the scenario
    diversity the symmetric engine could not replay.
    """
    ctrl = ParticipationController(
        n_nodes=N_NODES, gamma=GAMMA, cost=6.0,
        duration_model=theoretical_duration(N_NODES))
    cost_grid = np.linspace(2.0, 9.0, scenarios)
    costs = jnp.asarray(cost_grid)[:, None] * jnp.ones((1, N_NODES))
    gammas = jnp.full((scenarios, N_NODES), GAMMA)
    return cost_grid, ctrl.solve_batched(gammas, costs, mode="ne",
                                         damping=0.6, max_iters=300)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", type=int, default=32)
    ap.add_argument("--sample", type=int, default=3,
                    help="reference scenarios to time (extrapolated to all)")
    ap.add_argument("--full-reference", action="store_true",
                    help="loop the reference simulator over every scenario")
    ap.add_argument("--json", default="BENCH_hetero_campaign.json")
    args = ap.parse_args(argv)

    task = synthetic_mlp_task()
    fl = FLConfig(n_clients=N_NODES, local_steps=1, batch_per_client=8,
                  max_rounds=50, target_acc=0.73, seed=1)
    opt = sgd(0.15)

    # -- scenario batch: per-node p, two hardware tiers, mild churn ----------
    t0 = time.perf_counter()
    cost_grid, p_matrix = solve_fleet_profiles(args.scenarios)
    jax.block_until_ready(p_matrix)
    t_game = time.perf_counter() - t0
    spread = np.asarray(jnp.max(p_matrix, 1) - jnp.min(p_matrix, 1))
    n_strat = int((spread > 0.3).sum())
    record("hetero_campaign.game_solves", t_game * 1e6,
           f"{args.scenarios} fleets solved+certified; "
           f"{n_strat} stratified")

    # battery sensors (nodes 0..4, lighter hw) vs mains gateways (5..9)
    tiers = [EnergyParams(p_hw_w=150.0, t_train_s=6.0) if i < N_NODES // 2
             else EnergyParams() for i in range(N_NODES)]
    e_part, e_idle = per_node_energy_rates(tiers)
    rates = (e_part[None, :], e_idle[None, :])
    churn = ChurnConfig(arrival=0.5, departure=0.02)

    # -- scan-fused: compile once per backend, then warm timed sweeps --------
    # backend="ref" (bitwise; speedup + oracle assertions run on it) vs
    # backend="pallas" (FedAvg merge through the fused kernel, interpret
    # mode on CPU).
    backend_s, compile_s = {}, {}
    for backend in ("ref", "pallas"):
        engine = build_campaign(fl, *task.campaign_args(), opt, churn=True,
                                backend=backend)

        def sweep():
            return run_campaigns(fl, *task.campaign_args(), opt, p_matrix,
                                 energy_rates_j=rates, churn=churn,
                                 engine=engine)

        t0 = time.perf_counter()
        res_b = sweep()
        jax.block_until_ready(res_b.energy_wh)
        compile_s[backend] = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_b = sweep()
        jax.block_until_ready(res_b.energy_wh)
        backend_s[backend] = time.perf_counter() - t0
        record(f"hetero_campaign.fused_total[{backend}]",
               backend_s[backend] * 1e6,
               f"{args.scenarios} per-node campaigns x {fl.max_rounds} "
               f"rounds; {int(jnp.sum(res_b.converged))} converged; "
               f"compile {compile_s[backend]:.1f}s")
        if backend == "ref":
            res = res_b
        else:
            # fp32 merge parity: at most one round of convergence skew
            assert int(jnp.max(jnp.abs(res_b.rounds - res.rounds))) <= 1, \
                (res_b.rounds, res.rounds)
    t_fused = backend_s["ref"]
    t_cold = compile_s["ref"]
    n_conv = int(jnp.sum(res.converged))

    # -- per-node reference loop ---------------------------------------------
    if args.full_reference:
        idx = np.arange(args.scenarios)
    else:
        idx = np.linspace(0, args.scenarios - 1,
                          min(args.sample, args.scenarios)).astype(int)
    t0 = time.perf_counter()
    ref = {}
    for i in idx:
        ref[int(i)] = run_heterogeneous_reference(
            fl, *task.campaign_args(), opt, p_matrix[i],
            energy_rates_j=(e_part, e_idle), churn=churn)
    t_ref_sample = time.perf_counter() - t0
    t_ref = t_ref_sample * (args.scenarios / len(idx))
    tag = ("measured" if args.full_reference
           else f"extrapolated from {len(idx)}")
    record("hetero_campaign.reference_total", t_ref * 1e6,
           f"{args.scenarios} campaigns ({tag})")

    # sanity: the engine IS the oracle wherever the reference actually ran
    for i, r in ref.items():
        assert int(res.rounds[i]) == r.rounds, (i, int(res.rounds[i]), r.rounds)
        np.testing.assert_array_equal(np.asarray(res.ledger.per_node_j[i]),
                                      np.asarray(r.ledger.per_node_j))

    speedup = t_ref / t_fused
    record("hetero_campaign.speedup", speedup,
           f"target >= 50x; fused {t_fused:.2f}s vs reference {t_ref:.1f}s")

    # -- per-node splits ------------------------------------------------------
    p_np = np.asarray(p_matrix)
    e_np = np.asarray(res.per_node_energy_wh)
    a_np = np.asarray(res.per_node_aoi)
    workers = p_np > 0.5
    split = []
    for i in range(args.scenarios):
        w = workers[i]
        split.append({
            "cost": round(float(cost_grid[i]), 3),
            "p_spread": round(float(spread[i]), 3),
            "workers": int(w.sum()),
            "rounds": int(res.rounds[i]),
            "energy_wh": round(float(res.energy_wh[i]), 2),
            "worker_energy_wh": round(float(e_np[i][w].mean()), 3)
            if w.any() else None,
            "freerider_energy_wh": round(float(e_np[i][~w].mean()), 3)
            if (~w).any() else None,
            "worker_aoi": round(float(a_np[i][w].mean()), 3)
            if w.any() else None,
            "freerider_aoi": round(float(a_np[i][~w].mean()), 3)
            if (~w).any() else None,
        })

    write_artifact(args.json, "hetero_campaign", {
        "scenarios": args.scenarios,
        "n_clients": N_NODES,
        "max_rounds": fl.max_rounds,
        "stratified_scenarios": n_strat,
        "converged": n_conv,
        "game_solve_s": round(t_game, 2),
        "fused_s": round(t_fused, 4),
        "fused_s_by_backend": {k: round(v, 4)
                               for k, v in backend_s.items()},
        "fused_compile_s": round(t_cold, 2),
        "reference_s": round(t_ref, 2),
        "reference_timing": tag,
        "speedup": round(speedup, 1),
        "per_node_energy_wh": np.round(e_np, 4).tolist(),
        "per_node_aoi": np.round(a_np, 4).tolist(),
        "present_counts": np.asarray(res.present_counts).tolist(),
        "strata": split,
    }, seed=fl.seed, backend="ref")
    print(f"\nfused sweep: {t_fused:.2f}s for {args.scenarios} per-node "
          f"campaigns ({t_fused / args.scenarios * 1e3:.1f} ms/campaign)")
    print(f"reference:   {t_ref:.1f}s ({tag})")
    print(f"speedup: {speedup:.1f}x  -> {args.json}")


if __name__ == "__main__":
    main()
