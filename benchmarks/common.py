"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax

ROWS: list[tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def header() -> None:
    print("name,us_per_call,derived", flush=True)
