"""Shared benchmark utilities: timing + CSV emission.

Timing goes through :func:`time_fn`, which returns the full
``repro.obs.export.timing_stats`` dict (p50/p95/mean/min/max µs over a
configurable number of iterations) instead of a bare median — benchmark
emitters stamp these stats straight into their schema'd artifacts. Call
sites that only want one number read ``stats["p50_us"]``.
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax

from repro.obs.export import timing_stats

ROWS: list[tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1,
            iters: int = 5) -> dict[str, Any]:
    """Time ``fn(*args)`` synchronously; returns a timing-stats dict.

    Keys: ``p50_us``, ``p95_us``, ``mean_us``, ``min_us``, ``max_us``,
    ``n`` (see :func:`repro.obs.export.timing_stats`). Each sample wraps
    one call in ``jax.block_until_ready``; ``warmup`` calls are discarded
    first (compile + cache effects).
    """
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return timing_stats(samples)


def header() -> None:
    print("name,us_per_call,derived", flush=True)
