"""Benchmark entry point: every sweep, one command, schema'd artifacts.

``python benchmarks/run.py`` runs the full suite — paper-figure CSV rows,
the roofline analysis, both campaign sweeps, the kernel micro-benches, the
kernel-gap localization, and the instrumented obs smoke — and leaves the
``repro.obs/v1`` artifacts (``BENCH_*.json``, ``OBS_events.jsonl``,
``TRACE_*.json``) in the working directory, then schema-validates the lot
(the same gate CI runs via ``tools/obs_report.py --check``).

Select subsets with ``--only``::

    PYTHONPATH=src:. python benchmarks/run.py --only kernels,kernel_gap
    PYTHONPATH=src:. python benchmarks/run.py --list
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import header

#: name -> (runner, artifacts it emits). Order is the run order: cheap
#: smoke/figure rows first, the campaign sweeps (slowest) last.
SUITES: dict[str, tuple] = {}


def _suite(name, artifacts):
    def deco(fn):
        SUITES[name] = (fn, artifacts)
        return fn
    return deco


@_suite("figures", ())
def _figures() -> None:
    from benchmarks import paper_figures
    paper_figures.run_all()


@_suite("roofline", ())
def _roofline() -> None:
    from benchmarks import roofline
    roofline.run(emit_rows=True)


@_suite("kernels", ("BENCH_kernels.json",))
def _kernels() -> None:
    from benchmarks import kernels_micro
    kernels_micro.main([])


@_suite("kernel_gap", ("BENCH_kernel_gap.json",))
def _kernel_gap() -> None:
    from benchmarks import kernel_gap
    kernel_gap.main([])


@_suite("obs_smoke", ("BENCH_obs_smoke.json", "OBS_events.jsonl",
                      "TRACE_obs_smoke.json"))
def _obs_smoke() -> None:
    from benchmarks import obs_smoke
    obs_smoke.main([])


@_suite("serve", ("BENCH_serve.json", "OBS_serve_events.jsonl"))
def _serve() -> None:
    from benchmarks import serve_load
    serve_load.main([])


@_suite("ne_sweep", ())
def _ne_sweep() -> None:
    from benchmarks import heterogeneous_sweep
    heterogeneous_sweep.main([])


@_suite("mechanisms", ())
def _mechanisms() -> None:
    from benchmarks import mechanisms_sweep
    mechanisms_sweep.main([])


@_suite("coalition", ("BENCH_coalition.json",))
def _coalition() -> None:
    from benchmarks import coalition_sweep
    coalition_sweep.main([])


@_suite("campaign", ("BENCH_campaign.json",))
def _campaign() -> None:
    from benchmarks import campaign_sweep
    campaign_sweep.main([])


@_suite("hetero", ("BENCH_hetero_campaign.json",))
def _hetero() -> None:
    from benchmarks import heterogeneous_campaign
    heterogeneous_campaign.main([])


@_suite("sharded", ("BENCH_sharded_campaign.json",))
def _sharded() -> None:
    # Runs in a subprocess: the XLA device count locks at the first in-process
    # jax init, so the 8-device fake topology can't be set up from here.
    import os
    import subprocess
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    subprocess.run(
        [sys.executable, "benchmarks/sharded_campaign.py"],
        env=env, check=True)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of suites to run")
    ap.add_argument("--list", action="store_true",
                    help="list suite names and exit")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the artifact schema validation at the end")
    args = ap.parse_args(argv)

    if args.list:
        for name, (_, artifacts) in SUITES.items():
            print(f"{name}: {', '.join(artifacts) or '(CSV rows only)'}")
        return 0

    names = list(SUITES) if args.only is None else args.only.split(",")
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; choices: {list(SUITES)}")

    header()
    emitted: list[str] = []
    for name in names:
        fn, artifacts = SUITES[name]
        print(f"\n== {name} ==", flush=True)
        fn()
        emitted += artifacts

    if emitted and not args.no_check:
        from tools.obs_report import check
        print("\n== artifact validation ==", flush=True)
        return check(emitted)
    return 0


if __name__ == "__main__":
    sys.exit(main())
