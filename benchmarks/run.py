"""Benchmark harness — one function per paper table/figure + roofline +
kernel micro-benches. Prints ``name,us_per_call,derived`` CSV."""
from benchmarks import kernels_micro, paper_figures, roofline
from benchmarks.common import header


def main() -> None:
    header()
    paper_figures.run_all()
    roofline.run(emit_rows=True)
    kernels_micro.run_all()


if __name__ == '__main__':
    main()
