"""Localize the fedavg_agg / poibin pallas-vs-ref gap with measured numbers.

The perf trajectory has carried a "fedavg_agg interpret-mode ~35x slower
than the jnp reference on CPU" note since the kernel landed, with nothing
to say *where* the time goes. This benchmark pins it down per kernel and
backend using the obs layer:

* :func:`repro.obs.trace.compile_stats` — trace/lower and XLA-compile wall
  times split from warm execute stats (p50/p95/mean), plus the compiled
  module's own ``cost_analysis()`` FLOPs / bytes-accessed and
  ``memory_analysis()`` buffer sizes;
* dispatch counters — ``repro.kernels.ops.dispatch_stats()`` snapshotted
  over the measured region, proving which call sites resolved to which
  backend while tracing (no silent env/override leakage into the numbers).

Emits ``BENCH_kernel_gap.json`` (schema ``repro.obs/v1``, kind
``kernel_gap``); the checked-in copy lives at
``experiments/obs/BENCH_kernel_gap.json``.

Run:  PYTHONPATH=src:. python benchmarks/kernel_gap.py
"""
from __future__ import annotations

import argparse
import functools

import jax

from benchmarks.common import header, record
from repro.kernels import ops
from repro.obs.export import write_artifact
from repro.obs.trace import compile_stats

# The campaign hot-path merge shape (kernels_micro's fedavg case) and the
# NE-engine poibin batch.
FEDAVG_SHAPE = dict(n_clients=50, n_params=1 << 16)
POIBIN_SHAPE = dict(scenarios=64, n_nodes=50)


def _fedavg_case(key):
    n, p = FEDAVG_SHAPE["n_clients"], FEDAVG_SHAPE["n_params"]
    ks = jax.random.split(key, 3)
    g = jax.random.normal(ks[0], (p,))
    cf = jax.random.normal(ks[1], (n, p))
    mask = jax.random.bernoulli(ks[2], 0.5, (n,))
    return (g, cf, mask)


def _poibin_case(key):
    b, n = POIBIN_SHAPE["scenarios"], POIBIN_SHAPE["n_nodes"]
    return (jax.random.uniform(key, (b, n)),)


def measure(seed: int = 0, iters: int = 10) -> dict:
    """compile-vs-execute + cost_analysis for both kernels x both backends."""
    key = jax.random.PRNGKey(seed)
    cases = {
        "fedavg_agg": (ops.fedavg, _fedavg_case(key), FEDAVG_SHAPE),
        "poibin": (ops.poibin, _poibin_case(key), POIBIN_SHAPE),
    }
    ops.reset_dispatch_stats()
    kernels: dict[str, dict] = {}
    for name, (fn, args, shape) in cases.items():
        per_backend = {}
        for backend in ("pallas", "ref"):
            stats = compile_stats(functools.partial(fn, backend=backend),
                                  *args, iters=iters)
            per_backend[backend] = stats
            record(f"kernel_gap.{name}[{backend}]",
                   stats["execute"]["p50_us"],
                   f"compile {stats['compile_s']:.2f}s, "
                   f"{stats['flops']:.2e} flops, "
                   f"{stats['bytes_accessed']:.2e} B")
        ratio = (per_backend["pallas"]["execute"]["p50_us"]
                 / max(per_backend["ref"]["execute"]["p50_us"], 1e-9))
        record(f"kernel_gap.{name}.ratio", ratio,
               "pallas-interpret p50 / ref p50 (CPU; not a TPU projection)")
        kernels[name] = {"shape": shape, **per_backend,
                         "pallas_over_ref_p50": round(ratio, 2)}
    return {
        "note": "pallas rows are interpret mode on CPU: the execute gap is "
                "interpreter overhead, not kernel arithmetic — flops/bytes "
                "are XLA post-optimization estimates per compiled module",
        "iters": iters,
        "kernels": kernels,
        "dispatch_stats": ops.dispatch_stats(),
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_kernel_gap.json")
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args(argv)
    header()
    data = measure(iters=args.iters)
    write_artifact(args.json, "kernel_gap", data, seed=0)
    print(f"\nkernel gap localization -> {args.json}")


if __name__ == "__main__":
    main()
