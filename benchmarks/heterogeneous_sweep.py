"""≥500-scenario heterogeneous-NE sweep: batched engine vs. scalar loop.

The heterogeneous game is where population-scale incentive questions live
(free-rider stratification, heterogeneous PoA, who a uniform reward actually
moves) — and where the seed solver was hopeless: Python-loop Gauss-Seidel
with a full DFT pmf recompute per node per iteration takes seconds for a
*single* N=50 equilibrium. This benchmark times the two ways to run a
B-scenario (costs, gammas) sweep at N=50:

* ``scalar`` — loop ``best_response_dynamics_reference`` (the seed eager
  Gauss-Seidel) over scenarios. A ``--sample`` subset is timed and the total
  extrapolated (the full loop takes hours); pass ``--full-scalar`` for an
  exact number.
* ``batched`` — ``repro.core.asymmetric_batched.solve_heterogeneous``: the
  same damped Gauss-Seidel semantics as one vmapped jitted XLA program
  (leave-one-out pmf deconvolution instead of per-node recomputes).

Every batched NE is certified by the jitted ``verify_equilibrium_batched``
(max profitable unilateral deviation ≤ 1e-4) before the speedup is reported.
Emits ``name,us_per_call,derived`` CSV rows like the other benchmarks plus a
final ``speedup`` row; the acceptance bar is ≥ 100×.

Run:  PYTHONPATH=src:. python benchmarks/heterogeneous_sweep.py
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.asymmetric import (HeterogeneousGame,
                                   best_response_dynamics_reference)
from repro.core.asymmetric_batched import (poa_report, solve_heterogeneous,
                                           verify_equilibrium_batched)
from repro.core.duration import theoretical_duration
from benchmarks.common import header, record

N_NODES = 50
DAMPING = 0.6
MAX_ITERS = 300


def build_scenarios(batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    costs = jnp.asarray(rng.uniform(0.5, 12.0, (batch, N_NODES)))
    gammas = jnp.asarray(rng.uniform(0.2, 1.0, (batch, N_NODES)))
    return costs, gammas, theoretical_duration(N_NODES)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=500,
                    help="scenarios in the sweep (acceptance bar: >= 500)")
    ap.add_argument("--sample", type=int, default=3,
                    help="scalar scenarios to time (extrapolated to all)")
    ap.add_argument("--full-scalar", action="store_true",
                    help="loop the scalar solver over every scenario")
    args = ap.parse_args(argv)

    costs, gammas, dur = build_scenarios(args.batch)
    header()

    # -- batched: warm-up compile, then time one sweep + certification -------
    sol = solve_heterogeneous(costs, gammas, dur, damping=DAMPING,
                              max_iters=MAX_ITERS)
    jax.block_until_ready(sol.p)
    t0 = time.perf_counter()
    sol = solve_heterogeneous(costs, gammas, dur, damping=DAMPING,
                              max_iters=MAX_ITERS)
    jax.block_until_ready(sol.p)
    t_batched = time.perf_counter() - t0
    n_conv = int(jnp.sum(sol.converged))
    record("heterogeneous_sweep.batched_total", t_batched * 1e6,
           f"{args.batch} scenarios N={N_NODES}; {n_conv} converged")

    # certification (also jitted; timed separately so the solve number is
    # comparable to the scalar loop, which certifies nothing)
    dev = verify_equilibrium_batched(costs, gammas, dur, sol.p)
    jax.block_until_ready(dev)
    t0 = time.perf_counter()
    dev = verify_equilibrium_batched(costs, gammas, dur, sol.p)
    jax.block_until_ready(dev)
    t_verify = time.perf_counter() - t0
    max_dev = float(jnp.max(dev))
    record("heterogeneous_sweep.verify_total", t_verify * 1e6,
           f"max profitable deviation {max_dev:.2e} (bar <= 1e-4)")
    assert max_dev <= 1e-4, f"uncertified NE in the batch: {max_dev}"

    # full PoA report (solve + certify + planner + social costs)
    rep = poa_report(costs, gammas, dur, damping=DAMPING,
                     max_iters=MAX_ITERS)
    jax.block_until_ready(rep.poa)
    record("heterogeneous_sweep.poa_report", float("nan"),
           f"heterogeneous PoA in [{float(jnp.min(rep.poa)):.3f}, "
           f"{float(jnp.max(rep.poa)):.3f}]")

    # -- scalar loop (seed implementation) -----------------------------------
    rng = np.random.default_rng(1)
    total = args.batch
    if args.full_scalar:
        idx = np.arange(total)
    else:
        idx = rng.choice(total, size=min(args.sample, total), replace=False)
    t0 = time.perf_counter()
    for i in idx:
        game = HeterogeneousGame(costs=costs[i], gammas=gammas[i], dur=dur)
        best_response_dynamics_reference(game, damping=DAMPING,
                                         max_iters=MAX_ITERS)
    t_scalar_sample = time.perf_counter() - t0
    t_scalar = t_scalar_sample * (total / len(idx))
    tag = "measured" if args.full_scalar else f"extrapolated from {len(idx)}"
    record("heterogeneous_sweep.scalar_total", t_scalar * 1e6,
           f"{total} scenarios ({tag})")

    speedup = t_scalar / t_batched
    record("heterogeneous_sweep.speedup", speedup,
           f"target >= 100x; batched {t_batched:.2f}s vs scalar {t_scalar:.0f}s")
    print(f"\nbatched sweep: {t_batched:.2f}s for {total} scenarios "
          f"({t_batched / total * 1e3:.2f} ms/scenario), "
          f"certification {t_verify:.2f}s, max deviation {max_dev:.2e}")
    print(f"scalar loop:   {t_scalar:.0f}s ({tag}; "
          f"{t_scalar / total * 1e3:.0f} ms/scenario)")
    print(f"speedup: {speedup:.0f}x")


if __name__ == "__main__":
    main()
