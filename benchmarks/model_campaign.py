"""Real-model FL campaigns: model-zoo tasks through the scan-fused engine.

ISSUE 8 seam benchmark: wrap reduced registry models (a tiny transformer
LM and the paper's ResNet-18 client) into :func:`repro.federated.tasks.
model_task` and sweep B scenarios through :func:`run_campaigns`, measuring

* **engine vs reference** — the scan-fused campaign against the Python
  per-round reference loop (``--sample`` scenarios timed, extrapolated);
* **per-model per-backend round wall-clock** — ``backend=None`` (the
  model's plain jnp path), ``"ref"`` (kernels.ops jnp oracles) and
  ``"pallas"`` (interpret mode on CPU: a harness check, not a TPU
  projection) for kernel-backed families;
* **non-iid vs iid split** — final accuracy and energy of Dirichlet
  label-skewed shards vs the stateless iid streams, same scenarios.

Emits ``name,us_per_call,derived`` CSV rows and ``BENCH_model_campaign.json``
(``repro.obs/v1``; CI validates via ``tools/obs_report.py --check``).

Run:  PYTHONPATH=src:. python benchmarks/model_campaign.py
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core  # noqa: F401  (enables x64)
from repro.configs import ARCHITECTURES
from repro.federated.campaign import build_campaign, run_campaigns
from repro.federated.simulation import FLConfig, run_simulation_reference
from repro.federated.tasks import model_task
from repro.obs.export import write_artifact
from repro.optim import sgd
from benchmarks.common import header, record


def _model_cfgs() -> dict:
    lm = dataclasses.replace(
        ARCHITECTURES["stablelm-3b"].reduced(), n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64)
    return {"transformer-lm": (lm, ["plain", "ref", "pallas"]),
            "resnet18": (ARCHITECTURES["resnet18-cifar"].reduced(),
                         ["plain"])}


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--seq", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=1.0,
                    help="Dirichlet concentration of the non-iid split")
    ap.add_argument("--sample", type=int, default=2,
                    help="reference scenarios to time (extrapolated to all)")
    ap.add_argument("--json", default="BENCH_model_campaign.json")
    args = ap.parse_args(argv)

    fl = FLConfig(n_clients=args.clients, local_steps=2, batch_per_client=4,
                  max_rounds=args.rounds, seed=1)
    opt = sgd(0.1)
    ps = jnp.asarray(np.linspace(0.3, 0.9, args.scenarios), jnp.float32)
    n_camp = args.scenarios * args.rounds
    header()

    models: dict = {}
    for name, (cfg, backends) in _model_cfgs().items():
        entry: dict = {"family": cfg.family, "backends": {}}

        # -- per-backend scan-fused sweeps (iid streams) ---------------------
        res_plain = None
        for label in backends:
            backend = None if label == "plain" else label
            task = model_task(cfg, args.seq, backend=backend, val_size=32,
                              data_seed=fl.seed)
            engine = build_campaign(fl, *task.campaign_args(), opt)
            t0 = time.perf_counter()
            res = run_campaigns(fl, *task.campaign_args(), opt, ps,
                                engine=engine)
            jax.block_until_ready(res.energy_wh)
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = run_campaigns(fl, *task.campaign_args(), opt, ps,
                                engine=engine)
            jax.block_until_ready(res.energy_wh)
            warm_s = time.perf_counter() - t0
            entry["backends"][label] = {
                "warm_s": round(warm_s, 4),
                "round_ms": round(warm_s / n_camp * 1e3, 4),
                "compile_s": round(compile_s, 2),
            }
            record(f"model_campaign.round[{name},{label}]",
                   warm_s / n_camp * 1e6,
                   f"{args.scenarios} campaigns x {args.rounds} rounds x "
                   f"{args.clients} clients; compile {compile_s:.1f}s")
            if label == "plain":
                res_plain = res
                task_plain = task

        # -- engine vs Python reference loop ---------------------------------
        idx = np.linspace(0, args.scenarios - 1,
                          min(args.sample, args.scenarios)).astype(int)
        t0 = time.perf_counter()
        for i in idx:
            run_simulation_reference(fl, *task_plain.campaign_args(), opt,
                                     p=float(ps[i]))
        t_ref = (time.perf_counter() - t0) * (args.scenarios / len(idx))
        speedup = t_ref / entry["backends"]["plain"]["warm_s"]
        entry["reference_s"] = round(t_ref, 2)
        entry["reference_timing"] = f"extrapolated from {len(idx)}"
        entry["speedup"] = round(speedup, 1)
        record(f"model_campaign.speedup[{name}]", speedup,
               f"scan-fused vs reference loop "
               f"({entry['reference_timing']})")

        # -- non-iid (Dirichlet) vs iid accuracy/energy split ----------------
        task_skew = model_task(cfg, args.seq, partition="dirichlet",
                               alpha=args.alpha, n_clients=args.clients,
                               dataset_size=512, val_size=32,
                               data_seed=fl.seed)
        res_skew = run_campaigns(fl, *task_skew.campaign_args(), opt, ps)
        jax.block_until_ready(res_skew.energy_wh)
        split = {}
        for tag, r in (("iid", res_plain), ("noniid", res_skew)):
            split[tag] = {
                "final_acc_mean": round(
                    float(jnp.mean(r.acc_history[:, -1])), 4),
                "energy_wh_mean": round(float(jnp.mean(r.energy_wh)), 6),
            }
        split["noniid"]["alpha"] = args.alpha
        entry["iid_vs_noniid"] = split
        record(f"model_campaign.noniid_gap[{name}]",
               (split["iid"]["final_acc_mean"]
                - split["noniid"]["final_acc_mean"]) * 1e4,
               f"iid {split['iid']['final_acc_mean']:.3f} vs dirichlet"
               f"(a={args.alpha}) {split['noniid']['final_acc_mean']:.3f} "
               f"final acc (x1e-4)")
        models[name] = entry

    write_artifact(args.json, "model_campaign", {
        "scenarios": args.scenarios,
        "max_rounds": args.rounds,
        "n_clients": args.clients,
        "seq": args.seq,
        "models": models,
    }, seed=fl.seed, backend="ref")
    for name, entry in models.items():
        by = {k: v["round_ms"] for k, v in entry["backends"].items()}
        print(f"\n{name}: {by} ms/round, speedup {entry['speedup']}x, "
              f"iid/noniid final acc "
              f"{entry['iid_vs_noniid']['iid']['final_acc_mean']}/"
              f"{entry['iid_vs_noniid']['noniid']['final_acc_mean']}")
    print(f"-> {args.json}")


if __name__ == "__main__":
    main()
