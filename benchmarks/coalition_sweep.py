"""Coalition-formation sweep: certified partition equilibria at scale.

The coalition engine (:mod:`repro.core.coalition`) runs hedonic
best-switch dynamics over pooled FedAvg groups, with every coalition's
internal profile at the certified heterogeneous NE, as one jitted
vmap program. This sweep solves ``--scenarios`` (>= 200) random fleets,
**certifies every returned partition** with ``verify_partition_batched``
(no node gains more than 1e-6 by an in-coalition deviation or a coalition
switch; inner tol 1e-10 keeps the corner residual far below the bar),
benchmarks the equilibria against the coalition-structured planner
(partition PoA) and the grand-coalition NE (formation gain), and
spot-diffs the jitted dynamics against the eager Python oracle
``partition_equilibrium_reference`` on small side games (the oracle costs
tens of seconds per fleet, so it cannot follow the full sweep).

Emits ``name,us_per_call,derived`` CSV rows and ``BENCH_coalition.json``
(repro.obs/v1) with timing stats, certification evidence, and the
PoA/formation-gain distributions.

Run:  PYTHONPATH=src:. python benchmarks/coalition_sweep.py
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core  # noqa: F401  (enables x64)
from repro.core.coalition import (partition_equilibrium_reference,
                                  solve_partition)
from repro.core.duration import theoretical_duration
from repro.mechanisms import coalition_report
from repro.obs.export import write_artifact
from benchmarks.common import header, record, time_fn

N_NODES = 12
N_COALITIONS = 3
CAP = 6                  # >= ceil(N/M): every fleet has a feasible partition
TOL = 1e-10              # corner residual tol/damping x boundary slope << 1e-6
MAX_ITERS = 600          # geometric tail to 1e-10 outruns the default 200
CERT_TOL = 1e-6


def build_scenarios(batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    costs = jnp.asarray(rng.uniform(0.5, 10.0, (batch, N_NODES)))
    gammas = jnp.asarray(rng.uniform(0.2, 1.0, (batch, N_NODES)))
    return costs, gammas, theoretical_duration(n_nodes=N_NODES)


def spot_check_oracle(seed: int = 3) -> float:
    """Diff the jitted dynamics against the eager oracle on tiny fleets."""
    rng = np.random.default_rng(seed)
    worst = 0.0
    for n in (3, 4):
        dur = theoretical_duration(n_nodes=n, d_inf=30.0, slope=6.0)
        costs = jnp.asarray(rng.uniform(0.5, 8.0, (n,)))
        gammas = jnp.asarray(rng.uniform(0.2, 1.0, (n,)))
        sol = solve_partition(costs, gammas, dur, n_coalitions=2)
        assign_ref, p_ref, conv_ref, _ = partition_equilibrium_reference(
            costs, gammas, dur, n_coalitions=2)
        assert bool(sol.converged[0]) == conv_ref, f"n={n}: convergence skew"
        if not conv_ref:
            continue
        assert np.array_equal(np.asarray(sol.assign[0]),
                              np.asarray(assign_ref)), f"n={n}: partitions"
        worst = max(worst, float(np.max(np.abs(
            np.asarray(sol.p[0]) - np.asarray(p_ref)))))
        assert worst <= 1e-5, f"n={n}: profile drift {worst}"
    return worst


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", type=int, default=208,
                    help="fleets in the sweep (acceptance bar: >= 200)")
    ap.add_argument("--iters", type=int, default=3,
                    help="timed solver repetitions for the stats block")
    ap.add_argument("--json", default="BENCH_coalition.json")
    args = ap.parse_args(argv)

    costs, gammas, dur = build_scenarios(args.scenarios)
    header()

    # -- oracle spot checks (tiny fleets; the eager oracle can't follow) -----
    t0 = time.perf_counter()
    drift = spot_check_oracle()
    record("coalition_sweep.oracle_spot", (time.perf_counter() - t0) * 1e6,
           f"jitted dynamics == Python oracle; worst |dp| {drift:.2e}")

    # -- timed partition solves ----------------------------------------------
    stats = time_fn(
        lambda: solve_partition(costs, gammas, dur,
                                n_coalitions=N_COALITIONS, cap=CAP,
                                tol=TOL, max_iters=MAX_ITERS).p,
        iters=args.iters)
    record("coalition_sweep.solve_total", stats["p50_us"],
           f"{args.scenarios} fleets N={N_NODES} M={N_COALITIONS} cap={CAP}")

    # -- solve + certify + planner + grand-coalition benchmark ---------------
    t0 = time.perf_counter()
    rep = coalition_report(costs, gammas, dur, n_coalitions=N_COALITIONS,
                           cap=CAP, cert_tol=CERT_TOL, tol=TOL,
                           max_iters=MAX_ITERS)
    jax.block_until_ready(rep.partition.poa)
    t_report = time.perf_counter() - t0
    sol = rep.partition.solution

    n_conv = int(jnp.sum(sol.converged & sol.inner_converged))
    n_cert = int(jnp.sum(rep.certified))
    max_dev = float(jnp.max(rep.partition.deviation))
    record("coalition_sweep.report_total", t_report * 1e6,
           f"{n_conv} converged; {n_cert} certified; "
           f"max deviation {max_dev:.2e} (bar <= {CERT_TOL:g})")
    assert n_conv == args.scenarios, \
        f"unconverged partition dynamics: {args.scenarios - n_conv}"
    assert n_cert == args.scenarios, \
        f"uncertified partitions in the sweep: max deviation {max_dev}"

    poa = np.asarray(rep.partition.poa)
    gain = np.asarray(rep.formation_gain)
    sizes = np.asarray(sol.sizes)
    record("coalition_sweep.poa", float("nan"),
           f"partition PoA in [{poa.min():.3f}, {poa.max():.3f}]")
    record("coalition_sweep.formation_gain", float("nan"),
           f"grand-NE cost minus partition-NE cost in "
           f"[{gain.min():.3f}, {gain.max():.3f}]; "
           f"{int((gain > 0).sum())}/{args.scenarios} fleets prefer splitting")

    write_artifact(args.json, "coalition_sweep", {
        "scenarios": args.scenarios,
        "n_nodes": N_NODES,
        "n_coalitions": N_COALITIONS,
        "cap": CAP,
        "inner_tol": TOL,
        "max_iters": MAX_ITERS,
        "cert_tol": CERT_TOL,
        "converged": n_conv,
        "certified": n_cert,
        "max_deviation": max_dev,
        "oracle_spot_drift": drift,
        "solve_timing": stats,
        "report_s": round(t_report, 2),
        "switches": np.asarray(sol.switches).tolist(),
        "coalition_sizes": sizes.tolist(),
        "poa": np.round(poa, 6).tolist(),
        "ne_cost": np.round(np.asarray(rep.partition.ne_cost), 6).tolist(),
        "opt_cost": np.round(np.asarray(rep.partition.opt_cost), 6).tolist(),
        "grand_cost": np.round(np.asarray(rep.grand_cost), 6).tolist(),
        "formation_gain": np.round(gain, 6).tolist(),
    }, seed=0, backend="ref")
    print(f"\npartition sweep: {args.scenarios} fleets, all certified "
          f"(max deviation {max_dev:.2e}), PoA up to {poa.max():.3f}, "
          f"{int((gain > 0).sum())} fleets gain by splitting -> {args.json}")


if __name__ == "__main__":
    main()
