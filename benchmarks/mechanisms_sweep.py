"""1000-scenario (γ, c, N) NE sweep: batched solver vs. scalar loop.

Mechanism design solves the participation game as an inner loop — γ*
calibration, Stackelberg rate grids, scenario tables. This benchmark times
the two ways to do a 1000-scenario sweep:

* ``scalar`` — loop the pre-existing scalar pipeline (Python-level
  bisection + eager JAX: ``solve_symmetric_ne`` + ``centralized_optimum``
  + ``price_of_anarchy``, i.e. the old ``solve_game`` body) over every
  scenario. By default a ``--sample`` subset is timed and the total is
  extrapolated (the full scalar sweep takes tens of minutes); pass
  ``--full-scalar`` to loop all scenarios for an exact number.
* ``batched`` — ``repro.mechanisms.solve_scenarios``: scenarios grouped by
  N (shapes are static per N), one jitted XLA program per group.

Emits ``name,us_per_call,derived`` CSV rows like the other benchmarks and a
final ``speedup`` row; the acceptance bar is ≥ 10×.

Run:  PYTHONPATH=src:. python benchmarks/mechanisms_sweep.py
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.duration import theoretical_duration
from repro.core.game import (centralized_optimum, price_of_anarchy,
                             solve_symmetric_ne)
from repro.core.utility import UtilityParams
from repro.mechanisms import solve_scenarios
from benchmarks.common import header, record

GAMMAS = np.linspace(0.0, 1.2, 10)
COSTS = np.linspace(0.25, 12.0, 20)
N_NODES = (30, 40, 50, 60, 70)


def build_scenarios() -> tuple[list[UtilityParams], dict]:
    scenarios = [
        UtilityParams(gamma=float(g), cost=float(c), n_nodes=n)
        for n in N_NODES for g in GAMMAS for c in COSTS
    ]
    dur_for_n = {n: theoretical_duration(n) for n in N_NODES}
    return scenarios, dur_for_n


def solve_game_scalar(up: UtilityParams, dur) -> float:
    """The pre-batching scalar pipeline (old ``solve_game`` body)."""
    nes = solve_symmetric_ne(up, dur, grid_size=400)
    opt_p, opt_cost = centralized_optimum(up, dur)
    poa, _ = price_of_anarchy(nes, opt_cost, up, dur)
    return poa


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sample", type=int, default=20,
                    help="scalar scenarios to time (extrapolated to all)")
    ap.add_argument("--full-scalar", action="store_true",
                    help="loop the scalar solver over every scenario")
    args = ap.parse_args(argv)

    scenarios, dur_for_n = build_scenarios()
    total = len(scenarios)
    header()

    # -- batched: warm-up compiles (one program per distinct N), then time --
    sols = solve_scenarios(scenarios, dur_for_n)
    jax.block_until_ready([s.poa for s in sols])
    t0 = time.perf_counter()
    sols = solve_scenarios(scenarios, dur_for_n)
    poas = np.concatenate([np.asarray(s.poa) for s in sols])
    jax.block_until_ready(poas)
    t_batched = time.perf_counter() - t0
    record("mechanisms_sweep.batched_total", t_batched * 1e6,
           f"{total} scenarios; worst PoA {np.nanmax(poas[np.isfinite(poas)]):.2f}")

    # -- scalar loop -------------------------------------------------------
    rng = np.random.default_rng(0)
    if args.full_scalar:
        sample = scenarios
    else:
        idx = rng.choice(total, size=min(args.sample, total), replace=False)
        sample = [scenarios[i] for i in idx]
    t0 = time.perf_counter()
    for up in sample:
        solve_game_scalar(up, dur_for_n[up.n_nodes])
    t_scalar_sample = time.perf_counter() - t0
    t_scalar = t_scalar_sample * (total / len(sample))
    tag = "measured" if args.full_scalar else f"extrapolated from {len(sample)}"
    record("mechanisms_sweep.scalar_total", t_scalar * 1e6,
           f"{total} scenarios ({tag})")

    speedup = t_scalar / t_batched
    record("mechanisms_sweep.speedup", speedup,
           f"target >= 10x; batched {t_batched:.2f}s vs scalar {t_scalar:.1f}s")
    print(f"\nbatched sweep: {t_batched:.2f}s for {total} scenarios "
          f"({t_batched / total * 1e3:.2f} ms/scenario)")
    print(f"scalar loop:   {t_scalar:.1f}s ({tag}; "
          f"{t_scalar / total * 1e3:.0f} ms/scenario)")
    print(f"speedup: {speedup:.1f}x")


if __name__ == "__main__":
    main()
