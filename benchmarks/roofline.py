"""Roofline analysis from the dry-run artifacts (deliverable g).

For every (arch × shape × mesh) JSON produced by ``repro.launch.dryrun``:

    compute term    = HLO_FLOPs / (chips × 197e12)
    memory term     = HLO_bytes / (chips × 819e9)
    collective term = collective_bytes / (chips × 50e9)

``cost_analysis`` numbers come from the post-SPMD per-device module, so they
are already per-chip; global = per-chip × chips. Collective bytes use ring
factors (all-reduce 2×(n-1)/n ≈ 2, all-gather/reduce-scatter/all-to-all
(n-1)/n ≈ 1, collective-permute 1).

MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (fwd-only), with N_active
counting routed experts at top_k/n_experts utilization. The ratio
MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is useful
(remat + dispatch overheads show up here).

The md also carries a **measured kernel costs** section fed by the
``kernel_gap`` artifact (``benchmarks/kernel_gap.py`` →
``experiments/obs/BENCH_kernel_gap.json``): per kernel × backend, the
compiled module's own cost_analysis FLOPs/bytes and the *measured* warm
p50 — real numbers next to the analytic terms above.

Writes experiments/roofline.md and emits one CSV row per combo.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import record
from repro.configs import ARCHITECTURES, INPUT_SHAPES
from repro.models.registry import get_model

PEAK = 197e12
HBM = 819e9
ICI = 50e9

RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")
OUT_MD = os.path.join(os.path.dirname(ART_DIR), "roofline.md")

_param_cache: dict = {}


def param_counts(arch: str) -> tuple[float, float]:
    """(total params, active params per token)."""
    if arch in _param_cache:
        return _param_cache[arch]
    cfg = ARCHITECTURES[arch]
    api = get_model(cfg)
    sds = jax.eval_shape(lambda k: api.init(k)[0], jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(sds)[0]
    total = active = 0.0
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if cfg.moe is not None and "/moe/" in "/" + keys + "/" and \
                any(w in keys for w in ("w_gate", "w_up", "w_down")) and \
                "shared" not in keys:
            active += n * (cfg.moe.top_k / cfg.moe.n_experts)
        else:
            active += n
    _param_cache[arch] = (total, active)
    return total, active


def model_flops(arch: str, shape_name: str) -> float:
    cfg = ARCHITECTURES[arch]
    shape = INPUT_SHAPES[shape_name]
    _, active = param_counts(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch        # decode: one token


def _probe_correction(arch: str, shape: str, preset: str = "baseline") -> dict | None:
    """Per-layer cost deltas from the depth-probe artifacts.

    XLA cost_analysis counts a while/scan body once regardless of trip
    count; with probes at depths d1 < d2 the corrected full-depth cost is
    f(L) = f(d1) + (L - d1) * (f(d2) - f(d1)).
    """
    from repro.launch.dryrun import probe_depths
    cfg = ARCHITECTURES[arch]
    d1, d2 = probe_depths(cfg)
    sfx = "" if preset == "baseline" else f"__{preset}"
    p1 = os.path.join(ART_DIR, f"{arch}__{shape}__16x16{sfx}__d{d1}.json")
    p2 = os.path.join(ART_DIR, f"{arch}__{shape}__16x16{sfx}__d{d2}.json")
    if not (os.path.exists(p1) and os.path.exists(p2)):
        return None
    with open(p1) as f:
        a1 = json.load(f)
    with open(p2) as f:
        a2 = json.load(f)
    if not (a1.get("ok") and a2.get("ok")):
        return None

    def corr(get):
        f1, f2 = get(a1), get(a2)
        return f1 + (cfg.n_layers - d1) * max(f2 - f1, 0.0)

    return {
        "flops": corr(lambda a: a["cost"].get("flops", 0.0)),
        "bytes": corr(lambda a: a["cost"].get("bytes accessed", 0.0)),
        "coll": corr(lambda a: sum(v["bytes"] * RING_FACTOR[k]
                                   for k, v in a["collectives"].items())),
    }


def analyze_artifact(path: str) -> dict | None:
    with open(path) as f:
        d = json.load(f)
    import re as _re
    base = os.path.basename(path)
    if not d.get("ok") or _re.search(r"__d\d+\.json$", base):
        return None
    m = _re.match(r".+?__.+?__[\dx]+__(\w+)\.json$", base)
    preset = m.group(1) if m else "baseline"
    chips = d["sizes"]["n_devices"]
    flops_dev = d["cost"].get("flops", 0.0)
    bytes_dev = d["cost"].get("bytes accessed", 0.0)
    coll_dev = sum(v["bytes"] * RING_FACTOR[k]
                   for k, v in d["collectives"].items())
    corrected = False
    # depth probes exist for the single-pod mesh only; applying them to
    # 2x16x16 rows would claim per-device numbers measured on a different
    # partitioning, so multi-pod rows stay scan-uncorrected (marked).
    probe = (_probe_correction(d["arch"], d["shape"], preset)
             if d["mesh"] == "16x16" else None)
    if probe is not None:
        flops_dev = max(flops_dev, probe["flops"])
        bytes_dev = max(bytes_dev, probe["bytes"])
        coll_dev = max(coll_dev, probe["coll"])
        corrected = True
    t_compute = flops_dev / PEAK
    t_memory = bytes_dev / HBM
    t_coll = coll_dev / ICI
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(d["arch"], d["shape"])
    hlo_global = flops_dev * chips
    useful = mf / hlo_global if hlo_global else float("nan")
    return {
        "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
        "chips": chips,
        "t_compute": t_compute, "t_memory": t_memory,
        "t_collective": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "collective_bytes_dev": coll_dev,
        "mem_args_gb": d["memory"].get("argument_size_in_bytes", 0) / 1e9,
        "mem_temp_gb": d["memory"].get("temp_size_in_bytes", 0) / 1e9,
        "probe_corrected": corrected,
        "preset": preset,
    }


def suggestion(row: dict) -> str:
    dom = row["dominant"]
    if dom == "compute":
        if row["useful_ratio"] < 0.4:
            return ("compute-bound with low useful ratio: cut remat/redundant "
                    "compute (replicated attention heads, MoE dispatch cost)")
        return "compute-bound near peak: only model/scale changes move this"
    if dom == "memory":
        return ("memory-bound: fuse attention (flash kernel avoids S^2 "
                "materialization), shrink temps, bf16 activations")
    return ("collective-bound: reshard to cut all-gathers (FSDP -> TP swap), "
            "overlap collectives with compute, or shrink per-step traffic")


def kernel_rows() -> list[dict]:
    """Measured kernel costs from the latest ``kernel_gap`` artifact.

    Prefers a fresh ``BENCH_kernel_gap.json`` in the working directory,
    falling back to the checked-in ``experiments/obs`` copy; returns one
    row per kernel x backend with measured p50 and XLA FLOPs/bytes (and
    the arithmetic intensity they imply).
    """
    candidates = [
        "BENCH_kernel_gap.json",
        os.path.join(os.path.dirname(ART_DIR), "obs",
                     "BENCH_kernel_gap.json"),
    ]
    art = None
    for c in candidates:
        if os.path.exists(c):
            with open(c) as f:
                art = json.load(f)
            break
    if art is None:
        return []
    rows = []
    for kname, k in art["data"]["kernels"].items():
        for backend in ("pallas", "ref"):
            s = k[backend]
            flops, byts = s["flops"], s["bytes_accessed"]
            rows.append({
                "kernel": kname, "backend": backend,
                "compile_s": s["compile_s"],
                "p50_us": s["execute"]["p50_us"],
                "flops": flops, "bytes": byts,
                "ai": flops / byts if byts else float("nan"),
            })
    return rows


def run(emit_rows: bool = True) -> list[dict]:
    rows = []
    if not os.path.isdir(ART_DIR):
        # Keep going: the measured-kernel section below only needs the
        # kernel_gap artifact, not the dry-run estimates.
        print("no dry-run artifacts; run python -m repro.launch.dryrun --all")
    else:
        for f in sorted(os.listdir(ART_DIR)):
            if not f.endswith(".json"):
                continue
            r = analyze_artifact(os.path.join(ART_DIR, f))
            if r:
                rows.append(r)

    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as md:
        md.write("# Roofline terms per (arch × shape × mesh)\n\n")
        md.write("Terms in seconds/step on TPU v5e "
                 "(197 TF bf16, 819 GB/s HBM, 50 GB/s ICI).\n\n")
        md.write("16x16 rows are depth-probe corrected (scan-body x L); "
                 "2x16x16 rows prove multi-pod lowering but report raw "
                 "scan-counted costs (no multi-pod probes) — compare "
                 "meshes via the §Dry-run pod-scaling table instead.\n\n")
        md.write("| arch | shape | mesh | preset | compute | memory | "
                 "collective | dominant | MODEL_FLOPS/HLO | next move |\n")
        md.write("|---|---|---|---|---|---|---|---|---|---|\n")
        for r in rows:
            md.write(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {r['preset']} "
                f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} "
                f"| {r['t_collective']:.3e} | **{r['dominant']}** "
                f"| {r['useful_ratio']:.2f} | {suggestion(r)} |\n")
        krows = kernel_rows()
        if krows:
            md.write("\n## Measured kernel costs (CPU; kernel_gap "
                     "artifact)\n\n")
            md.write("XLA post-optimization cost_analysis per compiled "
                     "module + warm p50 wall time; pallas rows run in "
                     "interpret mode, so their wall times bound the "
                     "harness, not a TPU.\n\n")
            md.write("| kernel | backend | compile (s) | p50 (µs) | "
                     "FLOPs | bytes | AI (flop/byte) |\n")
            md.write("|---|---|---|---|---|---|---|\n")
            for k in krows:
                md.write(f"| {k['kernel']} | {k['backend']} "
                         f"| {k['compile_s']:.2f} | {k['p50_us']:.1f} "
                         f"| {k['flops']:.2e} | {k['bytes']:.2e} "
                         f"| {k['ai']:.3f} |\n")
    if emit_rows:
        for k in kernel_rows():
            record(f"roofline_kernel_{k['kernel']}[{k['backend']}]",
                   k["p50_us"],
                   f"measured: {k['flops']:.2e} flops, {k['bytes']:.2e} B, "
                   f"AI={k['ai']:.3f}")
        for r in rows:
            if r["mesh"] != "16x16" or r["preset"] != "baseline":
                continue        # CSV rows: single-pod baselines per the spec
            name = f"roofline_{r['arch']}_{r['shape']}"
            worst = max(r["t_compute"], r["t_memory"], r["t_collective"])
            record(name, 0.0,
                   f"dom={r['dominant']} comp={r['t_compute']:.2e}s "
                   f"mem={r['t_memory']:.2e}s coll={r['t_collective']:.2e}s "
                   f"useful={r['useful_ratio']:.2f}")
        record("roofline_md", 0.0, f"wrote {OUT_MD} ({len(rows)} combos)")
    return rows


if __name__ == "__main__":
    run()
