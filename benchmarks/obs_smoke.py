"""End-to-end observability smoke: one tiny fully-instrumented campaign.

Runs a small scenario sweep with every obs channel on at once —

* in-carry :class:`~repro.obs.metrics.MetricStream` (per-round
  participation / merge norm / ledger delta / accuracy),
* :class:`~repro.obs.events.EventSink` tapped from inside the jitted scan
  (``jax.debug.callback``) appending JSONL,
* :class:`~repro.obs.trace.SpanTracer` spans around the host phases with
  Chrome-trace export (load in https://ui.perfetto.dev),

— then cross-checks the instrumented outputs against an uninstrumented run
(bitwise) and writes three artifacts: ``OBS_events.jsonl``,
``TRACE_obs_smoke.json``, ``BENCH_obs_smoke.json``. CI validates all three
with ``tools/obs_report.py --check``.

Run:  PYTHONPATH=src:. python benchmarks/obs_smoke.py
"""
from __future__ import annotations

import argparse
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

import repro.core  # noqa: F401  (enables x64)
from repro.federated.campaign import run_campaigns
from repro.federated.simulation import FLConfig
from repro.federated.tasks import synthetic_mlp_task
from repro.obs import EventSink, ObsConfig, SpanTracer
from repro.obs.export import write_artifact
from repro.optim import sgd


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_obs_smoke.json")
    ap.add_argument("--events", default="OBS_events.jsonl")
    ap.add_argument("--trace", default="TRACE_obs_smoke.json")
    ap.add_argument("--scenarios", type=int, default=4)
    args = ap.parse_args(argv)

    task = synthetic_mlp_task()
    fl = FLConfig(n_clients=5, local_steps=1, batch_per_client=8,
                  max_rounds=8, target_acc=0.73, seed=3)
    opt = sgd(0.15)
    ps = jnp.asarray(np.linspace(0.2, 0.8, args.scenarios), jnp.float32)

    tracer = SpanTracer(process_name="obs_smoke")
    with tracer.span("baseline", scenarios=args.scenarios):
        base = run_campaigns(fl, *task.campaign_args(), opt, ps)
        jax.block_until_ready(base.acc_history)

    # the sink appends (crash/interleave safety); start a fresh stream here
    pathlib.Path(args.events).unlink(missing_ok=True)
    with EventSink(args.events) as sink:
        obs = ObsConfig(enabled=True, events=True, sink=sink)
        with tracer.span("instrumented_compile+run"):
            res = run_campaigns(fl, *task.campaign_args(), opt, ps, obs=obs)
            jax.block_until_ready(res.acc_history)
        with tracer.span("instrumented_warm"):
            res = run_campaigns(fl, *task.campaign_args(), opt, ps, obs=obs)
            jax.block_until_ready(res.acc_history)
        sink.flush()
        n_events = len(sink)

    with tracer.span("readout"):
        # instrumentation must not perturb the program: bitwise check
        np.testing.assert_array_equal(np.asarray(res.acc_history),
                                      np.asarray(base.acc_history))
        np.testing.assert_array_equal(np.asarray(res.ledger.per_node_j),
                                      np.asarray(base.ledger.per_node_j))
        summary = res.metrics.summary()

    tracer.save(args.trace)
    write_artifact(args.json, "obs_smoke", {
        "scenarios": args.scenarios,
        "max_rounds": fl.max_rounds,
        "bitwise_equal_to_uninstrumented": True,
        "events": n_events,
        "metrics": summary,
        "spans": tracer.summary(),
    }, seed=fl.seed, backend="ref")
    print(f"obs smoke: {n_events} events -> {args.events}; "
          f"trace -> {args.trace}; artifact -> {args.json}")


if __name__ == "__main__":
    main()
