"""One benchmark per paper table/figure (§IV).

table2  — d and E vs participation probability p (FL simulation at reduced
          scale + the calibrated analytic model at paper scale).
fig1    — linearity of E vs d (fit R² on Table II data + our model).
fig2    — utility vs p at c=0 (eq. 11 over the fitted duration model).
fig3    — NE contour over (gamma, c).
fig4    — participation probability: centralized vs NE with/without incentive.
fig5    — utility of centralized vs NE solutions vs c.
fig6    — PoA vs c with and without the AoI incentive.

Each emits ``name,us_per_call,derived`` rows; "derived" carries the
reproduced quantity compared against the paper's claim.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.core.duration import PAPER_TABLE_II, paper_duration_model
from repro.core.energy import EnergyParams, calibrate_from_table, J_PER_WH
from repro.core.game import centralized_optimum, solve_game, solve_symmetric_ne
from repro.core.poibin import expected_duration
from repro.core.utility import UtilityParams, social_utility
from benchmarks.common import record, time_fn

N = 50
GAMMA_STAR = 0.6          # paper: "γ ≈ 0.6 obtains the highest participation"


def _dur():
    return paper_duration_model()


def table2_sweep():
    """d(p) and E(p): analytic reproduction of Table II(b) + FL sim spots."""
    dur = _dur()
    ep = calibrate_from_table()
    t0 = time.perf_counter()
    errs_d, errs_e = [], []
    for p, d_ref, _, e_ref, _ in PAPER_TABLE_II:
        pv = jnp.full((N,), float(p))
        d_hat = float(expected_duration(pv, dur.table()))
        e_hat = d_hat * float(
            N * ep.e_idle_j + N * p * (ep.e_participant_j - ep.e_idle_j)
        ) / J_PER_WH
        errs_d.append(abs(d_hat - d_ref) / d_ref)
        errs_e.append(abs(e_hat - e_ref) / e_ref)
    us = (time.perf_counter() - t0) * 1e6 / len(PAPER_TABLE_II)
    record("table2_duration_fit", us,
           f"median|rel err| d={np.median(errs_d):.3f} "
           f"E={np.median(errs_e):.3f} over {len(PAPER_TABLE_II)} rows")

    # small live FL simulation sweep (reduced scale, same pipeline)
    from repro.federated.simulation import FLConfig, run_simulation
    from repro.data.synthetic import SyntheticCifar
    from repro.optim import sgd
    data = SyntheticCifar(noise=3.2)

    def init_params(key):
        k1, k2 = jax.random.split(key)
        d = 32 * 32 * 3
        return {"w1": jax.random.normal(k1, (d, 32)) * d ** -0.5,
                "b1": jnp.zeros(32),
                "w2": jax.random.normal(k2, (32, 10)) * 32 ** -0.5,
                "b2": jnp.zeros(10)}

    def fwd(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss_fn(p, b):
        lp = jax.nn.log_softmax(fwd(p, b["images"]))
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1))

    def eval_fn(p, b):
        return jnp.mean(jnp.argmax(fwd(p, b["images"]), -1) == b["labels"])

    def client_data(cid, rnd, n, steps):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(1), cid), rnd)
        return jax.vmap(lambda k: data.batch(k, n))(
            jax.random.split(key, steps))

    from repro.federated.campaign import build_campaign

    rows = []
    fl = FLConfig(n_clients=16, local_steps=2, batch_per_client=8,
                  max_rounds=60, target_acc=0.73, seed=2)
    # one compiled scan program shared across the p sweep; warm it with an
    # untimed call so no timed row absorbs the one-time compile
    engine = build_campaign(fl, init_params, loss_fn, eval_fn, client_data,
                            data.val_set(256), sgd(0.04))
    run_simulation(fl, init_params, loss_fn, eval_fn, client_data,
                   data.val_set(256), sgd(0.04), p=0.15, engine=engine)
    for p in (0.15, 0.3, 0.6):
        t0 = time.perf_counter()
        res = run_simulation(fl, init_params, loss_fn, eval_fn, client_data,
                             data.val_set(256), sgd(0.04), p=p,
                             engine=engine)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((p, res.rounds, res.energy_wh))
        record(f"table2_sim_p{p}", us,
               f"d={res.rounds} E={res.energy_wh:.1f}Wh "
               f"converged={res.converged}")
    # monotone trend check: more participation, fewer rounds (at these p's)
    ds = [r[1] for r in rows]
    record("table2_sim_trend", 0.0,
           f"d(0.15)={ds[0]} >= d(0.6)={ds[2]}: {ds[0] >= ds[2]}")


def fig1_energy_linearity():
    """E vs d is ~affine: regression R² on the paper's own table + our model."""
    t0 = time.perf_counter()
    d = PAPER_TABLE_II[:, 1]
    e = PAPER_TABLE_II[:, 3]
    A = np.stack([d, np.ones_like(d)], 1)
    coef, *_ = np.linalg.lstsq(A, e, rcond=None)
    resid = e - A @ coef
    r2 = 1 - resid.var() / e.var()
    us = (time.perf_counter() - t0) * 1e6
    record("fig1_energy_vs_rounds", us,
           f"slope={coef[0]:.2f}Wh/round intercept={coef[1]:.1f}Wh "
           f"R2={r2:.4f} (paper: ~linear)")


def fig2_utility_curve():
    """u(p) at c=0, gamma=0: peak location reproduces Fig. 2's shape."""
    dur = _dur()
    up = UtilityParams(gamma=0.0, cost=0.0, n_nodes=N)
    grid = jnp.linspace(0.02, 1.0, 197)
    t0 = time.perf_counter()
    vals = jax.vmap(lambda p: social_utility(p, up, dur))(grid)
    us = (time.perf_counter() - t0) * 1e6
    peak = float(grid[int(jnp.argmax(vals))])
    record("fig2_utility_c0", us,
           f"argmax_p={peak:.3f} (paper Fig.2 peak ~0.6-0.7) "
           f"u(peak)={float(jnp.max(vals)):.2f}")


def fig3_ne_contour():
    """NE over the (gamma, c) plane — coarse contour."""
    dur = _dur()
    gammas = [0.0, 0.3, 0.6, 1.0]
    costs = [0.5, 2.0, 5.0]
    t0 = time.perf_counter()
    cells = []
    for g in gammas:
        for c in costs:
            nes = solve_symmetric_ne(UtilityParams(gamma=g, cost=c,
                                                   n_nodes=N), dur,
                                     grid_size=300)
            cells.append(max(nes) if nes else 0.0)
    us = (time.perf_counter() - t0) * 1e6 / len(cells)
    arr = np.asarray(cells).reshape(len(gammas), len(costs))
    best_gamma = gammas[int(arr.mean(axis=1).argmax())]
    record("fig3_ne_contour", us,
           f"best gamma={best_gamma} (paper: ~0.6); "
           f"p(g=0.6 c=2)={arr[2][1]:.3f}")


def fig4_participation():
    """Centralized vs NE (γ=0 and γ=0.6) participation across c."""
    dur = _dur()
    t0 = time.perf_counter()
    rows = []
    for c in (0.5, 1.5, 3.0, 6.0):
        opt_p, _ = centralized_optimum(UtilityParams(gamma=0, cost=c,
                                                     n_nodes=N), dur)
        ne0 = solve_symmetric_ne(UtilityParams(gamma=0.0, cost=c, n_nodes=N),
                                 dur, grid_size=400)
        ne1 = solve_symmetric_ne(UtilityParams(gamma=GAMMA_STAR, cost=c,
                                               n_nodes=N), dur, grid_size=400)
        rows.append((c, opt_p, min(ne0) if ne0 else 0.0,
                     max(ne1) if ne1 else 0.0))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    c, o, n0, n1 = rows[1]
    record("fig4_participation", us,
           f"c={c}: opt={o:.2f} (paper .61) ne={n0:.2f} (paper .24) "
           f"ne_aoi={n1:.2f} (paper .6); collapse@c=6: "
           f"ne={rows[3][2]:.3f} ne_aoi={rows[3][3]:.3f}")


def fig5_utility_vs_c():
    dur = _dur()
    t0 = time.perf_counter()
    gaps = []
    for c in (0.5, 1.5, 3.0, 6.0):
        sol0 = solve_game(UtilityParams(gamma=0.0, cost=c, n_nodes=N), dur)
        u_opt = -sol0.opt_cost
        u_ne = -max(sol0.ne_costs) if sol0.ne_costs else float("-inf")
        gaps.append((c, u_opt, u_ne))
    us = (time.perf_counter() - t0) * 1e6 / len(gaps)
    drop = gaps[2]
    record("fig5_utility_vs_c", us,
           f"c={drop[0]}: u_opt={drop[1]:.1f} u_ne={drop[2]:.1f} "
           f"(NE drop grows with c: "
           f"{all(gaps[i][1]-gaps[i][2] <= gaps[i+1][1]-gaps[i+1][2] for i in range(len(gaps)-1))})")


def fig6_poa():
    """PoA vs c, with and without incentive (paper: 1.28 -> inf vs ~1)."""
    dur = _dur()
    t0 = time.perf_counter()
    out = []
    for c in (0.5, 1.5, 3.0, 6.0, 12.0):
        p0 = solve_game(UtilityParams(gamma=0.0, cost=c, n_nodes=N), dur).poa
        p1 = solve_game(UtilityParams(gamma=GAMMA_STAR, cost=c, n_nodes=N),
                        dur).poa
        out.append((c, p0, p1))
    us = (time.perf_counter() - t0) * 1e6 / len(out)
    txt = " ".join(f"c={c}:{p0:.2f}/{p1:.2f}" for c, p0, p1 in out)
    ok = all(p1 <= p0 + 1e-9 for _, p0, p1 in out)
    record("fig6_poa", us,
           f"{txt} [no-inc/inc] incentive_dominates={ok} "
           f"(paper: 1.28@c0 vs ~1)")


def beyond_heterogeneous():
    """Beyond-paper: asymmetric NE for a mixed battery/mains fleet."""
    import jax.numpy as jnp
    from repro.core.asymmetric import (HeterogeneousGame,
                                       best_response_dynamics,
                                       planner_coordinate_descent)
    from repro.core.duration import theoretical_duration
    n = 12
    dur = theoretical_duration(n_nodes=n, d_inf=35.0, slope=8.0)
    game = HeterogeneousGame(costs=jnp.asarray([0.5] * 6 + [9.0] * 6),
                             gammas=jnp.full((n,), 0.6), dur=dur)
    t0 = time.perf_counter()
    p, conv, iters = best_response_dynamics(game, damping=0.6)
    us = (time.perf_counter() - t0) * 1e6
    ne_cost = float(game.social_cost(p))
    het = float(game.social_cost(planner_coordinate_descent(game, p)))
    record("beyond_heterogeneous_ne", us,
           f"converged={conv} iters={iters} "
           f"p_cheap={float(p[0]):.2f} p_dear={float(p[-1]):.2f} "
           f"het_PoA={ne_cost/het:.3f}")


def run_all():
    table2_sweep()
    fig1_energy_linearity()
    fig2_utility_curve()
    fig3_ne_contour()
    fig4_participation()
    fig5_utility_vs_c()
    fig6_poa()
    beyond_heterogeneous()
