"""Kernel micro-benchmarks.

CPU wall times of interpret-mode Pallas are NOT TPU projections — they
validate the harness and catch pathological regressions; the derived column
carries the analytic arithmetic intensity that the TPU roofline uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import record, time_fn
from repro.kernels import ref
from repro.kernels.ops import attention, fedavg, rwkv6, ssm


def run_all():
    key = jax.random.PRNGKey(0)

    b, s, h, d = 1, 256, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    us = time_fn(lambda: attention(q, k, v, block_q=64, block_k=64))
    flops = 4 * b * h * s * s * d / 2  # causal
    bytes_ = (3 * q.size + q.size) * 4
    record("kernel_flash_attention", us,
           f"AI={flops/bytes_:.1f} flop/byte (causal {s}x{s}, interpret)")
    us_ref = time_fn(lambda: ref.flash_attention_ref(q, k, v))
    record("kernel_flash_attention_ref", us_ref, "pure-jnp oracle")

    b, s, h, d = 1, 128, 2, 64
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, d)) * 0.5
    kk = jax.random.normal(ks[1], (b, s, h, d)) * 0.5
    vv = jax.random.normal(ks[2], (b, s, h, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    us = time_fn(lambda: rwkv6(r, kk, vv, w, u, block_t=64))
    record("kernel_rwkv6_scan", us,
           f"state={d}x{d} fp32/head, {s} steps (interpret)")
    us_ref = time_fn(lambda: ref.rwkv6_scan_ref(r, kk, vv, w, u))
    record("kernel_rwkv6_scan_ref", us_ref, "pure-jnp oracle")

    bsz, sl, din, n = 1, 128, 64, 16
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (bsz, sl, din))
    delta = jax.nn.softplus(jax.random.normal(ks[1], (bsz, sl, din)))
    a_log = jax.random.normal(ks[2], (din, n)) * 0.5
    bb = jax.random.normal(ks[3], (bsz, sl, n))
    cc = jax.random.normal(ks[4], (bsz, sl, n))
    dsk = jax.random.normal(ks[5], (din,))
    us = time_fn(lambda: ssm(x, delta, a_log, bb, cc, dsk, block_t=64,
                             block_d=64))
    record("kernel_ssm_scan", us, f"state={din}x{n} fp32 (interpret)")

    t, d, v = 128, 64, 2048
    ks = jax.random.split(key, 3)
    h = jax.random.normal(ks[0], (t, d))
    wv = jax.random.normal(ks[1], (d, v)) * d ** -0.5
    lab = jax.random.randint(ks[2], (t,), 0, v)
    from repro.kernels.ops import cross_entropy
    us = time_fn(lambda: cross_entropy(h, wv, lab, block_t=64, block_v=512))
    saved = t * v * 4
    record("kernel_fused_ce", us,
           f"avoids {saved/1e6:.1f} MB logits materialization (interpret)")

    n_cl, p = 50, 1 << 16
    ks = jax.random.split(key, 3)
    g = jax.random.normal(ks[0], (p,))
    cf = jax.random.normal(ks[1], (n_cl, p))
    mask = jax.random.bernoulli(ks[2], 0.5, (n_cl,))
    us = time_fn(lambda: fedavg(g, cf, mask))
    gbps = (cf.size + g.size) * 4 / (us * 1e-6) / 1e9
    record("kernel_fedavg_agg", us,
           f"{n_cl}x{p} merge, {gbps:.2f} GB/s effective (interpret)")
