"""Kernel micro-benchmarks: every Pallas kernel vs its jnp reference.

CPU wall times of interpret-mode Pallas are NOT TPU projections — they
validate the harness and catch pathological regressions; the derived column
carries the analytic arithmetic intensity that the TPU roofline uses. Each
kernel is timed on both backends of the dispatch layer
(``repro.kernels.ops``), so the emitted ``BENCH_kernels.json`` doubles as a
record of which backend a deployment should pin where.

Run:  PYTHONPATH=src:. python benchmarks/kernels_micro.py   # -> BENCH_kernels.json
(also invoked by benchmarks/run.py and as a CI smoke step.)
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import header, record, time_fn
from repro.kernels import ref
from repro.kernels.ops import (attention, cross_entropy, fedavg, poibin,
                               rwkv6, ssm)
from repro.obs.export import write_artifact


def run_all() -> dict[str, dict]:
    """Time every kernel (pallas-interpret + ref backends); return the
    results keyed by kernel name for the JSON artifact."""
    results: dict[str, dict] = {}

    def bench(name: str, pallas_fn, ref_fn, derived) -> None:
        """``derived`` is the label string, or a callable of the measured
        p50 microseconds (for bandwidth-style labels) so nothing is timed
        twice just to format it."""
        stats = time_fn(pallas_fn)
        label = derived(stats["p50_us"]) if callable(derived) else derived
        record(f"kernel_{name}", stats["p50_us"], f"{label} (interpret)")
        stats_ref = time_fn(ref_fn)
        record(f"kernel_{name}_ref", stats_ref["p50_us"],
               "pure-jnp reference backend")
        results[name] = {"pallas_interpret": stats,
                         "ref": stats_ref, "derived": label}

    key = jax.random.PRNGKey(0)

    b, s, h, d = 1, 256, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    flops = 4 * b * h * s * s * d / 2  # causal
    bytes_ = (3 * q.size + q.size) * 4
    bench("flash_attention",
          lambda: attention(q, k, v, block_q=64, block_k=64),
          lambda: attention(q, k, v, backend="ref"),
          f"AI={flops/bytes_:.1f} flop/byte (causal {s}x{s})")

    b, s, h, d = 1, 128, 2, 64
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, d)) * 0.5
    kk = jax.random.normal(ks[1], (b, s, h, d)) * 0.5
    vv = jax.random.normal(ks[2], (b, s, h, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    bench("rwkv6_scan",
          lambda: rwkv6(r, kk, vv, w, u, block_t=64),
          lambda: rwkv6(r, kk, vv, w, u, backend="ref"),
          f"state={d}x{d} fp32/head, {s} steps")

    bsz, sl, din, n = 1, 128, 64, 16
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (bsz, sl, din))
    delta = jax.nn.softplus(jax.random.normal(ks[1], (bsz, sl, din)))
    a_log = jax.random.normal(ks[2], (din, n)) * 0.5
    bb = jax.random.normal(ks[3], (bsz, sl, n))
    cc = jax.random.normal(ks[4], (bsz, sl, n))
    dsk = jax.random.normal(ks[5], (din,))
    bench("ssm_scan",
          lambda: ssm(x, delta, a_log, bb, cc, dsk, block_t=64, block_d=64),
          lambda: ssm(x, delta, a_log, bb, cc, dsk, backend="ref"),
          f"state={din}x{n} fp32")

    t, d, v = 128, 64, 2048
    ks = jax.random.split(key, 3)
    hh = jax.random.normal(ks[0], (t, d))
    wv = jax.random.normal(ks[1], (d, v)) * d ** -0.5
    lab = jax.random.randint(ks[2], (t,), 0, v)
    saved = t * v * 4
    bench("fused_ce",
          lambda: cross_entropy(hh, wv, lab, block_t=64, block_v=512),
          lambda: cross_entropy(hh, wv, lab, backend="ref"),
          f"avoids {saved/1e6:.1f} MB logits materialization")

    n_cl, p = 50, 1 << 16
    ks = jax.random.split(key, 3)
    g = jax.random.normal(ks[0], (p,))
    cf = jax.random.normal(ks[1], (n_cl, p))
    mask = jax.random.bernoulli(ks[2], 0.5, (n_cl,))
    bytes_moved = (cf.size + g.size) * 4
    bench("fedavg_agg",
          lambda: fedavg(g, cf, mask),
          lambda: fedavg(g, cf, mask, backend="ref"),
          lambda us: (f"{n_cl}x{p} merge, "
                      f"{bytes_moved / (us * 1e-6) / 1e9:.2f} GB/s "
                      f"effective"))

    # the NE-engine hot path: pmf + all leave-one-out pmfs for a (B, N) batch
    b_sc, n_nodes = 64, 50
    p_mat = jax.random.uniform(jax.random.PRNGKey(9), (b_sc, n_nodes))
    bench("poibin_dft",
          lambda: poibin(p_mat),
          lambda: poibin(p_mat, backend="ref"),
          f"{b_sc}x{n_nodes} scenarios: DFT pmf + {n_nodes} loo deconvs each")

    return results


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_kernels.json")
    args = ap.parse_args(argv)
    header()
    results = run_all()
    write_artifact(args.json, "kernels_micro", {
        "backend_default": "pallas (interpret on CPU; compiled on TPU)",
        "note": "interpret-mode wall times validate the harness, they are "
                "not TPU projections; 'ref' is the pure-jnp backend "
                "(`backend='ref'` / REPRO_KERNEL_BACKEND=ref)",
        "kernels": results,
    }, seed=0)
    print(f"\n{len(results)} kernels -> {args.json}")


if __name__ == "__main__":
    main()
