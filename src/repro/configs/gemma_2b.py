"""gemma-2b — dense decoder LM, GeGLU, MQA, head_dim=256 [arXiv:2403.08295]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,             # MQA
    d_ff=16384,
    vocab=256000,
    source="arXiv:2403.08295 (GeGLU, head_dim=256, MQA)",
    attn="gqa",
    head_dim=256,
    act="geglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    sliding_window=4096,      # long_500k via sliding-window variant
)
