"""Model/run configuration schema.

Every assigned architecture is a :class:`ModelConfig`; every assigned input
shape is a :class:`ShapeSpec`. ``reduced()`` produces the CPU smoke-test
variant (≤2 layers, d_model ≤ 512, ≤4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

__all__ = ["ModelConfig", "ShapeSpec", "INPUT_SHAPES", "MLAConfig", "MoEConfig",
           "SSMConfig"]

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio", "vision"]
AttnKind = Literal["gqa", "mla", "none"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Top-k routed experts with optional always-on shared experts."""

    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # layers before the first MoE layer use a dense MLP of this width
    first_dense_layers: int = 0
    dense_ff: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-recurrence head config (Mamba-style or RWKV)."""

    state_dim: int = 16
    head_dim: int = 64          # rwkv wkv head size / mamba head grouping
    expand: int = 1             # d_inner = expand * d_model (mamba branch)
    dt_rank: int = 0            # 0 -> ceil(d_model/16)
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""             # paper/model-card citation
    attn: AttnKind = "gqa"
    head_dim: int = 0            # 0 -> d_model // n_heads
    act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    sliding_window: int = 0      # 0 = full causal; >0 enables long_500k decode
    # -- modality frontends (stubs; see DESIGN.md) --------------------------
    n_patches: int = 0           # vlm: image patch tokens per example
    d_frontend: int = 0          # vlm: vision encoder output dim (projector in)
    n_frames: int = 0            # audio: encoder frames per example
    encoder_layers: int = 0      # audio: encoder depth (enc-dec)
    # -- numerics ------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logit_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 64

    @property
    def is_encoder_decoder(self) -> bool:
        return self.family == "audio"

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant of the same family (system-prompt bounds)."""
        changes: dict = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=min(self.d_model, 256),
            vocab=min(self.vocab, 512),
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.family == "vision":
            # d_model is the convnet stem width; 8 keeps the reduced
            # ResNet-18 at ~0.2M params for CPU campaign smoke tests.
            changes["d_model"] = min(self.d_model, 8)
        if self.n_heads:
            n_heads = min(self.n_heads, 4)
            changes["n_heads"] = n_heads
            ratio = max(1, self.n_heads // max(self.n_kv_heads, 1))
            changes["n_kv_heads"] = max(1, n_heads // min(ratio, n_heads))
            changes["head_dim"] = changes["d_model"] // n_heads
        else:
            changes["n_heads"] = 0
            changes["n_kv_heads"] = 0
            changes["head_dim"] = 32
        changes["d_ff"] = min(self.d_ff, 512)
        if self.mla is not None:
            changes["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                       qk_nope_head_dim=16, qk_rope_head_dim=8,
                                       v_head_dim=16)
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                dense_ff=min(self.moe.dense_ff, 512))
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=min(self.ssm.state_dim, 8), head_dim=32)
        if self.sliding_window:
            changes["sliding_window"] = min(self.sliding_window, 64)
        if self.n_patches:
            changes["n_patches"] = 16
            changes["d_frontend"] = 64
        if self.n_frames:
            changes["n_frames"] = 32
        if self.encoder_layers:
            changes["encoder_layers"] = min(self.encoder_layers, 2)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """An assigned (seq_len, global_batch) workload."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
