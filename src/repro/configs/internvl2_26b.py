"""internvl2-26b — VLM: InternViT (stub) + InternLM2 backbone [arXiv:2404.16821].

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (InternViT-6B output dim 3200); the framework
implements the projector MLP + the 48-layer InternLM2 language backbone.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,             # GQA kv=8
    d_ff=16384,
    vocab=92553,
    source="arXiv:2404.16821 (InternViT + InternLM2)",
    attn="gqa",
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    sliding_window=4096,      # long_500k via sliding-window variant
    n_patches=256,            # one 448px tile -> 256 visual tokens
    d_frontend=3200,          # InternViT-6B hidden size
)
