"""stablelm-3b — dense decoder LM [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,            # GQA kv=32 (full MHA)
    d_ff=6912,
    vocab=50304,
    source="hf:stabilityai/stablelm-2-1_6b",
    attn="gqa",
    act="swiglu",
    norm="layernorm",
    rope_theta=10000.0,
    sliding_window=4096,      # long_500k via sliding-window variant (DESIGN §4)
)
