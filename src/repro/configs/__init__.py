"""Architecture configs. ``get_config(name)`` resolves --arch ids."""
from __future__ import annotations

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeSpec

from repro.configs.stablelm_3b import CONFIG as STABLELM_3B
from repro.configs.internvl2_26b import CONFIG as INTERNVL2_26B
from repro.configs.minicpm3_4b import CONFIG as MINICPM3_4B
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.phi4_mini_3_8b import CONFIG as PHI4_MINI_3_8B
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.hymba_1_5b import CONFIG as HYMBA_1_5B
from repro.configs.rwkv6_3b import CONFIG as RWKV6_3B
from repro.configs.deepseek_v2_236b import CONFIG as DEEPSEEK_V2_236B
from repro.configs.gemma_2b import CONFIG as GEMMA_2B
from repro.configs.resnet18_cifar import CONFIG as RESNET18_CIFAR

ARCHITECTURES: dict[str, ModelConfig] = {
    c.name: c for c in [
        STABLELM_3B, INTERNVL2_26B, MINICPM3_4B, WHISPER_TINY,
        PHI4_MINI_3_8B, OLMOE_1B_7B, HYMBA_1_5B, RWKV6_3B,
        DEEPSEEK_V2_236B, GEMMA_2B, RESNET18_CIFAR,
    ]
}

# (arch, shape) pairs excluded from the 10x4 grid, with reasons (DESIGN.md §4).
SKIPS: dict[tuple[str, str], str] = {
    ("whisper-tiny", "long_500k"):
        "encoder-decoder ASR with bounded (30 s) audio context; a 512k-token "
        "autoregressive decode is not meaningful",
    ("resnet18-cifar", "prefill_32k"):
        "image classifier: no token sequence, no prefill/decode paths",
    ("resnet18-cifar", "decode_32k"):
        "image classifier: no token sequence, no prefill/decode paths",
    ("resnet18-cifar", "long_500k"):
        "image classifier: no token sequence, no prefill/decode paths",
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}"
        ) from None


def grid() -> list[tuple[ModelConfig, ShapeSpec]]:
    """The assigned 10 x 4 grid minus documented skips."""
    out = []
    for arch in ARCHITECTURES.values():
        for shape in INPUT_SHAPES.values():
            if (arch.name, shape.name) not in SKIPS:
                out.append((arch, shape))
    return out
