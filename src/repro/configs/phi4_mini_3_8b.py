"""phi4-mini-3.8b — dense decoder LM, RoPE+SwiGLU+GQA [arXiv:2412.08905]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,             # GQA kv=8
    d_ff=8192,
    vocab=200064,
    source="arXiv:2412.08905 (RoPE SwiGLU GQA)",
    attn="gqa",
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    sliding_window=4096,      # long_500k via sliding-window variant
)
