"""rwkv6-3b (Finch) — attention-free RNN with data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,                # attention-free
    n_kv_heads=0,
    d_ff=8960,
    vocab=65536,
    source="arXiv:2404.05892 (Finch, data-dependent decay)",
    attn="none",
    act="swiglu",             # rwkv channel-mix uses squared relu; see models/rwkv.py
    norm="layernorm",
    ssm=SSMConfig(head_dim=64, state_dim=64),   # wkv head size 64 -> 40 heads
)
