"""olmoe-1b-7b — MoE decoder LM, 64 experts top-8 [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,                # per-expert FF width
    vocab=50304,
    source="arXiv:2409.02060 (64 experts top-8)",
    attn="gqa",
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=64, top_k=8, n_shared=0,
                  capacity_factor=1.25, router_aux_weight=0.01),
    sliding_window=4096,      # long_500k via sliding-window variant
)
