"""deepseek-v2-236b — MoE + MLA [arXiv:2405.04434].

MLA kv_lora=512; 2 shared + 160 routed experts, top-6; first layer dense.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,                # per-expert FF width
    vocab=102400,
    source="arXiv:2405.04434 (MLA kv_lora=512, 2 shared + 160 routed top-6)",
    attn="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2,
                  capacity_factor=1.25, router_aux_weight=0.003,
                  first_dense_layers=1, dense_ff=12288),
    sliding_window=4096,      # long_500k via sliding-window variant
)
