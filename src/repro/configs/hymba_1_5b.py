"""hymba-1.5b — hybrid: parallel attention + mamba heads [arXiv:2411.13676]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,             # GQA kv=5
    d_ff=5504,
    vocab=32001,
    source="arXiv:2411.13676 (parallel attn+mamba heads)",
    attn="gqa",
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    ssm=SSMConfig(state_dim=16, expand=1, conv_width=4),
    sliding_window=1024,      # Hymba uses SWA in most layers; native long ctx
)
