"""minicpm3-4b — dense decoder LM with MLA [hf:openbmb/MiniCPM3-4B]."""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,            # MLA: kv heads = q heads over the latent cache
    d_ff=6400,
    vocab=73448,
    source="hf:openbmb/MiniCPM3-4B (MLA)",
    attn="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    sliding_window=4096,      # long_500k via sliding-window variant
)
