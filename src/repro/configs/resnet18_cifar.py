"""ResNet-18 on CIFAR-class images — the paper's federated workload.

The paper's Table I trains ResNet-18 (w = 11,181,642 params, S_w = 44.73 MB
fp32) federated over 50 IoT nodes. Mapping onto :class:`ModelConfig`:
``d_model`` is the stem width (stages are x1/x2/x4/x8 multiples) and
``vocab`` is the class count; attention/FFN fields are unused
(``attn="none"``, ``d_ff=0``). ``reduced()`` shrinks the width to 8
(~0.2M params) for CPU campaign smoke tests.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="resnet18-cifar",
    family="vision",
    n_layers=18,            # fixed ResNet-18 topology (4 stages x 2 blocks)
    d_model=64,             # stem width (paper: 11.18M params at 64)
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=10,               # CIFAR-10 classes
    source="He et al. 2015; paper Table I (w=11,181,642, S_w=44.73 MB)",
    attn="none",
    param_dtype="float32",
    compute_dtype="float32",
)
