"""whisper-tiny — audio encoder-decoder [arXiv:2212.04356].

Mel-spectrogram + conv feature extractor are STUBS per the assignment:
``input_specs()`` provides precomputed frame embeddings (n_frames, d_model)
for the encoder; the framework implements the 4+4 layer transformer.
long_500k is SKIPPED (DESIGN.md §4): a bounded-audio-context ASR decoder has
no meaningful 512k-token autoregressive decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,               # decoder layers
    encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    source="arXiv:2212.04356 (enc-dec, conv frontend stubbed)",
    attn="gqa",
    act="gelu",
    norm="layernorm",
    n_frames=1500,            # 30 s of audio at 50 Hz after conv frontend
)
