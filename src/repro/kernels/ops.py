"""Public jit'd wrappers + backend dispatch for the Pallas kernels.

Every kernel has two interchangeable implementations:

* ``backend="pallas"`` — the Pallas TPU kernel. On TPU it runs compiled; on
  this CPU container it runs in ``interpret=True`` mode (the kernel body
  executes step-by-step in Python/XLA, validating the exact TPU program
  logic). Held to the jnp references to tight tolerance in
  ``tests/test_kernels.py`` / ``tests/test_property_poibin.py``.
* ``backend="ref"`` — the pure-jnp oracle (:mod:`repro.kernels.ref`), or at
  higher-level call sites (``repro.federated.server.fedavg_merge``,
  ``repro.core.asymmetric_batched``) the pre-existing jnp code path, which
  stays **bitwise** identical to the dispatch-free behaviour.

Backend resolution order (first hit wins):

1. the explicit ``backend=`` argument of the call,
2. a process-wide override installed with :func:`set_backend` (or
   temporarily via the :func:`backend_scope` context manager),
3. the ``REPRO_KERNEL_BACKEND`` environment variable,
4. the call site's default — ``"pallas"`` for the model kernels below
   (attention / rwkv6 / ssm / cross_entropy / fedavg / poibin wrappers),
   ``"ref"`` for the campaign and game hot loops so their results stay
   bitwise-reproducible unless a kernel backend is asked for.

Resolution happens at **trace time**: a jitted program (e.g. a prebuilt
``build_campaign`` engine) bakes in whatever backend was resolved when it
was traced, and later ``set_backend``/env changes do not retrace it.
"""
from __future__ import annotations

import contextlib
import os
import sys

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fedavg_agg import fedavg_agg as _fedavg_pallas
from repro.kernels.fused_ce import fused_ce as _fused_ce_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.poibin_dft import poibin_dft as _poibin_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6_pallas
from repro.kernels.ssm_scan import ssm_scan as _ssm_pallas

__all__ = ["BACKENDS", "ENV_VAR", "resolve_backend", "set_backend",
           "backend_scope", "use_pallas",
           "dispatch_stats", "reset_dispatch_stats",
           "attention", "rwkv6", "ssm", "fedavg", "cross_entropy",
           "fedavg_merge_pallas", "poibin", "poibin_pmf"]

# ---------------------------------------------------------------------------
# Differentiable pallas dispatch
# ---------------------------------------------------------------------------
#
# The Pallas kernels carry no AD rules, so a bare kernel call inside
# ``jax.grad`` (the FL client step) fails to differentiate. The model-kernel
# wrappers below therefore route ``backend="pallas"`` through a
# ``jax.custom_vjp`` pair: the forward pass runs the Pallas kernel (grid
# program validated in interpret mode on CPU, compiled on TPU) and the
# backward pass linearizes the jnp reference oracle at the same primals.
# Forward values are exactly the kernel's; gradients are the oracle's
# evaluated at those primals — the same <=2e-6 parity class as the forward,
# pinned through a full training round in ``tests/test_task_factory.py``.
# Integer args (CE labels) flow through as float0 cotangents.


def _pallas_fwd_ref_bwd(pallas_fn, ref_fn):
    """Build a differentiable function: ``pallas_fn`` fwd, ``ref_fn``-vjp bwd.

    Both callables must take the same positional args and return the same
    pytree structure. Residuals are the primal args (the oracle re-linearizes
    in the backward pass — no kernel-side activation plumbing needed).
    """
    @jax.custom_vjp
    def fn(*args):
        return pallas_fn(*args)

    def fwd(*args):
        return pallas_fn(*args), args

    def bwd(args, ct):
        _, vjp = jax.vjp(ref_fn, *args)
        return vjp(ct)

    fn.defvjp(fwd, bwd)
    return fn

BACKENDS = ("pallas", "ref")
ENV_VAR = "REPRO_KERNEL_BACKEND"

_override: str | None = None   # set_backend() state; beats the env var
_env_warned = False            # warn-once latch for a bogus env value

#: (call_site, backend) -> number of trace-time dispatch resolutions.
_dispatch_counts: dict[tuple[str, str], int] = {}


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _validate(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"backend {backend!r} not in {BACKENDS}")
    return backend


def _env_backend() -> str | None:
    """The env-var backend, or ``None`` — warning once on a bogus value.

    A typo'd ``REPRO_KERNEL_BACKEND`` must not blow up an import chain (or
    every later resolution) with an exception the user can't trace back to
    their shell profile: it is reported once on stderr and then ignored,
    so resolution falls through to the override/default chain.
    """
    global _env_warned
    env = os.environ.get(ENV_VAR)
    if not env:
        return None
    if env not in BACKENDS:
        if not _env_warned:
            print(f"repro.kernels.ops: ignoring {ENV_VAR}={env!r} "
                  f"(unknown backend; expected one of {BACKENDS})",
                  file=sys.stderr)
            _env_warned = True
        return None
    return env


_env_backend()   # surface a bogus env value at import, not mid-sweep


def resolve_backend(backend: str | None = None, *,
                    default: str = "pallas",
                    site: str | None = None) -> str:
    """Resolve a ``backend=`` argument to ``"pallas"`` or ``"ref"``.

    Precedence (first hit wins):

    1. the explicit ``backend=`` argument — always honoured, so a call
       site can pin itself regardless of process state (invalid values
       raise ``ValueError``);
    2. a process-wide :func:`set_backend` override (or its scoped form
       :func:`backend_scope`) — programmatic control, beats the env;
    3. the ``REPRO_KERNEL_BACKEND`` environment variable — deploy-time
       control without code changes (an *unknown* value is ignored with a
       one-time stderr warning rather than raising, so a typo'd shell
       export can't break imports);
    4. ``default`` — the call site's own default: ``"pallas"`` for the
       model-kernel wrappers, ``"ref"`` for the bitwise-reproducible
       campaign/game hot loops.

    Resolution happens at **trace time** (a jitted program bakes in the
    backend it was traced with). ``site`` names the call site for the
    dispatch telemetry: every resolution with a ``site`` increments a
    ``(site, backend)`` counter readable via :func:`dispatch_stats`.

    Debugging a backend regression with the counters::

        from repro.kernels import ops
        ops.reset_dispatch_stats()
        run_the_slow_sweep(...)
        print(ops.dispatch_stats())
        # {'server.fedavg_merge': {'pallas': 1}, 'ops.poibin': {'ref': 2}}

    The stats say which call sites resolved to which backend *while
    tracing* — exactly the map needed to localize a "the sweep is slower
    on pallas" report to the kernel/call-site pair responsible (see
    ``benchmarks/kernel_gap.py`` for the packaged version).
    """
    resolved = _resolve(backend, default)
    if site is not None:
        key = (site, resolved)
        _dispatch_counts[key] = _dispatch_counts.get(key, 0) + 1
    return resolved


def _resolve(backend: str | None, default: str) -> str:
    if backend is not None:
        return _validate(backend)
    if _override is not None:
        return _override
    env = _env_backend()
    if env is not None:
        return env
    return _validate(default)


def dispatch_stats() -> dict[str, dict[str, int]]:
    """Trace-time dispatch counters: ``{site: {backend: count}}``.

    Counts *resolutions* (one per trace of each call site), not runtime
    executions — a jitted program resolves once when traced and then runs
    the baked-in backend. Sites are only counted when the wrapper passes
    ``site=`` (all wrappers in this module and the campaign/game hot-path
    call sites do).
    """
    out: dict[str, dict[str, int]] = {}
    for (site, backend), count in sorted(_dispatch_counts.items()):
        out.setdefault(site, {})[backend] = count
    return out


def reset_dispatch_stats() -> None:
    """Zero the dispatch counters (start of a measured region)."""
    _dispatch_counts.clear()


def set_backend(backend: str | None) -> str | None:
    """Install a process-wide backend override (``None`` clears it).

    Returns the previous override so callers can restore it; prefer
    :func:`backend_scope` for temporary pinning. Only affects programs
    traced *after* the call (see module docstring).
    """
    global _override
    prev = _override
    _override = None if backend is None else _validate(backend)
    return prev


@contextlib.contextmanager
def backend_scope(backend: str):
    """Context manager pinning every dispatched call inside to ``backend``."""
    prev = set_backend(backend)
    try:
        yield
    finally:
        set_backend(prev)


def use_pallas() -> bool:
    """Whether dispatched calls currently resolve to the Pallas kernels.

    The kernels themselves are always *available* (interpret mode on CPU);
    this reports the outcome of :func:`resolve_backend` at its ``"pallas"``
    default — i.e. ``False`` only when a ``set_backend``/env override pins
    the process to the jnp references.
    """
    return resolve_backend() == "pallas"


# ---------------------------------------------------------------------------
# Model kernels (default backend: pallas)
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128,
              backend: str | None = None):
    """Flash attention. q: (B,S,H,D); k,v: (B,S,KV,D) -> (B,S,H,D)."""
    if resolve_backend(backend, site="ops.attention") == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    fn = _pallas_fwd_ref_bwd(
        lambda q, k, v: _flash_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=_interpret()),
        lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=causal,
                                                window=window))
    return fn(q, k, v)


def rwkv6(r, k, v, w, u, *, block_t: int = 256, backend: str | None = None):
    """WKV6 recurrence. r,k,v,w: (B,S,H,D); u: (H,D) -> (out, state)."""
    if resolve_backend(backend, site="ops.rwkv6") == "ref":
        return ref.rwkv6_scan_ref(r, k, v, w, u)
    fn = _pallas_fwd_ref_bwd(
        lambda *a: _rwkv6_pallas(*a, block_t=block_t,
                                 interpret=_interpret()),
        ref.rwkv6_scan_ref)
    return fn(r, k, v, w, u)


def ssm(x, delta, a_log, b, c, d_skip, *, block_t: int = 256,
        block_d: int = 512, backend: str | None = None):
    """Mamba selective scan. x,delta: (B,S,Din) -> (y, h_final)."""
    if resolve_backend(backend, site="ops.ssm") == "ref":
        return ref.ssm_scan_ref(x, delta, a_log, b, c, d_skip)
    fn = _pallas_fwd_ref_bwd(
        lambda *a: _ssm_pallas(*a, block_t=block_t, block_d=block_d,
                               interpret=_interpret()),
        ref.ssm_scan_ref)
    return fn(x, delta, a_log, b, c, d_skip)


def cross_entropy(hidden, w_vocab, labels, *, block_t: int = 128,
                  block_v: int = 512, backend: str | None = None):
    """Fused per-token NLL without materializing (T, V) logits in HBM."""
    if resolve_backend(backend, site="ops.cross_entropy") == "ref":
        return ref.fused_ce_ref(hidden, w_vocab, labels)
    fn = _pallas_fwd_ref_bwd(
        lambda *a: _fused_ce_pallas(*a, block_t=block_t, block_v=block_v,
                                    interpret=_interpret()),
        ref.fused_ce_ref)
    return fn(hidden, w_vocab, labels)


# ---------------------------------------------------------------------------
# FedAvg merge (the campaign hot path)
# ---------------------------------------------------------------------------

def fedavg(global_flat, client_flat, mask, *, block_p: int = 2048,
           backend: str | None = None):
    """Masked FedAvg merge on flat params.

    global_flat: (P,); client_flat: (N,P); mask: (N,) bool or float
    (pre-scaled weights) -> (P,). Ragged P is padded to ``block_p`` inside
    the kernel wrapper; N = 1 and the all-zero mask (previous-global
    fallback) are supported.
    """
    if resolve_backend(backend, site="ops.fedavg") == "ref":
        return ref.fedavg_agg_ref(global_flat, client_flat, mask)
    return _fedavg_pallas(global_flat, client_flat, mask, block_p=block_p,
                          interpret=_interpret())


def fedavg_merge_pallas(global_params, client_params, mask, *,
                        block_p: int = 2048):
    """Pallas twin of :func:`repro.federated.server.fedavg_merge`.

    Flattens the pytree **once** into a single (P,) global / (N, P) client
    buffer (fp32), runs the fused kernel over ``block_p`` tiles, and
    restores structure and per-leaf dtypes. Non-fp32 leaves (f64 params
    under x64, bf16) round-trip through fp32 — the kernel dtype policy —
    so this path is parity-tested to tolerance, not bitwise
    (``tests/test_kernels.py``). Vmapping this over a scenario batch adds
    a grid dimension to the kernel (the campaign engine's pallas path).
    """
    g_leaves = jax.tree.leaves(global_params)
    c_leaves = jax.tree.leaves(client_params)
    sizes = [int(x.size) for x in g_leaves]
    g_flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                              for x in g_leaves])
    c_flat = jnp.concatenate([c.reshape(c.shape[0], -1).astype(jnp.float32)
                              for c in c_leaves], axis=1)
    merged = fedavg(g_flat, c_flat, mask, block_p=block_p, backend="pallas")
    out, off = [], 0
    for g, size in zip(g_leaves, sizes):
        out.append(merged[off:off + size].reshape(g.shape).astype(g.dtype))
        off += size
    return jax.tree.unflatten(jax.tree.structure(global_params), out)


# ---------------------------------------------------------------------------
# Batched Poisson-Binomial (the NE-engine hot path)
# ---------------------------------------------------------------------------

def poibin(p_mat, *, block_b: int = 8, backend: str | None = None):
    """DFT pmf + all leave-one-out pmfs for a (B, N) probability matrix.

    Returns ``(pmf (B, N+1), loo (B, N, N+1))`` in ``p_mat``'s dtype;
    ``loo[b, i]`` is the pmf of scenario b's nodes excluding node i (last
    entry zero). Kernel arithmetic is fp32 (oracle:
    :func:`repro.kernels.ref.poibin_dft_ref`); the ``"ref"`` backend runs
    that oracle in the input dtype.
    """
    if resolve_backend(backend, site="ops.poibin") == "ref":
        return ref.poibin_dft_ref(p_mat)
    return _poibin_pallas(p_mat, block_b=block_b, with_loo=True,
                          interpret=_interpret())


def poibin_pmf(p_mat, *, block_b: int = 8, backend: str | None = None):
    """(B, N) probability matrix -> (B, N+1) Poisson-Binomial pmfs.

    The pmf-only variant of :func:`poibin` (the leave-one-out pass is
    skipped entirely — e.g. the social-cost evaluation only needs pmfs).
    """
    if resolve_backend(backend, site="ops.poibin_pmf") == "ref":
        return ref.poibin_dft_ref(p_mat, with_loo=False)
    return _poibin_pallas(p_mat, block_b=block_b, with_loo=False,
                          interpret=_interpret())
