"""Public jit'd wrappers for the Pallas kernels.

On TPU the kernels run compiled; on this CPU container they run in
``interpret=True`` mode (the kernel body executes step-by-step in Python/XLA,
validating the exact TPU program logic). ``use_pallas()`` reports whether the
model layer should route through these or the pure-jnp references.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fedavg_agg import fedavg_agg as _fedavg_pallas
from repro.kernels.fused_ce import fused_ce as _fused_ce_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6_pallas
from repro.kernels.ssm_scan import ssm_scan as _ssm_pallas

__all__ = ["attention", "rwkv6", "ssm", "fedavg", "cross_entropy",
           "use_pallas", "fedavg_merge_pallas"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def use_pallas() -> bool:
    """Pallas path is always available (interpret on CPU); models opt in."""
    return True


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128):
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         block_q=block_q, block_k=block_k,
                         interpret=_interpret())


def rwkv6(r, k, v, w, u, *, block_t: int = 256):
    return _rwkv6_pallas(r, k, v, w, u, block_t=block_t,
                         interpret=_interpret())


def ssm(x, delta, a_log, b, c, d_skip, *, block_t: int = 256,
        block_d: int = 512):
    return _ssm_pallas(x, delta, a_log, b, c, d_skip, block_t=block_t,
                       block_d=block_d, interpret=_interpret())


def fedavg(global_flat, client_flat, mask, *, block_p: int = 2048):
    return _fedavg_pallas(global_flat, client_flat, mask, block_p=block_p,
                          interpret=_interpret())


def fedavg_merge_pallas(global_params, client_params, mask):
    """Drop-in replacement for federated.server.fedavg_merge: flattens the
    pytree, runs the fused kernel, restores structure."""
    g_leaves = jax.tree.leaves(global_params)
    c_leaves = jax.tree.leaves(client_params)
    sizes = [int(x.size) for x in g_leaves]
    g_flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                              for x in g_leaves])
    c_flat = jnp.concatenate([c.reshape(c.shape[0], -1).astype(jnp.float32)
                              for c in c_leaves], axis=1)
    merged = fedavg(g_flat, c_flat, mask)
    out, off = [], 0
    for g, size in zip(g_leaves, sizes):
        out.append(merged[off:off + size].reshape(g.shape).astype(g.dtype))
        off += size
    return jax.tree.unflatten(jax.tree.structure(global_params), out)


def cross_entropy(hidden, w_vocab, labels, *, block_t: int = 128,
                  block_v: int = 512):
    """Fused per-token NLL without materializing (T, V) logits in HBM."""
    return _fused_ce_pallas(hidden, w_vocab, labels, block_t=block_t,
                            block_v=block_v, interpret=_interpret())
