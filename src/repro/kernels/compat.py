"""Version shims for the Pallas TPU API surface the kernels rely on.

The Mosaic compiler-params dataclass was renamed across JAX releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``); every kernel
module imports :data:`CompilerParams` from here so the whole layer tracks
whichever name the installed JAX provides.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams"]

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
