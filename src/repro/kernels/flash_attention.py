"""Flash attention Pallas TPU kernel (causal / sliding-window, GQA).

Streaming-softmax attention with explicit VMEM tiling:

* grid = (batch, q_heads, q_blocks, kv_blocks); the kv axis is the innermost
  ("arbitrary") dimension so the fp32 accumulators in VMEM scratch carry
  across kv iterations of one (b, h, qi) tile.
* BlockSpecs stream (BQ, D) query tiles against (BK, D) key/value tiles —
  per-tile VMEM = BQ·D + 2·BK·D + BQ·BK (+ fp32 accumulators), e.g.
  (128, 128)-tiles with D=128 in bf16: ~0.5 MB, far under the ~16 MB v5e
  VMEM budget.
* MXU alignment: BQ, BK, D are multiples of 128 at production shapes (the
  CPU interpret tests also sweep ragged shapes to exercise the masking).
* Causal skip: kv tiles strictly above the diagonal do zero work via
  @pl.when; the sliding-window skip mirrors it on the stale left edge —
  long-window decode only touches ceil(W/BK) tiles per query tile.
* GQA: kv tiles are indexed by h // (H/KV) so query-head groups share loads.

Oracle: :func:`repro.kernels.ref.flash_attention_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, seq_len: int,
            causal: bool, window: int, n_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile-level skips (traced scalars; zero work when false)
    should = jnp.asarray(True)
    if causal:
        should = jnp.logical_and(should,
                                 k_start <= q_start + block_q - 1)
    if window > 0:
        should = jnp.logical_and(
            should, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(should)
    def compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)                 # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)                 # (BK, D)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (BQ, BK)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos < seq_len                               # ragged tail
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_scr[...]                                  # (BQ,)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
        # fully-masked-so-far rows keep m == NEG_INF: no correction term
        correction = jnp.where(m_prev == NEG_INF, 0.0,
                               jnp.exp(m_prev - m_new))
        p = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * correction + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * correction[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B,S,H,D); k,v: (B,S,KV,D) -> (B,S,H,D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    scale = d ** -0.5

    qt = jnp.moveaxis(q, 2, 1)                               # (B,H,S,D)
    kt = jnp.moveaxis(k, 2, 1)                               # (B,KV,S,D)
    vt = jnp.moveaxis(v, 2, 1)

    block_q = min(block_q, s)
    block_k = min(block_k, s)
    n_q = pl.cdiv(s, block_q)
    n_k = pl.cdiv(s, block_k)
    if n_q * block_q != s:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, n_q * block_q - s), (0, 0)))
    if n_k * block_k != s:
        pad = n_k * block_k - s
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k, seq_len=s,
        causal=causal, window=window, n_kv_blocks=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qq, kk: (bb, hh // groups, kk, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qq, kk: (bb, hh // groups, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, n_q * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)

    out = out[:, :, :s, :]
    return jnp.moveaxis(out, 1, 2)                           # (B,S,H,D)
