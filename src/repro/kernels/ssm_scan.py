"""Selective-scan (Mamba) Pallas TPU kernel — Hymba's SSM branch.

TPU adaptation: the CUDA kernel parallelizes over channels with one thread
each; here a (Din_tile, N) fp32 state is VMEM-resident and the kernel
consumes (BT,)-length time tiles, vectorizing the diagonal recurrence over
the channel tile on the VPU:

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t B_t) ⊙ x_t ;   y_t = h_t C_t + D x_t

* grid = (batch, channel_tiles, time_tiles), time innermost/"arbitrary" so
  the state scratch carries.
* Per-tile VMEM: BT·DC (x, Δ) + 2·BT·N (B, C) + DC·N state; DC=512, N=16,
  BT=256 fp32 ≈ 1.3 MB.

Oracle: :func:`repro.kernels.ref.ssm_scan_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref, y_ref, h_out_ref,
            h_scr, *, block_t: int, n_t_blocks: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)           # (BT, DC)
    dt = dt_ref[0].astype(jnp.float32)         # (BT, DC)
    a = a_ref[...].astype(jnp.float32)         # (DC, N)
    bsel = b_ref[0].astype(jnp.float32)        # (BT, N)
    csel = c_ref[0].astype(jnp.float32)        # (BT, N)
    dskip = dskip_ref[...].astype(jnp.float32)  # (DC,)

    neg_a = -jnp.exp(a)                        # (DC, N)

    def step(t, carry):
        h, ys = carry                           # h: (DC, N)
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)[0]      # (DC,)
        dtt = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]    # (DC,)
        bt = jax.lax.dynamic_slice_in_dim(bsel, t, 1, 0)[0]   # (N,)
        ct = jax.lax.dynamic_slice_in_dim(csel, t, 1, 0)[0]   # (N,)
        da = jnp.exp(dtt[:, None] * neg_a)                    # (DC, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = (h * ct[None, :]).sum(axis=1) + dskip * xt        # (DC,)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y[None], t, 0)
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros_like(x)
    h, ys = jax.lax.fori_loop(0, block_t, step, (h0, ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ti == n_t_blocks - 1)
    def write_state():
        h_out_ref[0] = h


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "interpret"))
def ssm_scan(x, delta, a_log, b, c, d_skip, *, block_t: int = 256,
             block_d: int = 512, interpret: bool = False):
    """x, delta: (B,S,Din); a_log: (Din,N); b,c: (B,S,N); d_skip: (Din,).

    Returns (y (B,S,Din), h_final (B,Din,N) fp32).
    """
    bsz, s, d_in = x.shape
    n = a_log.shape[1]
    block_t = min(block_t, s)
    block_d = min(block_d, d_in)
    n_t = pl.cdiv(s, block_t)
    n_d = pl.cdiv(d_in, block_d)
    pad_t = n_t * block_t - s
    pad_d = n_d * block_d - d_in

    xt = jnp.moveaxis(x, 1, 1)
    if pad_t or pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, pad_d)))
        delta = jnp.pad(delta, ((0, 0), (0, pad_t), (0, pad_d)))
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_t), (0, 0)))
        a_log = jnp.pad(a_log, ((0, pad_d), (0, 0)))
        d_skip = jnp.pad(d_skip, ((0, pad_d),))

    kernel = functools.partial(_kernel, block_t=block_t, n_t_blocks=n_t)
    y, h = pl.pallas_call(
        kernel,
        grid=(bsz, n_d, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, block_d),
                         lambda bb, dd, tt: (bb, tt, dd)),
            pl.BlockSpec((1, block_t, block_d),
                         lambda bb, dd, tt: (bb, tt, dd)),
            pl.BlockSpec((block_d, n), lambda bb, dd, tt: (dd, 0)),
            pl.BlockSpec((1, block_t, n), lambda bb, dd, tt: (bb, tt, 0)),
            pl.BlockSpec((1, block_t, n), lambda bb, dd, tt: (bb, tt, 0)),
            pl.BlockSpec((block_d,), lambda bb, dd, tt: (dd,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d),
                         lambda bb, dd, tt: (bb, tt, dd)),
            pl.BlockSpec((1, block_d, n), lambda bb, dd, tt: (bb, dd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n_t * block_t, n_d * block_d), x.dtype),
            jax.ShapeDtypeStruct((bsz, n_d * block_d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, delta, a_log, b, c, d_skip)
    y = y[:, :s, :d_in]
    return y, h[:, :d_in, :]
