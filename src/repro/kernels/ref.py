"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "rwkv6_scan_ref", "ssm_scan_ref",
           "fedavg_agg_ref", "fused_ce_ref", "poibin_dft_ref"]


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: (B,S,H,D); k,v: (B,S,KV,D); GQA broadcast; fp32 softmax.

    window > 0 limits attention to the last `window` positions (inclusive of
    self): j in (i-window, i].
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, s, kvh, groups, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= i >= j
    if window > 0:
        mask &= (i - j) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, d)


def rwkv6_scan_ref(r, k, v, w, u, state=None):
    """Sequential WKV6 (same math as models.rwkv.wkv_scan).

    r,k,v,w: (B,S,H,D); u: (H,D); state: (B,H,D,D) or None.
    Returns (out (B,S,H,D), final_state fp32).

    ``state`` is an oracle-only convenience for chunked-scan tests: the
    Pallas kernel (and the ``ops.rwkv6`` wrapper) always starts from the
    zero state and returns the final state for the caller to chain.
    """
    b, s, h, d = r.shape
    if state is None:
        state = jnp.zeros((b, h, d, d), jnp.float32)
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(st, rkvw):
        rt, kt, vt, wt = rkvw
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt, st + uf[..., None] * kv)
        st = wt[..., :, None] * st + kv
        return st, out

    rs, ks, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    state, outs = jax.lax.scan(step, state, (rs, ks, vs, ws))
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), state


def ssm_scan_ref(x, delta, a_log, b, c, d_skip, h0=None):
    """Mamba selective scan (same math as models.ssm.selective_scan).

    x, delta: (B,S,Din); a_log: (Din,N); b,c: (B,S,N); d_skip: (Din,);
    h0: (B,Din,N) or None. Returns (y (B,S,Din), h_final fp32).

    Like ``rwkv6_scan_ref``, ``h0`` is oracle-only: the Pallas kernel and
    the ``ops.ssm`` wrapper always start from the zero state.
    """
    bsz, s, d_in = x.shape
    n = a_log.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, d_in, n), jnp.float32)
    xf = x.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    da = jnp.exp(df[..., None] * (-jnp.exp(a_log))[None, None])
    dbx = df[..., None] * b.astype(jnp.float32)[:, :, None, :] * xf[..., None]

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(da, 1, 0), jnp.moveaxis(dbx, 1, 0),
                          jnp.moveaxis(c.astype(jnp.float32), 1, 0)))
    y = jnp.moveaxis(ys, 0, 1) + xf * d_skip[None, None]
    return y.astype(x.dtype), h


def fedavg_agg_ref(global_flat, client_flat, mask):
    """Masked mean over the client axis with k=0 fallback.

    global_flat: (P,); client_flat: (N,P); mask: (N,) bool 0/1
    participation, or float participation·weight products (the weighted
    FedAvg path — the math is the same Σmθ/Σm). fp32 accumulation; output
    in ``global_flat.dtype``.
    """
    m = mask.astype(jnp.float32)
    total = jnp.sum(m)
    avg = jnp.einsum("np,n->p", client_flat.astype(jnp.float32), m) \
        / jnp.maximum(total, 1e-9)
    return jnp.where(total > 0, avg,
                     global_flat.astype(jnp.float32)).astype(global_flat.dtype)


def poibin_dft_ref(p_mat, with_loo: bool = True):
    """Batched Poisson-Binomial DFT pmf + leave-one-out deconvolution.

    p_mat: (B, N) probabilities in [0, 1]. Returns pmf (B, N+1) and — with
    ``with_loo`` — loo (B, N, N+1) where ``loo[b, i]`` is the pmf of
    scenario b's nodes excluding node i (support 0..N-1, last entry zero).

    Same math as :func:`repro.core.poibin.poibin_pmf` (eq. (9) DFT with
    clip + renormalize) and :func:`repro.core.poibin.poibin_pmf_loo`
    (forward recursion for p ≤ 1/2, backward for p > 1/2), restated here
    self-contained in the input dtype so the kernel layer stays
    dependency-free; the three-way agreement (this oracle, the Pallas
    kernel, the repro.core functions) is pinned in
    ``tests/test_property_poibin.py``.
    """
    p_mat = jnp.asarray(p_mat)
    _, n = p_mat.shape
    size = n + 1
    cdtype = jnp.complex64 if p_mat.dtype == jnp.float32 else jnp.complex128
    idx = jnp.arange(size)
    omega = jnp.exp(2j * jnp.pi * idx / size).astype(cdtype)   # (S,)
    terms = p_mat[:, None, :] * (omega[None, :, None] - 1.0) + 1.0
    chi = jnp.prod(terms, axis=2)                              # (B, S)
    dft = jnp.exp(-2j * jnp.pi * jnp.outer(idx, idx) / size).astype(cdtype)
    raw = jnp.clip((chi @ dft.T).real / size, 0.0, 1.0)
    pmf = raw / jnp.sum(raw, axis=1, keepdims=True)
    if not with_loo:
        return pmf

    def loo_one(f, p_i):
        q_i = 1.0 - p_i
        use_fwd = p_i <= 0.5
        q_safe = jnp.where(use_fwd, q_i, 0.5)
        p_safe = jnp.where(use_fwd, 0.5, p_i)

        def fwd(g_prev, f_k):
            g_k = (f_k - p_i * g_prev) / q_safe
            return g_k, g_k

        _, g_fwd = jax.lax.scan(fwd, jnp.zeros((), f.dtype), f[:-1])

        def bwd(g_next, f_k1):
            g_k = (f_k1 - q_i * g_next) / p_safe
            return g_k, g_k

        _, g_bwd = jax.lax.scan(bwd, jnp.zeros((), f.dtype), f[1:],
                                reverse=True)
        g = jnp.where(use_fwd, g_fwd, g_bwd)
        return jnp.concatenate([g, jnp.zeros((1,), f.dtype)])

    loo = jax.vmap(jax.vmap(loo_one, in_axes=(None, 0)))(pmf, p_mat)
    return pmf, loo


def fused_ce_ref(hidden, w_vocab, labels):
    """Per-token NLL via dense logits (the memory hog the kernel avoids)."""
    logits = (hidden.astype(jnp.float32) @ w_vocab.astype(jnp.float32))
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32),
                              axis=1)[:, 0]
    return lse - lab
