"""Batched Poisson-Binomial Pallas TPU kernel (paper eq. (9) + leave-one-out).

The game layer's hot loop evaluates, for a whole batch of scenarios, the
pmf of the participant count ``m = Σ_i Bernoulli(p_i)`` and — for
equilibrium certification — the N *leave-one-out* pmfs "everyone except
node i". One kernel invocation fuses both over a (B, N) probability
matrix:

* **DFT pmf** (eq. (9)): the characteristic function on the (N+1)-point
  unit circle, ``χ(n) = Π_k [p_k(ω^n − 1) + 1]``, is accumulated as an
  explicit (re, im) pair over a ``fori_loop`` of the N Bernoulli factors
  (Pallas TPU has no complex dtype), then inverted with two MXU matmuls
  against precomputed (S, S) cos/sin DFT matrices (S = N+1), clipped to
  [0, 1] and renormalized — the same cleanup as
  :func:`repro.core.poibin.poibin_pmf`.
* **Leave-one-out deconvolution**: node i's ``[1-p_i, p_i]`` factor is
  divided back out of the full pmf for *all N nodes at once* — the (B, N)
  lanes run the forward recursion ``g_k = (f_k − p·g_{k-1})/(1−p)`` where
  ``p ≤ 1/2`` and the backward recursion ``g_k = (f_{k+1} − (1−p)·g_{k+1})/p``
  where ``p > 1/2`` (per-step error amplification ≤ 1, including the
  p ∈ {0, 1} corners), exactly mirroring
  :func:`repro.core.poibin.poibin_pmf_loo`.

* grid = (batch_tiles,); each tile owns a (BB, N) probability slab, the
  shared (S, S) cos/sin matrices, and writes a (BB, S) pmf tile plus —
  with ``with_loo`` — a (BB, S, N) leave-one-out tile (support axis
  second-to-last so the per-step dynamic writes land on a contiguous
  (BB, 1, N) slab; the public wrapper transposes to (B, N, S)).
* Per-tile VMEM at BB = 8, N = 64 fp32: ~0.3 MB (p 2 KB + 2·S² DFT 33 KB +
  pmf 2 KB + loo 133 KB + recursion carries) — far under budget; the
  matmuls are (BB, S)·(S, S) MXU work, the recursions VPU work.
* dtype policy: inputs are cast to fp32 in the wrapper and all in-kernel
  arithmetic is fp32; outputs are cast back to ``p_mat.dtype`` (the game
  layer runs x64, so the pallas path is parity-to-tolerance, ~1e-6).

Oracle: :func:`repro.kernels.ref.poibin_dft_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _pmf_body(p, cos, sin, size: int, n: int):
    """Shared DFT-pmf computation: (BB, N) fp32 probs -> (BB, S) pmf."""
    omega_re = cos[1, :]                   # cos(2π n / S), n = 0..S-1
    omega_im = sin[1, :]                   # sin(2π n / S)

    def chi_step(k, carry):
        re, im = carry                     # (BB, S) running complex product
        pk = jax.lax.dynamic_slice_in_dim(p, k, 1, axis=1)   # (BB, 1)
        t_re = pk * (omega_re[None, :] - 1.0) + 1.0
        t_im = pk * omega_im[None, :]
        return re * t_re - im * t_im, re * t_im + im * t_re

    ones = jnp.ones((p.shape[0], size), jnp.float32)
    chi_re, chi_im = jax.lax.fori_loop(0, n, chi_step, (ones, ones * 0.0))
    # Re[Σ_n e^{-2πi nm/S} χ(n)] / S; cos/sin matrices are symmetric.
    raw = (jnp.dot(chi_re, cos, preferred_element_type=jnp.float32)
           + jnp.dot(chi_im, sin, preferred_element_type=jnp.float32)) / size
    raw = jnp.clip(raw, 0.0, 1.0)
    return raw / jnp.sum(raw, axis=1, keepdims=True)


def _kernel_pmf(p_ref, cos_ref, sin_ref, pmf_ref, *, n: int):
    pmf_ref[...] = _pmf_body(p_ref[...].astype(jnp.float32), cos_ref[...],
                             sin_ref[...], n + 1, n)


def _kernel_loo(p_ref, cos_ref, sin_ref, pmf_ref, loo_ref, *, n: int):
    p = p_ref[...].astype(jnp.float32)                 # (BB, N)
    f = _pmf_body(p, cos_ref[...], sin_ref[...], n + 1, n)
    pmf_ref[...] = f

    # Leave-one-out for all N nodes at once; (BB, S, N) output layout.
    use_fwd = p <= 0.5                                 # (BB, N)
    q_safe = jnp.where(use_fwd, 1.0 - p, 0.5)          # benign divisors for
    p_safe = jnp.where(use_fwd, 0.5, p)                # the masked-out branch
    zero = jnp.zeros(p.shape, jnp.float32)

    def fwd_step(k, g_prev):
        f_k = jax.lax.dynamic_slice_in_dim(f, k, 1, axis=1)       # (BB, 1)
        g_k = (f_k - p * g_prev) / q_safe
        loo_ref[:, pl.ds(k, 1), :] = g_k[:, None, :]
        return g_k

    jax.lax.fori_loop(0, n, fwd_step, zero)
    loo_ref[:, pl.ds(n, 1), :] = zero[:, None, :]      # support is 0..N-1

    def bwd_step(j, g_next):                           # k runs n-1 .. 0
        k = n - 1 - j
        f_k1 = jax.lax.dynamic_slice_in_dim(f, k + 1, 1, axis=1)
        g_k = (f_k1 - (1.0 - p) * g_next) / p_safe
        keep = loo_ref[:, pl.ds(k, 1), :][:, 0, :]     # forward-pass value
        loo_ref[:, pl.ds(k, 1), :] = jnp.where(use_fwd, keep, g_k)[:, None, :]
        return g_k

    jax.lax.fori_loop(0, n, bwd_step, zero)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "with_loo", "interpret"))
def poibin_dft(p_mat, *, block_b: int = 8, with_loo: bool = True,
               interpret: bool = False):
    """p_mat: (B, N) -> pmf (B, N+1) [, loo (B, N, N+1) if ``with_loo``]."""
    b, n = p_mat.shape
    size = n + 1
    block_b = min(block_b, b)
    n_b = pl.cdiv(b, block_b)
    pad = n_b * block_b - b
    p32 = jnp.pad(p_mat.astype(jnp.float32), ((0, pad), (0, 0)))
    idx = jnp.arange(size)
    ang = 2.0 * jnp.pi * jnp.outer(idx, idx) / size
    cos = jnp.cos(ang).astype(jnp.float32)
    sin = jnp.sin(ang).astype(jnp.float32)

    in_specs = [
        pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        pl.BlockSpec((size, size), lambda i: (0, 0)),
        pl.BlockSpec((size, size), lambda i: (0, 0)),
    ]
    if not with_loo:
        pmf = pl.pallas_call(
            functools.partial(_kernel_pmf, n=n),
            grid=(n_b,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((block_b, size), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((n_b * block_b, size),
                                           jnp.float32),
            compiler_params=CompilerParams(dimension_semantics=("parallel",)),
            interpret=interpret,
        )(p32, cos, sin)
        return pmf[:b].astype(p_mat.dtype)

    pmf, loo = pl.pallas_call(
        functools.partial(_kernel_loo, n=n),
        grid=(n_b,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, size), lambda i: (i, 0)),
            pl.BlockSpec((block_b, size, n), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_b * block_b, size), jnp.float32),
            jax.ShapeDtypeStruct((n_b * block_b, size, n), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(p32, cos, sin)
    return (pmf[:b].astype(p_mat.dtype),
            jnp.swapaxes(loo, 1, 2)[:b].astype(p_mat.dtype))
