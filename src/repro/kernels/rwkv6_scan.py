"""WKV6 recurrence Pallas TPU kernel (RWKV-6 data-dependent decay).

TPU adaptation of the CUDA wkv6 kernel: instead of one thread per channel,
the (Dk x Dv) per-head state lives in VMEM scratch as a matrix and each grid
step consumes a (BT, D) time tile, running the recurrence with rank-1
updates formed by VPU outer products:

    out_t = r_t^T (S + diag(u) k_t v_t^T)
    S     = diag(w_t) S + k_t v_t^T

* grid = (batch, heads, time_tiles); the time axis is "arbitrary" so the
  fp32 state scratch carries across tiles.
* Per-tile VMEM: 4·BT·D (r,k,v,w) + D·D state + BT·D out; head_dim 64 and
  BT=256 in fp32 is ~0.5 MB.
* The final state is written to a second output on the last tile (used by
  chunked prefill / decode handoff).

Oracle: :func:`repro.kernels.ref.rwkv6_scan_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref, state_scr,
            *, block_t: int, n_t_blocks: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)        # (BT, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (D,)

    def step(t, carry):
        state, out = carry
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)[0]     # (D,)
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)[0]
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)[0]
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)[0]
        kv = kt[:, None] * vt[None, :]                       # (Dk, Dv)
        y = (rt[:, None] * (state + u[:, None] * kv)).sum(axis=0)
        out = jax.lax.dynamic_update_slice_in_dim(out, y[None], t, 0)
        state = wt[:, None] * state + kv
        return state, out

    state0 = state_scr[...]
    out0 = jnp.zeros((block_t, v.shape[1]), jnp.float32)
    state, out = jax.lax.fori_loop(0, block_t, step, (state0, out0))
    state_scr[...] = state
    o_ref[0, 0] = out.astype(o_ref.dtype)

    @pl.when(ti == n_t_blocks - 1)
    def write_state():
        s_out_ref[0, 0] = state


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, block_t: int = 256, interpret: bool = False):
    """r,k,v,w: (B,S,H,D); u: (H,D) -> (out (B,S,H,D), state (B,H,D,D))."""
    b, s, h, d = r.shape
    block_t = min(block_t, s)
    n_t = pl.cdiv(s, block_t)
    pad = n_t * block_t - s

    def prep(x, pad_value=0.0):
        x = jnp.moveaxis(x, 1, 2)                            # (B,H,S,D)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)),
                        constant_values=pad_value)
        return x

    rt, kt, vt = prep(r), prep(k), prep(v)
    wt = prep(w, pad_value=1.0)   # decay 1.0 on padding leaves state frozen

    kernel = functools.partial(_kernel, block_t=block_t, n_t_blocks=n_t)
    out, state = pl.pallas_call(
        kernel,
        grid=(b, h, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, block_t, d), lambda bb, hh, tt: (bb, hh, tt, 0)),
            pl.BlockSpec((1, 1, block_t, d), lambda bb, hh, tt: (bb, hh, tt, 0)),
            pl.BlockSpec((1, 1, block_t, d), lambda bb, hh, tt: (bb, hh, tt, 0)),
            pl.BlockSpec((1, 1, block_t, d), lambda bb, hh, tt: (bb, hh, tt, 0)),
            pl.BlockSpec((1, d), lambda bb, hh, tt: (hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_t, d), lambda bb, hh, tt: (bb, hh, tt, 0)),
            pl.BlockSpec((1, 1, d, d), lambda bb, hh, tt: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, n_t * block_t, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, wt, u)
    out = out[:, :, :s, :]
    return jnp.moveaxis(out, 1, 2), state
