"""Pallas TPU kernels for the framework's compute hot spots.

flash_attention — streaming-softmax attention (causal/sliding-window, GQA)
rwkv6_scan      — WKV6 recurrence with data-dependent decay
ssm_scan        — Mamba-style selective scan (Hymba's SSM branch)
fedavg_agg      — fused participation-masked FedAvg parameter merge
fused_ce        — cross-entropy via streamed vocab tiles (no (T,V) logits)

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper in
``ops.py`` (interpret=True on CPU, compiled on TPU).
"""
from repro.kernels import ops, ref
