"""Pallas TPU kernels for the framework's compute hot spots.

flash_attention — streaming-softmax attention (causal/sliding-window, GQA)
rwkv6_scan      — WKV6 recurrence with data-dependent decay
ssm_scan        — Mamba-style selective scan (Hymba's SSM branch)
fedavg_agg      — fused participation-masked FedAvg parameter merge
fused_ce        — cross-entropy via streamed vocab tiles (no (T,V) logits)
poibin_dft      — batched Poisson-Binomial DFT pmf + leave-one-out deconv

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper
in ``ops.py`` (interpret=True on CPU, compiled on TPU). ``ops`` is also the
backend dispatch layer: every wrapper takes ``backend="pallas"|"ref"``,
overridable process-wide via ``ops.set_backend``/``ops.backend_scope`` or
the ``REPRO_KERNEL_BACKEND`` environment variable, and the campaign/game
hot loops (``repro.federated.server.fedavg_merge``,
``repro.core.asymmetric_batched``) route through it — see
``docs/kernels.md`` for the catalog.
"""
from repro.kernels import ops, ref
