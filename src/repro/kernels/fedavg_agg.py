"""Participation-masked FedAvg merge Pallas TPU kernel (the paper's agg step).

The server merge is bandwidth-bound elementwise work over the flattened
parameter vector: out = Σ_i m_i θ_i / Σ_i m_i, falling back to the previous
global θ when nobody participated. Fusing mask-multiply + reduce + renorm +
fallback into one pass reads each client parameter exactly once.

* grid = (param_tiles,); each tile loads an (N, BP) client slab + the (BP,)
  previous-global slice. N ≤ ~64 clients and BP = 2048 fp32 keeps tiles
  ~0.5 MB in VMEM.
* The mask lives in SMEM-friendly (N, 1) layout; participant count is
  reduced in-kernel (N is tiny). Float masks carry participation·weight
  products for the weighted-FedAvg path.
* Ragged P is padded up to a ``block_p`` multiple in the wrapper and
  sliced back off; N = 1 degenerates to a copy-or-fallback and the
  all-zero mask returns the previous global exactly.
* dtype policy: fp32 accumulate regardless of input dtype; output in
  ``global_flat.dtype`` (f64 campaign params round-trip through fp32 —
  the pallas backend is parity-to-tolerance, not bitwise).

Oracle: :func:`repro.kernels.ref.fedavg_agg_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams


def _kernel(global_ref, clients_ref, mask_ref, o_ref):
    g = global_ref[...].astype(jnp.float32)          # (BP,)
    c = clients_ref[...].astype(jnp.float32)         # (N, BP)
    m = mask_ref[...].astype(jnp.float32)            # (N, 1)
    total = jnp.sum(m)
    avg = jnp.sum(c * m, axis=0) / jnp.maximum(total, 1e-9)
    o_ref[...] = jnp.where(total > 0, avg, g).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def fedavg_agg(global_flat, client_flat, mask, *, block_p: int = 2048,
               interpret: bool = False):
    """global_flat: (P,); client_flat: (N,P); mask: (N,) -> (P,)."""
    n, p = client_flat.shape
    block_p = min(block_p, p)
    n_p = pl.cdiv(p, block_p)
    pad = n_p * block_p - p
    if pad:
        global_flat = jnp.pad(global_flat, ((0, pad),))
        client_flat = jnp.pad(client_flat, ((0, 0), (0, pad)))
    mask2 = mask.astype(jnp.float32).reshape(n, 1)

    out = pl.pallas_call(
        _kernel,
        grid=(n_p,),
        in_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((n, block_p), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_p * block_p,), global_flat.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(global_flat, client_flat, mask2)
    return out[:p]
