"""Fused cross-entropy Pallas TPU kernel (hidden @ vocab -> per-token NLL).

For 200k-class vocabularies (phi4, gemma) the logits tensor (T, V) is the
single largest activation in the training step — bigger than the attention
scores at train_4k. This kernel never materializes it in HBM: vocab tiles
stream through VMEM with an online logsumexp, and the label logit is
accumulated on the fly:

    nll_t = logsumexp_v(h_t · W_v) − h_t · W_{label_t}

* grid = (token_tiles, vocab_tiles); vocab is the innermost "arbitrary"
  dimension so the fp32 running (m, l, label_logit) scratch carries.
* Per-tile VMEM: BT·D (hidden) + D·BV (weight tile) + BT·BV (logit tile);
  (128 tokens × 512 vocab × D=4096) bf16 ≈ 4.5 MB.
* labels enter as an (BT,) int tile; the label logit is extracted with a
  one-hot mask inside the tile that owns it.

Oracle: :func:`repro.kernels.ref.fused_ce_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(h_ref, w_ref, lab_ref, o_ref, m_scr, l_scr, lab_scr, *,
            block_v: int, vocab: int, n_v_blocks: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        lab_scr[...] = jnp.zeros_like(lab_scr)

    h = h_ref[...].astype(jnp.float32)            # (BT, D)
    w = w_ref[...].astype(jnp.float32)            # (D, BV)
    logits = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (BT, BV)

    v_start = vi * block_v
    v_pos = v_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = v_pos < vocab
    logits = jnp.where(valid, logits, NEG_INF)

    # online logsumexp
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    corr = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
    p = jnp.where(valid, jnp.exp(logits - m_new[:, None]), 0.0)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    m_scr[...] = m_new

    # label logit if it lives in this tile
    lab = lab_ref[...]                            # (BT,)
    hit = (v_pos == lab[:, None]) & valid
    lab_scr[...] = lab_scr[...] + jnp.sum(
        jnp.where(hit, logits, 0.0), axis=1)

    @pl.when(vi == n_v_blocks - 1)
    def finalize():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        o_ref[...] = (lse - lab_scr[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v",
                                             "interpret"))
def fused_ce(hidden, w_vocab, labels, *, block_t: int = 128,
             block_v: int = 512, interpret: bool = False):
    """hidden: (T, D); w_vocab: (D, V); labels: (T,) int32 -> (T,) fp32 NLL."""
    t, d = hidden.shape
    v = w_vocab.shape[1]
    block_t = min(block_t, t)
    block_v = min(block_v, v)
    n_t = pl.cdiv(t, block_t)
    n_v = pl.cdiv(v, block_v)
    pad_t = n_t * block_t - t
    pad_v = n_v * block_v - v
    if pad_t:
        hidden = jnp.pad(hidden, ((0, pad_t), (0, 0)))
        labels = jnp.pad(labels, ((0, pad_t),))
    if pad_v:
        w_vocab = jnp.pad(w_vocab, ((0, 0), (0, pad_v)))

    kernel = functools.partial(_kernel, block_v=block_v, vocab=v,
                               n_v_blocks=n_v)
    out = pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((d, block_v), lambda ti, vi: (0, vi)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        out_shape=jax.ShapeDtypeStruct((n_t * block_t,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t,), jnp.float32),
            pltpu.VMEM((block_t,), jnp.float32),
            pltpu.VMEM((block_t,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(hidden, w_vocab, labels.astype(jnp.int32))
    return out[:t]
