"""Logical-axis → mesh-axis rules engine (MaxText-style).

Models annotate every param/cache dimension with a logical name
(``repro.models.layers``). Here a *rules table* maps logical names to an
ordered list of candidate mesh axes; the resolver picks the first candidate
whose size divides the dimension, else leaves the dim unsharded and records
the relaxation (e.g. phi4's 24 heads on a 16-way model axis).

This single mechanism drives the smoke tests (trivial 1-device mesh), the
multi-pod dry-run, and the perf iterations (rule-table swaps are the main
hillclimbing knob).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "TRAIN_RULES", "DECODE_RULES", "SCENARIO_RULES",
           "resolve_specs", "batch_rules_axes", "scenario_batch_spec",
           "spec_axis_size", "pad_batch", "padded_size"]

# a candidate is a mesh axis name, a tuple of axis names, or None
Candidate = Any


@dataclasses.dataclass
class Rules:
    """Ordered candidates per logical axis; first divisible wins."""

    table: dict[str, list[Candidate]]
    relaxations: list[str] = dataclasses.field(default_factory=list)

    def candidates(self, logical: str) -> list[Candidate]:
        return self.table.get(logical, [None])

    def with_overrides(self, **overrides) -> "Rules":
        t = dict(self.table)
        for k, v in overrides.items():
            t[k] = v
        return Rules(table=t)


def _axis_size(mesh: Mesh, cand: Candidate) -> int:
    if cand is None:
        return 1
    if isinstance(cand, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in cand]))
    return mesh.shape[cand]


def _mesh_axes(mesh: Mesh) -> set:
    return set(mesh.axis_names)


def _filter_cand(mesh: Mesh, cand: Candidate) -> Optional[Candidate]:
    """Drop candidates referencing axes this mesh doesn't have (e.g. 'pod'
    on the single-pod mesh) — collapse tuples to their present members."""
    if cand is None:
        return None
    if isinstance(cand, (tuple, list)):
        present = tuple(a for a in cand if a in _mesh_axes(mesh))
        if not present:
            return None
        return present if len(present) > 1 else present[0]
    return cand if cand in _mesh_axes(mesh) else None


def resolve_one(shape: tuple, logical: tuple, mesh: Mesh, rules: Rules,
                used_note: str = "") -> P:
    """PartitionSpec for one array; no mesh axis reused across dims."""
    parts = []
    used: set = set()
    for dim, name in zip(shape, logical):
        chosen = None
        if name is not None:
            for cand in rules.candidates(name):
                cand = _filter_cand(mesh, cand)
                if cand is None:
                    continue
                axes = cand if isinstance(cand, tuple) else (cand,)
                if any(a in used for a in axes):
                    continue
                size = _axis_size(mesh, cand)
                if size > 1 and dim % size == 0:
                    chosen = cand
                    used.update(axes)
                    break
            if chosen is None and rules.candidates(name) != [None]:
                want = rules.candidates(name)[0]
                if want is not None:
                    rules.relaxations.append(
                        f"{used_note}: dim {name}={dim} not divisible by "
                        f"{want} -> replicated")
        parts.append(chosen)
    # trailing Nones can be dropped
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def resolve_specs(shapes_tree, specs_tree, mesh: Mesh, rules: Rules,
                  note: str = ""):
    """Tree of NamedShardings for (shapes, logical specs) twin pytrees."""
    def resolve(shape_leaf, spec_leaf):
        if spec_leaf is None or not isinstance(spec_leaf, tuple):
            return NamedSharding(mesh, P())
        shape = getattr(shape_leaf, "shape", ())
        if len(shape) != len(spec_leaf):
            # scalar-or-mismatch: replicate (e.g. cache 'pos' scalars)
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, resolve_one(shape, spec_leaf, mesh, rules, note))

    return jax.tree.map(
        resolve, shapes_tree, specs_tree,
        is_leaf=lambda v: isinstance(v, tuple) or v is None)


# --------------------------------------------------------------------------
# rule tables
# --------------------------------------------------------------------------

# Baseline training rules: FSDP-style param sharding over 'data' is NOT used;
# params live on 'model' (tensor parallel) and are replicated across 'data'
# and 'pod'; activations shard batch over ('pod','data'). This is the
# paper-era baseline; perf iterations add FSDP/zero-style variants.
TRAIN_RULES = Rules(table={
    # params
    "vocab": ["model"],
    "embed": [None],
    "embed_out": [None],
    "heads": ["model"],
    "kv_heads": ["model"],
    "head": [None],
    "head_v": [None],
    "mlp": ["model"],
    "expert": ["model"],
    "inner": ["model"],
    "q_lora": [None],
    "kv_lora": [None],
    "mix_lora": [None],
    "decay_lora": [None],
    "dt_rank": [None],
    "state": [None],
    "state_proj": ["model"],
    "conv": [None],
    "stream": [None],
    "frontend": [None],
    "layers": [None],
    # activations / batch
    "batch": [("pod", "data")],
    "seq": [None],
    "frames": [None],
})

# Decode: KV cache batch over ('pod','data'), heads over 'model'.
DECODE_RULES = Rules(table={
    **TRAIN_RULES.table,
    "batch": [("pod", "data")],
    "seq": [None],
})


def batch_rules_axes(mesh: Mesh) -> tuple:
    """The data-parallel axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# --------------------------------------------------------------------------
# scenario-batch rules (the campaign / NE sweep engines)
# --------------------------------------------------------------------------

# The batched game/campaign engines are embarrassingly parallel along their
# scenario axis: every per-scenario dimension (nodes, rounds, pmf support)
# stays on-device and only 'scenario' goes to the data-parallel axes. The
# same resolver that places model dims (above) places the sweep batch.
SCENARIO_RULES = Rules(table={
    "scenario": [("pod", "data")],
    "node": [None],
    "round": [None],
})


def scenario_batch_spec(batch: int, mesh: Mesh, *,
                        axis: str | Sequence[str] | None = None,
                        rules: Rules | None = None) -> P:
    """PartitionSpec placing a scenario batch dim of size ``batch``.

    Resolved through the rules engine (first candidate whose mesh size
    divides ``batch`` wins — callers pad to divisibility first, see
    :func:`padded_size`). ``axis`` overrides the candidate list with a
    single mesh axis name (or tuple of names); default is
    :data:`SCENARIO_RULES`'s ``("pod", "data")`` preference.
    """
    if rules is None:
        table = dict(SCENARIO_RULES.table)
        if axis is not None:
            table["scenario"] = [tuple(axis) if isinstance(axis, (tuple, list))
                                 else axis]
        rules = Rules(table=table)
    return resolve_one((batch,), ("scenario",), mesh, rules,
                       used_note="scenario_batch")


def spec_axis_size(mesh: Mesh, spec: P) -> int:
    """Total number of shards the leading dim of ``spec`` is split into."""
    if not len(spec):
        return 1
    return _axis_size(mesh, spec[0])


def padded_size(batch: int, multiple: int) -> int:
    """Smallest ``B' >= batch`` divisible by ``multiple``."""
    if multiple <= 1:
        return batch
    return ((batch + multiple - 1) // multiple) * multiple


def pad_batch(x, batch: int, multiple: int):
    """Edge-pad the leading (batch) dim of ``x`` up to a multiple.

    Padding rows replicate the last valid scenario — real, finite inputs,
    so the padded lanes trace the same program without NaN hazards — and
    callers slice every result back to ``batch`` rows (the validity mask),
    so replica lanes can never leak into ledgers/metrics/events.
    """
    import jax.numpy as jnp

    target = padded_size(batch, multiple)
    if target == batch:
        return x
    pad = [(0, target - batch)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, mode="edge")


# --------------------------------------------------------------------------
# named presets (the §Perf hillclimb results; see EXPERIMENTS.md)
# --------------------------------------------------------------------------

# Each preset: (rules_override, opt_rules_override). `opt_rules_override`
# shards the Adam m/v independently of the parameters (ZeRO-1).
PRESETS: dict[str, tuple[dict, dict | None]] = {
    # paper-era TP+DP — the faithful baseline
    "baseline": ({}, None),
    # sequence-parallel activations: the fix for head counts that don't
    # divide the 16-way model axis (16.1x memory win on minicpm3 prefill)
    "seqpar": ({"seq": ["model"]}, None),
    # pure 256-way data parallelism + ZeRO-1 optimizer sharding: the right
    # scheme for <=7 GB (bf16) models (24x collective win on rwkv6 train,
    # 10x on stablelm train). NOT applicable to deepseek/internvl2 scale.
    "fulldp_zero1": (
        {"batch": [("pod", "data", "model")],
         "mlp": [None], "vocab": [None], "embed": [None], "heads": [None],
         "kv_heads": [None], "inner": [None], "expert": [None],
         "state_proj": [None], "decay_lora": [None], "mix_lora": [None]},
        {"mlp": ["model"], "vocab": ["model"], "embed": ["model"],
         "heads": ["model"], "kv_heads": ["model"], "inner": ["model"],
         "expert": ["model"], "state_proj": ["model"],
         "decay_lora": ["model"], "mix_lora": ["model"]},
    ),
}
