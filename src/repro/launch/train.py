"""Production training driver.

Runs the selected architecture on the local device set (1 CPU here, a v5e
pod in production — same code path, the mesh just grows) with the
participatory-FL layer on top: the data-parallel axis is partitioned into
``n_clients`` virtual clients whose Bernoulli participation masks gate their
gradient contribution each round, merged FedAvg-style; the participation
probability comes from the game-theoretic controller.

Usage:
  python -m repro.launch.train --arch gemma-2b --reduced --steps 20
  python -m repro.launch.train --arch olmoe-1b-7b --reduced --gamma 0.6 --cost 2.0
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.controller import ParticipationController
from repro.data.synthetic import SyntheticLM
from repro.models.registry import get_model, param_count
from repro.optim import adamw
from repro.optim.base import apply_updates, clip_by_global_norm
from repro.checkpoint.checkpoint import save_checkpoint


def make_fl_train_step(api, opt, n_clients: int):
    """One FL round: per-client grads -> Bernoulli-masked FedAvg of grads.

    With equal shards, FedAvg over one local step == masked gradient
    averaging; this keeps the whole round a single XLA program. The batch
    leading axis is (clients, per_client_batch, ...).
    """
    def step(params, opt_state, batch, mask):
        def client_loss(p, cb):
            return api.loss(p, cb, remat=True)

        def one_client(cb):
            return jax.value_and_grad(client_loss)(params, cb)

        losses, grads = jax.vmap(one_client)(batch)
        m = mask.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(m), 1.0)

        def merge(g):
            mm = m.reshape((-1,) + (1,) * (g.ndim - 1))
            return jnp.sum(g.astype(jnp.float32) * mm, axis=0) / denom

        avg_grads = jax.tree.map(merge, grads)
        avg_grads, gnorm = clip_by_global_norm(avg_grads, 1.0)
        updates, opt_state = opt.update(avg_grads, opt_state, params)
        new_params = apply_updates(params, updates)
        # if nobody participated, keep old params (wasted round)
        any_part = jnp.sum(m) > 0
        new_params = jax.tree.map(
            lambda new, old: jnp.where(any_part, new, old), new_params, params)
        return new_params, opt_state, jnp.sum(losses * m) / denom, gnorm

    return step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the architecture")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8, help="per-client batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-clients", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--gamma", type=float, default=0.6)
    ap.add_argument("--cost", type=float, default=2.0)
    ap.add_argument("--p-mode", default="ne",
                    choices=["ne", "ne_worst", "centralized", "fixed"])
    ap.add_argument("--fixed-p", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = api.init(key)
    print(f"arch={cfg.name} params={param_count(params):,}")

    controller = ParticipationController(
        n_nodes=50, gamma=args.gamma, cost=args.cost, mode=args.p_mode,
        fixed_p=args.fixed_p)
    p = controller.participation_probability()
    diag = controller.diagnostics()
    print(f"participation p={p:.3f} (mode={args.p_mode}, "
          f"opt_p={diag['opt_p']:.3f}, PoA={diag['poa']:.3f})")

    opt = adamw(args.lr)
    opt_state = opt.init(params)
    data = SyntheticLM(vocab=cfg.vocab, seed=args.seed)
    step_fn = jax.jit(make_fl_train_step(api, opt, args.n_clients))

    ledger = controller.new_ledger() if controller.n_nodes == args.n_clients \
        else None
    t0 = time.time()
    for step in range(args.steps):
        kb = jax.random.fold_in(key, 1000 + step)
        batch = jax.vmap(
            lambda k: data.batch(k, args.batch, args.seq))(
                jax.random.split(kb, args.n_clients))
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                jax.random.fold_in(kb, 7),
                (args.n_clients, args.batch, cfg.n_patches, cfg.d_frontend))
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                jax.random.fold_in(kb, 8),
                (args.n_clients, args.batch, cfg.n_frames, cfg.d_model))
        mask = jax.random.bernoulli(jax.random.fold_in(kb, 9), p,
                                    (args.n_clients,))
        params, opt_state, loss, gnorm = step_fn(params, opt_state, batch,
                                                 mask)
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):7.4f} "
                  f"gnorm {float(gnorm):8.3f} "
                  f"participants {int(mask.sum())}/{args.n_clients} "
                  f"({time.time()-t0:5.1f}s)")
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps,
                               {"params": params, "opt": opt_state},
                               metadata={"arch": cfg.name})
        print("saved", path)


if __name__ == "__main__":
    main()
