"""Serving driver: batched autoregressive decode with a KV/recurrent cache.

Usage:
  python -m repro.launch.serve --arch gemma-2b --reduced --batch 4 --prompt-len 16 --gen 32
  python -m repro.launch.serve --arch rwkv6-3b --reduced --gen 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import SyntheticLM
from repro.models.registry import get_model, param_count
from repro.models import encdec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = api.init(key)
    print(f"arch={cfg.name} params={param_count(params):,}")

    data = SyntheticLM(vocab=cfg.vocab, seed=args.seed)
    prompt = data.batch(jax.random.fold_in(key, 1), args.batch,
                        args.prompt_len)["tokens"]
    total = args.prompt_len + args.gen

    if cfg.family == "ssm":
        cache, _ = api.init_cache(args.batch, 0, False)
        ring = False
    elif cfg.family == "hybrid":
        cache, _ = api.init_cache(args.batch, cfg.sliding_window, True)
        ring = True
    elif cfg.family == "audio":
        cache, _ = api.init_cache(args.batch, total, False)
        frames = jax.random.normal(jax.random.fold_in(key, 2),
                                   (args.batch, cfg.n_frames, cfg.d_model))
        cache = encdec.warm_cache(cfg, params, cache, frames)
        ring = False
    else:
        cache, _ = api.init_cache(args.batch, total, False)
        ring = False

    serve = jax.jit(lambda p, c, t, pos: api.serve_step(p, c, t, pos,
                                                        ring=ring))

    # prefill by replay (teacher-forced single-token steps)
    t0 = time.time()
    tok = prompt[:, :1]
    for i in range(args.prompt_len):
        logits, cache = serve(params, cache, prompt[:, i:i + 1],
                              jnp.asarray(i, jnp.int32))
    prefill_s = time.time() - t0

    # autoregressive generation
    t0 = time.time()
    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for i in range(args.prompt_len, total):
        out_tokens.append(tok)
        logits, cache = serve(params, cache, tok, jnp.asarray(i, jnp.int32))
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    gen_s = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"prefill {args.prompt_len} toks in {prefill_s:.2f}s; "
          f"generated {args.gen} toks in {gen_s:.2f}s "
          f"({args.gen * args.batch / max(gen_s, 1e-9):.1f} tok/s)")
    print("sample tokens:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
