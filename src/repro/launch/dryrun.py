import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

The two lines above MUST run before any jax import (jax locks the device
count at first init); that's why this module sets XLA_FLAGS at line 1-2 and
why conftest/pyproject do NOT set it (smoke tests see 1 device).

For each combination this:
  1. builds ShapeDtypeStruct stand-ins for params / optimizer state / inputs
     (zero allocation),
  2. resolves logical-axis shardings via the rules engine,
  3. ``jax.jit(step).lower(...).compile()`` on the production mesh,
  4. records memory_analysis / cost_analysis / the collective schedule parsed
     from the partitioned HLO into a JSON artifact (consumed by the roofline
     benchmark and EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out experiments/dryrun
"""
import argparse
import collections
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHITECTURES, INPUT_SHAPES, SKIPS, get_config
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.sharding import (DECODE_RULES, PRESETS, TRAIN_RULES,
                                   Rules, resolve_specs)
from repro.models.registry import get_model
from repro.optim import adamw
from repro.optim.base import apply_updates

# logical specs for input batches, by key name
_INPUT_SPECS = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "token": ("batch", "seq"),
    "patches": ("batch", "seq", "frontend"),
    "frames": ("batch", "frames", "embed"),
    "pos": (),
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16}


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective op class from partitioned HLO.

    Shapes in post-SPMD HLO are per-device. Per-chip bytes moved are
    estimated with ring-algorithm factors at the roofline stage; here we
    record raw result bytes + op counts.
    """
    out: dict = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*(\w[\w\-]*)\(", s)
        if not m:
            continue
        op = m.group(2)
        for cls in _COLLECTIVES:
            if op == cls or op.startswith(cls + "-"):
                total = 0
                for dt, dims in shape_re.findall(m.group(1)):
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    total += n * _DTYPE_BYTES[dt]
                out[cls]["count"] += 1
                out[cls]["bytes"] += total
                break
    return out


def _sds_tree(f, *args):
    return jax.eval_shape(f, *args)


def _build_param_specs(api):
    holder = {}

    def init_only(key):
        p, s = api.init(key)
        holder["specs"] = s
        return p

    params_sds = jax.eval_shape(init_only, jax.random.PRNGKey(0))
    return params_sds, holder["specs"]


def _input_shardings(batch_sds: dict, mesh, rules: Rules):
    specs = {k: _INPUT_SPECS.get(k, None) for k in batch_sds}
    return resolve_specs(batch_sds, specs, mesh, rules, note="inputs")


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def make_train_step(api, opt):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss(p, batch, remat=True))(params)
        updates, new_opt = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), new_opt, loss
    return train_step


def make_eval_step(api):
    def eval_step(params, batch):
        return api.loss(params, batch, remat=False)
    return eval_step


@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds: float
    error: str = ""
    memory: dict = dataclasses.field(default_factory=dict)
    cost: dict = dataclasses.field(default_factory=dict)
    collectives: dict = dataclasses.field(default_factory=dict)
    sizes: dict = dataclasses.field(default_factory=dict)
    relaxations: list = dataclasses.field(default_factory=list)


def probe_depths(cfg: ModelConfig) -> tuple[int, int]:
    """Two depths whose cost delta isolates one scanned layer.

    XLA cost_analysis counts a while/scan body ONCE regardless of trip
    count, so the full-depth artifact undercounts FLOPs/bytes by ~L×. The
    roofline pass corrects with f(L) ≈ f(d1) + (L - d1)·(f(d2) − f(d1)).
    MoE models with a dense prefix need d ≥ prefix + 1 so the probe varies
    the MoE body, not the prefix.
    """
    prefix = cfg.moe.first_dense_layers if cfg.moe is not None else 0
    d1 = prefix + 1
    return d1, d1 + 1


def _with_depth(cfg: ModelConfig, depth: int) -> ModelConfig:
    changes: dict = {"n_layers": depth, "name": f"{cfg.name}-d{depth}"}
    if cfg.encoder_layers:
        changes["encoder_layers"] = depth
    return dataclasses.replace(cfg, **changes)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            rules_override: dict | None = None,
            remat: bool = True, depth: int | None = None,
            opt_rules_override: dict | None = None) -> DryrunResult:
    from repro.models import runtime
    cfg = get_config(arch)
    runtime.SCAN_UNROLL = False
    if depth is not None:
        cfg = _with_depth(cfg, depth)
        # probes need the layer stack unrolled: cost_analysis counts a
        # while-loop body once regardless of trip count
        runtime.SCAN_UNROLL = True
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        api = get_model(cfg)
        rules_base = TRAIN_RULES if shape.kind != "decode" else DECODE_RULES
        rules = Rules(table=dict(rules_base.table))
        if rules_override:
            rules = rules.with_overrides(**rules_override)

        params_sds, param_specs = _build_param_specs(api)
        param_sh = resolve_specs(params_sds, param_specs, mesh, rules,
                                 note=f"{arch}-params")
        batch_sds = api.input_specs(shape)
        batch_sh = _input_shardings(batch_sds, mesh, rules)

        if shape.kind in ("train", "prefill"):
            if shape.kind == "train":
                opt = adamw(3e-4)
                opt_sds = jax.eval_shape(opt.init, params_sds)
                if opt_rules_override:
                    # ZeRO-style: optimizer state sharded independently of
                    # the (possibly replicated) parameters
                    zrules = Rules(table=dict(rules.table))
                    zrules = zrules.with_overrides(**opt_rules_override)
                    mv_sh = resolve_specs(params_sds, param_specs, mesh,
                                          zrules, note=f"{arch}-optstate")
                else:
                    mv_sh = param_sh
                opt_sh = {
                    "m": mv_sh, "v": mv_sh,
                    "step": NamedSharding(mesh, P()),
                }
                step = make_train_step(api, opt)
                jitted = jax.jit(
                    step,
                    in_shardings=(param_sh, opt_sh, batch_sh),
                    out_shardings=(param_sh, opt_sh,
                                   NamedSharding(mesh, P())),
                )
                with mesh:
                    lowered = jitted.lower(params_sds, opt_sds, batch_sds)
            else:
                step = make_eval_step(api)
                jitted = jax.jit(step, in_shardings=(param_sh, batch_sh),
                                 out_shardings=NamedSharding(mesh, P()))
                with mesh:
                    lowered = jitted.lower(params_sds, batch_sds)
        else:
            kind = api.cache_kind(shape)
            ring = kind["ring"]
            cache_holder = {}

            def cache_only():
                c, s = api.init_cache(shape.global_batch, kind["length"], ring)
                cache_holder["specs"] = s
                return c

            cache_sds = jax.eval_shape(cache_only)
            cache_sh = resolve_specs(cache_sds, cache_holder["specs"], mesh,
                                     rules, note=f"{arch}-cache")
            serve = lambda p, c, t, pos: api.serve_step(p, c, t, pos,
                                                        ring=ring)
            jitted = jax.jit(
                serve,
                in_shardings=(param_sh, cache_sh, batch_sh["token"],
                              batch_sh["pos"]),
                out_shardings=(None, cache_sh),
            )
            with mesh:
                lowered = jitted.lower(params_sds, cache_sds,
                                       batch_sds["token"], batch_sds["pos"])

        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        mem_d = {}
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    mem_d[attr] = int(v)
        try:
            cost = dict(compiled.cost_analysis() or {})
            cost = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float)) and not k.startswith("utilization")}
        except Exception as e:  # pragma: no cover
            cost = {"error": str(e)}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)

        res = DryrunResult(
            arch=arch, shape=shape_name, mesh=mesh_name, ok=True,
            seconds=round(time.time() - t0, 1),
            memory=mem_d, cost=cost, collectives=coll,
            sizes={
                "param_bytes": _tree_bytes(params_sds),
                "batch_bytes": _tree_bytes(batch_sds),
                "n_devices": int(np.prod(list(mesh.shape.values()))),
            },
            relaxations=list(rules.relaxations),
        )
        return res
    except Exception:
        return DryrunResult(arch=arch, shape=shape_name, mesh=mesh_name,
                            ok=False, seconds=round(time.time() - t0, 1),
                            error=traceback.format_exc(limit=8))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="depth-probe pass (d1, d1+1) for per-layer cost "
                         "deltas; single-pod mesh only")
    ap.add_argument("--preset", default="baseline", choices=sorted(PRESETS),
                    help="sharding preset from launch.sharding.PRESETS")
    args = ap.parse_args()
    rules_ov, opt_ov = PRESETS[args.preset]
    suffix = "" if args.preset == "baseline" else f"__{args.preset}"

    os.makedirs(args.out, exist_ok=True)
    arch_list = list(ARCHITECTURES) if (args.all or not args.arch) else [args.arch]
    shape_list = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]

    if args.probe:
        combos_p: list[tuple[str, str, int]] = []
        for a in arch_list:
            d1, d2 = probe_depths(get_config(a))
            for s in shape_list:
                if (a, s) in SKIPS:
                    continue
                combos_p.extend([(a, s, d1), (a, s, d2)])
        n_fail = 0
        for a, s, d in combos_p:
            path = os.path.join(args.out, f"{a}__{s}__16x16{suffix}__d{d}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {a} {s} d{d}")
                continue
            res = run_one(a, s, multi_pod=False, depth=d,
                          rules_override=rules_ov, opt_rules_override=opt_ov)
            blob = dataclasses.asdict(res)
            blob["depth"] = d
            with open(path, "w") as f:
                json.dump(blob, f, indent=1)
            status = "OK " if res.ok else "FAIL"
            print(f"[{status}] {a:18s} {s:12s} d{d}  {res.seconds:6.1f}s"
                  + ("" if res.ok else f"  {res.error.splitlines()[-1]}"),
                  flush=True)
            n_fail += 0 if res.ok else 1
        print(f"probe done: {len(combos_p) - n_fail}/{len(combos_p)} OK")
        raise SystemExit(1 if n_fail else 0)

    combos: list[tuple[str, str, bool]] = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in arch_list:
        for s in shape_list:
            if (a, s) in SKIPS:
                continue
            for mp in meshes:
                combos.append((a, s, mp))

    n_fail = 0
    for a, s, mp in combos:
        mesh_name = "2x16x16" if mp else "16x16"
        path = os.path.join(args.out, f"{a}__{s}__{mesh_name}{suffix}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {a} {s} {mesh_name}")
            continue
        res = run_one(a, s, mp, rules_override=rules_ov,
                      opt_rules_override=opt_ov)
        with open(path, "w") as f:
            json.dump(dataclasses.asdict(res), f, indent=1)
        status = "OK " if res.ok else "FAIL"
        print(f"[{status}] {a:18s} {s:12s} {mesh_name:8s} {res.seconds:7.1f}s"
              + ("" if res.ok else f"  {res.error.splitlines()[-1]}"),
              flush=True)
        if not res.ok:
            n_fail += 1
    print(f"done: {len(combos) - n_fail}/{len(combos)} OK")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
