"""Production mesh definitions (TPU v5e pods).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Hardware constants used by the roofline analysis live here too.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 v5e pod (256 chips), or 2 pods = 512 chips with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


class HW:
    """TPU v5e per-chip roofline constants (assignment-specified)."""

    PEAK_FLOPS_BF16 = 197e12      # FLOP/s
    HBM_BW = 819e9                # B/s
    ICI_BW = 50e9                 # B/s per link
    CHIP_POWER_W = 170.0          # board power (energy model coupling)
    HBM_BYTES = 16e9              # capacity, for memory_analysis sanity
