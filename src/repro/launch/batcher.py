"""Continuous-batching serving scheduler (slot-based, vLLM-lite).

A fixed pool of B decode slots shares one jitted ``serve_step``. Each slot
holds an independent request at its own depth — the per-row ``positions``
support added to the decode path makes rows fully independent, so a
finishing request's slot is refilled immediately from the queue while other
slots keep decoding (no batch barrier between requests).

Prompt tokens are fed through the same decode path (prefill-by-replay, one
token per engine tick per slot) — simple, correct, and adequate for the
CPU container; a chunked-prefill fast path is the natural TPU upgrade.

Only full-buffer and recurrent cache families are supported here
(dense/moe/vlm-text and rwkv6); the ring cache keys slots by absolute
position, which composes the same way (per-row ``pos % W``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import ModelApi

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                       # next write position for this row

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatcher:
    """Slot scheduler over a shared batched decode step."""

    def __init__(self, api: ModelApi, params, n_slots: int,
                 max_len: int, ring: bool = False, greedy: bool = True,
                 seed: int = 0):
        self.api = api
        self.cfg: ModelConfig = api.cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.ring = ring
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.cache, _ = api.init_cache(n_slots, max_len, ring)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._step = jax.jit(
            lambda p, c, t, pos: api.serve_step(p, c, t, pos, ring=ring))
        self.ticks = 0

    # -- public api ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        """Engine loop until queue + slots drain (or tick budget)."""
        while (self.queue or any(not s.free for s in self.slots)) \
                and self.ticks < max_ticks:
            self._refill()
            self._tick()
        return self.finished

    # -- internals -----------------------------------------------------------
    def _refill(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.free and self.queue:
                slot.req = self.queue.popleft()
                slot.pos = 0
                self._reset_row(i)

    def _reset_row(self, i: int) -> None:
        """Zero row i of every cache buffer. Full-buffer KV rows are already
        correct via position masking; recurrent/ring state (rwkv, hymba)
        genuinely leaks across requests without this."""
        def zero_row(x):
            if hasattr(x, "ndim") and x.ndim >= 2:
                return x.at[:, i].set(jnp.zeros_like(x[:, i]))
            return x
        self.cache = jax.tree.map(zero_row, self.cache)

    def _next_token_for(self, slot: _Slot) -> int:
        """Token to feed this tick: prompt token or last generated."""
        req = slot.req
        if slot.pos < len(req.prompt):
            return int(req.prompt[slot.pos])
        return int(req.generated[-1]) if req.generated else 0

    def _tick(self) -> None:
        self.ticks += 1
        tokens = np.zeros((self.n_slots, 1), np.int32)
        positions = np.zeros((self.n_slots,), np.int32)
        active = []
        for i, slot in enumerate(self.slots):
            if slot.free:
                # park idle rows at position 0 writing token 0; their cache
                # row is reinitialized on refill via position restart
                positions[i] = max(self.max_len - 1, 0) if not self.ring \
                    else slot.pos
                continue
            tokens[i, 0] = self._next_token_for(slot)
            positions[i] = slot.pos
            active.append(i)
        if not active:
            return
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(positions))
        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        else:
            self.key, sk = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(sk, logits[:, -1]))
        for i in active:
            slot = self.slots[i]
            req = slot.req
            slot.pos += 1
            in_prompt = slot.pos < len(req.prompt)
            if not in_prompt:
                req.generated.append(int(nxt[i]))
            hit_len = (slot.pos + 1 >= self.max_len and not self.ring)
            if len(req.generated) >= req.max_new or hit_len:
                req.done = True
                self.finished.append(req)
                slot.req = None

    # -- introspection ---------------------------------------------------------
    def stats(self) -> dict:
        return {
            "ticks": self.ticks,
            "finished": len(self.finished),
            "queued": len(self.queue),
            "active": sum(not s.free for s in self.slots),
        }
