"""Sweep-service driver: serve a JSONL request stream through
:class:`repro.serve.SweepService`.

The scenario-sweep twin of :mod:`repro.launch.serve` (the token-decode
driver): reads schema-versioned requests (one JSON object per line),
serves them through the padded/bucketed engines, writes one response per
line, and prints the service's cache/latency summary.

Usage:
  python -m repro.launch.serve_sweeps --input requests.jsonl --output -
  python -m repro.launch.serve_sweeps --demo 24 --events serve_events.jsonl
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.obs import EventSink
from repro.serve import SweepService
from repro.serve.workload import synthetic_workload


def _load_requests(path: str) -> list[dict]:
    out = []
    text = (sys.stdin.read() if path == "-"
            else pathlib.Path(path).read_text())
    for line in text.splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--input", help="requests JSONL ('-' for stdin)")
    ap.add_argument("--demo", type=int, default=0, metavar="N",
                    help="serve N synthetic mixed requests instead")
    ap.add_argument("--output", default="-",
                    help="responses JSONL ('-' for stdout)")
    ap.add_argument("--events", help="optional EventSink JSONL path")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--backend", default=None,
                    choices=(None, "ref", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.demo:
        payloads = synthetic_workload(args.demo, seed=args.seed)
    elif args.input:
        payloads = _load_requests(args.input)
    else:
        ap.error("one of --input or --demo is required")

    sink = None
    if args.events:
        # the sink appends; the driver owns the file, so start it fresh
        pathlib.Path(args.events).unlink(missing_ok=True)
        sink = EventSink(args.events)

    t0 = time.perf_counter()
    with SweepService(backend=args.backend, max_batch=args.max_batch,
                      sink=sink) as svc:
        responses = svc.serve(payloads)
        elapsed = time.perf_counter() - t0
        stats = svc.stats()

    lines = "\n".join(json.dumps(r.to_dict()) for r in responses) + "\n"
    if args.output == "-":
        sys.stdout.write(lines)
    else:
        pathlib.Path(args.output).write_text(lines)

    ok = sum(r.ok for r in responses)
    lat = stats.get("latency", {})
    print(f"served {len(responses)} responses ({ok} ok, "
          f"{len(responses) - ok} rejected) in {elapsed:.2f}s "
          f"({len(responses) / max(elapsed, 1e-9):.1f} req/s)",
          file=sys.stderr)
    print(f"cache: {stats['cache']['hits']} hits / "
          f"{stats['cache']['misses']} misses over "
          f"{stats['dispatches']} dispatches; padding overhead "
          f"{stats['padding_overhead']:.1%}; p50 latency "
          f"{lat.get('p50_us', float('nan')) / 1e3:.1f} ms",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
