"""Player utility (paper eq. 11) and the social objective used for PoA.

    u_i = -E[D] - gamma * log(E[delta_i]) - c * p_i

* ``E[D]`` — expected task duration, eq. (8), via the Poisson-Binomial pmf of
  the participant count and the duration model d(k).
* ``log(E[delta_i])`` — AoI incentive, eq. (10): rewards frequent participation.
* ``c * p_i`` — the node's private (energy) participation cost; ``c`` converts
  energy into utility units (the paper sweeps it).

For the Price of Anarchy we use the *social cost* ``E[D] + c*p`` per node —
the AoI incentive is a transfer paid by the sink, not a physical cost, so it
nets out of the welfare comparison (the paper's centralized optimum at c=0 is
the E[D] minimizer, p ≈ 0.61, which matches this reading).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.aoi import log_aoi
from repro.core.duration import DurationModel
from repro.core.poibin import poibin_pmf

__all__ = [
    "UtilityParams",
    "player_utility",
    "symmetric_player_utility",
    "social_utility",
    "social_cost",
]


@dataclasses.dataclass(frozen=True)
class UtilityParams:
    """Weights of eq. (11)."""

    gamma: float = 0.0   # AoI incentive weight
    cost: float = 0.0    # participation cost factor c
    n_nodes: int = 50


def _expected_duration_profile(p_vec: jax.Array, dur: DurationModel) -> jax.Array:
    """E[D] (eq. 8) for an arbitrary (possibly asymmetric) profile."""
    pmf = poibin_pmf(p_vec)
    return jnp.sum(pmf * dur.table())


def player_utility(
    p_i: jax.Array,
    p_others: jax.Array,
    params: UtilityParams,
    dur: DurationModel,
) -> jax.Array:
    """u_i of eq. (11) with opponents fixed at ``p_others`` (shape (N-1,))."""
    p_vec = jnp.concatenate([jnp.reshape(p_i, (1,)), jnp.asarray(p_others)])
    e_d = _expected_duration_profile(p_vec, dur)
    return -e_d - params.gamma * log_aoi(p_i) - params.cost * p_i


def symmetric_player_utility(
    p_i: jax.Array,
    p_sym: jax.Array,
    params: UtilityParams,
    dur: DurationModel,
) -> jax.Array:
    """u_i when the other N-1 nodes all play ``p_sym``.

    Uses the decomposition  m = X_i + m_-i,  m_-i ~ Binomial(N-1, p_sym):
        E[D] = p_i * E[d(m_-i + 1)] + (1 - p_i) * E[d(m_-i)],
    which keeps the profile evaluation O(N) instead of building an N-vector —
    and makes ∂u_i/∂p_i exact and cheap (it is the *constant* slope
    E[d(m_-i+1)] - E[d(m_-i)] plus the private terms).
    """
    n = params.n_nodes
    pmf_others = poibin_pmf(jnp.full((n - 1,), p_sym))          # (N,) over 0..N-1
    d_tab = dur.table()                                          # (N+1,)
    e_d_without = jnp.sum(pmf_others * d_tab[:-1])
    e_d_with = jnp.sum(pmf_others * d_tab[1:])
    e_d = p_i * e_d_with + (1.0 - p_i) * e_d_without
    return -e_d - params.gamma * log_aoi(p_i) - params.cost * p_i


def social_utility(
    p_sym: jax.Array,
    params: UtilityParams,
    dur: DurationModel,
    include_incentive: bool = False,
) -> jax.Array:
    """Per-node utility when everyone plays ``p_sym`` (symmetric profile)."""
    pmf = poibin_pmf(jnp.full((params.n_nodes,), p_sym))
    e_d = jnp.sum(pmf * dur.table())
    u = -e_d - params.cost * p_sym
    if include_incentive:
        u = u - params.gamma * log_aoi(p_sym)
    return u


def social_cost(
    p_sym: jax.Array,
    params: UtilityParams,
    dur: DurationModel,
) -> jax.Array:
    """Per-node social cost E[D] + c*p used in the PoA (eq. 13)."""
    return -social_utility(p_sym, params, dur, include_incentive=False)
