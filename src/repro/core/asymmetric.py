"""Beyond-paper extension: heterogeneous nodes and asymmetric equilibria.

The paper assumes identical nodes and solves the symmetric NE; its §V names
heterogeneous extensions as future work. Here nodes carry individual cost
factors ``c_i`` (e.g. battery-constrained sensors vs mains-powered gateways)
and optionally individual AoI weights ``gamma_i``. We compute:

* asymmetric best-response dynamics over the full Poisson-Binomial profile
  (the exact E[D] of eq. 8 with per-node probabilities — no mean-field
  approximation), damped to a fixed point;
* the utilitarian optimum over a common p (planner without price
  discrimination) and the heterogeneity-aware social cost of the reached
  profile, giving a heterogeneous PoA.

Everything reuses :mod:`repro.core.poibin`; the per-node best response
exploits the same decomposition as the symmetric case: with opponents'
profile fixed, u_i is linear in p_i (duration, cost) plus the concave AoI
term, so the BR is either a corner or the unique stationary point of the
concave part.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aoi import log_aoi
from repro.core.duration import DurationModel
from repro.core.poibin import poibin_pmf

__all__ = ["HeterogeneousGame", "best_response_dynamics"]

P_MIN = 1e-3


@dataclasses.dataclass(frozen=True)
class HeterogeneousGame:
    """N nodes with per-node cost factors and incentive weights."""

    costs: jax.Array              # (N,) c_i
    gammas: jax.Array             # (N,) gamma_i
    dur: DurationModel

    @property
    def n(self) -> int:
        return int(self.costs.shape[0])

    def duration_slope(self, p: jax.Array, i: int) -> jax.Array:
        """E[d(m_-i + 1)] - E[d(m_-i)]: node i's marginal effect on E[D]."""
        p_others = jnp.delete(p, i, assume_unique_indices=True)
        pmf = poibin_pmf(p_others)                    # (N,) over 0..N-1
        tab = self.dur.table()                        # (N+1,)
        return jnp.sum(pmf * (tab[1:] - tab[:-1]))

    def utility(self, p: jax.Array, i: int) -> jax.Array:
        pmf = poibin_pmf(p)
        e_d = jnp.sum(pmf * self.dur.table())
        return (-e_d - self.gammas[i] * log_aoi(p[i])
                - self.costs[i] * p[i])

    def best_response(self, p: jax.Array, i: int) -> jax.Array:
        """Exact BR of node i: corner or stationary point of the concave part.

        u_i(p_i) = const + p_i * slope_d(-) - gamma_i*log(1/p_i - 1/2)
                   - c_i p_i
        d/dp_i = slope - c_i + gamma_i * 2 / (p_i (2 - p_i)).
        For gamma_i = 0: bang-bang on sign(slope - c_i). Else solve the
        quadratic gamma*2/(p(2-p)) = c_i - slope for p in (0, 1].
        """
        slope = -self.duration_slope(p, i)            # utility slope part
        a = slope - self.costs[i]
        g = self.gammas[i]
        if_zero = jnp.where(a > 0, 1.0, P_MIN)
        # g*2/(p(2-p)) + a = 0  =>  p(2-p) = -2g/a (needs a < 0)
        prod = -2.0 * g / jnp.where(a < 0, a, -1e-9)
        # p^2 - 2p + prod = 0 -> p = 1 - sqrt(1 - prod)
        disc = jnp.clip(1.0 - prod, 0.0, 1.0)
        p_star = 1.0 - jnp.sqrt(disc)
        interior = jnp.clip(p_star, P_MIN, 1.0)
        return jnp.where(g <= 0.0, if_zero,
                         jnp.where(a >= 0, 1.0, interior))

    def social_cost(self, p: jax.Array) -> jax.Array:
        """Sum over nodes of (E[D] + c_i p_i) (transfers excluded)."""
        pmf = poibin_pmf(p)
        e_d = jnp.sum(pmf * self.dur.table())
        return self.n * e_d + jnp.sum(self.costs * p)


def best_response_dynamics(
    game: HeterogeneousGame,
    p0: jax.Array | None = None,
    damping: float = 0.5,
    max_iters: int = 200,
    tol: float = 1e-5,
) -> tuple[jax.Array, bool, int]:
    """Damped Gauss-Seidel (sequential round-robin) best-response iteration.

    Sequential updates avoid the simultaneous-update cycling that strongly
    coupled congestion-style games exhibit. Returns (profile, converged,
    iters); the fixed point is an asymmetric NE (each node's BR given the
    others).
    """
    p = jnp.full((game.n,), 0.5) if p0 is None else jnp.asarray(p0)
    for it in range(max_iters):
        delta = 0.0
        for i in range(game.n):
            br = game.best_response(p, i)
            new_pi = (1 - damping) * p[i] + damping * br
            delta = max(delta, float(jnp.abs(new_pi - p[i])))
            p = p.at[i].set(new_pi)
        if delta < tol:
            return p, True, it + 1
    return p, False, max_iters


def planner_coordinate_descent(
    game: HeterogeneousGame,
    p0: jax.Array,
    grid: int = 101,
    rounds: int = 20,
) -> jax.Array:
    """Heterogeneity-aware planner: round-robin per-node minimization of the
    social cost. Monotone non-increasing, so started from any profile it
    lower-bounds that profile's cost — the PoA denominator for heterogeneous
    games (a common-p planner is provably suboptimal under cost spread)."""
    p = jnp.asarray(p0)
    gridv = jnp.linspace(P_MIN, 1.0, grid)
    for _ in range(rounds):
        changed = False
        for i in range(game.n):
            costs = jnp.stack([game.social_cost(p.at[i].set(q))
                               for q in gridv])
            best = gridv[int(jnp.argmin(costs))]
            if abs(float(best) - float(p[i])) > 1e-9:
                p = p.at[i].set(best)
                changed = True
        if not changed:
            break
    return p


def verify_equilibrium(game: HeterogeneousGame, p: jax.Array,
                       grid: int = 64) -> float:
    """Max profitable unilateral deviation over a grid (0 at an exact NE)."""
    worst = 0.0
    gridv = jnp.linspace(P_MIN, 1.0, grid)
    for i in range(game.n):
        u_eq = float(game.utility(p, i))
        for q in gridv:
            u_dev = float(game.utility(p.at[i].set(q), i))
            worst = max(worst, u_dev - u_eq)
    return worst
