"""Beyond-paper extension: heterogeneous nodes and asymmetric equilibria.

The paper assumes identical nodes and solves the symmetric NE; its §V names
heterogeneous extensions as future work. Here nodes carry individual cost
factors ``c_i`` (e.g. battery-constrained sensors vs mains-powered gateways)
and optionally individual AoI weights ``gamma_i``. We compute:

* asymmetric best-response dynamics over the full Poisson-Binomial profile
  (the exact E[D] of eq. 8 with per-node probabilities — no mean-field
  approximation), damped to a fixed point;
* the utilitarian optimum over a common p (planner without price
  discrimination) and the heterogeneity-aware social cost of the reached
  profile, giving a heterogeneous PoA.

The heavy lifting lives in :mod:`repro.core.asymmetric_batched`: one jitted
XLA program runs the damped Gauss-Seidel sweep as a `lax.scan` over nodes
with O(N) leave-one-out pmf deconvolution, and ``vmap``s over scenario
batches. :func:`best_response_dynamics`, :func:`verify_equilibrium`, and
:func:`planner_coordinate_descent` below keep their original signatures and
semantics but delegate there (B = 1); the pre-batching Python-loop
implementations are retained as ``*_reference`` oracles for tests.

Everything reuses :mod:`repro.core.poibin`; the per-node best response
exploits the same decomposition as the symmetric case: with opponents'
profile fixed, u_i is linear in p_i (duration, cost) plus the concave AoI
term, so the BR is either a corner or the unique stationary point of the
concave part (closed form in
:func:`repro.core.asymmetric_batched.best_response_given_slope`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.aoi import log_aoi
from repro.core.asymmetric_batched import (P_MIN, best_response_given_slope,
                                           planner_batched,
                                           solve_heterogeneous,
                                           verify_equilibrium_batched)
from repro.core.duration import DurationModel
from repro.core.poibin import poibin_pmf

__all__ = [
    "HeterogeneousGame",
    "best_response_dynamics",
    "best_response_dynamics_reference",
    "planner_coordinate_descent",
    "verify_equilibrium",
    "verify_equilibrium_reference",
]


@dataclasses.dataclass(frozen=True)
class HeterogeneousGame:
    """N nodes with per-node cost factors and incentive weights."""

    costs: jax.Array              # (N,) c_i
    gammas: jax.Array             # (N,) gamma_i
    dur: DurationModel

    @property
    def n(self) -> int:
        return int(self.costs.shape[0])

    def duration_slope(self, p: jax.Array, i: int) -> jax.Array:
        """E[d(m_-i + 1)] - E[d(m_-i)]: node i's marginal effect on E[D]."""
        p_others = jnp.delete(p, i, assume_unique_indices=True)
        pmf = poibin_pmf(p_others)                    # (N,) over 0..N-1
        tab = self.dur.table()                        # (N+1,)
        return jnp.sum(pmf * (tab[1:] - tab[:-1]))

    def utility(self, p: jax.Array, i: int) -> jax.Array:
        pmf = poibin_pmf(p)
        e_d = jnp.sum(pmf * self.dur.table())
        return (-e_d - self.gammas[i] * log_aoi(p[i])
                - self.costs[i] * p[i])

    def best_response(self, p: jax.Array, i: int) -> jax.Array:
        """Exact BR of node i: corner or stationary point of the concave part.

        Closed form shared with the batched engine — see
        :func:`repro.core.asymmetric_batched.best_response_given_slope` for
        the derivation (and the two-sided division guard at a = 0).
        """
        slope = -self.duration_slope(p, i)            # utility slope part
        return best_response_given_slope(slope, self.costs[i], self.gammas[i])

    def social_cost(self, p: jax.Array) -> jax.Array:
        """Sum over nodes of (E[D] + c_i p_i) (transfers excluded)."""
        pmf = poibin_pmf(p)
        e_d = jnp.sum(pmf * self.dur.table())
        return self.n * e_d + jnp.sum(self.costs * p)


def best_response_dynamics(
    game: HeterogeneousGame,
    p0: jax.Array | None = None,
    damping: float = 0.5,
    max_iters: int = 200,
    tol: float = 1e-5,
) -> tuple[jax.Array, bool, int]:
    """Damped Gauss-Seidel (sequential round-robin) best-response iteration.

    Sequential updates avoid the simultaneous-update cycling that strongly
    coupled congestion-style games exhibit. Returns (profile, converged,
    iters); the fixed point is an asymmetric NE (each node's BR given the
    others).

    Delegates to the batched engine (B = 1 of one jitted XLA program) with
    identical semantics; see :func:`best_response_dynamics_reference` for the
    pre-batching Python loop it is tested against.
    """
    sol = solve_heterogeneous(game.costs, game.gammas, game.dur, p0=p0,
                              damping=damping, max_iters=max_iters, tol=tol)
    return sol.single()


def best_response_dynamics_reference(
    game: HeterogeneousGame,
    p0: jax.Array | None = None,
    damping: float = 0.5,
    max_iters: int = 200,
    tol: float = 1e-5,
) -> tuple[jax.Array, bool, int]:
    """The original eager Gauss-Seidel loop (oracle for the batched engine)."""
    p = jnp.full((game.n,), 0.5) if p0 is None else jnp.asarray(p0)
    for it in range(max_iters):
        delta = 0.0
        for i in range(game.n):
            br = game.best_response(p, i)
            new_pi = (1 - damping) * p[i] + damping * br
            delta = max(delta, float(jnp.abs(new_pi - p[i])))
            p = p.at[i].set(new_pi)
        if delta < tol:
            return p, True, it + 1
    return p, False, max_iters


def planner_coordinate_descent(
    game: HeterogeneousGame,
    p0: jax.Array,
    grid: int = 101,
    rounds: int = 20,
) -> jax.Array:
    """Heterogeneity-aware planner: round-robin per-node minimization of the
    social cost. Monotone non-increasing, so started from any profile it
    lower-bounds that profile's cost — the PoA denominator for heterogeneous
    games (a common-p planner is provably suboptimal under cost spread).

    Delegates to the jitted :func:`repro.core.asymmetric_batched.planner_batched`.
    The social cost is linear in each ``p_i`` with the others fixed, so each
    coordinate minimum is a corner and the historical ``grid`` parameter is
    moot (kept for API compatibility — a grid argmin of a linear function
    picks the same corner).
    """
    del grid  # exact corner selection supersedes the grid argmin
    return planner_batched(game.costs, game.dur, jnp.asarray(p0),
                           rounds=rounds)[0]


def verify_equilibrium(game: HeterogeneousGame, p: jax.Array,
                       grid: int = 64) -> float:
    """Max profitable unilateral deviation over a grid (0 at an exact NE).

    Delegates to the jitted vectorized deviation grid in
    :func:`repro.core.asymmetric_batched.verify_equilibrium_batched`.
    """
    return float(verify_equilibrium_batched(game.costs, game.gammas, game.dur,
                                            jnp.asarray(p), grid=grid)[0])


def verify_equilibrium_reference(game: HeterogeneousGame, p: jax.Array,
                                 grid: int = 64) -> float:
    """The original Python double loop (oracle for the jitted certifier)."""
    worst = 0.0
    gridv = jnp.linspace(P_MIN, 1.0, grid)
    for i in range(game.n):
        u_eq = float(game.utility(p, i))
        for q in gridv:
            u_dev = float(game.utility(p.at[i].set(q), i))
            worst = max(worst, u_dev - u_eq)
    return worst
