"""Energy accounting for participatory FL (paper eqs. 1-7).

Per round t and node i:

    participant:      E_i^t = P_hw * T_train + P_tx * T_tx + P_idle * (T_round - T_train)   (1,2,3,4)
    non-participant:  E_j^t = P_idle * T_round                                              (5)
    round total:      E^t   = sum over all nodes                                            (6)
    task total:       E     = sum_t E^t                                                     (7)

Power constants follow Table I (P_idle = 96.85 W); ``P_hw`` and ``T_train``
are calibrated so the affine E-vs-d relationship of Fig. 1 matches Table II
(see :func:`calibrate_from_table`). ``E_tx`` comes from the 802.11ax airtime
model. On the TPU path, ``T_train`` is instead derived from the dry-run
roofline (HLO FLOPs / chip peak) — see :mod:`repro.core.controller`.

All round-level functions are jittable and differentiable; the ledger is a
pytree usable inside ``lax.scan`` round loops.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm80211ax import (
    CommParams, PAPER_COMM, airtime_model, airtime_model_batched)
from repro.core.duration import PAPER_N_CLIENTS, PAPER_TABLE_II

__all__ = [
    "EnergyParams",
    "EnergyLedger",
    "round_energy",
    "expected_round_energy",
    "task_energy",
    "expected_task_energy",
    "calibrate_from_table",
    "per_node_energy_rates",
    "channel_energy_rates",
    "PAPER_MODEL_BYTES",
]

PAPER_MODEL_BYTES = 44.73e6  # S_w: ResNet-18 fp32 update, Table I

J_PER_WH = 3600.0


@dataclasses.dataclass(frozen=True)
class EnergyParams:
    """Power/time constants of the energy model (Table I + calibration)."""

    p_idle_w: float = 96.85       # P_idle (Table I)
    p_hw_w: float = 250.0         # P_hw: CPU+GPU+DRAM while training (eq. 1)
    t_round_s: float = 10.0       # T_round (Table I)
    t_train_s: float = 4.0        # T_train (calibrated; <= t_round)
    model_bytes: float = PAPER_MODEL_BYTES
    comm: CommParams = PAPER_COMM

    @property
    def e_tx_j(self) -> float:
        """E_tx = P_tx * T_tx (eq. 2) — constant across rounds/nodes."""
        a = airtime_model(self.model_bytes, self.comm)
        return a["tx_power_w"] * a["t_tx_s"]

    @property
    def e_participant_j(self) -> float:
        """Per-round energy of a participating node (eq. 4)."""
        return (self.p_hw_w * self.t_train_s
                + self.e_tx_j
                + self.p_idle_w * (self.t_round_s - self.t_train_s))

    @property
    def e_idle_j(self) -> float:
        """Per-round energy of a non-participant (eq. 5)."""
        return self.p_idle_w * self.t_round_s


def round_energy(mask: jax.Array, params: EnergyParams) -> jax.Array:
    """Eq. (6): total energy of one round given the participation mask.

    Args:
        mask: ``(N,)`` bool/0-1 — who participated this round.
    Returns:
        scalar Joules.
    """
    mask = jnp.asarray(mask, jnp.float64)
    return jnp.sum(mask * params.e_participant_j
                   + (1.0 - mask) * params.e_idle_j)


def expected_round_energy(p: jax.Array, params: EnergyParams) -> jax.Array:
    """E over participation draws of eq. (6); linear in p."""
    p = jnp.asarray(p, jnp.float64)
    return jnp.sum(p * params.e_participant_j
                   + (1.0 - p) * params.e_idle_j)


def task_energy(round_energies: jax.Array) -> jax.Array:
    """Eq. (7): sum over rounds."""
    return jnp.sum(round_energies)


def expected_task_energy(
    p: jax.Array,
    expected_rounds: jax.Array,
    params: EnergyParams,
) -> jax.Array:
    """E[task energy] = E[D] * E[round energy].

    Exact when participation is iid across rounds and independent of the
    (deterministic-given-k) round count — the paper's Fig. 1 linearity.
    Returns Joules.
    """
    return expected_rounds * expected_round_energy(p, params)


def per_node_energy_rates(
    params: "EnergyParams | list[EnergyParams] | tuple[EnergyParams, ...]",
    n_nodes: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Flatten per-node :class:`EnergyParams` into raw joule-rate vectors.

    Heterogeneous fleets mix hardware tiers (battery sensors vs. mains
    gateways), i.e. different ``(P_hw, T_train, comm)`` per node. The
    campaign engine consumes raw per-round rates, so this helper resolves a
    node-indexed list of :class:`EnergyParams` into the
    ``(e_participant_j, e_idle_j)`` vectors it vmaps over.

    Args:
        params: one shared :class:`EnergyParams` (requires ``n_nodes``) or a
            length-N sequence, one per node.
        n_nodes: fleet size when ``params`` is a single instance.

    Returns:
        ``(e_participant_j, e_idle_j)`` — two ``(N,)`` float64 arrays in
        Joules per round (eq. 4 / eq. 5 evaluated per node).
    """
    if isinstance(params, EnergyParams):
        if n_nodes is None:
            raise ValueError("n_nodes required for a single EnergyParams")
        params = [params] * n_nodes
    e_part = jnp.asarray([e.e_participant_j for e in params], jnp.float64)
    e_idle = jnp.asarray([e.e_idle_j for e in params], jnp.float64)
    return e_part, e_idle


def channel_energy_rates(
    bits_per_symbol_per_sc: jax.Array,
    params: EnergyParams = EnergyParams(),
    payload_bytes: "jax.Array | float | None" = None,
) -> tuple[jax.Array, jax.Array]:
    """Channel-aware per-node joule rates from a per-node MCS vector.

    The channel-heterogeneous counterpart of :func:`per_node_energy_rates`:
    instead of node-indexed :class:`EnergyParams` instances, the fleet
    shares one power model and differs in *link quality* — per-node
    ``bits_per_symbol_per_sc`` (and optionally per-node update sizes).
    ``E_tx`` is evaluated per node with :func:`airtime_model_batched`
    and substituted into eq. (4):

        e_part[i] = P_hw*T_train + E_tx(MCS_i, S_i) + P_idle*(T_round - T_train)
        e_idle[i] = P_idle*T_round

    jit/vmap-compatible, so a campaign batch can sweep channel maps.

    Args:
        bits_per_symbol_per_sc: ``(N,)`` per-node MCS knob.
        params: shared power/time constants (``params.comm`` supplies every
            non-MCS channel parameter).
        payload_bytes: per-node or scalar update size; defaults to
            ``params.model_bytes``.

    Returns:
        ``(e_participant_j, e_idle_j)`` — ``(N,)`` float64 vectors feeding
        the campaign engine's ``energy_rates_j`` seam. At a uniform MCS
        equal to ``params.comm.bits_per_symbol_per_sc`` they reproduce the
        scalar ``params.e_participant_j`` / ``params.e_idle_j`` exactly
        (the uniform-channel bitwise pin in ``tests/test_hetero_campaign.py``).
    """
    bps = jnp.asarray(bits_per_symbol_per_sc, jnp.float64)
    if payload_bytes is None:
        payload_bytes = params.model_bytes
    a = airtime_model_batched(payload_bytes, bps, params.comm)
    e_tx_j = a["tx_power_w"] * a["t_tx_s"]
    e_part = (params.p_hw_w * params.t_train_s
              + e_tx_j
              + params.p_idle_w * (params.t_round_s - params.t_train_s))
    e_idle = jnp.broadcast_to(
        jnp.asarray(params.p_idle_w * params.t_round_s, jnp.float64),
        e_part.shape)
    return e_part, e_idle


def calibrate_from_table(
    p_idle_w: float = 96.85,
    t_round_s: float = 10.0,
    n_nodes: int = PAPER_N_CLIENTS,
) -> EnergyParams:
    """Back out (P_hw, T_train) so E(p, d) reproduces Table II(b).

    Table II(b) gives (p, mean d, mean E[Wh]). Under the model,
        E_wh(p, d) = d * [N*P_idle*T_round + N*p*(P_hw*T_train
                     - P_idle*T_train + E_tx)] / 3600
    i.e. per-round extra joules per participant
        x = P_hw*T_train - P_idle*T_train + E_tx
    is the single unknown; least-squares over the table rows yields x, and we
    split it with the paper-plausible T_train = 4 s to report P_hw.
    """
    tab = PAPER_TABLE_II
    p_col, d_col, e_col = tab[:, 0], tab[:, 1], tab[:, 3]
    floor_j = n_nodes * p_idle_w * t_round_s
    # e_col[Wh]*3600 = d * (floor + N*p*x)  =>  x via least squares
    y = e_col * J_PER_WH / d_col - floor_j
    a = n_nodes * p_col
    x = float(np.dot(a, y) / np.dot(a, a))
    t_train = 4.0
    e_tx = EnergyParams(p_idle_w=p_idle_w).e_tx_j
    p_hw = (x - e_tx) / t_train + p_idle_w
    return EnergyParams(p_idle_w=p_idle_w, p_hw_w=float(p_hw),
                        t_round_s=t_round_s, t_train_s=t_train)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EnergyLedger:
    """Running energy account, usable inside jitted round loops.

    Attributes (all jnp scalars/arrays, Joules):
        per_node_j: ``(N,)`` cumulative per-node energy.
        rounds: number of rounds accounted.
        participation_counts: ``(N,)`` how often each node joined.
    """

    per_node_j: jax.Array
    rounds: jax.Array
    participation_counts: jax.Array

    @staticmethod
    def create(n_nodes: int) -> "EnergyLedger":
        return EnergyLedger(
            per_node_j=jnp.zeros((n_nodes,), jnp.float64),
            rounds=jnp.zeros((), jnp.int64),
            participation_counts=jnp.zeros((n_nodes,), jnp.int64),
        )

    def record_round(self, mask: jax.Array, params: EnergyParams) -> "EnergyLedger":
        return self.record_round_j(mask, params.e_participant_j,
                                   params.e_idle_j)

    def record_round_j(
        self,
        mask: jax.Array,
        e_participant_j: jax.Array | float,
        e_idle_j: jax.Array | float,
    ) -> "EnergyLedger":
        """Record one round from raw per-round joule rates.

        Unlike :meth:`record_round` the rates may be traced values — a
        scalar (symmetric hardware) or an ``(N,)`` per-node vector
        (heterogeneous fleet; see :func:`per_node_energy_rates`) — so a
        batch of scenarios with *different* energy models can be
        ``vmap``-ed over ``(e_participant_j, e_idle_j)`` arrays inside one
        jitted campaign program.

        Args:
            mask: ``(N,)`` bool/0-1 — who participated this round. Nodes
                with ``mask[i] == False`` (including churned-out nodes)
                accrue ``e_idle_j`` only.
            e_participant_j / e_idle_j: Joules per round, scalar or ``(N,)``.
        """
        maskf = jnp.asarray(mask, jnp.float64)
        node_j = maskf * e_participant_j + (1.0 - maskf) * e_idle_j
        return EnergyLedger(
            per_node_j=self.per_node_j + node_j,
            rounds=self.rounds + 1,
            participation_counts=self.participation_counts
            + jnp.asarray(mask, jnp.int64),
        )

    @property
    def total_j(self) -> jax.Array:
        """Scalar task energy in Joules (``(B,)`` for a batched ledger)."""
        return jnp.sum(self.per_node_j, axis=-1)

    @property
    def total_wh(self) -> jax.Array:
        """Scalar task energy in Watt-hours (``(B,)`` when batched)."""
        return self.total_j / J_PER_WH

    @property
    def per_node_wh(self) -> jax.Array:
        """``(N,)`` cumulative per-node energy in Watt-hours (``(B, N)``
        when the ledger carries a leading batch axis)."""
        return self.per_node_j / J_PER_WH

    def summary(self) -> dict[str, Any]:
        return {
            "total_wh": float(self.total_wh),
            "rounds": int(self.rounds),
            "mean_node_wh": float(jnp.mean(self.per_node_j) / J_PER_WH),
            "mean_participation": float(jnp.mean(
                self.participation_counts / jnp.maximum(self.rounds, 1))),
        }
