"""Beyond-paper: online duration-model learning + adaptive participation.

The paper fits d(k) offline from a 42-point simulation campaign, then fixes
p for the whole task. A deployed system has neither the campaign nor a
stationary task. ``OnlineDurationEstimator`` learns d(k) on the fly from
per-round (participants, progress) observations and hands the refreshed
model to the game solver, so the controller can re-solve the NE between
rounds ("adaptive participatory FL").

Model: convergence is reached when accumulated *progress* hits 1. A round
with k participants contributes progress ≈ 1/d(k), so observing per-round
validation-accuracy deltas gives noisy samples of 1/d(k). We regress
progress-per-round on the diminishing-returns basis
``g(k) = a + b·k/(k + s)`` (monotone, saturating — the shape the paper's
Table II implies) by recursive least squares over basis features
[1, k/(k+s)] with a small ridge; d(k) = ceil(remaining / g(k)) feeds the
standard :class:`DurationModel` interface via table evaluation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.duration import DurationModel, fit_polynomial_duration

__all__ = ["OnlineDurationEstimator"]


@dataclasses.dataclass
class OnlineDurationEstimator:
    """Recursive least squares on progress-per-round vs participant count."""

    n_nodes: int
    saturation: float = 5.0        # s in k/(k+s)
    ridge: float = 1e-3
    horizon: float = 500.0
    _xtx: np.ndarray = dataclasses.field(default=None, repr=False)
    _xty: np.ndarray = dataclasses.field(default=None, repr=False)
    _n_obs: int = 0

    def __post_init__(self):
        self._xtx = np.eye(2) * self.ridge
        self._xty = np.zeros(2)

    def _features(self, k: np.ndarray) -> np.ndarray:
        k = np.asarray(k, np.float64)
        return np.stack([np.ones_like(k), k / (k + self.saturation)], -1)

    def observe(self, participants: int, progress: float) -> None:
        """One round's observation: k participants, progress in [0, 1]
        (e.g. validation-accuracy gain normalized by the target gap)."""
        x = self._features(np.asarray([participants]))[0]
        self._xtx += np.outer(x, x)
        self._xty += x * max(progress, 0.0)
        self._n_obs += 1

    @property
    def n_obs(self) -> int:
        return self._n_obs

    def progress_rate(self, k: np.ndarray) -> np.ndarray:
        theta = np.linalg.solve(self._xtx, self._xty)
        return np.clip(self._features(k) @ theta, 1e-6, None)

    def duration_model(self) -> DurationModel:
        """Snapshot as a DurationModel (d(k) = 1 / rate(k), capped)."""
        k = np.arange(0, self.n_nodes + 1, dtype=np.float64)
        d = np.clip(1.0 / self.progress_rate(k), 1.0, self.horizon)
        d[0] = self.horizon
        # express through the polynomial interface used everywhere else
        coeffs = fit_polynomial_duration(
            jnp.asarray(k[1:] / self.n_nodes), jnp.asarray(d[1:]), degree=6)
        return DurationModel(
            coeffs=coeffs, n_nodes=self.n_nodes, d_zero=self.horizon,
            d_floor=float(d[1:].min()), lo_frac=1.0 / self.n_nodes,
            hi_frac=1.0, rise=0.0)
