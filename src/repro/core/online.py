"""Beyond-paper: online duration-model learning + adaptive participation.

The paper fits d(k) offline from a 42-point simulation campaign, then fixes
p for the whole task. A deployed system has neither the campaign nor a
stationary task. ``OnlineDurationEstimator`` learns d(k) on the fly from
per-round (participants, progress) observations and hands the refreshed
model to the game solver, so the controller can re-solve the NE between
rounds ("adaptive participatory FL").

Model: convergence is reached when accumulated *progress* hits 1. A round
with k participants contributes progress ≈ 1/d(k), so observing per-round
validation-accuracy deltas gives noisy samples of 1/d(k). We regress
progress-per-round on the diminishing-returns basis
``g(k) = a + b·k/(k + s)`` (monotone, saturating — the shape the paper's
Table II implies) by recursive least squares over basis features
[1, k/(k+s)] with a small ridge; d(k) = ceil(remaining / g(k)) feeds the
standard :class:`DurationModel` interface via table evaluation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.duration import DurationModel, fit_polynomial_duration

__all__ = ["OnlineDurationEstimator"]


@dataclasses.dataclass
class OnlineDurationEstimator:
    """Recursive least squares on progress-per-round vs participant count."""

    n_nodes: int
    saturation: float = 5.0        # s in k/(k+s)
    ridge: float = 1e-3
    horizon: float = 500.0
    _xtx: np.ndarray = dataclasses.field(default=None, repr=False)
    _xty: np.ndarray = dataclasses.field(default=None, repr=False)
    _n_obs: int = 0

    def __post_init__(self):
        self._xtx = np.eye(2) * self.ridge
        self._xty = np.zeros(2)

    def _features(self, k: np.ndarray) -> np.ndarray:
        k = np.asarray(k, np.float64)
        return np.stack([np.ones_like(k), k / (k + self.saturation)], -1)

    def observe(self, participants: int, progress: float) -> None:
        """One round's observation: k participants, progress in [0, 1]
        (e.g. validation-accuracy gain normalized by the target gap)."""
        x = self._features(np.asarray([participants]))[0]
        self._xtx += np.outer(x, x)
        self._xty += x * max(progress, 0.0)
        self._n_obs += 1

    def observe_batch(self, participants, progress) -> None:
        """Vectorized :meth:`observe` over whole campaigns.

        Equivalent to calling ``observe`` per round (RLS normal equations
        are additive), but one matmul per campaign — the ingestion path for
        the scan-fused engine's ``(rounds,)`` histories.
        """
        k = np.asarray(participants, np.float64).ravel()
        g = np.clip(np.asarray(progress, np.float64).ravel(), 0.0, None)
        if k.shape != g.shape:
            raise ValueError(f"participants {k.shape} vs progress {g.shape}")
        x = self._features(k)
        self._xtx += x.T @ x
        self._xty += x.T @ g
        self._n_obs += int(k.size)

    def ingest_trajectory(self, participants, acc_history,
                          target_acc: float) -> None:
        """Feed one campaign's realized trajectory.

        ``participants``/``acc_history`` are the per-round participant
        counts and validation accuracies of the rounds actually run (slice a
        :class:`~repro.federated.campaign.CampaignResult`'s histories with
        ``[:rounds[i]]``). Per-round progress is the accuracy gain
        normalized by the initial gap to ``target_acc``.
        """
        acc = np.asarray(acc_history, np.float64).ravel()
        k = np.asarray(participants, np.float64).ravel()
        if acc.size < 2:
            return
        gap = target_acc - acc[0]
        if gap <= 1e-6:
            return  # started at/above target: no informative progress signal
        # acc[t] is measured AFTER round t, so round t's participants k[t]
        # produced the gain acc[t] - acc[t-1]; round 0's gain is unobservable
        # (no pre-round accuracy) and is dropped rather than fabricated.
        self.observe_batch(k[1:acc.size], np.diff(acc) / gap)

    @property
    def n_obs(self) -> int:
        return self._n_obs

    def progress_rate(self, k: np.ndarray) -> np.ndarray:
        theta = np.linalg.solve(self._xtx, self._xty)
        return np.clip(self._features(k) @ theta, 1e-6, None)

    def duration_model(self) -> DurationModel:
        """Snapshot as a DurationModel (d(k) = 1 / rate(k), capped)."""
        k = np.arange(0, self.n_nodes + 1, dtype=np.float64)
        d = np.clip(1.0 / self.progress_rate(k), 1.0, self.horizon)
        d[0] = self.horizon
        # express through the polynomial interface used everywhere else
        coeffs = fit_polynomial_duration(
            jnp.asarray(k[1:] / self.n_nodes), jnp.asarray(d[1:]), degree=6)
        return DurationModel(
            coeffs=coeffs, n_nodes=self.n_nodes, d_zero=self.horizon,
            d_floor=float(d[1:].min()), lo_frac=1.0 / self.n_nodes,
            hi_frac=1.0, rise=0.0)
