"""Poisson-Binomial distribution of the number of participating nodes.

Paper eq. (9): closed-form DFT expression for the pmf of ``m = sum_i X_i``
with independent ``X_i ~ Bernoulli(p_i)`` (Fernandez & Williams, 2010), and
eq. (8): the expected task duration ``E[D] = sum_k d(k) P[m=k]``.

Everything is pure JAX (complex64/complex128 DFT) and differentiable in the
participation probabilities — the NE solver in :mod:`repro.core.game`
differentiates straight through this pmf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "poibin_pmf",
    "poibin_pmf_recursive",
    "poibin_mean",
    "poibin_cdf",
    "expected_duration",
    "symmetric_pmf",
]


def poibin_pmf(p: jax.Array) -> jax.Array:
    """Pmf of the Poisson-Binomial distribution via the DFT closed form.

    Implements paper eq. (9)::

        P[m] = (1/(N+1)) * sum_{n=0}^{N} exp(-j 2 pi n m/(N+1))
                  * prod_{k=1}^{N} [p_k (exp(j 2 pi n/(N+1)) - 1) + 1]

    Args:
        p: ``(N,)`` participation probabilities in [0, 1].

    Returns:
        ``(N+1,)`` real pmf over m = 0..N.
    """
    p = jnp.asarray(p)
    n_nodes = p.shape[0]
    size = n_nodes + 1
    # Characteristic function evaluated on the (N+1)-point unit circle.
    n = jnp.arange(size)
    omega = jnp.exp(2j * jnp.pi * n / size)  # (N+1,)
    # prod_k [p_k (w - 1) + 1] for each frequency.
    terms = p[None, :] * (omega[:, None] - 1.0) + 1.0  # (N+1, N)
    # Product via sum of logs is unstable near zeros; direct prod is fine at N<=few hundred.
    chi = jnp.prod(terms, axis=1)  # (N+1,)
    m = jnp.arange(size)
    dft = jnp.exp(-2j * jnp.pi * jnp.outer(m, n) / size)  # (N+1, N+1)
    pmf = (dft @ chi).real / size
    # Numerical cleanup: clip tiny negatives, renormalize.
    pmf = jnp.clip(pmf, 0.0, 1.0)
    return pmf / jnp.sum(pmf)


def poibin_pmf_recursive(p: jax.Array) -> jax.Array:
    """Pmf via the stable O(N^2) convolution recursion (oracle for tests).

    ``f_{k+1} = conv(f_k, [1-p_k, p_k])`` — exact up to float error, no DFT.
    """
    p = jnp.asarray(p)
    n_nodes = p.shape[0]
    size = n_nodes + 1

    def step(pmf, pk):
        shifted = jnp.concatenate([jnp.zeros((1,), pmf.dtype), pmf[:-1]])
        return pmf * (1.0 - pk) + shifted * pk, None

    init = jnp.zeros((size,), p.dtype).at[0].set(1.0)
    pmf, _ = jax.lax.scan(step, init, p)
    return pmf


def poibin_mean(p: jax.Array) -> jax.Array:
    """E[m] = sum_i p_i."""
    return jnp.sum(p)


def poibin_cdf(p: jax.Array) -> jax.Array:
    """Cdf over m = 0..N."""
    return jnp.cumsum(poibin_pmf(p))


def symmetric_pmf(p_scalar: jax.Array, n_nodes: int) -> jax.Array:
    """Pmf when all nodes share probability ``p`` (Binomial(N, p)) via eq. (9)."""
    return poibin_pmf(jnp.full((n_nodes,), p_scalar))


def expected_duration(p: jax.Array, duration_of_k: jax.Array) -> jax.Array:
    """Paper eq. (8): ``E[D] = sum_{i=0}^{N} d(i) P[m=i]``.

    Args:
        p: ``(N,)`` participation probabilities.
        duration_of_k: ``(N+1,)`` rounds-to-converge when exactly k nodes
            participate each round (see :mod:`repro.core.duration`).
    """
    pmf = poibin_pmf(p)
    return jnp.sum(pmf * duration_of_k)
