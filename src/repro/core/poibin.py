"""Poisson-Binomial distribution of the number of participating nodes.

Paper eq. (9): closed-form DFT expression for the pmf of ``m = sum_i X_i``
with independent ``X_i ~ Bernoulli(p_i)`` (Fernandez & Williams, 2010), and
eq. (8): the expected task duration ``E[D] = sum_k d(k) P[m=k]``.

Everything scalar here is pure JAX (complex64/complex128 DFT) and
differentiable in the participation probabilities — the NE solver in
:mod:`repro.core.game` differentiates straight through this pmf.

The *batched* entry points (:func:`poibin_pmf_batched`,
:func:`poibin_pmf_loo_all`) additionally dispatch through the kernel layer
(:mod:`repro.kernels.poibin_dft` via ``repro.kernels.ops``): pass
``backend="pallas"`` — or set ``REPRO_KERNEL_BACKEND=pallas`` — to fuse a
whole (B, N) scenario batch into one Pallas program. The kernel path is
fp32 and **not differentiable**; the default ``"ref"`` backend keeps the
pure-jnp vmapped math (bitwise-identical to calling the scalar functions
under ``jax.vmap`` yourself).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "poibin_pmf",
    "poibin_pmf_recursive",
    "poibin_convolve",
    "poibin_pmf_loo",
    "poibin_pmf_batched",
    "poibin_pmf_loo_all",
    "poibin_mean",
    "poibin_cdf",
    "expected_duration",
    "symmetric_pmf",
]


def poibin_pmf(p: jax.Array) -> jax.Array:
    """Pmf of the Poisson-Binomial distribution via the DFT closed form.

    Implements paper eq. (9)::

        P[m] = (1/(N+1)) * sum_{n=0}^{N} exp(-j 2 pi n m/(N+1))
                  * prod_{k=1}^{N} [p_k (exp(j 2 pi n/(N+1)) - 1) + 1]

    Args:
        p: ``(N,)`` participation probabilities in [0, 1].

    Returns:
        ``(N+1,)`` real pmf over m = 0..N.
    """
    p = jnp.asarray(p)
    n_nodes = p.shape[0]
    size = n_nodes + 1
    # Characteristic function evaluated on the (N+1)-point unit circle.
    n = jnp.arange(size)
    omega = jnp.exp(2j * jnp.pi * n / size)  # (N+1,)
    # prod_k [p_k (w - 1) + 1] for each frequency.
    terms = p[None, :] * (omega[:, None] - 1.0) + 1.0  # (N+1, N)
    # Product via sum of logs is unstable near zeros; direct prod is fine at N<=few hundred.
    chi = jnp.prod(terms, axis=1)  # (N+1,)
    m = jnp.arange(size)
    dft = jnp.exp(-2j * jnp.pi * jnp.outer(m, n) / size)  # (N+1, N+1)
    pmf = (dft @ chi).real / size
    # Numerical cleanup: clip tiny negatives, renormalize.
    pmf = jnp.clip(pmf, 0.0, 1.0)
    return pmf / jnp.sum(pmf)


def poibin_pmf_recursive(p: jax.Array) -> jax.Array:
    """Pmf via the stable O(N^2) convolution recursion (oracle for tests).

    ``f_{k+1} = conv(f_k, [1-p_k, p_k])`` — exact up to float error, no DFT.
    """
    p = jnp.asarray(p)
    n_nodes = p.shape[0]
    size = n_nodes + 1

    def step(pmf, pk):
        shifted = jnp.concatenate([jnp.zeros((1,), pmf.dtype), pmf[:-1]])
        return pmf * (1.0 - pk) + shifted * pk, None

    init = jnp.zeros((size,), p.dtype).at[0].set(1.0)
    pmf, _ = jax.lax.scan(step, init, p)
    return pmf


def poibin_convolve(pmf: jax.Array, p_k: jax.Array) -> jax.Array:
    """Fold one Bernoulli(``p_k``) factor into a Poisson-Binomial pmf.

    ``pmf`` is a fixed-length ``(S,)`` array whose top entry must be zero
    (the support grows by one); the result stays ``(S,)``. This is the single
    step of :func:`poibin_pmf_recursive` exposed so the heterogeneous-game
    engine can do incremental Gauss-Seidel pmf updates in O(N) instead of a
    full O(N²) recompute per node.
    """
    shifted = jnp.concatenate([jnp.zeros((1,), pmf.dtype), pmf[:-1]])
    return pmf * (1.0 - p_k) + shifted * p_k


def poibin_pmf_loo(pmf: jax.Array, p_i: jax.Array) -> jax.Array:
    """Leave-one-out deconvolution: divide node i's Bernoulli factor back out.

    Given the ``(N+1,)`` pmf of all N nodes and node i's probability ``p_i``,
    returns the ``(N+1,)`` pmf of the other N-1 nodes (support 0..N-1; the
    last entry is zero). This inverts :func:`poibin_convolve` exactly:
    ``poibin_convolve(poibin_pmf_loo(f, p_i), p_i) == f`` up to float error.

    Numerics: the division recursion amplifies error by ``p/(1-p)`` per step
    run forward and by ``(1-p)/p`` run backward, so we run

    * forward  ``g[k] = (f[k] - p_i·g[k-1]) / (1-p_i)`` when ``p_i ≤ 1/2``,
    * backward ``g[k] = (f[k+1] - (1-p_i)·g[k+1]) / p_i`` when ``p_i > 1/2``,

    keeping the per-step amplification ≤ 1 for every ``p_i`` in [0, 1]
    including the ``p_i ∈ {0, 1}`` corners (where the recursion degenerates
    to a copy/shift). Both branches are fixed-shape `lax.scan`s, so this is
    jit/vmap-safe.
    """
    pmf = jnp.asarray(pmf)
    p_i = jnp.asarray(p_i, pmf.dtype)
    q_i = 1.0 - p_i
    use_fwd = p_i <= 0.5
    # Safe denominators: the unused branch still executes under jit, so give
    # it a benign divisor instead of a possible 0.
    q_safe = jnp.where(use_fwd, q_i, 0.5)
    p_safe = jnp.where(use_fwd, 0.5, p_i)

    def fwd(g_prev, f_k):
        g_k = (f_k - p_i * g_prev) / q_safe
        return g_k, g_k

    _, g_fwd = jax.lax.scan(fwd, jnp.zeros((), pmf.dtype), pmf[:-1])

    def bwd(g_next, f_k1):
        g_k = (f_k1 - q_i * g_next) / p_safe
        return g_k, g_k

    _, g_bwd = jax.lax.scan(bwd, jnp.zeros((), pmf.dtype), pmf[1:],
                            reverse=True)

    g = jnp.where(use_fwd, g_fwd, g_bwd)
    return jnp.concatenate([g, jnp.zeros((1,), pmf.dtype)])


def poibin_pmf_batched(p: jax.Array, *, backend: str | None = None
                       ) -> jax.Array:
    """Pmfs of a whole ``(B, N)`` probability-matrix batch, ``(B, N+1)``.

    ``backend="pallas"`` runs the batched DFT kernel
    (:mod:`repro.kernels.poibin_dft`, fp32, one program for the batch);
    the default ``"ref"`` is exactly ``jax.vmap(poibin_pmf)`` (float64
    under x64, differentiable).
    """
    from repro.kernels import ops as kernel_ops  # lazy: keep core light

    if kernel_ops.resolve_backend(
            backend, default="ref", site="poibin.pmf_batched") == "pallas":
        return kernel_ops.poibin_pmf(p, backend="pallas")
    return jax.vmap(poibin_pmf)(p)


def poibin_pmf_loo_all(p: jax.Array, *, backend: str | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """All leave-one-out pmfs of a ``(B, N)`` batch in one pass.

    Returns ``(pmf (B, N+1), loo (B, N, N+1))`` where ``loo[b, i]`` is the
    pmf of scenario b's nodes excluding node i. ``backend="pallas"`` fuses
    DFT pmf + N deconvolutions per scenario into one kernel; the default
    ``"ref"`` builds the pmf with the stable convolution recursion and
    deconvolves it (``vmap``-ed :func:`poibin_pmf_loo`) — the exact op
    sequence of the heterogeneous-game certifier, kept as its bitwise
    oracle.
    """
    from repro.kernels import ops as kernel_ops  # lazy: keep core light

    if kernel_ops.resolve_backend(
            backend, default="ref", site="poibin.pmf_loo_all") == "pallas":
        return kernel_ops.poibin(p, backend="pallas")
    pmf = jax.vmap(poibin_pmf_recursive)(p)
    loo = jax.vmap(jax.vmap(poibin_pmf_loo, in_axes=(None, 0)))(pmf, p)
    return pmf, loo


def poibin_mean(p: jax.Array) -> jax.Array:
    """E[m] = sum_i p_i."""
    return jnp.sum(p)


def poibin_cdf(p: jax.Array) -> jax.Array:
    """Cdf over m = 0..N."""
    return jnp.cumsum(poibin_pmf(p))


def symmetric_pmf(p_scalar: jax.Array, n_nodes: int) -> jax.Array:
    """Pmf when all nodes share probability ``p`` (Binomial(N, p)) via eq. (9)."""
    return poibin_pmf(jnp.full((n_nodes,), p_scalar))


def expected_duration(p: jax.Array, duration_of_k: jax.Array) -> jax.Array:
    """Paper eq. (8): ``E[D] = sum_{i=0}^{N} d(i) P[m=i]``.

    Args:
        p: ``(N,)`` participation probabilities.
        duration_of_k: ``(N+1,)`` rounds-to-converge when exactly k nodes
            participate each round (see :mod:`repro.core.duration`).
    """
    pmf = poibin_pmf(p)
    return jnp.sum(pmf * duration_of_k)
