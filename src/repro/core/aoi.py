"""Age-of-Information incentive (paper eq. 10).

With per-round Bernoulli(p) participation, the inter-participation time Y is
Geometric(p) (support 1, 2, ...). The expected AoI of a node is the renewal
reward ratio

    E[delta] = E[Y^2] / (2 E[Y]) = 1/p - 1/2,

using E[Y] = 1/p and E[Y^2] = (2 - p)/p^2. The paper rewards participation
with ``-gamma * log(E[delta])`` inside the utility.

:class:`AoITracker` is the *realized* counterpart: a pytree that rides in a
``lax.scan`` carry (one update per FL round) and reports the empirical
per-node mean age, so simulated campaigns can be checked against the renewal
formula above.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["expected_aoi", "log_aoi", "simulate_aoi", "AoITracker"]


def expected_aoi(p: jax.Array) -> jax.Array:
    """E[delta_i] = 1/p_i - 1/2 (eq. 10). Clipped away from p=0 for finiteness."""
    p = jnp.clip(p, 1e-9, 1.0)
    return 1.0 / p - 0.5


def log_aoi(p: jax.Array) -> jax.Array:
    """log E[delta_i]; the incentive term of eq. (11)."""
    return jnp.log(expected_aoi(p))


def simulate_aoi(p: float, n_rounds: int, key: jax.Array) -> jax.Array:
    """Monte-Carlo mean AoI over a participation sample path (test oracle).

    AoI increments by 1 each round and resets to 0 on participation (unit
    round duration, age sampled at round boundaries, matching the renewal
    formula's sampling convention up to the -1/2 discretization).
    """
    participate = jax.random.bernoulli(key, p, (n_rounds,))

    def step(age, joined):
        new_age = jnp.where(joined, 0.0, age + 1.0)
        return new_age, age + 0.5  # mid-round sampling

    _, ages = jax.lax.scan(step, 0.0, participate)
    return jnp.mean(ages)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AoITracker:
    """Per-node realized AoI over a participation sample path.

    Same sampling convention as :func:`simulate_aoi` — the age is read
    mid-round (pre-update age + 1/2), so the long-run mean matches the
    renewal formula E[delta] = 1/p - 1/2. All fields are jnp arrays; the
    tracker is a registered pytree, so it can be a ``lax.scan`` carry leaf
    inside jitted campaign loops.

    Node churn (heterogeneous campaigns): :meth:`update` takes an optional
    ``present`` mask — absent nodes are *frozen* (age and cumulative age
    untouched, their round not counted in ``tracked``), so a node that
    departs and later re-arrives resumes the age it left with.

    Attributes:
        age: ``(N,)`` rounds since each node's last participation.
        cum_age: ``(N,)`` sum of mid-round sampled ages (unitless rounds).
        rounds: scalar — total rounds this tracker has seen.
        tracked: ``(N,)`` rounds each node was present for (== ``rounds``
            for every node when no churn mask was ever passed).
    """

    age: jax.Array
    cum_age: jax.Array
    rounds: jax.Array
    tracked: jax.Array

    @staticmethod
    def create(n_nodes: int) -> "AoITracker":
        return AoITracker(
            age=jnp.zeros((n_nodes,), jnp.float64),
            cum_age=jnp.zeros((n_nodes,), jnp.float64),
            rounds=jnp.zeros((), jnp.int64),
            tracked=jnp.zeros((n_nodes,), jnp.int64),
        )

    def update(self, mask: jax.Array,
               present: jax.Array | None = None) -> "AoITracker":
        """Record one round: sample ages mid-round, reset participants.

        Args:
            mask: ``(N,)`` bool/0-1 — who participated this round.
            present: optional ``(N,)`` bool — who was in the fleet this
                round. Absent nodes are frozen (age/cum_age/tracked
                untouched); ``None`` means everyone is present.
        """
        joined = jnp.asarray(mask, bool)
        new_age = jnp.where(joined, 0.0, self.age + 1.0)
        new_cum = self.cum_age + self.age + 0.5
        if present is None:
            return AoITracker(
                age=new_age,
                cum_age=new_cum,
                rounds=self.rounds + 1,
                tracked=self.tracked + 1,
            )
        here = jnp.asarray(present, bool)
        return AoITracker(
            age=jnp.where(here, new_age, self.age),
            cum_age=jnp.where(here, new_cum, self.cum_age),
            rounds=self.rounds + 1,
            tracked=self.tracked + jnp.asarray(here, self.tracked.dtype),
        )

    @property
    def per_node_aoi(self) -> jax.Array:
        """``(N,)`` empirical mean age per node (``(B, N)`` when the tracker
        carries a leading batch axis, e.g. out of a vmapped campaign).
        Normalized by each node's *tracked* rounds, so churned nodes report
        the mean age over the rounds they were actually in the fleet."""
        return self.cum_age / jnp.maximum(self.tracked, 1)

    @property
    def mean_aoi(self) -> jax.Array:
        """Fleet-mean realized AoI (``(B,)`` for a batched tracker)."""
        return jnp.mean(self.per_node_aoi, axis=-1)
