"""Age-of-Information incentive (paper eq. 10).

With per-round Bernoulli(p) participation, the inter-participation time Y is
Geometric(p) (support 1, 2, ...). The expected AoI of a node is the renewal
reward ratio

    E[delta] = E[Y^2] / (2 E[Y]) = 1/p - 1/2,

using E[Y] = 1/p and E[Y^2] = (2 - p)/p^2. The paper rewards participation
with ``-gamma * log(E[delta])`` inside the utility.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["expected_aoi", "log_aoi", "simulate_aoi"]


def expected_aoi(p: jax.Array) -> jax.Array:
    """E[delta_i] = 1/p_i - 1/2 (eq. 10). Clipped away from p=0 for finiteness."""
    p = jnp.clip(p, 1e-9, 1.0)
    return 1.0 / p - 0.5


def log_aoi(p: jax.Array) -> jax.Array:
    """log E[delta_i]; the incentive term of eq. (11)."""
    return jnp.log(expected_aoi(p))


def simulate_aoi(p: float, n_rounds: int, key: jax.Array) -> jax.Array:
    """Monte-Carlo mean AoI over a participation sample path (test oracle).

    AoI increments by 1 each round and resets to 0 on participation (unit
    round duration, age sampled at round boundaries, matching the renewal
    formula's sampling convention up to the -1/2 discretization).
    """
    participate = jax.random.bernoulli(key, p, (n_rounds,))

    def step(age, joined):
        new_age = jnp.where(joined, 0.0, age + 1.0)
        return new_age, age + 0.5  # mid-round sampling

    _, ages = jax.lax.scan(step, 0.0, participate)
    return jnp.mean(ages)
