"""IEEE 802.11ax airtime/energy model for the FL model-update upload.

Reproduces the communication model of the paper (Table I; full derivation in
Guerra et al., "The cost of training machine learning models over distributed
data sources", IEEE OJ-COMS 2023): a single-user HE transmission with
RTS/CTS protection and a fixed contention window. ``T_tx`` is the airtime to
upload the ``S_w``-byte model update; ``E_tx = P_tx * T_tx`` (paper eq. 2).

All quantities are scalars; the model is closed-form and jit-free by design
(it parameterizes the game, it is not inside the training step).
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["CommParams", "airtime_model", "PAPER_COMM"]


@dataclasses.dataclass(frozen=True)
class CommParams:
    """Table I — Communication (IEEE 802.11ax), 20 MHz, 1 spatial stream."""

    tx_power_dbm: float = 9.0          # P_tx for edge devices
    sigma_legacy_us: float = 4.0       # legacy OFDM symbol duration
    n_subcarriers: int = 234           # 20 MHz RU
    n_spatial_streams: int = 1
    t_empty_slot_us: float = 9.0
    t_sifs_us: float = 16.0
    t_difs_us: float = 34.0
    t_phy_preamble_us: float = 20.0    # legacy preamble
    t_he_su_us: float = 100.0          # HE single-user field duration
    l_ofdm_symbol_bits: int = 24       # L_s, legacy rate for control frames
    l_rts_bits: int = 160
    l_cts_bits: int = 112
    l_ack_bits: int = 240
    l_service_bits: int = 16
    l_mac_header_bits: int = 320
    contention_window: int = 15        # CW (fixed)
    bits_per_symbol_per_sc: float = 10.0  # 1024-QAM 5/6 → 8.33; MCS settable
    sigma_he_us: float = 13.6          # HE OFDM symbol (incl. 0.8 us GI)
    a_mpdu_max_bits: int = 65536 * 8   # max A-MPDU aggregate size


PAPER_COMM = CommParams()


def _control_frame_us(p: CommParams, l_bits: int) -> float:
    """Legacy-rate control frame airtime (preamble + ceil(bits/24) symbols)."""
    n_sym = math.ceil((l_bits + p.l_service_bits) / p.l_ofdm_symbol_bits)
    return p.t_phy_preamble_us + n_sym * p.sigma_legacy_us


def airtime_model(
    payload_bytes: float,
    params: CommParams = PAPER_COMM,
) -> dict:
    """Airtime to upload ``payload_bytes`` over 802.11ax single-user HE.

    The payload (the 44.73 MB ResNet-18 update in the paper) is fragmented
    into maximum-size A-MPDUs; each transmission pays
    DIFS + backoff + RTS/CTS + HE preamble + data symbols + SIFS + ACK.

    Returns dict with ``t_tx_s`` (total airtime, seconds), ``t_data_s``,
    ``t_overhead_s``, ``n_ampdu``, ``goodput_mbps``.
    """
    p = params
    bits_total = payload_bytes * 8.0
    data_bits_per_symbol = (
        p.n_subcarriers * p.n_spatial_streams * p.bits_per_symbol_per_sc)

    mpdu_bits = p.a_mpdu_max_bits
    n_ampdu = max(1, math.ceil(bits_total / mpdu_bits))

    t_rts = _control_frame_us(p, p.l_rts_bits)
    t_cts = _control_frame_us(p, p.l_cts_bits)
    t_ack = _control_frame_us(p, p.l_ack_bits)
    mean_backoff_us = (p.contention_window / 2.0) * p.t_empty_slot_us

    per_txop_overhead_us = (
        p.t_difs_us + mean_backoff_us
        + t_rts + p.t_sifs_us + t_cts + p.t_sifs_us
        + p.t_phy_preamble_us + p.t_he_su_us
        + p.t_sifs_us + t_ack)

    def data_airtime_us(bits: float) -> float:
        n_sym = math.ceil(
            (bits + p.l_mac_header_bits + p.l_service_bits) / data_bits_per_symbol)
        return n_sym * p.sigma_he_us

    full, rem = divmod(bits_total, mpdu_bits)
    t_data_us = full * data_airtime_us(mpdu_bits)
    if rem > 0:
        t_data_us += data_airtime_us(rem)
    t_overhead_us = n_ampdu * per_txop_overhead_us
    t_total_us = t_data_us + t_overhead_us

    tx_power_w = 10.0 ** (p.tx_power_dbm / 10.0) * 1e-3
    t_total_s = t_total_us * 1e-6
    return {
        "t_tx_s": t_total_s,
        "t_data_s": t_data_us * 1e-6,
        "t_overhead_s": t_overhead_us * 1e-6,
        "n_ampdu": n_ampdu,
        "goodput_mbps": (bits_total / t_total_us) if t_total_us else 0.0,
        "tx_power_w": tx_power_w,
        "e_tx_wh": tx_power_w * t_total_s / 3600.0,
    }
