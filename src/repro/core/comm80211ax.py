"""IEEE 802.11ax airtime/energy model for the FL model-update upload.

Reproduces the communication model of the paper (Table I; full derivation in
Guerra et al., "The cost of training machine learning models over distributed
data sources", IEEE OJ-COMS 2023): a single-user HE transmission with
RTS/CTS protection and a fixed contention window. ``T_tx`` is the airtime to
upload the ``S_w``-byte model update; ``E_tx = P_tx * T_tx`` (paper eq. 2).

Two evaluators share the model:

* :func:`airtime_model` — the seed scalar closed form (pure Python
  ``math``), kept **verbatim** as the test oracle. It parameterizes the
  symmetric game and is jit-free by design.
* :func:`airtime_model_batched` — the jit-compatible vectorized form for
  *channel-heterogeneous fleets*: per-node MCS (``bits_per_symbol_per_sc``)
  and/or payload arrays broadcast to per-node airtime/energy vectors that
  feed :func:`repro.core.energy.channel_energy_rates` and, through the
  ``energy_rates_j`` seam, the scan-fused campaign engine. Pinned
  elementwise (≤ 1e-12 relative) against the scalar oracle across an
  MCS × payload grid — including the ``payload_bytes = 0`` and
  sub-A-MPDU remainder corners — in ``tests/test_energy_comm.py``.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = ["CommParams", "airtime_model", "airtime_model_batched",
           "PAPER_COMM"]


@dataclasses.dataclass(frozen=True)
class CommParams:
    """Table I — Communication (IEEE 802.11ax), 20 MHz, 1 spatial stream."""

    tx_power_dbm: float = 9.0          # P_tx for edge devices
    sigma_legacy_us: float = 4.0       # legacy OFDM symbol duration
    n_subcarriers: int = 234           # 20 MHz RU
    n_spatial_streams: int = 1
    t_empty_slot_us: float = 9.0
    t_sifs_us: float = 16.0
    t_difs_us: float = 34.0
    t_phy_preamble_us: float = 20.0    # legacy preamble
    t_he_su_us: float = 100.0          # HE single-user field duration
    l_ofdm_symbol_bits: int = 24       # L_s, legacy rate for control frames
    l_rts_bits: int = 160
    l_cts_bits: int = 112
    l_ack_bits: int = 240
    l_service_bits: int = 16
    l_mac_header_bits: int = 320
    contention_window: int = 15        # CW (fixed)
    bits_per_symbol_per_sc: float = 10.0  # 1024-QAM 5/6 → 8.33; MCS settable
    sigma_he_us: float = 13.6          # HE OFDM symbol (incl. 0.8 us GI)
    a_mpdu_max_bits: int = 65536 * 8   # max A-MPDU aggregate size


PAPER_COMM = CommParams()


def _control_frame_us(p: CommParams, l_bits: int) -> float:
    """Legacy-rate control frame airtime (preamble + ceil(bits/24) symbols)."""
    n_sym = math.ceil((l_bits + p.l_service_bits) / p.l_ofdm_symbol_bits)
    return p.t_phy_preamble_us + n_sym * p.sigma_legacy_us


def airtime_model(
    payload_bytes: float,
    params: CommParams = PAPER_COMM,
) -> dict:
    """Airtime to upload ``payload_bytes`` over 802.11ax single-user HE.

    The payload (the 44.73 MB ResNet-18 update in the paper) is fragmented
    into maximum-size A-MPDUs; each transmission pays
    DIFS + backoff + RTS/CTS + HE preamble + data symbols + SIFS + ACK.

    Returns dict with ``t_tx_s`` (total airtime, seconds), ``t_data_s``,
    ``t_overhead_s``, ``n_ampdu``, ``goodput_mbps``.
    """
    p = params
    bits_total = payload_bytes * 8.0
    data_bits_per_symbol = (
        p.n_subcarriers * p.n_spatial_streams * p.bits_per_symbol_per_sc)

    mpdu_bits = p.a_mpdu_max_bits
    n_ampdu = max(1, math.ceil(bits_total / mpdu_bits))

    t_rts = _control_frame_us(p, p.l_rts_bits)
    t_cts = _control_frame_us(p, p.l_cts_bits)
    t_ack = _control_frame_us(p, p.l_ack_bits)
    mean_backoff_us = (p.contention_window / 2.0) * p.t_empty_slot_us

    per_txop_overhead_us = (
        p.t_difs_us + mean_backoff_us
        + t_rts + p.t_sifs_us + t_cts + p.t_sifs_us
        + p.t_phy_preamble_us + p.t_he_su_us
        + p.t_sifs_us + t_ack)

    def data_airtime_us(bits: float) -> float:
        n_sym = math.ceil(
            (bits + p.l_mac_header_bits + p.l_service_bits) / data_bits_per_symbol)
        return n_sym * p.sigma_he_us

    full, rem = divmod(bits_total, mpdu_bits)
    t_data_us = full * data_airtime_us(mpdu_bits)
    if rem > 0:
        t_data_us += data_airtime_us(rem)
    t_overhead_us = n_ampdu * per_txop_overhead_us
    t_total_us = t_data_us + t_overhead_us

    tx_power_w = 10.0 ** (p.tx_power_dbm / 10.0) * 1e-3
    t_total_s = t_total_us * 1e-6
    return {
        "t_tx_s": t_total_s,
        "t_data_s": t_data_us * 1e-6,
        "t_overhead_s": t_overhead_us * 1e-6,
        "n_ampdu": n_ampdu,
        "goodput_mbps": (bits_total / t_total_us) if t_total_us else 0.0,
        "tx_power_w": tx_power_w,
        "e_tx_wh": tx_power_w * t_total_s / 3600.0,
    }


def airtime_model_batched(
    payload_bytes: jax.Array,
    bits_per_symbol_per_sc: jax.Array | None = None,
    params: CommParams = PAPER_COMM,
) -> dict:
    """Vectorized :func:`airtime_model`: per-node MCS/payload → airtimes.

    The jit/vmap-compatible form of the scalar oracle above — the per-node
    channel knob is ``bits_per_symbol_per_sc`` (the MCS: 1024-QAM 5/6 ≈
    8.33 bits at the top, low-order modulations below), broadcast against
    ``payload_bytes``. All outputs are float64 arrays of the broadcast
    shape (``tx_power_w`` stays a Python scalar: the paper's P_tx is
    common to the fleet).

    Guards the two traps of vectorizing the closed form: the
    ``goodput_mbps`` division uses a ``where``-safe denominator (both
    branches of a ``jnp.where`` evaluate under jit, and
    ``payload_bytes = 0`` would otherwise divide 0/0 when a pathological
    parameterization zeroes the airtime), and the float ``divmod``
    A-MPDU fragmentation is re-expressed as ``floor_divide``/``remainder``
    with the zero-remainder data frame masked out (``data_airtime(0)``
    would still charge a MAC-header symbol).

    Pinned ≤ 1e-12 relative against the scalar oracle elementwise in
    ``tests/test_energy_comm.py``.
    """
    p = params
    payload = jnp.asarray(payload_bytes, jnp.float64)
    bps = jnp.asarray(
        p.bits_per_symbol_per_sc if bits_per_symbol_per_sc is None
        else bits_per_symbol_per_sc, jnp.float64)
    payload, bps = jnp.broadcast_arrays(payload, bps)

    bits_total = payload * 8.0
    data_bits_per_symbol = p.n_subcarriers * p.n_spatial_streams * bps

    mpdu_bits = float(p.a_mpdu_max_bits)
    n_ampdu = jnp.maximum(1.0, jnp.ceil(bits_total / mpdu_bits))

    # control frames ride at the legacy rate — no per-node dependence, so
    # the overhead constant is the scalar oracle's float, exactly
    t_rts = _control_frame_us(p, p.l_rts_bits)
    t_cts = _control_frame_us(p, p.l_cts_bits)
    t_ack = _control_frame_us(p, p.l_ack_bits)
    mean_backoff_us = (p.contention_window / 2.0) * p.t_empty_slot_us
    per_txop_overhead_us = (
        p.t_difs_us + mean_backoff_us
        + t_rts + p.t_sifs_us + t_cts + p.t_sifs_us
        + p.t_phy_preamble_us + p.t_he_su_us
        + p.t_sifs_us + t_ack)

    def data_airtime_us(bits):
        n_sym = jnp.ceil(
            (bits + p.l_mac_header_bits + p.l_service_bits)
            / data_bits_per_symbol)
        return n_sym * p.sigma_he_us

    full = jnp.floor_divide(bits_total, mpdu_bits)
    rem = jnp.remainder(bits_total, mpdu_bits)
    t_data_us = (full * data_airtime_us(jnp.asarray(mpdu_bits))
                 + jnp.where(rem > 0.0, data_airtime_us(rem), 0.0))
    t_overhead_us = n_ampdu * per_txop_overhead_us
    t_total_us = t_data_us + t_overhead_us

    tx_power_w = 10.0 ** (p.tx_power_dbm / 10.0) * 1e-3
    t_total_s = t_total_us * 1e-6
    safe_t = jnp.where(t_total_us > 0.0, t_total_us, 1.0)
    return {
        "t_tx_s": t_total_s,
        "t_data_s": t_data_us * 1e-6,
        "t_overhead_s": t_overhead_us * 1e-6,
        "n_ampdu": n_ampdu,
        "goodput_mbps": jnp.where(t_total_us > 0.0, bits_total / safe_t,
                                  0.0),
        "tx_power_w": tx_power_w,
        "e_tx_wh": tx_power_w * t_total_s / 3600.0,
    }
