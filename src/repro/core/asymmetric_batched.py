"""Batched heterogeneous-equilibrium engine: one XLA program per sweep.

The scalar solver in :mod:`repro.core.asymmetric` runs Python-loop
Gauss-Seidel with a full ``jnp.delete`` + O(N·N) DFT pmf recompute per node
per iteration — seconds for a single N=50 equilibrium, and a (costs, gammas)
scenario sweep is out of reach. This module rebuilds that layer as pure
fixed-shape ``lax`` control flow, jitted once and ``vmap``-ed over a batch of
scenarios:

* **Damped Gauss-Seidel as a scan.** One round-robin sweep is a `lax.scan`
  over nodes carrying ``(pmf, p)``: node i's opponents' pmf comes from the
  O(N) leave-one-out *deconvolution* (:func:`repro.core.poibin.poibin_pmf_loo`
  divides node i's ``[1-p_i, p_i]`` factor back out of the full pmf), its
  exact closed-form best response is evaluated, and the updated factor is
  convolved back in O(N). A full sweep is O(N²) — the same cost as *one*
  pmf recompute in the scalar path — and the pmf is rebuilt from scratch
  via the stable O(N²) convolution recursion once per sweep so
  deconvolve/convolve round-trip error never accumulates across sweeps.
  Sweeps iterate inside a `lax.while_loop` until the sweep-wide update
  delta drops below ``tol`` (identical semantics to the scalar loop).
* **Jitted certification.** :func:`verify_equilibrium_batched` evaluates
  every node's utility on a deviation grid in one shot — all N leave-one-out
  pmfs (a vmapped deconvolution), then a broadcast (N, G) utility table —
  no Python double loop.
* **Jitted planner.** The social cost ``N·E[D] + Σ c_i p_i`` is *linear* in
  each ``p_i`` with the others fixed (E[D] is multilinear), so the
  per-coordinate minimum sits at a corner determined by the sign of
  ``N·∂E[D]/∂p_i + c_i``; :func:`planner_batched` runs that coordinate
  descent with the same deconvolution trick and matches the scalar
  grid-argmin planner's fixed points.
* **Heterogeneous PoA.** :func:`poa_report` packages NE + certification +
  planner + social costs for a whole scenario batch.

Everything is written single-scenario and lifted with ``vmap`` in the jitted
wrappers, so a ≥500-scenario (costs, gammas, dur) sweep at N=50 is one XLA
dispatch (see ``benchmarks/heterogeneous_sweep.py``).

The batch-parallel surfaces (:func:`verify_equilibrium_batched`,
:func:`social_cost_batched`, and :func:`poa_report` through them) also
dispatch their Poisson-binomial work through the kernel layer: pass
``backend="pallas"`` to evaluate the whole batch's pmfs + leave-one-out
deconvolutions in the fused :mod:`repro.kernels.poibin_dft` kernel (fp32,
parity to ~1e-6). The default ``"ref"`` keeps the pre-existing vmapped jnp
programs bitwise-unchanged. The Gauss-Seidel NE solve itself stays jnp:
its per-node sweep is sequential (each deconvolution uses the profile
updated by the previous node), which is not the kernel's batch-parallel
shape.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.aoi import log_aoi
from repro.core.duration import DurationModel
from repro.core.poibin import (poibin_convolve, poibin_pmf_batched,
                               poibin_pmf_loo, poibin_pmf_loo_all,
                               poibin_pmf_recursive)

__all__ = [
    "P_MIN",
    "HeterogeneousSolution",
    "HeterogeneousPoA",
    "best_response_given_slope",
    "solve_heterogeneous",
    "verify_equilibrium_batched",
    "planner_batched",
    "social_cost_batched",
    "poa_report",
]

P_MIN = 1e-3  # matches repro.core.game / repro.core.asymmetric


def best_response_given_slope(slope: jax.Array, cost: jax.Array,
                              gamma: jax.Array) -> jax.Array:
    """Exact best response from the (utility-side) duration slope.

    With opponents fixed, ``u_i(p_i) = const + a·p_i - γ·log(1/p_i - 1/2)``
    where ``a = slope - cost`` and ``slope = -(E[d(m_-i+1)] - E[d(m_-i)])``.
    ``du/dp_i = a + 2γ/(p_i(2-p_i))``:

    * γ = 0: bang-bang on sign(a); exact indifference (a = 0) resolves to
      ``P_MIN``, matching the scalar solver.
    * γ > 0, a ≥ 0: utility strictly increasing ⇒ p = 1.
    * γ > 0, a < 0: the unique stationary point solves
      ``p(2-p) = -2γ/a``, i.e. ``p* = 1 - sqrt(1 + 2γ/a)`` (clipped to
      [P_MIN, 1]; the clip also absorbs the a → 0⁻ limit p* → 1).

    The a ≥ 0 quadratic branch is masked out by the outer ``where``, so its
    divisor is replaced by a benign -1 — a two-sided guard; dividing by a
    ``-1e-9`` sentinel (the old guard) produced a huge ``prod`` intermediate
    at a = 0 exactly.
    """
    a = slope - cost
    if_zero = jnp.where(a > 0.0, 1.0, P_MIN)
    denom = jnp.where(a < 0.0, a, -1.0)
    prod = -2.0 * gamma / denom          # p(2-p) at the stationary point
    disc = jnp.clip(1.0 - prod, 0.0, 1.0)
    interior = jnp.clip(1.0 - jnp.sqrt(disc), P_MIN, 1.0)
    return jnp.where(gamma <= 0.0, if_zero,
                     jnp.where(a >= 0.0, 1.0, interior))


# ---------------------------------------------------------------------------
# Gauss-Seidel fixed point (single scenario; vmapped by the public wrapper)
# ---------------------------------------------------------------------------

def _gs_fixed_point(costs, gammas, d_tab, p0, *, damping, max_iters, tol):
    n = costs.shape[0]
    dd = d_tab[1:] - d_tab[:-1]

    def sweep(p):
        f = poibin_pmf_recursive(p)  # fresh O(N²) pmf once per sweep

        def node(carry, i):
            f, p = carry
            pi = p[i]
            loo = poibin_pmf_loo(f, pi)              # (N+1,), last entry 0
            slope = -(loo[:-1] @ dd)                  # utility-side slope
            br = best_response_given_slope(slope, costs[i], gammas[i])
            new_pi = (1.0 - damping) * pi + damping * br
            f_new = poibin_convolve(loo, new_pi)
            return (f_new, p.at[i].set(new_pi)), jnp.abs(new_pi - pi)

        (_, p_new), deltas = jax.lax.scan(node, (f, p), jnp.arange(n))
        return p_new, jnp.max(deltas)

    def cond(state):
        _, delta, it = state
        return (delta >= tol) & (it < max_iters)

    def body(state):
        p, _, it = state
        p_new, delta = sweep(p)
        return p_new, delta, it + 1

    p, delta, iters = jax.lax.while_loop(
        cond, body, (p0, jnp.asarray(jnp.inf, p0.dtype), jnp.asarray(0)))
    return p, delta < tol, iters


@functools.partial(jax.jit, static_argnames=("damping", "max_iters", "tol"))
def _solve_vmapped(costs, gammas, d_tab, p0, *, damping, max_iters, tol):
    solve = functools.partial(_gs_fixed_point, damping=damping,
                              max_iters=max_iters, tol=tol)
    return jax.vmap(solve)(costs, gammas, d_tab, p0)


# ---------------------------------------------------------------------------
# scenario-mesh sharding: pad + NamedSharding inputs, out_shardings results
# ---------------------------------------------------------------------------

#: (surface, mesh, axis, static kwargs…) -> jitted sharded program. jax.jit
#: caches per callable, so sharded programs must be built once per
#: (surface, mesh) — a fresh jit per call would retrace every sweep.
_SHARDED_PROGRAMS: dict = {}


def _batch_sharding(mesh, batch_axis):
    """NamedSharding + shard count for the scenario batch dim on ``mesh``.

    Resolved through :func:`repro.launch.sharding.scenario_batch_spec`
    (the MaxText-style rules engine), so the NE sweep places its batch on
    the same ``("pod", "data")`` candidates as every other engine.
    """
    from jax.sharding import NamedSharding

    from repro.launch.sharding import scenario_batch_spec, spec_axis_size

    spec = scenario_batch_spec(0, mesh, axis=batch_axis)
    return NamedSharding(mesh, spec), spec_axis_size(mesh, spec)


def _shard_batch_args(mesh, batch_axis, batch, arrays):
    """Edge-pad each leading-``batch`` array to shard-divisible size and
    ``device_put`` it with the resolved NamedSharding."""
    from repro.launch.sharding import pad_batch

    sharding, shards = _batch_sharding(mesh, batch_axis)
    return (tuple(jax.device_put(pad_batch(a, batch, shards), sharding)
                  for a in arrays), sharding)


def _sharded_program(key, builder):
    prog = _SHARDED_PROGRAMS.get(key)
    if prog is None:
        prog = _SHARDED_PROGRAMS[key] = builder()
    return prog


def _require_ref_backend(mesh, backend, *, site: str) -> bool:
    """Mesh sharding runs the vmapped jnp programs; resolve + guard.

    Returns True when the pallas kernel path should be taken (only ever
    with ``mesh=None``): the interpret-mode Pallas kernels are not
    partitionable by GSPMD, so combining them with a scenario mesh raises
    rather than silently gathering the batch onto one device.
    """
    from repro.kernels import ops as kernel_ops  # lazy: keep core light

    pallas = kernel_ops.resolve_backend(
        backend, default="ref", site=site) == "pallas"
    if pallas and mesh is not None:
        raise ValueError(
            f"{site}: mesh sharding is only supported on the ref backend "
            "(the interpret-mode Pallas kernels cannot be partitioned)")
    return pallas


@dataclasses.dataclass(frozen=True)
class HeterogeneousSolution:
    """A vmapped batch of asymmetric-NE solves."""

    costs: jax.Array       # (B, N)
    gammas: jax.Array      # (B, N)
    p: jax.Array           # (B, N) fixed-point profiles
    converged: jax.Array   # (B,) bool
    iters: jax.Array       # (B,) Gauss-Seidel sweeps run

    @property
    def batch(self) -> int:
        return int(self.p.shape[0])

    def single(self) -> tuple[jax.Array, bool, int]:
        """The (profile, converged, iters) triple of a B = 1 solve."""
        if self.batch != 1:
            raise ValueError(
                f"single() called on a batch of {self.batch} scenarios")
        return self.p[0], bool(self.converged[0]), int(self.iters[0])


def _prepare_batch(costs, gammas, dur, p0):
    d_tab = dur.table() if isinstance(dur, DurationModel) else jnp.asarray(dur)
    costs = jnp.atleast_2d(jnp.asarray(costs, d_tab.dtype))
    gammas = jnp.atleast_2d(jnp.asarray(gammas, d_tab.dtype))
    try:
        shape = jnp.broadcast_shapes(costs.shape, gammas.shape)
    except ValueError as e:
        raise ValueError(f"costs {costs.shape} vs gammas {gammas.shape}: {e}")
    costs = jnp.broadcast_to(costs, shape)
    gammas = jnp.broadcast_to(gammas, shape)
    b, n = shape
    if d_tab.ndim == 1:
        d_tab = jnp.broadcast_to(d_tab, (b,) + d_tab.shape)
    if d_tab.shape != (b, n + 1):
        raise ValueError(f"duration table {d_tab.shape}, want {(b, n + 1)}")
    if p0 is None:
        p0 = jnp.full((b, n), 0.5, d_tab.dtype)
    else:
        p0 = jnp.broadcast_to(jnp.atleast_2d(jnp.asarray(p0, d_tab.dtype)),
                              (b, n))
    return costs, gammas, d_tab, p0


def solve_heterogeneous(
    costs: jax.Array,
    gammas: jax.Array,
    dur: DurationModel | jax.Array,
    *,
    p0: jax.Array | None = None,
    damping: float = 0.5,
    max_iters: int = 200,
    tol: float = 1e-5,
    mesh=None,
    batch_axis=None,
) -> HeterogeneousSolution:
    """Solve a batch of heterogeneous games in one jitted program.

    Args:
        costs / gammas: ``(N,)`` for a single game or ``(B, N)`` for a batch;
            the two broadcast against each other in either direction, so e.g.
            ``costs (N,)`` with ``gammas (B, N)`` runs a γ-sweep over one
            cost vector.
        dur: a shared :class:`DurationModel`, a shared ``(N+1,)`` duration
            table, or a per-scenario ``(B, N+1)`` stack of tables.
        p0: initial profile(s); defaults to the all-0.5 profile like the
            scalar solver.
        damping / max_iters / tol: Gauss-Seidel controls with the scalar
            solver's defaults and semantics (``iters`` counts round-robin
            sweeps; convergence is max per-node update < tol within a sweep).
        mesh: optional :class:`jax.sharding.Mesh` — shard the scenario
            batch over its data-parallel axes (``batch_axis`` overrides
            the rules-table candidates). Arbitrary ``B`` is edge-padded to
            shard-divisibility and results are sliced back; ``mesh=None``
            (default) is the unchanged single-device program. Note the
            batched while_loop runs until every lane (padding included)
            converges, so wall-clock is the max over the shard.
    """
    costs, gammas, d_tab, p0 = _prepare_batch(costs, gammas, dur, p0)
    statics = (float(damping), int(max_iters), float(tol))
    if mesh is None:
        p, conv, iters = _solve_vmapped(costs, gammas, d_tab, p0,
                                        damping=statics[0],
                                        max_iters=int(max_iters),
                                        tol=statics[2])
    else:
        b = costs.shape[0]
        args, sharding = _shard_batch_args(
            mesh, batch_axis, b, (costs, gammas, d_tab, p0))

        def build():
            solve = functools.partial(
                _gs_fixed_point, damping=statics[0],
                max_iters=int(max_iters), tol=statics[2])
            return jax.jit(jax.vmap(solve), in_shardings=sharding,
                           out_shardings=sharding)

        prog = _sharded_program(("solve", mesh, batch_axis) + statics, build)
        p, conv, iters = prog(*args)
        p, conv, iters = p[:b], conv[:b], iters[:b]
    return HeterogeneousSolution(costs=costs, gammas=gammas, p=p,
                                 converged=conv, iters=iters)


# ---------------------------------------------------------------------------
# Jitted certification: vectorized unilateral-deviation grid
# ---------------------------------------------------------------------------

def _loo_tables(p, d_tab):
    """Per-node E[d(m_-i)] and its p_i-slope from one pmf + N deconvolutions.

    Returns ``(base, slope)``, both (N,): with opponents fixed,
    ``E[D](q) = base_i + q·slope_i`` for node i playing q.
    """
    dd = d_tab[1:] - d_tab[:-1]
    f = poibin_pmf_recursive(p)
    loo = jax.vmap(poibin_pmf_loo, in_axes=(None, 0))(f, p)   # (N, N+1)
    base = loo[:, :-1] @ d_tab[:-1]
    slope = loo[:, :-1] @ dd
    return base, slope


def _verify_one(costs, gammas, d_tab, p, *, grid):
    base, slope = _loo_tables(p, d_tab)
    gridv = jnp.linspace(P_MIN, 1.0, grid).astype(p.dtype)
    aoi_dev = log_aoi(gridv)
    u_dev = (-(base[:, None] + gridv[None, :] * slope[:, None])
             - gammas[:, None] * aoi_dev[None, :]
             - costs[:, None] * gridv[None, :])                # (N, G)
    u_eq = (-(base + p * slope) - gammas * log_aoi(p) - costs * p)  # (N,)
    return jnp.maximum(jnp.max(u_dev - u_eq[:, None]), 0.0)


@functools.partial(jax.jit, static_argnames=("grid",))
def _verify_vmapped(costs, gammas, d_tab, p, *, grid):
    return jax.vmap(functools.partial(_verify_one, grid=grid))(
        costs, gammas, d_tab, p)


@functools.partial(jax.jit, static_argnames=("grid",))
def _verify_vmapped_pallas(costs, gammas, d_tab, p, *, grid):
    """Kernel-path certifier: one fused poibin program for the whole batch,
    then the same broadcast deviation-utility table as :func:`_verify_one`
    with a leading batch axis."""
    _, loo = poibin_pmf_loo_all(p, backend="pallas")      # (B,S), (B,N,S)
    dd = d_tab[:, 1:] - d_tab[:, :-1]
    base = jnp.einsum("bns,bs->bn", loo[:, :, :-1], d_tab[:, :-1])
    slope = jnp.einsum("bns,bs->bn", loo[:, :, :-1], dd)
    gridv = jnp.linspace(P_MIN, 1.0, grid).astype(p.dtype)
    aoi_dev = log_aoi(gridv)
    u_dev = (-(base[..., None] + gridv[None, None, :] * slope[..., None])
             - gammas[..., None] * aoi_dev[None, None, :]
             - costs[..., None] * gridv[None, None, :])   # (B, N, G)
    u_eq = (-(base + p * slope) - gammas * log_aoi(p) - costs * p)  # (B, N)
    return jnp.maximum(
        jnp.max(u_dev - u_eq[..., None], axis=(1, 2)), 0.0)


def verify_equilibrium_batched(
    costs: jax.Array,
    gammas: jax.Array,
    dur: DurationModel | jax.Array,
    p: jax.Array,
    *,
    grid: int = 64,
    backend: str | None = None,
    mesh=None,
    batch_axis=None,
) -> jax.Array:
    """Max profitable unilateral deviation per scenario (0 at an exact NE).

    One jitted program: all N leave-one-out pmfs via vmapped deconvolution,
    then an (N, grid) deviation-utility table per scenario — no Python loops.
    Accepts the same single-game / batched shapes as
    :func:`solve_heterogeneous`; returns ``(B,)``.

    ``backend="pallas"`` computes the pmf/leave-one-out block in the fused
    :mod:`repro.kernels.poibin_dft` kernel (fp32 parity); the default
    ``"ref"`` is the bitwise-unchanged vmapped jnp program. ``mesh`` shards
    the scenario batch (ref backend only; see :func:`solve_heterogeneous`).
    """
    costs, gammas, d_tab, p = _prepare_batch(costs, gammas, dur, p)
    if _require_ref_backend(mesh, backend,
                            site="ne.verify_equilibrium_batched"):
        return _verify_vmapped_pallas(costs, gammas, d_tab, p,
                                      grid=int(grid))
    if mesh is None:
        return _verify_vmapped(costs, gammas, d_tab, p, grid=int(grid))
    b = costs.shape[0]
    args, sharding = _shard_batch_args(
        mesh, batch_axis, b, (costs, gammas, d_tab, p))

    def build():
        return jax.jit(
            jax.vmap(functools.partial(_verify_one, grid=int(grid))),
            in_shardings=sharding, out_shardings=sharding)

    prog = _sharded_program(("verify", mesh, batch_axis, int(grid)), build)
    return prog(*args)[:b]


# ---------------------------------------------------------------------------
# Jitted heterogeneity-aware planner + social cost + PoA report
# ---------------------------------------------------------------------------

def _social_cost_one(costs, d_tab, p):
    n = costs.shape[0]
    f = poibin_pmf_recursive(p)
    return n * (f @ d_tab) + costs @ p


@jax.jit
def _social_cost_vmapped(costs, d_tab, p):
    return jax.vmap(_social_cost_one)(costs, d_tab, p)


@jax.jit
def _social_cost_vmapped_pallas(costs, d_tab, p):
    f = poibin_pmf_batched(p, backend="pallas")           # (B, S)
    n = costs.shape[1]
    return n * jnp.sum(f * d_tab, axis=1) + jnp.sum(costs * p, axis=1)


def social_cost_batched(costs: jax.Array, dur: DurationModel | jax.Array,
                        p: jax.Array, *,
                        backend: str | None = None,
                        mesh=None, batch_axis=None) -> jax.Array:
    """``Σ_i (E[D] + c_i p_i) = N·E[D] + Σ c_i p_i`` per scenario, ``(B,)``.

    ``backend="pallas"`` evaluates the batch's pmfs in the DFT kernel;
    the default ``"ref"`` keeps the vmapped convolution-recursion program
    bitwise-unchanged. ``mesh`` shards the scenario batch (ref backend
    only; see :func:`solve_heterogeneous`).
    """
    costs, _, d_tab, p = _prepare_batch(costs, jnp.zeros_like(costs), dur, p)
    if _require_ref_backend(mesh, backend, site="ne.social_cost_batched"):
        return _social_cost_vmapped_pallas(costs, d_tab, p)
    if mesh is None:
        return _social_cost_vmapped(costs, d_tab, p)
    b = costs.shape[0]
    args, sharding = _shard_batch_args(mesh, batch_axis, b,
                                       (costs, d_tab, p))

    def build():
        return jax.jit(jax.vmap(_social_cost_one),
                       in_shardings=sharding, out_shardings=sharding)

    prog = _sharded_program(("social_cost", mesh, batch_axis), build)
    return prog(*args)[:b]


def _planner_one(costs, d_tab, p0, *, rounds):
    n = costs.shape[0]
    dd = d_tab[1:] - d_tab[:-1]

    def sweep(p):
        f = poibin_pmf_recursive(p)

        def node(carry, i):
            f, p = carry
            loo = poibin_pmf_loo(f, p[i])
            slope = loo[:-1] @ dd                 # ∂E[D]/∂p_i, others fixed
            # Social cost is linear in p_i: N·slope + c_i decides the corner.
            best = jnp.where(n * slope + costs[i] >= 0.0, P_MIN, 1.0)
            f_new = poibin_convolve(loo, best)
            return (f_new, p.at[i].set(best)), jnp.abs(best - p[i])

        (_, p_new), deltas = jax.lax.scan(node, (f, p), jnp.arange(n))
        return p_new, jnp.max(deltas)

    def cond(state):
        _, delta, it = state
        return (delta > 0.0) & (it < rounds)

    def body(state):
        p, _, it = state
        p_new, delta = sweep(p)
        return p_new, delta, it + 1

    p, _, _ = jax.lax.while_loop(
        cond, body, (p0, jnp.asarray(jnp.inf, p0.dtype), jnp.asarray(0)))
    return p


@functools.partial(jax.jit, static_argnames=("rounds",))
def _planner_vmapped(costs, d_tab, p0, *, rounds):
    return jax.vmap(functools.partial(_planner_one, rounds=rounds))(
        costs, d_tab, p0)


def planner_batched(
    costs: jax.Array,
    dur: DurationModel | jax.Array,
    p0: jax.Array,
    *,
    rounds: int = 20,
    mesh=None,
    batch_axis=None,
) -> jax.Array:
    """Heterogeneity-aware planner: jitted round-robin coordinate descent.

    Each coordinate update is *exact* (the social cost is linear in one
    ``p_i``, so the minimum is a corner picked by the sign of
    ``N·∂E[D]/∂p_i + c_i``), which reproduces the scalar planner's
    grid-argmin fixed points without any grid. Monotone non-increasing, so
    started from an NE profile its cost lower-bounds the NE cost — the PoA
    denominator. Returns ``(B, N)`` profiles. ``mesh`` shards the scenario
    batch (see :func:`solve_heterogeneous`).
    """
    costs, _, d_tab, p0 = _prepare_batch(costs, jnp.zeros_like(costs), dur, p0)
    if mesh is None:
        return _planner_vmapped(costs, d_tab, p0, rounds=int(rounds))
    b = costs.shape[0]
    args, sharding = _shard_batch_args(mesh, batch_axis, b,
                                       (costs, d_tab, p0))

    def build():
        return jax.jit(
            jax.vmap(functools.partial(_planner_one, rounds=int(rounds))),
            in_shardings=sharding, out_shardings=sharding)

    prog = _sharded_program(("planner", mesh, batch_axis, int(rounds)), build)
    return prog(*args)[:b]


@dataclasses.dataclass(frozen=True)
class HeterogeneousPoA:
    """NE + certification + planner benchmark for a scenario batch."""

    solution: HeterogeneousSolution
    deviation: jax.Array   # (B,) max profitable unilateral deviation at NE
    ne_cost: jax.Array     # (B,) social cost of the reached profile
    opt_p: jax.Array       # (B, N) planner profile (descent from the NE)
    opt_cost: jax.Array    # (B,)
    poa: jax.Array         # (B,) heterogeneous PoA ≥ 1

    @property
    def batch(self) -> int:
        return self.solution.batch


def poa_report(
    costs: jax.Array,
    gammas: jax.Array,
    dur: DurationModel | jax.Array,
    *,
    verify_grid: int = 64,
    planner_rounds: int = 20,
    backend: str | None = None,
    mesh=None,
    batch_axis=None,
    **solver_kwargs,
) -> HeterogeneousPoA:
    """Solve, certify, and benchmark a batch of heterogeneous scenarios.

    ``backend`` routes the certification and social-cost evaluations
    through :mod:`repro.kernels.poibin_dft` when ``"pallas"`` (the NE
    solve and planner stay jnp — their sweeps are sequential per node);
    the default ``"ref"`` is bitwise-unchanged. ``mesh``/``batch_axis``
    shard every stage's scenario batch over the mesh's data axes (ref
    backend only; see :func:`solve_heterogeneous`).
    """
    sol = solve_heterogeneous(costs, gammas, dur, mesh=mesh,
                              batch_axis=batch_axis, **solver_kwargs)
    dev = verify_equilibrium_batched(sol.costs, sol.gammas, dur, sol.p,
                                     grid=verify_grid, backend=backend,
                                     mesh=mesh, batch_axis=batch_axis)
    ne_cost = social_cost_batched(sol.costs, dur, sol.p, backend=backend,
                                  mesh=mesh, batch_axis=batch_axis)
    opt_p = planner_batched(sol.costs, dur, sol.p, rounds=planner_rounds,
                            mesh=mesh, batch_axis=batch_axis)
    opt_cost = social_cost_batched(sol.costs, dur, opt_p, backend=backend,
                                   mesh=mesh, batch_axis=batch_axis)
    poa = ne_cost / jnp.maximum(opt_cost, 1e-12)
    return HeterogeneousPoA(solution=sol, deviation=dev, ne_cost=ne_cost,
                            opt_p=opt_p, opt_cost=opt_cost, poa=poa)
