"""Game-theoretic participation control for federated learning (the paper).

The game/energy math is done in float64 — NE root finding and the
Poisson-Binomial DFT at N=50 want the headroom. Model/kernel code elsewhere
in the package is explicitly dtype-annotated (bf16/f32) and unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.poibin import (  # noqa: E402,F401
    expected_duration,
    poibin_mean,
    poibin_pmf,
    poibin_pmf_recursive,
    symmetric_pmf,
)
from repro.core.duration import (  # noqa: E402,F401
    PAPER_TABLE_II,
    PAPER_N_CLIENTS,
    DurationModel,
    fit_polynomial_duration,
    paper_duration_model,
    theoretical_duration,
)
from repro.core.aoi import expected_aoi  # noqa: E402,F401
from repro.core.comm80211ax import (  # noqa: E402,F401
    CommParams,
    airtime_model,
    airtime_model_batched,
)
from repro.core.energy import (  # noqa: E402,F401
    EnergyParams,
    EnergyLedger,
    channel_energy_rates,
    task_energy,
)
from repro.core.utility import UtilityParams, player_utility, social_utility  # noqa: E402,F401
from repro.core.game import (  # noqa: E402,F401
    GameSolution,
    best_response,
    centralized_optimum,
    price_of_anarchy,
    solve_symmetric_ne,
)
from repro.core.controller import ParticipationController  # noqa: E402,F401
from repro.core.asymmetric import (  # noqa: E402,F401
    HeterogeneousGame,
    best_response_dynamics,
    best_response_dynamics_reference,
    planner_coordinate_descent,
    verify_equilibrium,
)
from repro.core.asymmetric_batched import (  # noqa: E402,F401
    HeterogeneousPoA,
    HeterogeneousSolution,
    planner_batched,
    poa_report,
    social_cost_batched,
    solve_heterogeneous,
    verify_equilibrium_batched,
)
from repro.core.coalition import (  # noqa: E402,F401
    PartitionPoA,
    PartitionSolution,
    partition_equilibrium_reference,
    partition_planner_batched,
    partition_poa_report,
    partition_social_cost_batched,
    solve_partition,
    verify_partition_batched,
)
from repro.core.online import OnlineDurationEstimator  # noqa: E402,F401
