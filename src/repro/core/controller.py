"""ParticipationController — the paper's mechanism as a framework feature.

Bridges the game-theory layer to the FL runtime:

* derives the per-round duration/energy parameters either from the paper's
  calibration (IoT scenario) or from a compiled dry-run's roofline terms
  (datacenter scenario: T_train = HLO FLOPs / (chips × peak), P_hw = chip TDP);
* solves the game for the configured (gamma, c) and hands the runtime either
  the NE probability (distributed mode), the centralized optimum
  (centralized mode), or a fixed user probability;
* meters realized energy per round through :class:`EnergyLedger` and exposes
  convergence/PoA diagnostics.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Literal, Optional

import jax
import jax.numpy as jnp

from repro.core.duration import DurationModel, paper_duration_model
from repro.core.energy import EnergyLedger, EnergyParams
from repro.core.game import GameSolution, solve_game
from repro.core.utility import UtilityParams

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.mechanisms.base import Mechanism, MechanismReport

__all__ = ["ParticipationController", "RooflineClock"]


@dataclasses.dataclass(frozen=True)
class RooflineClock:
    """Analytic per-round timing from a compiled dry-run (CPU container:
    we cannot wall-clock a TPU, so T_train is modeled from the roofline).

    Attributes:
        flops_per_step: HLO FLOPs of one local training step (cost_analysis).
        hbm_bytes_per_step: HLO bytes accessed per step.
        steps_per_round: local steps in one FL round (E epochs × batches).
        chips: chips available to one client (shard group size).
        peak_flops: per-chip peak (bf16), default TPU v5e 197e12.
        hbm_bw: per-chip HBM bandwidth, default 819e9 B/s.
        chip_power_w: per-chip board power for E_train accounting.
    """

    flops_per_step: float
    hbm_bytes_per_step: float
    steps_per_round: int = 1
    chips: int = 1
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    chip_power_w: float = 170.0

    @property
    def t_train_s(self) -> float:
        t_compute = self.flops_per_step / (self.chips * self.peak_flops)
        t_memory = self.hbm_bytes_per_step / (self.chips * self.hbm_bw)
        return self.steps_per_round * max(t_compute, t_memory)

    @property
    def p_hw_w(self) -> float:
        return self.chips * self.chip_power_w


@dataclasses.dataclass
class ParticipationController:
    """Chooses and applies the per-node participation probability.

    Modes:
        "ne"          — symmetric NE of the paper's game (distributed nodes).
        "ne_worst"    — worst-cost NE (the PoA numerator; pessimistic).
        "centralized" — centralized optimum (the PoA denominator).
        "fixed"       — externally supplied probability.
        "mechanism"   — worst NE of the game *induced by an incentive
                        mechanism* (repro.mechanisms). When no mechanism is
                        supplied, the AoI reward weight γ* is calibrated on
                        the fly so even the worst induced NE is within
                        ``target_poa`` of the centralized optimum.
    """

    n_nodes: int
    gamma: float = 0.0
    cost: float = 0.0
    mode: Literal["ne", "ne_worst", "centralized", "fixed",
                  "mechanism"] = "ne"
    fixed_p: float = 0.5
    duration_model: Optional[DurationModel] = None
    energy_params: EnergyParams = dataclasses.field(default_factory=EnergyParams)
    mechanism: Optional["Mechanism"] = None
    target_poa: float = 1.05
    _solution: Optional[GameSolution] = dataclasses.field(default=None, repr=False)
    _mech_report: Optional["MechanismReport"] = dataclasses.field(
        default=None, repr=False)

    def __post_init__(self) -> None:
        if self.duration_model is None:
            self.duration_model = paper_duration_model()
        if self.duration_model.n_nodes != self.n_nodes:
            raise ValueError(
                f"duration model is for N={self.duration_model.n_nodes}, "
                f"controller has N={self.n_nodes}")

    # -- game ---------------------------------------------------------------
    @property
    def utility_params(self) -> UtilityParams:
        return UtilityParams(gamma=self.gamma, cost=self.cost,
                             n_nodes=self.n_nodes)

    def solve(self) -> GameSolution:
        if self._solution is None:
            self._solution = solve_game(self.utility_params,
                                        self.duration_model)
        return self._solution

    def solve_mechanism(self) -> "MechanismReport":
        """Evaluate (calibrating if needed) the incentive mechanism."""
        if self._mech_report is None:
            # Lazy import — repro.mechanisms imports repro.core at load time.
            from repro.mechanisms import calibrate_gamma, evaluate_mechanism

            mech = self.mechanism
            if mech is None:
                mech = calibrate_gamma(self.utility_params,
                                       self.duration_model,
                                       target_poa=self.target_poa).mechanism
            self._mech_report = evaluate_mechanism(
                mech, self.utility_params, self.duration_model)
        return self._mech_report

    def solve_batched(
        self,
        gammas: jax.Array | float | None = None,
        costs: jax.Array | float | None = None,
        mode: str | None = None,
        *,
        gamma_max: float = 5.0,
        coarse: int = 64,
    ) -> jax.Array:
        """Participation probabilities for a whole (γ, c) scenario grid.

        The batched counterpart of :meth:`participation_probability`: all
        scenarios are resolved through the batched game solver
        (:func:`repro.mechanisms.batched.solve_batched`) with no
        Python-level per-scenario solves — the path the campaign engine
        (:mod:`repro.federated.campaign`) feeds on for Table II-style
        sweeps.

        Args:
            gammas / costs: scalars or broadcast-compatible ``(B,)`` arrays
                (default: this controller's own γ / c).
            mode: overrides ``self.mode``. Semantics per scenario match the
                scalar path — ``"ne"`` best-cost NE, ``"ne_worst"``
                worst-cost NE, ``"centralized"`` planner optimum,
                ``"fixed"`` the fixed probability, ``"mechanism"`` the worst
                NE induced by a γ-grid-calibrated AoI reward (grid
                resolution ``gamma_max / (coarse - 1)``; the scalar path
                refines by bisection, so mechanism probabilities agree only
                to that resolution). Scenarios with no NE resolve to 0.0.

        Returns:
            ``(B,)`` probabilities.
        """
        # Lazy import — repro.mechanisms imports repro.core at load time.
        from repro.mechanisms.batched import solve_batched

        mode = mode or self.mode
        g = jnp.atleast_1d(jnp.asarray(
            self.gamma if gammas is None else gammas, jnp.float64))
        c = jnp.atleast_1d(jnp.asarray(
            self.cost if costs is None else costs, jnp.float64))
        g, c = jnp.broadcast_arrays(g, c)
        if mode == "fixed":
            return jnp.full(g.shape, self.fixed_p, jnp.float64)
        if mode == "mechanism":
            if self.mechanism is not None:
                # Honour the explicitly supplied mechanism (scalar-path
                # parity): apply its transfer to every scenario's utilities,
                # then one batched solve of the induced games.
                induced = [self.mechanism.induced_params(UtilityParams(
                    gamma=float(gb), cost=float(cb), n_nodes=self.n_nodes))
                    for gb, cb in zip(g, c)]
                sol = solve_batched(
                    jnp.asarray([u.gamma for u in induced]),
                    jnp.asarray([u.cost for u in induced]),
                    self.duration_model)
                return jnp.nan_to_num(sol.worst_ne, nan=0.0)
            batch = g.shape[0]
            grid = jnp.linspace(0.0, gamma_max, coarse)
            sol = solve_batched((g[:, None] + grid[None, :]).reshape(-1),
                                jnp.repeat(c, coarse), self.duration_model)
            poa = sol.poa.reshape(batch, coarse)
            worst_ne = sol.worst_ne.reshape(batch, coarse)
            ok = poa <= self.target_poa + 1e-9
            # Smallest γ meeting the target; else the best PoA seen
            # (calibrate_gamma's achieved=False fallback).
            first_ok = jnp.argmax(ok, axis=1)
            best = jnp.argmin(jnp.where(jnp.isnan(poa), jnp.inf, poa), axis=1)
            idx = jnp.where(jnp.any(ok, axis=1), first_ok, best)
            p = jnp.take_along_axis(worst_ne, idx[:, None], axis=1)[:, 0]
            return jnp.nan_to_num(p, nan=0.0)
        sol = solve_batched(g, c, self.duration_model)
        if mode == "centralized":
            return sol.opt_p
        if mode not in ("ne", "ne_worst"):
            raise ValueError(f"unknown mode {mode!r}")
        p = sol.worst_ne if mode == "ne_worst" else sol.best_ne
        return jnp.nan_to_num(p, nan=0.0)

    def participation_probability(self) -> float:
        if self.mode == "fixed":
            return float(self.fixed_p)
        if self.mode == "mechanism":
            ne_p = self.solve_mechanism().ne_p
            return float(ne_p) if ne_p == ne_p else 0.0  # NaN: no induced NE
        sol = self.solve()
        if self.mode == "centralized":
            return sol.opt_p
        if not sol.equilibria:
            return 0.0
        if self.mode == "ne_worst":
            worst = max(range(len(sol.equilibria)),
                        key=lambda i: sol.ne_costs[i])
            return sol.equilibria[worst]
        # "ne": the paper reports the best-cost NE curve in Figs. 4-5
        best = min(range(len(sol.equilibria)), key=lambda i: sol.ne_costs[i])
        return sol.equilibria[best]

    # -- runtime hooks --------------------------------------------------------
    def draw_masks(self, key: jax.Array, n_rounds: int) -> jax.Array:
        """(n_rounds, N) Bernoulli participation masks, deterministic in key."""
        p = self.participation_probability()
        return jax.random.bernoulli(key, p, (n_rounds, self.n_nodes))

    def new_ledger(self) -> EnergyLedger:
        return EnergyLedger.create(self.n_nodes)

    def with_roofline(self, clock: RooflineClock) -> "ParticipationController":
        """Rebuild the controller with dry-run-derived timing/power."""
        ep = dataclasses.replace(
            self.energy_params,
            p_hw_w=clock.p_hw_w,
            t_train_s=min(clock.t_train_s, self.energy_params.t_round_s),
        )
        return dataclasses.replace(self, energy_params=ep, _solution=None,
                                   _mech_report=None)

    def diagnostics(self) -> dict:
        sol = self.solve()
        out = {
            "mode": self.mode,
            "p": self.participation_probability(),
            "equilibria": sol.equilibria,
            "ne_costs": sol.ne_costs,
            "opt_p": sol.opt_p,
            "opt_cost": sol.opt_cost,
            "poa": sol.poa,
            "e_participant_j": self.energy_params.e_participant_j,
            "e_idle_j": self.energy_params.e_idle_j,
        }
        if self.mode == "mechanism":
            rep = self.solve_mechanism()
            out.update({
                "mechanism": rep.mechanism,
                "mechanism_poa": rep.poa,
                "mechanism_ne": rep.ne_p,
                # False when calibration could not reach target_poa (the
                # best-effort mechanism is still applied — callers must not
                # assume the efficiency target silently held).
                "mechanism_target_met": rep.poa <= self.target_poa + 1e-9,
                "planner_budget": rep.planner_budget,
                "individually_rational": rep.individually_rational,
            })
        return out
