"""ParticipationController — the paper's mechanism as a framework feature.

Bridges the game-theory layer to the FL runtime:

* derives the per-round duration/energy parameters either from the paper's
  calibration (IoT scenario) or from a compiled dry-run's roofline terms
  (datacenter scenario: T_train = HLO FLOPs / (chips × peak), P_hw = chip TDP);
* solves the game for the configured (gamma, c) and hands the runtime either
  the NE probability (distributed mode), the centralized optimum
  (centralized mode), or a fixed user probability;
* resolves whole scenario *batches* with zero Python-level solves:
  :meth:`ParticipationController.solve_batched` returns ``(B,)``
  probabilities for symmetric (γ, c) grids and — given ``(B, N)`` per-node
  cost/γ matrices — ``(B, N)`` certified asymmetric-NE / planner /
  uniform-γ* profiles ready for the scan-fused campaign engine
  (:mod:`repro.federated.campaign`);
* meters realized energy per round through :class:`EnergyLedger` and exposes
  convergence/PoA diagnostics.

Shape conventions: scalars configure one game; ``(B,)`` arrays batch
symmetric scenarios; ``(B, N)`` matrices batch heterogeneous fleets
(``N == n_nodes``). Energies are Joules per round inside the game layer and
Watt-hours in reported summaries.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Literal, Optional

import jax
import jax.numpy as jnp

from repro.core.duration import DurationModel, paper_duration_model
from repro.core.energy import EnergyLedger, EnergyParams
from repro.core.game import GameSolution, solve_game
from repro.core.utility import UtilityParams

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.mechanisms.base import Mechanism, MechanismReport

__all__ = ["ParticipationController", "RooflineClock"]


@dataclasses.dataclass(frozen=True)
class RooflineClock:
    """Analytic per-round timing from a compiled dry-run (CPU container:
    we cannot wall-clock a TPU, so T_train is modeled from the roofline).

    Attributes:
        flops_per_step: HLO FLOPs of one local training step (cost_analysis).
        hbm_bytes_per_step: HLO bytes accessed per step.
        steps_per_round: local steps in one FL round (E epochs × batches).
        chips: chips available to one client (shard group size).
        peak_flops: per-chip peak (bf16), default TPU v5e 197e12.
        hbm_bw: per-chip HBM bandwidth, default 819e9 B/s.
        chip_power_w: per-chip board power for E_train accounting.
    """

    flops_per_step: float
    hbm_bytes_per_step: float
    steps_per_round: int = 1
    chips: int = 1
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    chip_power_w: float = 170.0

    @property
    def t_train_s(self) -> float:
        t_compute = self.flops_per_step / (self.chips * self.peak_flops)
        t_memory = self.hbm_bytes_per_step / (self.chips * self.hbm_bw)
        return self.steps_per_round * max(t_compute, t_memory)

    @property
    def p_hw_w(self) -> float:
        return self.chips * self.chip_power_w


@dataclasses.dataclass
class ParticipationController:
    """Chooses and applies the per-node participation probability.

    Modes:
        "ne"          — symmetric NE of the paper's game (distributed nodes).
        "ne_worst"    — worst-cost NE (the PoA numerator; pessimistic).
        "centralized" — centralized optimum (the PoA denominator).
        "fixed"       — externally supplied probability.
        "mechanism"   — worst NE of the game *induced by an incentive
                        mechanism* (repro.mechanisms). When no mechanism is
                        supplied, the AoI reward weight γ* is calibrated on
                        the fly so even the worst induced NE is within
                        ``target_poa`` of the centralized optimum.
        "coalition"   — partition equilibrium of the coalition-formation
                        game (:mod:`repro.core.coalition`): nodes sort
                        themselves into ``n_coalitions`` pooled FedAvg
                        groups (≤ ``coalition_cap`` members each), each
                        coalition playing its internal heterogeneous NE.
                        Per-node profiles only — use :meth:`solve_batched`.
    """

    n_nodes: int
    gamma: float = 0.0
    cost: float = 0.0
    mode: Literal["ne", "ne_worst", "centralized", "fixed",
                  "mechanism", "coalition"] = "ne"
    fixed_p: float = 0.5
    n_coalitions: int = 1
    coalition_cap: Optional[int] = None
    duration_model: Optional[DurationModel] = None
    energy_params: EnergyParams = dataclasses.field(default_factory=EnergyParams)
    mechanism: Optional["Mechanism"] = None
    target_poa: float = 1.05
    _solution: Optional[GameSolution] = dataclasses.field(default=None, repr=False)
    _mech_report: Optional["MechanismReport"] = dataclasses.field(
        default=None, repr=False)

    def __post_init__(self) -> None:
        if self.duration_model is None:
            self.duration_model = paper_duration_model()
        if self.duration_model.n_nodes != self.n_nodes:
            raise ValueError(
                f"duration model is for N={self.duration_model.n_nodes}, "
                f"controller has N={self.n_nodes}")
        if self.n_coalitions < 1:
            raise ValueError(f"n_coalitions={self.n_coalitions} must be >= 1")

    # -- game ---------------------------------------------------------------
    @property
    def utility_params(self) -> UtilityParams:
        return UtilityParams(gamma=self.gamma, cost=self.cost,
                             n_nodes=self.n_nodes)

    def solve(self) -> GameSolution:
        """Solve (and cache) the symmetric game at this (γ, c)."""
        if self._solution is None:
            self._solution = solve_game(self.utility_params,
                                        self.duration_model)
        return self._solution

    def solve_mechanism(self) -> "MechanismReport":
        """Evaluate (calibrating if needed) the incentive mechanism."""
        if self._mech_report is None:
            # Lazy import — repro.mechanisms imports repro.core at load time.
            from repro.mechanisms import calibrate_gamma, evaluate_mechanism

            mech = self.mechanism
            if mech is None:
                mech = calibrate_gamma(self.utility_params,
                                       self.duration_model,
                                       target_poa=self.target_poa).mechanism
            self._mech_report = evaluate_mechanism(
                mech, self.utility_params, self.duration_model)
        return self._mech_report

    def solve_batched(
        self,
        gammas: jax.Array | float | None = None,
        costs: jax.Array | float | None = None,
        mode: str | None = None,
        *,
        gamma_max: float = 5.0,
        coarse: int | None = None,
        **solver_kwargs,
    ) -> jax.Array:
        """Participation probabilities for a whole (γ, c) scenario grid.

        The batched counterpart of :meth:`participation_probability`: all
        scenarios are resolved through the batched game solvers with no
        Python-level per-scenario solves — the path the campaign engine
        (:mod:`repro.federated.campaign`) feeds on for Table II-style and
        stratified-fleet sweeps.

        Two regimes, dispatched on input rank:

        * **symmetric** — ``gammas`` / ``costs`` are scalars or ``(B,)``
          arrays (one identical-node game per scenario); resolved through
          :func:`repro.mechanisms.batched.solve_batched`; returns ``(B,)``.
        * **heterogeneous** — either input is a ``(B, N)`` *matrix* of
          per-node values (``N == n_nodes``); resolved through
          :mod:`repro.core.asymmetric_batched` (certified asymmetric NEs,
          heterogeneity-aware planner, uniform-γ* mechanism); returns a
          ``(B, N)`` probability matrix ready to feed
          :func:`repro.federated.campaign.run_campaigns`. See
          :meth:`solve_batched_heterogeneous` for the knobs.

        Args:
            gammas / costs: scalars, ``(B,)`` arrays, or ``(B, N)``
                matrices (default: this controller's own γ / c).
            mode: overrides ``self.mode``. Semantics per scenario match the
                scalar path — ``"ne"`` best-cost NE, ``"ne_worst"``
                worst-cost NE, ``"centralized"`` planner optimum,
                ``"fixed"`` the fixed probability, ``"mechanism"`` the worst
                NE induced by a γ-grid-calibrated AoI reward (grid
                resolution ``gamma_max / (coarse - 1)``; the scalar path
                refines by bisection, so mechanism probabilities agree only
                to that resolution). Scenarios with no NE resolve to 0.0.
            coarse: mechanism-mode γ-grid size (default 64 symmetric, 16
                heterogeneous — the asymmetric solves cost more).
            solver_kwargs: heterogeneous path only — forwarded to the
                asymmetric engine (``damping``, ``max_iters``, ``tol``, …).

        Returns:
            ``(B,)`` probabilities, or ``(B, N)`` in the heterogeneous
            regime.
        """
        # Lazy import — repro.mechanisms imports repro.core at load time.
        from repro.mechanisms.batched import solve_batched

        eff_mode = mode or self.mode
        if (eff_mode == "coalition"
                or (gammas is not None and jnp.asarray(gammas).ndim == 2)
                or (costs is not None and jnp.asarray(costs).ndim == 2)):
            if coarse is not None:
                solver_kwargs["coarse"] = coarse
            if eff_mode == "coalition":
                # Partitions are inherently per-node: spread scalar /
                # per-scenario (B,) configs uniformly across the fleet so
                # the coalition engine sees its (B, N) matrices.
                def _as_matrix(x, default):
                    arr = jnp.atleast_1d(jnp.asarray(
                        default if x is None else x, jnp.float64))
                    if arr.ndim == 1:
                        arr = arr[:, None]
                    return jnp.broadcast_to(
                        arr, (arr.shape[0], self.n_nodes))

                gammas = _as_matrix(gammas, self.gamma)
                costs = _as_matrix(costs, self.cost)
            return self.solve_batched_heterogeneous(
                gammas, costs, mode, gamma_max=gamma_max, **solver_kwargs)
        if solver_kwargs:
            raise TypeError(
                f"solver_kwargs {sorted(solver_kwargs)} only apply to the "
                "heterogeneous path (pass (B, N) gammas/costs)")
        coarse = 64 if coarse is None else coarse
        mode = mode or self.mode
        g = jnp.atleast_1d(jnp.asarray(
            self.gamma if gammas is None else gammas, jnp.float64))
        c = jnp.atleast_1d(jnp.asarray(
            self.cost if costs is None else costs, jnp.float64))
        g, c = jnp.broadcast_arrays(g, c)
        if mode == "fixed":
            return jnp.full(g.shape, self.fixed_p, jnp.float64)
        if mode == "mechanism":
            if self.mechanism is not None:
                # Honour the explicitly supplied mechanism (scalar-path
                # parity): apply its transfer to every scenario's utilities,
                # then one batched solve of the induced games.
                induced = [self.mechanism.induced_params(UtilityParams(
                    gamma=float(gb), cost=float(cb), n_nodes=self.n_nodes))
                    for gb, cb in zip(g, c)]
                sol = solve_batched(
                    jnp.asarray([u.gamma for u in induced]),
                    jnp.asarray([u.cost for u in induced]),
                    self.duration_model)
                return jnp.nan_to_num(sol.worst_ne, nan=0.0)
            batch = g.shape[0]
            grid = jnp.linspace(0.0, gamma_max, coarse)
            sol = solve_batched((g[:, None] + grid[None, :]).reshape(-1),
                                jnp.repeat(c, coarse), self.duration_model)
            poa = sol.poa.reshape(batch, coarse)
            worst_ne = sol.worst_ne.reshape(batch, coarse)
            ok = poa <= self.target_poa + 1e-9
            # Smallest γ meeting the target; else the best PoA seen
            # (calibrate_gamma's achieved=False fallback).
            first_ok = jnp.argmax(ok, axis=1)
            best = jnp.argmin(jnp.where(jnp.isnan(poa), jnp.inf, poa), axis=1)
            idx = jnp.where(jnp.any(ok, axis=1), first_ok, best)
            p = jnp.take_along_axis(worst_ne, idx[:, None], axis=1)[:, 0]
            return jnp.nan_to_num(p, nan=0.0)
        sol = solve_batched(g, c, self.duration_model)
        if mode == "centralized":
            return sol.opt_p
        if mode not in ("ne", "ne_worst"):
            raise ValueError(f"unknown mode {mode!r}")
        p = sol.worst_ne if mode == "ne_worst" else sol.best_ne
        return jnp.nan_to_num(p, nan=0.0)

    def solve_batched_heterogeneous(
        self,
        gammas: jax.Array | float | None = None,
        costs: jax.Array | float | None = None,
        mode: str | None = None,
        *,
        gamma_max: float = 5.0,
        coarse: int = 16,
        cert_tol: float = 1e-3,
        mesh=None,
        batch_axis=None,
        **solver_kwargs,
    ) -> jax.Array:
        """Per-node participation matrices for heterogeneous scenario sweeps.

        Resolves a batch of *asymmetric* games — per-node cost/γ vectors —
        straight into the ``(B, N)`` probability matrices the campaign
        engine replays, with every scenario solved inside the batched
        asymmetric engine (:mod:`repro.core.asymmetric_batched`):

        * ``"ne"`` / ``"ne_worst"`` — damped Gauss-Seidel from three
          starting profiles (0.5, ``P_MIN``, 1.0) to reach distinct
          equilibria (identical fleets can stratify — see PR 2's
          spontaneous-stratification finding), every candidate certified by
          the jitted deviation grid; per scenario the certified NE with the
          lowest / highest social cost wins (fallback: the default-start
          fixed point when nothing certifies within ``cert_tol``).
        * ``"centralized"`` — the heterogeneity-aware planner
          (:func:`~repro.core.asymmetric_batched.planner_batched`),
          descending from the default-start NE.
        * ``"mechanism"`` — the smallest *uniform* AoI-reward weight γ* on
          a ``coarse``-point grid in ``[0, gamma_max]`` whose induced
          asymmetric NE has heterogeneous PoA ≤ ``target_poa`` (grid
          counterpart of
          :func:`repro.mechanisms.heterogeneous.calibrate_gamma_heterogeneous`,
          which refines by bisection); returns that induced NE profile.
        * ``"coalition"`` — the certified partition equilibrium of the
          coalition-formation game (:func:`repro.core.coalition.solve_partition`
          with this controller's ``n_coalitions`` / ``coalition_cap``):
          each node's probability is its NE strategy *inside the coalition
          it settled in* after best-switch dynamics converge.
        * ``"fixed"`` — ``fixed_p`` everywhere.

        Args:
            gammas / costs: per-node matrices, broadcastable to ``(B, N)``
                with ``N == n_nodes`` (scalars/vectors default to this
                controller's γ / c spread uniformly).
            cert_tol: max profitable unilateral deviation for a fixed point
                to count as a certified NE in the multistart selection.
            mesh / batch_axis: optional :class:`jax.sharding.Mesh` (and
                mesh-axis override) sharding every stage's scenario batch
                over the mesh's data axes — see
                :func:`repro.core.asymmetric_batched.solve_heterogeneous`.
                ``mesh=None`` keeps the single-device programs
                bitwise-unchanged.
            solver_kwargs: forwarded to the asymmetric engine (``damping``,
                ``max_iters``, ``tol``).

        Returns:
            ``(B, N)`` per-node probabilities.
        """
        from repro.core.asymmetric_batched import (
            P_MIN, planner_batched, poa_report, social_cost_batched,
            solve_heterogeneous, verify_equilibrium_batched)

        mode = mode or self.mode
        n = self.n_nodes
        g = jnp.atleast_2d(jnp.asarray(
            self.gamma if gammas is None else gammas, jnp.float64))
        c = jnp.atleast_2d(jnp.asarray(
            self.cost if costs is None else costs, jnp.float64))
        g, c = jnp.broadcast_arrays(g, c)
        if g.shape[-1] != n:
            raise ValueError(f"per-node arrays have N={g.shape[-1]}, "
                             f"controller has n_nodes={n}")
        b = g.shape[0]
        dur = self.duration_model

        if mode == "fixed":
            return jnp.full((b, n), self.fixed_p, jnp.float64)

        if mode == "coalition":
            if mesh is not None:
                raise ValueError(
                    "coalition mode does not support mesh sharding")
            from repro.core.coalition import solve_partition

            sol = solve_partition(c, g, dur,
                                  n_coalitions=self.n_coalitions,
                                  cap=self.coalition_cap, **solver_kwargs)
            return sol.p

        if mode == "mechanism":
            grid = jnp.linspace(0.0, gamma_max, coarse)
            g_all = (g[:, None, :] + grid[None, :, None]).reshape(-1, n)
            c_all = jnp.repeat(c, coarse, axis=0)
            rep = poa_report(c_all, g_all, dur, mesh=mesh,
                             batch_axis=batch_axis, **solver_kwargs)
            poa = jnp.where(rep.solution.converged, rep.poa,
                            jnp.inf).reshape(b, coarse)
            ok = poa <= self.target_poa + 1e-9
            first_ok = jnp.argmax(ok, axis=1)
            best = jnp.argmin(poa, axis=1)
            idx = jnp.where(jnp.any(ok, axis=1), first_ok, best)
            p_all = rep.solution.p.reshape(b, coarse, n)
            return p_all[jnp.arange(b), idx]

        if mode in ("ne", "ne_worst"):
            starts = jnp.asarray([0.5, P_MIN, 1.0], jnp.float64)
            s = starts.shape[0]
            c_all = jnp.tile(c, (s, 1))
            g_all = jnp.tile(g, (s, 1))
            p0 = jnp.repeat(starts, b)[:, None] * jnp.ones((1, n))
            sol = solve_heterogeneous(c_all, g_all, dur, p0=p0, mesh=mesh,
                                      batch_axis=batch_axis, **solver_kwargs)
            dev = verify_equilibrium_batched(c_all, g_all, dur, sol.p,
                                             mesh=mesh, batch_axis=batch_axis)
            cost = social_cost_batched(c_all, dur, sol.p, mesh=mesh,
                                       batch_axis=batch_axis)
            valid = (sol.converged & (dev <= cert_tol)).reshape(s, b)
            cost = cost.reshape(s, b)
            if mode == "ne_worst":
                score = jnp.where(valid, cost, -jnp.inf)
                pick = jnp.argmax(score, axis=0)
            else:
                score = jnp.where(valid, cost, jnp.inf)
                pick = jnp.argmin(score, axis=0)
            pick = jnp.where(jnp.any(valid, axis=0), pick, 0)
            p_all = sol.p.reshape(s, b, n)
            return p_all[pick, jnp.arange(b)]

        if mode == "centralized":
            sol = solve_heterogeneous(c, g, dur, mesh=mesh,
                                      batch_axis=batch_axis, **solver_kwargs)
            return planner_batched(c, dur, sol.p, mesh=mesh,
                                   batch_axis=batch_axis)

        raise ValueError(f"unknown mode {mode!r}")

    def participation_probability(self) -> float:
        """The scalar symmetric participation probability of this mode.

        Returns a plain float in [0, 1] (0.0 when the configured game has
        no NE / no induced NE). Per-node heterogeneous profiles come from
        :meth:`solve_batched_heterogeneous` instead — this scalar surface
        covers the paper's identical-node scenarios.
        """
        if self.mode == "coalition":
            raise ValueError(
                "coalition mode yields per-node partition profiles, not a "
                "scalar probability; use solve_batched() (or "
                "repro.core.coalition.solve_partition directly)")
        if self.mode == "fixed":
            return float(self.fixed_p)
        if self.mode == "mechanism":
            ne_p = self.solve_mechanism().ne_p
            return float(ne_p) if ne_p == ne_p else 0.0  # NaN: no induced NE
        sol = self.solve()
        if self.mode == "centralized":
            return sol.opt_p
        if not sol.equilibria:
            return 0.0
        if self.mode == "ne_worst":
            worst = max(range(len(sol.equilibria)),
                        key=lambda i: sol.ne_costs[i])
            return sol.equilibria[worst]
        # "ne": the paper reports the best-cost NE curve in Figs. 4-5
        best = min(range(len(sol.equilibria)), key=lambda i: sol.ne_costs[i])
        return sol.equilibria[best]

    # -- runtime hooks --------------------------------------------------------
    def draw_masks(self, key: jax.Array, n_rounds: int) -> jax.Array:
        """(n_rounds, N) Bernoulli participation masks, deterministic in key."""
        p = self.participation_probability()
        return jax.random.bernoulli(key, p, (n_rounds, self.n_nodes))

    def new_ledger(self) -> EnergyLedger:
        """A fresh ``(N,)`` per-node :class:`EnergyLedger` (Joules)."""
        return EnergyLedger.create(self.n_nodes)

    def with_roofline(self, clock: RooflineClock) -> "ParticipationController":
        """Rebuild the controller with dry-run-derived timing/power."""
        ep = dataclasses.replace(
            self.energy_params,
            p_hw_w=clock.p_hw_w,
            t_train_s=min(clock.t_train_s, self.energy_params.t_round_s),
        )
        return dataclasses.replace(self, energy_params=ep, _solution=None,
                                   _mech_report=None)

    def diagnostics(self) -> dict:
        """Game/energy summary dict: probabilities and PoA are unitless,
        ``e_participant_j`` / ``e_idle_j`` are Joules per round."""
        sol = self.solve()
        out = {
            "mode": self.mode,
            "p": (None if self.mode == "coalition"
                  else self.participation_probability()),
            "equilibria": sol.equilibria,
            "ne_costs": sol.ne_costs,
            "opt_p": sol.opt_p,
            "opt_cost": sol.opt_cost,
            "poa": sol.poa,
            "e_participant_j": self.energy_params.e_participant_j,
            "e_idle_j": self.energy_params.e_idle_j,
        }
        if self.mode == "mechanism":
            rep = self.solve_mechanism()
            out.update({
                "mechanism": rep.mechanism,
                "mechanism_poa": rep.poa,
                "mechanism_ne": rep.ne_p,
                # False when calibration could not reach target_poa (the
                # best-effort mechanism is still applied — callers must not
                # assume the efficiency target silently held).
                "mechanism_target_met": rep.poa <= self.target_poa + 1e-9,
                "planner_budget": rep.planner_budget,
                "individually_rational": rep.individually_rational,
            })
        return out
