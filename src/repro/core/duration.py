"""Duration models d(k): FL rounds-to-convergence vs. mean participants.

The paper measures rounds-to-convergence ``d`` for participation probabilities
``p in [0.1, 0.7]`` with N = 50 clients (Table II) and fits a polynomial
regression; ``d(k)`` is then read as a function of the *number of
participating nodes* ``k ~ PoiBin(p)`` via ``k = N p``.

We provide:

* ``PAPER_TABLE_II`` — the paper's measured (p, d_mean, d_std, E_mean, E_std)
  verbatim, used to calibrate the reproduction exactly as the paper does.
* ``fit_polynomial_duration`` — weighted least-squares polynomial fit in JAX.
* ``DurationModel`` — evaluates d(k) on k = 0..N with a guarded k=0 plateau
  (zero participants ⇒ the round contributes nothing: d(0) is set to a finite
  horizon penalty, mirroring the paper's finite simulation horizon).
* ``theoretical_duration`` — an optional analytic surrogate
  d(k) ≈ a + b/k (convergence speedup ~ participant count, diminishing
  returns), used in unit tests and available to the controller when no
  simulation data exists yet.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PAPER_TABLE_II",
    "PAPER_N_CLIENTS",
    "fit_polynomial_duration",
    "DurationModel",
    "paper_duration_model",
    "theoretical_duration",
]

PAPER_N_CLIENTS = 50

# Table II(b): p, mean rounds, std rounds, mean energy (Wh), std energy (Wh).
PAPER_TABLE_II: np.ndarray = np.array([
    [0.100, 74.50, 11.47, 1072.14, 123.43],
    [0.125, 68.00, 13.09, 1005.97, 140.49],
    [0.130, 56.00, 5.29, 862.84, 60.19],
    [0.150, 62.50, 8.81, 950.26, 100.14],
    [0.160, 57.25, 6.13, 887.80, 61.31],
    [0.175, 51.00, 9.42, 797.18, 145.67],
    [0.200, 51.00, 4.55, 816.96, 37.86],
    [0.225, 45.50, 3.70, 747.44, 54.52],
    [0.250, 51.00, 9.56, 803.96, 132.64],
    [0.300, 46.75, 2.75, 768.25, 41.50],
    [0.350, 43.00, 5.23, 724.40, 73.21],
    [0.400, 43.25, 2.22, 734.25, 33.22],
    [0.410, 44.50, 5.32, 758.88, 62.29],
    [0.420, 42.75, 4.11, 725.76, 59.45],
    [0.430, 42.75, 3.30, 734.69, 35.41],
    [0.440, 43.00, 4.08, 732.95, 49.07],
    [0.450, 43.50, 4.43, 751.96, 61.11],
    [0.460, 42.75, 5.56, 750.14, 89.77],
    [0.470, 39.50, 3.11, 698.25, 33.15],
    [0.480, 39.25, 6.70, 696.30, 71.74],
    [0.490, 40.67, 2.89, 709.99, 33.48],
    [0.500, 40.00, 0.82, 704.10, 11.11],
    [0.510, 41.75, 3.30, 719.96, 43.71],
    [0.520, 42.50, 7.33, 729.13, 81.90],
    [0.530, 40.00, 3.16, 703.01, 37.23],
    [0.540, 41.75, 4.27, 726.11, 44.34],
    [0.550, 39.50, 2.65, 706.41, 35.12],
    [0.560, 40.25, 2.99, 719.03, 48.51],
    [0.570, 40.50, 4.43, 712.93, 46.15],
    [0.580, 46.25, 14.15, 771.83, 152.41],
    [0.590, 39.00, 2.58, 694.74, 27.70],
    [0.600, 39.00, 4.24, 691.24, 51.19],
    [0.610, 37.75, 2.87, 682.34, 30.05],
    [0.620, 39.75, 5.56, 708.59, 58.31],
    [0.630, 37.75, 3.50, 697.93, 70.71],
    [0.640, 39.75, 5.91, 726.61, 102.68],
    [0.650, 39.00, 2.16, 702.75, 23.75],
    [0.660, 40.75, 4.99, 719.79, 48.48],
    [0.670, 40.00, 4.69, 725.12, 75.90],
    [0.680, 41.25, 4.03, 728.89, 36.60],
    [0.690, 37.50, 3.87, 676.75, 45.17],
    [0.700, 38.25, 5.50, 696.29, 59.19],
])


def fit_polynomial_duration(
    mean_participants: jax.Array,
    rounds: jax.Array,
    degree: int = 3,
    weights: jax.Array | None = None,
) -> jax.Array:
    """Weighted least-squares polynomial fit ``d(k) ~ sum_j c_j k^j``.

    Mirrors the paper's "polynomial regression model" over Table II(b).
    Returns coefficients ``(degree+1,)`` low-order-first.
    """
    k = jnp.asarray(mean_participants, jnp.float64)
    d = jnp.asarray(rounds, jnp.float64)
    # Normalize k to [0,1]-ish for conditioning; bake the scale into coeffs
    # evaluation by storing the Vandermonde in normalized space.
    vander = jnp.stack([k**j for j in range(degree + 1)], axis=1)
    if weights is not None:
        w = jnp.sqrt(jnp.asarray(weights, jnp.float64))
        vander = vander * w[:, None]
        d = d * w
    coeffs, *_ = jnp.linalg.lstsq(vander, d, rcond=None)
    return coeffs


def _polyval(coeffs: jax.Array, k: jax.Array) -> jax.Array:
    powers = jnp.stack([k**j for j in range(coeffs.shape[0])], axis=-1)
    return powers @ coeffs


@dataclasses.dataclass(frozen=True)
class DurationModel:
    """Evaluates d(k) for k = 0..N participants per round.

    The polynomial is fit on the paper's measured domain p = k/N ∈
    [lo_frac, hi_frac]. Outside it:

    * below ``lo_frac`` the raw polynomial is kept (for the Table II fit it
      rises steeply toward the finite horizon — the p→0 cliff the paper's
      Tragedy of the Commons rests on) but capped at ``d_zero``;
    * above ``hi_frac`` extrapolation is replaced by an increasing quadratic
      continuation ``d(edge) + rise · ((x - hi)/(1 - hi))²`` — full
      participation is penalized ("overfitting or entrapment", §I), matching
      the paper's Fig. 2 utility that falls beyond its peak.

    Attributes:
        coeffs: polynomial coefficients in normalized participants x = k/N.
        n_nodes: N.
        d_zero: k→0 penalty horizon (rounds; the sim never converges at p=0).
        d_floor: minimum achievable rounds (guards downward blips).
        lo_frac / hi_frac: fitted data range in x = k/N.
        rise: rounds added by the time x = 1 relative to the hi edge.
    """

    coeffs: jax.Array
    n_nodes: int
    d_zero: float
    d_floor: float
    lo_frac: float = 0.1
    hi_frac: float = 0.70
    rise: float = 80.0

    def table(self) -> jax.Array:
        """d(k) for k = 0..N, shape (N+1,). Entry 0 is the penalty horizon."""
        k = jnp.arange(self.n_nodes + 1, dtype=jnp.float64)
        return self.eval_continuous(k)

    def eval_continuous(self, k: jax.Array) -> jax.Array:
        """Evaluate d at (possibly fractional) participant count k >= 0."""
        kf = jnp.asarray(k, jnp.float64)
        x = kf / self.n_nodes
        poly = _polyval(self.coeffs, jnp.clip(x, 0.0, self.hi_frac))
        d_edge = _polyval(self.coeffs, jnp.asarray(self.hi_frac))
        above = d_edge + self.rise * ((x - self.hi_frac)
                                      / (1.0 - self.hi_frac)) ** 2
        d = jnp.where(x > self.hi_frac, above, poly)
        d = jnp.clip(d, self.d_floor, self.d_zero)
        # At k = 0 the task never converges: charge the full horizon.
        return jnp.where(kf <= 0.0, self.d_zero, d)


def paper_duration_model(degree: int = 9, horizon: float = 500.0,
                         rise: float = 80.0) -> DurationModel:
    """Duration model calibrated on the paper's Table II(b), N = 50.

    Degree 9 (inverse-variance weighted) reproduces the multi-minimum
    structure the paper's results imply: a local minimum near p ≈ 0.28
    (d ≈ 45.6 — the paper's no-incentive NE basin at p ≈ 0.24) and the
    global minimum near p ≈ 0.62 (d ≈ 38.4 — the paper's centralized
    optimum p ≈ 0.61). ``horizon`` is the k→0 penalty; 500 rounds ≫ any
    measured d preserves the collapse cliff while keeping the utility finite.
    """
    tab = PAPER_TABLE_II
    x = jnp.asarray(tab[:, 0], jnp.float64)  # p = k/N (table is indexed by p)
    d = jnp.asarray(tab[:, 1], jnp.float64)
    w = 1.0 / jnp.clip(jnp.asarray(tab[:, 2], jnp.float64), 0.5, None) ** 2
    coeffs = fit_polynomial_duration(x, d, degree=degree, weights=w)
    d_floor = float(tab[:, 1].min() * 0.9)
    return DurationModel(coeffs=coeffs, n_nodes=PAPER_N_CLIENTS,
                         d_zero=horizon, d_floor=d_floor,
                         lo_frac=float(tab[:, 0].min()),
                         hi_frac=float(tab[:, 0].max()), rise=rise)


def theoretical_duration(
    n_nodes: int,
    d_inf: float = 35.0,
    slope: float = 4.0,
    horizon: float = 500.0,
) -> DurationModel:
    """Analytic surrogate d(k) = d_inf + slope * N / k.

    Encodes diminishing returns of extra participants; exposed as a
    DurationModel by fitting the polynomial to the curve so both paths share
    one code path downstream.
    """
    k = np.arange(1, n_nodes + 1, dtype=np.float64)
    d = d_inf + slope * n_nodes / k
    coeffs = fit_polynomial_duration(
        jnp.asarray(k / n_nodes), jnp.asarray(np.minimum(d, horizon)), degree=6)
    return DurationModel(coeffs=coeffs, n_nodes=n_nodes, d_zero=horizon,
                         d_floor=float(d.min()))
