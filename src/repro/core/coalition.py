"""Coalition-formation equilibria: batched hedonic partition dynamics.

The next game class after per-node participation (the asymmetric layer):
nodes choose *which coalition* — a pooled FedAvg group training its own
model — to join, in the spirit of participant-centric coalition formation
(Huang et al., arXiv:2207.12030) and free-riding under heterogeneous-agent
pooling (Yi et al., arXiv:2503.09039). The two-level game:

* **Inner game** — within a coalition ``S``, members play the existing
  heterogeneous participation game (utility
  ``u_i = -E[D_S] - γ_i·log E[Δ_i] - c_i·p_i``, eqs. 8-11 restricted to
  ``S``); its equilibrium is the certified asymmetric NE of
  :mod:`repro.core.asymmetric_batched`, solved here by the *same* damped
  Gauss-Seidel sweep run masked: non-members are pinned at ``p = 0``
  exactly, whose Bernoulli factor ``[1, 0]`` is a convolution identity, so
  an all-true mask reproduces :func:`~repro.core.asymmetric_batched.
  solve_heterogeneous` bitwise (the grand-coalition reduction pinned in
  ``tests/test_property_coalition.py``).
* **Outer game** — a hedonic partition game: node ``i`` in coalition
  ``S_c`` values a switch to ``S_{c'}`` at the utility it would earn at
  the *re-solved* inner NE of ``S_{c'} ∪ {i}`` (preferences depend only on
  the coalition joined — a hedonic game). :func:`solve_partition` runs
  jitted best-switch dynamics: per iteration every (node, coalition)
  candidate NE is solved in one vmapped program, the single most
  profitable eligible switch (respecting the per-coalition cap) is
  applied, and the dynamics stop when no node gains more than
  ``switch_tol`` — a partition (Nash-stable hedonic) equilibrium.

Certification and benchmarking mirror the asymmetric layer's surfaces:
:func:`verify_partition_batched` re-derives every switch gain *and* every
within-coalition deviation grid at the returned partition (0 at an exact
partition equilibrium), :func:`partition_planner_batched` descends the
per-coalition social cost from the equilibrium profile (corner descent —
the cost is linear in each ``p_i``), and :func:`partition_poa_report`
packages NE + certification + planner + PoA for a scenario batch.
Everything is written single-scenario and ``vmap``-ed over
(costs, gammas, cap) batches in the jitted wrappers.

Oracle-first rails: :func:`partition_equilibrium_reference` restates both
levels as eager Python loops over *compact* subgames (no masks — each
coalition's pmf is built from its members only), kept verbatim as the test
oracle for ``tests/test_property_coalition.py``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.aoi import log_aoi
from repro.core.asymmetric_batched import P_MIN, best_response_given_slope
from repro.core.duration import DurationModel
from repro.core.poibin import (poibin_convolve, poibin_pmf_loo,
                               poibin_pmf_recursive)

__all__ = [
    "PartitionSolution",
    "PartitionPoA",
    "solve_partition",
    "verify_partition_batched",
    "partition_social_cost_batched",
    "partition_planner_batched",
    "partition_poa_report",
    "partition_equilibrium_reference",
]


# ---------------------------------------------------------------------------
# masked inner game: Gauss-Seidel NE of one coalition at full fleet width
# ---------------------------------------------------------------------------

def _masked_gs(costs, gammas, d_tab, member, p0, *, damping, max_iters, tol):
    """Damped Gauss-Seidel NE of the subgame on ``member`` at width N.

    Identical op sequence to ``asymmetric_batched._gs_fixed_point`` with
    one masked select at the update: non-members are held at ``p = 0``
    exactly, whose ``[1, 0]`` Bernoulli factor deconvolves/convolves as an
    identity (``poibin_pmf_loo`` at ``p = 0`` is a copy), so with an
    all-true mask every intermediate — and the fixed point — is bitwise
    the unmasked solver's.
    """
    n = costs.shape[0]
    dd = d_tab[1:] - d_tab[:-1]

    def sweep(p):
        f = poibin_pmf_recursive(p)

        def node(carry, i):
            f, p = carry
            pi = p[i]
            loo = poibin_pmf_loo(f, pi)
            slope = -(loo[:-1] @ dd)
            br = best_response_given_slope(slope, costs[i], gammas[i])
            upd = (1.0 - damping) * pi + damping * br
            new_pi = jnp.where(member[i], upd, 0.0)
            f_new = poibin_convolve(loo, new_pi)
            return (f_new, p.at[i].set(new_pi)), jnp.abs(new_pi - pi)

        (_, p_new), deltas = jax.lax.scan(node, (f, p), jnp.arange(n))
        return p_new, jnp.max(deltas)

    def cond(state):
        _, delta, it = state
        return (delta >= tol) & (it < max_iters)

    def body(state):
        p, _, it = state
        p_new, delta = sweep(p)
        return p_new, delta, it + 1

    p, delta, iters = jax.lax.while_loop(
        cond, body, (p0, jnp.asarray(jnp.inf, p0.dtype), jnp.asarray(0)))
    return p, delta < tol, iters


def _member_matrix(assign, m):
    """(M, N) bool coalition-membership masks from an (N,) assignment."""
    return jnp.arange(m)[:, None] == assign[None, :]


def _solve_coalitions(costs, gammas, d_tab, member, *, damping, max_iters,
                      tol):
    """Inner NE of every coalition: (M, N) profiles (zeros off-coalition),
    (M,) convergence flags, and (M,) expected durations E[D_{S_c}]."""
    def one(mask):
        p0 = jnp.where(mask, 0.5, 0.0).astype(d_tab.dtype)
        p, conv, _ = _masked_gs(costs, gammas, d_tab, mask, p0,
                                damping=damping, max_iters=max_iters, tol=tol)
        return p, conv

    p_cs, conv = jax.vmap(one)(member)
    e_d = jax.vmap(poibin_pmf_recursive)(p_cs) @ d_tab
    return p_cs, conv, e_d


def _candidate_gains(costs, gammas, d_tab, assign, cap, *, m, damping,
                     max_iters, tol):
    """The hedonic deviation table of one scenario.

    Returns ``(gain, p_full, e_d, inner_conv)``: ``gain[i, c]`` is node
    i's utility change from joining coalition ``c`` (the re-solved NE of
    ``S_c ∪ {i}`` versus its current coalition's NE), ``-inf`` where the
    switch is ineligible (own coalition, or ``|S_c| ≥ cap``); ``p_full``
    the (N,) equilibrium profile of the current partition; ``e_d`` the
    (M,) per-coalition expected durations; ``inner_conv`` whether every
    inner solve (current and candidate) converged.
    """
    n = costs.shape[0]
    member = _member_matrix(assign, m)
    p_cs, conv, e_d = _solve_coalitions(costs, gammas, d_tab, member,
                                        damping=damping,
                                        max_iters=max_iters, tol=tol)
    p_full = jnp.sum(p_cs, axis=0)              # coalitions are disjoint
    u_cur = (-e_d[assign] - gammas * log_aoi(p_full) - costs * p_full)

    # candidate masks: node i joins coalition c → S_c ∪ {i}, (N, M, N)
    cand = member[None, :, :] | jnp.eye(n, dtype=bool)[:, None, :]
    p_cand, conv_cand = jax.vmap(jax.vmap(
        lambda mask: _masked_gs(
            costs, gammas, d_tab, mask,
            jnp.where(mask, 0.5, 0.0).astype(d_tab.dtype),
            damping=damping, max_iters=max_iters, tol=tol)[:2]))(cand)
    e_d_cand = jax.vmap(jax.vmap(poibin_pmf_recursive))(p_cand) @ d_tab
    p_i_cand = p_cand[jnp.arange(n), :, jnp.arange(n)]          # (N, M)
    u_cand = (-e_d_cand - gammas[:, None] * log_aoi(p_i_cand)
              - costs[:, None] * p_i_cand)
    sizes = jnp.sum(member, axis=1)
    eligible = ((assign[:, None] != jnp.arange(m)[None, :])
                & (sizes[None, :] < cap))
    gain = jnp.where(eligible, u_cand - u_cur[:, None], -jnp.inf)
    return gain, p_full, e_d, conv.all() & conv_cand.all()


def _partition_dynamics_one(costs, gammas, d_tab, cap, assign0, *, m,
                            damping, max_iters, tol, switch_tol,
                            max_switches):
    """Best-switch hedonic dynamics of one scenario (while_loop)."""
    gains = functools.partial(_candidate_gains, costs, gammas, d_tab, m=m,
                              damping=damping, max_iters=max_iters, tol=tol)

    def cond(state):
        _, best, applied = state
        return (best > switch_tol) & (applied < max_switches)

    def body(state):
        assign, _, applied = state
        gain, _, _, _ = gains(assign, cap)
        flat = jnp.argmax(gain)
        i, c = flat // m, flat % m
        best = gain.reshape(-1)[flat]
        improving = best > switch_tol
        new_assign = jnp.where(improving,
                               assign.at[i].set(c.astype(assign.dtype)),
                               assign)
        return new_assign, best, applied + jnp.asarray(improving, jnp.int32)

    assign, _, switches = jax.lax.while_loop(
        cond, body,
        (assign0, jnp.asarray(jnp.inf, d_tab.dtype),
         jnp.asarray(0, jnp.int32)))
    # one last gain evaluation at the settled partition: the certificate
    # (and the equilibrium profile/durations) of what is returned
    gain, p_full, e_d, inner_conv = gains(assign, cap)
    best = jnp.maximum(jnp.max(gain), 0.0)      # -inf → 0 when no switch
    converged = best <= switch_tol
    return assign, p_full, e_d, converged, switches, best, inner_conv


@functools.partial(jax.jit, static_argnames=(
    "m", "damping", "max_iters", "tol", "switch_tol", "max_switches"))
def _solve_partition_vmapped(costs, gammas, d_tab, cap, assign0, *, m,
                             damping, max_iters, tol, switch_tol,
                             max_switches):
    fn = functools.partial(_partition_dynamics_one, m=m, damping=damping,
                           max_iters=max_iters, tol=tol,
                           switch_tol=switch_tol, max_switches=max_switches)
    return jax.vmap(fn)(costs, gammas, d_tab, cap, assign0)


@dataclasses.dataclass(frozen=True)
class PartitionSolution:
    """A vmapped batch of partition-equilibrium solves."""

    costs: jax.Array        # (B, N)
    gammas: jax.Array       # (B, N)
    assign: jax.Array       # (B, N) coalition index per node, in [0, M)
    p: jax.Array            # (B, N) inner-NE participation profiles
    e_d: jax.Array          # (B, M) per-coalition E[D_{S_c}]
    converged: jax.Array    # (B,) hedonic dynamics reached stability
    inner_converged: jax.Array  # (B,) every inner GS solve converged
    switches: jax.Array     # (B,) coalition switches applied
    max_gain: jax.Array     # (B,) best remaining switch gain (≤ switch_tol
    #                             wherever ``converged``)
    n_coalitions: int

    @property
    def batch(self) -> int:
        return int(self.assign.shape[0])

    @property
    def sizes(self) -> jax.Array:
        """(B, M) coalition sizes."""
        return jnp.sum(
            self.assign[:, None, :] == jnp.arange(self.n_coalitions)[
                None, :, None], axis=-1)


def _prepare_partition_batch(costs, gammas, dur, n_coalitions, cap, assign0):
    from repro.core.asymmetric_batched import _prepare_batch

    costs, gammas, d_tab, _ = _prepare_batch(costs, gammas, dur, None)
    b, n = costs.shape
    m = int(n_coalitions)
    if m < 1:
        raise ValueError(f"n_coalitions must be >= 1, got {m}")
    cap = jnp.asarray(n if cap is None else cap, jnp.int32)
    cap = jnp.broadcast_to(jnp.atleast_1d(cap), (b,))
    if assign0 is None:
        assign0 = jnp.arange(n, dtype=jnp.int32) % m     # round-robin
    assign0 = jnp.broadcast_to(
        jnp.atleast_2d(jnp.asarray(assign0, jnp.int32)), (b, n))
    return costs, gammas, d_tab, cap, assign0, b, n, m


def solve_partition(
    costs: jax.Array,
    gammas: jax.Array,
    dur: DurationModel | jax.Array,
    *,
    n_coalitions: int,
    cap: jax.Array | int | None = None,
    assign0: jax.Array | None = None,
    damping: float = 0.5,
    max_iters: int = 200,
    tol: float = 1e-5,
    switch_tol: float = 1e-6,
    max_switches: int | None = None,
) -> PartitionSolution:
    """Solve a batch of coalition-formation games in one jitted program.

    Args:
        costs / gammas: ``(N,)`` or ``(B, N)`` per-node game parameters
            (broadcast against each other like
            :func:`~repro.core.asymmetric_batched.solve_heterogeneous`).
        dur: shared :class:`DurationModel` / ``(N+1,)`` table or a
            per-scenario ``(B, N+1)`` stack — ``d(k)`` is indexed by the
            number of *participants inside one coalition*.
        n_coalitions: M, the number of coalition slots (static — it fixes
            program shapes). Empty coalitions are fine: a node can open
            one by switching in (subject to ``cap``).
        cap: max coalition size — scalar or per-scenario ``(B,)``
            (dynamic; it only gates switch eligibility). ``None`` = no cap.
        assign0: initial assignment, ``(N,)`` or ``(B, N)`` ints in
            ``[0, M)``; default round-robin ``i % M`` (the grand coalition
            when ``M == 1``).
        damping / max_iters / tol: inner Gauss-Seidel controls
            (:func:`~repro.core.asymmetric_batched.solve_heterogeneous`
            defaults and semantics).
        switch_tol: a partition is stable when no node's best eligible
            switch gains more than this (also the certification bar of
            :func:`verify_partition_batched`).
        max_switches: outer-iteration budget; default ``4·N·M``.

    Returns:
        A :class:`PartitionSolution`; ``converged`` marks scenarios whose
        dynamics reached a stable partition within budget.
    """
    costs, gammas, d_tab, cap, assign0, b, n, m = _prepare_partition_batch(
        costs, gammas, dur, n_coalitions, cap, assign0)
    if max_switches is None:
        max_switches = 4 * n * m
    assign, p, e_d, conv, switches, max_gain, inner = \
        _solve_partition_vmapped(
            costs, gammas, d_tab, cap, assign0, m=m,
            damping=float(damping), max_iters=int(max_iters),
            tol=float(tol), switch_tol=float(switch_tol),
            max_switches=int(max_switches))
    return PartitionSolution(costs=costs, gammas=gammas, assign=assign, p=p,
                             e_d=e_d, converged=conv, inner_converged=inner,
                             switches=switches, max_gain=max_gain,
                             n_coalitions=m)


# ---------------------------------------------------------------------------
# certification: switch gains + within-coalition deviation grid
# ---------------------------------------------------------------------------

def _verify_partition_one(costs, gammas, d_tab, assign, cap, p, *, m, grid,
                          damping, max_iters, tol):
    n = costs.shape[0]
    member = _member_matrix(assign, m)
    # within-coalition unilateral p-deviations on a grid: per coalition,
    # the same leave-one-out base/slope table as the asymmetric certifier,
    # gathered at each node's own coalition
    f_cs = jax.vmap(poibin_pmf_recursive)(p * member)          # (M, N+1)
    dd = d_tab[1:] - d_tab[:-1]
    loo = jax.vmap(jax.vmap(poibin_pmf_loo, in_axes=(None, 0)))(
        f_cs, jnp.broadcast_to(p, (m, n)))                     # (M, N, N+1)
    base = loo[:, :, :-1] @ d_tab[:-1]                         # (M, N)
    slope = loo[:, :, :-1] @ dd
    base_i = base[assign, jnp.arange(n)]                       # (N,)
    slope_i = slope[assign, jnp.arange(n)]
    gridv = jnp.linspace(P_MIN, 1.0, grid).astype(p.dtype)
    u_dev = (-(base_i[:, None] + gridv[None, :] * slope_i[:, None])
             - gammas[:, None] * log_aoi(gridv)[None, :]
             - costs[:, None] * gridv[None, :])                # (N, G)
    u_eq = (-(base_i + p * slope_i) - gammas * log_aoi(p) - costs * p)
    dev_p = jnp.max(u_dev - u_eq[:, None])
    # coalition-switch deviations: the dynamics' own gain table
    gain, _, _, _ = _candidate_gains(costs, gammas, d_tab, assign, cap, m=m,
                                     damping=damping, max_iters=max_iters,
                                     tol=tol)
    return jnp.maximum(jnp.maximum(dev_p, jnp.max(gain)), 0.0)


@functools.partial(jax.jit, static_argnames=(
    "m", "grid", "damping", "max_iters", "tol"))
def _verify_partition_vmapped(costs, gammas, d_tab, assign, cap, p, *, m,
                              grid, damping, max_iters, tol):
    fn = functools.partial(_verify_partition_one, m=m, grid=grid,
                           damping=damping, max_iters=max_iters, tol=tol)
    return jax.vmap(fn)(costs, gammas, d_tab, assign, cap, p)


def verify_partition_batched(
    costs: jax.Array,
    gammas: jax.Array,
    dur: DurationModel | jax.Array,
    assign: jax.Array,
    p: jax.Array,
    *,
    n_coalitions: int,
    cap: jax.Array | int | None = None,
    grid: int = 64,
    damping: float = 0.5,
    max_iters: int = 200,
    tol: float = 1e-5,
) -> jax.Array:
    """Max profitable deviation per scenario (0 at a partition equilibrium).

    Two deviation classes are certified in one jitted program: every
    node's *within-coalition* participation deviation over a ``grid``
    (the asymmetric certifier restricted to the node's coalition) and
    every node's *coalition switch* (the re-solved hedonic gain table of
    the dynamics, eligibility — own coalition, cap — included). Returns
    ``(B,)``; a returned partition of :func:`solve_partition` with
    ``converged`` true certifies ≤ its ``switch_tol`` by construction on
    the switch class, and ≤ the inner solver's residual on the grid class.
    """
    costs, gammas, d_tab, cap, assign, b, n, m = _prepare_partition_batch(
        costs, gammas, dur, n_coalitions, cap, assign)
    p = jnp.broadcast_to(jnp.atleast_2d(jnp.asarray(p, d_tab.dtype)), (b, n))
    return _verify_partition_vmapped(
        costs, gammas, d_tab, assign, cap, p, m=m, grid=int(grid),
        damping=float(damping), max_iters=int(max_iters), tol=float(tol))


# ---------------------------------------------------------------------------
# social cost, per-coalition planner, PoA report
# ---------------------------------------------------------------------------

def _partition_social_cost_one(costs, d_tab, assign, p, *, m):
    member = _member_matrix(assign, m)
    sizes = jnp.sum(member, axis=1)
    e_d = jax.vmap(poibin_pmf_recursive)(p * member) @ d_tab     # (M,)
    # empty coalitions contribute 0·d(0) — the d_zero horizon never leaks
    return jnp.sum(sizes * e_d) + costs @ p


@functools.partial(jax.jit, static_argnames=("m",))
def _partition_social_cost_vmapped(costs, d_tab, assign, p, *, m):
    return jax.vmap(functools.partial(_partition_social_cost_one, m=m))(
        costs, d_tab, assign, p)


def partition_social_cost_batched(
    costs: jax.Array,
    dur: DurationModel | jax.Array,
    assign: jax.Array,
    p: jax.Array,
    *,
    n_coalitions: int,
) -> jax.Array:
    """``Σ_c |S_c|·E[D_{S_c}] + Σ_i c_i p_i`` per scenario, ``(B,)``."""
    costs, _, d_tab, _, assign, b, n, m = _prepare_partition_batch(
        costs, jnp.zeros_like(jnp.asarray(costs, jnp.float64)), dur,
        n_coalitions, None, assign)
    p = jnp.broadcast_to(jnp.atleast_2d(jnp.asarray(p, d_tab.dtype)), (b, n))
    return _partition_social_cost_vmapped(costs, d_tab, assign, p, m=m)


def _partition_planner_one(costs, d_tab, assign, p0, *, m, rounds):
    """Per-coalition corner coordinate descent of the partition's social
    cost (linear in each ``p_i`` with the others fixed — the corner is
    picked by the sign of ``|S_c|·∂E[D_c]/∂p_i + c_i``). Non-members of a
    coalition stay pinned at 0; descending from the equilibrium profile
    the cost is monotone non-increasing, so it lower-bounds the NE cost
    within the same partition (the PoA denominator)."""
    n = costs.shape[0]
    member = _member_matrix(assign, m)
    dd = d_tab[1:] - d_tab[:-1]
    sizes = jnp.sum(member, axis=1)
    size_i = sizes[assign]                       # |S_c| of node i's coalition

    def sweep(p):
        f_cs = jax.vmap(poibin_pmf_recursive)(p * member)       # (M, N+1)

        def node(carry, i):
            f_cs, p = carry
            c = assign[i]
            loo = poibin_pmf_loo(f_cs[c], p[i])
            slope = loo[:-1] @ dd
            corner = jnp.where(size_i[i] * slope + costs[i] >= 0.0,
                               P_MIN, 1.0)
            best = jnp.where(member[c, i], corner, 0.0)
            f_new = poibin_convolve(loo, best)
            return (f_cs.at[c].set(f_new), p.at[i].set(best)), \
                jnp.abs(best - p[i])

        (_, p_new), deltas = jax.lax.scan(node, (f_cs, p), jnp.arange(n))
        return p_new, jnp.max(deltas)

    def cond(state):
        _, delta, it = state
        return (delta > 0.0) & (it < rounds)

    def body(state):
        p, _, it = state
        p_new, delta = sweep(p)
        return p_new, delta, it + 1

    p, _, _ = jax.lax.while_loop(
        cond, body, (p0, jnp.asarray(jnp.inf, p0.dtype), jnp.asarray(0)))
    return p


@functools.partial(jax.jit, static_argnames=("m", "rounds"))
def _partition_planner_vmapped(costs, d_tab, assign, p0, *, m, rounds):
    return jax.vmap(functools.partial(_partition_planner_one, m=m,
                                      rounds=rounds))(costs, d_tab, assign,
                                                      p0)


def partition_planner_batched(
    costs: jax.Array,
    dur: DurationModel | jax.Array,
    assign: jax.Array,
    p0: jax.Array,
    *,
    n_coalitions: int,
    rounds: int = 20,
) -> jax.Array:
    """Coalition-level planner: jitted per-coalition corner descent.

    Holds the partition fixed and minimizes its social cost over the
    members' participation (each coordinate minimum is exact — see
    :func:`~repro.core.asymmetric_batched.planner_batched`; here the
    corner sign uses the *coalition* size). Started from the equilibrium
    profile it lower-bounds the equilibrium's cost. Returns ``(B, N)``.
    """
    costs, _, d_tab, _, assign, b, n, m = _prepare_partition_batch(
        costs, jnp.zeros_like(jnp.asarray(costs, jnp.float64)), dur,
        n_coalitions, None, assign)
    p0 = jnp.broadcast_to(jnp.atleast_2d(jnp.asarray(p0, d_tab.dtype)),
                          (b, n))
    return _partition_planner_vmapped(costs, d_tab, assign, p0, m=m,
                                      rounds=int(rounds))


@dataclasses.dataclass(frozen=True)
class PartitionPoA:
    """Partition NE + certification + planner benchmark for a batch."""

    solution: PartitionSolution
    deviation: jax.Array   # (B,) max profitable deviation at the partition
    ne_cost: jax.Array     # (B,) social cost of the equilibrium
    opt_p: jax.Array       # (B, N) planner profile (descent from the NE)
    opt_cost: jax.Array    # (B,)
    poa: jax.Array         # (B,) partition PoA ≥ 1

    @property
    def batch(self) -> int:
        return self.solution.batch


def partition_poa_report(
    costs: jax.Array,
    gammas: jax.Array,
    dur: DurationModel | jax.Array,
    *,
    n_coalitions: int,
    cap: jax.Array | int | None = None,
    verify_grid: int = 64,
    planner_rounds: int = 20,
    **solver_kwargs,
) -> PartitionPoA:
    """Solve, certify, and benchmark a batch of coalition games."""
    sol = solve_partition(costs, gammas, dur, n_coalitions=n_coalitions,
                          cap=cap, **solver_kwargs)
    inner_kw = {k: solver_kwargs[k] for k in ("damping", "max_iters", "tol")
                if k in solver_kwargs}
    dev = verify_partition_batched(sol.costs, sol.gammas, dur, sol.assign,
                                   sol.p, n_coalitions=n_coalitions, cap=cap,
                                   grid=verify_grid, **inner_kw)
    ne_cost = partition_social_cost_batched(sol.costs, dur, sol.assign,
                                            sol.p, n_coalitions=n_coalitions)
    opt_p = partition_planner_batched(sol.costs, dur, sol.assign, sol.p,
                                      n_coalitions=n_coalitions,
                                      rounds=planner_rounds)
    opt_cost = partition_social_cost_batched(sol.costs, dur, sol.assign,
                                             opt_p,
                                             n_coalitions=n_coalitions)
    poa = ne_cost / jnp.maximum(opt_cost, 1e-12)
    return PartitionPoA(solution=sol, deviation=dev, ne_cost=ne_cost,
                        opt_p=opt_p, opt_cost=opt_cost, poa=poa)


# ---------------------------------------------------------------------------
# Python reference oracle (kept verbatim; tests/test_property_coalition.py)
# ---------------------------------------------------------------------------

def _reference_subgame_ne(costs, gammas, d_tab, members, *, damping,
                          max_iters, tol):
    """Eager compact-subgame Gauss-Seidel: the simplest statement of the
    inner NE — pmfs are built from the coalition's members only (no
    masks), matching the engine's fixed points to solver tolerance."""
    import numpy as np

    members = list(members)
    p = {i: 0.5 for i in members}
    for _ in range(max_iters):
        delta = 0.0
        for i in members:
            others = jnp.asarray([p[j] for j in members if j != i],
                                 jnp.float64)
            pmf = np.asarray(poibin_pmf_recursive(others))   # (|S|,) support
            k = pmf.shape[0]
            dd = np.asarray(d_tab[1:k + 1]) - np.asarray(d_tab[:k])
            slope = -float(pmf @ dd)
            br = float(best_response_given_slope(
                jnp.asarray(slope), jnp.asarray(float(costs[i])),
                jnp.asarray(float(gammas[i]))))
            new_pi = (1.0 - damping) * p[i] + damping * br
            delta = max(delta, abs(new_pi - p[i]))
            p[i] = new_pi
        if delta < tol:
            break
    return p


def _reference_utility(costs, gammas, d_tab, members, p, i):
    """u_i at the compact subgame profile ``p`` (dict over ``members``)."""
    import numpy as np

    probs = jnp.asarray([p[j] for j in members], jnp.float64)
    pmf = np.asarray(poibin_pmf_recursive(probs))
    e_d = float(pmf @ np.asarray(d_tab[:pmf.shape[0]]))
    return (-e_d - float(gammas[i]) * float(log_aoi(jnp.asarray(p[i])))
            - float(costs[i]) * p[i])


def partition_equilibrium_reference(
    costs,
    gammas,
    dur: DurationModel | jax.Array,
    *,
    n_coalitions: int,
    cap: int | None = None,
    assign0=None,
    damping: float = 0.5,
    max_iters: int = 200,
    tol: float = 1e-5,
    switch_tol: float = 1e-6,
    max_switches: int | None = None,
):
    """Eager Python restatement of :func:`solve_partition` (the oracle).

    Both levels as plain loops over *compact* subgames: inner NEs are
    solved on each coalition's members only (list-of-indices, no masked
    fleet-width arrays), the outer loop re-solves every
    (node, coalition) candidate and applies the single best eligible
    switch — the same best-switch-first tie-breaking (row-major argmax
    over the (N, M) gain table) as the engine. Returns
    ``(assign, p, converged, switches)`` with ``assign`` a length-N list
    of ints and ``p`` a length-N list of floats (zeros are impossible:
    every node is always in some coalition).
    """
    import numpy as np

    d_tab = np.asarray(dur.table() if isinstance(dur, DurationModel)
                       else jnp.asarray(dur))
    costs = np.asarray(costs, np.float64)
    gammas = np.asarray(gammas, np.float64)
    n = costs.shape[0]
    m = int(n_coalitions)
    cap = n if cap is None else int(cap)
    if max_switches is None:
        max_switches = 4 * n * m
    assign = ([i % m for i in range(n)] if assign0 is None
              else [int(a) for a in assign0])

    def coalition_members(a, c):
        return [i for i in range(n) if a[i] == c]

    def solve_all(a):
        profiles = {}
        for c in range(m):
            profiles[c] = _reference_subgame_ne(
                costs, gammas, d_tab, coalition_members(a, c),
                damping=damping, max_iters=max_iters, tol=tol)
        return profiles

    switches = 0
    converged = False
    for _ in range(max_switches + 1):
        profiles = solve_all(assign)
        gain = np.full((n, m), -np.inf)
        sizes = [len(coalition_members(assign, c)) for c in range(m)]
        for i in range(n):
            c0 = assign[i]
            u_cur = _reference_utility(
                costs, gammas, d_tab, coalition_members(assign, c0),
                profiles[c0], i)
            for c in range(m):
                if c == c0 or sizes[c] >= cap:
                    continue
                joined = coalition_members(assign, c) + [i]
                p_cand = _reference_subgame_ne(
                    costs, gammas, d_tab, joined, damping=damping,
                    max_iters=max_iters, tol=tol)
                gain[i, c] = _reference_utility(
                    costs, gammas, d_tab, joined, p_cand, i) - u_cur
        flat = int(np.argmax(gain))
        best = gain.reshape(-1)[flat]
        if not best > switch_tol:
            converged = True
            break
        assign[flat // m] = flat % m
        switches += 1

    profiles = solve_all(assign)
    p = [profiles[assign[i]][i] for i in range(n)]
    return assign, p, converged, switches
