"""Symmetric Nash equilibria, centralized optimum, and Price of Anarchy.

The game (paper §III): players = N nodes, actions = participation
probabilities p_i ∈ [0, 1], utilities = eq. (11). By symmetry we search for
symmetric equilibria p* where p* is a global best response to the other
N-1 nodes playing p* (paper eq. 12 states the first-order condition; we use
the full global-best-response definition so corner equilibria at p → 0 — the
Tragedy of the Commons collapse — are found too).

PoA (eq. 13) compares the worst-cost NE against the centralized optimum,
with cost = E[D] + c·p (the AoI incentive is a transfer; see utility.py).

Numerics: grid scan + vectorized utility evaluation (the whole utility is a
closed-form JAX function of p), then local golden-section refinement of best
responses, then damped fixed-point iteration cross-checked by direct
enumeration of BR fixed points on the grid. ``solve_game`` delegates the
end-to-end pipeline to the batched fixed-shape solver in
:mod:`repro.mechanisms.batched` (B = 1 of one jitted XLA program); the
scalar entry points below are kept as the slow-but-simple oracles the
batched solver is tested against.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.duration import DurationModel
from repro.core.utility import (
    UtilityParams,
    social_cost,
    social_utility,
    symmetric_player_utility,
)

__all__ = [
    "GameSolution",
    "best_response",
    "own_marginal",
    "solve_symmetric_ne",
    "centralized_optimum",
    "price_of_anarchy",
    "solve_game",
]

P_MIN = 1e-3  # p=0 exactly makes AoI/horizon math singular; the paper's
P_MAX = 1.0   # "p -> 0" collapse is represented by the grid's lowest cell.
GRID = 2000


def _p_grid(n: int = GRID) -> jnp.ndarray:
    return jnp.linspace(P_MIN, P_MAX, n)


def best_response(
    p_sym: float,
    params: UtilityParams,
    dur: DurationModel,
    grid: jnp.ndarray | None = None,
) -> tuple[float, float]:
    """Global best response of one node to the others all playing ``p_sym``.

    Returns (argmax p_i, utility at argmax). Vectorized over the action grid;
    exact because u_i is *linear* in p_i given the others (see
    symmetric_player_utility) apart from the concave -γ·log(AoI) and linear
    -c·p terms — so the grid only needs to localize a 1-D maximum.
    """
    g = _p_grid() if grid is None else grid
    u = jax.vmap(lambda pi: symmetric_player_utility(pi, jnp.asarray(p_sym),
                                                     params, dur))(g)
    i = int(jnp.argmax(u))
    # golden-section refine inside the bracketing cells (utility is smooth)
    lo = float(g[max(i - 1, 0)])
    hi = float(g[min(i + 1, g.shape[0] - 1)])
    f = lambda x: float(symmetric_player_utility(
        jnp.asarray(x), jnp.asarray(p_sym), params, dur))
    invphi = (np.sqrt(5.0) - 1.0) / 2.0
    a, b = lo, hi
    c_, d_ = b - invphi * (b - a), a + invphi * (b - a)
    fc, fd = f(c_), f(d_)
    for _ in range(40):
        if fc > fd:
            b, d_, fd = d_, c_, fc
            c_ = b - invphi * (b - a)
            fc = f(c_)
        else:
            a, c_, fc = c_, d_, fd
            d_ = a + invphi * (b - a)
            fd = f(d_)
    x = 0.5 * (a + b)
    return x, f(x)


@dataclasses.dataclass
class GameSolution:
    """All symmetric equilibria plus the centralized benchmark."""

    equilibria: list[float]
    ne_costs: list[float]
    opt_p: float
    opt_cost: float
    poa: float
    params: UtilityParams


def own_marginal(
    params: UtilityParams,
    dur: DurationModel,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """φ(p) = ∂u_i/∂p_i evaluated at the symmetric profile p_i = p_-i = p.

    This is exactly the paper's eq. (12) restricted to symmetric profiles.
    Computed by jax.grad through the Poisson-Binomial decomposition in
    ``symmetric_player_utility``.
    """
    g = jax.grad(lambda pi, ps: symmetric_player_utility(pi, ps, params, dur),
                 argnums=0)
    return lambda p: g(p, p)


def solve_symmetric_ne(
    params: UtilityParams,
    dur: DurationModel,
    grid_size: int = 800,
) -> list[float]:
    """Enumerate symmetric NEs as roots of φ(p) = ∂u_i/∂p_i|_sym plus corners.

    Why roots suffice: given the others at p, u_i(p_i) is *linear* in p_i in
    its duration and cost terms and strictly concave in the γ·AoI term. So if
    φ(p*) = 0 the symmetric action p* is a (for γ>0: the unique; for γ=0: an
    indifference-supported mixed) global best response — i.e. an NE. Corner
    equilibria: p = P_MIN is an NE iff φ(P_MIN) ≤ 0 (nobody wants to raise
    participation — the Tragedy-of-the-Commons collapse); p = 1 is an NE iff
    φ(1) ≥ 0.
    """
    phi = own_marginal(params, dur)
    grid = jnp.linspace(P_MIN, P_MAX, grid_size)
    vals = np.asarray(jax.vmap(phi)(grid))
    if not np.all(np.isfinite(vals)):
        raise FloatingPointError("non-finite marginal utility on the grid")
    nes: list[float] = []
    if vals[0] <= 0.0:
        nes.append(float(grid[0]))
    if vals[-1] >= 0.0:
        nes.append(float(grid[-1]))
    sign = np.sign(vals)
    for i in np.nonzero(sign[:-1] * sign[1:] < 0)[0]:
        lo, hi = float(grid[i]), float(grid[i + 1])
        flo = float(vals[i])
        for _ in range(60):  # bisection
            mid = 0.5 * (lo + hi)
            fm = float(phi(jnp.asarray(mid)))
            if fm == 0.0 or hi - lo < 1e-10:
                lo = hi = mid
                break
            if (fm > 0) == (flo > 0):
                lo, flo = mid, fm
            else:
                hi = mid
        root = 0.5 * (lo + hi)
        if not any(abs(root - e) < 1e-4 for e in nes):
            nes.append(root)
    return sorted(nes)


def centralized_optimum(
    params: UtilityParams,
    dur: DurationModel,
    grid_size: int = 2000,
) -> tuple[float, float]:
    """Symmetric p minimizing the social cost E[D] + c*p. Returns (p*, cost)."""
    g = _p_grid(grid_size)
    costs = jax.vmap(lambda p: social_cost(p, params, dur))(g)
    i = int(jnp.argmin(costs))
    return float(g[i]), float(costs[i])


def price_of_anarchy(
    equilibria: list[float],
    opt_cost: float,
    params: UtilityParams,
    dur: DurationModel,
    cap: float = 1e6,
) -> tuple[float, list[float]]:
    """Eq. (13): worst-NE social cost over optimal social cost."""
    if not equilibria:
        return float("inf"), []
    costs = [float(social_cost(jnp.asarray(p), params, dur))
             for p in equilibria]
    worst = max(costs)
    poa = worst / max(opt_cost, 1e-12)
    return min(poa, cap), costs


def solve_game(
    params: UtilityParams,
    dur: DurationModel,
    ne_grid: int = 400,
) -> GameSolution:
    """End-to-end: equilibria + optimum + PoA for one (gamma, c) setting.

    Delegates to the batched solver in :mod:`repro.mechanisms.batched`
    (B = 1 of its one-XLA-program pipeline): identical corner-NE semantics
    (p = P_MIN iff φ(P_MIN) ≤ 0, p = P_MAX iff φ(P_MAX) ≥ 0), sign-change
    root finding of φ, and the eq. (13) PoA against the grid-refined
    centralized optimum. Repeated calls with the same grid sizes hit the
    jit cache, so scalar callers get the batched speed too.
    """
    if dur.n_nodes != params.n_nodes:
        raise ValueError(f"duration model is for N={dur.n_nodes}, "
                         f"params have N={params.n_nodes}")
    # Lazy import: repro.mechanisms depends on this module at import time.
    from repro.mechanisms.batched import solve_batched

    sol = solve_batched(jnp.asarray([params.gamma]),
                        jnp.asarray([params.cost]), dur, ne_grid=ne_grid)
    return GameSolution(equilibria=sol.equilibria_list(0),
                        ne_costs=sol.ne_costs_list(0),
                        opt_p=float(sol.opt_p[0]),
                        opt_cost=float(sol.opt_cost[0]),
                        poa=float(sol.poa[0]), params=params)
