"""Pytree checkpointing to .npz (no orbax offline).

Flattens any params/opt-state pytree with '/'-joined key paths, saves arrays
with numpy, and restores into the exact original structure. Includes step /
round / round-robin retention metadata for the FL round loop.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_META = "_checkpoint_meta"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz has no bf16: store as f32 (lossless); restore_checkpoint
            # casts back to the dtype of the `like` tree.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: dict | None = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat = _flatten(tree)
    flat[_META] = np.frombuffer(
        json.dumps({"step": step, **(metadata or {})}).encode(), dtype=np.uint8)
    np.savez(path, **flat)
    _gc(directory, keep)
    return path


def restore_checkpoint(directory: str, like: Any, step: int | None = None
                       ) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (shapes/dtypes preserved)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(bytes(data[_META].tobytes()).decode())
        flat = {k: data[k] for k in data.files if k != _META}
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_k, leaf in leaves_like:
        key = "/".join(_path_str(p) for p in path_k)
        arr = flat[key]
        restored.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), restored), meta


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        int(m.group(1)) for f in os.listdir(directory)
        if (m := re.match(r"ckpt_(\d+)\.npz$", f)))
    for s in steps[:-keep]:
        os.remove(os.path.join(directory, f"ckpt_{s:08d}.npz"))
