"""`SweepService` — the long-running in-process scenario-sweep server.

The design-time twin of a production FL control plane: clients submit
schema-versioned requests (:mod:`repro.serve.schema`), the service queues
them, groups compatible rows, pads each dispatch onto the bucketing
ladder (:mod:`repro.serve.bucketing`), runs the repo's existing jitted
batched engines, and streams per-request responses with latency metadata.

Three properties the test harness pins (``tests/test_serve.py``,
``tests/test_serve_bucketing.py``):

* **Parity** — a request served through a padded bucket returns results
  bitwise-equal to calling the engine directly on the unpadded inputs:
  padding lanes are edge-replicas (:func:`repro.launch.sharding.pad_batch`)
  sliced away before assembly, and each bucket's program is AOT-lowered
  from the *same* jitted callable the direct path runs.
* **Compiled-program caching** — programs are cached per
  :class:`~repro.serve.bucketing.Bucket` (family, N, padded batch,
  statics, backend, mesh). A cache hit re-uses the compiled executable;
  the per-bucket ``compile`` stats in :meth:`SweepService.stats` prove the
  second same-bucket request compiles nothing.
* **Total validation** — every traced shape and static argument derives
  from fields validated at :meth:`SweepService.submit`; malformed payloads
  raise typed :class:`~repro.serve.schema.RequestError` and can never
  crash a trace.

Observability rides :mod:`repro.obs`: pass an
:class:`~repro.obs.EventSink` to stream ``serve.request`` /
``serve.dispatch`` / ``serve.complete`` events, and read
:meth:`SweepService.stats` for cache hit rates, padding overhead,
per-bucket compile/cost accounting and the kernel-dispatch counters.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

import repro.core  # noqa: F401  (enables x64 — the engines' dtype contract)
from repro.core.duration import theoretical_duration
from repro.core.energy import J_PER_WH, EnergyParams
from repro.launch.sharding import pad_batch
from repro.obs import EventSink
from repro.obs.export import timing_stats
from repro.obs.trace import _merge_cost
from repro.serve.bucketing import (DEFAULT_MAX_BATCH, Bucket, bucket_for,
                                   group_key, padding_overhead)
from repro.serve.schema import (CalibrateRequest, CampaignRequest,
                                NESolveRequest, Request, RequestError,
                                Response, parse_request)

__all__ = ["SweepService"]


@dataclasses.dataclass
class _Pending:
    rid: int
    request: Request
    t_submit: float
    t_dispatch: float | None = None


@dataclasses.dataclass
class _Program:
    """One AOT-compiled bucket program + its compile accounting."""

    bucket: Bucket
    compiled: Any
    lower_s: float
    compile_s: float
    flops: float
    bytes_accessed: float
    calls: int = 0

    def stats(self) -> dict[str, Any]:
        return {"lower_s": round(self.lower_s, 4),
                "compile_s": round(self.compile_s, 4),
                "flops": self.flops,
                "bytes_accessed": self.bytes_accessed,
                "calls": self.calls}


def _f64(shape: tuple) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float64)


class SweepService:
    """Persistent padded/bucketed NE + calibration + campaign server.

    Args:
        backend: kernel backend baked into the campaign merge
            (``None``/``"ref"`` keep the bitwise jnp path, ``"pallas"``
            the fused kernel; see :mod:`repro.kernels.ops`).
        mesh: optional :class:`jax.sharding.Mesh` — NE-solve and campaign
            buckets shard their (padded) batch over the mesh's data axes
            exactly like the offline engines; calibrate buckets always run
            unsharded (their grid rows are cheap). Bucket batch rungs are
            padded up to shard divisibility.
        batch_axis: mesh axis override, as in the offline engines.
        max_batch: top rung of the batch-padding ladder (per dispatch).
        task: the :class:`repro.federated.tasks.FLTask` campaign requests
            train (default: :func:`~repro.federated.tasks.synthetic_mlp_task`).
        opt: the optimizer for campaign local training (default SGD 0.15).
        sink: optional :class:`repro.obs.EventSink` receiving request
            lifecycle events.
    """

    def __init__(self, *, backend: str | None = None, mesh=None,
                 batch_axis=None, max_batch: int = DEFAULT_MAX_BATCH,
                 task=None, opt=None, sink: EventSink | None = None):
        self.backend = backend
        self.mesh = mesh
        self.batch_axis = batch_axis
        self.max_batch = int(max_batch)
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self._task = task
        self._opt = opt
        self.sink = sink

        self._queue: deque[_Pending] = deque()
        self._next_rid = 0
        self._programs: dict[Bucket, _Program] = {}
        self._dur_tables: dict[tuple, jax.Array] = {}
        self._rates = EnergyParams()
        self._engines: dict[tuple, Any] = {}   # un-jitted campaign builders

        # counters
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.dispatches = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.rows_real = 0
        self.rows_padded = 0
        self._by_kind: dict[str, int] = {}
        self._latencies_s: list[float] = []

        if mesh is not None:
            from repro.launch.sharding import (scenario_batch_spec,
                                               spec_axis_size)
            spec = scenario_batch_spec(0, mesh, axis=batch_axis)
            self._shards = spec_axis_size(mesh, spec)
            self._mesh_axes: tuple | None = (self._shards,)
        else:
            self._shards = 1
            self._mesh_axes = None

    # -- public api ----------------------------------------------------------

    def submit(self, payload: Any) -> int:
        """Validate and enqueue one request; returns its server ``rid``.

        Raises:
            RequestError: typed rejection — the request never enters the
                queue and no engine is touched.
        """
        try:
            req = parse_request(payload)
        except RequestError:
            self.rejected += 1
            raise
        rid = self._next_rid
        self._next_rid += 1
        self.submitted += 1
        self._by_kind[req.kind] = self._by_kind.get(req.kind, 0) + 1
        self._queue.append(_Pending(rid=rid, request=req,
                                    t_submit=time.perf_counter()))
        if self.sink is not None:
            self.sink.emit("serve.request", rid=rid, kind=req.kind,
                           n=req.n)
        return rid

    def poll(self) -> list[Response]:
        """Run one scheduling cycle: drain the queue, dispatch every group,
        return the completed responses in dispatch-completion order (which
        interleaves request families and may differ from submit order —
        pinned in ``tests/test_serve.py``)."""
        done: list[Response] = []
        while self._queue:
            pending = list(self._queue)
            self._queue.clear()
            groups: dict[tuple, list[_Pending]] = {}
            for pen in pending:
                groups.setdefault(group_key(pen.request), []).append(pen)
            for key, pens in groups.items():
                done.extend(self._dispatch_group(key[0], pens))
        return done

    def serve(self, payloads: Sequence[Any]) -> list[Response]:
        """Submit a batch of raw payloads and poll to completion.

        Malformed payloads become ``ok=False`` responses (typed error
        bodies) instead of raising, so mixed-quality workloads — the
        closed-loop load generator's — stream through uniformly.
        """
        errors: list[Response] = []
        for payload in payloads:
            try:
                self.submit(payload)
            except RequestError as e:
                rid = self._next_rid
                self._next_rid += 1
                kind = payload.get("kind") if isinstance(payload, dict) \
                    else None
                errors.append(Response(
                    rid=rid, kind=kind if kind in ("ne_solve", "calibrate",
                                                   "campaign") else "unknown",
                    ok=False, error=e.to_dict()))
        return self.poll() + errors

    def stats(self) -> dict[str, Any]:
        """Serving counters + per-bucket compile accounting (JSON-able)."""
        from repro.kernels import ops as kernel_ops

        total = self.cache_hits + self.cache_misses
        out: dict[str, Any] = {
            "requests": {"submitted": self.submitted,
                         "rejected": self.rejected,
                         "completed": self.completed,
                         "by_kind": dict(self._by_kind)},
            "dispatches": self.dispatches,
            "rows": {"real": self.rows_real, "padded": self.rows_padded},
            "padding_overhead": round(
                padding_overhead(self.rows_real, self.rows_padded), 4),
            "cache": {"hits": self.cache_hits, "misses": self.cache_misses,
                      "hit_rate": round(self.cache_hits / total, 4)
                      if total else 0.0,
                      "programs": len(self._programs)},
            "compile": {p.bucket.label: p.stats()
                        for p in self._programs.values()},
            "kernel_dispatch": kernel_ops.dispatch_stats(),
        }
        if self._latencies_s:
            out["latency"] = timing_stats(self._latencies_s)
        return out

    def close(self) -> None:
        if self.sink is not None:
            self.sink.flush()

    def __enter__(self) -> "SweepService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- program cache -------------------------------------------------------

    def _program(self, bucket: Bucket, lower) -> _Program:
        """Fetch-or-compile the bucket's executable; counts hits/misses."""
        prog = self._programs.get(bucket)
        if prog is not None:
            self.cache_hits += 1
            return prog
        self.cache_misses += 1
        t0 = time.perf_counter()
        lowered = lower()
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        cost = {}
        try:
            cost = _merge_cost(compiled.cost_analysis())
        except Exception:
            pass
        prog = _Program(bucket=bucket, compiled=compiled,
                        lower_s=t_lower, compile_s=t_compile,
                        flops=cost.get("flops", 0.0),
                        bytes_accessed=cost.get("bytes accessed", 0.0))
        self._programs[bucket] = prog
        if self.sink is not None:
            self.sink.emit("serve.compile", bucket=bucket.label,
                           lower_s=round(t_lower, 4),
                           compile_s=round(t_compile, 4))
        return prog

    def _run(self, prog: _Program, *args) -> Any:
        prog.calls += 1
        return prog.compiled(*args)

    # -- shared helpers ------------------------------------------------------

    def _dur_table(self, spec, n: int) -> jax.Array:
        key = (spec, n)
        tab = self._dur_tables.get(key)
        if tab is None:
            if spec.table is not None:
                tab = jnp.asarray(spec.table, jnp.float64)
            else:
                tab = theoretical_duration(
                    n, d_inf=spec.d_inf, slope=spec.slope,
                    horizon=spec.horizon).table()
            self._dur_tables[key] = tab
        return tab

    def _mesh_sharding(self):
        from jax.sharding import NamedSharding

        from repro.launch.sharding import scenario_batch_spec
        spec = scenario_batch_spec(0, self.mesh, axis=self.batch_axis)
        return NamedSharding(self.mesh, spec)

    def _emit_dispatch(self, bucket: Bucket, rows: int, hit: bool) -> None:
        self.dispatches += 1
        self.rows_real += rows
        self.rows_padded += bucket.batch
        if self.sink is not None:
            self.sink.emit("serve.dispatch", bucket=bucket.label,
                           rows=rows, padded=bucket.batch, cache_hit=hit)

    def _finish(self, pen: _Pending, bucket: Bucket,
                result: dict[str, Any]) -> Response:
        now = time.perf_counter()
        latency_s = now - pen.t_submit
        self._latencies_s.append(latency_s)
        self.completed += 1
        resp = Response(
            rid=pen.rid, kind=pen.request.kind, ok=True, result=result,
            id=pen.request.id, bucket=bucket.label,
            latency_us=latency_s * 1e6,
            queue_us=((pen.t_dispatch or now) - pen.t_submit) * 1e6)
        if self.sink is not None:
            self.sink.emit("serve.complete", rid=pen.rid, kind=resp.kind,
                           bucket=bucket.label,
                           latency_us=round(resp.latency_us, 1))
        return resp

    # -- dispatch ------------------------------------------------------------

    def _dispatch_group(self, family: str, pens: list[_Pending]
                        ) -> list[Response]:
        t_dispatch = time.perf_counter()
        for pen in pens:
            pen.t_dispatch = t_dispatch
        if family == "ne":
            return self._dispatch_ne(pens)
        if family == "sym":
            return self._dispatch_calibrate(pens)
        if family == "campaign":
            return self._dispatch_campaign(pens)
        raise AssertionError(f"unknown family {family!r}")

    # .. heterogeneous NE ....................................................

    def _dispatch_ne(self, pens: list[_Pending]) -> list[Response]:
        req0: NESolveRequest = pens[0].request
        n = req0.n
        damping, max_iters, tol, grid = (float(req0.damping),
                                         int(req0.max_iters),
                                         float(req0.tol),
                                         int(req0.verify_grid))
        out: list[Response] = []
        for start_end in _chunks(len(pens), self.max_batch):
            chunk = pens[start_end[0]:start_end[1]]
            rows = len(chunk)
            bucket = bucket_for(req0, rows, max_batch=self.max_batch,
                                backend=None, mesh_axes=self._mesh_axes)
            costs = jnp.asarray([p.request.costs for p in chunk],
                                jnp.float64)
            gammas = jnp.asarray([p.request.gammas for p in chunk],
                                 jnp.float64)
            d_tab = jnp.stack([self._dur_table(p.request.dur, n)
                               for p in chunk])
            p0 = jnp.full((rows, n), 0.5, jnp.float64)
            b = bucket.batch
            args = tuple(pad_batch(a, rows, b)
                         for a in (costs, gammas, d_tab, p0))

            solve_bucket = dataclasses.replace(
                bucket, family="ne/solve", statics=(damping, max_iters, tol))
            verify_bucket = dataclasses.replace(
                bucket, family="ne/verify", statics=(grid,))
            hit = solve_bucket in self._programs

            shapes = (_f64((b, n)), _f64((b, n)), _f64((b, n + 1)),
                      _f64((b, n)))
            if self.mesh is None:
                from repro.core.asymmetric_batched import (_solve_vmapped,
                                                           _verify_vmapped)
                solve = self._program(solve_bucket, lambda: _solve_vmapped
                                      .lower(*shapes, damping=damping,
                                             max_iters=max_iters, tol=tol))
                verify = self._program(verify_bucket, lambda: _verify_vmapped
                                       .lower(*shapes, grid=grid))
            else:
                import functools

                from repro.core.asymmetric_batched import (_gs_fixed_point,
                                                           _verify_one)
                sharding = self._mesh_sharding()

                def lower_solve():
                    fn = functools.partial(_gs_fixed_point, damping=damping,
                                           max_iters=max_iters, tol=tol)
                    return jax.jit(jax.vmap(fn), in_shardings=sharding,
                                   out_shardings=sharding).lower(*shapes)

                def lower_verify():
                    fn = functools.partial(_verify_one, grid=grid)
                    return jax.jit(jax.vmap(fn), in_shardings=sharding,
                                   out_shardings=sharding).lower(*shapes)

                solve = self._program(solve_bucket, lower_solve)
                verify = self._program(verify_bucket, lower_verify)

            self._emit_dispatch(bucket, rows, hit)
            p, conv, iters = self._run(solve, *args)
            dev = self._run(verify, args[0], args[1], args[2], p)
            p, conv = np.asarray(p[:rows]), np.asarray(conv[:rows])
            iters, dev = np.asarray(iters[:rows]), np.asarray(dev[:rows])
            for i, pen in enumerate(chunk):
                out.append(self._finish(pen, bucket, {
                    "p": [float(x) for x in p[i]],
                    "converged": bool(conv[i]),
                    "iters": int(iters[i]),
                    "deviation": float(dev[i]),
                }))
        return out

    # .. symmetric γ* calibration ............................................

    def _dispatch_calibrate(self, pens: list[_Pending]) -> list[Response]:
        req0: CalibrateRequest = pens[0].request
        n = req0.n
        d_tab = self._dur_table(req0.dur, n)
        # flatten: each request expands into its γ-grid rows
        row_gammas: list[np.ndarray] = []
        row_costs: list[np.ndarray] = []
        spans: list[tuple[int, int]] = []
        pos = 0
        for pen in pens:
            r: CalibrateRequest = pen.request
            g = r.gamma0 + np.linspace(0.0, r.gamma_max, r.grid)
            row_gammas.append(g)
            row_costs.append(np.full(r.grid, r.cost))
            spans.append((pos, pos + r.grid))
            pos += r.grid
        gam = np.concatenate(row_gammas)
        cos = np.concatenate(row_costs)

        poas = np.empty(pos)
        worst = np.empty(pos)
        opt_p = np.empty(pos)
        opt_cost = np.empty(pos)
        last_bucket: Bucket | None = None
        for start, end in _chunks(pos, self.max_batch):
            rows = end - start
            bucket = bucket_for(req0, rows, max_batch=self.max_batch)
            last_bucket = bucket
            b = bucket.batch
            gammas = pad_batch(jnp.asarray(gam[start:end], jnp.float64),
                               rows, b)
            costs = pad_batch(jnp.asarray(cos[start:end], jnp.float64),
                              rows, b)
            solve_bucket = dataclasses.replace(bucket, family="sym/solve")
            hit = solve_bucket in self._programs

            from repro.mechanisms.batched import _solve_batched
            prog = self._program(solve_bucket, lambda: _solve_batched.lower(
                _f64((b,)), _f64((b,)), _f64((n + 1,)),
                ne_grid=req0.ne_grid, opt_grid=req0.opt_grid, max_roots=4,
                bisect_iters=60, golden_iters=40))
            self._emit_dispatch(bucket, rows, hit)
            sol = self._run(prog, gammas, costs, d_tab)
            poas[start:end] = np.asarray(sol["poa"][:rows])
            worst[start:end] = np.asarray(sol["worst_ne"][:rows])
            opt_p[start:end] = np.asarray(sol["opt_p"][:rows])
            opt_cost[start:end] = np.asarray(sol["opt_cost"][:rows])

        out = []
        for pen, (start, end) in zip(pens, spans):
            r = pen.request
            g = gam[start:end]
            p_req = poas[start:end]
            ok = np.isfinite(p_req) & (p_req <= r.target_poa)
            if ok.any():
                first = int(np.argmax(ok))
                achieved = True
            else:
                finite = np.where(np.isfinite(p_req), p_req, np.inf)
                first = int(np.argmin(finite))
                achieved = False
            out.append(self._finish(pen, last_bucket, {
                "gamma_star": float(g[first]),
                "poa": float(p_req[first]),
                "achieved": achieved,
                "grid": int(r.grid),
                "p_ne": float(worst[start + first]),
                "opt_p": float(opt_p[start + first]),
                "opt_cost": float(opt_cost[start + first]),
            }))
        return out

    # .. FedAvg campaigns ....................................................

    def _campaign_task(self):
        if self._task is None:
            from repro.federated.tasks import synthetic_mlp_task
            self._task = synthetic_mlp_task()
        if self._opt is None:
            from repro.optim import sgd
            self._opt = sgd(0.15)
        return self._task, self._opt

    def _campaign_engine(self, n: int, statics: tuple):
        """The un-jitted→jitted :func:`build_campaign` engine per bucket
        family (shared across batch rungs — jit re-lowers per shape)."""
        key = (n, statics, self.backend)
        engine = self._engines.get(key)
        if engine is None:
            from repro.federated.campaign import build_campaign
            from repro.federated.simulation import FLConfig
            rounds, local_steps, bpc, target_acc, consecutive = statics
            task, opt = self._campaign_task()
            fl = FLConfig(n_clients=n, local_steps=local_steps,
                          batch_per_client=bpc, max_rounds=rounds,
                          target_acc=target_acc, consecutive=consecutive)
            engine = build_campaign(fl, *task.campaign_args(), opt,
                                    backend=self.backend, mesh=self.mesh,
                                    batch_axis=self.batch_axis)
            self._engines[key] = engine
        return engine

    def _dispatch_campaign(self, pens: list[_Pending]) -> list[Response]:
        req0: CampaignRequest = pens[0].request
        n = req0.n
        statics = (req0.rounds, req0.local_steps, req0.batch_per_client,
                   req0.target_acc, req0.consecutive)
        e_part_default = float(self._rates.e_participant_j)
        e_idle_default = float(self._rates.e_idle_j)
        out: list[Response] = []
        for start, end in _chunks(len(pens), self.max_batch):
            chunk = pens[start:end]
            rows = len(chunk)
            bucket = bucket_for(req0, rows, max_batch=self.max_batch,
                                backend=self.backend,
                                mesh_axes=self._mesh_axes)
            b = bucket.batch
            p = pad_batch(jnp.asarray([c.request.p for c in chunk],
                                      jnp.float64), rows, b)
            seeds = pad_batch(jnp.asarray([c.request.seed for c in chunk],
                                          jnp.uint32), rows, b)
            e_part = pad_batch(jnp.asarray(
                [c.request.e_participant_j if c.request.e_participant_j
                 is not None else e_part_default for c in chunk],
                jnp.float64), rows, b)
            e_idle = pad_batch(jnp.asarray(
                [c.request.e_idle_j if c.request.e_idle_j is not None
                 else e_idle_default for c in chunk], jnp.float64), rows, b)

            run_bucket = dataclasses.replace(bucket, family="campaign/run")
            hit = run_bucket in self._programs
            engine = self._campaign_engine(n, statics)
            prog = self._program(
                run_bucket, lambda: engine.lower(p, seeds, e_part, e_idle))
            self._emit_dispatch(bucket, rows, hit)
            res = self._run(prog, p, seeds, e_part, e_idle)
            res = jax.tree.map(lambda leaf: leaf[:rows], res)

            tracker, ledger, aoi = res["tracker"], res["ledger"], res["aoi"]
            converged_at = np.asarray(tracker.converged_at)
            per_node_j = np.asarray(ledger.per_node_j)
            counts = np.asarray(ledger.participation_counts)
            led_rounds = np.asarray(ledger.rounds)
            mean_aoi = np.asarray(aoi.mean_aoi)
            accs = np.asarray(res["accs"])
            max_rounds = statics[0]
            for i, pen in enumerate(chunk):
                conv = bool(converged_at[i] >= 0)
                realized = int(converged_at[i]) + 1 if conv else max_rounds
                denom = max(int(led_rounds[i]), 1)
                out.append(self._finish(pen, bucket, {
                    "converged": conv,
                    "rounds": realized,
                    "energy_wh": float(per_node_j[i].sum() / J_PER_WH),
                    "final_acc": float(accs[i, -1]),
                    "mean_aoi": float(mean_aoi[i]),
                    "participation_rate": float(
                        (counts[i] / denom).mean()),
                }))
        return out


def _chunks(total: int, size: int) -> list[tuple[int, int]]:
    """[(start, end), …] slices of at most ``size`` covering ``total``."""
    return [(s, min(s + size, total)) for s in range(0, total, size)]
