"""Scenario-sweep-as-a-service: the in-process NE/calibration/campaign
server.

The paper's control plane, made persistent: data-collector deployments
don't solve one game — they stream scenario batches (fleet sizes, cost
draws, incentive targets) against long-lived solvers. :mod:`repro.serve`
wraps the repo's jitted batched engines in a request/response service:

* :mod:`repro.serve.schema` — the versioned (``repro.serve/v1``) request/
  response wire format with total validation (typed
  :class:`~repro.serve.schema.RequestError`, never a trace-time crash);
* :mod:`repro.serve.bucketing` — the padding/bucketing policy mapping
  ragged traffic onto a closed set of compiled shapes;
* :mod:`repro.serve.service` — :class:`~repro.serve.service.SweepService`,
  the queue + dispatch + AOT-compiled-program cache + latency/obs layer.

Quickstart::

    from repro.serve import SweepService
    svc = SweepService()
    svc.submit({"schema": "repro.serve/v1", "kind": "ne_solve",
                "costs": [0.05, 0.1, 0.2], "gammas": 1.5})
    [resp] = svc.poll()
    assert resp.ok and resp.result["converged"]
"""
from repro.serve.bucketing import (DEFAULT_MAX_BATCH, Bucket, batch_rung,
                                   bucket_for, chunk_rows, group_key,
                                   padding_overhead)
from repro.serve.schema import (KINDS, SCHEMA, CalibrateRequest,
                                CampaignRequest, DurationSpec, NESolveRequest,
                                Request, RequestError, Response,
                                parse_request)
from repro.serve.service import SweepService

__all__ = [
    "SCHEMA", "KINDS", "DurationSpec", "NESolveRequest", "CalibrateRequest",
    "CampaignRequest", "Request", "RequestError", "Response",
    "parse_request", "Bucket", "DEFAULT_MAX_BATCH", "batch_rung",
    "bucket_for", "chunk_rows", "group_key", "padding_overhead",
    "SweepService",
]
