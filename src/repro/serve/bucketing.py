"""Padding/bucketing policy for the scenario-sweep service.

The jitted batched engines are fixed-shape programs: every distinct
``(batch, N, statics)`` combination is its own XLA compile. Serving ad-hoc
request traffic therefore needs a *bucketing policy* that maps ragged
request batches onto a small, closed set of compiled shapes:

* **The node axis is never padded.** Padding N would change the game (an
  extra node shifts every Poisson-binomial pmf), so a request's exact N is
  part of its bucket identity. Requests only share a compiled program when
  their games have the same N.
* **The batch axis is padded to a geometric ladder.** Scenario rows are
  embarrassingly parallel under ``vmap``, so padding lanes (edge-replicas
  via :func:`repro.launch.sharding.pad_batch`) change nothing about the
  real lanes — results are sliced back to the real rows, and the padded
  program is reused for every batch size that rounds up to the same rung.
  The ladder is geometric (1, 2, 4, …, ``max_batch``): at most
  ``log2(max_batch)+1`` compiles per (family, N, statics) bucket, and
  padding overhead is bounded by 50% of a dispatch in the worst case.
* **Oversize groups chunk.** More rows than ``max_batch`` dispatch as
  multiple full-ladder chunks (the compiled-program cache makes the repeat
  dispatches free).

Bucket selection is a pure function of the validated request and the row
count — deterministic, pinned by ``tests/test_serve_bucketing.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.serve.schema import (CalibrateRequest, CampaignRequest,
                                NESolveRequest, Request)

__all__ = ["DEFAULT_MAX_BATCH", "Bucket", "batch_rung", "bucket_for",
           "chunk_rows", "padding_overhead"]

DEFAULT_MAX_BATCH = 64


def batch_rung(rows: int, *, max_batch: int = DEFAULT_MAX_BATCH) -> int:
    """Smallest ladder rung >= ``rows`` (capped at ``max_batch``).

    >>> [batch_rung(r) for r in (1, 2, 3, 5, 17, 64, 200)]
    [1, 2, 4, 8, 32, 64, 64]
    """
    if rows < 1:
        raise ValueError(f"rows must be >= 1, got {rows}")
    rung = 1
    while rung < rows and rung < max_batch:
        rung *= 2
    return min(rung, max_batch)


def chunk_rows(rows: int, *, max_batch: int = DEFAULT_MAX_BATCH) -> list[int]:
    """Split ``rows`` into dispatch chunk sizes (full rungs, then the tail).

    >>> chunk_rows(150, max_batch=64)
    [64, 64, 22]
    """
    out = []
    while rows > 0:
        take = min(rows, max_batch)
        out.append(take)
        rows -= take
    return out


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Identity of one compiled program in the service cache.

    ``family`` names the engine stage (``ne/solve``, ``ne/verify``,
    ``sym/solve``, ``campaign/run``), ``n`` the unpadded node count,
    ``batch`` the padded ladder rung, ``statics`` the engine's static
    arguments (a hashable tuple — part of the traced program), and
    ``backend``/``mesh_axes`` the dispatch context.
    """

    family: str
    n: int
    batch: int
    statics: tuple
    backend: str | None = None
    mesh_axes: tuple | None = None

    @property
    def label(self) -> str:
        parts = [self.family, f"n{self.n}", f"b{self.batch}"]
        if self.backend:
            parts.append(self.backend)
        if self.mesh_axes:
            parts.append("mesh=" + "x".join(map(str, self.mesh_axes)))
        return "/".join(parts)


def _statics_for(req: Request) -> tuple:
    """The static (trace-baked) arguments a request's engine needs."""
    if isinstance(req, NESolveRequest):
        return (float(req.damping), int(req.max_iters), float(req.tol),
                int(req.verify_grid))
    if isinstance(req, CalibrateRequest):
        return (int(req.ne_grid), int(req.opt_grid))
    if isinstance(req, CampaignRequest):
        return (int(req.rounds), int(req.local_steps),
                int(req.batch_per_client), float(req.target_acc),
                int(req.consecutive))
    raise TypeError(f"not a request: {type(req).__name__}")


_FAMILY = {NESolveRequest: "ne", CalibrateRequest: "sym",
           CampaignRequest: "campaign"}


def bucket_for(req: Request, rows: int, *,
               max_batch: int = DEFAULT_MAX_BATCH,
               backend: str | None = None,
               mesh_axes: tuple | None = None) -> Bucket:
    """The compiled-program bucket serving ``rows`` rows of this request's
    family. Deterministic: same request fields + row count → same bucket."""
    batch = batch_rung(rows, max_batch=max_batch)
    if mesh_axes:
        # shard-divisibility: the mesh's data axes must divide the rung
        import math
        shards = math.prod(mesh_axes)
        batch = ((batch + shards - 1) // shards) * shards
    return Bucket(family=_FAMILY[type(req)], n=req.n, batch=batch,
                  statics=_statics_for(req), backend=backend,
                  mesh_axes=mesh_axes)


def padding_overhead(real_rows: int, padded_rows: int) -> float:
    """Wasted-lane fraction of a dispatch (0 when the rung fits exactly)."""
    if padded_rows <= 0:
        return 0.0
    return (padded_rows - real_rows) / padded_rows


def group_key(req: Request) -> tuple[Any, ...]:
    """Requests with equal group keys may share one dispatch.

    Finer than the bucket: rows in one *dispatch* must also agree on the
    values an engine takes once per call rather than once per row — the
    shared duration table of the symmetric solver — while the *program*
    cache only keys on shapes + statics.
    """
    if isinstance(req, NESolveRequest):
        return ("ne", req.n, _statics_for(req))
    if isinstance(req, CalibrateRequest):
        return ("sym", req.n, _statics_for(req), req.dur)
    if isinstance(req, CampaignRequest):
        # energy rates are per-row traced inputs, not dispatch-shared
        return ("campaign", req.n, _statics_for(req))
    raise TypeError(f"not a request: {type(req).__name__}")
