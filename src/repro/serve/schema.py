"""Versioned request/response schema for the scenario-sweep service.

Every payload crossing the :class:`repro.serve.SweepService` boundary is a
plain JSON-able dict wrapped in the ``repro.serve/v1`` envelope, mirroring
the ``repro.obs/v1`` artifact convention: a ``schema`` string, a ``kind``
discriminator, and kind-specific fields. Three request families map onto
the repo's three jitted batched engines:

=============  =====================================================
``kind``       engine
=============  =====================================================
``ne_solve``   :func:`repro.core.asymmetric_batched.solve_heterogeneous`
               (+ jitted certification) — one heterogeneous NE per
               request.
``calibrate``  :func:`repro.mechanisms.batched.solve_batched` — the
               request expands into a γ-grid of symmetric scenarios
               and the smallest γ meeting ``target_poa`` is returned
               (grid-resolution γ*, the serving twin of
               :func:`repro.mechanisms.aoi_reward.calibrate_gamma`).
``campaign``   :func:`repro.federated.campaign.run_campaigns` — one
               FedAvg campaign scenario per request on the service's
               task.
=============  =====================================================

Validation is strict and **total**: :func:`parse_request` either returns a
frozen request dataclass or raises a :class:`RequestError` carrying a
stable machine-readable ``code`` (and usually the offending ``field``).
Nothing escapes validation unchecked — every value that later determines a
traced shape or a static argument is type- and range-checked here, so a
malformed payload can never surface as a trace-time crash inside an engine
(the contract fuzzed by ``tests/test_serve.py``).

Round-trip contract: ``parse_request(req.to_dict()) == req`` for every
valid request, and ``to_dict()`` is canonical — defaults are materialized,
so two requests that solve the same scenario serialize identically.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping

__all__ = [
    "SCHEMA",
    "KINDS",
    "N_MAX",
    "CAMPAIGN_N_MAX",
    "GRID_MAX",
    "RequestError",
    "DurationSpec",
    "NESolveRequest",
    "CalibrateRequest",
    "CampaignRequest",
    "Request",
    "Response",
    "parse_request",
]

SCHEMA = "repro.serve/v1"
KINDS = ("ne_solve", "calibrate", "campaign")

#: hard caps on traced shapes a request can demand (DoS guard: these bound
#: every compiled-program bucket the service can be asked to create).
N_MAX = 512          # nodes per game
CAMPAIGN_N_MAX = 64  # clients per campaign
ROUNDS_MAX = 500     # campaign scan length
GRID_MAX = 1025      # γ-grid rows a calibrate request may expand into
ITERS_MAX = 2000     # solver iteration ceilings


class RequestError(ValueError):
    """A request failed validation — typed, never a trace-time crash.

    Attributes:
        code: stable machine-readable discriminator (``bad_schema``,
            ``bad_kind``, ``missing_field``, ``unknown_field``,
            ``bad_type``, ``bad_value``, ``too_large``).
        field: the offending field name, when one is identifiable.
    """

    def __init__(self, code: str, message: str, *, field: str | None = None):
        super().__init__(message)
        self.code = code
        self.field = field
        self.message = message

    def to_dict(self) -> dict[str, Any]:
        """The JSON error body an error :class:`Response` carries."""
        out: dict[str, Any] = {"code": self.code, "message": self.message}
        if self.field is not None:
            out["field"] = self.field
        return out


# ---------------------------------------------------------------------------
# field validators
# ---------------------------------------------------------------------------

def _is_num(v: Any) -> bool:
    # bool is an int subclass but "participation = True" is a payload bug.
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _num(obj: Mapping, field: str, default=None, *, lo=None, hi=None,
         lo_open=False, finite=True) -> float:
    v = obj.get(field, default)
    if v is None:
        raise RequestError("missing_field", f"{field!r} is required",
                           field=field)
    if not _is_num(v):
        raise RequestError("bad_type", f"{field!r} must be a number, "
                           f"got {type(v).__name__}", field=field)
    v = float(v)
    if finite and not math.isfinite(v):
        raise RequestError("bad_value", f"{field!r} must be finite",
                           field=field)
    if lo is not None and (v <= lo if lo_open else v < lo):
        op = ">" if lo_open else ">="
        raise RequestError("bad_value", f"{field!r} must be {op} {lo}, "
                           f"got {v}", field=field)
    if hi is not None and v > hi:
        raise RequestError("bad_value", f"{field!r} must be <= {hi}, "
                           f"got {v}", field=field)
    return v


def _int(obj: Mapping, field: str, default=None, *, lo=None,
         hi=None) -> int:
    v = obj.get(field, default)
    if v is None:
        raise RequestError("missing_field", f"{field!r} is required",
                           field=field)
    if not isinstance(v, int) or isinstance(v, bool):
        raise RequestError("bad_type", f"{field!r} must be an integer, "
                           f"got {type(v).__name__}", field=field)
    if lo is not None and v < lo:
        raise RequestError("bad_value", f"{field!r} must be >= {lo}, "
                           f"got {v}", field=field)
    if hi is not None and v > hi:
        code = "too_large" if hi in (N_MAX, CAMPAIGN_N_MAX, ROUNDS_MAX,
                                     GRID_MAX, ITERS_MAX) else "bad_value"
        raise RequestError(code, f"{field!r} must be <= {hi}, got {v}",
                           field=field)
    return int(v)


def _vec(obj: Mapping, field: str, *, n=None, lo=None, hi=None,
         lo_open=False, max_len=N_MAX) -> tuple[float, ...]:
    v = obj.get(field)
    if v is None:
        raise RequestError("missing_field", f"{field!r} is required",
                           field=field)
    if not isinstance(v, (list, tuple)):
        raise RequestError("bad_type", f"{field!r} must be a list, "
                           f"got {type(v).__name__}", field=field)
    if len(v) == 0:
        raise RequestError("bad_value", f"{field!r} must be non-empty",
                           field=field)
    if len(v) > max_len:
        raise RequestError("too_large", f"{field!r} has {len(v)} entries, "
                           f"cap is {max_len}", field=field)
    if n is not None and len(v) != n:
        raise RequestError("bad_value", f"{field!r} must have {n} entries, "
                           f"got {len(v)}", field=field)
    out = []
    for i, x in enumerate(v):
        if not _is_num(x) or not math.isfinite(float(x)):
            raise RequestError("bad_value", f"{field}[{i}] must be a finite "
                               f"number", field=field)
        x = float(x)
        if lo is not None and (x <= lo if lo_open else x < lo):
            op = ">" if lo_open else ">="
            raise RequestError("bad_value", f"{field}[{i}] must be {op} "
                               f"{lo}, got {x}", field=field)
        if hi is not None and x > hi:
            raise RequestError("bad_value", f"{field}[{i}] must be <= {hi}, "
                               f"got {x}", field=field)
        out.append(x)
    return tuple(out)


def _check_fields(obj: Mapping, allowed: frozenset) -> None:
    for k in obj:
        if k not in allowed:
            raise RequestError("unknown_field", f"unknown field {k!r} "
                               f"(allowed: {sorted(allowed)})", field=str(k))


def _request_id(obj: Mapping) -> str | int | None:
    rid = obj.get("id")
    if rid is not None and not isinstance(rid, (str, int)) \
            or isinstance(rid, bool):
        raise RequestError("bad_type", "'id' must be a string or integer",
                           field="id")
    return rid


# ---------------------------------------------------------------------------
# duration spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DurationSpec:
    """How a request specifies its round-duration model d(k).

    Either the analytic surrogate (``d_inf``/``slope``/``horizon`` →
    :func:`repro.core.duration.theoretical_duration` at the request's N) or
    an explicit ``table`` of N+1 values d(0..N). Hashable, so the service
    can cache materialized tables per (spec, N).
    """

    d_inf: float = 35.0
    slope: float = 8.0
    horizon: float = 500.0
    table: tuple[float, ...] | None = None

    @staticmethod
    def parse(obj: Any, *, n: int) -> "DurationSpec":
        if obj is None:
            return DurationSpec()
        if not isinstance(obj, Mapping):
            raise RequestError("bad_type", "'dur' must be an object",
                               field="dur")
        _check_fields(obj, frozenset({"d_inf", "slope", "horizon", "table"}))
        if "table" in obj and obj["table"] is not None:
            if len(obj) > 1:
                raise RequestError("bad_value", "'dur.table' excludes the "
                                   "analytic fields", field="dur")
            tab = _vec({"table": obj["table"]}, "table", n=n + 1, lo=0.0,
                       max_len=N_MAX + 1)
            return DurationSpec(table=tab)
        return DurationSpec(
            d_inf=_num(obj, "d_inf", 35.0, lo=0.0, lo_open=True),
            slope=_num(obj, "slope", 8.0, lo=0.0),
            horizon=_num(obj, "horizon", 500.0, lo=0.0, lo_open=True))

    def to_dict(self) -> dict[str, Any]:
        if self.table is not None:
            return {"table": list(self.table)}
        return {"d_inf": self.d_inf, "slope": self.slope,
                "horizon": self.horizon}


# ---------------------------------------------------------------------------
# request families
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NESolveRequest:
    """One heterogeneous-NE solve: per-node costs/γ → certified profile."""

    costs: tuple[float, ...]
    gammas: tuple[float, ...]
    dur: DurationSpec
    damping: float = 0.5
    max_iters: int = 200
    tol: float = 1e-5
    verify_grid: int = 64
    id: str | int | None = None

    kind = "ne_solve"

    @property
    def n(self) -> int:
        return len(self.costs)

    def to_dict(self) -> dict[str, Any]:
        out = {"schema": SCHEMA, "kind": self.kind,
               "costs": list(self.costs), "gammas": list(self.gammas),
               "dur": self.dur.to_dict(), "damping": self.damping,
               "max_iters": self.max_iters, "tol": self.tol,
               "verify_grid": self.verify_grid}
        if self.id is not None:
            out["id"] = self.id
        return out


@dataclasses.dataclass(frozen=True)
class CalibrateRequest:
    """Smallest uniform AoI weight γ* hitting a PoA target (γ-grid scan)."""

    n_nodes: int
    cost: float
    dur: DurationSpec
    gamma0: float = 0.0
    target_poa: float = 1.05
    gamma_max: float = 5.0
    grid: int = 33
    ne_grid: int = 400
    opt_grid: int = 2000
    id: str | int | None = None

    kind = "calibrate"

    @property
    def n(self) -> int:
        return self.n_nodes

    def to_dict(self) -> dict[str, Any]:
        out = {"schema": SCHEMA, "kind": self.kind, "n_nodes": self.n_nodes,
               "cost": self.cost, "dur": self.dur.to_dict(),
               "gamma0": self.gamma0, "target_poa": self.target_poa,
               "gamma_max": self.gamma_max, "grid": self.grid,
               "ne_grid": self.ne_grid, "opt_grid": self.opt_grid}
        if self.id is not None:
            out["id"] = self.id
        return out


@dataclasses.dataclass(frozen=True)
class CampaignRequest:
    """One FedAvg campaign scenario on the service's task."""

    p: tuple[float, ...]          # per-node participation, length n_clients
    n_clients: int = 5
    rounds: int = 8
    local_steps: int = 1
    batch_per_client: int = 8
    target_acc: float = 0.73
    consecutive: int = 3
    seed: int = 0
    e_participant_j: float | None = None   # None: service default rates
    e_idle_j: float | None = None
    id: str | int | None = None

    kind = "campaign"

    @property
    def n(self) -> int:
        return self.n_clients

    def to_dict(self) -> dict[str, Any]:
        out = {"schema": SCHEMA, "kind": self.kind, "p": list(self.p),
               "n_clients": self.n_clients, "rounds": self.rounds,
               "local_steps": self.local_steps,
               "batch_per_client": self.batch_per_client,
               "target_acc": self.target_acc,
               "consecutive": self.consecutive, "seed": self.seed,
               "e_participant_j": self.e_participant_j,
               "e_idle_j": self.e_idle_j}
        if self.id is not None:
            out["id"] = self.id
        return out


Request = NESolveRequest | CalibrateRequest | CampaignRequest

_COMMON = frozenset({"schema", "kind", "id"})
_NE_FIELDS = _COMMON | frozenset({"costs", "gammas", "dur", "damping",
                                  "max_iters", "tol", "verify_grid"})
_CAL_FIELDS = _COMMON | frozenset({"n_nodes", "cost", "dur", "gamma0",
                                   "target_poa", "gamma_max", "grid",
                                   "ne_grid", "opt_grid"})
_CAMPAIGN_FIELDS = _COMMON | frozenset({
    "p", "n_clients", "rounds", "local_steps", "batch_per_client",
    "target_acc", "consecutive", "seed", "e_participant_j", "e_idle_j"})


def _parse_ne(obj: Mapping) -> NESolveRequest:
    _check_fields(obj, _NE_FIELDS)
    costs = _vec(obj, "costs", lo=0.0)
    n = len(costs)
    gammas_raw = obj.get("gammas", 0.0)
    if _is_num(gammas_raw):
        gammas = (float(gammas_raw),) * n
        if not math.isfinite(gammas[0]) or gammas[0] < 0.0:
            raise RequestError("bad_value", "'gammas' must be finite >= 0",
                               field="gammas")
    else:
        gammas = _vec(obj, "gammas", n=n, lo=0.0)
    return NESolveRequest(
        costs=costs, gammas=gammas,
        dur=DurationSpec.parse(obj.get("dur"), n=n),
        damping=_num(obj, "damping", 0.5, lo=0.0, hi=1.0, lo_open=True),
        max_iters=_int(obj, "max_iters", 200, lo=1, hi=ITERS_MAX),
        tol=_num(obj, "tol", 1e-5, lo=0.0, lo_open=True),
        verify_grid=_int(obj, "verify_grid", 64, lo=2, hi=GRID_MAX),
        id=_request_id(obj))


def _parse_calibrate(obj: Mapping) -> CalibrateRequest:
    _check_fields(obj, _CAL_FIELDS)
    n = _int(obj, "n_nodes", lo=2, hi=N_MAX)
    return CalibrateRequest(
        n_nodes=n,
        cost=_num(obj, "cost", lo=0.0),
        dur=DurationSpec.parse(obj.get("dur"), n=n),
        gamma0=_num(obj, "gamma0", 0.0, lo=0.0),
        target_poa=_num(obj, "target_poa", 1.05, lo=1.0, lo_open=True),
        gamma_max=_num(obj, "gamma_max", 5.0, lo=0.0, lo_open=True),
        grid=_int(obj, "grid", 33, lo=2, hi=GRID_MAX),
        ne_grid=_int(obj, "ne_grid", 400, lo=8, hi=10_000),
        opt_grid=_int(obj, "opt_grid", 2000, lo=8, hi=10_000),
        id=_request_id(obj))


def _parse_campaign(obj: Mapping) -> CampaignRequest:
    _check_fields(obj, _CAMPAIGN_FIELDS)
    n = _int(obj, "n_clients", 5, lo=1, hi=CAMPAIGN_N_MAX)
    p_raw = obj.get("p")
    if _is_num(p_raw):
        if not (0.0 < float(p_raw) <= 1.0):
            raise RequestError("bad_value", "'p' must be in (0, 1]",
                               field="p")
        p = (float(p_raw),) * n
    else:
        p = _vec(obj, "p", n=n, lo=0.0, hi=1.0, lo_open=True,
                 max_len=CAMPAIGN_N_MAX)
    e_part = obj.get("e_participant_j")
    e_idle = obj.get("e_idle_j")
    if e_part is not None:
        e_part = _num(obj, "e_participant_j", lo=0.0)
    if e_idle is not None:
        e_idle = _num(obj, "e_idle_j", lo=0.0)
    return CampaignRequest(
        p=p, n_clients=n,
        rounds=_int(obj, "rounds", 8, lo=1, hi=ROUNDS_MAX),
        local_steps=_int(obj, "local_steps", 1, lo=1, hi=100),
        batch_per_client=_int(obj, "batch_per_client", 8, lo=1, hi=1024),
        target_acc=_num(obj, "target_acc", 0.73, lo=0.0, hi=1.0,
                        lo_open=True),
        consecutive=_int(obj, "consecutive", 3, lo=1, hi=100),
        seed=_int(obj, "seed", 0, lo=0, hi=2**32 - 1),
        e_participant_j=e_part, e_idle_j=e_idle,
        id=_request_id(obj))


_PARSERS = {"ne_solve": _parse_ne, "calibrate": _parse_calibrate,
            "campaign": _parse_campaign}


def parse_request(obj: Any) -> Request:
    """Validate one request payload into its typed form (or raise).

    Raises:
        RequestError: with a stable ``code``/``field`` for every possible
            malformation — unknown kind, missing/unknown fields, wrong
            types, out-of-range values, shape caps. Any non-mapping input
            is ``bad_request``.
    """
    if not isinstance(obj, Mapping):
        raise RequestError("bad_request", "request must be a JSON object, "
                           f"got {type(obj).__name__}")
    schema = obj.get("schema", SCHEMA)
    if schema != SCHEMA:
        raise RequestError("bad_schema", f"schema {schema!r}, want "
                           f"{SCHEMA!r}", field="schema")
    kind = obj.get("kind")
    if kind not in _PARSERS:
        raise RequestError("bad_kind", f"kind {kind!r}, want one of "
                           f"{KINDS}", field="kind")
    return _PARSERS[kind](obj)


# ---------------------------------------------------------------------------
# responses
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Response:
    """One completed (or rejected) request, JSON-able via :meth:`to_dict`.

    ``result`` carries the kind-specific payload (profiles, γ*, campaign
    summary); ``error`` is a :meth:`RequestError.to_dict` body when
    ``ok`` is False. Serving metadata: ``bucket`` (the compiled-program
    bucket label that served it), ``latency_us`` (submit → result on
    host), ``queue_us`` (submit → dispatch).
    """

    rid: int
    kind: str
    ok: bool
    result: dict[str, Any] | None = None
    error: dict[str, Any] | None = None
    id: str | int | None = None
    bucket: str | None = None
    latency_us: float | None = None
    queue_us: float | None = None

    def to_dict(self) -> dict[str, Any]:
        out: dict[str, Any] = {"schema": SCHEMA, "rid": self.rid,
                               "kind": self.kind, "ok": self.ok}
        if self.id is not None:
            out["id"] = self.id
        if self.result is not None:
            out["result"] = self.result
        if self.error is not None:
            out["error"] = self.error
        if self.bucket is not None:
            out["bucket"] = self.bucket
        if self.latency_us is not None:
            out["latency_us"] = round(self.latency_us, 1)
        if self.queue_us is not None:
            out["queue_us"] = round(self.queue_us, 1)
        return out
