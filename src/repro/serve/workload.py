"""Synthetic request traffic for the sweep service.

One deterministic generator shared by the closed-loop load benchmark
(``benchmarks/serve_load.py``), the driver's demo mode
(``python -m repro.launch.serve_sweeps``) and the request-level tests: a
seeded mix of NE-solve / calibrate / campaign payloads over a small set of
fleet sizes (so traffic actually exercises the bucket ladder and the
program cache), with an optional fraction of malformed payloads to keep
the typed-rejection path hot.

Calibrate rows default to coarse ``ne_grid``/``opt_grid`` values — load
traffic measures the serving layer, not mechanism-design accuracy.
"""
from __future__ import annotations

import numpy as np

from repro.serve.schema import SCHEMA

__all__ = ["synthetic_workload"]

# kept intentionally small: CPU CI serves the full mixed workload
_NE_SIZES = (4, 6, 8)
_SYM_SIZES = (6, 10)
_CAMPAIGN_CLIENTS = 4
_CAMPAIGN_ROUNDS = 3


def _malformed(rng: np.random.Generator) -> dict:
    """A payload that must be rejected with a typed error."""
    bad = rng.integers(5)
    if bad == 0:
        return {"schema": "repro.serve/v999", "kind": "ne_solve",
                "costs": [0.1]}
    if bad == 1:
        return {"schema": SCHEMA, "kind": "teleport"}
    if bad == 2:
        return {"schema": SCHEMA, "kind": "ne_solve",
                "costs": [0.1, float("nan")]}
    if bad == 3:
        return {"schema": SCHEMA, "kind": "calibrate", "n_nodes": 6,
                "cost": 0.1, "grid": -3}
    return {"schema": SCHEMA, "kind": "campaign", "p": 0.5,
            "surprise": True}


def synthetic_workload(n_requests: int, *, seed: int = 0,
                       malformed_frac: float = 0.02,
                       campaign_frac: float = 0.03,
                       calibrate_frac: float = 0.15) -> list[dict]:
    """``n_requests`` raw payload dicts: mostly NE solves, a calibrate
    stream, a trickle of campaigns, and a few malformed payloads.

    Deterministic in ``seed``; families are interleaved (shuffled), so the
    queue exercises mixed-family grouping on every poll.
    """
    rng = np.random.default_rng(seed)
    payloads: list[dict] = []
    for i in range(n_requests):
        u = rng.random()
        if u < malformed_frac:
            payloads.append(_malformed(rng))
        elif u < malformed_frac + campaign_frac:
            payloads.append({
                "schema": SCHEMA, "kind": "campaign",
                "id": f"load-{i}",
                "p": [round(float(p), 3) for p in
                      rng.uniform(0.2, 0.9, _CAMPAIGN_CLIENTS)],
                "n_clients": _CAMPAIGN_CLIENTS,
                "rounds": _CAMPAIGN_ROUNDS,
                "seed": int(rng.integers(1 << 16)),
            })
        elif u < malformed_frac + campaign_frac + calibrate_frac:
            n = int(rng.choice(_SYM_SIZES))
            payloads.append({
                "schema": SCHEMA, "kind": "calibrate",
                "id": f"load-{i}", "n_nodes": n,
                "cost": round(float(rng.uniform(0.02, 0.3)), 4),
                "grid": 7, "gamma_max": 3.0,
                "ne_grid": 160, "opt_grid": 400,
            })
        else:
            n = int(rng.choice(_NE_SIZES))
            payloads.append({
                "schema": SCHEMA, "kind": "ne_solve",
                "id": f"load-{i}",
                "costs": [round(float(c), 4) for c in
                          rng.uniform(0.02, 0.4, n)],
                "gammas": round(float(rng.uniform(0.5, 2.5)), 3),
            })
    return payloads
