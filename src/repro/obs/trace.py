"""Span tracing + compile-vs-execute accounting for jitted programs.

Two complementary views of where the time goes:

* :class:`SpanTracer` — host-side ``perf_counter`` spans around the
  *phases* of a run (game solve, engine build, compile, warm sweep, …)
  with Chrome-trace export: load the emitted JSON in `Perfetto
  <https://ui.perfetto.dev>`_ (or ``chrome://tracing``) and read the
  timeline. Spans nest; each span can carry arbitrary JSON-able ``args``.
* :func:`compile_stats` — the *compiled program's* own accounting:
  ``jax.jit(fn).lower(...)`` / ``.compile()`` wall times split out
  (compile-vs-execute — the number the campaign-sweep "compile 27s" lines
  were eyeballing), plus XLA's lowered ``cost_analysis()`` FLOPs/bytes and
  ``memory_analysis()`` buffer sizes. These are *measured-program* numbers
  — what ``benchmarks/roofline.py`` and ``benchmarks/kernel_gap.py`` feed
  on instead of analytic guesses.

Inside jitted code, regions are annotated with ``jax.named_scope`` (pure
HLO metadata — zero runtime effect, shows up in XLA dumps and profiler
traces); the campaign/NE engines carry ``campaign/…`` and ``ne/…`` scopes.
"""
from __future__ import annotations

import contextlib
import json
import os
import pathlib
import threading
import time
from typing import Any, Callable

__all__ = ["SpanTracer", "compile_stats"]


class SpanTracer:
    """Nestable wall-clock spans with Chrome-trace (Perfetto) export.

    .. code-block:: python

        tracer = SpanTracer()
        with tracer.span("sweep", scenarios=32):
            with tracer.span("compile"):
                ...
        tracer.save("TRACE_sweep.json")   # load in ui.perfetto.dev

    A disabled tracer (``SpanTracer(enabled=False)``) is a no-op whose
    ``span`` still yields, so call sites never branch. Thread-safe: spans
    carry the recording thread's id as the trace ``tid``.
    """

    def __init__(self, enabled: bool = True, *, process_name: str = "repro"):
        self.enabled = enabled
        self.process_name = process_name
        self._events: list[dict[str, Any]] = []
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, **args: Any):
        """Record a complete ("X") trace event around the with-block."""
        if not self.enabled:
            yield self
            return
        start = self._now_us()
        try:
            yield self
        finally:
            end = self._now_us()
            with self._lock:
                self._events.append({
                    "name": name, "ph": "X", "ts": start,
                    "dur": end - start, "pid": os.getpid(),
                    "tid": threading.get_ident(),
                    "args": args or {},
                })

    def instant(self, name: str, **args: Any) -> None:
        """Record a zero-duration instant event (trace marker)."""
        if not self.enabled:
            return
        with self._lock:
            self._events.append({
                "name": name, "ph": "i", "ts": self._now_us(), "s": "p",
                "pid": os.getpid(), "tid": threading.get_ident(),
                "args": args or {},
            })

    @property
    def spans(self) -> list[dict[str, Any]]:
        """The recorded events (Chrome-trace dicts, µs timestamps)."""
        with self._lock:
            return list(self._events)

    def summary(self) -> dict[str, dict[str, float]]:
        """Total/count per span name (µs) — the quick textual view."""
        out: dict[str, dict[str, float]] = {}
        for ev in self.spans:
            if ev["ph"] != "X":
                continue
            s = out.setdefault(ev["name"], {"total_us": 0.0, "count": 0})
            s["total_us"] += ev["dur"]
            s["count"] += 1
        for s in out.values():
            s["total_us"] = round(s["total_us"], 1)
        return out

    def to_chrome_trace(self) -> dict[str, Any]:
        """The ``{"traceEvents": [...]}`` object Perfetto loads directly."""
        meta = [{
            "name": "process_name", "ph": "M", "pid": os.getpid(),
            "args": {"name": self.process_name},
        }]
        return {"traceEvents": meta + self.spans,
                "displayTimeUnit": "ms"}

    def save(self, path: str | os.PathLike) -> pathlib.Path:
        """Write the Chrome trace JSON; returns the path written."""
        p = pathlib.Path(path)
        if p.parent != pathlib.Path("."):
            p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_chrome_trace()) + "\n")
        return p


def _merge_cost(cost: Any) -> dict[str, float]:
    """Normalize ``cost_analysis()`` output across jax versions.

    Older jaxlibs return a list of per-computation dicts, newer a single
    dict; keys of interest are ``flops`` and ``bytes accessed``.
    """
    if cost is None:
        return {}
    dicts = cost if isinstance(cost, (list, tuple)) else [cost]
    merged: dict[str, float] = {}
    for d in dicts:
        if not isinstance(d, dict):
            continue
        for k, v in d.items():
            if isinstance(v, (int, float)):
                merged[k] = merged.get(k, 0.0) + float(v)
    return merged


def compile_stats(fn: Callable, *args: Any,
                  static_argnames: Any = None,
                  warmup: int = 1, iters: int = 10,
                  **kwargs: Any) -> dict[str, Any]:
    """Compile-vs-execute accounting for one jitted function + inputs.

    Lowers and compiles ``jax.jit(fn)`` explicitly (so trace/lower and
    XLA-compile wall times are split out of the usual first-call blur),
    reads the compiled executable's ``cost_analysis()`` /
    ``memory_analysis()``, then times ``iters`` synchronous executions.

    Returns a dict ready for an artifact's ``data``:

    ``{"lower_s", "compile_s", "execute": timing_stats-dict,
    "flops", "bytes_accessed", "cost_analysis": {...},
    "memory": {"argument_bytes", "output_bytes", "temp_bytes"}}``

    FLOPs/bytes are XLA's *post-optimization* estimates for the compiled
    module on this platform — real measured-program numbers (remat, fusion
    and interpret-mode overheads all show up), unlike the analytic
    intensities the kernel micro-bench labels carry.
    """
    import jax

    from repro.obs.export import timing_stats

    jitted = jax.jit(fn, static_argnames=static_argnames)
    t0 = time.perf_counter()
    lowered = jitted.lower(*args, **kwargs)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    cost = {}
    try:
        cost = _merge_cost(compiled.cost_analysis())
    except Exception:
        pass
    memory: dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
        memory = {
            "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
        }
    except Exception:
        pass

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(compiled(*args, **kwargs))
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args, **kwargs))
        samples.append(time.perf_counter() - t0)

    return {
        "lower_s": round(t_lower, 4),
        "compile_s": round(t_compile, 4),
        "execute": timing_stats(samples),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "cost_analysis": {k: v for k, v in cost.items()
                          if k in ("flops", "bytes accessed",
                                   "transcendentals")},
        "memory": memory,
    }
