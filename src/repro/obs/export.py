"""The one BENCH/trace artifact schema (``repro.obs/v1``).

Every benchmark emitter in this repo — ``benchmarks/campaign_sweep.py``,
``benchmarks/heterogeneous_campaign.py``, ``benchmarks/kernels_micro.py``,
``benchmarks/kernel_gap.py``, the obs smoke run — wraps its payload in the
same versioned envelope:

.. code-block:: json

    {
      "schema": "repro.obs/v1",
      "kind": "campaign_sweep",
      "meta": {
        "git_sha": "…", "jax": "0.4.37", "jaxlib": "0.4.36",
        "device_kind": "cpu", "platform": "cpu", "device_count": 1,
        "python": "3.11.9", "hostname": "…", "timestamp": "…",
        "seed": 1, "backend": "ref"
      },
      "data": { … }
    }

so artifacts from different runs/machines/backends are *comparable*: the
perf trajectory accumulates points with enough metadata to explain a jump.
Timings inside ``data`` use the :func:`timing_stats` shape —
``{"p50_us", "p95_us", "mean_us", "min_us", "max_us", "n"}`` — never a
bare single-sample number.

Validation is hand-rolled (no jsonschema dependency in the container):
:func:`validate_artifact` / :func:`validate_events_jsonl` return a list of
problems, and ``tools/obs_report.py --check`` turns them into a CI gate.
"""
from __future__ import annotations

import datetime
import json
import os
import pathlib
import socket
import subprocess
import sys
from typing import Any, Iterable, Sequence

SCHEMA = "repro.obs/v1"
EVENT_SCHEMA = "repro.obs.event/v1"

#: meta keys every artifact must carry (``seed``/``backend`` are optional —
#: not every artifact has a single one of either).
REQUIRED_META = ("git_sha", "jax", "jaxlib", "device_kind", "platform",
                 "timestamp")

_TIMING_KEYS = ("p50_us", "p95_us", "mean_us", "min_us", "max_us", "n")


def git_sha(repo_dir: str | os.PathLike | None = None) -> str:
    """Current commit sha (``+dirty`` suffixed), or ``"unknown"``."""
    cwd = str(repo_dir) if repo_dir else os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10).stdout.strip()
        if not sha:
            return "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd,
            capture_output=True, text=True, timeout=10).stdout.strip()
        return sha + ("+dirty" if dirty else "")
    except Exception:
        return "unknown"


def run_metadata(*, seed: int | None = None, backend: str | None = None,
                 **extra: Any) -> dict[str, Any]:
    """Stamp the run: git sha, jax/jaxlib versions, device kind, seed, …

    Imports jax lazily so schema validation (``obs_report --check``) stays
    usable in environments without an accelerator stack warmed up.
    """
    import jax
    import jaxlib

    dev = jax.devices()[0]
    meta: dict[str, Any] = {
        "git_sha": git_sha(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "platform": dev.platform,
        "device_count": jax.device_count(),
        "python": sys.version.split()[0],
        "hostname": socket.gethostname(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    if seed is not None:
        meta["seed"] = int(seed)
    if backend is not None:
        meta["backend"] = backend
    meta.update(extra)
    return meta


def timing_stats(samples_s: Sequence[float]) -> dict[str, float | int]:
    """p50/p95/mean/min/max (µs) + sample count from wall times in seconds.

    The schema's timing shape: a lone median hides multimodality (first-run
    caching, GC pauses) and a lone mean hides tails, so artifacts carry
    both plus the p95. Percentiles use linear interpolation on the sorted
    samples (numpy-free so the events path stays import-light).
    """
    if not samples_s:
        raise ValueError("timing_stats needs at least one sample")
    xs = sorted(float(s) * 1e6 for s in samples_s)
    n = len(xs)

    def pct(q: float) -> float:
        if n == 1:
            return xs[0]
        pos = q * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        return xs[lo] + (pos - lo) * (xs[hi] - xs[lo])

    return {
        "p50_us": round(pct(0.50), 3),
        "p95_us": round(pct(0.95), 3),
        "mean_us": round(sum(xs) / n, 3),
        "min_us": round(xs[0], 3),
        "max_us": round(xs[-1], 3),
        "n": n,
    }


def make_artifact(kind: str, data: dict[str, Any], *,
                  seed: int | None = None, backend: str | None = None,
                  **extra_meta: Any) -> dict[str, Any]:
    """Wrap a payload in the versioned envelope with fresh run metadata."""
    if not kind:
        raise ValueError("artifact kind must be a non-empty string")
    return {
        "schema": SCHEMA,
        "kind": kind,
        "meta": run_metadata(seed=seed, backend=backend, **extra_meta),
        "data": data,
    }


def write_artifact(path: str | os.PathLike, kind: str, data: dict[str, Any],
                   *, seed: int | None = None, backend: str | None = None,
                   **extra_meta: Any) -> dict[str, Any]:
    """:func:`make_artifact` + pretty-printed JSON to ``path``."""
    art = make_artifact(kind, data, seed=seed, backend=backend, **extra_meta)
    p = pathlib.Path(path)
    if p.parent != pathlib.Path("."):
        p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(art, indent=2, sort_keys=False) + "\n")
    return art


def _walk_timings(node: Any, path: str, problems: list[str]) -> None:
    """Any dict that *looks like* a timing block must be a complete one."""
    if isinstance(node, dict):
        keys = set(node)
        if keys & {"p50_us", "p95_us"}:
            missing = [k for k in _TIMING_KEYS if k not in keys]
            if missing:
                problems.append(
                    f"{path}: timing block missing {missing}")
            elif not all(isinstance(node[k], (int, float))
                         for k in _TIMING_KEYS):
                problems.append(f"{path}: non-numeric timing values")
        for k, v in node.items():
            _walk_timings(v, f"{path}.{k}", problems)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            _walk_timings(v, f"{path}[{i}]", problems)


def validate_artifact(obj: Any, *, path: str = "artifact") -> list[str]:
    """Schema-check one artifact object; returns a list of problems.

    Checks the envelope (schema string, kind, meta with
    :data:`REQUIRED_META`, dict data) and that every timing-shaped block
    anywhere in ``data`` carries the full p50/p95/mean/min/max/n set.
    """
    problems: list[str] = []
    if not isinstance(obj, dict):
        return [f"{path}: not a JSON object"]
    if obj.get("schema") != SCHEMA:
        problems.append(
            f"{path}: schema {obj.get('schema')!r}, want {SCHEMA!r}")
    if not isinstance(obj.get("kind"), str) or not obj.get("kind"):
        problems.append(f"{path}: missing/empty 'kind'")
    meta = obj.get("meta")
    if not isinstance(meta, dict):
        problems.append(f"{path}: missing 'meta' object")
    else:
        for key in REQUIRED_META:
            if key not in meta:
                problems.append(f"{path}: meta missing {key!r}")
    data = obj.get("data")
    if not isinstance(data, dict):
        problems.append(f"{path}: missing 'data' object")
    else:
        _walk_timings(data, f"{path}.data", problems)
    return problems


def validate_events_jsonl(lines: Iterable[str], *,
                          path: str = "events") -> list[str]:
    """Schema-check a JSONL event stream (one event object per line).

    Each line must parse, carry ``schema == "repro.obs.event/v1"``, a
    non-empty ``event`` name, a numeric ``ts_us`` host timestamp, and a
    monotonically non-decreasing ``seq`` sequence number.
    """
    problems: list[str] = []
    last_seq = -1
    n = 0
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        n += 1
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append(f"{path}:{i}: unparseable JSON ({e})")
            continue
        if ev.get("schema") != EVENT_SCHEMA:
            problems.append(f"{path}:{i}: schema {ev.get('schema')!r}, "
                            f"want {EVENT_SCHEMA!r}")
        if not isinstance(ev.get("event"), str) or not ev.get("event"):
            problems.append(f"{path}:{i}: missing 'event' name")
        if not isinstance(ev.get("ts_us"), (int, float)):
            problems.append(f"{path}:{i}: missing numeric 'ts_us'")
        seq = ev.get("seq")
        if not isinstance(seq, int):
            problems.append(f"{path}:{i}: missing integer 'seq'")
        elif seq < last_seq:
            problems.append(f"{path}:{i}: seq {seq} < previous {last_seq}")
        else:
            last_seq = seq
    if n == 0:
        problems.append(f"{path}: empty event stream")
    return problems
