"""`repro.obs` — observability for the jitted campaign/NE/kernel hot paths.

The repo's argument runs on measured quantities (per-node energy ledgers,
AoI trajectories, PoA sweeps, kernel timings), yet jitted programs are
opaque post-hoc: by the time a sweep returns, *where* the time and FLOPs
went is gone. This package makes the hot paths observable without touching
their semantics:

* :mod:`repro.obs.export` — the **one artifact schema** every BENCH/trace
  emitter uses: versioned envelope, run metadata (git sha, jax/jaxlib
  version, device kind, seed, backend), timings as p50/p95/mean. Validated
  by ``tools/obs_report.py --check``.
* :mod:`repro.obs.trace` — ``perf_counter`` span tracer with Chrome-trace
  (Perfetto-loadable) export, plus compile-vs-execute accounting for jitted
  functions (jit compile time + lowered ``cost_analysis()`` FLOPs/bytes).
* :mod:`repro.obs.events` — a host-side structured-event sink fed from
  *inside* jitted programs via ``jax.debug.callback``; events are JSONL
  lines with the same schema envelope.
* :mod:`repro.obs.metrics` — :class:`MetricStream`, the in-carry
  metric-stream buffer the campaign engine records per-round participation
  counts, merge norms, and ledger deltas into (a registered pytree, so it
  vmaps/scans like every other tracker).

The hard contract, pinned in ``tests/test_obs.py``: observability is **off
by default**, and ``ObsConfig(enabled=False)`` (or ``obs=None``) is a
strict no-op — the instrumented engines build the *identical* program and
all pre-existing bitwise-equality pins stay green. Even with ``enabled=
True`` the instrumentation only *adds* outputs (extra carry leaves, host
callbacks); it never perturbs an RNG stream or a computed value.

See ``docs/observability.md`` for the walkthrough.
"""
from __future__ import annotations

import dataclasses

from repro.obs.events import EventSink
from repro.obs.export import (SCHEMA, run_metadata, make_artifact,
                              write_artifact, validate_artifact,
                              validate_events_jsonl, timing_stats)
from repro.obs.metrics import MetricStream
from repro.obs.trace import SpanTracer, compile_stats

__all__ = [
    "ObsConfig",
    "EventSink",
    "MetricStream",
    "SpanTracer",
    "compile_stats",
    "SCHEMA",
    "run_metadata",
    "make_artifact",
    "write_artifact",
    "validate_artifact",
    "validate_events_jsonl",
    "timing_stats",
]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Static observability switches for the instrumented engines.

    All fields are *static* Python values: they select what program gets
    traced, exactly like ``churn``/``backend`` in
    :func:`repro.federated.campaign.build_campaign`. The master switch
    gates everything — ``ObsConfig()`` (or passing ``obs=None``) builds
    the uninstrumented program, bit-for-bit.

    Attributes:
        enabled: master switch (default off).
        metrics: record a :class:`MetricStream` in the scan carry
            (per-round participants, merge norm, ledger delta, accuracy).
            Pure extra outputs — cheap enough to leave on when ``enabled``.
        events: stream per-round events to ``sink`` from inside the jitted
            program via ``jax.debug.callback``. Host round-trips per round
            per scenario — for small instrumented runs, not timed sweeps.
        sink: the :class:`EventSink` receiving events (required when
            ``events=True``).
    """

    enabled: bool = False
    metrics: bool = True
    events: bool = False
    sink: EventSink | None = None

    def __post_init__(self):
        if self.enabled and self.events and self.sink is None:
            raise ValueError("ObsConfig(events=True) needs a sink")

    @property
    def record_metrics(self) -> bool:
        return self.enabled and self.metrics

    @property
    def emit_events(self) -> bool:
        return self.enabled and self.events and self.sink is not None
