"""Host-side structured event sink, fed from inside jitted programs.

:class:`EventSink` collects schema-versioned events
(``repro.obs.event/v1``) in memory and optionally appends them to a JSONL
file as they arrive. Two entry points:

* :meth:`EventSink.emit` — plain host-side emission (benchmark phases,
  run boundaries);
* :meth:`EventSink.tap` — **inside-jit** emission: stages a
  ``jax.debug.callback`` whose host half converts the runtime arrays to
  JSON-able scalars/lists and emits them. Under ``vmap`` the callback
  fires once per batch element (each event carries that element's
  values); ``ordered=True`` sequences events with program order but is
  only legal outside ``vmap`` (a JAX restriction).

The no-op contract: a disabled sink's ``tap`` stages **nothing** — the
traced program is byte-identical to the uninstrumented one, which is what
keeps the campaign engine's bitwise-equality pins green when observability
is off (``tests/test_obs.py``).

Events are host-visible only after the device work runs; call
:meth:`EventSink.flush` (which issues a ``jax.effects_barrier()``) before
reading ``events`` or closing the file.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time
from typing import Any, IO

__all__ = ["EventSink"]

from repro.obs.export import EVENT_SCHEMA


def _jsonable(v: Any) -> Any:
    """Convert a host-landed runtime value to a JSON-able python value."""
    import numpy as np

    arr = np.asarray(v)
    if arr.ndim == 0:
        item = arr.item()
        if isinstance(item, (bool, int, str)):
            return item
        return float(item)
    return arr.tolist()


class EventSink:
    """Append-only structured event stream (memory + optional JSONL file).

    Args:
        path: optional ``.jsonl`` file to append each event to as it
            arrives (one JSON object per line, artifact-schema'd).
        enabled: master switch; a disabled sink ignores ``emit`` and makes
            ``tap`` a strict no-op inside traced code.
    """

    def __init__(self, path: str | os.PathLike | None = None,
                 enabled: bool = True):
        self.enabled = enabled
        self.path = pathlib.Path(path) if path is not None else None
        self._events: list[dict[str, Any]] = []
        self._lock = threading.Lock()
        self._seq = 0
        self._file: IO[str] | None = None
        self._t0 = time.perf_counter()
        if self.path is not None and enabled:
            if self.path.parent != pathlib.Path("."):
                self.path.parent.mkdir(parents=True, exist_ok=True)
            # Append mode: two sinks sharing a path interleave whole records
            # instead of truncating each other's stream mid-file. Emitters
            # that want a fresh stream (the benchmark drivers) unlink the
            # file before constructing the sink.
            self._file = self.path.open("a")

    # -- host-side ----------------------------------------------------------

    def emit(self, event: str, /, **fields: Any) -> None:
        """Record one event now (host side)."""
        if not self.enabled:
            return
        with self._lock:
            record = {
                "schema": EVENT_SCHEMA,
                "event": event,
                "seq": self._seq,
                "ts_us": round((time.perf_counter() - self._t0) * 1e6, 1),
                **fields,
            }
            self._seq += 1
            self._events.append(record)
            if self._file is not None:
                # One write + flush per record: a line is either absent or
                # whole, and concurrent sinks on one path can't shear it.
                self._file.write(json.dumps(record) + "\n")
                self._file.flush()

    # -- inside-jit ---------------------------------------------------------

    def tap(self, event: str, /, *, ordered: bool = False,
            valid: Any = None, **arrays: Any) -> None:
        """Stage an event emission inside a traced program.

        Args:
            event: event name (static).
            ordered: sequence the callback with program order
                (``jax.debug.callback(ordered=True)``); required for
                strict intra-program ordering guarantees, but illegal
                under ``vmap`` — batched call sites use the default and
                rely on ``seq`` stamped at host arrival.
            valid: optional traced boolean *validity mask*. Events whose
                mask lands False on the host are dropped before emission —
                the hook the mesh-sharded campaign engine uses so the
                edge-padding replica lanes (scenario_id stamped -1) never
                appear in the event stream. ``None`` (default) emits
                unconditionally and stages the identical callback as
                before.
            arrays: traced (or concrete) values; they land on the host as
                numpy and are stored as scalars/lists.

        No-op (stages nothing) when the sink is disabled.
        """
        if not self.enabled:
            return
        import jax

        names = tuple(arrays)

        if valid is None:
            def _cb(*vals):
                self.emit(event, **{n: _jsonable(v)
                                    for n, v in zip(names, vals)})

            jax.debug.callback(_cb, *arrays.values(), ordered=ordered)
            return

        def _cb_masked(ok, *vals):
            import numpy as np

            if not bool(np.asarray(ok)):
                return
            self.emit(event, **{n: _jsonable(v)
                                for n, v in zip(names, vals)})

        jax.debug.callback(_cb_masked, valid, *arrays.values(),
                           ordered=ordered)

    # -- readout ------------------------------------------------------------

    def flush(self) -> None:
        """Drain pending device-side callbacks and sync the JSONL file."""
        if not self.enabled:
            return
        try:
            import jax

            jax.effects_barrier()
        except Exception:
            pass
        with self._lock:
            if self._file is not None:
                self._file.flush()

    def close(self) -> None:
        self.flush()
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None

    @property
    def events(self) -> list[dict[str, Any]]:
        """Snapshot of events received so far (call :meth:`flush` first)."""
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def __enter__(self) -> "EventSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
