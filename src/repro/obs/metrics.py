"""In-carry metric-stream buffer for jitted scan loops.

:class:`MetricStream` is a registered-dataclass pytree of fixed-shape
per-round buffers that rides a ``lax.scan`` carry next to the engine's
own trackers (ledger/convergence/AoI). Each active round appends one row
via a masked ``.at[cursor].set``; post-convergence no-op rounds leave the
buffer untouched (the engine wraps the update in the same leafwise-where
masking as every other tracker), so ``cursor`` lands exactly on the
realized round count.

It records *derived observables only* — participation counts, the merge
update norm, the round's ledger energy delta, validation accuracy — and
never touches an RNG stream or feeds back into the computation, which is
what keeps the instrumented engine's results bitwise-equal to the
uninstrumented one (pinned in ``tests/test_obs.py``).

``jax.vmap`` over scenarios adds a leading batch axis to every leaf, like
the other carry pytrees: a batched campaign returns a ``(B, R)``-leaved
stream.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["MetricStream", "merge_norm"]


def merge_norm(new_params, old_params) -> jax.Array:
    """Global L2 norm of a pytree update (fp32) — the merge-step metric.

    ``||new - old||_2`` over all leaves; a cheap convergence/health signal
    (a collapsing norm means the merge stopped moving; a spike flags a
    divergent round) that costs one reduction per leaf.
    """
    leaves = jax.tree.leaves(
        jax.tree.map(lambda n, o: jnp.sum(
            jnp.square(n.astype(jnp.float32) - o.astype(jnp.float32))),
            new_params, old_params))
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MetricStream:
    """Fixed-shape per-round metric buffers (scan-carry pytree).

    Attributes:
        cursor: scalar int32 — rows recorded so far (== realized rounds).
        participants: ``(R,)`` int32 — participation count per round.
        merge_norm: ``(R,)`` float32 — L2 norm of the FedAvg update.
        ledger_delta_j: ``(R,)`` float — round energy delta in Joules.
        accuracy: ``(R,)`` float32 — validation accuracy per round.

    Rows past ``cursor`` are zero. ``R`` is the static scan length
    (``max_rounds``).
    """

    cursor: jax.Array
    participants: jax.Array
    merge_norm: jax.Array
    ledger_delta_j: jax.Array
    accuracy: jax.Array

    @staticmethod
    def create(max_rounds: int) -> "MetricStream":
        return MetricStream(
            cursor=jnp.zeros((), jnp.int32),
            participants=jnp.zeros((max_rounds,), jnp.int32),
            merge_norm=jnp.zeros((max_rounds,), jnp.float32),
            ledger_delta_j=jnp.zeros((max_rounds,), jnp.float64),
            accuracy=jnp.zeros((max_rounds,), jnp.float32),
        )

    def record(self, *, participants: jax.Array, merge_norm: jax.Array,
               ledger_delta_j: jax.Array,
               accuracy: jax.Array) -> "MetricStream":
        """Append one row at ``cursor``; mask with the engine's ``active``
        select (like every other carry tracker) to make no-op rounds skip
        the append."""
        r = self.cursor
        return MetricStream(
            cursor=r + 1,
            participants=self.participants.at[r].set(
                jnp.asarray(participants, jnp.int32)),
            merge_norm=self.merge_norm.at[r].set(
                jnp.asarray(merge_norm, jnp.float32)),
            ledger_delta_j=self.ledger_delta_j.at[r].set(
                jnp.asarray(ledger_delta_j, self.ledger_delta_j.dtype)),
            accuracy=self.accuracy.at[r].set(
                jnp.asarray(accuracy, jnp.float32)),
        )

    @property
    def rounds(self) -> jax.Array:
        """Realized rounds recorded (``(B,)`` for a batched stream)."""
        return self.cursor

    def summary(self) -> dict[str, Any]:
        """JSON-able rollup for artifacts (host-side; handles batching).

        Per-round rows are reported up to the max cursor across the batch;
        scalars are means over recorded rows only.
        """
        import numpy as np

        cur = np.atleast_1d(np.asarray(self.cursor))
        r_max = int(cur.max())
        part = np.atleast_2d(np.asarray(self.participants))[:, :r_max]
        norm = np.atleast_2d(np.asarray(self.merge_norm))[:, :r_max]
        dj = np.atleast_2d(np.asarray(self.ledger_delta_j))[:, :r_max]
        acc = np.atleast_2d(np.asarray(self.accuracy))[:, :r_max]
        valid = (np.arange(r_max)[None, :] < cur[:, None])
        nv = np.maximum(valid.sum(), 1)
        return {
            "rounds": cur.tolist(),
            "mean_participants": round(float(
                (part * valid).sum() / nv), 3),
            "mean_merge_norm": round(float((norm * valid).sum() / nv), 5),
            "total_energy_j": round(float((dj * valid).sum()), 3),
            "final_accuracy": [round(float(a[max(c - 1, 0)]), 5)
                               for a, c in zip(acc, cur)],
            "per_round": {
                "participants": part.tolist(),
                "merge_norm": np.round(norm, 5).tolist(),
                "ledger_delta_j": np.round(dj, 3).tolist(),
                "accuracy": np.round(acc, 5).tolist(),
            },
        }
