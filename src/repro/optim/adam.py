"""AdamW — production default for the cluster training driver."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer

__all__ = ["adamw"]


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        updates = jax.tree.map(
            lambda m_, v_, p: -lr * ((m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
                                     + weight_decay * p.astype(jnp.float32)),
            m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init=init, update=update)
