"""From-scratch optimizers (no optax offline): SGD(+momentum) and AdamW."""
from repro.optim.base import Optimizer, apply_updates, clip_by_global_norm
from repro.optim.sgd import sgd
from repro.optim.adam import adamw
