"""SGD with optional momentum — the paper's local optimizer (E epochs/round)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer

__all__ = ["sgd"]


def sgd(lr: float = 0.01, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads)
        updates = jax.tree.map(lambda m: -lr * m, mu)
        return updates, {"mu": mu}

    return Optimizer(init=init, update=update)
