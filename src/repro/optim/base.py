"""Optimizer interface: init(params) -> state; update(grads, state, params).

Mirrors the optax GradientTransformation contract so examples read familiar,
but is self-contained (optax is not available offline).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "clip_by_global_norm", "apply_updates"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable          # params -> opt_state
    update: Callable        # (grads, opt_state, params) -> (updates, opt_state)


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)
