"""Whisper-style audio encoder-decoder transformer.

The mel-spectrogram + conv frontend is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, n_frames, d_model).
We implement sinusoidal positions, the bidirectional encoder, and the causal
decoder with cross-attention; decode caches self-attention KV plus the
once-computed cross-attention K/V per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import runtime

Params = dict


def _sinusoid(length: int, dim: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, dim, 2, jnp.float32) / dim)
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _init_xattn(cfg: ModelConfig, key):
    """Cross-attention: q from decoder, k/v from encoder output."""
    dtype = L._dtype(cfg.param_dtype)
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = L.split_tree(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = L.dense_init(k1, (d, h, hd), ("embed", "heads", "head"), dtype)
    p["wk"], s["wk"] = L.dense_init(k2, (d, h, hd), ("embed", "kv_heads", "head"), dtype)
    p["wv"], s["wv"] = L.dense_init(k3, (d, h, hd), ("embed", "kv_heads", "head"), dtype)
    p["wo"], s["wo"] = L.dense_init(k4, (h, hd, d), ("heads", "head", "embed"),
                                    dtype, in_axis_sizes=h * hd)
    return p, s


def _mha(cfg, q, k, v, mask):
    cdt = L._dtype(cfg.compute_dtype)
    d = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * d**-0.5
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _xattn_apply(cfg, p, x, enc_kv):
    """enc_kv: (k, v) precomputed from encoder output."""
    cdt = L._dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    k, v = enc_kv
    out = _mha(cfg, q, k.astype(cdt), v.astype(cdt), None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))


def xattn_kv(cfg, p, enc_out):
    cdt = L._dtype(cfg.compute_dtype)
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"].astype(cdt))
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"].astype(cdt))
    return k, v


# -- encoder ---------------------------------------------------------------


def init_encoder_block(cfg: ModelConfig, key):
    k1, k2 = L.split_tree(key, 2)
    p, s = {}, {}
    p["ln_attn"], s["ln_attn"] = L.init_norm(cfg, L._dtype(cfg.param_dtype))
    p["ln_mlp"], s["ln_mlp"] = L.init_norm(cfg, L._dtype(cfg.param_dtype))
    p["attn"], s["attn"] = _init_xattn(cfg, k1)   # self-attn, full (bidir)
    p["mlp"], s["mlp"] = L.init_mlp(cfg, k2)
    return p, s


def encoder_block_apply(cfg, p, x):
    cdt = L._dtype(cfg.compute_dtype)
    h = L.apply_norm(cfg, p["ln_attn"], x)
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"].astype(cdt))
    out = _mha(cfg, q, k, v, None)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(cdt))
    h = L.apply_norm(cfg, p["ln_mlp"], x)
    return x + L.mlp_apply(cfg, p["mlp"], h)


# -- decoder ---------------------------------------------------------------


def init_decoder_block(cfg: ModelConfig, key):
    k1, k2, k3 = L.split_tree(key, 3)
    p, s = {}, {}
    dtype = L._dtype(cfg.param_dtype)
    p["ln_self"], s["ln_self"] = L.init_norm(cfg, dtype)
    p["ln_cross"], s["ln_cross"] = L.init_norm(cfg, dtype)
    p["ln_mlp"], s["ln_mlp"] = L.init_norm(cfg, dtype)
    p["self_attn"], s["self_attn"] = L.init_attention(cfg, k1)
    p["cross"], s["cross"] = _init_xattn(cfg, k2)
    p["mlp"], s["mlp"] = L.init_mlp(cfg, k3)
    return p, s


def decoder_block_apply(cfg, p, x, positions, enc_kv, cache=None):
    h = L.apply_norm(cfg, p["ln_self"], x)
    attn_out, new_cache = L.attention_apply(cfg, p["self_attn"], h, positions,
                                            cache=cache)
    x = x + attn_out
    h = L.apply_norm(cfg, p["ln_cross"], x)
    x = x + _xattn_apply(cfg, p["cross"], h, enc_kv)
    h = L.apply_norm(cfg, p["ln_mlp"], x)
    return x + L.mlp_apply(cfg, p["mlp"], h), new_cache


# -- full model --------------------------------------------------------------


def _stack(blocks_ps, blocks_ss):
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *blocks_ps) \
        if len(blocks_ps) > 1 else jax.tree.map(lambda v: v[None], blocks_ps[0])
    specs = jax.tree.map(lambda ax: ("layers",) + ax, blocks_ss,
                         is_leaf=lambda v: isinstance(v, tuple))
    return stacked, specs


def init_model(cfg: ModelConfig, key):
    dtype = L._dtype(cfg.param_dtype)
    ks = L.split_tree(key, 6)
    p, s = {}, {}
    p["embed"], s["embed"] = L.dense_init(
        ks[0], (cfg.vocab, cfg.d_model), ("vocab", "embed"), dtype,
        in_axis_sizes=cfg.d_model, scale=cfg.d_model**-0.5)
    enc = [init_encoder_block(cfg, k) for k in L.split_tree(ks[1], cfg.encoder_layers)]
    p["encoder"], s["encoder"] = _stack([e[0] for e in enc], enc[-1][1])
    dec = [init_decoder_block(cfg, k) for k in L.split_tree(ks[2], cfg.n_layers)]
    p["decoder"], s["decoder"] = _stack([d[0] for d in dec], dec[-1][1])
    p["ln_enc"], s["ln_enc"] = L.init_norm(cfg, dtype)
    p["ln_f"], s["ln_f"] = L.init_norm(cfg, dtype)
    return p, s


def encode(cfg: ModelConfig, params, frames):
    """frames: (B, F, D) stub frontend output. Returns encoder activations."""
    cdt = L._dtype(cfg.compute_dtype)
    x = frames.astype(cdt) + _sinusoid(frames.shape[1], cfg.d_model).astype(cdt)

    def body(xv, lp):
        return encoder_block_apply(cfg, lp, xv), None

    x, _ = jax.lax.scan(body, x, params["encoder"],
                        unroll=runtime.layer_scan_unroll())
    return L.apply_norm(cfg, params["ln_enc"], x)


def decode_train(cfg: ModelConfig, params, tokens, enc_out, remat=False):
    """Teacher-forced decoder over full token sequence."""
    cdt = L._dtype(cfg.compute_dtype)
    s_len = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    x = x + _sinusoid(s_len, cfg.d_model).astype(cdt)
    positions = jnp.arange(s_len, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, tokens.shape)

    def body(xv, lp):
        enc_kv = xattn_kv(cfg, lp["cross"], enc_out)
        out, _ = decoder_block_apply(cfg, lp, xv, positions, enc_kv)
        return out, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["decoder"],
                        unroll=runtime.layer_scan_unroll())
    x = L.apply_norm(cfg, params["ln_f"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return logits.astype(L._dtype(cfg.logit_dtype))


def lm_loss(cfg: ModelConfig, params, batch, remat=False):
    enc_out = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, batch["tokens"], enc_out, remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def init_cache(cfg: ModelConfig, batch: int, length: int,
               prefill_len: int = 0):
    """Self-attn KV cache + cross-attn KV (filled by ``warm_cache``)."""
    kv, kv_specs = L.init_kv_cache(cfg, batch, length, ring=False,
                                   prefill_len=prefill_len)
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    cdt = L._dtype(cfg.compute_dtype)
    one = {
        "self": kv,
        "cross_k": jnp.zeros((batch, cfg.n_frames, h, hd), cdt),
        "cross_v": jnp.zeros((batch, cfg.n_frames, h, hd), cdt),
    }
    n = cfg.n_layers
    cache = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), one)
    specs = {
        "self": jax.tree.map(lambda ax: ("layers",) + ax if isinstance(ax, tuple) else ax,
                             kv_specs, is_leaf=lambda v: isinstance(v, tuple)),
        "cross_k": ("layers", "batch", "frames", "heads", "head"),
        "cross_v": ("layers", "batch", "frames", "heads", "head"),
    }
    return cache, specs


def warm_cache(cfg: ModelConfig, params, cache, frames):
    """Compute encoder output and fill per-layer cross KV."""
    enc_out = encode(cfg, params, frames)

    def body(_, lp):
        k, v = xattn_kv(cfg, lp["cross"], enc_out)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["decoder"])
    cache = dict(cache)
    cache["cross_k"], cache["cross_v"] = ks, vs
    return cache


def serve_step(cfg: ModelConfig, params, cache, token, pos):
    """One decoder token against cached self KV + cross KV."""
    cdt = L._dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(cdt)
    pos = jnp.asarray(pos, jnp.int32)
    pe = _sinusoid(2048, cfg.d_model)  # static table; gather at pos
    if pos.ndim == 0:
        positions = jnp.broadcast_to(jnp.reshape(pos, (1, 1)),
                                     (token.shape[0], 1))
    else:
        positions = pos[:, None]
    # pos may exceed the table mechanically in decode_32k; wrap around
    x = x + jnp.take(pe, jnp.mod(positions[:, 0], 2048),
                     axis=0).astype(cdt)[:, None, :]

    def body(xv, xs):
        lp, lc = xs
        enc_kv = (lc["cross_k"], lc["cross_v"])
        out, new_self = decoder_block_apply(cfg, lp, xv, positions, enc_kv,
                                            cache=lc["self"])
        new_lc = dict(lc)
        new_lc["self"] = new_self
        return out, new_lc

    x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache),
                                unroll=runtime.layer_scan_unroll())
    x = L.apply_norm(cfg, params["ln_f"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return logits.astype(L._dtype(cfg.logit_dtype)), new_cache
