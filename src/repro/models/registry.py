"""Uniform model API: name -> init / loss / serve_step / cache / input_specs.

The launch layer (dry-run, train, serve) and the FL substrate only talk to
:class:`ModelApi`; family dispatch lives here.

Decode semantics per family (DESIGN.md §4):
* dense/moe/vlm — full-buffer KV cache for ``decode_32k``; ring-buffer
  (sliding-window) cache for ``long_500k``.
* hybrid (hymba) — ring KV (its attention is natively sliding-window) + SSM
  state for both decode shapes.
* ssm (rwkv6) — O(1) recurrent state for both decode shapes.
* audio (whisper) — self-KV cache + precomputed cross-KV; no long_500k.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec, hybrid, rwkv, transformer

Params = dict


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[[jax.Array], tuple[Params, dict]]
    loss: Callable[..., jax.Array]                  # (params, batch, remat=)
    serve_step: Callable[..., tuple]                # (params, cache, token, pos)
    init_cache: Callable[..., tuple]                # (batch, length, ring)
    input_specs: Callable[[ShapeSpec], dict]        # ShapeDtypeStructs
    cache_kind: Callable[[ShapeSpec], dict]         # {"length":…, "ring":…}
    #: (params, batch) -> per-position logits aligned with batch["labels"]
    #: (LMs: text-tail (B, S, V); vision: (B, n_classes)). The evaluation
    #: accessor the FL task factory builds accuracy metrics from.
    logits: Callable[[Params, dict], jax.Array] = None


def _token_sds(batch, seq):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def get_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _transformer_api(cfg)
    if fam == "ssm":
        return _rwkv_api(cfg)
    if fam == "hybrid":
        return _hybrid_api(cfg)
    if fam == "audio":
        return _encdec_api(cfg)
    if fam == "vision":
        return _resnet_api(cfg)
    raise ValueError(f"unknown family {fam}")


# -- decoder-only transformer ------------------------------------------------


def _transformer_api(cfg: ModelConfig) -> ModelApi:
    def input_specs(shape: ShapeSpec) -> dict:
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            specs = {"tokens": _token_sds(b, _text_len(cfg, s)),
                     "labels": _token_sds(b, _text_len(cfg, s))}
            if cfg.family == "vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_patches, cfg.d_frontend), jnp.float32)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": _token_sds(b, _text_len(cfg, s)),
                     "labels": _token_sds(b, _text_len(cfg, s))}
            if cfg.family == "vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_patches, cfg.d_frontend), jnp.float32)
            return specs
        return {"token": _token_sds(b, 1),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_kind(shape: ShapeSpec) -> dict:
        ring = shape.name == "long_500k"
        length = cfg.sliding_window if ring else shape.seq_len
        return {"length": length, "ring": ring}

    def loss(params, batch, remat=False):
        return transformer.lm_loss(cfg, params, batch, remat=remat)

    def serve_step(params, cache, token, pos, ring=False):
        return transformer.serve_step(cfg, params, cache, token, pos,
                                      ring=ring)

    def logits(params, batch):
        out, _ = transformer.forward(cfg, params, batch["tokens"],
                                     patches=batch.get("patches"))
        if cfg.family == "vlm":
            out = out[:, -batch["labels"].shape[1]:]
        return out

    return ModelApi(
        cfg=cfg,
        init=lambda key: transformer.init_lm(cfg, key),
        loss=loss,
        serve_step=serve_step,
        init_cache=lambda batch, length, ring, prefill_len=0:
            transformer.init_cache(cfg, batch, length, ring, prefill_len),
        input_specs=input_specs,
        cache_kind=cache_kind,
        logits=logits,
    )


def _text_len(cfg: ModelConfig, seq: int) -> int:
    """VLM total context = patches + text; keep the assigned total seq."""
    if cfg.family == "vlm":
        return seq - cfg.n_patches
    return seq


# -- rwkv6 -------------------------------------------------------------------


def _rwkv_api(cfg: ModelConfig) -> ModelApi:
    def input_specs(shape: ShapeSpec) -> dict:
        b, s = shape.global_batch, shape.seq_len
        if shape.kind in ("train", "prefill"):
            return {"tokens": _token_sds(b, s), "labels": _token_sds(b, s)}
        return {"token": _token_sds(b, 1),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_kind(shape: ShapeSpec) -> dict:
        return {"length": 0, "ring": False}   # O(1) recurrent state

    def serve_step(params, state, token, pos, ring=False):
        return rwkv.serve_step(cfg, params, state, token, pos)

    return ModelApi(
        cfg=cfg,
        init=lambda key: rwkv.init_lm(cfg, key),
        loss=lambda params, batch, remat=False:
            rwkv.lm_loss(cfg, params, batch, remat=remat),
        serve_step=serve_step,
        init_cache=lambda batch, length, ring, prefill_len=0:
            rwkv.init_state(cfg, batch),
        input_specs=input_specs,
        cache_kind=cache_kind,
        logits=lambda params, batch:
            rwkv.forward(cfg, params, batch["tokens"])[0],
    )


# -- hymba --------------------------------------------------------------------


def _hybrid_api(cfg: ModelConfig) -> ModelApi:
    def input_specs(shape: ShapeSpec) -> dict:
        b, s = shape.global_batch, shape.seq_len
        if shape.kind in ("train", "prefill"):
            return {"tokens": _token_sds(b, s), "labels": _token_sds(b, s)}
        return {"token": _token_sds(b, 1),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_kind(shape: ShapeSpec) -> dict:
        # attention is natively sliding-window: ring cache of window size
        return {"length": cfg.sliding_window, "ring": True}

    def serve_step(params, cache, token, pos, ring=True):
        return hybrid.serve_step(cfg, params, cache, token, pos, ring=ring)

    return ModelApi(
        cfg=cfg,
        init=lambda key: hybrid.init_lm(cfg, key),
        loss=lambda params, batch, remat=False:
            hybrid.lm_loss(cfg, params, batch, remat=remat),
        serve_step=serve_step,
        init_cache=lambda batch, length, ring, prefill_len=0:
            hybrid.init_cache(cfg, batch, length, ring, prefill_len),
        input_specs=input_specs,
        cache_kind=cache_kind,
        logits=lambda params, batch:
            hybrid.forward(cfg, params, batch["tokens"]),
    )


# -- whisper -------------------------------------------------------------------


def _encdec_api(cfg: ModelConfig) -> ModelApi:
    def input_specs(shape: ShapeSpec) -> dict:
        b, s = shape.global_batch, shape.seq_len
        frames = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model),
                                      jnp.float32)
        if shape.kind in ("train", "prefill"):
            return {"frames": frames, "tokens": _token_sds(b, s),
                    "labels": _token_sds(b, s)}
        return {"token": _token_sds(b, 1),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_kind(shape: ShapeSpec) -> dict:
        return {"length": shape.seq_len, "ring": False}

    def serve_step(params, cache, token, pos, ring=False):
        return encdec.serve_step(cfg, params, cache, token, pos)

    def logits(params, batch):
        enc_out = encdec.encode(cfg, params, batch["frames"])
        return encdec.decode_train(cfg, params, batch["tokens"], enc_out)

    return ModelApi(
        cfg=cfg,
        init=lambda key: encdec.init_model(cfg, key),
        loss=lambda params, batch, remat=False:
            encdec.lm_loss(cfg, params, batch, remat=remat),
        serve_step=serve_step,
        init_cache=lambda batch, length, ring, prefill_len=0:
            encdec.init_cache(cfg, batch, length, prefill_len),
        input_specs=input_specs,
        cache_kind=cache_kind,
        logits=logits,
    )


# -- resnet (the paper's CIFAR workload) ---------------------------------------


def _resnet_api(cfg: ModelConfig) -> ModelApi:
    """Vision family: ``d_model`` = stem width, ``vocab`` = class count.

    Batches are ``{"images": (B, H, W, 3) float32, "labels": (B,) int32}``
    — the same pytree :class:`repro.data.synthetic.SyntheticCifar` emits,
    so the FL task factory plugs it straight into the campaign engine.
    There is no token sequence: no decode path, no KV cache.
    """
    from repro.models import resnet

    def input_specs(shape: ShapeSpec) -> dict:
        b = shape.global_batch
        return {"images": jax.ShapeDtypeStruct((b, 32, 32, 3), jnp.float32),
                "labels": jax.ShapeDtypeStruct((b,), jnp.int32)}

    def init(key):
        params = resnet.init_resnet18(key, n_classes=cfg.vocab,
                                      width=cfg.d_model)
        # axis specs mirror the param tree (convnet: no sharded axes)
        specs = jax.tree.map(lambda _: (), params)
        return params, specs

    def serve_step(params, cache, token, pos, ring=False):
        raise NotImplementedError("vision family has no decode path")

    return ModelApi(
        cfg=cfg,
        init=init,
        loss=lambda params, batch, remat=False: resnet.loss_fn(params, batch),
        serve_step=serve_step,
        init_cache=lambda batch, length, ring, prefill_len=0: ({}, {}),
        input_specs=input_specs,
        cache_kind=lambda shape: {"length": 0, "ring": False},
        logits=lambda params, batch: resnet.forward(params, batch["images"]),
    )


# -- spec helpers ---------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, length: int, ring: bool):
    """Logical-axis specs for the cache pytree (for sharding rules)."""
    api = get_model(cfg)
    _, specs = api.init_cache(batch, length, ring)
    return specs


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params)
               if hasattr(x, "size"))
