"""Neural net layers in pure JAX with logical-axis sharding annotations.

Every ``init_*`` returns ``(params, specs)`` — twin pytrees where each param
leaf has a tuple of *logical axis names* describing its dimensions. The
launch layer (:mod:`repro.launch.sharding`) maps logical names to mesh axes
(MaxText-style rules), so the same model code serves the 1-device smoke tests
and the 512-chip dry-run.

Attention supports GQA/MQA/MHA, MLA (DeepSeek-V2 latent attention with the
absorbed decode path), causal and sliding-window masks, full-buffer and
ring-buffer KV caches. MoE uses capacity-based one-hot dispatch (TPU-native
einsum dispatch/combine, correct FLOPs, expert axis shardable).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig
from repro.models import runtime

Params = dict
Specs = dict

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, shape, axes, dtype, in_axis_sizes=None, scale=None):
    """Truncated-normal fan-in init with logical axes."""
    fan_in = shape[0] if in_axis_sizes is None else in_axis_sizes
    std = scale if scale is not None else (1.0 / max(fan_in, 1)) ** 0.5
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std
    return w.astype(dtype), tuple(axes)


def zeros_init(shape, axes, dtype):
    return jnp.zeros(shape, dtype), tuple(axes)


def ones_init(shape, axes, dtype):
    return jnp.ones(shape, dtype), tuple(axes)


def split_tree(key, n):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dtype) -> tuple[Params, Specs]:
    if cfg.norm == "rmsnorm":
        p, s = ones_init((cfg.d_model,), ("embed",), dtype)
        return {"scale": p}, {"scale": s}
    p, s = ones_init((cfg.d_model,), ("embed",), dtype)
    b, bs = zeros_init((cfg.d_model,), ("embed",), dtype)
    return {"scale": p, "bias": b}, {"scale": s, "bias": bs}


def apply_norm(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * params["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = ((xf - mean) * jax.lax.rsqrt(var + 1e-5)
             * params["scale"].astype(jnp.float32)
             + params["bias"].astype(jnp.float32))
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotated pairwise; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention
# --------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key) -> tuple[Params, Specs]:
    dtype = _dtype(cfg.param_dtype)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = split_tree(key, 4)
    wq, sq = dense_init(k1, (d, h, hd), ("embed", "heads", "head"), dtype)
    wk, sk = dense_init(k2, (d, kv, hd), ("embed", "kv_heads", "head"), dtype)
    wv, sv = dense_init(k3, (d, kv, hd), ("embed", "kv_heads", "head"), dtype)
    wo, so = dense_init(k4, (h, hd, d), ("heads", "head", "embed"), dtype,
                        in_axis_sizes=h * hd)
    return ({"wq": wq, "wk": wk, "wv": wv, "wo": wo},
            {"wq": sq, "wk": sk, "wv": sv, "wo": so})


def _attn_mask(q_pos: jax.Array, k_pos: jax.Array, window: int) -> jax.Array:
    """(..., Q, K) boolean mask: causal, optionally sliding-window."""
    causal = q_pos[..., :, None] >= k_pos[..., None, :]
    if window > 0:
        causal &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return causal


def sdpa(q, k, v, mask, compute_dtype) -> jax.Array:
    """Reference scaled-dot-product attention with GQA head-group broadcast.

    q: (B,S,H,D)  k/v: (B,T,KV,D)  mask: (B,1,S,T) or (S,T). fp32 softmax.
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, s, kvh, groups, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores *= d ** -0.5
    if mask.ndim == 2:
        mask_b = mask[None, None, None, :, :]
    else:
        mask_b = mask[:, :, None, :, :]  # (B,1,1,S,T) broadcast over k,g
    scores = jnp.where(mask_b, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, d)


def attention_apply(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    window: int = 0,
    cache: Optional[Params] = None,
    ring: bool = False,
) -> tuple[jax.Array, Optional[Params]]:
    """GQA attention. With ``cache`` → single-token decode (S=1), else full.

    cache = {"k": (B,T,KV,D), "v": ..., "pos": ()} — full buffer; ``ring``
    (static) reinterprets the buffer as a ring of the last T positions.
    """
    cdt = _dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cdt))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        kb = runtime.kernel_backend()
        if kb is not None or runtime.ATTN_IMPL == "flash":
            from repro.kernels import ops as kops
            out = kops.attention(q, k, v, causal=True, window=window,
                                 backend=kb)
        else:
            pos_row = positions[0] if positions.ndim > 1 else positions
            mask = _attn_mask(pos_row, pos_row, window)
            out = sdpa(q, k, v, mask, cdt)
        new_cache = None
    else:
        out, cache = _decode_attend(cfg, q, k, v, cache, window, positions,
                                    ring)
        new_cache = cache
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cdt))
    return y, new_cache



def _dus(buf, new, pos):
    """dynamic_update_slice along axis 1 with dtype-consistent indices
    (int32 even when x64 is enabled elsewhere in the process)."""
    z = jnp.zeros((), jnp.int32)
    idx = (z, jnp.asarray(pos, jnp.int32)) + (z,) * (buf.ndim - 2)
    return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), idx)


def _decode_attend(cfg, q, k_new, v_new, cache, window, positions,
                   ring=False):
    """One-token decode against a full or ring KV cache.

    q/k_new/v_new: (B, 1, H|KV, D). ``positions``: (B, 1) absolute position
    of this token PER batch row (continuous batching: rows may be at
    different depths). Returns (out (B,1,H,D), updated cache).
    """
    cdt = _dtype(cfg.compute_dtype)
    b = q.shape[0]
    t = cache["k"].shape[1]
    pos_b = jnp.asarray(positions[:, 0], jnp.int32)        # (B,)
    slot_b = jnp.mod(pos_b, t) if ring else pos_b
    rows = jnp.arange(b)
    k_buf = cache["k"].at[rows, slot_b].set(
        k_new[:, 0].astype(cache["k"].dtype))
    v_buf = cache["v"].at[rows, slot_b].set(
        v_new[:, 0].astype(cache["v"].dtype))
    idx = jnp.arange(t)[None, :]                           # (1, T)
    if ring:
        k_pos = pos_b[:, None] - jnp.mod(pos_b[:, None] - idx, t)
        win = min(window, t) if window > 0 else t
        valid = ((k_pos >= 0) & (k_pos > pos_b[:, None] - win)
                 & (k_pos <= pos_b[:, None]))              # (B, T)
    else:
        valid = idx <= pos_b[:, None]                      # (B, T)
    mask = valid[:, None, None, None, :]                   # -> (B,KV,G,1,T)
    s, h, d = q.shape[1], q.shape[2], q.shape[3]
    kvh = k_buf.shape[2]
    groups = h // kvh
    qg = q.reshape(b, s, kvh, groups, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_buf.astype(cdt))
    scores = scores.astype(jnp.float32) * d ** -0.5
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v_buf.astype(cdt))
    out = out.reshape(b, s, h, d)
    new_cache = dict(cache)
    new_cache.update(k=k_buf, v=v_buf, pos=cache["pos"] + 1)
    return out, new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, length: int, ring: bool,
                  prefill_len: int = 0) -> tuple[Params, Specs]:
    """Per-layer KV cache (stacked over layers by the caller)."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dtype = _dtype(cfg.compute_dtype)
    cache = {
        "k": jnp.zeros((batch, length, kv, hd), dtype),
        "v": jnp.zeros((batch, length, kv, hd), dtype),
        "pos": jnp.asarray(prefill_len, jnp.int32),
    }
    specs = {
        "k": ("batch", "seq", "kv_heads", "head"),
        "v": ("batch", "seq", "kv_heads", "head"),
        "pos": (),
    }
    return cache, specs


# --------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2, MiniCPM3)
# --------------------------------------------------------------------------


def init_mla(cfg: ModelConfig, key) -> tuple[Params, Specs]:
    m: MLAConfig = cfg.mla
    dtype = _dtype(cfg.param_dtype)
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim
    qr = m.qk_rope_head_dim
    dv = m.v_head_dim
    ks = split_tree(key, 8)
    p, s = {}, {}
    p["w_dq"], s["w_dq"] = dense_init(ks[0], (d, m.q_lora_rank), ("embed", "q_lora"), dtype)
    p["q_norm"], s["q_norm"] = ones_init((m.q_lora_rank,), ("q_lora",), dtype)
    p["w_uq"], s["w_uq"] = dense_init(
        ks[1], (m.q_lora_rank, h, qk + qr), ("q_lora", "heads", "head"), dtype)
    p["w_dkv"], s["w_dkv"] = dense_init(ks[2], (d, m.kv_lora_rank), ("embed", "kv_lora"), dtype)
    p["kv_norm"], s["kv_norm"] = ones_init((m.kv_lora_rank,), ("kv_lora",), dtype)
    p["w_uk"], s["w_uk"] = dense_init(
        ks[3], (m.kv_lora_rank, h, qk), ("kv_lora", "heads", "head"), dtype)
    p["w_uv"], s["w_uv"] = dense_init(
        ks[4], (m.kv_lora_rank, h, dv), ("kv_lora", "heads", "head"), dtype)
    p["w_kr"], s["w_kr"] = dense_init(ks[5], (d, qr), ("embed", "head"), dtype)
    p["wo"], s["wo"] = dense_init(
        ks[6], (h, dv, d), ("heads", "head", "embed"), dtype, in_axis_sizes=h * dv)
    return p, s


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


def mla_apply(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    window: int = 0,
    cache: Optional[Params] = None,
    ring: bool = False,
) -> tuple[jax.Array, Optional[Params]]:
    """MLA forward. Prefill/train: expanded path (paper-faithful).
    Decode: absorbed path — scores and values computed in the compressed
    latent space against the (c_kv, k_rope) cache (DeepSeek-V2 §2.1)."""
    m: MLAConfig = cfg.mla
    cdt = _dtype(cfg.compute_dtype)
    h = cfg.n_heads
    qk, qr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q_lat = _rms(jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(cdt)),
                 params["q_norm"])
    q_all = jnp.einsum("bsr,rhk->bshk", q_lat, params["w_uq"].astype(cdt))
    q_nope, q_rope = q_all[..., :qk], q_all[..., qk:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = _rms(jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(cdt)),
                params["kv_norm"])
    k_rope = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, params["w_kr"].astype(cdt))[:, :, None, :],
        positions, cfg.rope_theta)[:, :, 0, :]                       # (B,S,qr)

    scale = (qk + qr) ** -0.5

    if cache is None:
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"].astype(cdt))
        v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"].astype(cdt))
        pos_row = positions[0] if positions.ndim > 1 else positions
        mask = _attn_mask(pos_row, pos_row, window)
        scores = (jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
                  + jnp.einsum("bshk,btk->bhst", q_rope, k_rope))
        scores = scores.astype(jnp.float32) * scale
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
        out = jnp.einsum("bhst,bthk->bshk", probs, v)
        new_cache = None
    else:
        t = cache["c_kv"].shape[1]
        bsz = x.shape[0]
        pos_b = jnp.asarray(positions[:, 0], jnp.int32)    # (B,)
        slot_b = jnp.mod(pos_b, t) if ring else pos_b
        rows = jnp.arange(bsz)
        ckv_buf = cache["c_kv"].at[rows, slot_b].set(
            c_kv[:, 0].astype(cache["c_kv"].dtype))
        kr_buf = cache["k_rope"].at[rows, slot_b].set(
            k_rope[:, 0].astype(cache["k_rope"].dtype))
        idx = jnp.arange(t)[None, :]
        if ring:
            k_pos = pos_b[:, None] - jnp.mod(pos_b[:, None] - idx, t)
            win = min(window, t) if window > 0 else t
            valid = ((k_pos >= 0) & (k_pos > pos_b[:, None] - win)
                     & (k_pos <= pos_b[:, None]))
        else:
            valid = idx <= pos_b[:, None]
        mask = valid[:, None, None, :]
        # absorbed: q_eff[b,s,h,r] = q_nope · w_uk ;  scores vs c_kv cache
        q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"].astype(cdt))
        scores = (jnp.einsum("bshr,btr->bhst", q_eff, ckv_buf.astype(cdt))
                  + jnp.einsum("bshk,btk->bhst", q_rope, kr_buf.astype(cdt)))
        scores = scores.astype(jnp.float32) * scale
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, ckv_buf.astype(cdt))
        out = jnp.einsum("bshr,rhk->bshk", o_lat, params["w_uv"].astype(cdt))
        new_cache = dict(cache)
        new_cache.update(c_kv=ckv_buf, k_rope=kr_buf, pos=cache["pos"] + 1)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cdt))
    return y, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, length: int, ring: bool,
                   prefill_len: int = 0) -> tuple[Params, Specs]:
    m: MLAConfig = cfg.mla
    dtype = _dtype(cfg.compute_dtype)
    cache = {
        "c_kv": jnp.zeros((batch, length, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, length, m.qk_rope_head_dim), dtype),
        "pos": jnp.asarray(prefill_len, jnp.int32),
    }
    specs = {
        "c_kv": ("batch", "seq", "kv_lora"),
        "k_rope": ("batch", "seq", "head"),
        "pos": (),
    }
    return cache, specs


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> tuple[Params, Specs]:
    dtype = _dtype(cfg.param_dtype)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = split_tree(key, 3)
    p, s = {}, {}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"], s["w_gate"] = dense_init(k1, (d, ff), ("embed", "mlp"), dtype)
        p["w_up"], s["w_up"] = dense_init(k2, (d, ff), ("embed", "mlp"), dtype)
    else:
        p["w_up"], s["w_up"] = dense_init(k2, (d, ff), ("embed", "mlp"), dtype)
    p["w_down"], s["w_down"] = dense_init(k3, (ff, d), ("mlp", "embed"), dtype)
    return p, s


def mlp_apply(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    cdt = _dtype(cfg.compute_dtype)
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(cdt))
    if cfg.act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(cdt))
        hidden = jax.nn.silu(gate) * up
    elif cfg.act == "geglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(cdt))
        hidden = jax.nn.gelu(gate, approximate=True) * up
    else:
        hidden = jax.nn.gelu(up, approximate=True)
    return jnp.einsum("bsf,fd->bsd", hidden, params["w_down"].astype(cdt))


# --------------------------------------------------------------------------
# MoE — capacity-based one-hot dispatch (Switch/GShard style)
# --------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key) -> tuple[Params, Specs]:
    moe: MoEConfig = cfg.moe
    dtype = _dtype(cfg.param_dtype)
    d, ff, e = cfg.d_model, cfg.d_ff, moe.n_experts
    ks = split_tree(key, 6)
    p, s = {}, {}
    p["router"], s["router"] = dense_init(
        ks[0], (d, e), ("embed", "expert"), dtype, scale=0.02)
    p["w_gate"], s["w_gate"] = dense_init(
        ks[1], (e, d, ff), ("expert", "embed", "mlp"), dtype, in_axis_sizes=d)
    p["w_up"], s["w_up"] = dense_init(
        ks[2], (e, d, ff), ("expert", "embed", "mlp"), dtype, in_axis_sizes=d)
    p["w_down"], s["w_down"] = dense_init(
        ks[3], (e, ff, d), ("expert", "mlp", "embed"), dtype, in_axis_sizes=ff)
    if moe.n_shared:
        sh_ff = ff * moe.n_shared
        p["shared_gate"], s["shared_gate"] = dense_init(ks[4], (d, sh_ff), ("embed", "mlp"), dtype)
        p["shared_up"], s["shared_up"] = dense_init(ks[5], (d, sh_ff), ("embed", "mlp"), dtype)
        p["shared_down"], s["shared_down"] = dense_init(
            ks[4], (sh_ff, d), ("mlp", "embed"), dtype, in_axis_sizes=sh_ff)
    return p, s


MOE_GROUP_SIZE = 512  # tokens per routing group (GShard-style); capacity is
                      # enforced per group so dispatch tensors stay linear in
                      # total tokens: G*S*E*C = T * cf * k * S.


def moe_apply(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Top-k routed experts with grouped capacity-based einsum dispatch.

    Tokens are reshaped to (G, S_g) routing groups; each group dispatches at
    most C = cf*k*S_g/E tokens to each expert via a one-hot (G,S,E,C) tensor
    (GShard/Switch semantics, overflow dropped). All-einsum formulation:
    TPU-native, shards cleanly (G → data axes, E → model axis), and
    cost_analysis reports the true activated FLOPs.
    """
    moe: MoEConfig = cfg.moe
    cdt = _dtype(cfg.compute_dtype)
    b, s_len, d = x.shape
    t = b * s_len
    e, k = moe.n_experts, moe.top_k
    sg = min(MOE_GROUP_SIZE, t)
    assert t % sg == 0, f"token count {t} not divisible by group size {sg}"
    g = t // sg
    cap = max(4, int(moe.capacity_factor * k * sg / e))
    cap = min(cap, sg)

    xt = x.reshape(g, sg, d)
    logits = jnp.einsum("gsd,de->gse", xt, params["router"].astype(cdt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (G,S,E)
    gate_vals, choices = jax.lax.top_k(probs, k)                  # (G,S,k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    choice_oh = jax.nn.one_hot(choices, e, dtype=jnp.float32)     # (G,S,k,E)
    # queue position of each (token, choice) within its expert, per group
    flat = choice_oh.reshape(g, sg * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(g, sg, k, e)
    pos_in_expert = jnp.sum(pos_in_expert * choice_oh, axis=-1)   # (G,S,k)
    keep = pos_in_expert < cap
    gate_vals = gate_vals * keep

    cap_oh = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), cap,
                            dtype=jnp.float32) * keep[..., None]  # (G,S,k,C)
    dispatch = jnp.einsum("gske,gskc->gsec", choice_oh, cap_oh)
    combine = jnp.einsum("gske,gskc,gsk->gsec", choice_oh, cap_oh,
                         gate_vals.astype(jnp.float32))

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(cdt), xt)   # (G,E,C,D)
    gate = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(cdt))
    up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(cdt))
    act = jax.nn.silu(gate) * up if cfg.act == "swiglu" else \
        jax.nn.gelu(gate, approximate=True) * up
    ye = jnp.einsum("gecf,efd->gecd", act, params["w_down"].astype(cdt))
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(cdt), ye)     # (G,S,D)

    if moe.n_shared:
        sg_ = jnp.einsum("gsd,df->gsf", xt, params["shared_gate"].astype(cdt))
        su = jnp.einsum("gsd,df->gsf", xt, params["shared_up"].astype(cdt))
        sa = jax.nn.silu(sg_) * su if cfg.act == "swiglu" else \
            jax.nn.gelu(sg_, approximate=True) * su
        y = y + jnp.einsum("gsf,fd->gsd", sa, params["shared_down"].astype(cdt))

    # load-balance aux loss (Switch: E * sum_e f_e * P_e)
    me = jnp.mean(probs, axis=(0, 1))                              # (E,)
    ce = jnp.mean(choice_oh.sum(axis=2), axis=(0, 1))              # routed frac
    aux = moe.router_aux_weight * e * jnp.sum(me * ce)
    return y.reshape(b, s_len, d), aux.astype(jnp.float32)
