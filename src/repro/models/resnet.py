"""ResNet-18 in pure JAX — the paper's federated workload (CIFAR-10).

11.18M parameters at width 64 and 10 classes, matching Table I
(w = 11 181 642, S_w = 44.73 MB fp32). Norm layer is configurable:
``groupnorm`` (default — BN running stats are notoriously ill-posed under
FedAvg) or ``batchnorm`` (paper-faithful; stats are FedAvg-merged like any
other parameter). See DESIGN.md §9.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Params = dict

STAGES = (64, 128, 256, 512)
BLOCKS_PER_STAGE = 2


def _conv_init(key, k, c_in, c_out):
    fan_in = k * k * c_in
    w = jax.random.truncated_normal(key, -2, 2, (k, k, c_in, c_out),
                                    jnp.float32)
    return w * (2.0 / fan_in) ** 0.5


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _norm_params(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def _apply_norm(p, x, kind: str, groups: int = 8):
    if kind == "groupnorm":
        b, h, w, c = x.shape
        g = min(groups, c)
        xg = x.reshape(b, h, w, g, c // g)
        mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
        var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
        xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
        x = xg.reshape(b, h, w, c)
    else:  # batchnorm (batch statistics; stats FedAvg'd with the params)
        mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
        var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
        x = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return x * p["scale"] + p["bias"]


def init_resnet18(key, n_classes: int = 10, width: int = 64):
    ks = jax.random.split(key, 64)
    ki = iter(range(64))
    p: Params = {}
    p["stem_conv"] = _conv_init(ks[next(ki)], 3, 3, width)
    p["stem_norm"] = _norm_params(width)
    c_in = width
    for si, mult in enumerate((1, 2, 4, 8)):
        c_out = width * mult
        for bi in range(BLOCKS_PER_STAGE):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {
                "conv1": _conv_init(ks[next(ki)], 3, c_in, c_out),
                "norm1": _norm_params(c_out),
                "conv2": _conv_init(ks[next(ki)], 3, c_out, c_out),
                "norm2": _norm_params(c_out),
            }
            if stride != 1 or c_in != c_out:
                blk["proj"] = _conv_init(ks[next(ki)], 1, c_in, c_out)
                blk["proj_norm"] = _norm_params(c_out)
            p[f"stage{si}_block{bi}"] = blk
            c_in = c_out
    p["head_w"] = jax.random.truncated_normal(
        ks[next(ki)], -2, 2, (c_in, n_classes), jnp.float32) * c_in**-0.5
    p["head_b"] = jnp.zeros((n_classes,), jnp.float32)
    return p


def _block_apply(p, x, stride, norm_kind):
    y = _conv(x, p["conv1"], stride)
    y = jax.nn.relu(_apply_norm(p["norm1"], y, norm_kind))
    y = _conv(y, p["conv2"], 1)
    y = _apply_norm(p["norm2"], y, norm_kind)
    if "proj" in p:
        x = _apply_norm(p["proj_norm"], _conv(x, p["proj"], stride), norm_kind)
    return jax.nn.relu(x + y)


def forward(params: Params, images: jax.Array,
            norm: Literal["groupnorm", "batchnorm"] = "groupnorm"):
    """images: (B, 32, 32, 3) float32 -> logits (B, n_classes)."""
    x = _conv(images, params["stem_conv"], 1)
    x = jax.nn.relu(_apply_norm(params["stem_norm"], x, norm))
    for si in range(4):
        for bi in range(BLOCKS_PER_STAGE):
            stride = 2 if (si > 0 and bi == 0) else 1
            x = _block_apply(params[f"stage{si}_block{bi}"], x, stride, norm)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head_w"] + params["head_b"]


def loss_fn(params: Params, batch: dict, norm="groupnorm"):
    logits = forward(params, batch["images"], norm)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def accuracy(params: Params, batch: dict, norm="groupnorm"):
    logits = forward(params, batch["images"], norm)
    return jnp.mean(jnp.argmax(logits, -1) == batch["labels"])


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
