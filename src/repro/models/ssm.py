"""Mamba-style selective SSM head (the SSM branch of Hymba layers).

Selective scan: h_t = exp(Δ_t A) ⊙ h_{t-1} + Δ_t B_t x_t ;  y_t = C_t h_t + D x_t
with per-token Δ, B, C (data-dependent selectivity, diagonal A). Causal conv
front, SiLU gate. Reference path is a ``lax.scan``;
:mod:`repro.kernels.ssm_scan` is the VMEM-tiled Pallas version.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import runtime

Params = dict


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    dt_rank = cfg.ssm.dt_rank or max(1, -(-cfg.d_model // 16))
    return d_inner, cfg.ssm.state_dim, dt_rank


def init_ssm(cfg: ModelConfig, key):
    dtype = L._dtype(cfg.param_dtype)
    d = cfg.d_model
    d_in, n, dt_rank = _dims(cfg)
    ks = L.split_tree(key, 8)
    p, s = {}, {}
    p["w_in"], s["w_in"] = L.dense_init(ks[0], (d, 2 * d_in), ("embed", "inner"), dtype)
    p["conv_w"], s["conv_w"] = L.dense_init(
        ks[1], (cfg.ssm.conv_width, d_in), ("conv", "inner"), dtype,
        in_axis_sizes=cfg.ssm.conv_width)
    p["conv_b"], s["conv_b"] = L.zeros_init((d_in,), ("inner",), dtype)
    p["w_bcdt"], s["w_bcdt"] = L.dense_init(
        ks[2], (d_in, 2 * n + dt_rank), ("inner", "state_proj"), dtype)
    p["dt_proj"], s["dt_proj"] = L.dense_init(
        ks[3], (dt_rank, d_in), ("dt_rank", "inner"), dtype, scale=dt_rank**-0.5)
    p["dt_bias"], s["dt_bias"] = L.zeros_init((d_in,), ("inner",), dtype)
    # A stored as log(-A) for stability: A = -exp(a_log), diagonal (d_in, n)
    a_init = jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
                              (d_in, n))
    p["a_log"], s["a_log"] = a_init.astype(jnp.float32), ("inner", "state")
    p["d_skip"], s["d_skip"] = L.ones_init((d_in,), ("inner",), jnp.float32)
    p["w_out"], s["w_out"] = L.dense_init(ks[4], (d_in, d), ("inner", "embed"), dtype)
    return p, s


def selective_scan(x, delta, a, b, c, d_skip, h0):
    """x,delta: (B,S,Din); a: (Din,N); b,c: (B,S,N); h0: (B,Din,N).

    Returns (y (B,S,Din), h_final). fp32 recurrence.
    """
    xf = x.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    da = jnp.exp(df[..., None] * (-jnp.exp(a))[None, None])     # (B,S,Din,N)
    dbx = df[..., None] * bf[:, :, None, :] * xf[..., None]     # (B,S,Din,N)

    def step(h, inputs):
        da_t, dbx_t, c_t = inputs
        h = da_t * h + dbx_t                                    # (B,Din,N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    da_s = jnp.moveaxis(da, 1, 0)
    dbx_s = jnp.moveaxis(dbx, 1, 0)
    c_s = jnp.moveaxis(cf, 1, 0)
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), (da_s, dbx_s, c_s))
    y = jnp.moveaxis(ys, 0, 1) + xf * d_skip[None, None]
    return y.astype(x.dtype), h


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv. x: (B,S,Din); w: (K,Din); conv_state: (B,K-1,Din).

    Returns (y, new_conv_state)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                      # (B,K-1+S,Din)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return y + b[None, None], new_state


def ssm_apply(cfg: ModelConfig, p: Params, x, state=None):
    """x: (B,S,D). state: {"conv": (B,K-1,Din), "h": (B,Din,N)} or None.

    Returns (y (B,S,D), new_state)."""
    cdt = x.dtype
    d_in, n, dt_rank = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cdt))
    xc, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xc, p["conv_w"].astype(cdt),
                                p["conv_b"].astype(cdt), conv_state)
    xc = jax.nn.silu(xc)
    bcdt = jnp.einsum("bse,ep->bsp", xc, p["w_bcdt"].astype(cdt))
    b_sel = bcdt[..., :n]
    c_sel = bcdt[..., n:2 * n]
    dt = bcdt[..., 2 * n:]
    delta = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt, p["dt_proj"].astype(cdt)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    kb = runtime.kernel_backend()
    if kb is not None and state is None:
        # Training path (zero initial state): dispatch the recurrence to the
        # kernel layer — the Pallas scan always starts from h = 0, so the
        # streaming/decode path (state is not None) stays on lax.scan.
        from repro.kernels import ops as kops
        y, h = kops.ssm(xc, delta, p["a_log"], b_sel, c_sel, p["d_skip"],
                        backend=kb)
        y = y.astype(cdt)
    else:
        h0 = (state["h"] if state is not None
              else jnp.zeros((x.shape[0], d_in, n), jnp.float32))
        y, h = selective_scan(xc, delta, p["a_log"], b_sel, c_sel,
                              p["d_skip"], h0)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cdt))
    new_state = {"h": h}
    if new_conv is not None:
        new_state["conv"] = new_conv
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int):
    d_in, n, _ = _dims(cfg)
    k = cfg.ssm.conv_width
    cdt = L._dtype(cfg.compute_dtype)
    state = {
        "conv": jnp.zeros((batch, k - 1, d_in), cdt),
        "h": jnp.zeros((batch, d_in, n), jnp.float32),
    }
    specs = {
        "conv": ("batch", "conv", "inner"),
        "h": ("batch", "inner", "state"),
    }
    return state, specs
