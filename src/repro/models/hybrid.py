"""Hymba-style hybrid blocks: parallel attention + mamba heads per layer.

Each layer runs a (sliding-window) GQA attention branch and a selective-SSM
branch *in parallel on the same normalized input*, normalizes each branch
output, and averages them (arXiv:2411.13676 §2; meta-tokens are omitted —
see DESIGN.md §9). Decode carries both a KV ring cache (attention) and the
O(1) SSM recurrent state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import runtime

Params = dict


def init_block(cfg: ModelConfig, key):
    ks = L.split_tree(key, 4)
    dtype = L._dtype(cfg.param_dtype)
    p, s = {}, {}
    p["ln_mix"], s["ln_mix"] = L.init_norm(cfg, dtype)
    p["ln_mlp"], s["ln_mlp"] = L.init_norm(cfg, dtype)
    p["attn"], s["attn"] = L.init_attention(cfg, ks[0])
    p["ssm"], s["ssm"] = S.init_ssm(cfg, ks[1])
    p["mlp"], s["mlp"] = L.init_mlp(cfg, ks[2])
    # per-branch output norms (Hymba normalizes before averaging)
    p["norm_attn_out"], s["norm_attn_out"] = L.init_norm(cfg, dtype)
    p["norm_ssm_out"], s["norm_ssm_out"] = L.init_norm(cfg, dtype)
    return p, s


def block_apply(cfg: ModelConfig, params, x, positions, window=0,
                cache=None, ring=False):
    """cache: {"kv": <attention cache>, "ssm": <ssm state>} or None."""
    h = L.apply_norm(cfg, params["ln_mix"], x)
    kv_cache = cache["kv"] if cache is not None else None
    attn_y, new_kv = L.attention_apply(cfg, params["attn"], h, positions,
                                       window=window, cache=kv_cache,
                                       ring=ring)
    ssm_state = cache["ssm"] if cache is not None else None
    ssm_y, new_ssm = S.ssm_apply(cfg, params["ssm"], h, state=ssm_state)
    attn_y = L.apply_norm(cfg, params["norm_attn_out"], attn_y)
    ssm_y = L.apply_norm(cfg, params["norm_ssm_out"], ssm_y)
    x = x + 0.5 * (attn_y + ssm_y)
    h = L.apply_norm(cfg, params["ln_mlp"], x)
    x = x + L.mlp_apply(cfg, params["mlp"], h)
    new_cache = None
    if cache is not None:
        new_cache = {"kv": new_kv, "ssm": new_ssm}
    return x, new_cache


def init_lm(cfg: ModelConfig, key):
    dtype = L._dtype(cfg.param_dtype)
    k_embed, k_layers, k_head = L.split_tree(key, 3)
    p, s = {}, {}
    p["embed"], s["embed"] = L.dense_init(
        k_embed, (cfg.vocab, cfg.d_model), ("vocab", "embed"), dtype,
        in_axis_sizes=cfg.d_model, scale=cfg.d_model**-0.5)
    keys = L.split_tree(k_layers, cfg.n_layers)
    ps, ss = [], None
    for i in range(cfg.n_layers):
        bp, bs = init_block(cfg, keys[i])
        ps.append(bp)
        ss = bs
    p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ps) \
        if len(ps) > 1 else jax.tree.map(lambda v: v[None], ps[0])
    s["layers"] = jax.tree.map(lambda ax: ("layers",) + ax, ss,
                               is_leaf=lambda v: isinstance(v, tuple))
    p["ln_f"], s["ln_f"] = L.init_norm(cfg, dtype)
    p["lm_head"], s["lm_head"] = L.dense_init(
        k_head, (cfg.d_model, cfg.vocab), ("embed", "vocab"), dtype)
    return p, s


def init_cache(cfg: ModelConfig, batch: int, length: int, ring: bool,
               prefill_len: int = 0):
    """Stacked per-layer hybrid cache: attention KV + SSM state."""
    kv, kv_specs = L.init_kv_cache(cfg, batch, length, ring, prefill_len)
    st, st_specs = S.init_ssm_state(cfg, batch)
    one = {"kv": kv, "ssm": st}
    specs_one = {"kv": kv_specs, "ssm": st_specs}
    n = cfg.n_layers
    cache = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), one)
    specs = jax.tree.map(
        lambda ax: ("layers",) + ax if isinstance(ax, tuple) else ax,
        specs_one, is_leaf=lambda v: isinstance(v, tuple))
    return cache, specs


def _scan(cfg, params, x, positions, window, caches, remat, ring=False):
    def body(carry, xs):
        xv = carry
        lp = xs[0]
        lc = xs[1] if caches is not None else None
        out, nc = block_apply(cfg, lp, xv, positions, window=window,
                              cache=lc, ring=ring)
        return out, nc

    fn = jax.checkpoint(body) if remat else body
    xs = (params["layers"],) if caches is None else (params["layers"], caches)
    x, ncs = jax.lax.scan(fn, x, xs, unroll=runtime.layer_scan_unroll())
    return x, ncs


def forward(cfg: ModelConfig, params, tokens, remat=False,
            return_hidden=False):
    cdt = L._dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, tokens.shape)
    window = cfg.sliding_window  # Hymba uses SWA natively in train too
    x, _ = _scan(cfg, params, x, positions, window, None, remat)
    x = L.apply_norm(cfg, params["ln_f"], x)
    if return_hidden:
        return x
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits.astype(L._dtype(cfg.logit_dtype))


def lm_loss(cfg: ModelConfig, params, batch: dict, remat=False):
    kb = runtime.kernel_backend()
    if kb is not None:
        from repro.kernels import ops as kops
        x = forward(cfg, params, batch["tokens"], remat=remat,
                    return_hidden=True)
        b, s, d = x.shape
        nll = kops.cross_entropy(x.reshape(b * s, d),
                                 params["lm_head"].astype(x.dtype),
                                 batch["labels"].reshape(-1), backend=kb)
        return jnp.mean(nll)
    logits = forward(cfg, params, batch["tokens"], remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def serve_step(cfg: ModelConfig, params, cache, token, pos, ring: bool = True):
    cdt = L._dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(cdt)
    pos = jnp.asarray(pos, jnp.int32)
    positions = (pos[:, None] if pos.ndim == 1 else
                 jnp.broadcast_to(jnp.reshape(pos, (1, 1)),
                                  (token.shape[0], 1)))
    x, new_cache = _scan(cfg, params, x, positions, cfg.sliding_window,
                         cache, remat=False, ring=ring)
    x = L.apply_norm(cfg, params["ln_f"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits.astype(L._dtype(cfg.logit_dtype)), new_cache
