"""RWKV-6 "Finch" — attention-free RNN LM with data-dependent decay.

Faithful structure (arXiv:2404.05892): token-shift ddlerp mixing, low-rank
data-dependent per-channel decay w_t, bonus u, multi-head WKV state
S ∈ R^{dk×dv} per head, per-head group-norm, gated output; channel-mix FFN
with squared-ReLU. The sequential WKV is a ``lax.scan`` here (HLO-compact);
:mod:`repro.kernels.rwkv6_scan` provides the VMEM-tiled Pallas version.

Decode is O(1) per token: the serve "cache" is the recurrent state
(x_prev for both mixers + the WKV state), independent of context length —
this is why rwkv6 runs long_500k natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import runtime

Params = dict

LORA_DECAY = 64   # low-rank width of the data-dependent decay
LORA_MIX = 32     # low-rank width of the ddlerp mixers
MIX_STREAMS = 5   # w, k, v, r, g


def _heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.ssm.head_dim


def init_time_mix(cfg: ModelConfig, key):
    dtype = L._dtype(cfg.param_dtype)
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    h = _heads(cfg)
    ks = L.split_tree(key, 12)
    p, s = {}, {}
    # ddlerp: base mixes (5+1 streams) + low-rank data-dependent part
    p["mu_base"], s["mu_base"] = L.zeros_init((MIX_STREAMS + 1, d),
                                              ("stream", "embed"), dtype)
    p["mix_w1"], s["mix_w1"] = L.dense_init(
        ks[0], (d, MIX_STREAMS * LORA_MIX), ("embed", "mix_lora"), dtype, scale=0.01)
    p["mix_w2"], s["mix_w2"] = L.dense_init(
        ks[1], (MIX_STREAMS, LORA_MIX, d), ("stream", "mix_lora", "embed"),
        dtype, in_axis_sizes=LORA_MIX, scale=0.01)
    # projections
    p["w_r"], s["w_r"] = L.dense_init(ks[2], (d, d), ("embed", "inner"), dtype)
    p["w_k"], s["w_k"] = L.dense_init(ks[3], (d, d), ("embed", "inner"), dtype)
    p["w_v"], s["w_v"] = L.dense_init(ks[4], (d, d), ("embed", "inner"), dtype)
    p["w_g"], s["w_g"] = L.dense_init(ks[5], (d, d), ("embed", "inner"), dtype)
    p["w_o"], s["w_o"] = L.dense_init(ks[6], (d, d), ("inner", "embed"), dtype)
    # data-dependent decay: w_t = exp(-exp(w0 + tanh(x w1) w2))
    p["decay_base"], s["decay_base"] = L.zeros_init((d,), ("inner",), dtype)
    p["decay_w1"], s["decay_w1"] = L.dense_init(
        ks[7], (d, LORA_DECAY), ("embed", "decay_lora"), dtype, scale=0.01)
    p["decay_w2"], s["decay_w2"] = L.dense_init(
        ks[8], (LORA_DECAY, d), ("decay_lora", "inner"), dtype, scale=0.01)
    p["bonus"], s["bonus"] = L.zeros_init((h, hd), ("heads", "head"), dtype)
    # per-head group norm
    p["gn_scale"], s["gn_scale"] = L.ones_init((d,), ("inner",), dtype)
    p["gn_bias"], s["gn_bias"] = L.zeros_init((d,), ("inner",), dtype)
    return p, s


def init_channel_mix(cfg: ModelConfig, key):
    dtype = L._dtype(cfg.param_dtype)
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = L.split_tree(key, 3)
    p, s = {}, {}
    p["mu_k"], s["mu_k"] = L.zeros_init((d,), ("embed",), dtype)
    p["mu_r"], s["mu_r"] = L.zeros_init((d,), ("embed",), dtype)
    p["w_k"], s["w_k"] = L.dense_init(k1, (d, ff), ("embed", "mlp"), dtype)
    p["w_v"], s["w_v"] = L.dense_init(k2, (ff, d), ("mlp", "embed"), dtype)
    p["w_r"], s["w_r"] = L.dense_init(k3, (d, d), ("embed", "inner"), dtype)
    return p, s


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift interpolation -> 5 mixed streams."""
    cdt = x.dtype
    diff = x_prev - x                                           # (B,S,D)
    base = x + diff * p["mu_base"][0].astype(cdt)               # stream 0: probe
    lora = jnp.tanh(jnp.einsum("bsd,dl->bsl", base,
                               p["mix_w1"].astype(cdt)))
    lora = lora.reshape(*lora.shape[:-1], MIX_STREAMS, LORA_MIX)
    delta = jnp.einsum("bsml,mld->bsmd", lora, p["mix_w2"].astype(cdt))
    mu = p["mu_base"][1:].astype(cdt)[None, None] + delta       # (B,S,5,D)
    return x[:, :, None, :] + diff[:, :, None, :] * mu          # (B,S,5,D)


def wkv_scan(r, k, v, w, u, state):
    """Sequential WKV recurrence (reference; Pallas kernel mirrors this).

    r,k,v: (B,S,H,D); w: (B,S,H,D) per-channel decay in (0,1);
    u: (H,D) bonus; state: (B,H,D,D) [key-dim x value-dim].
    Returns (out (B,S,H,D), final state). fp32 state for stability.
    """
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                                   # (B,H,D)
        kv = kt[..., :, None] * vt[..., None, :]                # (B,H,Dk,Dv)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[..., None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    rs, ks, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    state, outs = jax.lax.scan(step, state.astype(jnp.float32),
                               (rs, ks, vs, ws))
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), state


def time_mix_apply(cfg, p, x, x_prev_last, wkv_state, fresh_state=False):
    """x: (B,S,D). x_prev_last: (B,D) state entering this chunk.
    Returns (y, new_x_prev_last, new_wkv_state).

    ``fresh_state`` (static) asserts the incoming ``wkv_state`` is zeros
    (the training path). Only then may the WKV recurrence dispatch to the
    Pallas kernel under an active :func:`repro.models.runtime.kernel_scope`
    — the kernel always starts its recurrence from a zero state; streaming
    chunks (decode, non-zero state) always take the lax.scan path."""
    cdt = x.dtype
    b, s_len, d = x.shape
    h, hd = _heads(cfg), cfg.ssm.head_dim
    x_prev = jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    mixed = _ddlerp(p, x, x_prev)                               # (B,S,5,D)
    xw, xk, xv, xr, xg = (mixed[:, :, i, :] for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(cdt))
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"].astype(cdt))
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"].astype(cdt))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"].astype(cdt)))
    decay = (p["decay_base"].astype(jnp.float32)
             + jnp.einsum("bsl,ld->bsd",
                          jnp.tanh(jnp.einsum("bsd,dl->bsl", xw,
                                              p["decay_w1"].astype(cdt))
                                   ).astype(jnp.float32),
                          p["decay_w2"].astype(jnp.float32)))
    w = jnp.exp(-jnp.exp(decay))                                # (B,S,D) in (0,1)

    rh = r.reshape(b, s_len, h, hd)
    kh = k.reshape(b, s_len, h, hd)
    vh = v.reshape(b, s_len, h, hd)
    wh = w.reshape(b, s_len, h, hd)
    kb = runtime.kernel_backend()
    if kb is not None and fresh_state:
        from repro.kernels import ops as kops
        out, new_state = kops.rwkv6(rh, kh, vh, wh, p["bonus"], backend=kb)
        out = out.astype(cdt)
    else:
        out, new_state = wkv_scan(rh, kh, vh, wh, p["bonus"], wkv_state)
    out = out.reshape(b, s_len, d)

    # per-head group norm
    og = out.reshape(b, s_len, h, hd).astype(jnp.float32)
    mean = jnp.mean(og, axis=-1, keepdims=True)
    var = jnp.var(og, axis=-1, keepdims=True)
    og = (og - mean) * jax.lax.rsqrt(var + 64e-5)
    out = (og.reshape(b, s_len, d) * p["gn_scale"].astype(jnp.float32)
           + p["gn_bias"].astype(jnp.float32)).astype(cdt)
    y = jnp.einsum("bsd,de->bse", out * g, p["w_o"].astype(cdt))
    return y, x[:, -1, :], new_state


def channel_mix_apply(cfg, p, x, x_prev_last):
    cdt = x.dtype
    x_prev = jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    diff = x_prev - x
    xk = x + diff * p["mu_k"].astype(cdt)
    xr = x + diff * p["mu_r"].astype(cdt)
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(cdt))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"].astype(cdt))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(cdt)))
    return r * kv, x[:, -1, :]


def init_block(cfg: ModelConfig, key):
    k1, k2 = L.split_tree(key, 2)
    p, s = {}, {}
    p["ln_time"], s["ln_time"] = L.init_norm(cfg, L._dtype(cfg.param_dtype))
    p["ln_chan"], s["ln_chan"] = L.init_norm(cfg, L._dtype(cfg.param_dtype))
    p["time"], s["time"] = init_time_mix(cfg, k1)
    p["chan"], s["chan"] = init_channel_mix(cfg, k2)
    return p, s


def block_apply(cfg, params, x, state, fresh_state=False):
    """state: {"x_time": (B,D), "x_chan": (B,D), "wkv": (B,H,D,D)}"""
    h = L.apply_norm(cfg, params["ln_time"], x)
    y, x_time, wkv = time_mix_apply(cfg, params["time"], h,
                                    state["x_time"], state["wkv"],
                                    fresh_state=fresh_state)
    x = x + y
    h = L.apply_norm(cfg, params["ln_chan"], x)
    y, x_chan = channel_mix_apply(cfg, params["chan"], h, state["x_chan"])
    x = x + y
    return x, {"x_time": x_time, "x_chan": x_chan, "wkv": wkv}


def init_lm(cfg: ModelConfig, key):
    dtype = L._dtype(cfg.param_dtype)
    k_embed, k_layers, k_head = L.split_tree(key, 3)
    p, s = {}, {}
    p["embed"], s["embed"] = L.dense_init(
        k_embed, (cfg.vocab, cfg.d_model), ("vocab", "embed"), dtype,
        in_axis_sizes=cfg.d_model, scale=cfg.d_model**-0.5)
    p["ln_in"], s["ln_in"] = L.init_norm(cfg, dtype)
    keys = L.split_tree(k_layers, cfg.n_layers)
    ps, ss = [], None
    for i in range(cfg.n_layers):
        bp, bs = init_block(cfg, keys[i])
        ps.append(bp)
        ss = bs
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ps) \
        if len(ps) > 1 else jax.tree.map(lambda v: v[None], ps[0])
    p["layers"] = stacked
    s["layers"] = jax.tree.map(lambda ax: ("layers",) + ax, ss,
                               is_leaf=lambda v: isinstance(v, tuple))
    p["ln_f"], s["ln_f"] = L.init_norm(cfg, dtype)
    p["lm_head"], s["lm_head"] = L.dense_init(
        k_head, (cfg.d_model, cfg.vocab), ("embed", "vocab"), dtype)
    return p, s


def init_state(cfg: ModelConfig, batch: int):
    """Recurrent state for all layers (the decode 'cache')."""
    h, hd = _heads(cfg), cfg.ssm.head_dim
    cdt = L._dtype(cfg.compute_dtype)
    one = {
        "x_time": jnp.zeros((batch, cfg.d_model), cdt),
        "x_chan": jnp.zeros((batch, cfg.d_model), cdt),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }
    state = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (cfg.n_layers,) + v.shape), one)
    specs = {
        "x_time": ("layers", "batch", "embed"),
        "x_chan": ("layers", "batch", "embed"),
        "wkv": ("layers", "batch", "heads", "head", "head_v"),
    }
    return state, specs


def forward(cfg: ModelConfig, params, tokens, state=None, remat=False,
            return_hidden=False):
    """Returns (logits, new_state). state=None -> fresh zeros.

    ``return_hidden`` skips the lm_head matmul and returns the final-norm
    hidden states instead of logits (the fused cross-entropy path)."""
    b = tokens.shape[0]
    fresh = state is None
    if state is None:
        state, _ = init_state(cfg, b)
    cdt = L._dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    x = L.apply_norm(cfg, params["ln_in"], x)

    def body(carry, xs):
        xv = carry
        lp, lstate = xs
        out, nstate = block_apply(cfg, lp, xv, lstate, fresh_state=fresh)
        return out, nstate

    fn = jax.checkpoint(body) if remat else body
    x, new_state = jax.lax.scan(fn, x, (params["layers"], state),
                                unroll=runtime.layer_scan_unroll())
    x = L.apply_norm(cfg, params["ln_f"], x)
    if return_hidden:
        return x, new_state
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits.astype(L._dtype(cfg.logit_dtype)), new_state


def lm_loss(cfg: ModelConfig, params, batch: dict, remat=False):
    kb = runtime.kernel_backend()
    if kb is not None:
        from repro.kernels import ops as kops
        x, _ = forward(cfg, params, batch["tokens"], remat=remat,
                       return_hidden=True)
        b, s, d = x.shape
        nll = kops.cross_entropy(x.reshape(b * s, d),
                                 params["lm_head"].astype(x.dtype),
                                 batch["labels"].reshape(-1), backend=kb)
        return jnp.mean(nll)
    logits, _ = forward(cfg, params, batch["tokens"], remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def serve_step(cfg: ModelConfig, params, state, token, pos=None):
    """O(1) decode: one token through the recurrent state."""
    logits, new_state = forward(cfg, params, token, state=state)
    return logits, new_state
