"""Decoder-only transformer LM (dense / MoE / MLA / VLM families).

Layer parameters are stacked along a leading ``layers`` axis and the forward
pass runs ``lax.scan`` over them — the lowered HLO is depth-independent,
which keeps the 512-device dry-run compiles fast and matches production
practice (MaxText does the same).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import runtime

Params = dict


# --------------------------------------------------------------------------
# one block
# --------------------------------------------------------------------------


def init_block(cfg: ModelConfig, key, layer_idx: int = 0):
    ks = L.split_tree(key, 4)
    p, s = {}, {}
    p["ln_attn"], s["ln_attn"] = L.init_norm(cfg, L._dtype(cfg.param_dtype))
    p["ln_mlp"], s["ln_mlp"] = L.init_norm(cfg, L._dtype(cfg.param_dtype))
    if cfg.attn == "mla":
        p["attn"], s["attn"] = L.init_mla(cfg, ks[0])
    else:
        p["attn"], s["attn"] = L.init_attention(cfg, ks[0])
    if cfg.moe is not None and layer_idx >= cfg.moe.first_dense_layers:
        p["moe"], s["moe"] = L.init_moe(cfg, ks[1])
    else:
        d_ff = (cfg.moe.dense_ff if (cfg.moe is not None and cfg.moe.dense_ff)
                else cfg.d_ff)
        p["mlp"], s["mlp"] = L.init_mlp(cfg, ks[1], d_ff=d_ff)
    return p, s


def block_apply(cfg: ModelConfig, params: Params, x, positions, window=0,
                cache=None, ring=False):
    """Pre-norm block. Returns (x, new_cache, aux_loss)."""
    h = L.apply_norm(cfg, params["ln_attn"], x)
    if cfg.attn == "mla":
        attn_out, new_cache = L.mla_apply(cfg, params["attn"], h, positions,
                                          window=window, cache=cache,
                                          ring=ring)
    else:
        attn_out, new_cache = L.attention_apply(cfg, params["attn"], h,
                                                positions, window=window,
                                                cache=cache, ring=ring)
    x = x + attn_out
    h = L.apply_norm(cfg, params["ln_mlp"], x)
    if "moe" in params:
        mlp_out, aux = L.moe_apply(cfg, params["moe"], h)
    else:
        mlp_out, aux = L.mlp_apply(cfg, params["mlp"], h), jnp.zeros((), jnp.float32)
    return x + mlp_out, new_cache, aux


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------


def _stack_layers(cfg: ModelConfig, key):
    """Init all layers and stack leading 'layers' axis (scan-ready).

    MoE models with ``first_dense_layers > 0`` have heterogeneous layers; we
    split the stack into a dense prefix and a MoE body, each scanned
    separately.
    """
    n_dense_prefix = (cfg.moe.first_dense_layers if cfg.moe is not None else 0)
    groups = []
    if n_dense_prefix:
        groups.append(("prefix", 0, n_dense_prefix))
    groups.append(("body", n_dense_prefix, cfg.n_layers))

    out_p, out_s = {}, {}
    keys = L.split_tree(key, cfg.n_layers)
    for name, lo, hi in groups:
        ps, ss = [], None
        for i in range(lo, hi):
            p, s = init_block(cfg, keys[i], layer_idx=i)
            ps.append(p)
            ss = s
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *ps) \
            if len(ps) > 1 else jax.tree.map(lambda x: x[None], ps[0])
        out_p[name] = stacked
        out_s[name] = jax.tree.map(lambda ax: ("layers",) + ax, ss,
                                   is_leaf=lambda v: isinstance(v, tuple))
    return out_p, out_s


def init_lm(cfg: ModelConfig, key) -> tuple[Params, dict]:
    dtype = L._dtype(cfg.param_dtype)
    k_embed, k_layers, k_head, k_proj = L.split_tree(key, 4)
    p, s = {}, {}
    p["embed"], s["embed"] = L.dense_init(
        k_embed, (cfg.vocab, cfg.d_model), ("vocab", "embed"), dtype,
        in_axis_sizes=cfg.d_model, scale=cfg.d_model**-0.5)
    p["layers"], s["layers"] = _stack_layers(cfg, k_layers)
    p["ln_f"], s["ln_f"] = L.init_norm(cfg, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"], s["lm_head"] = L.dense_init(
            k_head, (cfg.d_model, cfg.vocab), ("embed", "vocab"), dtype)
    if cfg.family == "vlm":
        # two-layer projector from the (stubbed) vision encoder output
        kp1, kp2 = L.split_tree(k_proj, 2)
        p["proj_in"], s["proj_in"] = L.dense_init(
            kp1, (cfg.d_frontend, cfg.d_model), ("frontend", "embed"), dtype)
        p["proj_out"], s["proj_out"] = L.dense_init(
            kp2, (cfg.d_model, cfg.d_model), ("embed", "embed_out"), dtype)
    return p, s


def _embed(cfg: ModelConfig, params, tokens):
    cdt = L._dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    return x


def _project_patches(cfg: ModelConfig, params, patches):
    cdt = L._dtype(cfg.compute_dtype)
    h = jnp.einsum("bpf,fd->bpd", patches.astype(cdt),
                   params["proj_in"].astype(cdt))
    h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("bpd,de->bpe", h, params["proj_out"].astype(cdt))


def _scan_blocks(cfg, stacked, x, positions, window, caches, remat,
                 ring=False):
    """Scan each layer group; returns (x, new_caches, aux_sum)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {} if caches is not None else None

    for name, group in stacked.items():
        group_cache = caches[name] if caches is not None else None

        def body(carry, xs):
            xv, aux = carry
            lp = xs[0]
            lc = xs[1] if group_cache is not None else None
            out, nc, a = block_apply(cfg, lp, xv, positions,
                                     window=window, cache=lc, ring=ring)
            return (out, aux + a), nc

        fn = jax.checkpoint(body) if remat else body
        xs = (group,) if group_cache is None else (group, group_cache)
        (x, aux_total), ncs = jax.lax.scan(
            fn, (x, aux_total), xs, unroll=runtime.layer_scan_unroll())
        if caches is not None:
            new_caches[name] = ncs
    return x, new_caches, aux_total


def forward(cfg: ModelConfig, params: Params, tokens, positions=None,
            patches=None, window=0, remat=False, return_hidden=False):
    """Full-sequence forward. Returns (logits, aux_loss).

    ``return_hidden`` returns the final-norm hidden states ``(B, S, D)``
    instead of logits — the fused cross-entropy path avoids materializing
    the ``(B, S, V)`` logits in HBM (see :func:`lm_loss`)."""
    if positions is None:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, tokens.shape)
    x = _embed(cfg, params, tokens)
    if cfg.family == "vlm":
        assert patches is not None, "vlm forward needs patch embeddings"
        px = _project_patches(cfg, params, patches)
        x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
        n_total = x.shape[1]
        positions = jnp.arange(n_total, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, (x.shape[0], n_total))
    x, _, aux = _scan_blocks(cfg, params["layers"], x, positions, window,
                             None, remat)
    x = L.apply_norm(cfg, params["ln_f"], x)
    if return_hidden:
        return x, aux
    ldt = L._dtype(cfg.logit_dtype)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits.astype(ldt), aux


def lm_loss(cfg: ModelConfig, params: Params, batch: dict, remat=False):
    """Next-token cross entropy (+ MoE aux). batch: tokens, labels[, patches].

    Under an active :func:`repro.models.runtime.kernel_scope` the NLL is
    computed by the fused cross-entropy dispatch
    (:func:`repro.kernels.ops.cross_entropy`) on the final hidden states —
    the ``(B, S, V)`` logits are never materialized."""
    labels = batch["labels"]
    kb = runtime.kernel_backend()
    if kb is not None:
        from repro.kernels import ops as kops
        x, aux = forward(cfg, params, batch["tokens"],
                         patches=batch.get("patches"), remat=remat,
                         return_hidden=True)
        if cfg.family == "vlm":
            # visual positions carry no LM loss; text-tail hidden only
            x = x[:, -labels.shape[1]:]
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"]).astype(x.dtype)
        b, s, d = x.shape
        nll = kops.cross_entropy(x.reshape(b * s, d), w,
                                 labels.reshape(-1), backend=kb)
        return jnp.mean(nll) + aux
    logits, aux = forward(cfg, params, batch["tokens"],
                          patches=batch.get("patches"), remat=remat)
    if cfg.family == "vlm":
        # visual positions carry no LM loss; logits for text tail only
        logits = logits[:, -labels.shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + aux


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, length: int, ring: bool,
               prefill_len: int = 0):
    """Stacked per-layer caches matching the layer groups."""
    maker = L.init_mla_cache if cfg.attn == "mla" else L.init_kv_cache
    groups = {}
    specs = {}
    n_dense_prefix = (cfg.moe.first_dense_layers if cfg.moe is not None else 0)
    sizes = {}
    if n_dense_prefix:
        sizes["prefix"] = n_dense_prefix
    sizes["body"] = cfg.n_layers - n_dense_prefix
    for name, n in sizes.items():
        c, cs = maker(cfg, batch, length, ring, prefill_len)
        groups[name] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape) if
            isinstance(x, jax.Array) else x, c,
            is_leaf=lambda v: not isinstance(v, dict))
        specs[name] = jax.tree.map(
            lambda ax: (("layers",) + ax) if isinstance(ax, tuple) else ax, cs,
            is_leaf=lambda v: isinstance(v, tuple) or v is None or v is True)
    return groups, specs


def serve_step(cfg: ModelConfig, params: Params, cache, token, pos,
               ring: bool = False):
    """Decode one token. token: (B, 1) int32; pos: () int32 absolute position.

    ``ring`` (static) means the cache buffers hold only the last W positions
    (sliding-window long-context decode). Returns (logits (B,1,V), new_cache).
    """
    x = _embed(cfg, params, token)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        positions = jnp.broadcast_to(jnp.reshape(pos, (1, 1)),
                                     (token.shape[0], 1))
    else:                      # (B,): continuous batching, per-slot depth
        positions = pos[:, None]
    window = cfg.sliding_window if ring else 0
    x, new_cache, _ = _scan_blocks(cfg, params["layers"], x, positions,
                                   window, cache, remat=False, ring=ring)
    x = L.apply_norm(cfg, params["ln_f"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits.astype(L._dtype(cfg.logit_dtype)), new_cache


def prefill(cfg: ModelConfig, params: Params, tokens, cache_length: int,
            patches=None):
    """Run the prompt through the model, building a full-buffer cache.

    Implemented as full forward (teacher-forced) followed by cache writes via
    a scan of single-token decodes would be O(S) scans; instead we compute
    K/V for all positions in one pass per layer — reusing block_apply with a
    preallocated cache is equivalent; for simplicity and testability we build
    the cache by running attention in full mode and capturing K/V.

    For the dry-run we only need ``serve_step`` (decode shapes); prefill
    here supports the serving example and parity tests by replaying tokens
    through serve_step under ``lax.scan``.
    """
    b, s = tokens.shape
    cache, _ = init_cache(cfg, batch=b, length=cache_length, ring=False)

    def step(carry, tok_pos):
        c = carry
        tok, p = tok_pos
        logits, c = serve_step(cfg, params, c, tok[:, None], p)
        return c, logits[:, 0]

    toks = jnp.moveaxis(tokens, 1, 0)                      # (S, B)
    poss = jnp.arange(s, dtype=jnp.int32)
    cache, logits = jax.lax.scan(step, cache, (toks, poss))
    return cache, jnp.moveaxis(logits, 0, 1)               # (B, S, V)
