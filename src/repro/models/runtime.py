"""Trace-time model knobs.

SCAN_UNROLL — when True, layer-stack scans fully unroll (lax.scan
unroll=True). Used by the dry-run depth probes: XLA's cost_analysis counts a
while-loop body once regardless of trip count, so per-layer cost deltas are
only measurable on an unrolled module. Time-dimension scans (WKV/SSM) never
unroll. Default False: production lowering keeps the compact scanned HLO.
"""
SCAN_UNROLL = False


def layer_scan_unroll():
    """Value to pass as lax.scan(..., unroll=...) for layer stacks."""
    return True if SCAN_UNROLL else 1


# Attention implementation for full-sequence (train/prefill) paths:
# "reference" — pure-jnp sdpa (default; what the dry-run lowers today)
# "flash"     — the Pallas flash-attention kernel (interpret on CPU,
#               compiled on TPU). Decode paths always use the cache code.
ATTN_IMPL = "reference"
