"""Trace-time model knobs.

SCAN_UNROLL — when True, layer-stack scans fully unroll (lax.scan
unroll=True). Used by the dry-run depth probes: XLA's cost_analysis counts a
while-loop body once regardless of trip count, so per-layer cost deltas are
only measurable on an unrolled module. Time-dimension scans (WKV/SSM) never
unroll. Default False: production lowering keeps the compact scanned HLO.
"""
SCAN_UNROLL = False


def layer_scan_unroll():
    """Value to pass as lax.scan(..., unroll=...) for layer stacks."""
    return True if SCAN_UNROLL else 1


# Attention implementation for full-sequence (train/prefill) paths:
# "reference" — pure-jnp sdpa (default; what the dry-run lowers today)
# "flash"     — the Pallas flash-attention kernel (interpret on CPU,
#               compiled on TPU). Decode paths always use the cache code.
ATTN_IMPL = "reference"


# Kernel backend for the model forward/backward hot paths (attention, WKV,
# selective scan, fused cross-entropy). ``None`` (default) keeps every model
# on its plain jnp code — bitwise-identical to the pre-dispatch program.
# "ref" routes the hot paths through the :mod:`repro.kernels.ops` wrappers
# pinned to the jnp oracles (the parity baseline); "pallas" reaches the
# Pallas kernels (interpret on CPU, compiled on TPU) with oracle-vjp
# backward passes, so the same loss is differentiable end-to-end.
#
# Like SCAN_UNROLL/ATTN_IMPL this is a *trace-time* knob: enter
# :func:`kernel_scope` inside the function being traced (the FL task
# factory wraps its loss/eval bodies — see ``repro.federated.tasks``), and
# a jitted program bakes in whatever was active when it was traced.
KERNEL_BACKEND: str | None = None


def kernel_backend() -> str | None:
    """The active model-kernel backend (``None`` = plain jnp model code)."""
    return KERNEL_BACKEND


import contextlib as _contextlib


@_contextlib.contextmanager
def kernel_scope(backend: str | None):
    """Pin the model-kernel backend inside the ``with`` block (trace time).

    ``kernel_scope(None)`` is a no-op context (the plain-model default),
    so callers can thread an optional backend without branching.
    """
    global KERNEL_BACKEND
    prev = KERNEL_BACKEND
    KERNEL_BACKEND = backend
    try:
        yield
    finally:
        KERNEL_BACKEND = prev
