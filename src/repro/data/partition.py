"""Client data partitioning for FL.

The paper divides 50k CIFAR samples "randomly but fairly" (iid) across N
clients. With synthetic stateless data the partition is a (client, seed)
keying scheme; this module adds the classic index-based partitioner for
array-backed datasets plus a Dirichlet non-iid option (framework extension,
used in the ablation example).
"""
from __future__ import annotations

import numpy as np

__all__ = ["iid_partition", "dirichlet_partition"]


def iid_partition(n_samples: int, n_clients: int, seed: int = 0) -> list[np.ndarray]:
    """Shuffle and split evenly; remainder spread one-per-client."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_samples)
    return [np.sort(part) for part in np.array_split(idx, n_clients)]


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float = 0.5,
                        seed: int = 0) -> list[np.ndarray]:
    """Label-skewed non-iid partition (Dirichlet over class proportions)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards: list[list[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for shard, part in zip(shards, np.split(idx, cuts)):
            shard.extend(part.tolist())
    return [np.sort(np.array(s, dtype=np.int64)) for s in shards]
