"""Client data partitioning for FL.

The paper divides 50k CIFAR samples "randomly but fairly" (iid) across N
clients. With synthetic stateless data the partition is a (client, seed)
keying scheme; this module adds the classic index-based partitioner for
array-backed datasets plus a Dirichlet non-iid option (framework extension,
used in the ablation example).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["iid_partition", "dirichlet_partition", "pad_shards",
           "sharded_client_data", "sharded_client_arrays"]


def iid_partition(n_samples: int, n_clients: int, seed: int = 0) -> list[np.ndarray]:
    """Shuffle and split evenly; remainder spread one-per-client."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_samples)
    return [np.sort(part) for part in np.array_split(idx, n_clients)]


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float = 0.5,
                        seed: int = 0) -> list[np.ndarray]:
    """Label-skewed non-iid partition (Dirichlet over class proportions)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards: list[list[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for shard, part in zip(shards, np.split(idx, cuts)):
            shard.extend(part.tolist())
    return [np.sort(np.array(s, dtype=np.int64)) for s in shards]


def pad_shards(parts: Sequence[np.ndarray]) -> np.ndarray:
    """Stack ragged per-client index shards into one ``(N, maxlen)`` array.

    Shorter shards wrap around (``np.resize``) so every client exposes the
    same shard length — the fixed shape the campaign engine needs to
    ``vmap`` local training across clients.

    Raises:
        ValueError: if any shard is empty — ``np.resize`` would silently
            turn it into all-zero indices, i.e. train that client on
            sample 0 of the *global* dataset (easy to hit with strongly
            skewed :func:`dirichlet_partition` draws on small datasets).
    """
    empty = [i for i, p in enumerate(parts) if len(p) == 0]
    if empty:
        raise ValueError(
            f"clients {empty} have empty shards; re-partition (larger "
            f"dataset, higher alpha, or different seed) — padding would "
            f"silently map them to global sample 0")
    maxlen = max(len(p) for p in parts)
    return np.stack([np.resize(np.asarray(p), maxlen) for p in parts])


def sharded_client_data(images, labels, parts: Sequence[np.ndarray], *,
                        seed: int = 1):
    """Per-node data-shard API for the scan-fused campaign engine.

    Materializes an arbitrary (iid or non-iid) index partition into the
    ``client_data(cid, round, batch, steps)`` callback the engine vmaps
    over clients — each node samples minibatches *only from its own shard*,
    so label-skewed fleets (:func:`dirichlet_partition`) plug straight into
    :func:`repro.federated.campaign.run_campaigns` with no hand-rolled
    masking.

    Args:
        images / labels: full dataset arrays, leading axis = samples.
        parts: per-client index shards (e.g. from :func:`iid_partition` or
            :func:`dirichlet_partition`); padded to equal length via
            :func:`pad_shards`.
        seed: PRNG seed of the per-(client, round) minibatch sampling.

    Returns:
        ``client_data(cid, rnd, n, steps)`` returning a batch pytree with
        leaves of shape ``(steps, n, ...)`` (leading axis = local steps),
        deterministic in ``(seed, cid, rnd)`` and safe to call under
        ``vmap`` with a traced ``cid``.
    """
    return sharded_client_arrays({"images": images, "labels": labels},
                                 parts, seed=seed)


def sharded_client_arrays(arrays: dict, parts: Sequence[np.ndarray], *,
                          seed: int = 1):
    """Generalization of :func:`sharded_client_data` to any batch pytree.

    ``arrays`` is a dict of dataset arrays sharing the sample axis (e.g.
    ``{"tokens": (N, S), "labels": (N, S)}`` for LM corpora). Minibatch
    indices are drawn *once* per (client, round) and applied to every
    array, so the image/label special case is bitwise-identical to the
    historical two-argument form.
    """
    shards = pad_shards(parts)
    maxlen = shards.shape[1]
    sharded = {k: jnp.asarray(np.asarray(v)[shards]) for k, v in arrays.items()}

    def client_data(cid, rnd, n, steps):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), cid), rnd)
        idx = jax.random.randint(key, (steps, n), 0, maxlen)
        return {k: v[cid][idx] for k, v in sharded.items()}

    return client_data
