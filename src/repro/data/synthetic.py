"""Deterministic synthetic datasets (offline container: no CIFAR download).

Two flavors:

* ``SyntheticCifar`` — a learnable 10-class image task replacing CIFAR-10 for
  the paper's FL simulations: class templates + per-sample noise. Signal
  strength is tuned so a small CNN reaches >0.73 "validation accuracy" within
  tens of FedAvg rounds (mirrors the paper's T_acc = 0.73 on real CIFAR).
* ``SyntheticLM`` — a Zipf-ish Markov token stream for the LM architectures
  (cluster examples, smoke tests).

All sampling is stateless-deterministic in (seed, index) so every FL client
regenerates identical shards with no data files.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticCifar", "SyntheticLM"]


@dataclasses.dataclass(frozen=True)
class SyntheticCifar:
    n_classes: int = 10
    image_shape: tuple = (32, 32, 3)
    noise: float = 0.8          # template SNR; higher = harder
    n_train: int = 50_000       # paper: 50k train
    n_val: int = 7_000          # paper: 7k validation
    seed: int = 0

    def _templates(self) -> jax.Array:
        key = jax.random.PRNGKey(self.seed)
        return jax.random.normal(key, (self.n_classes, *self.image_shape))

    def batch(self, key: jax.Array, n: int) -> dict:
        """Sample n examples: template[label] + noise."""
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (n,), 0, self.n_classes)
        noise = jax.random.normal(k2, (n, *self.image_shape)) * self.noise
        images = self._templates()[labels] + noise
        return {"images": images, "labels": labels}

    def val_set(self, n: int | None = None) -> dict:
        n = n or min(self.n_val, 1024)
        return self.batch(jax.random.PRNGKey(self.seed + 10_007), n)

    def client_batch(self, client_id: int, round_idx: int, n: int) -> dict:
        """Deterministic per-(client, round) shard — the iid partition."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), client_id),
            round_idx)
        return self.batch(key, n)

    def dataset(self, n: int) -> dict:
        """Materialize a fixed n-sample dataset (for index partitioning).

        Unlike the stateless per-(client, round) streams, non-iid splits
        (:func:`repro.data.partition.dirichlet_partition`) need a concrete
        sample axis to partition. Deterministic in ``seed`` and disjoint
        from the stream/val RNG keys.
        """
        return self.batch(jax.random.PRNGKey(self.seed + 20_011), n)


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int = 512
    order_weight: float = 0.7   # how predictable the stream is
    seed: int = 0

    def batch(self, key: jax.Array, batch: int, seq: int) -> dict:
        """Markov-ish stream: next token = f(prev) with noise."""
        k1, k2 = jax.random.split(key)
        # deterministic successor table
        succ = jax.random.permutation(jax.random.PRNGKey(self.seed),
                                      self.vocab)
        start = jax.random.randint(k1, (batch, 1), 0, self.vocab)
        noise = jax.random.uniform(k2, (batch, seq)) > self.order_weight
        rand = jax.random.randint(jax.random.fold_in(k2, 1),
                                  (batch, seq), 0, self.vocab)

        def step(tok, inputs):
            noisy, rnd = inputs
            nxt = jnp.where(noisy, rnd, succ[tok])
            return nxt, nxt

        _, seq_toks = jax.lax.scan(
            step, start[:, 0], (jnp.moveaxis(noise, 1, 0),
                                jnp.moveaxis(rand, 1, 0)))
        toks = jnp.moveaxis(seq_toks, 0, 1)                  # (B, S)
        tokens = jnp.concatenate([start, toks[:, :-1]], axis=1)
        return {"tokens": tokens, "labels": toks}

    def client_batch(self, client_id: int, step: int, batch: int, seq: int) -> dict:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), client_id), step)
        return self.batch(key, batch, seq)

    def dataset(self, n: int, seq: int) -> dict:
        """Materialize a fixed n-sequence corpus (for index partitioning).

        Non-iid splits bucket sequences by a class surrogate; LM streams
        have no labels, so :func:`repro.federated.tasks.model_task` derives
        one from the leading token. Deterministic in ``seed``, disjoint key
        from the per-(client, step) streams.
        """
        return self.batch(jax.random.PRNGKey(self.seed + 20_011), n, seq)

    def val_set(self, n: int, seq: int) -> dict:
        return self.batch(jax.random.PRNGKey(self.seed + 10_007), n, seq)
