"""Stackelberg participation pricing: a leader sets a per-participation
reward, followers play the induced symmetric game.

Related work (Khan et al., arXiv:1911.05642) designs Stackelberg incentives
for edge FL; here the leader is the sink. It commits to a reward rate r paid
per expected participation (utility ``+ r·p_i``), which the followers
perceive as a cost reduction c → c - r. The leader anticipates the *worst*
induced equilibrium p(r) and picks r on a grid — one batched solve for the
whole follower-game family — to minimize

    J(r) = social_cost(p(r)) + budget_weight · r · p(r)

(social cost priced at the true c; the reward is a transfer). With
``target_poa`` set, the leader instead picks the *cheapest* r whose worst NE
is within the efficiency target — the budget-minimal subsidy.

The report converts the duration saving into energy via the calibrated
per-round energy model (Table I/II), closing the loop to the paper's
headline metric: planner expenditure (utility units/round) vs. Wh saved per
task.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.duration import DurationModel
from repro.core.energy import EnergyParams, J_PER_WH, expected_task_energy
from repro.core.utility import UtilityParams
from repro.mechanisms.base import Mechanism, MechanismReport, evaluate_mechanism
from repro.mechanisms.batched import BatchedGameSolution, binom_pmf, solve_batched

__all__ = [
    "ParticipationRewardMechanism",
    "StackelbergPlanner",
    "StackelbergSolution",
]


@dataclasses.dataclass(frozen=True)
class ParticipationRewardMechanism(Mechanism):
    """Pay r per unit of (expected) participation: u_i += r·p_i."""

    rate: float
    name: str = "participation_reward"

    def induced_params(self, base: UtilityParams) -> UtilityParams:
        return dataclasses.replace(base, cost=base.cost - self.rate)

    def transfer(self, p: float, base: UtilityParams) -> float:
        return self.rate * p


@dataclasses.dataclass(frozen=True)
class StackelbergSolution:
    """Leader's choice plus the follower-game family it was chosen from."""

    rate: float                   # chosen reward r*
    report: MechanismReport       # full mechanism report at r*
    baseline_cost: float          # worst-NE social cost at r = 0
    energy_saved_wh: float        # per-task expected energy vs r = 0
    planner_spend_per_round: float  # N · r* · p(r*) (utility units)
    rate_grid: np.ndarray         # leader's r grid (diagnostics)
    worst_ne_grid: np.ndarray     # true-cost-worst induced NE p(r) per rate
    social_cost_grid: np.ndarray  # its social cost E[D] + c·p along the grid

    @property
    def cost_saved(self) -> float:
        return self.baseline_cost - self.report.ne_cost


@dataclasses.dataclass(frozen=True)
class StackelbergPlanner:
    """Grid-search leader over per-participation reward rates.

    Attributes:
        rate_max: top of the r grid as a fraction of the scenario cost c
            (r = c fully rebates the energy cost; going a bit beyond allows
            net subsidies).
        n_rates: grid resolution — the whole follower family is one batched
            solve, so this is cheap.
        budget_weight: λ ≥ 0 — how much one unit of planner budget per node
            per round weighs against one unit of social cost.
        target_poa: if set, pick the cheapest r meeting it instead of
            minimizing J.
    """

    rate_max_frac: float = 1.25
    n_rates: int = 128
    budget_weight: float = 0.0
    target_poa: float | None = None
    energy_params: EnergyParams = dataclasses.field(
        default_factory=EnergyParams)

    def follower_family(self, base: UtilityParams,
                        dur: DurationModel, **kw) -> tuple[np.ndarray,
                                                           BatchedGameSolution]:
        rates = np.linspace(0.0, self.rate_max_frac * max(base.cost, 1e-6),
                            self.n_rates)
        sol = solve_batched(jnp.full((self.n_rates,), base.gamma),
                            base.cost - jnp.asarray(rates), dur, **kw)
        return rates, sol

    def solve(self, base: UtilityParams, dur: DurationModel,
              **solver_kwargs) -> StackelbergSolution:
        rates, fam = self.follower_family(base, dur, **solver_kwargs)
        # True social cost: the solver priced the followers at c - r, so add
        # the transfer back (solver cost + r·p = E[D] + c·p) — for *every*
        # induced NE, then take the worst. (The solver's worst_ne is worst
        # under the induced cost; re-pricing can reorder multi-NE rows.)
        eqs = np.asarray(fam.equilibria)                      # (R, K)
        mask = np.asarray(fam.ne_mask)
        s_all = np.where(
            mask, np.asarray(fam.ne_costs) + rates[:, None] * eqs, -np.inf)
        worst = np.argmax(s_all, axis=1)                      # (R,)
        p_ne = np.take_along_axis(eqs, worst[:, None], axis=1)[:, 0]
        s_true = np.take_along_axis(s_all, worst[:, None], axis=1)[:, 0]
        s_true = np.where(mask.any(axis=1), s_true, np.inf)

        if self.target_poa is not None:
            opt_cost = float(fam.opt_cost[0])  # c is the true cost at r=0
            ok = s_true <= self.target_poa * max(opt_cost, 1e-12)
            idx = int(np.argmax(ok)) if ok.any() else int(np.argmin(s_true))
        else:
            objective = np.where(
                np.isfinite(p_ne),
                s_true + self.budget_weight * rates * p_ne, np.inf)
            idx = int(np.argmin(objective))
        rate = float(rates[idx])

        mech = ParticipationRewardMechanism(rate=rate)
        report = evaluate_mechanism(mech, base, dur)
        baseline_cost = float(s_true[0])

        # Energy saved per task vs the r = 0 status quo: E[D]·E[round energy]
        # at the respective worst equilibria (eq. 7 via Fig. 1 linearity).
        e_star = self._task_energy_wh(report.ne_p, dur, base.n_nodes)
        e_base = self._task_energy_wh(float(p_ne[0]), dur, base.n_nodes)
        return StackelbergSolution(
            rate=rate,
            report=report,
            baseline_cost=baseline_cost,
            energy_saved_wh=e_base - e_star,
            planner_spend_per_round=base.n_nodes * rate * report.ne_p,
            rate_grid=rates,
            worst_ne_grid=p_ne,
            social_cost_grid=s_true,
        )

    def _task_energy_wh(self, p: float, dur: DurationModel,
                        n_nodes: int) -> float:
        if not np.isfinite(p):
            return float("inf")
        e_d = float(binom_pmf(jnp.asarray(p), n_nodes) @ dur.table())
        e_j = expected_task_energy(
            jnp.full((n_nodes,), p), jnp.asarray(e_d), self.energy_params)
        return float(e_j) / J_PER_WH
