"""Batched symmetric-NE + centralized-optimum solver (one XLA program).

Mechanism design needs the game solved *thousands* of times — a γ-grid for
AoI calibration, an r-grid for Stackelberg pricing, (γ, c, N) scenario
sweeps. The scalar solver in :mod:`repro.core.game` runs Python-level
bisection with eager JAX scalars (~100 dispatches per root); here the whole
pipeline is fixed-shape `lax` control flow, jitted once and batched over B
scenarios:

1. the symmetric marginal φ(p) = ∂u_i/∂p_i|_{p_i=p_-i=p} is evaluated in
   closed form on a shared action grid (the Binomial(N-1, p) opponent pmf and
   the duration table are the only ingredients — no Poisson-Binomial DFT, no
   autodiff);
2. interior equilibria are sign changes of φ refined by a fixed-iteration
   vectorized bisection; corner equilibria keep the scalar solver's
   semantics (p = P_MIN is an NE iff φ(P_MIN) ≤ 0, p = P_MAX iff φ(P_MAX) ≥ 0);
3. the centralized optimum is a grid argmin of the social cost E[D] + c·p
   refined by a fixed-iteration vectorized golden section.

Everything is (B,)- or (B, K)-shaped with NaN/mask padding so the program
has static shapes; `repro.core.game.solve_game` delegates here with B = 1.

Derivation of φ (see ``symmetric_player_utility``): with the other N-1 nodes
at p, E[D] is *linear* in p_i, slope Δe(p) = E[d(m+1)] - E[d(m)] with
m ~ Binomial(N-1, p); the AoI term -γ·log(1/p_i - 1/2) has derivative
-γ·(-2/(p_i(2-p_i))); the cost term contributes -c.  Hence

    φ(p) = -Δe(p) + 2γ / (p(2-p)) - c.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammaln, xlog1py, xlogy

from repro.core.duration import DurationModel
from repro.core.game import P_MAX, P_MIN
from repro.core.utility import UtilityParams

__all__ = [
    "BatchedGameSolution",
    "binom_pmf",
    "batched_phi",
    "solve_batched",
    "solve_scenarios",
]

_NE_CAP = 1e6       # PoA cap, matches repro.core.game.price_of_anarchy
_DEDUP_TOL = 1e-4   # root-merging tolerance, matches solve_symmetric_ne


def binom_pmf(p: jax.Array, n: int) -> jax.Array:
    """Binomial(n, p) pmf over k = 0..n, batched over leading dims of ``p``.

    Stable at the p = 0 / p = 1 corners via xlogy/xlog1py (0·log 0 = 0).
    Shape: ``p (...,) -> (..., n+1)``.
    """
    k = jnp.arange(n + 1, dtype=p.dtype)
    log_comb = (gammaln(n + 1.0) - gammaln(k + 1.0) - gammaln(n - k + 1.0))
    log_pmf = log_comb + xlogy(k, p[..., None]) + xlog1py(n - k, -p[..., None])
    return jnp.exp(log_pmf)


def batched_phi(
    p: jax.Array,
    gammas: jax.Array,
    costs: jax.Array,
    d_tab: jax.Array,
) -> jax.Array:
    """φ(p) for a (B, ...) batch of symmetric profiles.

    Args:
        p: ``(B, M)`` evaluation points (or ``(B,)``).
        gammas / costs: ``(B,)`` scenario parameters.
        d_tab: ``(N+1,)`` duration table d(k).
    """
    n = d_tab.shape[0] - 1
    squeeze = p.ndim == 1
    if squeeze:
        p = p[:, None]
    pmf_others = binom_pmf(p, n - 1)                      # (B, M, N)
    delta_e = pmf_others @ (d_tab[1:] - d_tab[:-1])       # (B, M)
    phi = (-delta_e + 2.0 * gammas[:, None] / (p * (2.0 - p))
           - costs[:, None])
    return phi[:, 0] if squeeze else phi


@dataclasses.dataclass(frozen=True)
class BatchedGameSolution:
    """Fixed-shape solution of B simultaneous games.

    ``equilibria``/``ne_costs`` are ``(B, K)`` NaN-padded ascending arrays
    (slot 0 = the P_MIN corner, last slot = the P_MAX corner, interior roots
    between); ``ne_mask`` marks valid slots. Costs are the social cost
    E[D] + c·p of eq. (13) — worst/best NE and PoA are precomputed.
    """

    gammas: jax.Array      # (B,)
    costs: jax.Array       # (B,)
    equilibria: jax.Array  # (B, K) NaN-padded
    ne_mask: jax.Array     # (B, K) bool
    ne_costs: jax.Array    # (B, K) NaN-padded
    worst_ne: jax.Array    # (B,) argmax-cost NE (NaN when no NE)
    best_ne: jax.Array     # (B,) argmin-cost NE
    worst_ne_cost: jax.Array  # (B,)
    best_ne_cost: jax.Array   # (B,)
    opt_p: jax.Array       # (B,)
    opt_cost: jax.Array    # (B,)
    poa: jax.Array         # (B,) inf when no NE

    @property
    def batch(self) -> int:
        return int(self.poa.shape[0])

    def equilibria_list(self, i: int) -> list[float]:
        mask = np.asarray(self.ne_mask[i])
        return [float(x) for x in np.asarray(self.equilibria[i])[mask]]

    def ne_costs_list(self, i: int) -> list[float]:
        mask = np.asarray(self.ne_mask[i])
        return [float(x) for x in np.asarray(self.ne_costs[i])[mask]]


@functools.partial(
    jax.jit,
    static_argnames=("ne_grid", "opt_grid", "max_roots", "bisect_iters",
                     "golden_iters"))
def _solve_batched(
    gammas: jax.Array,
    costs: jax.Array,
    d_tab: jax.Array,
    *,
    ne_grid: int,
    opt_grid: int,
    max_roots: int,
    bisect_iters: int,
    golden_iters: int,
) -> dict[str, jax.Array]:
    n = d_tab.shape[0] - 1
    batch = gammas.shape[0]

    # ---- equilibria: φ on the grid, corners, vectorized bisection ----------
    grid = jnp.linspace(P_MIN, P_MAX, ne_grid)
    # Δe(p) is scenario-independent: share it across the batch.
    delta_e_grid = binom_pmf(grid, n - 1) @ (d_tab[1:] - d_tab[:-1])  # (G,)
    aoi_grid = 2.0 / (grid * (2.0 - grid))                            # (G,)
    phi_grid = (-delta_e_grid[None, :] + gammas[:, None] * aoi_grid[None, :]
                - costs[:, None])                                     # (B, G)

    corner_lo = phi_grid[:, 0] <= 0.0
    corner_hi = phi_grid[:, -1] >= 0.0

    sign = jnp.sign(phi_grid)
    crossing = sign[:, :-1] * sign[:, 1:] < 0.0                       # (B, G-1)
    cell = jnp.arange(ne_grid - 1)
    # First `max_roots` crossing cells per scenario; sentinel = ne_grid.
    cand = jnp.where(crossing, cell[None, :], ne_grid)
    cand = jnp.sort(cand, axis=1)[:, :max_roots]                      # (B, K)
    root_valid = cand < ne_grid
    cell_idx = jnp.minimum(cand, ne_grid - 2)
    lo = grid[cell_idx]
    hi = grid[cell_idx + 1]
    f_lo = jnp.take_along_axis(phi_grid, cell_idx, axis=1)

    def bisect_body(_, carry):
        lo, hi, f_lo = carry
        mid = 0.5 * (lo + hi)
        f_mid = batched_phi(mid, gammas, costs, d_tab)
        same_side = (f_mid > 0.0) == (f_lo > 0.0)
        return (jnp.where(same_side, mid, lo),
                jnp.where(same_side, hi, mid),
                jnp.where(same_side, f_mid, f_lo))

    lo, hi, _ = jax.lax.fori_loop(0, bisect_iters, bisect_body,
                                  (lo, hi, f_lo))
    roots = 0.5 * (lo + hi)                                           # (B, K)

    # Corner-NE dedup (scalar solver registers corners first, then skips any
    # interior root within _DEDUP_TOL of an already-found equilibrium).
    root_valid = root_valid & ~(
        corner_lo[:, None] & (jnp.abs(roots - grid[0]) < _DEDUP_TOL))
    root_valid = root_valid & ~(
        corner_hi[:, None] & (jnp.abs(roots - grid[-1]) < _DEDUP_TOL))
    for j in range(1, max_roots):
        for i in range(j):
            dup = (root_valid[:, i]
                   & (jnp.abs(roots[:, j] - roots[:, i]) < _DEDUP_TOL))
            root_valid = root_valid.at[:, j].set(root_valid[:, j] & ~dup)

    # Assemble ascending [P_MIN corner, interior roots..., P_MAX corner].
    eq = jnp.concatenate([
        jnp.full((batch, 1), grid[0]), roots, jnp.full((batch, 1), grid[-1]),
    ], axis=1)                                                        # (B, K+2)
    mask = jnp.concatenate([
        corner_lo[:, None], root_valid, corner_hi[:, None]], axis=1)

    # ---- social costs at the equilibria ------------------------------------
    e_d_at = binom_pmf(eq, n) @ d_tab                                  # (B, K+2)
    ne_cost = e_d_at + costs[:, None] * eq
    any_ne = jnp.any(mask, axis=1)
    worst_i = jnp.argmax(jnp.where(mask, ne_cost, -jnp.inf), axis=1)
    best_i = jnp.argmin(jnp.where(mask, ne_cost, jnp.inf), axis=1)

    # ---- centralized optimum: grid argmin + golden section -----------------
    g2 = jnp.linspace(P_MIN, P_MAX, opt_grid)
    e_d_grid = binom_pmf(g2, n) @ d_tab                                # (G2,)
    cost_grid = e_d_grid[None, :] + costs[:, None] * g2[None, :]       # (B, G2)
    i_min = jnp.argmin(cost_grid, axis=1)
    a = g2[jnp.maximum(i_min - 1, 0)]
    b = g2[jnp.minimum(i_min + 1, opt_grid - 1)]

    def social(p):  # (B,) social cost E[D] + c p
        return binom_pmf(p, n) @ d_tab + costs * p

    invphi = (np.sqrt(5.0) - 1.0) / 2.0
    c_ = b - invphi * (b - a)
    d_ = a + invphi * (b - a)
    f_c, f_d = social(c_), social(d_)

    def golden_body(_, carry):
        a, b, c_, d_, f_c, f_d = carry
        shrink_right = f_c < f_d          # minimum in [a, d]
        a2 = jnp.where(shrink_right, a, c_)
        b2 = jnp.where(shrink_right, d_, b)
        c2 = jnp.where(shrink_right, b2 - invphi * (b2 - a2), d_)
        d2 = jnp.where(shrink_right, c_, a2 + invphi * (b2 - a2))
        probe = jnp.where(shrink_right, c2, d2)
        f_probe = social(probe)
        f_c2 = jnp.where(shrink_right, f_probe, f_d)
        f_d2 = jnp.where(shrink_right, f_c, f_probe)
        return a2, b2, c2, d2, f_c2, f_d2

    a, b, *_ = jax.lax.fori_loop(0, golden_iters, golden_body,
                                 (a, b, c_, d_, f_c, f_d))
    opt_p = 0.5 * (a + b)
    opt_cost = social(opt_p)

    # ---- PoA (eq. 13) -------------------------------------------------------
    worst_cost = jnp.max(jnp.where(mask, ne_cost, -jnp.inf), axis=1)
    best_cost = jnp.min(jnp.where(mask, ne_cost, jnp.inf), axis=1)
    poa = jnp.minimum(worst_cost / jnp.maximum(opt_cost, 1e-12), _NE_CAP)
    poa = jnp.where(any_ne, poa, jnp.inf)

    nan = jnp.nan
    take = lambda arr, idx: jnp.take_along_axis(arr, idx[:, None], 1)[:, 0]
    return {
        "equilibria": jnp.where(mask, eq, nan),
        "ne_mask": mask,
        "ne_costs": jnp.where(mask, ne_cost, nan),
        "worst_ne": jnp.where(any_ne, take(eq, worst_i), nan),
        "best_ne": jnp.where(any_ne, take(eq, best_i), nan),
        "worst_ne_cost": jnp.where(any_ne, worst_cost, nan),
        "best_ne_cost": jnp.where(any_ne, best_cost, nan),
        "opt_p": opt_p,
        "opt_cost": opt_cost,
        "poa": poa,
    }


def solve_batched(
    gammas: jax.Array,
    costs: jax.Array,
    dur: DurationModel | jax.Array,
    *,
    ne_grid: int = 400,
    opt_grid: int = 2000,
    max_roots: int = 4,
    bisect_iters: int = 60,
    golden_iters: int = 40,
) -> BatchedGameSolution:
    """Solve B scenarios (γ_b, c_b) sharing one duration model, in one jit.

    Args:
        gammas / costs: ``(B,)`` UtilityParams weights per scenario.
        dur: a :class:`DurationModel` or a raw ``(N+1,)`` duration table.
        ne_grid / opt_grid: φ-grid and social-cost-grid resolutions (match
            ``solve_game``'s scalar defaults).
        max_roots: interior-equilibrium slots per scenario (K+2 total with
            corners); extra sign changes beyond this are dropped.
    """
    d_tab = dur.table() if isinstance(dur, DurationModel) else jnp.asarray(dur)
    gammas = jnp.atleast_1d(jnp.asarray(gammas, d_tab.dtype))
    costs = jnp.atleast_1d(jnp.asarray(costs, d_tab.dtype))
    if gammas.shape != costs.shape:
        raise ValueError(f"gammas {gammas.shape} vs costs {costs.shape}")
    out = _solve_batched(gammas, costs, d_tab, ne_grid=ne_grid,
                         opt_grid=opt_grid, max_roots=max_roots,
                         bisect_iters=bisect_iters, golden_iters=golden_iters)
    return BatchedGameSolution(gammas=gammas, costs=costs, **out)


def solve_scenarios(
    scenarios: list[UtilityParams],
    dur_for_n: dict[int, DurationModel],
    **solver_kwargs,
) -> list[BatchedGameSolution]:
    """(γ, c, N) sweep: group scenarios by N (shapes are static per N) and
    run one batched solve per group.

    Returns one :class:`BatchedGameSolution` per distinct N, in ascending-N
    order; each carries its scenarios in the original relative order.
    """
    by_n: dict[int, list[UtilityParams]] = {}
    for s in scenarios:
        by_n.setdefault(s.n_nodes, []).append(s)
    out = []
    for n in sorted(by_n):
        group = by_n[n]
        out.append(solve_batched(
            jnp.asarray([s.gamma for s in group]),
            jnp.asarray([s.cost for s in group]),
            dur_for_n[n], **solver_kwargs))
    return out
