"""Heterogeneous-population incentive calibration on the batched engine.

:mod:`repro.mechanisms.aoi_reward` calibrates the AoI weight γ* for the
paper's *identical-node* game. Real IoT fleets are not identical — battery
sensors and mains gateways face different participation costs — and the
interesting incentive questions (free-rider stratification, who the reward
actually moves, heterogeneous PoA) only appear once costs spread. This
module answers the heterogeneous design question directly:

    Given a heterogeneous cost vector ``c`` (and optional base weights
    ``γ₀``), find the smallest **uniform** AoI weight γ* — one reward
    schedule for the whole fleet, no price discrimination — whose induced
    asymmetric NE has social cost within ``target_poa`` of the
    heterogeneity-aware planner.

Search mirrors :func:`repro.mechanisms.aoi_reward.calibrate_gamma`: one
vmapped :func:`repro.core.asymmetric_batched.poa_report` over a coarse
γ-grid localizes the first crossing (every grid point solved, certified,
and benchmarked in a single XLA program), then bisection refines inside the
crossing cell. PoA(γ) is not monotone — over-incentivization pushes cheap
nodes past the planner's corner profile — so *first* crossing, not any
crossing, and the unreachable-target fallback returns the best γ seen
(which may be γ = 0, i.e. "no mechanism").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.asymmetric_batched import HeterogeneousPoA, poa_report
from repro.core.duration import DurationModel
from repro.mechanisms.aoi_reward import AoIRewardMechanism

__all__ = ["HeterogeneousCalibration", "calibrate_gamma_heterogeneous"]


@dataclasses.dataclass(frozen=True)
class HeterogeneousCalibration:
    """Outcome of :func:`calibrate_gamma_heterogeneous`."""

    mechanism: AoIRewardMechanism
    gamma_star: float
    poa: float                    # heterogeneous PoA at gamma_star
    deviation: float              # NE certification at gamma_star
    target_poa: float
    achieved: bool                # False: target unreachable below gamma_max
    grid_gammas: jnp.ndarray      # coarse-scan γ values (diagnostics)
    grid_poas: jnp.ndarray        # heterogeneous PoA along the scan
    grid_report: HeterogeneousPoA # full batched report of the scan


def _scan_report(costs, base_gammas, gammas, dur, **solver_kwargs):
    """poa_report over a γ-grid: B = len(gammas) scenarios, shared costs."""
    b = gammas.shape[0]
    n = costs.shape[0]
    costs_b = jnp.broadcast_to(costs, (b, n))
    gammas_b = base_gammas[None, :] + gammas[:, None]
    return poa_report(costs_b, gammas_b, dur, **solver_kwargs)


def calibrate_gamma_heterogeneous(
    costs: jax.Array,
    dur: DurationModel,
    *,
    base_gammas: jax.Array | float = 0.0,
    target_poa: float = 1.05,
    gamma_max: float = 5.0,
    coarse: int = 32,
    bisect_iters: int = 16,
    **solver_kwargs,
) -> HeterogeneousCalibration:
    """Smallest uniform γ* hitting a heterogeneous-PoA target.

    Args:
        costs: ``(N,)`` heterogeneous per-node cost factors.
        dur: duration model shared by the fleet.
        base_gammas: pre-existing per-node AoI weights γ₀ (scalar or
            ``(N,)``); γ* is *added uniformly* on top.
        target_poa: 1 + ε efficiency target for the induced asymmetric NE
            against the heterogeneity-aware planner.
        gamma_max: search ceiling; if even γ_max misses the target the
            result reports ``achieved=False`` with the best γ seen.
        coarse: γ-grid size of the single vmapped localization solve.
        solver_kwargs: forwarded to the batched engine (damping, max_iters,
            tol, verify_grid, planner_rounds).
    """
    costs = jnp.asarray(costs)
    n = costs.shape[0]
    base = jnp.broadcast_to(jnp.asarray(base_gammas, costs.dtype), (n,))
    gammas = jnp.linspace(0.0, gamma_max, coarse)
    rep = _scan_report(costs, base, gammas, dur, **solver_kwargs)
    # Unconverged scenarios are not certified equilibria: exclude them.
    poas = jnp.where(rep.solution.converged, rep.poa, jnp.inf)
    ok = poas <= target_poa

    def _result(gamma_star, poa, dev, achieved):
        return HeterogeneousCalibration(
            mechanism=AoIRewardMechanism(gamma_star=float(gamma_star)),
            gamma_star=float(gamma_star), poa=float(poa),
            deviation=float(dev), target_poa=target_poa, achieved=achieved,
            grid_gammas=gammas, grid_poas=poas, grid_report=rep)

    if not bool(jnp.any(ok)):
        best = int(jnp.argmin(poas))
        return _result(gammas[best], poas[best], rep.deviation[best],
                       achieved=False)

    first = int(jnp.argmax(ok))   # first grid γ meeting the target
    hi = float(gammas[first])
    hi_poa = float(poas[first])
    hi_dev = float(rep.deviation[first])
    if first > 0:
        lo = float(gammas[first - 1])
        # Bisect the crossing cell: invariant poa(hi) ≤ target < poa(lo).
        for _ in range(bisect_iters):
            mid = 0.5 * (lo + hi)
            mrep = _scan_report(costs, base, jnp.asarray([mid]), dur,
                                **solver_kwargs)
            mid_ok = (bool(mrep.solution.converged[0])
                      and float(mrep.poa[0]) <= target_poa)
            if mid_ok:
                hi, hi_poa = mid, float(mrep.poa[0])
                hi_dev = float(mrep.deviation[0])
            else:
                lo = mid
    return _result(hi, hi_poa, hi_dev, achieved=True)
