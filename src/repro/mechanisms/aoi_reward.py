"""AoI-reward mechanism: *choose* the incentive weight instead of sweeping it.

The paper sweeps γ and eyeballs that γ ≈ 0.6 keeps PoA near 1 (Figs. 3/6).
Here the planner solves the design problem directly: given (c, N), find the
smallest γ* whose *worst* induced NE has social cost within ``target_gap`` of
the centralized optimum. Smallest matters twice — the AoI reward is paid by
the sink (budget grows with γ), and over-incentivization pushes participation
past the optimum (the Fig. 2 utility falls beyond its peak), so PoA(γ) is not
monotone: we want the first crossing, not any crossing.

Search: one batched solve over a coarse γ-grid localizes the first γ cell
achieving the target, then bisection (batched solver, B = 1) refines inside
that cell. Total cost is two-ish XLA dispatches plus ~20 tiny ones — versus
thousands of eager scalar solves for the same sweep pre-batching.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.aoi import log_aoi
from repro.core.duration import DurationModel
from repro.core.game import P_MIN
from repro.core.utility import UtilityParams
from repro.mechanisms.base import Mechanism
from repro.mechanisms.batched import solve_batched

__all__ = ["AoIRewardMechanism", "CalibrationResult", "calibrate_gamma"]


@dataclasses.dataclass(frozen=True)
class AoIRewardMechanism(Mechanism):
    """Pay each node γ*·(log E[δ(P_MIN)] - log E[δ(p)]) per round.

    Up to the additive constant γ*·log E[δ(P_MIN)] — which does not move any
    best response — this is exactly the paper's eq. (11) AoI term with weight
    γ*; the constant shift makes the transfer ≥ 0 (a node that never
    participates is paid nothing) so the planner budget is well defined.
    """

    gamma_star: float
    name: str = "aoi_reward"

    def induced_params(self, base: UtilityParams) -> UtilityParams:
        return dataclasses.replace(base, gamma=base.gamma + self.gamma_star)

    def transfer(self, p: float, base: UtilityParams) -> float:
        return self.gamma_star * float(
            log_aoi(jnp.asarray(P_MIN)) - log_aoi(jnp.asarray(p)))


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Outcome of :func:`calibrate_gamma`."""

    mechanism: AoIRewardMechanism
    gamma_star: float
    poa: float                    # worst-NE PoA at gamma_star
    target_poa: float
    achieved: bool                # False: target unreachable below gamma_max
    grid_gammas: jnp.ndarray      # coarse-scan γ values (diagnostics)
    grid_poas: jnp.ndarray        # worst-NE PoA along the scan


def _worst_poa(gamma, base: UtilityParams, dur, **kw) -> float:
    sol = solve_batched(jnp.asarray([gamma]), jnp.asarray([base.cost]),
                        dur, **kw)
    return float(sol.poa[0])


def calibrate_gamma(
    base: UtilityParams,
    dur: DurationModel,
    *,
    target_poa: float = 1.05,
    gamma_max: float = 5.0,
    coarse: int = 64,
    bisect_iters: int = 24,
    **solver_kwargs,
) -> CalibrationResult:
    """Smallest γ* with worst-NE social cost ≤ target_poa · optimum.

    Args:
        base: the scenario's (γ₀, c, N); γ* is *added* on top of base.gamma
            (normally 0 — the planner owns the whole incentive).
        target_poa: 1 + ε efficiency target for the worst induced NE.
        gamma_max: search ceiling; if even γ_max misses the target the
            result reports ``achieved=False`` with γ* = γ_max.
        coarse: γ-grid size of the single batched localization solve.
    """
    gammas = jnp.linspace(0.0, gamma_max, coarse)
    scan = solve_batched(base.gamma + gammas,
                         jnp.full((coarse,), base.cost), dur,
                         **solver_kwargs)
    ok = scan.poa <= target_poa
    if not bool(jnp.any(ok)):
        # Target unreachable below gamma_max: fall back to the best γ seen
        # (which may be γ = 0, i.e. "no mechanism" — over-incentivization can
        # make every γ > 0 strictly worse), never to a degrading γ_max.
        best = int(jnp.argmin(scan.poa))
        mech = AoIRewardMechanism(gamma_star=float(gammas[best]))
        return CalibrationResult(
            mechanism=mech, gamma_star=float(gammas[best]),
            poa=float(scan.poa[best]), target_poa=target_poa, achieved=False,
            grid_gammas=gammas, grid_poas=scan.poa)
    first = int(jnp.argmax(ok))  # first grid γ meeting the target
    hi = float(gammas[first])
    hi_poa = float(scan.poa[first])
    if first == 0:
        lo = 0.0
    else:
        lo = float(gammas[first - 1])
        # Bisect the first crossing cell: invariant poa(hi) ≤ target < poa(lo).
        for _ in range(bisect_iters):
            mid = 0.5 * (lo + hi)
            mid_poa = _worst_poa(base.gamma + mid, base, dur, **solver_kwargs)
            if mid_poa <= target_poa:
                hi, hi_poa = mid, mid_poa
            else:
                lo = mid
    mech = AoIRewardMechanism(gamma_star=hi)
    return CalibrationResult(
        mechanism=mech, gamma_star=hi, poa=hi_poa, target_poa=target_poa,
        achieved=True, grid_gammas=gammas, grid_poas=scan.poa)
