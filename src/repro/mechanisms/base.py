"""Mechanism protocol: transfer rule → induced game → PoA/budget/IR report.

The paper stops at measuring PoA ≥ 1.28 and argues for "incentive
mechanisms, possibly based on Age of Information" (§V). This module is the
shared contract for such mechanisms:

* a mechanism modifies each player's utility via a transfer paid by the
  sink/planner (``induced_params`` — the transfer shows up as utility terms,
  e.g. the AoI reward weight γ or a per-participation price r);
* the *induced* game is solved for its symmetric equilibria (batched solver
  under the hood via ``solve_game``);
* the report judges the mechanism the way a planner would: worst-NE social
  cost against the **no-mechanism** centralized optimum (transfers net out
  of welfare, so the optimum is mechanism-invariant), the planner's expected
  per-round expenditure, and individual rationality at the induced NE.

Pessimism convention: all guarantees are stated for the *worst-cost* induced
equilibrium — a mechanism only "closes the PoA gap" if even its worst NE is
near-optimal.
"""
from __future__ import annotations

import abc
import dataclasses

import jax.numpy as jnp

from repro.core.duration import DurationModel
from repro.core.game import P_MIN, centralized_optimum, solve_game
from repro.core.utility import (UtilityParams, social_cost,
                                symmetric_player_utility)

__all__ = ["Mechanism", "MechanismReport", "evaluate_mechanism"]

IR_TOL = 1e-6


@dataclasses.dataclass(frozen=True)
class MechanismReport:
    """Planner-facing evaluation of a mechanism on one (γ, c, N) scenario."""

    mechanism: str
    base_params: UtilityParams
    induced_params: UtilityParams
    equilibria: list[float]        # induced symmetric NEs (ascending)
    ne_costs: list[float]          # social cost E[D] + c·p at each NE
    ne_p: float                    # worst-cost induced NE (pessimistic pick)
    ne_cost: float                 # its social cost
    opt_p: float                   # no-mechanism centralized optimum
    opt_cost: float
    poa: float                     # worst induced NE vs centralized optimum
    transfer_per_node: float       # expected per-round transfer at ne_p
    planner_budget: float          # N * transfer_per_node
    ir_slack: float                # u(NE) - u(opt-out) under induced utility
    individually_rational: bool

    @property
    def optimality_gap(self) -> float:
        """Relative social-cost excess of the worst induced NE."""
        return self.ne_cost / max(self.opt_cost, 1e-12) - 1.0


class Mechanism(abc.ABC):
    """A transfer rule the planner commits to before the game is played."""

    name: str = "mechanism"

    @abc.abstractmethod
    def induced_params(self, base: UtilityParams) -> UtilityParams:
        """Utility weights the players face once the transfer is in place."""

    @abc.abstractmethod
    def transfer(self, p: float, base: UtilityParams) -> float:
        """Expected per-round transfer to one node playing p (≥ 0)."""

    def evaluate(self, base: UtilityParams,
                 dur: DurationModel) -> MechanismReport:
        return evaluate_mechanism(self, base, dur)


def evaluate_mechanism(
    mech: Mechanism,
    base: UtilityParams,
    dur: DurationModel,
) -> MechanismReport:
    """Solve the induced game and grade ``mech`` against the first best.

    The social cost and centralized optimum use the *base* cost c (the
    transfer is money changing hands, not energy burned), while equilibria
    come from the induced utilities the players actually best-respond to.
    """
    induced = mech.induced_params(base)
    sol = solve_game(induced, dur)
    # The optimum depends only on the true cost c (transfers net out of
    # welfare), so it is mechanism-invariant.
    opt_p, opt_cost = centralized_optimum(base, dur)
    # Social cost of eq. (13) likewise uses the true private cost c: re-price
    # the induced equilibria when the mechanism altered the cost term
    # (e.g. a per-participation reward r shifts c -> c - r for the players).
    ne_costs = [
        float(social_cost(jnp.asarray(p), base, dur)) for p in sol.equilibria]
    if sol.equilibria:
        worst = max(range(len(sol.equilibria)), key=lambda i: ne_costs[i])
        ne_p, ne_cost = sol.equilibria[worst], ne_costs[worst]
        poa = min(ne_cost / max(opt_cost, 1e-12), 1e6)
        transfer = float(mech.transfer(ne_p, base))
        # IR: at the induced NE, a node must weakly prefer playing ne_p over
        # the opt-out action P_MIN (never participate, keep the idle payoff).
        # An NE is a global best response, so slack ≥ 0 up to solver
        # tolerance — the report states it numerically rather than by fiat.
        u_eq = float(symmetric_player_utility(
            jnp.asarray(ne_p), jnp.asarray(ne_p), induced, dur))
        u_out = float(symmetric_player_utility(
            jnp.asarray(P_MIN), jnp.asarray(ne_p), induced, dur))
        ir_slack = u_eq - u_out
    else:
        ne_p, ne_cost, poa = float("nan"), float("nan"), float("inf")
        transfer = 0.0
        ir_slack = float("-inf")

    return MechanismReport(
        mechanism=mech.name,
        base_params=base,
        induced_params=induced,
        equilibria=sol.equilibria,
        ne_costs=ne_costs,
        ne_p=ne_p,
        ne_cost=ne_cost,
        opt_p=opt_p,
        opt_cost=opt_cost,
        poa=poa,
        transfer_per_node=transfer,
        planner_budget=base.n_nodes * transfer,
        ir_slack=ir_slack,
        individually_rational=ir_slack >= -IR_TOL,
    )
