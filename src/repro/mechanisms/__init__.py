"""Incentive-mechanism design on top of the participation game.

The paper measures PoA ≥ 1.28 for distributed participatory FL and argues
for incentive mechanisms "possibly based on Age of Information" (§V). This
subsystem closes that gap constructively:

* :mod:`repro.mechanisms.batched` — jit/vmap-style batched symmetric-NE +
  centralized-optimum solver (pure ``lax`` control flow; B scenarios per
  XLA program). ``repro.core.game.solve_game`` delegates here.
* :mod:`repro.mechanisms.base` — the :class:`Mechanism` contract: transfer
  rule → induced game → worst-NE PoA, planner budget, IR check.
* :mod:`repro.mechanisms.aoi_reward` — calibrates the smallest AoI weight
  γ* hitting a PoA target (bisection over the batched solver).
* :mod:`repro.mechanisms.stackelberg` — leader/follower per-participation
  pricing; reports planner expenditure vs. energy saved.
* :mod:`repro.mechanisms.heterogeneous` — smallest *uniform* γ* hitting a
  PoA target for a **heterogeneous** cost vector, on the batched
  asymmetric-NE engine (:mod:`repro.core.asymmetric_batched`).
* :mod:`repro.mechanisms.coalition` — coalition formation as a
  *structural* mechanism: certified partition equilibria
  (:mod:`repro.core.coalition`) benchmarked against the grand-coalition
  NE and the coalition-structured planner.
"""
import repro.core  # noqa: F401  (enables x64 before any game math)

from repro.mechanisms.base import (  # noqa: E402,F401
    Mechanism,
    MechanismReport,
    evaluate_mechanism,
)
from repro.mechanisms.batched import (  # noqa: E402,F401
    BatchedGameSolution,
    batched_phi,
    binom_pmf,
    solve_batched,
    solve_scenarios,
)
from repro.mechanisms.aoi_reward import (  # noqa: E402,F401
    AoIRewardMechanism,
    CalibrationResult,
    calibrate_gamma,
)
from repro.mechanisms.stackelberg import (  # noqa: E402,F401
    ParticipationRewardMechanism,
    StackelbergPlanner,
    StackelbergSolution,
)
from repro.mechanisms.heterogeneous import (  # noqa: E402,F401
    HeterogeneousCalibration,
    calibrate_gamma_heterogeneous,
)
from repro.mechanisms.coalition import (  # noqa: E402,F401
    CoalitionReport,
    coalition_report,
)
