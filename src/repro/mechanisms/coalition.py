"""Coalition formation as a mechanism: does splitting the fleet help?

The paper's mechanisms (AoI rewards, Stackelberg pricing) change the
*utilities* of one big game. Coalition formation changes the *structure*
instead: the operator fixes a number of pooled FedAvg groups (and
optionally a per-group cap) and lets nodes sort themselves — each
coalition trains its own model with its members' participation at the
coalition-internal heterogeneous NE, and nodes switch groups while any
unilateral switch is profitable (:mod:`repro.core.coalition`).

:func:`coalition_report` evaluates that design point: it solves and
certifies the partition equilibrium, benchmarks it against the
coalition-structured planner (partition PoA), and against the *grand
coalition* — the existing single-game heterogeneous NE — so the
"formation gain" (grand-coalition social cost minus partition social
cost) directly answers whether the structural mechanism beats the status
quo for a given fleet.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.asymmetric_batched import (social_cost_batched,
                                           solve_heterogeneous)
from repro.core.coalition import PartitionPoA, partition_poa_report
from repro.core.duration import DurationModel

__all__ = ["CoalitionReport", "coalition_report"]


@dataclasses.dataclass
class CoalitionReport:
    """Batched evaluation of a coalition-formation design point.

    Attributes:
        partition: the :class:`~repro.core.coalition.PartitionPoA` bundle
            (equilibrium partition, certification, planner benchmark).
        certified: ``(B,)`` bool — no node can gain more than ``cert_tol``
            by any in-coalition deviation or coalition switch.
        grand_p: ``(B, N)`` heterogeneous NE of the one-group game (the
            status-quo baseline every mechanism in this package competes
            against).
        grand_cost: ``(B,)`` social cost of that grand-coalition NE.
        formation_gain: ``(B,)`` ``grand_cost - partition.ne_cost`` —
            positive when letting the fleet split into coalitions lowers
            social cost versus keeping one big federation.
    """

    partition: PartitionPoA
    certified: jax.Array
    grand_p: jax.Array
    grand_cost: jax.Array
    formation_gain: jax.Array

    @property
    def batch(self) -> int:
        return self.partition.batch

    def summary(self, i: int = 0) -> dict:
        """Scalar diagnostics for scenario ``i``."""
        return {
            "n_coalitions": int(self.partition.solution.n_coalitions),
            "sizes": [int(s) for s in self.partition.solution.sizes[i]],
            "certified": bool(self.certified[i]),
            "max_deviation": float(self.partition.deviation[i]),
            "ne_cost": float(self.partition.ne_cost[i]),
            "opt_cost": float(self.partition.opt_cost[i]),
            "poa": float(self.partition.poa[i]),
            "grand_cost": float(self.grand_cost[i]),
            "formation_gain": float(self.formation_gain[i]),
        }


def coalition_report(
    costs: jax.Array,
    gammas: jax.Array,
    dur: DurationModel | jax.Array,
    *,
    n_coalitions: int,
    cap: jax.Array | int | None = None,
    cert_tol: float = 1e-6,
    verify_grid: int = 64,
    planner_rounds: int = 20,
    **solver_kwargs,
) -> CoalitionReport:
    """Solve, certify, and benchmark a batch of coalition-formation games.

    Args:
        costs / gammas: per-node ``(B, N)`` (or broadcastable) game
            parameters, as for
            :func:`repro.core.coalition.solve_partition`.
        dur: shared :class:`~repro.core.duration.DurationModel` (or a raw
            duration table).
        n_coalitions: number of coalition slots M (static).
        cap: per-coalition membership cap (scalar or ``(B,)``).
        cert_tol: certification bar on the verified max profitable
            deviation/switch gain.
        verify_grid / planner_rounds / solver_kwargs: forwarded to
            :func:`repro.core.coalition.partition_poa_report` (tighten the
            inner ``tol`` when certifying against a small ``cert_tol`` —
            the within-coalition deviation bound tracks the inner solver
            tolerance).

    Returns:
        A :class:`CoalitionReport`.
    """
    rep = partition_poa_report(costs, gammas, dur,
                               n_coalitions=n_coalitions, cap=cap,
                               verify_grid=verify_grid,
                               planner_rounds=planner_rounds,
                               **solver_kwargs)
    inner_kw = {k: solver_kwargs[k] for k in ("damping", "max_iters", "tol")
                if k in solver_kwargs}
    grand = solve_heterogeneous(rep.solution.costs, rep.solution.gammas,
                                dur, **inner_kw)
    grand_cost = social_cost_batched(rep.solution.costs, dur, grand.p)
    return CoalitionReport(
        partition=rep,
        certified=rep.deviation <= cert_tol,
        grand_p=grand.p,
        grand_cost=grand_cost,
        formation_gain=grand_cost - rep.ne_cost,
    )
