"""Scan-fused multi-scenario FL campaign engine.

The paper's headline artifacts (Table II, Figs. 4-5) are *sweeps*: full
FedAvg campaigns repeated over participation probabilities or (gamma, cost)
game settings. :func:`repro.federated.simulation.run_simulation_reference`
runs one scenario per call through a Python round loop — fine as a test
oracle, hopeless for a 32+-scenario sweep (per-round dispatch overhead times
rounds times scenarios).

Here the whole campaign is one XLA program:

* one **round** = draw Bernoulli masks → vmap local training → masked
  FedAvg merge → validation → :class:`EnergyLedger` update →
  :class:`ConvergenceTracker` update → :class:`AoITracker` update;
* the round loop is a ``lax.scan`` with all trackers in the carry.
  Convergence cannot break a fixed-shape scan, so post-convergence rounds
  are masked to accounting no-ops (model frozen, ledger/tracker/AoI
  untouched) — realized energy, participation, and AoI therefore match the
  early-stopping reference exactly;
* a batch of scenarios — per-scenario ``p`` vectors (or probabilities
  resolved from a (gamma, cost) grid via
  :meth:`repro.core.controller.ParticipationController.solve_batched`),
  seeds, and energy rates — is ``jax.vmap``-ed over the scanned campaign.

``benchmarks/campaign_sweep.py`` measures the result: a Table II-style
sweep compiles to one jitted program and runs orders of magnitude faster
than looping the reference.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.aoi import AoITracker
from repro.core.energy import J_PER_WH, EnergyLedger, EnergyParams
from repro.federated.client import make_local_train
from repro.federated.server import ConvergenceTracker, fedavg_merge
from repro.optim.base import Optimizer

__all__ = ["CampaignResult", "build_campaign", "run_campaigns"]


def _tree_select(cond: jax.Array, on_true, on_false):
    """Leafwise ``where`` — keeps scan carries type-stable under masking."""
    return jax.tree.map(lambda t, f: jnp.where(cond, t, f), on_true, on_false)


@dataclasses.dataclass
class CampaignResult:
    """Batched outcome of B scan-fused campaigns (leading axis B).

    ``acc_history``/``k_history`` are full ``(B, max_rounds)`` arrays;
    post-convergence entries repeat the last converged accuracy and report
    0 participants (the masked no-op rounds). Slice ``[:rounds[i]]`` for the
    realized trajectory of scenario ``i``.
    """

    p: jax.Array                 # (B, N) per-node participation probability
    seeds: jax.Array             # (B,)
    converged_at: jax.Array      # (B,) round index or -1
    converged: jax.Array         # (B,) bool
    rounds: jax.Array            # (B,) realized rounds (early stop honoured)
    energy_wh: jax.Array         # (B,) realized task energy
    acc_history: jax.Array       # (B, R)
    k_history: jax.Array         # (B, R) participants per round
    participation_rate: jax.Array  # (B,) mean realized participation
    per_node_aoi: jax.Array      # (B, N) realized mean age per node
    mean_aoi: jax.Array          # (B,) fleet-mean realized AoI
    ledger: EnergyLedger         # batched (leaves carry leading B axis)
    aoi: AoITracker              # batched

    @property
    def batch(self) -> int:
        return int(self.rounds.shape[0])

    def scenario_ledger(self, i: int) -> EnergyLedger:
        """The i-th scenario's ledger as an unbatched :class:`EnergyLedger`."""
        return jax.tree.map(lambda leaf: leaf[i], self.ledger)

    def summary(self, i: int) -> dict[str, Any]:
        s = self.scenario_ledger(i).summary()
        s.update(converged=bool(self.converged[i]),
                 rounds=int(self.rounds[i]),
                 mean_aoi=float(self.mean_aoi[i]))
        return s


def build_campaign(
    fl,
    init_params: Callable[[jax.Array], dict],
    loss_fn: Callable,
    eval_fn: Callable,
    client_data: Callable,
    val_batch: dict,
    opt: Optimizer,
):
    """Compile the campaign engine for one task definition.

    Args mirror :func:`repro.federated.simulation.run_simulation`; ``fl`` is
    an :class:`~repro.federated.simulation.FLConfig` (``max_rounds`` fixes
    the static scan length).

    Returns a jitted ``fn(p, seeds, e_participant_j, e_idle_j)`` mapping
    ``(B, N)`` probabilities, ``(B,)`` seeds, and ``(B,)`` per-round joule
    rates to the raw batched scan state (dict of params/ledger/tracker/aoi/
    accs/ks). Use :func:`run_campaigns` for the friendly wrapper.
    """
    n = fl.n_clients
    train_one = make_local_train(loss_fn, opt)

    def one_campaign(p_vec, seed, e_participant_j, e_idle_j):
        key = jax.random.PRNGKey(seed)
        state0 = (
            init_params(jax.random.fold_in(key, 1)),
            EnergyLedger.create(n),
            ConvergenceTracker.create(fl.target_acc, fl.consecutive),
            AoITracker.create(n),
            jnp.zeros((), jnp.float64),          # last recorded accuracy
        )

        def round_step(carry, r):
            params, ledger, tracker, aoi, last_acc = carry
            active = ~tracker.converged
            # Same RNG stream as the Python-loop reference: masks (and hence
            # energy/participation/AoI) are bitwise-identical per round.
            rng = jax.random.fold_in(key, 10_000 + r)
            mask = jax.random.bernoulli(rng, p_vec, (n,))
            batches = jax.vmap(
                lambda cid: client_data(cid, r, fl.batch_per_client,
                                        fl.local_steps))(jnp.arange(n))
            client_params, _ = jax.vmap(train_one, in_axes=(None, 0))(
                params, batches)
            merged = fedavg_merge(params, client_params, mask)
            acc = eval_fn(merged, val_batch)

            new_carry = (
                _tree_select(active, merged, params),
                _tree_select(active,
                             ledger.record_round_j(mask, e_participant_j,
                                                   e_idle_j), ledger),
                tracker.masked_update(acc, jnp.asarray(r, jnp.int32), active),
                _tree_select(active, aoi.update(mask), aoi),
                jnp.where(active, acc, last_acc),
            )
            k = jnp.where(active, jnp.sum(jnp.asarray(mask, jnp.int32)), 0)
            return new_carry, (new_carry[-1], k)

        (params, ledger, tracker, aoi, _), (accs, ks) = jax.lax.scan(
            round_step, state0, jnp.arange(fl.max_rounds))
        return {"params": params, "ledger": ledger, "tracker": tracker,
                "aoi": aoi, "accs": accs, "ks": ks}

    return jax.jit(jax.vmap(one_campaign))


def _energy_rates(energy, batch: int) -> tuple[jax.Array, jax.Array]:
    if energy is None:
        energy = EnergyParams()
    if isinstance(energy, EnergyParams):
        energy = [energy] * batch
    if len(energy) != batch:
        raise ValueError(f"{len(energy)} EnergyParams for {batch} scenarios")
    e_part = jnp.asarray([e.e_participant_j for e in energy], jnp.float64)
    e_idle = jnp.asarray([e.e_idle_j for e in energy], jnp.float64)
    return e_part, e_idle


def run_campaigns(
    fl,
    init_params: Callable[[jax.Array], dict],
    loss_fn: Callable,
    eval_fn: Callable,
    client_data: Callable,
    val_batch: dict,
    opt: Optimizer,
    p: jax.Array,
    *,
    energy: EnergyParams | Sequence[EnergyParams] | None = None,
    seeds: Sequence[int] | jax.Array | None = None,
    engine: Callable | None = None,
) -> CampaignResult:
    """Run B FedAvg campaigns as one jitted scan+vmap program.

    Args:
        p: scenario participation — scalar, ``(B,)`` symmetric
            probabilities, or ``(B, N)`` per-node vectors.
        energy: one shared :class:`EnergyParams` or one per scenario.
        seeds: per-scenario PRNG seeds (default: ``fl.seed`` for all — the
            scenarios then share model init and data streams, isolating the
            effect of ``p``).
        engine: a prebuilt :func:`build_campaign` program. Pass it when
            sweeping repeatedly over one task so the XLA compile is paid
            once (a fresh engine is built — and traced — per call
            otherwise).
    """
    n = fl.n_clients
    # Preserve the caller's p dtype: bernoulli draws its uniforms in p's
    # dtype, so coercion here would change masks vs the reference loop.
    p_arr = jnp.atleast_1d(jnp.asarray(p))
    if p_arr.ndim == 1:
        p_arr = jnp.broadcast_to(p_arr[:, None], (p_arr.shape[0], n))
    batch = p_arr.shape[0]
    seeds = (jnp.full((batch,), fl.seed, jnp.uint32) if seeds is None
             else jnp.asarray(seeds, jnp.uint32))
    if seeds.shape != (batch,):
        raise ValueError(f"seeds {seeds.shape} for {batch} scenarios")
    e_part, e_idle = _energy_rates(energy, batch)

    fn = engine if engine is not None else build_campaign(
        fl, init_params, loss_fn, eval_fn, client_data, val_batch, opt)
    out = fn(p_arr, seeds, e_part, e_idle)

    tracker, ledger, aoi = out["tracker"], out["ledger"], out["aoi"]
    converged = tracker.converged_at >= 0
    rounds = jnp.where(converged, tracker.converged_at + 1, fl.max_rounds)
    per_node_aoi = aoi.per_node_aoi
    return CampaignResult(
        p=p_arr,
        seeds=seeds,
        converged_at=tracker.converged_at,
        converged=converged,
        rounds=rounds,
        energy_wh=jnp.sum(ledger.per_node_j, axis=-1) / J_PER_WH,
        acc_history=out["accs"],
        k_history=out["ks"],
        participation_rate=jnp.mean(
            ledger.participation_counts
            / jnp.maximum(ledger.rounds, 1)[:, None], axis=-1),
        per_node_aoi=per_node_aoi,
        mean_aoi=aoi.mean_aoi,
        ledger=ledger,
        aoi=aoi,
    )
