"""Scan-fused multi-scenario FL campaign engine (heterogeneous fleets).

The paper's headline artifacts (Table II, Figs. 4-5) are *sweeps*: full
FedAvg campaigns repeated over participation probabilities or (gamma, cost)
game settings. :func:`repro.federated.simulation.run_simulation_reference`
runs one scenario per call through a Python round loop — fine as a test
oracle, hopeless for a 32+-scenario sweep (per-round dispatch overhead times
rounds times scenarios).

Here the whole campaign is one XLA program:

* one **round** = (optional churn draw: arrival/departure masks update the
  fleet-presence carry) → draw Bernoulli participation masks (``& present``)
  → vmap local training → masked FedAvg merge → validation →
  :class:`EnergyLedger` update → :class:`ConvergenceTracker` update →
  :class:`AoITracker` update;
* the round loop is a ``lax.scan`` with all trackers (and, under churn, the
  presence mask + per-node presence counts) in the carry. Convergence
  cannot break a fixed-shape scan, so post-convergence rounds are masked to
  accounting no-ops (model frozen, ledger/tracker/AoI/presence untouched) —
  realized energy, participation, and AoI therefore match the
  early-stopping reference exactly;
* a batch of scenarios — per-scenario **or per-node** ``p`` (shape ``(B,)``
  or ``(B, N)``; heterogeneous profiles come straight from
  :meth:`repro.core.controller.ParticipationController.solve_batched` in
  its heterogeneous mode), seeds, and energy rates (scalar-per-scenario
  ``(B,)`` or per-node ``(B, N)`` Joules/round) — is ``jax.vmap``-ed over
  the scanned campaign.

This is the first place the game layer's full heterogeneity (per-node
costs/γ, certified asymmetric equilibria, stratified fleets) reaches the FL
runtime: the engine replays a ``(B, N)`` probability *matrix*, meters
per-node energy at per-node rates, and models node churn, while constant
rows with scalar rates and no churn reproduce the symmetric engine
bitwise (pinned in ``tests/test_hetero_campaign.py``).

``benchmarks/campaign_sweep.py`` and
``benchmarks/heterogeneous_campaign.py`` measure the result: Table II-style
and stratified-fleet sweeps compile to one jitted program and run orders of
magnitude faster than looping the per-node Python reference
(:func:`repro.federated.simulation.run_heterogeneous_reference`).

The round's FedAvg merge dispatches through the kernel layer:
``backend="pallas"`` routes it to the fused Pallas merge kernel
(:mod:`repro.kernels.fedavg_agg`), the default ``"ref"`` keeps the pure-jnp
merge and its bitwise-reproducible results — see ``docs/kernels.md``.

The engine is observable in-flight (``docs/observability.md``): pass an
:class:`repro.obs.ObsConfig` to record a per-round
:class:`repro.obs.MetricStream` (participants, merge norms, ledger deltas,
accuracy) in the scan carry and/or stream per-round events to a host
:class:`repro.obs.EventSink` via ``jax.debug.callback``. Observability is
off by default and ``obs=None`` builds the identical program — the bitwise
pins are unaffected; even enabled, the instrumentation only adds outputs
(RNG streams and results are untouched, pinned in ``tests/test_obs.py``).

See ``docs/architecture.md`` for the layer diagram and the scan-carry /
reference-oracle conventions, and ``docs/api.md`` for runnable snippets.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.aoi import AoITracker
from repro.core.energy import J_PER_WH, EnergyLedger, EnergyParams
from repro.federated.client import make_local_train
from repro.federated.participation import round_mask
from repro.federated.server import ConvergenceTracker, fedavg_merge
from repro.obs import ObsConfig
from repro.obs.metrics import MetricStream, merge_norm
from repro.optim.base import Optimizer

__all__ = ["CampaignResult", "ChurnConfig", "DeadlineConfig",
           "build_campaign", "run_campaigns"]

# RNG stream offsets shared with the reference simulators — masks (and hence
# ledgers/AoI) are bitwise-comparable between engine and oracle.
MASK_STREAM = 10_000      # participation Bernoulli draws, one fold per round
CHURN_STREAM = 20_000     # arrival/departure draws, one fold per round
DEADLINE_STREAM = 30_000  # straggler/deadline-miss draws, one fold per round


def _tree_select(cond: jax.Array, on_true, on_false):
    """Leafwise ``where`` — keeps scan carries type-stable under masking."""
    return jax.tree.map(lambda t, f: jnp.where(cond, t, f), on_true, on_false)


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Per-round fleet churn: a 2-state Markov chain per node.

    At the start of every round each *present* node departs with
    probability ``departure`` and each *absent* node (re-)arrives with
    probability ``arrival``; the updated presence mask then gates
    participation (``mask = Bernoulli(p) & present``). Departed nodes
    accrue idle-only energy (they are still powered IoT devices) and their
    AoI is frozen (no fresh information is expected of them) — the
    invariants pinned in ``tests/test_hetero_campaign.py``.

    Attributes:
        arrival: per-round (re-)arrival probability — scalar, ``(N,)``,
            ``(B, 1)``, or ``(B, N)`` (broadcast to ``(B, N)``).
        departure: per-round departure probability, same shapes.
        present0: initial presence (bool, broadcastable to ``(B, N)``);
            default: everyone starts in the fleet.

    ``ChurnConfig()`` (all defaults) is the no-churn identity: presence
    stays all-true and participation masks equal the churn-free engine's.
    """

    arrival: Any = 0.0
    departure: Any = 0.0
    present0: Any = True

    def as_arrays(self, batch: int, n: int) -> tuple[jax.Array, ...]:
        """Broadcast to the engine's ``(B, N)`` inputs."""
        arr = jnp.broadcast_to(
            jnp.atleast_2d(jnp.asarray(self.arrival, jnp.float64)), (batch, n))
        dep = jnp.broadcast_to(
            jnp.atleast_2d(jnp.asarray(self.departure, jnp.float64)),
            (batch, n))
        pres = jnp.broadcast_to(
            jnp.atleast_2d(jnp.asarray(self.present0, bool)), (batch, n))
        return arr, dep, pres


@dataclasses.dataclass(frozen=True)
class DeadlineConfig:
    """Per-round straggler model: a node wins the participation lottery but
    misses the round deadline with probability ``miss``.

    A straggler *attempts* the round — it trains and transmits, so the
    ledger charges it the full participant energy (eq. 4) — but its update
    arrives after the aggregation deadline and is dropped from the FedAvg
    merge; its AoI is not reset (no fresh information reached the server).
    Draws come from their own RNG stream (``DEADLINE_STREAM``), so with
    ``miss = 0`` the delivered masks — and the whole program under the
    static no-deadline flag — stay bitwise-identical to the deadline-free
    engine (pinned in ``tests/test_hetero_campaign.py``).

    Attributes:
        miss: per-round deadline-miss probability — scalar, ``(N,)``,
            ``(B, 1)``, or ``(B, N)`` (broadcast to ``(B, N)``).
    """

    miss: Any = 0.0

    def as_arrays(self, batch: int, n: int) -> jax.Array:
        """Broadcast to the engine's ``(B, N)`` miss-probability input."""
        return jnp.broadcast_to(
            jnp.atleast_2d(jnp.asarray(self.miss, jnp.float64)), (batch, n))


@dataclasses.dataclass
class CampaignResult:
    """Batched outcome of B scan-fused campaigns (leading axis B).

    ``acc_history``/``k_history`` are full ``(B, max_rounds)`` arrays;
    post-convergence entries repeat the last converged accuracy and report
    0 participants (the masked no-op rounds). Slice ``[:rounds[i]]`` for the
    realized trajectory of scenario ``i``.
    """

    p: jax.Array                 # (B, N) per-node participation probability
    seeds: jax.Array             # (B,)
    converged_at: jax.Array      # (B,) round index or -1
    converged: jax.Array         # (B,) bool
    rounds: jax.Array            # (B,) realized rounds (early stop honoured)
    energy_wh: jax.Array         # (B,) realized task energy [Wh]
    acc_history: jax.Array       # (B, R)
    k_history: jax.Array         # (B, R) participants per round
    participation_rate: jax.Array  # (B,) mean realized participation
    per_node_aoi: jax.Array      # (B, N) realized mean age per node [rounds]
    mean_aoi: jax.Array          # (B,) fleet-mean realized AoI [rounds]
    ledger: EnergyLedger         # batched (leaves carry leading B axis)
    aoi: AoITracker              # batched
    present_counts: jax.Array    # (B, N) rounds each node was in the fleet
    present_final: jax.Array     # (B, N) bool presence after the last round
    straggler_counts: jax.Array  # (B, N) attempts that missed the deadline
    metrics: MetricStream | None = None  # batched, when obs recorded one
    #: final merged model params, batched (leaves carry leading B axis) —
    #: slice scenario i via ``jax.tree.map(lambda x: x[i], result.params)``
    params: Any = None

    @property
    def batch(self) -> int:
        return int(self.rounds.shape[0])

    @property
    def per_node_energy_wh(self) -> jax.Array:
        """``(B, N)`` realized per-node energy in Watt-hours."""
        return self.ledger.per_node_wh

    def scenario_ledger(self, i: int) -> EnergyLedger:
        """The i-th scenario's ledger as an unbatched :class:`EnergyLedger`."""
        return jax.tree.map(lambda leaf: leaf[i], self.ledger)

    def summary(self, i: int) -> dict[str, Any]:
        s = self.scenario_ledger(i).summary()
        s.update(converged=bool(self.converged[i]),
                 rounds=int(self.rounds[i]),
                 mean_aoi=float(self.mean_aoi[i]))
        return s


def build_campaign(
    fl,
    init_params: Callable[[jax.Array], dict],
    loss_fn: Callable,
    eval_fn: Callable,
    client_data: Callable,
    val_batch: dict,
    opt: Optimizer,
    *,
    churn: bool = False,
    deadline: bool = False,
    backend: str | None = None,
    obs: ObsConfig | None = None,
    mesh=None,
    batch_axis=None,
):
    """Compile the campaign engine for one task definition.

    Args mirror :func:`repro.federated.simulation.run_simulation`; ``fl`` is
    an :class:`~repro.federated.simulation.FLConfig` (``max_rounds`` fixes
    the static scan length). ``churn`` is a *static* flag: the churn-free
    program is built without any presence logic, so it stays instruction-
    identical to the symmetric engine. ``backend`` is likewise static and
    picks the FedAvg-merge implementation baked into the program:
    ``"ref"`` (the pure-jnp merge — with ``backend=None`` and no
    env/``set_backend`` override this is the default, keeping results
    bitwise-identical to the dispatch-free engine) or ``"pallas"`` (the
    fused :mod:`repro.kernels.fedavg_agg` kernel, vmapped over the
    scenario batch as an extra grid dimension; parity to tolerance, see
    ``tests/test_kernels.py``). ``obs`` (static, default off) instruments
    the program: ``obs.metrics`` adds a :class:`repro.obs.MetricStream`
    to the scan carry, ``obs.events`` streams per-round events to
    ``obs.sink`` via ``jax.debug.callback``. Instrumentation never touches
    an RNG stream or a computed value — it only adds outputs.

    ``deadline`` is a third static flag: it adds the straggler model
    (attempted-but-late updates dropped from the merge, full participant
    energy still charged, straggler counts in the carry) and a ``miss
    (B, N)`` probability input. ``deadline=False`` builds the program
    without any deadline logic — bitwise-identical to the PR-4 engine.

    Returns a jitted engine whose positional signature grows with the
    static flags, in this fixed order:

    ``fn(p, seeds, e_participant_j, e_idle_j,
    [miss,] [arrival, departure, present0,] [scenario_ids])``

    * ``miss (B, N)`` iff ``deadline=True``;
    * the churn triple iff ``churn=True``;
    * ``scenario_ids (B,)`` iff ``obs.events`` is enabled (event records
      need a stable per-scenario identity under ``vmap``).

    ``p`` is ``(B, N)``; ``seeds`` ``(B,)``; the joule rates are per-round
    energies, ``(B,)`` scalar-per-scenario or ``(B, N)`` per-node; the churn
    probabilities/presence are ``(B, N)``. The engine returns the raw
    batched scan state (dict of params/ledger/tracker/aoi/accs/ks, plus
    present/present_counts under churn and metrics under obs). Use
    :func:`run_campaigns` for the friendly wrapper.

    ``mesh``/``batch_axis`` (static) place the scenario batch axis of every
    input and result leaf on a device mesh: the program is jitted with
    ``in_shardings``/``out_shardings`` resolved through the
    :mod:`repro.launch.sharding` rules engine
    (:func:`~repro.launch.sharding.scenario_batch_spec`; ``batch_axis``
    overrides the ``("pod", "data")`` candidate order), so the vmapped
    scenario sweep partitions across devices — each device runs its block
    of campaigns with no cross-scenario collectives. ``mesh=None`` (the
    default) builds the exact single-device program as before.  Callers
    must pass batch sizes divisible by the mesh axis
    (:func:`run_campaigns` pads arbitrary ``B`` and slices results back).
    """
    if mesh is not None:
        from jax.sharding import NamedSharding

        from repro.launch.sharding import scenario_batch_spec

        # batch=0 sentinel: resolve the spec by axis name only (divisibility
        # is the caller's padding contract, not re-checked per trace).
        axes = batch_axis
        spec = scenario_batch_spec(0, mesh, axis=axes) if axes is not None \
            else scenario_batch_spec(0, mesh)
        batch_sharding = NamedSharding(mesh, spec)
    n = fl.n_clients
    train_one = make_local_train(loss_fn, opt)
    record_metrics = obs is not None and obs.record_metrics
    emit_events = obs is not None and obs.emit_events
    sink = obs.sink if emit_events else None

    def train_round(params, p_vec, mask_rng, r):
        """Shared round body: masks → local training → merge → validation."""
        with jax.named_scope("campaign/masks"):
            mask = round_mask(mask_rng, p_vec)
        with jax.named_scope("campaign/local_train"):
            batches = jax.vmap(
                lambda cid: client_data(cid, r, fl.batch_per_client,
                                        fl.local_steps))(jnp.arange(n))
            client_params, _ = jax.vmap(train_one, in_axes=(None, 0))(
                params, batches)
        return mask, client_params

    # One body for every engine: ``churn``/``deadline``/``obs`` are static
    # Python, so the branches below resolve at trace time — the flag-free
    # program is instruction-identical to the symmetric engine's.
    def one_campaign(p_vec, seed, e_participant_j, e_idle_j,
                     miss=None, arrival=None, departure=None, present0=None,
                     scenario_id=None):
        key = jax.random.PRNGKey(seed)
        state0 = (
            init_params(jax.random.fold_in(key, 1)),
            EnergyLedger.create(n),
            ConvergenceTracker.create(fl.target_acc, fl.consecutive),
            AoITracker.create(n),
            jnp.zeros((), jnp.float64),          # last recorded accuracy
        )
        if deadline:
            state0 += (jnp.zeros((n,), jnp.int64),)  # straggler counts
        if churn:
            state0 += (
                jnp.asarray(present0, bool),     # fleet presence
                jnp.zeros((n,), jnp.int64),      # per-node presence rounds
            )
        if record_metrics:
            state0 += (MetricStream.create(fl.max_rounds),)

        def round_step(carry, r):
            params, ledger, tracker, aoi, last_acc, *rest = carry
            active = ~tracker.converged
            pos = 0
            if deadline:
                scount = rest[pos]
                pos += 1
            if churn:
                present, pcount = rest[pos], rest[pos + 1]
                # Churn draws come from their own stream (CHURN_STREAM), so
                # the participation stream — and with zero churn the masks
                # themselves — stay bitwise-identical to the churn-free
                # engine.
                with jax.named_scope("campaign/churn"):
                    ka, kd = jax.random.split(
                        jax.random.fold_in(key, CHURN_STREAM + r))
                    arrive = jax.random.bernoulli(ka, arrival, (n,))
                    depart = jax.random.bernoulli(kd, departure, (n,))
                    here = jnp.where(present, ~depart, arrive)
            else:
                here = None

            # Same RNG stream as the Python-loop reference: masks (and hence
            # energy/participation/AoI) are bitwise-identical per round.
            rng = jax.random.fold_in(key, MASK_STREAM + r)
            mask, client_params = train_round(params, p_vec, rng, r)
            if churn:
                mask = mask & here               # absentees cannot join
            if deadline:
                # Late draws have their own stream (DEADLINE_STREAM), so the
                # participation stream — and with miss=0 the delivered masks
                # themselves — stay bitwise-identical to the deadline-free
                # engine.
                with jax.named_scope("campaign/deadline"):
                    kl = jax.random.fold_in(key, DEADLINE_STREAM + r)
                    late = jax.random.bernoulli(kl, miss, (n,))
                delivered = mask & ~late
            else:
                delivered = mask
            with jax.named_scope("campaign/merge"):
                merged = fedavg_merge(params, client_params, delivered,
                                      backend=backend)
            with jax.named_scope("campaign/validate"):
                acc = eval_fn(merged, val_batch)

            new_acc = jnp.where(active, acc, last_acc)
            with jax.named_scope("campaign/accounting"):
                # The ledger charges *attempts*: a straggler trained and
                # transmitted (full eq.-4 energy) even though its update
                # missed the merge. AoI resets only on *delivered* updates.
                new_ledger = ledger.record_round_j(mask, e_participant_j,
                                                   e_idle_j)
                new_carry = (
                    _tree_select(active, merged, params),
                    _tree_select(active, new_ledger, ledger),
                    tracker.masked_update(acc, jnp.asarray(r, jnp.int32),
                                          active),
                    _tree_select(active, aoi.update(delivered, here), aoi),
                    new_acc,
                )
                if deadline:
                    new_carry += (
                        scount + jnp.where(
                            active, jnp.asarray(mask & late, jnp.int64), 0),
                    )
                if churn:
                    new_carry += (
                        jnp.where(active, here, present),
                        pcount + jnp.where(active,
                                           jnp.asarray(here, jnp.int64), 0),
                    )
            k = jnp.where(active,
                          jnp.sum(jnp.asarray(delivered, jnp.int32)), 0)
            if record_metrics:
                with jax.named_scope("campaign/obs_metrics"):
                    stream = rest[-1]
                    recorded = stream.record(
                        participants=k,
                        merge_norm=jnp.where(
                            active, merge_norm(merged, params), 0.0),
                        ledger_delta_j=new_ledger.total_j - ledger.total_j,
                        accuracy=new_acc)
                    new_carry += (_tree_select(active, recorded, stream),)
            if emit_events:
                # valid= drops padding-replica lanes (scenario_id < 0) the
                # mesh path adds to fill devices — their events would
                # double-count real scenarios (tests/test_obs.py).
                sink.tap("round", valid=scenario_id >= 0,
                         scenario=scenario_id, round=r,
                         active=active, participants=k, accuracy=new_acc)
            return new_carry, (new_acc, k)

        final, (accs, ks) = jax.lax.scan(round_step, state0,
                                         jnp.arange(fl.max_rounds))
        out = {"params": final[0], "ledger": final[1], "tracker": final[2],
               "aoi": final[3], "accs": accs, "ks": ks}
        pos = 5
        if deadline:
            out["straggler_counts"] = final[pos]
            pos += 1
        if churn:
            out.update(present=final[pos], present_counts=final[pos + 1])
        if record_metrics:
            out["metrics"] = final[-1]
        if emit_events:
            tracker = out["tracker"]
            sink.tap("campaign", valid=scenario_id >= 0,
                     scenario=scenario_id,
                     converged_at=tracker.converged_at,
                     energy_j=out["ledger"].total_j)
        return out

    def _jit(vfn):
        if mesh is None:
            return jax.jit(vfn)
        # One sharding as a pytree prefix: every input/result leaf carries
        # the scenario batch as its leading dim, so the single
        # ``batch_sharding`` places them all (GSPMD partitions the vmapped
        # program along it — no cross-scenario collectives exist).
        return jax.jit(vfn, in_shardings=batch_sharding,
                       out_shardings=batch_sharding)

    # The engine's positional signature grows with the static flags; build
    # it once from the flag set (order: miss, churn triple, scenario_ids)
    # instead of enumerating every flag combination.
    extra: list[str] = []
    if deadline:
        extra.append("miss")
    if churn:
        extra.extend(("arrival", "departure", "present0"))
    if emit_events:
        extra.append("scenario_id")

    def _engine(p, s, ep, ei, *rest):
        return one_campaign(p, s, ep, ei, **dict(zip(extra, rest)))

    return _jit(jax.vmap(_engine))


def _energy_rates(energy, batch: int) -> tuple[jax.Array, jax.Array]:
    """Per-scenario ``(B,)`` joule rates from :class:`EnergyParams` input."""
    if energy is None:
        energy = EnergyParams()
    if isinstance(energy, EnergyParams):
        energy = [energy] * batch
    if len(energy) != batch:
        raise ValueError(f"{len(energy)} EnergyParams for {batch} scenarios")
    e_part = jnp.asarray([e.e_participant_j for e in energy], jnp.float64)
    e_idle = jnp.asarray([e.e_idle_j for e in energy], jnp.float64)
    return e_part, e_idle


def _raw_rate(rate, batch: int, n: int, name: str) -> jax.Array:
    """Normalize one raw joule-rate input to ``(B,)`` or ``(B, N)``.

    1-D inputs are *per-scenario* rates (length B); anything per-node must
    be 2-D (``(1, N)`` or ``(B, N)``). When B == N a 1-D vector is
    ambiguous — e.g. the ``(N,)`` output of
    :func:`~repro.core.energy.per_node_energy_rates` passed without the
    ``[None, :]`` — and is rejected rather than silently metering scenario
    i at node i's rate.
    """
    r = jnp.asarray(rate, jnp.float64)
    if r.ndim == 0:
        return jnp.broadcast_to(r, (batch,))
    if r.ndim == 1:
        if batch == n:
            raise ValueError(
                f"{name}: B == N == {batch}, so a 1-D rate vector is "
                f"ambiguous; pass rates[:, None] for per-scenario or "
                f"rates[None, :] for per-node")
        if r.shape[0] != batch:
            raise ValueError(
                f"{name}: 1-D rates are per-scenario and must have length "
                f"B={batch}, got {r.shape}; pass (1, N) or (B, N) for "
                f"per-node rates")
        return r
    if r.ndim == 2:
        return jnp.broadcast_to(r, (batch, n))
    raise ValueError(f"{name}: rank-{r.ndim} rates unsupported")


def run_campaigns(
    fl,
    init_params: Callable[[jax.Array], dict],
    loss_fn: Callable,
    eval_fn: Callable,
    client_data: Callable,
    val_batch: dict,
    opt: Optimizer,
    p: jax.Array,
    *,
    energy: EnergyParams | Sequence[EnergyParams] | None = None,
    energy_rates_j: tuple[jax.Array, jax.Array] | None = None,
    churn: ChurnConfig | None = None,
    deadline: DeadlineConfig | None = None,
    seeds: Sequence[int] | jax.Array | None = None,
    engine: Callable | None = None,
    backend: str | None = None,
    obs: ObsConfig | None = None,
    mesh=None,
    batch_axis=None,
) -> CampaignResult:
    """Run B FedAvg campaigns as one jitted scan+vmap program.

    Args:
        p: scenario participation — scalar, ``(B,)`` symmetric
            probabilities, or a ``(B, N)`` per-node matrix (e.g. the
            certified asymmetric equilibria out of
            :meth:`repro.core.controller.ParticipationController.solve_batched`).
        energy: one shared :class:`EnergyParams` or one per scenario
            (symmetric within each scenario).
        energy_rates_j: raw per-round joule rates
            ``(e_participant_j, e_idle_j)`` overriding ``energy``. Each may
            be a scalar, a per-scenario ``(B,)`` vector, or a per-node
            ``(1, N)`` / ``(B, N)`` matrix — the heterogeneous-fleet path
            (see :func:`repro.core.energy.per_node_energy_rates`).
        churn: optional :class:`ChurnConfig` enabling the fleet-churn model
            (presence mask folded into the scan carry). ``None`` builds the
            churn-free program — instruction-identical to the symmetric
            engine.
        deadline: optional :class:`DeadlineConfig` enabling the straggler
            model: nodes that win the participation lottery miss the round
            deadline with probability ``miss`` — they burn the full
            participant energy but are dropped from the merge and their
            AoI is not reset. ``None`` builds the deadline-free program
            (bitwise-identical to the engine without the flag); per-node
            miss counts land in ``CampaignResult.straggler_counts``.
        seeds: per-scenario PRNG seeds (default: ``fl.seed`` for all — the
            scenarios then share model init and data streams, isolating the
            effect of ``p``).
        engine: a prebuilt :func:`build_campaign` program. Pass it when
            sweeping repeatedly over one task so the XLA compile is paid
            once (a fresh engine is built — and traced — per call
            otherwise). Must have been built with ``churn=True`` iff
            ``churn`` is passed here (likewise ``deadline``); a prebuilt
            engine also bakes in its own ``backend``, ignoring this
            call's.
        backend: FedAvg-merge implementation, ``"ref"`` (default —
            bitwise-stable jnp path) or ``"pallas"`` (fused kernel); see
            :func:`build_campaign`.
        obs: optional :class:`repro.obs.ObsConfig`. With metrics enabled
            the result carries a batched :class:`repro.obs.MetricStream`
            in ``.metrics``; with events enabled, per-round events stream
            to ``obs.sink``. ``None`` (the default) builds the
            uninstrumented program. A prebuilt ``engine`` bakes in its own
            ``obs``, and this call's must match it (the engine signature
            and outputs depend on it).
        mesh: optional :class:`jax.sharding.Mesh`. Shards the scenario
            batch axis across the mesh's data-parallel axes: inputs are
            ``jax.device_put`` with a ``NamedSharding`` resolved through
            the :mod:`repro.launch.sharding` rules engine, the engine is
            jitted with matching ``out_shardings``, and arbitrary ``B`` is
            edge-padded to the next multiple of the axis size — every
            result leaf (ledger, AoI, metrics, histories) is sliced back
            to ``B`` rows, so padding replicas never reach accounting.
            ``None`` (the default) is the bitwise-pinned single-device
            path (``tests/test_sharded_campaign.py``). A prebuilt
            ``engine`` must have been built with the same ``mesh``.
        batch_axis: mesh axis name (or tuple) for the batch dim, default
            the rules table's ``("pod", "data")`` preference.

    Returns:
        A :class:`CampaignResult`; per-node realized splits live in
        ``per_node_energy_wh`` (Wh), ``per_node_aoi`` (rounds), the
        batched ``ledger``, and — under churn — ``present_counts`` /
        ``present_final``.
    """
    n = fl.n_clients
    # Preserve the caller's p dtype: bernoulli draws its uniforms in p's
    # dtype, so coercion here would change masks vs the reference loop.
    p_arr = jnp.atleast_1d(jnp.asarray(p))
    if p_arr.ndim == 1:
        p_arr = jnp.broadcast_to(p_arr[:, None], (p_arr.shape[0], n))
    if p_arr.shape[1] != n:
        raise ValueError(f"p {p_arr.shape} for n_clients={n}")
    batch = p_arr.shape[0]
    seeds = (jnp.full((batch,), fl.seed, jnp.uint32) if seeds is None
             else jnp.asarray(seeds, jnp.uint32))
    if seeds.shape != (batch,):
        raise ValueError(f"seeds {seeds.shape} for {batch} scenarios")
    if energy_rates_j is not None:
        e_part = _raw_rate(energy_rates_j[0], batch, n, "e_participant_j")
        e_idle = _raw_rate(energy_rates_j[1], batch, n, "e_idle_j")
    else:
        e_part, e_idle = _energy_rates(energy, batch)

    fn = engine if engine is not None else build_campaign(
        fl, init_params, loss_fn, eval_fn, client_data, val_batch, opt,
        churn=churn is not None, deadline=deadline is not None,
        backend=backend, obs=obs, mesh=mesh, batch_axis=batch_axis)
    call_args = [p_arr, seeds, e_part, e_idle]
    if deadline is not None:
        call_args.append(deadline.as_arrays(batch, n))
    if churn is not None:
        call_args.extend(churn.as_arrays(batch, n))
    if obs is not None and obs.emit_events:
        call_args.append(jnp.arange(batch, dtype=jnp.int32))
    if mesh is not None:
        from jax.sharding import NamedSharding

        from repro.launch.sharding import (pad_batch, scenario_batch_spec,
                                           spec_axis_size)

        spec = scenario_batch_spec(0, mesh, axis=batch_axis)
        shards = spec_axis_size(mesh, spec)
        call_args = [pad_batch(a, batch, shards) for a in call_args]
        if obs is not None and obs.emit_events and call_args[-1].shape[0] != batch:
            # Padding lanes get scenario_id = -1: the event taps carry a
            # validity mask and the sink drops their records.
            call_args[-1] = call_args[-1].at[batch:].set(-1)
        sharding = NamedSharding(mesh, spec)
        call_args = [jax.device_put(a, sharding) for a in call_args]
    out = fn(*call_args)
    if mesh is not None and next(iter(jax.tree.leaves(out))).shape[0] != batch:
        # Drop the padding replicas from every result leaf — the validity
        # mask of the pad-to-divisible contract.
        out = jax.tree.map(lambda leaf: leaf[:batch], out)

    tracker, ledger, aoi = out["tracker"], out["ledger"], out["aoi"]
    converged = tracker.converged_at >= 0
    rounds = jnp.where(converged, tracker.converged_at + 1, fl.max_rounds)
    if churn is not None:
        present_counts = out["present_counts"]
        present_final = out["present"]
    else:
        present_counts = jnp.broadcast_to(rounds[:, None], (batch, n))
        present_final = jnp.ones((batch, n), bool)
    return CampaignResult(
        p=p_arr,
        seeds=seeds,
        converged_at=tracker.converged_at,
        converged=converged,
        rounds=rounds,
        energy_wh=jnp.sum(ledger.per_node_j, axis=-1) / J_PER_WH,
        acc_history=out["accs"],
        k_history=out["ks"],
        participation_rate=jnp.mean(
            ledger.participation_counts
            / jnp.maximum(ledger.rounds, 1)[:, None], axis=-1),
        per_node_aoi=aoi.per_node_aoi,
        mean_aoi=aoi.mean_aoi,
        ledger=ledger,
        aoi=aoi,
        present_counts=present_counts,
        present_final=present_final,
        straggler_counts=out.get(
            "straggler_counts", jnp.zeros((batch, n), jnp.int64)),
        metrics=out.get("metrics"),
        params=out["params"],
    )
