"""Self-contained synthetic FL tasks for docs, examples, and benchmarks.

Every campaign-engine entry point takes the same five task callables
(``init_params, loss_fn, eval_fn, client_data, val_batch``). Examples and
benchmarks used to hand-roll an MLP-on-synthetic-CIFAR task each; this
module provides the canonical small instance so docs snippets, examples,
and sweeps share one definition (and one compile cache key).

The default task is deliberately tiny — 8x8 images, a 16-unit MLP —
so a whole multi-scenario campaign sweep measures *engine* overhead, not
matmul throughput, and docs snippets run in seconds on CPU CI.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import SyntheticCifar

__all__ = ["FLTask", "synthetic_mlp_task"]


@dataclasses.dataclass(frozen=True)
class FLTask:
    """One FL task definition, bundled for the campaign engine.

    ``campaign_args()`` splats into
    :func:`repro.federated.campaign.run_campaigns` /
    :func:`~repro.federated.campaign.build_campaign`:

    >>> # run_campaigns(fl, *task.campaign_args(), opt, ps)   # doctest: +SKIP
    """

    data: SyntheticCifar
    init_params: Callable[[jax.Array], dict]
    loss_fn: Callable
    eval_fn: Callable
    client_data: Callable
    val_batch: dict

    def campaign_args(self) -> tuple:
        """The positional task args of the campaign-engine entry points."""
        return (self.init_params, self.loss_fn, self.eval_fn,
                self.client_data, self.val_batch)


def synthetic_mlp_task(
    image_shape: tuple = (8, 8, 3),
    hidden: int = 16,
    noise: float = 3.0,
    val_size: int = 128,
    data_seed: int = 0,
) -> FLTask:
    """A small learnable 10-class task (CIFAR stand-in) + 1-hidden-layer MLP.

    Args:
        image_shape: synthetic image shape (default shrunk 8x8x3).
        hidden: MLP hidden width.
        noise: template SNR — higher is harder (3.0 converges to the
            paper's 0.73 target within tens of rounds at moderate p).
        val_size: validation batch size.
        data_seed: PRNG seed of the per-(client, round) iid data stream.

    Returns:
        An :class:`FLTask`; ``client_data`` is the stateless iid stream
        (every client draws fresh template+noise batches). For non-iid
        shards build the callback with
        :func:`repro.data.partition.sharded_client_data` and
        ``dataclasses.replace(task, client_data=...)``.
    """
    data = SyntheticCifar(noise=noise, image_shape=image_shape)
    d = int(np.prod(image_shape))

    def init_params(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (d, hidden)) * d ** -0.5,
                "b1": jnp.zeros(hidden),
                "w2": jax.random.normal(k2, (hidden, 10)) * hidden ** -0.5,
                "b2": jnp.zeros(10)}

    def fwd(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss_fn(p, b):
        lp = jax.nn.log_softmax(fwd(p, b["images"]))
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1))

    def eval_fn(p, b):
        return jnp.mean(jnp.argmax(fwd(p, b["images"]), -1) == b["labels"])

    def client_data(cid, rnd, n, steps):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(data_seed), cid), rnd)
        return jax.vmap(lambda k: data.batch(k, n))(
            jax.random.split(key, steps))

    return FLTask(data=data, init_params=init_params, loss_fn=loss_fn,
                  eval_fn=eval_fn, client_data=client_data,
                  val_batch=data.val_set(val_size))
