"""Self-contained synthetic FL tasks for docs, examples, and benchmarks.

Every campaign-engine entry point takes the same five task callables
(``init_params, loss_fn, eval_fn, client_data, val_batch``). Examples and
benchmarks used to hand-roll an MLP-on-synthetic-CIFAR task each; this
module provides the canonical small instance so docs snippets, examples,
and sweeps share one definition (and one compile cache key).

The default task is deliberately tiny — 8x8 images, a 16-unit MLP —
so a whole multi-scenario campaign sweep measures *engine* overhead, not
matmul throughput, and docs snippets run in seconds on CPU CI.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import SyntheticCifar

__all__ = ["FLTask", "synthetic_mlp_task", "model_task"]


@dataclasses.dataclass(frozen=True)
class FLTask:
    """One FL task definition, bundled for the campaign engine.

    ``campaign_args()`` splats into
    :func:`repro.federated.campaign.run_campaigns` /
    :func:`~repro.federated.campaign.build_campaign`:

    >>> # run_campaigns(fl, *task.campaign_args(), opt, ps)   # doctest: +SKIP
    """

    data: Any
    init_params: Callable[[jax.Array], dict]
    loss_fn: Callable
    eval_fn: Callable
    client_data: Callable
    val_batch: dict
    #: model config behind the task (None for the hand-rolled MLP task)
    cfg: Any = None
    #: suggested OptConfig (None -> caller picks); informational only —
    #: ``campaign_args()`` stays the five engine callables.
    opt: Any = None

    def campaign_args(self) -> tuple:
        """The positional task args of the campaign-engine entry points."""
        return (self.init_params, self.loss_fn, self.eval_fn,
                self.client_data, self.val_batch)


def synthetic_mlp_task(
    image_shape: tuple = (8, 8, 3),
    hidden: int = 16,
    noise: float = 3.0,
    val_size: int = 128,
    data_seed: int = 0,
) -> FLTask:
    """A small learnable 10-class task (CIFAR stand-in) + 1-hidden-layer MLP.

    Args:
        image_shape: synthetic image shape (default shrunk 8x8x3).
        hidden: MLP hidden width.
        noise: template SNR — higher is harder (3.0 converges to the
            paper's 0.73 target within tens of rounds at moderate p).
        val_size: validation batch size.
        data_seed: PRNG seed of the per-(client, round) iid data stream.

    Returns:
        An :class:`FLTask`; ``client_data`` is the stateless iid stream
        (every client draws fresh template+noise batches). For non-iid
        shards build the callback with
        :func:`repro.data.partition.sharded_client_data` and
        ``dataclasses.replace(task, client_data=...)``.
    """
    data = SyntheticCifar(noise=noise, image_shape=image_shape)
    d = int(np.prod(image_shape))

    def init_params(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (d, hidden)) * d ** -0.5,
                "b1": jnp.zeros(hidden),
                "w2": jax.random.normal(k2, (hidden, 10)) * hidden ** -0.5,
                "b2": jnp.zeros(10)}

    def fwd(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss_fn(p, b):
        lp = jax.nn.log_softmax(fwd(p, b["images"]))
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1))

    def eval_fn(p, b):
        return jnp.mean(jnp.argmax(fwd(p, b["images"]), -1) == b["labels"])

    def client_data(cid, rnd, n, steps):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(data_seed), cid), rnd)
        return jax.vmap(lambda k: data.batch(k, n))(
            jax.random.split(key, steps))

    return FLTask(data=data, init_params=init_params, loss_fn=loss_fn,
                  eval_fn=eval_fn, client_data=client_data,
                  val_batch=data.val_set(val_size))


def model_task(
    cfg,
    shape=None,
    *,
    backend: Optional[str] = None,
    optimizer=None,
    data=None,
    partition: str = "iid",
    alpha: float = 0.5,
    n_clients: int = 8,
    dataset_size: int = 2048,
    val_size: int = 64,
    data_seed: int = 0,
    remat: bool = False,
) -> FLTask:
    """Wrap any registered :class:`~repro.models.registry.ModelApi` as an FL task.

    The campaign engine only sees the five :class:`FLTask` callables, so a
    reduced transformer LM, an RWKV/SSM client, or the paper's ResNet-18
    runs through the same jitted scan+vmap round loop as the synthetic MLP —
    including B-scenario vmap, churn, and the mesh-sharded merge.

    Args:
        cfg: a :class:`~repro.configs.base.ModelConfig` (use ``.reduced()``
            for CPU-sized campaigns).
        shape: sequence length for LM families — an int, a
            :class:`~repro.configs.base.ShapeSpec` (its ``seq_len`` is
            used), or None for the 16-token smoke default. Ignored for
            ``family="vision"``.
        backend: kernel backend threaded through
            :func:`repro.models.runtime.kernel_scope` for the *training*
            loss — ``None`` keeps the model's plain jnp path (bitwise
            whatever the model already did), ``"ref"`` routes fwd/bwd
            through the :mod:`repro.kernels.ops` jnp oracles, ``"pallas"``
            runs the Pallas kernels (interpret mode on CPU) with
            oracle-linearized backward. Eval always uses the plain path.
        optimizer: optional OptConfig stored on the task (informational).
        data: override the synthetic data source
            (:class:`~repro.data.synthetic.SyntheticCifar` for vision,
            :class:`~repro.data.synthetic.SyntheticLM` otherwise).
        partition: ``"iid"`` — stateless per-(client, round) streams, the
            same RNG scheme as :func:`synthetic_mlp_task`; ``"dirichlet"``
            — materialize a ``dataset_size``-sample dataset and split it
            label-skewed via :func:`repro.data.partition.dirichlet_partition`
            (LM streams bucket by leading token). Dirichlet tasks are tied
            to ``n_clients``: run them with ``fl.n_clients == n_clients``.
        alpha: Dirichlet concentration (lower = more skew).
        n_clients: shard count for ``partition="dirichlet"``.
        dataset_size: materialized sample count for ``partition="dirichlet"``.
        val_size: validation batch size.
        data_seed: seed of both the data source and the minibatch streams.
        remat: forward ``remat=`` to ``ModelApi.loss`` (gradient
            checkpointing inside the client step).

    Returns:
        An :class:`FLTask` whose ``client_data(cid, rnd, n, steps)`` emits
        ``(steps, n, ...)`` batch pytrees, deterministic in
        ``(data_seed, cid, rnd)`` and vmap-safe with a traced ``cid``.
    """
    from repro.data.synthetic import SyntheticLM
    from repro.models import runtime
    from repro.models.registry import get_model

    api = get_model(cfg)
    vision = cfg.family == "vision"
    if isinstance(shape, int):
        seq = shape
    elif shape is not None:
        seq = shape.seq_len
    else:
        seq = 16

    if data is None:
        data = (SyntheticCifar(n_classes=cfg.vocab, seed=data_seed) if vision
                else SyntheticLM(vocab=cfg.vocab, seed=data_seed))

    def _extras(key, n: int) -> dict:
        """Modality frontends beyond the token stream (vlm / audio)."""
        out = {}
        if cfg.n_patches:
            out["patches"] = jax.random.normal(
                jax.random.fold_in(key, 2),
                (n, cfg.n_patches, cfg.d_frontend))
        if cfg.n_frames:
            out["frames"] = jax.random.normal(
                jax.random.fold_in(key, 3), (n, cfg.n_frames, cfg.d_model))
        return out

    def _cast(batch: dict) -> dict:
        """Pin input dtypes: ``repro.core`` flips on x64, so the default
        synthetic streams emit float64/int64 in campaign contexts while
        model params are explicit float32 — cast to each ModelApi's
        declared input dtypes (int32 tokens/labels, float32 frontends)."""
        return {k: v.astype(jnp.int32 if jnp.issubdtype(v.dtype, jnp.integer)
                            else jnp.float32)
                for k, v in batch.items()}

    def _sample(key, n: int) -> dict:
        if vision:
            return _cast(data.batch(key, n))
        return _cast({**data.batch(key, n, seq), **_extras(key, n)})

    def loss_fn(p, b):
        if backend is None:
            return api.loss(p, b, remat=remat)
        with runtime.kernel_scope(backend):
            return api.loss(p, b, remat=remat)

    def eval_fn(p, b):
        logits = api.logits(p, b)
        return jnp.mean(jnp.argmax(logits, -1) == b["labels"])

    if partition == "iid":
        def client_data(cid, rnd, n, steps):
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(data_seed), cid), rnd)
            return jax.vmap(lambda k: _sample(k, n))(
                jax.random.split(key, steps))
    elif partition == "dirichlet":
        from repro.data.partition import (dirichlet_partition,
                                          sharded_client_arrays)
        if vision:
            arrays = _cast(data.dataset(dataset_size))
            part_labels = np.asarray(arrays["labels"])
        else:
            arrays = data.dataset(dataset_size, seq)
            arrays.update(_extras(jax.random.PRNGKey(data_seed + 20_011),
                                  dataset_size))
            arrays = _cast(arrays)
            # LM sequences carry no class label; bucket by leading token
            # so low alpha still induces distribution skew across shards.
            part_labels = np.asarray(arrays["tokens"][:, 0]) % 10
        parts = dirichlet_partition(part_labels, n_clients, alpha=alpha,
                                    seed=data_seed)
        client_data = sharded_client_arrays(arrays, parts, seed=data_seed)
    else:
        raise ValueError(f"unknown partition {partition!r}; "
                         f"expected 'iid' or 'dirichlet'")

    if vision:
        val_batch = _cast(data.val_set(val_size))
    else:
        val_batch = _cast({**data.val_set(val_size, seq),
                           **_extras(jax.random.PRNGKey(data_seed + 10_007),
                                     val_size)})

    return FLTask(data=data,
                  init_params=lambda key: api.init(key)[0],
                  loss_fn=loss_fn, eval_fn=eval_fn,
                  client_data=client_data, val_batch=val_batch,
                  cfg=cfg, opt=optimizer)
