"""Bernoulli participation machinery (the paper's §III decision process).

Each node holds a fixed probability p_i set a priori (by the game's NE, the
centralized optimum, or the user) and flips an independent coin each round.
Everything here is jittable and deterministic in the PRNG key so multi-host
replicas draw identical masks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["round_mask", "mask_schedule", "participant_count"]


def round_mask(key: jax.Array, p: jax.Array) -> jax.Array:
    """(N,) bool participation mask for one round. p: scalar or (N,)."""
    p = jnp.asarray(p)
    n = p.shape[0] if p.ndim else None
    if n is None:
        raise ValueError("pass a per-node probability vector, e.g. "
                         "jnp.full((n_nodes,), p)")
    return jax.random.bernoulli(key, p, (n,))


def mask_schedule(key: jax.Array, p: jax.Array, n_rounds: int) -> jax.Array:
    """(n_rounds, N) masks, one key-fold per round."""
    p = jnp.asarray(p)
    keys = jax.random.split(key, n_rounds)
    return jax.vmap(lambda k: jax.random.bernoulli(k, p, p.shape))(keys)


def participant_count(mask: jax.Array) -> jax.Array:
    return jnp.sum(mask.astype(jnp.int32), axis=-1)
