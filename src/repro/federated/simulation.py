"""End-to-end participatory-FL simulation with energy metering (paper §IV).

One round = (draw Bernoulli masks) → (vmap local training across clients)
→ (masked FedAvg merge) → (validation) → (energy ledger update) →
(convergence check).

Two engines share that round definition:

* :func:`run_simulation` — the production path. Delegates to the
  scan-fused campaign engine (:mod:`repro.federated.campaign`): the whole
  round loop is one ``lax.scan`` inside one jitted XLA program, with
  post-convergence rounds masked to accounting no-ops.
* :func:`run_simulation_reference` — the seed Python round loop with eager
  early stopping, kept verbatim as the **test oracle** the engine is
  regression-tested against (see ``tests/test_federated.py``).

``run_simulation`` is what the Table II benchmark sweeps over p; plugging
the :class:`repro.core.controller.ParticipationController` in
``p_mode="ne"`` gives the paper's distributed scenario, ``"centralized"``
the planner's. For sweeps of many scenarios at once, call
:func:`repro.federated.campaign.run_campaigns` directly — one program for
the whole grid instead of one ``run_simulation`` per point.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.aoi import AoITracker
from repro.core.controller import ParticipationController
from repro.core.energy import EnergyLedger, EnergyParams
from repro.federated.client import local_train
from repro.federated.server import ConvergenceTracker, fedavg_merge
from repro.optim.base import Optimizer

__all__ = ["FLConfig", "FLResult", "HeterogeneousReference",
           "run_simulation", "run_simulation_reference",
           "run_heterogeneous_reference"]


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 50
    local_steps: int = 5            # E local epochs (1 minibatch/epoch here)
    batch_per_client: int = 32
    max_rounds: int = 200
    target_acc: float = 0.73
    consecutive: int = 3
    seed: int = 0


@dataclasses.dataclass
class FLResult:
    rounds: int
    converged: bool
    energy_wh: float
    acc_history: list
    participation_rate: float
    wall_s: float
    ledger_summary: dict
    mean_aoi: float = float("nan")  # realized fleet AoI (scan engine only)


def _resolve(p, energy, controller, n):
    if controller is not None:
        p = controller.participation_probability()
        energy = controller.energy_params
    energy = energy or EnergyParams()
    p_vec = jnp.broadcast_to(jnp.asarray(p, jnp.float32), (n,))
    return p_vec, energy


def run_simulation(
    fl: FLConfig,
    init_params: Callable[[jax.Array], dict],
    loss_fn: Callable,                       # (params, batch) -> scalar
    eval_fn: Callable,                       # (params, batch) -> accuracy
    client_data: Callable,                   # (client_id, round, n) -> batch
    val_batch: dict,
    opt: Optimizer,
    p: float | jax.Array,
    energy: EnergyParams | None = None,
    controller: Optional[ParticipationController] = None,
    engine=None,
) -> FLResult:
    """Run FedAvg with Bernoulli(p) participation until convergence.

    ``p`` may be a scalar (symmetric) or an (N,) vector. If ``controller`` is
    given its probability overrides ``p`` and its energy params are used.

    This is the B = 1 case of the scan-fused campaign engine; masks, ledger,
    tracker, and accuracies match :func:`run_simulation_reference` (same RNG
    streams, post-convergence rounds masked out). Each call traces and
    compiles a fresh ``max_rounds`` scan — when calling repeatedly on one
    task, build the program once with
    :func:`repro.federated.campaign.build_campaign` and pass it as
    ``engine`` (or better, batch the scenarios into one
    :func:`~repro.federated.campaign.run_campaigns` call).
    """
    from repro.federated.campaign import run_campaigns

    p_vec, energy = _resolve(p, energy, controller, fl.n_clients)
    t0 = time.time()
    res = run_campaigns(fl, init_params, loss_fn, eval_fn, client_data,
                        val_batch, opt, p_vec[None, :], energy=energy,
                        seeds=[fl.seed], engine=engine)
    wall = time.time() - t0
    rounds = int(res.rounds[0])
    return FLResult(
        rounds=rounds,
        converged=bool(res.converged[0]),
        energy_wh=float(res.energy_wh[0]),
        acc_history=[float(a) for a in res.acc_history[0][:rounds]],
        participation_rate=float(res.participation_rate[0]),
        wall_s=wall,
        ledger_summary=res.scenario_ledger(0).summary(),
        mean_aoi=float(res.mean_aoi[0]),
    )


def run_simulation_reference(
    fl: FLConfig,
    init_params: Callable[[jax.Array], dict],
    loss_fn: Callable,
    eval_fn: Callable,
    client_data: Callable,
    val_batch: dict,
    opt: Optimizer,
    p: float | jax.Array,
    energy: EnergyParams | None = None,
    controller: Optional[ParticipationController] = None,
) -> FLResult:
    """The seed Python-loop simulator — the scan engine's test oracle.

    One jitted program per *round*, eager ledger/tracker updates, early
    ``break`` on convergence. Kept unfused on purpose: it is the simplest
    possible statement of the round semantics.
    """
    p_vec, energy = _resolve(p, energy, controller, fl.n_clients)
    n = fl.n_clients

    key = jax.random.PRNGKey(fl.seed)
    params = init_params(jax.random.fold_in(key, 1))

    # pre-build per-round client batches lazily inside the jitted round
    def client_batches(round_idx):
        def one(cid):
            return client_data(cid, round_idx, fl.batch_per_client,
                               fl.local_steps)
        return jax.vmap(one)(jnp.arange(n))

    @jax.jit
    def round_fn(params, round_idx, rng):
        mask = jax.random.bernoulli(rng, p_vec, (n,))
        batches = client_batches(round_idx)

        def train_one(pp, bb):
            new_p, losses = local_train(loss_fn, pp, bb, opt)
            return new_p, losses

        client_params, losses = jax.vmap(train_one, in_axes=(None, 0))(
            params, batches)
        merged = fedavg_merge(params, client_params, mask)
        acc = eval_fn(merged, val_batch)
        return merged, mask, acc, jnp.mean(losses)

    ledger = EnergyLedger.create(n)
    tracker = ConvergenceTracker.create(fl.target_acc, fl.consecutive)
    accs = []
    t0 = time.time()
    rounds_done = fl.max_rounds
    for r in range(fl.max_rounds):
        rng = jax.random.fold_in(key, 10_000 + r)
        params, mask, acc, _ = round_fn(params, jnp.asarray(r), rng)
        ledger = ledger.record_round(mask, energy)
        tracker = tracker.update(acc, jnp.asarray(r, jnp.int32))
        accs.append(float(acc))
        if bool(tracker.converged):
            rounds_done = r + 1
            break
    wall = time.time() - t0
    return FLResult(
        rounds=rounds_done,
        converged=bool(tracker.converged),
        energy_wh=float(ledger.total_wh),
        acc_history=accs,
        participation_rate=float(jnp.mean(
            ledger.participation_counts / jnp.maximum(ledger.rounds, 1))),
        wall_s=wall,
        ledger_summary=ledger.summary(),
    )


@dataclasses.dataclass
class HeterogeneousReference:
    """Outcome of :func:`run_heterogeneous_reference` (one scenario).

    Attributes:
        rounds: realized rounds (eager early stop).
        converged: whether the accuracy target was hit.
        acc_history: per-round validation accuracies (length ``rounds``).
        ledger: the eager :class:`~repro.core.energy.EnergyLedger`
            (``per_node_j`` in Joules).
        aoi: the eager :class:`~repro.core.aoi.AoITracker`.
        present_counts: ``(N,)`` rounds each node was in the fleet.
        present_final: ``(N,)`` bool presence after the last round.
        straggler_counts: ``(N,)`` rounds each node attempted but missed
            the deadline (all zeros without a ``deadline`` config).
        wall_s: wall-clock seconds of the Python round loop.
    """

    rounds: int
    converged: bool
    acc_history: list
    ledger: EnergyLedger
    aoi: AoITracker
    present_counts: jax.Array
    present_final: jax.Array
    straggler_counts: jax.Array
    wall_s: float


def run_heterogeneous_reference(
    fl: FLConfig,
    init_params: Callable[[jax.Array], dict],
    loss_fn: Callable,
    eval_fn: Callable,
    client_data: Callable,
    val_batch: dict,
    opt: Optimizer,
    p: jax.Array,
    *,
    energy_rates_j: tuple | None = None,
    energy: EnergyParams | None = None,
    churn=None,
    deadline=None,
) -> HeterogeneousReference:
    """Per-node Python round loop — the heterogeneous engine's test oracle.

    The simplest possible statement of the heterogeneous round semantics:
    one jitted program per *round*, eager per-node ledger/AoI updates,
    eager presence bookkeeping, early ``break`` on convergence. The
    scan-fused engine (:func:`repro.federated.campaign.run_campaigns`)
    draws every random variable from the *same* RNG streams
    (``MASK_STREAM`` / ``CHURN_STREAM`` / ``DEADLINE_STREAM`` folds of
    ``PRNGKey(fl.seed)``), so the two produce bitwise-identical masks,
    per-node ledgers, and AoI trackers — pinned in
    ``tests/test_hetero_campaign.py``.

    Args:
        p: scalar or ``(N,)`` per-node participation probabilities (dtype
            preserved — Bernoulli uniforms are drawn in ``p``'s dtype).
        energy_rates_j: ``(e_participant_j, e_idle_j)`` per-round Joule
            rates, scalars or ``(N,)`` per-node vectors; overrides
            ``energy``.
        energy: shared :class:`EnergyParams` (default paper Table I).
        churn: optional :class:`~repro.federated.campaign.ChurnConfig`
            (single scenario: fields broadcastable to ``(N,)``).
        deadline: optional
            :class:`~repro.federated.campaign.DeadlineConfig` — stragglers
            attempt the round (full participant energy) but their updates
            miss the merge and leave their AoI unreset.
    """
    from repro.federated.campaign import (CHURN_STREAM, DEADLINE_STREAM,
                                          MASK_STREAM)

    n = fl.n_clients
    p_vec = jnp.asarray(p)
    if p_vec.ndim == 0:
        p_vec = jnp.broadcast_to(p_vec, (n,))
    if energy_rates_j is not None:
        e_part = jnp.asarray(energy_rates_j[0], jnp.float64)
        e_idle = jnp.asarray(energy_rates_j[1], jnp.float64)
    else:
        ep = energy or EnergyParams()
        e_part = jnp.asarray(ep.e_participant_j, jnp.float64)
        e_idle = jnp.asarray(ep.e_idle_j, jnp.float64)

    key = jax.random.PRNGKey(fl.seed)
    params = init_params(jax.random.fold_in(key, 1))

    if churn is not None:
        arrival, departure, present0 = (a[0] for a in churn.as_arrays(1, n))
        present = jnp.asarray(present0, bool)
    else:
        present = jnp.ones((n,), bool)
    miss = deadline.as_arrays(1, n)[0] if deadline is not None else None

    @jax.jit
    def round_fn(params, round_idx, rng, present, late):
        mask = jax.random.bernoulli(rng, p_vec, (n,)) & present
        delivered = mask & ~late
        batches = jax.vmap(
            lambda cid: client_data(cid, round_idx, fl.batch_per_client,
                                    fl.local_steps))(jnp.arange(n))
        client_params, _ = jax.vmap(
            lambda pp, bb: local_train(loss_fn, pp, bb, opt),
            in_axes=(None, 0))(params, batches)
        merged = fedavg_merge(params, client_params, delivered)
        return merged, mask, delivered, eval_fn(merged, val_batch)

    @jax.jit
    def churn_fn(rng, present):
        ka, kd = jax.random.split(rng)
        arrive = jax.random.bernoulli(ka, arrival, (n,))
        depart = jax.random.bernoulli(kd, departure, (n,))
        return jnp.where(present, ~depart, arrive)

    ledger = EnergyLedger.create(n)
    aoi = AoITracker.create(n)
    tracker = ConvergenceTracker.create(fl.target_acc, fl.consecutive)
    present_counts = jnp.zeros((n,), jnp.int64)
    straggler_counts = jnp.zeros((n,), jnp.int64)
    no_late = jnp.zeros((n,), bool)
    accs: list[float] = []
    t0 = time.time()
    rounds_done = fl.max_rounds
    for r in range(fl.max_rounds):
        if churn is not None:
            present = churn_fn(
                jax.random.fold_in(key, CHURN_STREAM + r), present)
            present_counts = present_counts + jnp.asarray(present, jnp.int64)
        if deadline is not None:
            late = jax.random.bernoulli(
                jax.random.fold_in(key, DEADLINE_STREAM + r), miss, (n,))
        else:
            late = no_late
        rng = jax.random.fold_in(key, MASK_STREAM + r)
        params, mask, delivered, acc = round_fn(
            params, jnp.asarray(r), rng, present, late)
        # attempts are charged; only delivered updates reset AoI
        ledger = ledger.record_round_j(mask, e_part, e_idle)
        aoi = aoi.update(delivered, present if churn is not None else None)
        straggler_counts = straggler_counts + jnp.asarray(
            mask & late, jnp.int64)
        tracker = tracker.update(acc, jnp.asarray(r, jnp.int32))
        accs.append(float(acc))
        if bool(tracker.converged):
            rounds_done = r + 1
            break
    if churn is None:
        present_counts = jnp.full((n,), rounds_done, jnp.int64)
    return HeterogeneousReference(
        rounds=rounds_done,
        converged=bool(tracker.converged),
        acc_history=accs,
        ledger=ledger,
        aoi=aoi,
        present_counts=present_counts,
        present_final=present,
        straggler_counts=straggler_counts,
        wall_s=time.time() - t0,
    )
