"""Client-side local training: E epochs of SGD on the private shard.

``local_train`` is a pure function (params in, params out) so the simulation
can ``vmap`` it across all clients — every client starts each round from the
same global model (FedAvg), which makes the whole round a single XLA program.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, apply_updates

__all__ = ["local_train", "make_local_train"]


def local_train(loss_fn: Callable, params, batches: dict, opt: Optimizer):
    """Run one optimizer step per leading-axis slice of ``batches``.

    batches: pytree whose leaves have leading axis = number of local steps
    (E epochs x minibatches, pre-shaped by the caller).
    """
    opt_state = opt.init(params)

    def step(carry, batch):
        p, s = carry
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        updates, s = opt.update(grads, s, p)
        return (apply_updates(p, updates), s), loss

    (params, _), losses = jax.lax.scan(step, (params, opt_state), batches)
    return params, losses


def make_local_train(loss_fn: Callable, opt: Optimizer):
    """Returns f(params, batches) -> (new_params, losses), vmap-ready."""
    def fn(params, batches):
        return local_train(loss_fn, params, batches, opt)
    return fn
