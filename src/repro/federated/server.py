"""FedAvg server: participation-masked merge + convergence tracking.

The merge implements McMahan et al.'s FedAvg restricted to the round's
participants (paper §III): equal data shards ⇒ unweighted mean over the
participating subset. If nobody participates the global model is unchanged
(the round is wasted — exactly the energy/duration penalty the game studies).

``fedavg_merge`` operates on *stacked* client params (leading client axis) so
it runs as one fused XLA op per leaf — and dispatches to its Pallas twin
(:mod:`repro.kernels.fedavg_agg` via ``ops.fedavg_merge_pallas``) when the
kernel backend is selected (``backend="pallas"``, ``ops.set_backend``, or
``REPRO_KERNEL_BACKEND=pallas``; the default ``"ref"`` keeps the pure-jnp
path and its bitwise-reproducible results).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["fedavg_merge", "ConvergenceTracker"]


def fedavg_merge(global_params, client_params, mask: jax.Array,
                 weights: jax.Array | None = None, *,
                 backend: str | None = None):
    """Masked (weighted) average of stacked client params.

    Args:
        global_params: pytree (no client axis) — fallback when k = 0.
        client_params: same pytree with leading client axis N.
        mask: (N,) bool/0-1 participation.
        weights: optional (N,) data-size weights (paper: equal shards).
        backend: ``"ref"`` (default; pure-jnp per-leaf merge, bitwise
            stable) or ``"pallas"`` (flatten-once fused kernel, fp32
            round-trip — parity to tolerance). ``None`` resolves through
            :func:`repro.kernels.ops.resolve_backend` at trace time.
    """
    from repro.kernels import ops as kernel_ops  # lazy: keep imports light

    if kernel_ops.resolve_backend(
            backend, default="ref", site="server.fedavg_merge") == "pallas":
        m = mask if weights is None \
            else mask.astype(jnp.float32) * weights.astype(jnp.float32)
        return kernel_ops.fedavg_merge_pallas(global_params, client_params, m)
    m = mask.astype(jnp.float32)
    if weights is not None:
        m = m * weights.astype(jnp.float32)
    total = jnp.sum(m)
    safe = jnp.maximum(total, 1e-9)

    def merge(g, c):
        mexp = m.reshape((-1,) + (1,) * (c.ndim - 1)).astype(jnp.float32)
        avg = jnp.sum(c.astype(jnp.float32) * mexp, axis=0) / safe
        return jnp.where(total > 0, avg, g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree.map(merge, global_params, client_params)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ConvergenceTracker:
    """Paper §IV: converged when val acc >= target for 3 consecutive rounds."""

    target: jax.Array            # float scalar
    needed: jax.Array            # int scalar (3 in the paper)
    streak: jax.Array
    converged_at: jax.Array      # round index or -1

    @staticmethod
    def create(target: float = 0.73, needed: int = 3) -> "ConvergenceTracker":
        return ConvergenceTracker(
            target=jnp.asarray(target, jnp.float32),
            needed=jnp.asarray(needed, jnp.int32),
            streak=jnp.zeros((), jnp.int32),
            converged_at=jnp.asarray(-1, jnp.int32),
        )

    def update(self, acc: jax.Array, round_idx: jax.Array) -> "ConvergenceTracker":
        hit = acc >= self.target
        streak = jnp.where(hit, self.streak + 1, 0)
        first = (self.converged_at < 0) & (streak >= self.needed)
        return ConvergenceTracker(
            target=self.target, needed=self.needed, streak=streak,
            converged_at=jnp.where(first, round_idx, self.converged_at))

    def masked_update(self, acc: jax.Array, round_idx: jax.Array,
                      active: jax.Array) -> "ConvergenceTracker":
        """`update` when ``active`` else identity — for fixed-length scan
        round loops where post-convergence rounds are accounting no-ops."""
        upd = self.update(acc, round_idx)
        return jax.tree.map(lambda new, old: jnp.where(active, new, old),
                            upd, self)

    @property
    def converged(self) -> jax.Array:
        return self.converged_at >= 0
