"""Cluster-mode FedAvg: clients are data-parallel shard groups (shard_map).

In datacenter FL (DESIGN.md §3) each client is one shard group along the
``data`` (and ``pod``) mesh axes. Each group computes its local update from
its private shard; the merge is a participation-masked ``psum`` over those
axes — the paper's eq.-FedAvg with Bernoulli participation, expressed as an
explicit collective so the roofline's collective term *is* the paper's
merge cost.

``fedavg_allreduce_merge`` is written with ``jax.shard_map``: per-device
code sees its own client's update + scalar mask and participates in two
psums (masked sum + participant count).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["fedavg_allreduce_merge", "make_cluster_round"]


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """Version shim: ``jax.shard_map(check_vma=...)`` on new JAX,
    ``jax.experimental.shard_map.shard_map(check_rep=...)`` on old —
    replication checking off in both (the merge psums by hand)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def fedavg_allreduce_merge(global_params, local_update, mask_local,
                           mesh: Mesh, axes: Sequence[str] = ("data",)):
    """Masked FedAvg across mesh axes via shard_map + psum.

    Args:
        global_params: replicated pytree (previous global model).
        local_update: pytree with the same structure — THIS shard group's
            proposed params, sharded so each (axes)-group holds its own
            version (leading 'client' dim of size = prod(axes sizes)).
        mask_local: (n_clients,) bool — participation of each group.
    Returns:
        merged params, replicated (identical on every device).
    """
    n_clients = 1
    for a in axes:
        n_clients *= mesh.shape[a]

    def merge_fn(g, upd, mask):
        # per-device view: upd leaves have leading dim 1 (this group's copy)
        idx = jax.lax.axis_index(axes[0])
        if len(axes) > 1:
            for a in axes[1:]:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        m = mask[idx].astype(jnp.float32)
        total = jax.lax.psum(m, axes)

        def one(g_leaf, u_leaf):
            contrib = u_leaf[0].astype(jnp.float32) * m
            s = jax.lax.psum(contrib, axes)
            avg = s / jnp.maximum(total, 1e-9)
            return jnp.where(total > 0, avg,
                             g_leaf.astype(jnp.float32)).astype(g_leaf.dtype)

        return jax.tree.map(one, g, upd)

    client_spec = P(tuple(axes))
    in_specs = (
        jax.tree.map(lambda _: P(), global_params),
        jax.tree.map(lambda _: client_spec, local_update),
        P(),
    )
    out_specs = jax.tree.map(lambda _: P(), global_params)
    fn = _shard_map(merge_fn, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs)
    return fn(global_params, local_update, mask_local)


def make_cluster_round(loss_fn, opt, mesh: Mesh, axes=("data",)):
    """One cluster FL round: local step per shard group + masked merge.

    Returns round(params, opt_state, batch, mask) jittable under `mesh`,
    where batch leaves have a leading client dim sharded over `axes`.
    """
    n_clients = 1
    for a in axes:
        n_clients *= mesh.shape[a]

    def round_fn(params, opt_state, batch, mask):
        def local(p, b):
            loss, grads = jax.value_and_grad(loss_fn)(p, b)
            updates, _ = opt.update(grads, opt.init(p), p)
            from repro.optim.base import apply_updates
            return apply_updates(p, updates), loss

        def per_client(b):
            return local(params, b)

        client_params, losses = jax.vmap(
            per_client, in_axes=(jax.tree.map(lambda _: 0, batch),))(batch)
        merged = fedavg_allreduce_merge(params, client_params, mask, mesh,
                                        axes)
        return merged, losses

    return round_fn
