"""Cluster-mode FedAvg: clients are data-parallel shard groups (shard_map).

In datacenter FL (DESIGN.md §3) each client is one shard group along the
``data`` (and ``pod``) mesh axes. Each group computes its local update from
its private shard; the merge is a participation-masked ``psum`` over those
axes — the paper's eq.-FedAvg with Bernoulli participation, expressed as an
explicit collective so the roofline's collective term *is* the paper's
merge cost.

``fedavg_allreduce_merge`` is written with ``jax.shard_map``: per-device
code sees its own *block* of client updates (the stacked leading client
axis splits over the mesh axes, so large fleets place ``n_clients /
n_devices`` clients per device) plus that block's slice of the mask, and
participates in two psums (masked sum + participant count). Accumulation
runs in ``promote_types(leaf_dtype, float32)`` — f64 leaves merge at full
f64 precision (the campaign layer's mixed f64/bf16 contract), bf16 leaves
still accumulate in f32.

``make_cluster_round`` carries one optimizer state per client (stacked
leading client axis, see :func:`init_cluster_opt_state`) across rounds —
momentum/Adam moments persist round to round exactly like a sequential
per-client loop (pinned in ``tests/test_distributed.py``).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim.base import apply_updates

__all__ = ["fedavg_allreduce_merge", "init_cluster_opt_state",
           "make_cluster_round"]


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """Version shim: ``jax.shard_map(check_vma=...)`` on new JAX,
    ``jax.experimental.shard_map.shard_map(check_rep=...)`` on old —
    replication checking off in both (the merge psums by hand)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def fedavg_allreduce_merge(global_params, local_update, mask_local,
                           mesh: Mesh, axes: Sequence[str] = ("data",)):
    """Masked FedAvg across mesh axes via shard_map + psum.

    Args:
        global_params: replicated pytree (previous global model).
        local_update: pytree with the same structure plus a leading client
            axis of size ``n_clients``; it splits over ``axes``, so each
            device holds a contiguous block of ``n_clients / n_devices``
            clients' proposed params (``n_clients`` must divide evenly).
        mask_local: (n_clients,) bool — participation of each client.
        mesh / axes: the device mesh and the axes the client dim spans.

    Returns:
        merged params, replicated (identical on every device). Each leaf
        accumulates in ``promote_types(leaf_dtype, float32)`` — f64 stays
        f64 end to end — and is cast back to the leaf dtype.
    """
    n_devices = 1
    for a in axes:
        n_devices *= mesh.shape[a]
    n_clients = jax.tree.leaves(mask_local)[0].shape[0]
    if n_clients % n_devices != 0:
        raise ValueError(
            f"{n_clients} clients over {n_devices} devices along {axes}: "
            "the client axis must split evenly")
    per = n_clients // n_devices

    def merge_fn(g, upd, mask):
        # per-device view: upd leaves carry this device's block of `per`
        # clients; the mask is replicated, so slice this block's entries.
        idx = jax.lax.axis_index(axes[0])
        if len(axes) > 1:
            for a in axes[1:]:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        m_block = jax.lax.dynamic_slice_in_dim(mask, idx * per, per)
        total = jax.lax.psum(jnp.sum(m_block.astype(jnp.float32)), axes)

        def one(g_leaf, u_leaf):
            acc = jnp.promote_types(g_leaf.dtype, jnp.float32)
            m = m_block.astype(acc).reshape(
                (per,) + (1,) * (u_leaf.ndim - 1))
            contrib = jnp.sum(u_leaf.astype(acc) * m, axis=0)
            s = jax.lax.psum(contrib, axes)
            avg = s / jnp.maximum(total.astype(acc), 1e-9)
            return jnp.where(total > 0, avg,
                             g_leaf.astype(acc)).astype(g_leaf.dtype)

        return jax.tree.map(one, g, upd)

    client_spec = P(tuple(axes))
    in_specs = (
        jax.tree.map(lambda _: P(), global_params),
        jax.tree.map(lambda _: client_spec, local_update),
        P(),
    )
    out_specs = jax.tree.map(lambda _: P(), global_params)
    fn = _shard_map(merge_fn, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs)
    return fn(global_params, local_update, mask_local)


def init_cluster_opt_state(opt, params, n_clients: int):
    """Per-client optimizer states, stacked along a leading client axis.

    The stacked pytree feeds :func:`make_cluster_round`'s ``opt_state``
    argument (and round outputs thread straight back in), so every client
    keeps its own Adam/momentum moments across rounds.
    """
    return jax.vmap(lambda _: opt.init(params))(jnp.arange(n_clients))


def make_cluster_round(loss_fn, opt, mesh: Mesh, axes=("data",)):
    """One cluster FL round: local step per shard group + masked merge.

    Returns ``round_fn(params, opt_state, batch, mask) -> (merged,
    opt_state, losses)``, jittable under ``mesh``: ``opt_state`` and the
    ``batch`` leaves carry a leading client dim (sharded over ``axes``;
    build the initial state with :func:`init_cluster_opt_state`). The
    returned ``opt_state`` is each client's *advanced* state — thread it
    into the next round so optimizer moments accumulate across rounds
    instead of resetting (the seed version re-``init``-ed per round and
    dropped the update, silently degrading Adam to sign-less SGD).
    """
    def round_fn(params, opt_state, batch, mask):
        def local(p, st, b):
            loss, grads = jax.value_and_grad(loss_fn)(p, b)
            updates, new_st = opt.update(grads, st, p)
            return apply_updates(p, updates), new_st, loss

        client_params, new_state, losses = jax.vmap(
            local, in_axes=(None, 0, 0))(params, opt_state, batch)
        merged = fedavg_allreduce_merge(params, client_params, mask, mesh,
                                        axes)
        return merged, new_state, losses

    return round_fn
