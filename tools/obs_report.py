#!/usr/bin/env python
"""Validate and render ``repro.obs/v1`` artifacts.

Three modes over the BENCH/trace/events files the benchmarks emit:

* ``--check a.json b.jsonl …`` — schema-validate every file (BENCH
  artifacts via :func:`repro.obs.export.validate_artifact`, ``.jsonl``
  event streams via :func:`~repro.obs.export.validate_events_jsonl`,
  Chrome traces structurally) and exit non-zero listing every problem.
  This is the CI gate after the benchmark smoke steps.
* ``--table a.json …`` — print the markdown performance table the README
  carries, one row per headline number per artifact.
* ``--readme README.md a.json …`` — splice that table between the
  ``<!-- obs:perf-table -->`` markers in the README, in place.

Usage::

    PYTHONPATH=src python tools/obs_report.py --check BENCH_*.json OBS_events.jsonl
    PYTHONPATH=src python tools/obs_report.py --readme README.md BENCH_*.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.obs.export import (SCHEMA, validate_artifact,       # noqa: E402
                              validate_events_jsonl)

START = "<!-- obs:perf-table:start -->"
END = "<!-- obs:perf-table:end -->"
SCALING_START = "<!-- obs:scaling-table:start -->"
SCALING_END = "<!-- obs:scaling-table:end -->"


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def _check_trace(obj: object, path: str) -> list[str]:
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return [f"{path}: not a Chrome trace (no 'traceEvents')"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list) or not evs:
        return [f"{path}: empty traceEvents"]
    problems = []
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            problems.append(f"{path}: traceEvents[{i}] missing ph/name")
        elif ev["ph"] not in ("M", "i") and "ts" not in ev:
            problems.append(f"{path}: traceEvents[{i}] missing ts")
    return problems


def check(paths: list[str]) -> int:
    problems: list[str] = []
    for p in paths:
        path = pathlib.Path(p)
        if not path.exists():
            problems.append(f"{p}: missing")
            continue
        if path.suffix == ".jsonl":
            problems += validate_events_jsonl(
                path.read_text().splitlines(), path=p)
            continue
        try:
            obj = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            problems.append(f"{p}: unparseable JSON ({e})")
            continue
        if isinstance(obj, dict) and "traceEvents" in obj:
            problems += _check_trace(obj, p)
        else:
            problems += validate_artifact(obj, path=p)
    if problems:
        for problem in problems:
            print(f"FAIL {problem}")
        return 1
    print(f"ok: {len(paths)} file(s) conform to {SCHEMA}")
    return 0


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

def _rows_campaign(name: str, art: dict) -> list[tuple[str, str, str, str]]:
    d = art["data"]
    label = ("Table II campaign sweep" if art["kind"] == "campaign_sweep"
             else "Stratified-fleet sweep (churn + tiered rates)")
    sweep = (f"{d['scenarios']} campaigns x {d['max_rounds']} rounds")
    rows = [(f"{label} ({sweep})",
             "scan-fused engine vs Python-loop reference",
             f"{d['fused_s']:.2f} s vs {d['reference_s']:.1f} s — "
             f"**{d['speedup']:.0f}x**", name)]
    by_backend = d.get("fused_s_by_backend", {})
    if "pallas" in by_backend:
        rows.append((f"FedAvg merge backends ({sweep})",
                     '`backend="ref"` vs `backend="pallas"` (interpret)',
                     f"{by_backend['ref']:.2f} s vs "
                     f"{by_backend['pallas']:.2f} s",
                     f"{name} `fused_s_by_backend`"))
    if "obs_overhead_pct" in d:
        rows.append((f"Metric-stream instrumentation ({sweep})",
                     "in-carry obs buffers vs uninstrumented (bitwise-equal)",
                     f"{d['obs_overhead_pct']:+.1f}% (bar ≤ 5%)",
                     f"{name} `obs_overhead_pct`"))
    return rows


def _rows_kernels(name: str, art: dict) -> list[tuple[str, str, str, str]]:
    ks = art["data"]["kernels"]
    rows = []
    if "poibin_dft" in ks:
        k = ks["poibin_dft"]
        rows.append(("Poisson-binomial batch (64 x N=50: pmf + all loo)",
                     "`poibin_dft` kernel (interpret) vs jnp ref",
                     f"{k['pallas_interpret']['p50_us'] / 1e3:.1f} ms vs "
                     f"{k['ref']['p50_us'] / 1e3:.1f} ms", name))
    rows.append((f"Kernel micro-bench suite ({len(ks)} kernels)",
                 "pallas-interpret + ref p50/p95/mean per kernel",
                 "both backends", name))
    return rows


def _rows_gap(name: str, art: dict) -> list[tuple[str, str, str, str]]:
    rows = []
    for kname, k in art["data"]["kernels"].items():
        rows.append((
            f"`{kname}` gap localization",
            "compile-vs-execute + XLA cost_analysis, both backends",
            f"pallas/ref p50 = **{k['pallas_over_ref_p50']:.1f}x**; "
            f"pallas {k['pallas']['flops']:.1e} flops / "
            f"{k['pallas']['bytes_accessed']:.1e} B vs "
            f"ref {k['ref']['flops']:.1e} / {k['ref']['bytes_accessed']:.1e}",
            name))
    return rows


def _rows_smoke(name: str, art: dict) -> list[tuple[str, str, str, str]]:
    d = art["data"]
    return [("Instrumented smoke campaign",
             "metric stream + event taps + span trace, all on",
             f"{d['events']} events, bitwise-equal outputs", name)]


def _rows_sharded(name: str, art: dict) -> list[tuple[str, str, str, str]]:
    d = art["data"]
    ne_top = d["ne"]["scaling"][-1]
    eq = d["equivalence"]
    return [
        (f"Mesh-sharded NE sweep ({ne_top['scenarios']:,} scenarios, "
         f"N={d['ne']['n_nodes']})",
         f"`solve_heterogeneous(mesh=...)` on {ne_top['devices']} devices",
         f"{ne_top['warm_s']:.1f} s — {ne_top['throughput_per_s']:,.0f} "
         f"scen/s, weak-scaling eff {ne_top['efficiency']:.2f}", name),
        (f"Sharded == single-device contract (B={eq['scenarios']}, "
         f"non-divisible)",
         "`run_campaigns(mesh=...)` vs unsharded engine",
         f"ledger bitwise={eq['ledger_bitwise']}, params max|diff| "
         f"{eq['params_max_abs_diff']:.1e} (bar "
         f"{eq['params_tolerance']:.0e})", name),
    ]


def _rows_serve(name: str, art: dict) -> list[tuple[str, str, str, str]]:
    d = art["data"]
    lat = d["latency_us"]
    return [(f"Sweep service, mixed closed-loop load ({d['requests']} "
             f"requests: {d['by_kind'].get('ne_solve', 0)} NE / "
             f"{d['by_kind'].get('calibrate', 0)} γ* / "
             f"{d['by_kind'].get('campaign', 0)} campaign)",
             "`repro.serve` padded/bucketed AOT program cache",
             f"{d['throughput_rps']:.1f} req/s, p50 "
             f"{lat['p50_us'] / 1e3:.0f} ms / p95 "
             f"{lat['p95_us'] / 1e3:.0f} ms, cache hit "
             f"{d['cache_hit_rate']:.0%}, padding {d['padding_overhead']:.1%}",
             name)]


_RENDERERS = {
    "campaign_sweep": _rows_campaign,
    "hetero_campaign": _rows_campaign,
    "kernels_micro": _rows_kernels,
    "kernel_gap": _rows_gap,
    "obs_smoke": _rows_smoke,
    "sharded_campaign": _rows_sharded,
    "serve_load": _rows_serve,
}


def render_table(paths: list[str]) -> str:
    rows: list[tuple[str, str, str, str]] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.suffix != ".json" or not path.exists():
            continue
        art = json.loads(path.read_text())
        renderer = _RENDERERS.get(art.get("kind"))
        if renderer is None:
            continue
        rows += renderer(path.name, art)
    lines = ["| hot path | program | measured | artifact |",
             "|---|---|---|---|"]
    for a, b, c, d in rows:
        fname, _, field = d.partition(" ")
        cell = f"`{fname}`" + (f" {field}" if field else "")
        lines.append(f"| {a} | {b} | {c} | {cell} |")
    return "\n".join(lines)


def render_scaling_table(paths: list[str]) -> str | None:
    """Weak-scaling table from a ``sharded_campaign`` artifact, or None."""
    for p in paths:
        path = pathlib.Path(p)
        if path.suffix != ".json" or not path.exists():
            continue
        art = json.loads(path.read_text())
        if art.get("kind") != "sharded_campaign":
            continue
        d = art["data"]
        lines = ["| devices | campaigns | campaigns/s | NE scenarios "
                 "| NE scen/s | NE per-device | NE efficiency |",
                 "|---|---|---|---|---|---|---|"]
        for c_row, n_row in zip(d["campaign"]["scaling"], d["ne"]["scaling"]):
            lines.append(
                f"| {n_row['devices']} | {c_row['scenarios']} "
                f"| {c_row['throughput_per_s']:,.1f} "
                f"| {n_row['scenarios']:,} "
                f"| {n_row['throughput_per_s']:,.0f} "
                f"| {n_row['per_device_per_s']:,.0f} "
                f"| {n_row['efficiency']:.2f} |")
        lines.append(
            f"\nEquivalence on {d['devices']} faked CPU devices "
            f"(B={d['equivalence']['scenarios']}, non-divisible): ledger "
            f"bitwise = {d['equivalence']['ledger_bitwise']}, params "
            f"max|diff| = {d['equivalence']['params_max_abs_diff']:.1e} "
            f"(bar {d['equivalence']['params_tolerance']:.0e}). "
            f"Source: `{path.name}`.")
        return "\n".join(lines)
    return None


def _splice(text: str, start: str, end: str, body: str) -> str:
    head, rest = text.split(start, 1)
    _, tail = rest.split(end, 1)
    return head + start + "\n" + body + "\n" + end + tail


def splice_readme(readme: str, paths: list[str]) -> int:
    p = pathlib.Path(readme)
    text = p.read_text()
    if START not in text or END not in text:
        print(f"FAIL {readme}: missing {START} / {END} markers")
        return 1
    text = _splice(text, START, END, render_table(paths))
    scaling = render_scaling_table(paths)
    if scaling is not None and SCALING_START in text and SCALING_END in text:
        text = _splice(text, SCALING_START, SCALING_END, scaling)
    p.write_text(text)
    print(f"updated {readme} performance table from {len(paths)} artifact(s)")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--check", action="store_true",
                    help="validate files against the obs schemas")
    ap.add_argument("--readme", metavar="README",
                    help="splice the rendered table into this file's markers")
    ap.add_argument("paths", nargs="+",
                    help="BENCH_*.json / TRACE_*.json / *.jsonl files")
    args = ap.parse_args(argv)
    if args.check:
        return check(args.paths)
    if args.readme:
        return splice_readme(args.readme, args.paths)
    print(render_table(args.paths))
    return 0


if __name__ == "__main__":
    sys.exit(main())
