#!/usr/bin/env python
"""Execute every fenced ``python`` snippet in Markdown docs — so docs can't rot.

Walks the given Markdown files (default: ``docs/*.md`` and ``README.md``),
extracts fenced code blocks whose info string starts with ``python``, and
``exec``-utes them **per file in one shared namespace**, in order — later
snippets may build on names defined by earlier ones, exactly as a reader
would type them into one REPL session.

Opt-outs:

* fences tagged ``python no-run`` are skipped (use sparingly — e.g. for
  pseudo-code signatures);
* non-python fences (``bash``, ASCII diagrams, …) are ignored.

Any exception fails the run with the offending ``file:line`` so CI (the
``docs`` job in ``.github/workflows/ci.yml``) pins every published snippet
to the real API.

Run:  PYTHONPATH=src python tools/run_doc_snippets.py [files...]
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time
import traceback

REPO = pathlib.Path(__file__).resolve().parent.parent


def extract_snippets(path: pathlib.Path) -> list[tuple[int, str]]:
    """(first_code_lineno, code) for each runnable ```python fence."""
    snippets: list[tuple[int, str]] = []
    lines = path.read_text().splitlines()
    cur: list[str] | None = None
    info = ""
    start = 0
    for i, line in enumerate(lines, 1):
        stripped = line.strip()
        if cur is None and stripped.startswith("```") and stripped != "```":
            info = stripped[3:].strip().lower()
            cur, start = [], i + 1
        elif cur is not None and stripped == "```":
            if info.split() and info.split()[0] == "python" \
                    and "no-run" not in info:
                snippets.append((start, "\n".join(cur)))
            cur = None
        elif cur is not None:
            cur.append(line)
    if cur is not None:
        raise SystemExit(f"{path}: unterminated code fence at line {start}")
    return snippets


def run_file(path: pathlib.Path) -> tuple[int, bool]:
    """Execute the file's snippets in one namespace; (count, ok)."""
    snippets = extract_snippets(path)
    if not snippets:
        print(f"-- {path}: no runnable python snippets")
        return 0, True
    ns: dict = {"__name__": f"__doc_snippet__[{path.name}]"}
    for lineno, code in snippets:
        # pad so tracebacks report real line numbers within the .md file
        src = "\n" * (lineno - 1) + code
        t0 = time.time()
        try:
            exec(compile(src, str(path), "exec"), ns)
        except Exception:
            print(f"FAIL {path}:{lineno}")
            traceback.print_exc()
            return len(snippets), False
        print(f"  ok {path}:{lineno}  ({time.time() - t0:.1f}s)", flush=True)
    return len(snippets), True


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", type=pathlib.Path,
                    help="Markdown files (default: docs/*.md + README.md)")
    args = ap.parse_args()
    files = args.files or [*sorted((REPO / "docs").glob("*.md")),
                           REPO / "README.md"]

    total, t0, ok = 0, time.time(), True
    for path in files:
        n, file_ok = run_file(path)
        total += n
        ok = ok and file_ok
        if not file_ok:
            break
    status = "PASS" if ok else "FAIL"
    print(f"{status}: {total} snippets across {len(files)} file(s) "
          f"in {time.time() - t0:.1f}s")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
