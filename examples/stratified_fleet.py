"""Spontaneous stratification, end-to-end through the FL campaign engine.

PR 2's beyond-paper finding (pinned in
``tests/test_asymmetric_batched.py::test_identical_nodes_can_stratify``):
an *identical*-node fleet outside the symmetric equilibrium's stability
region settles on a **certified asymmetric** NE — a few "workers" at
p = 1 carry the task while the rest free-ride near P_MIN — without any
cost heterogeneity.

This example pushes that game-layer finding through the FL runtime for the
first time: the stratified equilibrium profile, the heterogeneity-aware
planner profile, and the uniform-γ* mechanism's induced NE are replayed as
three *per-node* campaign scenarios inside one scan-fused program, and the
realized per-node energy/AoI splits show what stratification costs whom.

Run:  PYTHONPATH=src python examples/stratified_fleet.py
"""
import jax.numpy as jnp
import numpy as np

import repro.core  # noqa: F401  (enables x64)
from repro.core.controller import ParticipationController
from repro.core.duration import theoretical_duration
from repro.federated.campaign import run_campaigns
from repro.federated.simulation import FLConfig
from repro.federated.tasks import synthetic_mlp_task
from repro.optim import sgd

N = 10
COST, GAMMA = 6.0, 0.2   # identical fleet, outside the stable region


def main():
    ctrl = ParticipationController(n_nodes=N, gamma=GAMMA, cost=COST,
                                   duration_model=theoretical_duration(N))
    gammas = jnp.full((1, N), GAMMA)
    costs = jnp.full((1, N), COST)
    kw = dict(damping=0.6, max_iters=300)

    # one (B, N) matrix per policy, all through the batched asymmetric engine
    p_ne = ctrl.solve_batched(gammas, costs, mode="ne", **kw)
    p_plan = ctrl.solve_batched(gammas, costs, mode="centralized", **kw)
    p_mech = ctrl.solve_batched(gammas, costs, mode="mechanism",
                                coarse=12, **kw)
    spread = float(jnp.max(p_ne) - jnp.min(p_ne))
    print(f"identical fleet (c={COST}, gamma={GAMMA}, N={N})")
    print(f"  NE profile:        {np.round(np.asarray(p_ne[0]), 3)}")
    print(f"  -> stratified (max-min = {spread:.2f}), no cost heterogeneity")
    print(f"  planner profile:   {np.round(np.asarray(p_plan[0]), 3)}")
    print(f"  uniform-γ* NE:     {np.round(np.asarray(p_mech[0]), 3)}")

    # replay all three as per-node campaigns in ONE scan+vmap program
    task = synthetic_mlp_task()
    fl = FLConfig(n_clients=N, local_steps=1, batch_per_client=8,
                  max_rounds=60, target_acc=0.73, seed=7)
    p_matrix = jnp.concatenate([p_ne, p_plan, p_mech], axis=0)
    res = run_campaigns(fl, *task.campaign_args(), sgd(0.15), p_matrix)

    names = ("stratified NE", "planner", "uniform-γ* NE")
    print(f"\n{'scenario':<16}{'rounds':>7}{'energy Wh':>11}{'mean AoI':>10}")
    for i, name in enumerate(names):
        print(f"{name:<16}{int(res.rounds[i]):>7}"
              f"{float(res.energy_wh[i]):>11.1f}"
              f"{float(res.mean_aoi[i]):>10.2f}"
              + ("" if bool(res.converged[i]) else "  (no convergence)"))

    # who pays for stratification: realized per-node splits of scenario 0
    e = np.asarray(res.per_node_energy_wh[0])
    a = np.asarray(res.per_node_aoi[0])
    p0 = np.asarray(res.p[0])
    workers = p0 > 0.5
    print(f"\nstratified-NE per-node split ({int(workers.sum())} workers / "
          f"{int((~workers).sum())} free-riders):")
    print(f"  energy Wh: workers {e[workers].mean():.2f} "
          f"vs free-riders {e[~workers].mean():.2f}")
    print(f"  mean AoI:  workers {a[workers].mean():.2f} "
          f"vs free-riders {a[~workers].mean():.2f}")
    print("workers subsidize the fleet in energy *and* hold all the fresh "
          "information; the uniform-γ* reward spreads both.")


if __name__ == "__main__":
    main()
