"""Ablation: participation economics under non-iid (Dirichlet) client data.

The paper assumes iid shards ("randomly but fairly divided"). Real IoT
fleets are label-skewed; this ablation shows that non-iid data *steepens*
d(p) — each missing participant withholds unique label mass, so low
participation hurts more than the iid theory predicts, widening the
Tragedy-of-the-Commons energy gap.

Run:  PYTHONPATH=src python examples/noniid_ablation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import (dirichlet_partition, iid_partition,
                                  sharded_client_data)
from repro.data.synthetic import SyntheticCifar
from repro.federated.campaign import run_campaigns
from repro.federated.simulation import FLConfig
from repro.optim import sgd

N_CLIENTS = 16
N_SAMPLES = 8192


def build_task(alpha: float | None):
    """alpha=None -> iid; else Dirichlet(alpha) label-skew partition."""
    data = SyntheticCifar(noise=7.0)
    key = jax.random.PRNGKey(0)
    full = data.batch(key, N_SAMPLES)
    labels_np = np.asarray(full["labels"])
    if alpha is None:
        parts = iid_partition(N_SAMPLES, N_CLIENTS, seed=0)
    else:
        parts = dirichlet_partition(labels_np, N_CLIENTS, alpha=alpha, seed=0)
    # per-node shard API: pads shards and binds the per-(client, round)
    # minibatch sampler — no hand-rolled masking
    client_data = sharded_client_data(full["images"], labels_np, parts,
                                      seed=1)

    def init_params(key):
        k1, k2 = jax.random.split(key)
        d = 32 * 32 * 3
        return {"w1": jax.random.normal(k1, (d, 32)) * d ** -0.5,
                "b1": jnp.zeros(32),
                "w2": jax.random.normal(k2, (32, 10)) * 32 ** -0.5,
                "b2": jnp.zeros(10)}

    def fwd(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss_fn(p, b):
        lp = jax.nn.log_softmax(fwd(p, b["images"]))
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1))

    def eval_fn(p, b):
        return jnp.mean(jnp.argmax(fwd(p, b["images"]), -1) == b["labels"])

    return data, init_params, loss_fn, eval_fn, client_data


def main():
    print(f"{'regime':<16}{'p':>6}{'rounds':>8}{'energy Wh':>11}")
    results = {}
    ps = (0.25, 0.7)
    for alpha, label in [(None, "iid"), (0.1, "dirichlet(0.1)")]:
        data, init_params, loss_fn, eval_fn, client_data = build_task(alpha)
        fl = FLConfig(n_clients=N_CLIENTS, local_steps=1,
                      batch_per_client=4, max_rounds=100,
                      target_acc=0.73, seed=4)
        # both p scenarios ride one scan-fused campaign program
        res = run_campaigns(fl, init_params, loss_fn, eval_fn, client_data,
                            data.val_set(512), sgd(0.12),
                            jnp.asarray(ps, jnp.float32))
        for i, p in enumerate(ps):
            results[(label, p)] = int(res.rounds[i])
            print(f"{label:<16}{p:>6.2f}{int(res.rounds[i]):>8}"
                  f"{float(res.energy_wh[i]):>11.1f}"
                  + ("" if bool(res.converged[i]) else "  (no convergence)"))
    iid_ratio = results[("iid", 0.25)] / max(results[("iid", 0.7)], 1)
    nid_ratio = results[("dirichlet(0.1)", 0.25)] / max(
        results[("dirichlet(0.1)", 0.7)], 1)
    print(f"\nd(p=0.25)/d(p=0.7): iid {iid_ratio:.2f} vs "
          f"non-iid {nid_ratio:.2f}")
    if nid_ratio > iid_ratio:
        print("non-iid steepens d(p) (here mildly): low participation costs "
              "more than the iid theory predicts -> incentives matter more.")
    else:
        print("on this synthetic task label skew did not steepen d(p) "
              "measurably — the template task is learnable from any shard.")


if __name__ == "__main__":
    main()
