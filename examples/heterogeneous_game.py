"""Beyond-paper example: heterogeneous IoT fleet (battery vs mains nodes).

Half the fleet runs on batteries (high participation cost), half on mains
power (low cost). The asymmetric game stratifies participation; the uniform
planner of the paper cannot express that and pays for it.

Run:  PYTHONPATH=src python examples/heterogeneous_game.py
"""
import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.core.asymmetric import (HeterogeneousGame, best_response_dynamics,
                                   planner_coordinate_descent,
                                   verify_equilibrium)


def main():
    n = 14
    dur = C.theoretical_duration(n_nodes=n, d_inf=35.0, slope=8.0)
    # mains-powered gateways (cheap) + battery sensors (expensive)
    costs = jnp.asarray([0.5] * (n // 2) + [9.0] * (n - n // 2))
    gammas = jnp.full((n,), 0.6)
    game = HeterogeneousGame(costs=costs, gammas=gammas, dur=dur)

    p_ne, conv, iters = best_response_dynamics(game, damping=0.6)
    assert conv
    print(f"asymmetric NE found in {iters} Gauss-Seidel sweeps "
          f"(max profitable deviation "
          f"{verify_equilibrium(game, p_ne):.2e})")
    print(f"  mains nodes   (c=0.5): p = "
          f"{[round(float(x), 3) for x in p_ne[:n//2]]}")
    print(f"  battery nodes (c=9.0): p = "
          f"{[round(float(x), 3) for x in p_ne[n//2:]]}")

    ne_cost = float(game.social_cost(p_ne))
    grid = jnp.linspace(1e-3, 1.0, 300)
    uni_costs = [float(game.social_cost(jnp.full((n,), float(q))))
                 for q in grid]
    uni_best = float(grid[int(np.argmin(uni_costs))])
    uni_cost = min(uni_costs)
    p_opt = planner_coordinate_descent(game, p_ne)
    het_cost = float(game.social_cost(p_opt))

    print(f"\nsocial cost:")
    print(f"  asymmetric NE                 {ne_cost:9.1f}")
    print(f"  best uniform-p planner (p={uni_best:.2f}) {uni_cost:9.1f}")
    print(f"  heterogeneity-aware planner   {het_cost:9.1f}")
    print(f"\nheterogeneous PoA = {ne_cost / het_cost:.3f}")
    if ne_cost < uni_cost:
        print("note: the stratified NE UNDERCUTS the uniform planner — the "
              "paper's common-p benchmark stops being the right target once "
              "node costs differ.")


if __name__ == "__main__":
    main()
