"""Beyond-paper example: heterogeneous IoT fleet (battery vs mains nodes).

Half the fleet runs on batteries (high participation cost), half on mains
power (low cost). The asymmetric game stratifies participation; the uniform
planner of the paper cannot express that and pays for it.

Part 1 solves one fleet through the batched engine (solve → certify →
planner → heterogeneous PoA, all jitted). Part 2 shows why the batching
matters: a 200-scenario sweep over the battery/mains cost ratio runs as one
vmapped XLA program, and calibrates the smallest uniform AoI weight γ* that
keeps the fleet within 5% of the planner.

Run:  PYTHONPATH=src python examples/heterogeneous_game.py
"""
import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.core.asymmetric_batched import poa_report, social_cost_batched
from repro.mechanisms import calibrate_gamma_heterogeneous


def single_fleet(n: int, dur) -> None:
    # mains-powered gateways (cheap) + battery sensors (expensive)
    costs = jnp.asarray([0.5] * (n // 2) + [9.0] * (n - n // 2))
    gammas = jnp.full((n,), 0.6)

    rep = poa_report(costs, gammas, dur, damping=0.6)
    (p_ne, conv, iters) = rep.solution.single()
    assert conv
    print(f"asymmetric NE found in {iters} Gauss-Seidel sweeps "
          f"(max profitable deviation {float(rep.deviation[0]):.2e})")
    print(f"  mains nodes   (c=0.5): p = "
          f"{[round(float(x), 3) for x in p_ne[:n//2]]}")
    print(f"  battery nodes (c=9.0): p = "
          f"{[round(float(x), 3) for x in p_ne[n//2:]]}")

    grid = jnp.linspace(1e-3, 1.0, 300)
    uni = social_cost_batched(jnp.broadcast_to(costs, (300, n)), dur,
                              jnp.broadcast_to(grid[:, None], (300, n)))
    uni_best = float(grid[int(np.argmin(np.asarray(uni)))])
    uni_cost = float(jnp.min(uni))
    ne_cost = float(rep.ne_cost[0])
    het_cost = float(rep.opt_cost[0])

    print("\nsocial cost:")
    print(f"  asymmetric NE                 {ne_cost:9.1f}")
    print(f"  best uniform-p planner (p={uni_best:.2f}) {uni_cost:9.1f}")
    print(f"  heterogeneity-aware planner   {het_cost:9.1f}")
    print(f"\nheterogeneous PoA = {float(rep.poa[0]):.3f}")
    if ne_cost < uni_cost:
        print("note: the stratified NE UNDERCUTS the uniform planner — the "
              "paper's common-p benchmark stops being the right target once "
              "node costs differ.")


def scenario_sweep(n: int, dur, batch: int = 200) -> None:
    """One vmapped solve over the fleet's cost spread, then γ* calibration."""
    spreads = np.linspace(1.0, 24.0, batch)    # costliest/cheapest node ratio
    costs = np.stack([np.linspace(0.5, 0.5 * s, n) for s in spreads])
    gammas = jnp.zeros((batch, n))             # selfish fleet: no incentive
    rep = poa_report(jnp.asarray(costs), gammas, dur, damping=0.6,
                     max_iters=300)
    assert bool(jnp.all(rep.solution.converged))
    assert float(jnp.max(rep.deviation)) <= 1e-4
    poas = np.asarray(rep.poa)
    worst = int(np.argmax(poas))
    print(f"\n{batch}-scenario cost-spread sweep (one XLA program): "
          f"PoA in [{poas.min():.3f}, {poas.max():.3f}], "
          f"worst at spread {spreads[worst]:.1f}x")

    cal = calibrate_gamma_heterogeneous(
        jnp.asarray(costs[worst]), dur, target_poa=1.05,
        damping=0.6, max_iters=300)
    print(f"uniform-γ* calibration at the worst spread: γ* = "
          f"{cal.gamma_star:.3f} → PoA {cal.poa:.3f} "
          f"(target {cal.target_poa}, achieved={cal.achieved}, "
          f"NE certified to {cal.deviation:.1e})")


def main():
    n = 14
    dur = C.theoretical_duration(n_nodes=n, d_inf=35.0, slope=8.0)
    single_fleet(n, dur)
    scenario_sweep(n, dur)


if __name__ == "__main__":
    main()
