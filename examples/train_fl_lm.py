"""End-to-end driver: participatory federated training of a real LM.

Wraps a registry model (default: a 12-layer / d_model=768 decoder LM,
~103M params, GPT-2-small class) into the task factory
(:func:`repro.federated.tasks.model_task`) and trains it with the
scan-fused campaign engine — game-theoretic participation from
:class:`~repro.core.controller.ParticipationController`, full energy
metering, optional Dirichlet non-iid shards and Pallas-backed kernels.
This is deliverable (b)'s "train ~100M model for a few hundred steps"
driver, rewired through the same engine the paper sweeps run on.

CPU note: at the default --rounds 200 this takes a few hours on the
1-core container; --small (~7M params) finishes in minutes with the same
code path. Any registry architecture works via --arch (reduced variant),
e.g. ``--arch rwkv6-3b --backend pallas``.

Run:  PYTHONPATH=src python examples/train_fl_lm.py --small --rounds 30
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import ARCHITECTURES
from repro.configs.base import ModelConfig
from repro.core.controller import ParticipationController
from repro.data.synthetic import SyntheticLM
from repro.federated.campaign import run_campaigns
from repro.federated.simulation import FLConfig
from repro.federated.tasks import model_task
from repro.models.registry import param_count
from repro.optim import adamw
from repro.checkpoint.checkpoint import save_checkpoint

LM_100M = ModelConfig(
    name="fl-lm-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32768,
    act="swiglu", norm="rmsnorm", param_dtype="float32",
    compute_dtype="float32",
)

LM_SMALL = dataclasses.replace(
    LM_100M, name="fl-lm-small", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=8, d_ff=1024, vocab=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200,
                    help="FedAvg rounds (the campaign scan length)")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--arch", default="",
                    help="registry architecture (reduced variant) instead "
                         "of the built-in LM, e.g. rwkv6-3b, hymba-1.5b, "
                         "resnet18-cifar")
    ap.add_argument("--backend", default="none",
                    choices=["none", "ref", "pallas"],
                    help="kernel backend for the client fwd/bwd "
                         "(pallas = interpret mode on CPU)")
    ap.add_argument("--noniid", action="store_true",
                    help="Dirichlet label-skewed shards instead of iid "
                         "streams")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--n-clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--gamma", type=float, default=0.6)
    ap.add_argument("--cost", type=float, default=2.0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if args.arch:
        cfg = ARCHITECTURES[args.arch].reduced()
    else:
        cfg = LM_SMALL if args.small else LM_100M

    ctrl = ParticipationController(n_nodes=50, gamma=args.gamma,
                                   cost=args.cost, mode="ne")
    p = ctrl.participation_probability()
    print(f"game-theoretic participation p = {p:.3f} "
          f"(opt {ctrl.diagnostics()['opt_p']:.3f}, "
          f"PoA {ctrl.diagnostics()['poa']:.2f})")

    task = model_task(
        cfg, args.seq,
        backend=None if args.backend == "none" else args.backend,
        data=(None if cfg.family == "vision"
              else SyntheticLM(vocab=cfg.vocab, order_weight=0.8)),
        partition="dirichlet" if args.noniid else "iid",
        alpha=args.alpha, n_clients=args.n_clients,
        optimizer=adamw(args.lr))
    n_params = param_count(task.init_params(jax.random.PRNGKey(0)))
    print(f"model {cfg.name}: {n_params:,} params, "
          f"partition={'dirichlet' if args.noniid else 'iid'}, "
          f"backend={args.backend}")

    fl = FLConfig(n_clients=args.n_clients, local_steps=args.local_steps,
                  batch_per_client=args.batch, max_rounds=args.rounds,
                  seed=0)
    # B=1 scenario through the scan-fused engine; CampaignResult carries
    # metrics, the energy ledger, AND the final merged weights.
    t0 = time.time()
    res = run_campaigns(
        fl, *task.campaign_args(), task.opt,
        jax.numpy.full((1, args.n_clients), p, jax.numpy.float32),
        energy=ctrl.energy_params)
    jax.block_until_ready(res.energy_wh)
    wall = time.time() - t0

    rounds = int(res.rounds[0])
    accs = [float(a) for a in res.acc_history[0][:rounds]]
    tail = ", ".join(f"{a:.3f}" for a in accs[-5:])
    print(f"{rounds} rounds in {wall:.1f}s "
          f"(converged={bool(res.converged[0])})")
    print(f"val acc trajectory tail: [{tail}]")
    print(f"energy {float(res.energy_wh[0]):.2f} Wh, "
          f"mean AoI {float(res.mean_aoi[0]):.2f} rounds, "
          f"realized participation {float(res.participation_rate[0]):.3f}")
    print("ledger:", res.scenario_ledger(0).summary())

    if args.ckpt_dir:
        params = jax.tree.map(lambda x: x[0], res.params)
        print("saved", save_checkpoint(args.ckpt_dir, rounds,
                                       {"params": params},
                                       metadata={"arch": cfg.name}))


if __name__ == "__main__":
    main()
