"""End-to-end driver: participatory federated training of a ~100M LM.

Trains a 12-layer / d_model=768 decoder LM (~103M params, GPT-2-small class)
for a few hundred FedAvg rounds on synthetic LM data, with game-theoretic
participation control and full energy metering. This is deliverable (b)'s
"train ~100M model for a few hundred steps" driver.

CPU note: at the default --steps 200 this takes a few hours on the 1-core
container; --small (~7M params) finishes in minutes with the same code path.

Run:  PYTHONPATH=src python examples/train_fl_lm.py --small --steps 30
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.controller import ParticipationController
from repro.data.synthetic import SyntheticLM
from repro.models.registry import get_model, param_count
from repro.optim import adamw
from repro.optim.base import apply_updates, clip_by_global_norm
from repro.checkpoint.checkpoint import save_checkpoint

LM_100M = ModelConfig(
    name="fl-lm-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32768,
    act="swiglu", norm="rmsnorm", param_dtype="float32",
    compute_dtype="float32",
)

LM_SMALL = dataclasses.replace(
    LM_100M, name="fl-lm-small", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=8, d_ff=1024, vocab=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--n-clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--gamma", type=float, default=0.6)
    ap.add_argument("--cost", type=float, default=2.0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = LM_SMALL if args.small else LM_100M
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = api.init(key)
    print(f"model {cfg.name}: {param_count(params):,} params")

    ctrl = ParticipationController(n_nodes=50, gamma=args.gamma,
                                   cost=args.cost, mode="ne")
    p = ctrl.participation_probability()
    print(f"game-theoretic participation p = {p:.3f} "
          f"(opt {ctrl.diagnostics()['opt_p']:.3f}, "
          f"PoA {ctrl.diagnostics()['poa']:.2f})")

    data = SyntheticLM(vocab=cfg.vocab, order_weight=0.8)
    opt = adamw(args.lr)
    opt_state = opt.init(params)
    ledger = ctrl.new_ledger() if False else None  # ledger is per-50-nodes
    from repro.core.energy import EnergyLedger
    ledger = EnergyLedger.create(args.n_clients)

    @jax.jit
    def round_fn(params, opt_state, batch, mask):
        def one(cb):
            return jax.value_and_grad(lambda q: api.loss(q, cb))(params)

        losses, grads = jax.vmap(one)(batch)
        m = mask.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(m), 1.0)
        avg = jax.tree.map(
            lambda g: jnp.sum(
                g.astype(jnp.float32)
                * m.reshape((-1,) + (1,) * (g.ndim - 1)), axis=0) / denom,
            grads)
        avg, gnorm = clip_by_global_norm(avg, 1.0)
        updates, opt_state = opt.update(avg, opt_state, params)
        new_params = apply_updates(params, updates)
        keep = jnp.sum(m) > 0
        new_params = jax.tree.map(
            lambda a, b: jnp.where(keep, a, b), new_params, params)
        return new_params, opt_state, jnp.sum(losses * m) / denom

    t0 = time.time()
    for step in range(args.steps):
        kb = jax.random.fold_in(key, 100 + step)
        batch = jax.vmap(lambda k: data.batch(k, args.batch, args.seq))(
            jax.random.split(kb, args.n_clients))
        mask = jax.random.bernoulli(jax.random.fold_in(kb, 1), p,
                                    (args.n_clients,))
        params, opt_state, loss = round_fn(params, opt_state, batch, mask)
        ledger = ledger.record_round(mask, ctrl.energy_params)
        if step % max(1, args.steps // 20) == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"round {step:4d}  loss {float(loss):6.3f}  "
                  f"k={int(mask.sum())}/{args.n_clients}  "
                  f"energy {float(ledger.total_wh):7.2f} Wh  ({dt:6.1f}s)")
    print("ledger:", ledger.summary())
    if args.ckpt_dir:
        print("saved", save_checkpoint(args.ckpt_dir, args.steps,
                                       {"params": params},
                                       metadata={"arch": cfg.name}))


if __name__ == "__main__":
    main()
