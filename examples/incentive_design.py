"""Design the incentive instead of sweeping it (repro.mechanisms demo).

The paper measures PoA ≥ 1.28 for distributed participatory FL and calls for
AoI-based incentive mechanisms (§V). This example closes the loop:

1. sweep the *uncalibrated* game over c (one batched solve) to exhibit the
   PoA gap and the Tragedy-of-the-Commons collapse;
2. calibrate the smallest AoI weight γ*(c) driving the worst induced NE
   within 5% of the centralized optimum, and plot the planner budget it
   costs (aoi_reward);
3. price participation directly with a Stackelberg leader and report
   planner expenditure vs. energy saved (stackelberg);
4. run the ParticipationController in ``mode="mechanism"``.

Writes PNGs under experiments/figures/ and prints the headline numbers.

Run:  PYTHONPATH=src python examples/incentive_design.py
"""
import os

import jax.numpy as jnp
import numpy as np

import matplotlib
matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

from repro.core.controller import ParticipationController
from repro.core.duration import paper_duration_model
from repro.core.utility import UtilityParams
from repro.mechanisms import (StackelbergPlanner, calibrate_gamma,
                              evaluate_mechanism, solve_batched)

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "experiments", "figures")
N = 50
TARGET_POA = 1.05


def poa_gap(dur, costs):
    """Fig. A: the gap the mechanism must close — uncalibrated PoA vs c."""
    sol = solve_batched(jnp.zeros(len(costs)), jnp.asarray(costs), dur)
    poa = np.asarray(sol.poa)
    plt.figure(figsize=(5, 4))
    plt.plot(costs, poa, "r-o", ms=3, label="no mechanism (worst NE)")
    plt.axhline(1.28, color="gray", lw=0.8, ls=":", label="paper PoA=1.28")
    plt.axhline(TARGET_POA, color="k", lw=0.8, ls="--",
                label=f"design target {TARGET_POA}")
    plt.xlabel("cost factor c")
    plt.ylabel("Price of Anarchy")
    plt.yscale("log")
    plt.legend()
    plt.title("A: PoA gap without a mechanism")
    plt.tight_layout()
    plt.savefig(f"{OUT}/incentive_a_poa_gap.png", dpi=120)
    plt.close()
    print(f"A: PoA at c={costs[len(costs)//2]:.1f}: "
          f"{poa[len(costs)//2]:.2f}; worst over sweep {np.max(poa):.1f}")
    return poa


def aoi_calibration(dur, costs):
    """Fig. B: smallest γ*(c) hitting the PoA target + its planner budget."""
    rows = []
    for c in costs:
        base = UtilityParams(gamma=0.0, cost=float(c), n_nodes=N)
        cal = calibrate_gamma(base, dur, target_poa=TARGET_POA)
        rep = evaluate_mechanism(cal.mechanism, base, dur)
        rows.append((c, cal.gamma_star, rep.poa, rep.planner_budget,
                     rep.ne_p, rep.individually_rational))
    c, g, poa, budget, ne, ir = map(np.asarray, zip(*rows))

    fig, ax1 = plt.subplots(figsize=(5.5, 4))
    ax1.plot(c, g, "b-o", ms=3, label="calibrated γ*")
    ax1.set_xlabel("cost factor c")
    ax1.set_ylabel("smallest γ* for PoA ≤ 1.05", color="b")
    ax2 = ax1.twinx()
    ax2.plot(c, budget, "g--s", ms=3, label="planner budget")
    ax2.set_ylabel("planner budget / round (utility units)", color="g")
    fig.suptitle("B: AoI-reward calibration γ*(c)")
    fig.tight_layout()
    fig.savefig(f"{OUT}/incentive_b_gamma_star.png", dpi=120)
    plt.close(fig)
    mid = len(c) // 2
    print(f"B: c={c[mid]:.1f}: γ*={g[mid]:.2f} → PoA {poa[mid]:.3f} "
          f"(NE p={ne[mid]:.2f}, budget {budget[mid]:.0f}/round, "
          f"IR={'yes' if ir[mid] else 'NO'}; paper eyeballed γ≈0.6)")


def stackelberg(dur, c=8.0):
    """Fig. C: leader's rate response curve + expenditure vs energy saved."""
    base = UtilityParams(gamma=0.0, cost=c, n_nodes=N)
    planner = StackelbergPlanner(budget_weight=0.1)
    sol = planner.solve(base, dur)

    fig, ax1 = plt.subplots(figsize=(5.5, 4))
    ax1.plot(sol.rate_grid, sol.worst_ne_grid, "b-", label="worst NE p(r)")
    ax1.axvline(sol.rate, color="k", ls="--", lw=0.8,
                label=f"chosen r*={sol.rate:.2f}")
    ax1.set_xlabel("per-participation reward rate r")
    ax1.set_ylabel("induced participation p", color="b")
    ax2 = ax1.twinx()
    ax2.plot(sol.rate_grid, sol.social_cost_grid, "r-",
             label="social cost (true c)")
    ax2.set_ylabel("social cost E[D] + c·p", color="r")
    fig.suptitle("C: Stackelberg pricing of participation")
    fig.tight_layout()
    fig.savefig(f"{OUT}/incentive_c_stackelberg.png", dpi=120)
    plt.close(fig)
    print(f"C: c={c}: r*={sol.rate:.2f} → NE p={sol.report.ne_p:.2f}, "
          f"PoA {sol.report.poa:.3f}, spend {sol.planner_spend_per_round:.0f}"
          f"/round, saves {sol.energy_saved_wh:.0f} Wh/task "
          f"(IR={'yes' if sol.report.individually_rational else 'NO'})")


def controller_demo(c=5.0):
    """mode="mechanism": the runtime picks the incentive-backed NE."""
    selfish = ParticipationController(n_nodes=N, gamma=0.0, cost=c,
                                      mode="ne_worst")
    mech = ParticipationController(n_nodes=N, gamma=0.0, cost=c,
                                   mode="mechanism")
    d = mech.diagnostics()
    print(f"D: controller c={c}: selfish worst-NE p="
          f"{selfish.participation_probability():.2f} (PoA "
          f"{selfish.solve().poa:.2f}) → mechanism p={d['p']:.2f} "
          f"(PoA {d['mechanism_poa']:.3f}, budget "
          f"{d['planner_budget']:.0f}/round)")


def main():
    os.makedirs(OUT, exist_ok=True)
    dur = paper_duration_model()
    costs = np.linspace(0.5, 12.0, 12)
    poa_gap(dur, costs)
    aoi_calibration(dur, costs[::3])
    stackelberg(dur)
    controller_demo()
    print(f"\nplots written to {OUT}/")


if __name__ == "__main__":
    main()
