"""Quickstart: the paper in 60 seconds.

1. Solve the participation game (NE, centralized optimum, PoA).
2. Run participatory FL under each solution — all scenarios batched into
   ONE scan-fused campaign program (repro.federated.campaign).
3. Compare realized energy — the Tragedy of the Commons, measured.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.controller import ParticipationController
from repro.data.synthetic import SyntheticCifar
from repro.federated.campaign import run_campaigns
from repro.federated.simulation import FLConfig
from repro.optim import sgd


def make_task():
    data = SyntheticCifar(noise=7.0)

    def init_params(key):
        k1, k2 = jax.random.split(key)
        d = 32 * 32 * 3
        return {"w1": jax.random.normal(k1, (d, 32)) * d ** -0.5,
                "b1": jnp.zeros(32),
                "w2": jax.random.normal(k2, (32, 10)) * 32 ** -0.5,
                "b2": jnp.zeros(10)}

    def fwd(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss_fn(p, b):
        lp = jax.nn.log_softmax(fwd(p, b["images"]))
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1))

    def eval_fn(p, b):
        return jnp.mean(jnp.argmax(fwd(p, b["images"]), -1) == b["labels"])

    def client_data(cid, rnd, n, steps):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), cid), rnd)
        return jax.vmap(lambda k: data.batch(k, n))(
            jax.random.split(key, steps))

    return data, init_params, loss_fn, eval_fn, client_data


def main():
    print("=== 1. Solve the participation game (N=50, gamma=0.6, c=2) ===")
    ctrl = ParticipationController(n_nodes=50, gamma=0.6, cost=2.0, mode="ne")
    diag = ctrl.diagnostics()
    print(f"  NE participation p*        = {diag['p']:.3f}")
    print(f"  centralized optimum p_opt  = {diag['opt_p']:.3f}")
    print(f"  Price of Anarchy           = {diag['poa']:.3f}"
          f"  (paper: 1.28 w/o incentive, ~1 with AoI incentive)")

    print("\n=== 2. Run participatory FL under each solution "
          "(one scan-fused campaign batch) ===")
    data, init_params, loss_fn, eval_fn, client_data = make_task()
    scenarios = [
        ("selfish NE (no incentive)", dict(gamma=0.0, mode="ne_worst")),
        ("NE + AoI incentive", dict(gamma=0.6, mode="ne")),
        ("centralized optimum", dict(gamma=0.0, mode="centralized")),
    ]
    ctrls = [ParticipationController(n_nodes=50, cost=2.0, **kw)
             for _, kw in scenarios]
    ps = jnp.asarray([c.participation_probability() for c in ctrls],
                     jnp.float32)
    fl = FLConfig(n_clients=50, local_steps=1, batch_per_client=2,
                  max_rounds=120, target_acc=0.73)
    # Every scenario runs inside ONE jitted lax.scan+vmap program; the old
    # one-scenario-per-call path survives as run_simulation (same engine,
    # B = 1) and run_simulation_reference (the Python-loop test oracle).
    res = run_campaigns(fl, init_params, loss_fn, eval_fn, client_data,
                        data.val_set(512), sgd(0.15), ps,
                        energy=[c.energy_params for c in ctrls])
    for i, (label, _) in enumerate(scenarios):
        print(f"  {label:28s} p={float(ps[i]):.2f}: "
              f"{int(res.rounds[i])} rounds, "
              f"{float(res.energy_wh[i]):7.1f} Wh "
              f"(participation rate {float(res.participation_rate[i]):.2f}, "
              f"mean AoI {float(res.mean_aoi[i]):.2f})")

    print("\n=== 3. The energy verdict ===")
    e_ne, e_inc, e_opt = (float(x) for x in res.energy_wh)
    print(f"  selfish / centralized energy ratio:   {e_ne / e_opt:.3f}"
          f"   (paper: >= 1.28 -> the Tragedy of the Commons)")
    print(f"  incentive / centralized energy ratio: {e_inc / e_opt:.3f}"
          f"   (paper: ~1 -> the AoI incentive fixes it)")


if __name__ == "__main__":
    main()
