"""Batched serving example: decode several requests with different cache
families (full KV, sliding-window ring, O(1) recurrent state) side by side.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES
from repro.data.synthetic import SyntheticLM
from repro.models.registry import get_model


def decode(name: str, batch: int = 4, prompt_len: int = 8, gen: int = 24):
    cfg = ARCHITECTURES[name].reduced()
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = api.init(key)
    data = SyntheticLM(vocab=cfg.vocab)
    prompt = data.batch(jax.random.fold_in(key, 1), batch,
                        prompt_len)["tokens"]
    total = prompt_len + gen

    if cfg.family == "ssm":
        cache, _ = api.init_cache(batch, 0, False)
        ring, kind = False, "recurrent state (O(1))"
    elif cfg.family == "hybrid":
        cache, _ = api.init_cache(batch, cfg.sliding_window, True)
        ring, kind = True, f"ring KV (W={cfg.sliding_window}) + SSM state"
    else:
        cache, _ = api.init_cache(batch, total, False)
        ring, kind = False, f"full KV cache ({total} slots)"

    serve = jax.jit(lambda p, c, t, i: api.serve_step(p, c, t, i, ring=ring))
    tok = prompt[:, :1]
    t0 = time.time()
    for i in range(total - 1):
        src = prompt[:, i:i + 1] if i < prompt_len else tok
        logits, cache = serve(params, cache, src, jnp.asarray(i, jnp.int32))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    print(f"{name:14s} [{kind:34s}] {batch}x{total} tokens "
          f"in {dt:5.2f}s ({batch * total / dt:6.1f} tok/s)")


def main():
    print("batched decode across cache families (reduced configs, CPU):")
    for name in ("gemma-2b", "olmoe-1b-7b", "hymba-1.5b", "rwkv6-3b"):
        decode(name)


if __name__ == "__main__":
    main()
