"""Reproduce the paper's game-theoretic figures (Figs. 2-6) end to end.

Writes PNG plots under experiments/figures/ and prints the headline numbers
next to the paper's claims.

Run:  PYTHONPATH=src python examples/game_analysis.py
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

import matplotlib
matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

from repro.core.duration import PAPER_TABLE_II, paper_duration_model
from repro.core.game import centralized_optimum, solve_game, solve_symmetric_ne
from repro.core.utility import UtilityParams, social_utility

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "experiments", "figures")
N = 50
GAMMA_STAR = 0.6


def fig1(dur):
    d, e = PAPER_TABLE_II[:, 1], PAPER_TABLE_II[:, 3]
    coef = np.polyfit(d, e, 1)
    plt.figure(figsize=(5, 4))
    plt.scatter(d, e, s=12, label="Table II(b)")
    xs = np.linspace(d.min(), d.max(), 50)
    plt.plot(xs, np.polyval(coef, xs), "r-",
             label=f"fit {coef[0]:.1f} Wh/round")
    plt.xlabel("rounds to converge d")
    plt.ylabel("energy E [Wh]")
    plt.legend()
    plt.title("Fig.1: E vs d (linear)")
    plt.tight_layout()
    plt.savefig(f"{OUT}/fig1_energy_vs_rounds.png", dpi=120)
    plt.close()
    print(f"fig1: E ≈ {coef[0]:.2f}·d + {coef[1]:.1f}  (paper: linear trend)")


def fig2(dur):
    grid = jnp.linspace(0.02, 1.0, 300)
    up = UtilityParams(gamma=0.0, cost=0.0, n_nodes=N)
    u = jax.vmap(lambda p: social_utility(p, up, dur))(grid)
    plt.figure(figsize=(5, 4))
    plt.plot(np.asarray(grid), np.asarray(u))
    peak = float(grid[int(jnp.argmax(u))])
    plt.axvline(peak, color="r", ls="--", label=f"peak p={peak:.2f}")
    plt.xlabel("participation probability p")
    plt.ylabel("utility (c=0)")
    plt.title("Fig.2: utility from the FL fit")
    plt.legend()
    plt.tight_layout()
    plt.savefig(f"{OUT}/fig2_utility.png", dpi=120)
    plt.close()
    print(f"fig2: utility peak at p={peak:.2f} (paper: ~0.6-0.7)")


def fig3(dur):
    gammas = np.linspace(0.0, 1.2, 7)
    costs = np.linspace(0.25, 6.0, 7)
    z = np.zeros((len(gammas), len(costs)))
    for i, g in enumerate(gammas):
        for j, c in enumerate(costs):
            nes = solve_symmetric_ne(
                UtilityParams(gamma=float(g), cost=float(c), n_nodes=N), dur,
                grid_size=250)
            z[i, j] = max(nes) if nes else 0.0
    plt.figure(figsize=(5.5, 4))
    cs = plt.contourf(costs, gammas, z, levels=10, cmap="viridis")
    plt.colorbar(cs, label="NE participation p")
    plt.xlabel("cost factor c")
    plt.ylabel("incentive weight gamma")
    plt.title("Fig.3: NE over (gamma, c)")
    plt.tight_layout()
    plt.savefig(f"{OUT}/fig3_ne_contour.png", dpi=120)
    plt.close()
    best = gammas[int(z.mean(axis=1).argmax())]
    print(f"fig3: participation-maximizing gamma ≈ {best:.2f} (paper: ~0.6)")


def figs456(dur):
    costs = np.linspace(0.25, 12.0, 13)
    rows = []
    for c in costs:
        up0 = UtilityParams(gamma=0.0, cost=float(c), n_nodes=N)
        up1 = UtilityParams(gamma=GAMMA_STAR, cost=float(c), n_nodes=N)
        opt_p, opt_cost = centralized_optimum(up0, dur)
        s0 = solve_game(up0, dur)
        s1 = solve_game(up1, dur)
        rows.append(dict(
            c=c, opt_p=opt_p,
            ne0=min(s0.equilibria) if s0.equilibria else 0.0,
            ne1=max(s1.equilibria) if s1.equilibria else 0.0,
            u_opt=-s0.opt_cost,
            u_ne0=-max(s0.ne_costs) if s0.ne_costs else np.nan,
            u_ne1=-max(s1.ne_costs) if s1.ne_costs else np.nan,
            poa0=s0.poa, poa1=s1.poa))
    c = [r["c"] for r in rows]

    plt.figure(figsize=(5, 4))
    plt.plot(c, [r["opt_p"] for r in rows], "k-", label="centralized opt")
    plt.plot(c, [r["ne0"] for r in rows], "r--", label="NE (no incentive)")
    plt.plot(c, [r["ne1"] for r in rows], "b-.", label="NE (AoI incentive)")
    plt.xlabel("cost factor c")
    plt.ylabel("participation p")
    plt.legend()
    plt.title("Fig.4: participation vs c")
    plt.tight_layout()
    plt.savefig(f"{OUT}/fig4_participation.png", dpi=120)
    plt.close()

    plt.figure(figsize=(5, 4))
    plt.plot(c, [r["u_opt"] for r in rows], "k-", label="centralized")
    plt.plot(c, [r["u_ne0"] for r in rows], "r--", label="NE no incentive")
    plt.plot(c, [r["u_ne1"] for r in rows], "b-.", label="NE AoI incentive")
    plt.xlabel("cost factor c")
    plt.ylabel("utility")
    plt.legend()
    plt.title("Fig.5: utility vs c")
    plt.tight_layout()
    plt.savefig(f"{OUT}/fig5_utility.png", dpi=120)
    plt.close()

    plt.figure(figsize=(5, 4))
    plt.plot(c, [r["poa0"] for r in rows], "r--", label="no incentive")
    plt.plot(c, [r["poa1"] for r in rows], "b-.", label="AoI incentive")
    plt.axhline(1.28, color="gray", lw=0.8, label="paper PoA=1.28")
    plt.xlabel("cost factor c")
    plt.ylabel("Price of Anarchy")
    plt.legend()
    plt.title("Fig.6: PoA vs c")
    plt.tight_layout()
    plt.savefig(f"{OUT}/fig6_poa.png", dpi=120)
    plt.close()

    mid = rows[2]
    print(f"fig4: c={mid['c']:.1f}: opt={mid['opt_p']:.2f} "
          f"ne={mid['ne0']:.2f} ne_aoi={mid['ne1']:.2f} "
          f"(paper c=0: 0.61 / 0.24 / 0.6)")
    print(f"fig6: PoA no-inc {mid['poa0']:.2f} vs inc {mid['poa1']:.2f} "
          f"(paper: 1.28 vs ~1); PoA@c={rows[-1]['c']:.0f}: "
          f"{rows[-1]['poa0']:.2f} vs {rows[-1]['poa1']:.2f}")


def main():
    os.makedirs(OUT, exist_ok=True)
    dur = paper_duration_model()
    fig1(dur)
    fig2(dur)
    fig3(dur)
    figs456(dur)
    print(f"\nplots written to {OUT}/")


if __name__ == "__main__":
    main()
