"""Hypothesis properties of the data-partition layer and the task factory.

Partition invariants (ISSUE 8 satellite 3):

* every dataset sample lands in **exactly one** Dirichlet shard;
* shards are deterministic in the seed;
* alpha → ∞ recovers near-iid per-client label histograms.

Task invariants: model-task losses stay finite float32 scalars across
(batch, seq) draws, and the per-(client, round) streams are deterministic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die, without it
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core  # noqa: F401  (x64 on: the campaign-context numerics)
from repro.configs import ARCHITECTURES
from repro.data.partition import dirichlet_partition, pad_shards
from repro.federated.tasks import model_task

label_sets = st.integers(0, 2 ** 31 - 1).flatmap(
    lambda seed: st.builds(
        lambda n, c: np.random.default_rng(seed).integers(0, c, n),
        st.integers(40, 400), st.integers(2, 10)))


@settings(max_examples=25, deadline=None)
@given(label_sets, st.integers(1, 8),
       st.floats(0.05, 100.0, allow_nan=False),
       st.integers(0, 2 ** 31 - 1))
def test_dirichlet_assigns_every_sample_exactly_once(labels, n_clients,
                                                     alpha, seed):
    parts = dirichlet_partition(labels, n_clients, alpha=alpha, seed=seed)
    assert len(parts) == n_clients
    flat = np.concatenate([p for p in parts]) if parts else np.array([])
    assert len(flat) == len(labels)                      # no drops
    assert len(np.unique(flat)) == len(labels)           # no duplicates
    np.testing.assert_array_equal(np.sort(flat), np.arange(len(labels)))


@settings(max_examples=25, deadline=None)
@given(label_sets, st.integers(1, 8),
       st.floats(0.05, 100.0, allow_nan=False),
       st.integers(0, 2 ** 31 - 1))
def test_dirichlet_is_deterministic_in_seed(labels, n_clients, alpha, seed):
    a = dirichlet_partition(labels, n_clients, alpha=alpha, seed=seed)
    b = dirichlet_partition(labels, n_clients, alpha=alpha, seed=seed)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_dirichlet_alpha_inf_is_near_iid(seed):
    """alpha → ∞ ⇒ every client's label histogram ≈ the global one."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, 4000)
    n_clients = 4
    parts = dirichlet_partition(labels, n_clients, alpha=1e6, seed=seed)
    global_hist = np.bincount(labels, minlength=10) / len(labels)
    for p in parts:
        hist = np.bincount(labels[p], minlength=10) / max(len(p), 1)
        # ~1000 samples/client: binomial noise keeps |Δ| well under 0.06
        assert np.max(np.abs(hist - global_hist)) < 0.06


@settings(max_examples=25, deadline=None)
@given(label_sets, st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_pad_shards_wraps_to_own_shard(labels, n_clients, seed):
    """Padding repeats a client's own indices — never leaks other shards."""
    parts = dirichlet_partition(labels, n_clients, alpha=5.0, seed=seed)
    if any(len(p) == 0 for p in parts):
        with pytest.raises(ValueError):
            pad_shards(parts)
        return
    shards = pad_shards(parts)
    assert shards.shape == (n_clients, max(len(p) for p in parts))
    for i, p in enumerate(parts):
        assert set(shards[i].tolist()) == set(np.asarray(p).tolist())


# -- task-factory stream properties ------------------------------------------

_LM_CFG = dataclasses.replace(
    ARCHITECTURES["stablelm-3b"].reduced(), n_layers=1, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64)
_TASK_CACHE: dict = {}


def _lm_task(seq: int):
    if seq not in _TASK_CACHE:
        _TASK_CACHE[seq] = model_task(_LM_CFG, seq, val_size=4)
    return _TASK_CACHE[seq]


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.sampled_from([4, 8, 16]),
       st.integers(0, 50), st.integers(0, 50))
def test_model_task_loss_finite_float32(batch, seq, cid, rnd):
    """Loss is a finite float32 scalar for any (batch, seq, client, round)."""
    task = _lm_task(seq)
    if "params" not in _TASK_CACHE:
        _TASK_CACHE["params"] = task.init_params(jax.random.PRNGKey(0))
    params = _TASK_CACHE["params"]
    batches = task.client_data(cid, rnd, batch, 1)
    assert batches["tokens"].shape == (1, batch, seq)
    assert batches["tokens"].dtype == jnp.int32
    loss = task.loss_fn(params, jax.tree.map(lambda x: x[0], batches))
    assert loss.shape == ()
    assert loss.dtype == jnp.float32          # stable under x64 mode
    assert bool(jnp.isfinite(loss))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50), st.integers(0, 50))
def test_model_task_stream_deterministic(cid, rnd):
    """client_data is pure in (seed, cid, rnd) — scan/vmap replay safety."""
    task = _lm_task(8)
    a = task.client_data(cid, rnd, 2, 2)
    b = task.client_data(cid, rnd, 2, 2)
    for ka, kb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))
