"""Observability layer (`repro.obs`) tests.

The contracts this file pins:

* **no-op off-switch** — obs disabled (or absent) leaves the campaign
  engine's outputs bitwise-identical, and obs *enabled* must too (the
  metric stream and event taps are derived observables, never inputs);
* event-sink callbacks fire under ``jit``/``lax.scan`` in program order
  (``ordered=True``) and once per batch element under ``vmap``;
* the metric-stream pytree rides the scan carry and round-trips with the
  realized round count at its cursor;
* dispatch counters count (site, backend) resolutions and reset;
* artifact/events schema validation accepts what the emitters produce and
  rejects structurally broken documents.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401  (enables x64)
from repro.federated.campaign import ChurnConfig, run_campaigns
from repro.federated.simulation import FLConfig
from repro.federated.tasks import synthetic_mlp_task
from repro.kernels import ops
from repro.obs import EventSink, ObsConfig, SpanTracer, compile_stats
from repro.obs.export import (EVENT_SCHEMA, SCHEMA, make_artifact,
                              timing_stats, validate_artifact,
                              validate_events_jsonl, write_artifact)
from repro.obs.metrics import MetricStream, merge_norm
from repro.optim import sgd


# ---------------------------------------------------------------------------
# ObsConfig
# ---------------------------------------------------------------------------

def test_obs_config_flags():
    off = ObsConfig()
    assert not off.record_metrics and not off.emit_events
    on = ObsConfig(enabled=True)
    assert on.record_metrics and not on.emit_events
    with pytest.raises(ValueError):
        ObsConfig(enabled=True, events=True)          # needs a sink
    sink = EventSink()
    full = ObsConfig(enabled=True, events=True, sink=sink)
    assert full.record_metrics and full.emit_events


# ---------------------------------------------------------------------------
# EventSink: callbacks under jit / scan / vmap
# ---------------------------------------------------------------------------

def test_events_ordered_under_jit_scan():
    """ordered=True taps inside a scanned jit arrive in program order."""
    sink = EventSink()

    @jax.jit
    def prog(x0):
        def step(c, i):
            c = c + i
            sink.tap("step", ordered=True, i=i, total=c)
            return c, c
        return jax.lax.scan(step, x0, jnp.arange(5, dtype=jnp.int32))[0]

    out = prog(jnp.int32(0))
    sink.flush()
    evs = sink.events
    assert [e["event"] for e in evs] == ["step"] * 5
    assert [e["i"] for e in evs] == list(range(5))
    assert [e["total"] for e in evs] == [0, 1, 3, 6, 10]
    assert [e["seq"] for e in evs] == list(range(5))
    assert int(out) == 10


def test_events_per_element_under_vmap():
    """Under vmap the tap fires once per batch element, unbatched values."""
    sink = EventSink()

    def one(tag, x):
        y = x * 2
        sink.tap("elem", tag=tag, y=y)
        return y

    jax.block_until_ready(
        jax.jit(jax.vmap(one))(jnp.arange(3), jnp.arange(3.0)))
    sink.flush()
    evs = sink.events
    assert len(evs) == 3
    assert sorted(e["tag"] for e in evs) == [0, 1, 2]
    for e in evs:
        assert e["y"] == pytest.approx(e["tag"] * 2.0)


def test_tap_valid_mask_filters_events():
    """tap(valid=...) drops events whose mask lands False — the hook the
    mesh path uses so padding-replica lanes (scenario_id = -1) never reach
    the stream."""
    sink = EventSink()

    def one(sid, x):
        sink.tap("elem", valid=sid >= 0, sid=sid, x=x)
        return x * 2

    jax.block_until_ready(
        jax.jit(jax.vmap(one))(jnp.asarray([0, -1, 2, -1]),
                               jnp.arange(4.0)))
    sink.flush()
    assert sorted(e["sid"] for e in sink.events) == [0, 2]
    # valid=None (the default) still emits unconditionally
    sink2 = EventSink()
    jax.block_until_ready(
        jax.jit(lambda x: (sink2.tap("e", x=x), x)[1])(jnp.float32(1)))
    sink2.flush()
    assert len(sink2.events) == 1


def test_disabled_sink_stages_nothing():
    """A disabled sink's tap must not even enter the traced program."""
    sink = EventSink(enabled=False)
    traced = jax.make_jaxpr(
        lambda x: (sink.tap("ev", x=x), x + 1)[1])(jnp.float32(0))
    assert "callback" not in str(traced)
    assert len(sink) == 0


def test_event_sink_writes_valid_jsonl(tmp_path):
    path = tmp_path / "events.jsonl"
    with EventSink(path) as sink:
        sink.emit("start", n=2)
        jax.block_until_ready(
            jax.jit(lambda x: (sink.tap("mid", x=x), x)[1])(jnp.arange(3)))
        sink.flush()
        sink.emit("end")
    lines = path.read_text().splitlines()
    assert validate_events_jsonl(lines) == []
    mid = json.loads(lines[1])
    assert mid["schema"] == EVENT_SCHEMA and mid["x"] == [0, 1, 2]


def test_event_sink_two_sinks_interleave_one_path(tmp_path):
    """Two sinks sharing a path append whole records — neither truncates
    the other's stream (append mode + per-record flush)."""
    path = tmp_path / "shared.jsonl"
    with EventSink(path) as a, EventSink(path) as b:
        for i in range(5):
            a.emit("from_a", i=i)
            b.emit("from_b", i=i)
    records = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(records) == 10
    # per-sink seq streams are intact and monotonic
    assert [r["seq"] for r in records if r["event"] == "from_a"] == \
        list(range(5))
    assert [r["seq"] for r in records if r["event"] == "from_b"] == \
        list(range(5))
    # per-record flush preserves emission order across the two sinks
    assert [(r["event"], r["i"]) for r in records] == \
        [(e, i) for i in range(5) for e in ("from_a", "from_b")]


# ---------------------------------------------------------------------------
# MetricStream
# ---------------------------------------------------------------------------

def test_metric_stream_roundtrip_through_scan():
    """The stream pytree rides a scan carry; cursor == recorded rounds."""
    def step(stream, r):
        rec = stream.record(participants=r, merge_norm=jnp.float32(r) / 10,
                            ledger_delta_j=jnp.float64(r) * 2.0,
                            accuracy=jnp.float32(0.5))
        return rec, None

    stream0 = MetricStream.create(6)
    out, _ = jax.jit(lambda s: jax.lax.scan(step, s, jnp.arange(4)))(stream0)
    assert int(out.cursor) == 4
    np.testing.assert_array_equal(np.asarray(out.participants),
                                  [0, 1, 2, 3, 0, 0])
    np.testing.assert_allclose(np.asarray(out.ledger_delta_j),
                               [0.0, 2.0, 4.0, 6.0, 0.0, 0.0])


def test_merge_norm_is_global_l2():
    a = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((3,))}
    b = {"w": jnp.full((2, 2), 2.0), "b": jnp.full((3,), 1.0)}
    np.testing.assert_allclose(float(merge_norm(b, a)),
                               np.sqrt(4 * 4.0 + 3 * 1.0), rtol=1e-6)


# ---------------------------------------------------------------------------
# campaign integration: the hard bitwise contract
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_campaign():
    task = synthetic_mlp_task()
    fl = FLConfig(n_clients=5, local_steps=1, batch_per_client=8,
                  max_rounds=8, target_acc=0.73, seed=3)
    ps = jnp.asarray([0.35, 0.8], jnp.float32)
    base = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps)
    return task, fl, ps, base


def test_campaign_obs_disabled_is_bitwise_noop(small_campaign):
    task, fl, ps, base = small_campaign
    res = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                        obs=ObsConfig(enabled=False))
    np.testing.assert_array_equal(np.asarray(res.acc_history),
                                  np.asarray(base.acc_history))
    np.testing.assert_array_equal(np.asarray(res.ledger.per_node_j),
                                  np.asarray(base.ledger.per_node_j))
    assert res.metrics is None


def test_campaign_obs_enabled_is_bitwise_and_streams(small_campaign):
    task, fl, ps, base = small_campaign
    res = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                        obs=ObsConfig(enabled=True))
    np.testing.assert_array_equal(np.asarray(res.acc_history),
                                  np.asarray(base.acc_history))
    np.testing.assert_array_equal(np.asarray(res.ledger.per_node_j),
                                  np.asarray(base.ledger.per_node_j))
    m = res.metrics
    np.testing.assert_array_equal(np.asarray(m.cursor),
                                  np.asarray(base.rounds))
    # stream contents cross-check the engine's own outputs
    for b in range(len(ps)):
        r = int(base.rounds[b])
        np.testing.assert_array_equal(np.asarray(m.participants[b, :r]),
                                      np.asarray(base.k_history[b, :r]))
        np.testing.assert_array_equal(np.asarray(m.accuracy[b, :r]),
                                      np.asarray(base.acc_history[b, :r]))
    # per-round ledger deltas integrate exactly to the final ledger
    np.testing.assert_allclose(
        np.asarray(jnp.sum(m.ledger_delta_j, axis=1)),
        np.asarray(base.ledger.total_j), rtol=0, atol=0)
    summary = m.summary()
    assert summary["rounds"] == [int(r) for r in base.rounds]
    assert json.dumps(summary)                        # JSON-able


def test_campaign_events_bitwise_and_content(small_campaign):
    task, fl, ps, base = small_campaign
    with EventSink() as sink:
        res = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                            obs=ObsConfig(enabled=True, events=True,
                                          sink=sink))
        jax.block_until_ready(res.acc_history)
        sink.flush()
        evs = sink.events
    np.testing.assert_array_equal(np.asarray(res.acc_history),
                                  np.asarray(base.acc_history))
    rounds = [e for e in evs if e["event"] == "round"]
    finals = [e for e in evs if e["event"] == "campaign"]
    assert len(rounds) == len(ps) * fl.max_rounds
    assert len(finals) == len(ps)
    for e in finals:
        b = e["scenario"]
        # converged_at is the round index, -1 if the campaign ran out
        want = (int(base.rounds[b]) - 1 if bool(base.converged[b]) else -1)
        assert e["converged_at"] == want
    for e in rounds:
        b, r = e["scenario"], e["round"]
        if e["active"]:
            assert e["participants"] == int(base.k_history[b, r])


def test_campaign_obs_with_churn(small_campaign):
    """Metrics slot in behind the churn carry entries without collision."""
    task, fl, ps, _ = small_campaign
    churn = ChurnConfig(arrival=0.5, departure=0.05)
    p_mat = jnp.tile(ps[:, None], (1, fl.n_clients))
    base = run_campaigns(fl, *task.campaign_args(), sgd(0.15), p_mat,
                         churn=churn)
    res = run_campaigns(fl, *task.campaign_args(), sgd(0.15), p_mat,
                        churn=churn, obs=ObsConfig(enabled=True))
    np.testing.assert_array_equal(np.asarray(res.acc_history),
                                  np.asarray(base.acc_history))
    np.testing.assert_array_equal(np.asarray(res.present_counts),
                                  np.asarray(base.present_counts))
    np.testing.assert_array_equal(np.asarray(res.metrics.cursor),
                                  np.asarray(base.rounds))


# ---------------------------------------------------------------------------
# dispatch stats (trace-time counters)
# ---------------------------------------------------------------------------

def test_dispatch_stats_from_real_call_sites():
    ops.reset_dispatch_stats()
    p = jnp.full((2, 6), 0.4)
    from repro.core.poibin import poibin_pmf_batched
    jax.block_until_ready(poibin_pmf_batched(p))
    jax.block_until_ready(poibin_pmf_batched(p, backend="pallas"))
    stats = ops.dispatch_stats()
    assert stats["poibin.pmf_batched"] == {"pallas": 1, "ref": 1}
    # explicit-pallas route re-dispatches through the ops wrapper
    assert stats["ops.poibin_pmf"] == {"pallas": 1}
    ops.reset_dispatch_stats()
    assert ops.dispatch_stats() == {}


def test_dispatch_counts_once_under_shard_map():
    """Per-call-site counters are trace-time: a shard_map body traces once,
    so the count must be 1 — not once per device replica. Runs over every
    device the process has (8 in the multi-device CI job)."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.federated.distributed import _shard_map

    mesh = Mesh(np.array(jax.devices()), ("data",))

    def body(x):
        ops.resolve_backend(None, default="ref", site="test.shard_map_site")
        return x * 2

    fn = _shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    ops.reset_dispatch_stats()
    x = jnp.arange(jax.device_count() * 2.0)
    jax.block_until_ready(jax.jit(fn)(x))
    assert ops.dispatch_stats()["test.shard_map_site"] == {"ref": 1}
    ops.reset_dispatch_stats()


def test_metrics_and_dispatch_once_under_mesh(small_campaign):
    """The sharded campaign engine: MetricStream bitwise vs unsharded and
    the merge dispatch counter counting the trace once (no per-replica
    double-count)."""
    from jax.sharding import Mesh

    task, fl, ps, base = small_campaign
    mesh = Mesh(np.array(jax.devices()), ("data",))
    ref = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                        obs=ObsConfig(enabled=True))
    ops.reset_dispatch_stats()
    res = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                        mesh=mesh, obs=ObsConfig(enabled=True))
    assert ops.dispatch_stats()["server.fedavg_merge"] == {"ref": 1}
    ops.reset_dispatch_stats()
    for a, b in zip(jax.tree.leaves(res.metrics), jax.tree.leaves(ref.metrics)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(res.metrics.cursor),
                                  np.asarray(base.rounds))


# ---------------------------------------------------------------------------
# tracer + compile stats
# ---------------------------------------------------------------------------

def test_span_tracer_chrome_trace(tmp_path):
    tracer = SpanTracer(process_name="t")
    with tracer.span("outer", n=3):
        with tracer.span("inner"):
            pass
    tracer.instant("mark")
    trace = tracer.to_chrome_trace()
    names = [e["name"] for e in trace["traceEvents"]]
    assert names[0] == "process_name"           # metadata record
    assert {"outer", "inner", "mark"} <= set(names)
    outer = next(e for e in trace["traceEvents"] if e["name"] == "outer")
    inner = next(e for e in trace["traceEvents"] if e["name"] == "inner")
    assert outer["ph"] == "X" and outer["args"] == {"n": 3}
    assert inner["ts"] >= outer["ts"]
    assert inner["dur"] <= outer["dur"]
    p = tracer.save(tmp_path / "trace.json")
    assert json.loads(p.read_text())["traceEvents"]
    assert tracer.summary()["outer"]["count"] == 1
    # disabled tracer: still yields, records nothing
    off = SpanTracer(enabled=False)
    with off.span("x"):
        pass
    assert off.spans == []


def test_compile_stats_reports_cost_and_timing():
    stats = compile_stats(lambda x: jnp.dot(x, x), jnp.ones((64, 64)),
                          iters=3)
    assert stats["lower_s"] >= 0 and stats["compile_s"] > 0
    assert stats["execute"]["n"] == 3
    assert stats["flops"] >= 2 * 64 ** 3 * 0.9     # one 64^3 matmul
    assert stats["bytes_accessed"] > 0


# ---------------------------------------------------------------------------
# artifact schema
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_and_validation(tmp_path):
    art = write_artifact(tmp_path / "BENCH_t.json", "unit_test",
                         {"timing": timing_stats([1e-3, 2e-3, 3e-3])},
                         seed=7, backend="ref")
    assert art["schema"] == SCHEMA and art["meta"]["seed"] == 7
    loaded = json.loads((tmp_path / "BENCH_t.json").read_text())
    assert validate_artifact(loaded) == []
    assert loaded["data"]["timing"]["n"] == 3
    assert loaded["data"]["timing"]["p50_us"] == pytest.approx(2000.0)


def test_validation_rejects_broken_artifacts():
    assert validate_artifact([]) != []
    assert any("schema" in p for p in validate_artifact(
        {"schema": "v0", "kind": "x", "meta": {}, "data": {}}))
    # incomplete timing block anywhere in data is an error
    art = make_artifact("x", {"t": {"p50_us": 1.0, "p95_us": 2.0}})
    assert any("timing block missing" in p for p in validate_artifact(art))
    # complete one is fine
    art = make_artifact("x", {"t": timing_stats([0.001])})
    assert validate_artifact(art) == []


def test_validation_rejects_broken_events():
    good = json.dumps({"schema": EVENT_SCHEMA, "event": "e",
                       "seq": 0, "ts_us": 1.0})
    assert validate_events_jsonl([good]) == []
    assert validate_events_jsonl([]) != []                    # empty stream
    assert validate_events_jsonl(["not json"]) != []
    bad_seq = [good, json.dumps({"schema": EVENT_SCHEMA, "event": "e",
                                 "seq": -1, "ts_us": 2.0})]
    assert any("seq" in p for p in validate_events_jsonl(bad_seq))


def test_timing_stats_shape():
    s = timing_stats([0.001] * 10)
    assert s == {"p50_us": 1000.0, "p95_us": 1000.0, "mean_us": 1000.0,
                 "min_us": 1000.0, "max_us": 1000.0, "n": 10}
    with pytest.raises(ValueError):
        timing_stats([])
