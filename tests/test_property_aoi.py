"""Hypothesis property tests for the AoI layer (core/aoi.py).

The renewal formula E[delta] = 1/p - 1/2 (paper eq. 10) is checked against
the Monte-Carlo sample-path oracle ``simulate_aoi`` across the whole
participation range, and the p -> 0 clip boundary is pinned down:
``expected_aoi`` must stay finite, positive, and antitone in p everywhere
in [0, 1] — the properties the utility's -γ·log(AoI) term and the campaign
engine's realized-AoI reporting rely on.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core  # noqa: F401
from repro.core.aoi import AoITracker, expected_aoi, log_aoi, simulate_aoi

CLIP = 1e-9  # expected_aoi's p -> 0 clip


# One jitted oracle (p traced, length static) so hypothesis examples don't
# each pay a fresh scan compile.
_sim = jax.jit(functools.partial(simulate_aoi, n_rounds=120_000))


@settings(max_examples=15, deadline=None)
@given(p=st.floats(0.08, 0.95), seed=st.integers(0, 2**31 - 1))
def test_renewal_formula_matches_monte_carlo(p, seed):
    sim = float(_sim(p, key=jax.random.PRNGKey(seed)))
    want = float(expected_aoi(jnp.asarray(p)))
    # MC error grows as p -> 0 (longer renewal cycles); 120k rounds keeps
    # the sample mean within a few percent across the strategy's range.
    assert sim == pytest.approx(want, rel=0.08)


@given(p1=st.floats(0.0, 1.0), p2=st.floats(0.0, 1.0))
def test_expected_aoi_antitone(p1, p2):
    lo, hi = sorted([p1, p2])
    a_lo = float(expected_aoi(jnp.asarray(lo)))
    a_hi = float(expected_aoi(jnp.asarray(hi)))
    assert a_lo >= a_hi  # more participation -> fresher information


@given(p=st.floats(0.0, 1.0, allow_subnormal=False))
def test_expected_aoi_finite_positive_everywhere(p):
    """The clip at p -> 0 keeps both the AoI and its log finite."""
    a = float(expected_aoi(jnp.asarray(p)))
    la = float(log_aoi(jnp.asarray(p)))
    assert np.isfinite(a) and np.isfinite(la)
    assert a >= 0.5  # attained at p = 1
    assert a <= 1.0 / CLIP  # the clip ceiling


def test_expected_aoi_clip_boundary_exact():
    """Below the clip every p collapses to the p = CLIP ceiling."""
    ceiling = float(expected_aoi(jnp.asarray(CLIP)))
    for p in (0.0, 1e-12, CLIP):
        assert float(expected_aoi(jnp.asarray(p))) == pytest.approx(ceiling)
    # just above the clip the formula is live again and strictly below
    assert float(expected_aoi(jnp.asarray(1e-6))) < ceiling


@given(seed=st.integers(0, 2**31 - 1), p=st.floats(0.2, 0.9))
@settings(max_examples=10, deadline=None)
def test_tracker_agrees_with_simulate_oracle(seed, p):
    """AoITracker (the scan-carry pytree) and simulate_aoi implement the
    same sampling convention: identical mean over identical draws."""
    rounds = 400
    key = jax.random.PRNGKey(seed)
    draws = jax.random.bernoulli(key, p, (rounds,))

    tr = AoITracker.create(1)
    for joined in np.asarray(draws):
        tr = tr.update(jnp.asarray([joined]))
    want = float(simulate_aoi(p, rounds, key))
    got = float(tr.cum_age[0] / tr.rounds)
    assert got == pytest.approx(want, rel=1e-12)
