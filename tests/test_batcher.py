"""Continuous-batching scheduler: slot reuse == sequential decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.launch.batcher import ContinuousBatcher, Request
from repro.models.registry import get_model


def _sequential_greedy(api, params, prompt, max_new, max_len):
    """Reference: one request alone through serve_step."""
    cache, _ = api.init_cache(1, max_len, False)
    tok = None
    out = []
    pos = 0
    for t in prompt:
        logits, cache = api.serve_step(params, cache,
                                       jnp.asarray([[t]], jnp.int32),
                                       jnp.asarray(pos, jnp.int32))
        pos += 1
    tok = int(jnp.argmax(logits[0, -1]))
    out.append(tok)
    while len(out) < max_new:
        logits, cache = api.serve_step(params, cache,
                                       jnp.asarray([[tok]], jnp.int32),
                                       jnp.asarray(pos, jnp.int32))
        pos += 1
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
    return out


@pytest.mark.parametrize("name", ["gemma-2b", "rwkv6-3b"])
def test_batcher_matches_sequential(name):
    cfg = ARCHITECTURES[name].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = 32
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=plen,
                                        dtype=np.int32),
                    max_new=gen)
            for i, (plen, gen) in enumerate([(3, 5), (5, 4), (2, 6),
                                             (4, 3), (3, 4)])]
    want = {r.rid: _sequential_greedy(api, params, r.prompt, r.max_new,
                                      max_len)
            for r in reqs}

    # 2 slots for 5 requests -> forced slot reuse mid-stream
    batcher = ContinuousBatcher(api, params, n_slots=2, max_len=max_len)
    for r in reqs:
        batcher.submit(Request(rid=r.rid, prompt=r.prompt,
                               max_new=r.max_new))
    finished = batcher.run()
    assert len(finished) == len(reqs)
    for r in finished:
        assert r.generated == want[r.rid], (
            name, r.rid, r.generated, want[r.rid])


def test_batcher_stats_drain():
    cfg = ARCHITECTURES["gemma-2b"].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(api, params, n_slots=3, max_len=16)
    for i in range(4):
        b.submit(Request(rid=i, prompt=np.asarray([1, 2, 3], np.int32),
                         max_new=2))
    b.run()
    st = b.stats()
    assert st["finished"] == 4 and st["queued"] == 0 and st["active"] == 0


def test_batcher_completion_order_not_submit_order():
    """Continuous batching finishes short requests first: result order is
    completion order, not enqueue order (the queue contract the sweep
    service mirrors, see tests/test_serve.py)."""
    cfg = ARCHITECTURES["gemma-2b"].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(api, params, n_slots=2, max_len=32)
    prompt = np.asarray([1, 2, 3], np.int32)
    b.submit(Request(rid=0, prompt=prompt, max_new=10))
    b.submit(Request(rid=1, prompt=prompt, max_new=2))
    finished = b.run()
    assert [r.rid for r in finished] == [1, 0]
    assert len(finished[0].generated) == 2
    assert len(finished[1].generated) == 10


def test_batcher_drains_queue_deeper_than_slots():
    """6 requests through 2 slots: every one finishes, queue ends empty,
    and freed slots are reused mid-stream (queued rids start only after
    an earlier rid completes)."""
    cfg = ARCHITECTURES["gemma-2b"].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(api, params, n_slots=2, max_len=16)
    prompt = np.asarray([1, 2], np.int32)
    for i in range(6):
        b.submit(Request(rid=i, prompt=prompt, max_new=2))
    finished = b.run()
    assert sorted(r.rid for r in finished) == list(range(6))
    assert all(len(r.generated) == 2 for r in finished)
    st = b.stats()
    assert st["queued"] == 0 and st["active"] == 0 and st["finished"] == 6
    # identical requests drain in FIFO order through slot reuse
    assert [r.rid for r in finished] == list(range(6))
