"""Online duration learning + adaptive controller loop (core/online.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.online import OnlineDurationEstimator
from repro.core.game import solve_symmetric_ne
from repro.core.utility import UtilityParams


def _true_rate(k, a=0.005, b=0.06, s=6.0):
    return a + b * k / (k + s)


def test_estimator_recovers_rate_curve():
    est = OnlineDurationEstimator(n_nodes=20, saturation=6.0)
    rng = np.random.default_rng(0)
    for _ in range(400):
        k = int(rng.integers(1, 21))
        prog = _true_rate(k) * float(rng.lognormal(0.0, 0.2))
        est.observe(k, prog)
    ks = np.array([2, 5, 10, 20])
    got = est.progress_rate(ks)
    want = _true_rate(ks)
    assert np.all(np.abs(got - want) / want < 0.25), (got, want)


def test_duration_model_monotone_and_capped():
    est = OnlineDurationEstimator(n_nodes=20, saturation=6.0)
    for k in range(1, 21):
        for _ in range(10):
            est.observe(k, _true_rate(k))
    dm = est.duration_model()
    tab = np.asarray(dm.table())
    assert tab[0] == est.horizon           # no participants -> never
    assert tab[1] > tab[20]                # more participants -> fewer rounds
    assert np.all(tab >= 1.0)


def test_adaptive_ne_tracks_task_difficulty():
    """A harder task (lower progress rates) pushes the NE participation up —
    the controller re-solves and asks for more help."""
    ps = {}
    for name, scale in (("easy", 2.0), ("hard", 0.5)):
        est = OnlineDurationEstimator(n_nodes=20, saturation=6.0)
        for k in range(1, 21):
            for _ in range(20):
                est.observe(k, _true_rate(k) * scale)
        dm = est.duration_model()
        nes = solve_symmetric_ne(
            UtilityParams(gamma=0.6, cost=4.0, n_nodes=20), dm,
            grid_size=300)
        ps[name] = max(nes) if nes else 0.0
    assert ps["hard"] >= ps["easy"], ps


def test_estimator_feeds_controller():
    est = OnlineDurationEstimator(n_nodes=50)
    for k in range(1, 51, 2):
        est.observe(k, _true_rate(k, s=10.0))
    dm = est.duration_model()
    ctrl = C.ParticipationController(n_nodes=50, gamma=0.6, cost=1.0,
                                     duration_model=dm)
    p = ctrl.participation_probability()
    assert 0.0 < p <= 1.0
    assert np.isfinite(ctrl.diagnostics()["poa"])

def test_observe_batch_equals_sequential():
    """RLS normal equations are additive: one observe_batch == N observes."""
    seq = OnlineDurationEstimator(n_nodes=20, saturation=6.0)
    bat = OnlineDurationEstimator(n_nodes=20, saturation=6.0)
    rng = np.random.default_rng(7)
    ks = rng.integers(1, 21, size=60)
    gs = _true_rate(ks) * rng.lognormal(0.0, 0.2, size=60)
    for k, g in zip(ks, gs):
        seq.observe(int(k), float(g))
    bat.observe_batch(ks, gs)
    assert bat.n_obs == seq.n_obs == 60
    np.testing.assert_allclose(bat.progress_rate(np.array([2, 8, 15])),
                               seq.progress_rate(np.array([2, 8, 15])),
                               rtol=1e-12)


def test_ingest_trajectory_learns_from_campaign_histories():
    """Feeding (k, acc) round histories moves the duration model the right
    way: campaigns where more participants yield faster accuracy gains
    produce a decreasing d(k)."""
    est = OnlineDurationEstimator(n_nodes=20, saturation=6.0)
    rng = np.random.default_rng(1)
    for _ in range(30):
        ks = rng.integers(1, 21, size=25)
        gains = _true_rate(ks) * 0.43  # acc gain per round, target gap 0.43
        accs = 0.3 + np.concatenate([[0.0], np.cumsum(gains[1:])])
        est.ingest_trajectory(ks, accs, target_acc=0.73)
    dm = est.duration_model()
    tab = np.asarray(dm.table())
    assert tab[2] > tab[20]  # more participants -> fewer rounds
