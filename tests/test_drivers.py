"""Launch-driver smoke tests: train.py and serve.py run end to end on
reduced configs in a subprocess (clean jax device state)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
ENV.pop("XLA_FLAGS", None)


def _run(args, timeout=420):
    return subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                          text=True, env=ENV, timeout=timeout, cwd=REPO)


@pytest.mark.slow
def test_train_driver_gemma_reduced(tmp_path):
    out = _run(["repro.launch.train", "--arch", "gemma-2b", "--reduced",
                "--steps", "4", "--batch", "2", "--seq", "32",
                "--n-clients", "2", "--ckpt-dir", str(tmp_path)])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "participation p=" in out.stdout
    assert "loss" in out.stdout
    assert any(f.startswith("ckpt_") for f in os.listdir(tmp_path))


@pytest.mark.slow
def test_serve_driver_rwkv_reduced():
    out = _run(["repro.launch.serve", "--arch", "rwkv6-3b", "--reduced",
                "--batch", "2", "--prompt-len", "4", "--gen", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "generated 8 toks" in out.stdout


@pytest.mark.slow
def test_dryrun_driver_single_combo(tmp_path):
    out = _run(["repro.launch.dryrun", "--arch", "whisper-tiny",
                "--shape", "decode_32k", "--out", str(tmp_path)],
               timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[OK ]" in out.stdout
    files = os.listdir(tmp_path)
    assert len(files) == 1 and files[0].endswith(".json")


@pytest.mark.slow
def test_serve_sweeps_driver_demo(tmp_path):
    """The sweep-service driver serves a synthetic mixed demo workload:
    JSONL responses out, cache/latency summary on stderr, events on disk."""
    import json

    resp_path = tmp_path / "responses.jsonl"
    events_path = tmp_path / "events.jsonl"
    out = _run(["repro.launch.serve_sweeps", "--demo", "6",
                "--max-batch", "4", "--output", str(resp_path),
                "--events", str(events_path)])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "served 6 responses" in out.stderr
    assert "cache:" in out.stderr
    lines = resp_path.read_text().splitlines()
    assert len(lines) == 6
    resps = [json.loads(line) for line in lines]
    assert all(r["schema"] == "repro.serve/v1" for r in resps)
    assert all(r["ok"] for r in resps)  # seed-0 demo mix is all well-formed
    assert {r["kind"] for r in resps} == {"ne_solve", "calibrate"}
    events = [json.loads(line)
              for line in events_path.read_text().splitlines()]
    assert sum(e["event"] == "serve.request" for e in events) == 6
    assert sum(e["event"] == "serve.complete" for e in events) == 6
