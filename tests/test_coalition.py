"""Coalition-formation engine: deterministic pins.

Grand-coalition bitwise reduction, partition invariants (disjoint cover,
caps, certification), planner/PoA consistency, the controller's
``mode="coalition"`` dispatch, and the mechanism-layer report. Random-game
properties (engine == Python oracle, monotonicities) live in
``tests/test_property_coalition.py``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.asymmetric_batched import (social_cost_batched,
                                           solve_heterogeneous)
from repro.core.coalition import (partition_planner_batched,
                                  partition_poa_report,
                                  partition_social_cost_batched,
                                  solve_partition, verify_partition_batched)
from repro.core.controller import ParticipationController
from repro.mechanisms import coalition_report

N = 6
B = 3


@pytest.fixture(scope="module")
def dur():
    return C.theoretical_duration(n_nodes=N, d_inf=35.0, slope=8.0)


@pytest.fixture(scope="module")
def games():
    rng = np.random.default_rng(7)
    costs = jnp.asarray(rng.uniform(0.5, 8.0, (B, N)))
    gammas = jnp.asarray(rng.uniform(0.2, 1.2, (B, N)))
    return costs, gammas


def test_grand_coalition_reduces_bitwise(dur, games):
    """M = 1 partition solves == the unmasked heterogeneous engine bitwise:
    the p = 0 mask pin is a convolution identity, so every Gauss-Seidel
    intermediate is instruction- and value-identical."""
    costs, gammas = games
    sol = solve_partition(costs, gammas, dur, n_coalitions=1)
    het = solve_heterogeneous(costs, gammas, dur)
    np.testing.assert_array_equal(np.asarray(sol.p), np.asarray(het.p))
    np.testing.assert_array_equal(np.asarray(sol.assign), 0)
    np.testing.assert_array_equal(np.asarray(sol.switches), 0)
    assert bool(jnp.all(sol.converged))


def test_partition_invariants(dur, games):
    """Partitions are a disjoint cover, probabilities live in [P_MIN, 1],
    caps hold, and converged scenarios certify ≤ the tolerance budget."""
    costs, gammas = games
    cap = 4
    sol = solve_partition(costs, gammas, dur, n_coalitions=2, cap=cap,
                          tol=1e-10)
    assert sol.assign.shape == (B, N)
    a = np.asarray(sol.assign)
    assert np.all((a >= 0) & (a < 2))
    sizes = np.asarray(sol.sizes)
    np.testing.assert_array_equal(sizes.sum(axis=1), N)
    assert np.all(sizes <= cap)
    p = np.asarray(sol.p)
    assert np.all((p > 0.0) & (p <= 1.0))  # every node plays in its group
    assert bool(jnp.all(sol.converged)) and bool(jnp.all(sol.inner_converged))
    assert float(jnp.max(sol.max_gain)) <= 1e-6

    dev = verify_partition_batched(costs, gammas, dur, sol.assign, sol.p,
                                   n_coalitions=2, cap=cap, tol=1e-10)
    assert float(jnp.max(dev)) <= 1e-6


def test_grand_coalition_social_cost_matches_asymmetric(dur, games):
    """With one coalition the partition social cost is the asymmetric
    layer's N·E[D] + Σ c_i p_i."""
    costs, gammas = games
    sol = solve_partition(costs, gammas, dur, n_coalitions=1)
    got = partition_social_cost_batched(costs, dur, sol.assign, sol.p,
                                        n_coalitions=1)
    want = social_cost_batched(costs, dur, sol.p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_planner_descends_and_poa_at_least_one(dur, games):
    costs, gammas = games
    rep = partition_poa_report(costs, gammas, dur, n_coalitions=2, tol=1e-10)
    opt_direct = partition_planner_batched(
        costs, dur, rep.solution.assign, rep.solution.p, n_coalitions=2)
    np.testing.assert_array_equal(np.asarray(rep.opt_p),
                                  np.asarray(opt_direct))
    assert bool(jnp.all(rep.opt_cost <= rep.ne_cost + 1e-9))
    assert bool(jnp.all(rep.poa >= 1.0 - 1e-12))
    assert float(jnp.max(rep.deviation)) <= 1e-6


def test_cap_binds_switch_dynamics(dur, games):
    """cap = 1 with a singleton start freezes the partition: every other
    coalition is full, so no switch is eligible — 0 switches, stable."""
    costs, gammas = games
    sol = solve_partition(costs, gammas, dur, n_coalitions=N, cap=1,
                          assign0=jnp.arange(N, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(sol.assign),
                                  np.broadcast_to(np.arange(N), (B, N)))
    np.testing.assert_array_equal(np.asarray(sol.switches), 0)
    assert bool(jnp.all(sol.converged))


def test_controller_coalition_mode(dur, games):
    costs, gammas = games
    ctrl = ParticipationController(n_nodes=N, mode="coalition",
                                   n_coalitions=2, duration_model=dur)
    p = ctrl.solve_batched(gammas, costs)
    sol = solve_partition(costs, gammas, dur, n_coalitions=2)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(sol.p))
    # scalar configs are spread across the fleet
    p_scalar = ctrl.solve_batched(gammas=0.5, costs=3.0)
    assert p_scalar.shape == (1, N)
    with pytest.raises(ValueError, match="per-node partition"):
        ctrl.participation_probability()
    assert ctrl.diagnostics()["p"] is None
    with pytest.raises(ValueError, match="n_coalitions"):
        ParticipationController(n_nodes=N, n_coalitions=0,
                                duration_model=dur)


def test_coalition_report_benchmarks_grand_coalition(dur, games):
    costs, gammas = games
    rep = coalition_report(costs, gammas, dur, n_coalitions=2, tol=1e-10)
    assert bool(jnp.all(rep.certified))
    grand_cost = social_cost_batched(costs, dur, rep.grand_p)
    np.testing.assert_allclose(np.asarray(rep.grand_cost),
                               np.asarray(grand_cost), rtol=1e-12)
    np.testing.assert_allclose(
        np.asarray(rep.formation_gain),
        np.asarray(rep.grand_cost - rep.partition.ne_cost), rtol=1e-12)
    s = rep.summary(0)
    assert s["certified"] and s["poa"] >= 1.0
    assert sum(s["sizes"]) == N
