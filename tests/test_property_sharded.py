"""Hypothesis property: sharded NE solves are bitwise-equal to unsharded.

Random (B, N, device_count) triples through
:func:`repro.core.asymmetric_batched.solve_heterogeneous` — the mesh path
pads to shard-divisibility, and per-scenario programs are independent, so
every profile/flag/iteration count must match the single-device engine
exactly. Device counts above 1 are only drawn when the process actually
has the devices (the multi-device CI job runs with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die, without it
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core  # noqa: F401  (enables x64)
from jax.sharding import Mesh
from repro.core.asymmetric_batched import solve_heterogeneous
from repro.core.duration import paper_duration_model

DEVICES = jax.device_count()


@settings(max_examples=20)
@given(b=st.integers(1, 8), n=st.integers(2, 5),
       k=st.sampled_from([k for k in (1, 2, 4, 8) if k <= DEVICES]),
       seed=st.integers(0, 2 ** 16))
def test_property_sharded_solve_bitwise(b, n, k, seed):
    """Any (B, N) batch on any available device count solves bitwise-equal
    to the single-device engine, divisible or not."""
    rng = np.random.default_rng(seed)
    costs = jnp.asarray(rng.uniform(0.3, 3.0, (b, n)))
    gammas = jnp.asarray(rng.uniform(0.0, 2.0, (b, n)))
    dur = dataclasses.replace(paper_duration_model(), n_nodes=n)
    mesh = Mesh(np.array(jax.devices()[:k]), ("data",))
    ref = solve_heterogeneous(costs, gammas, dur)
    sh = solve_heterogeneous(costs, gammas, dur, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref.p), np.asarray(sh.p))
    np.testing.assert_array_equal(np.asarray(ref.converged),
                                  np.asarray(sh.converged))
    np.testing.assert_array_equal(np.asarray(ref.iters), np.asarray(sh.iters))
