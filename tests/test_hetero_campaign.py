"""Heterogeneous-fleet campaign engine: oracle equivalence, symmetric
reduction, churn accounting invariants, channel-rate / deadline reductions,
and the controller's heterogeneous batched front end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401  (enables x64)
from repro.core.asymmetric_batched import (social_cost_batched,
                                           verify_equilibrium_batched)
from repro.core.controller import ParticipationController
from repro.core.duration import theoretical_duration
from repro.core.energy import (EnergyParams, channel_energy_rates,
                               per_node_energy_rates)
from repro.federated.campaign import (ChurnConfig, DeadlineConfig,
                                      run_campaigns)
from repro.federated.simulation import (FLConfig,
                                        run_heterogeneous_reference)
from repro.federated.tasks import synthetic_mlp_task
from repro.optim import sgd

N = 6


@pytest.fixture(scope="module")
def task():
    return synthetic_mlp_task(noise=2.5)


def _fl(**kw):
    base = dict(n_clients=N, local_steps=1, batch_per_client=8,
                max_rounds=12, target_acc=0.73, seed=5)
    base.update(kw)
    return FLConfig(**base)


def _per_node_setup():
    p_vec = jnp.asarray(np.linspace(0.2, 0.9, N), jnp.float32)
    tiers = [EnergyParams(p_hw_w=150.0) if i < N // 2 else EnergyParams()
             for i in range(N)]
    e_part, e_idle = per_node_energy_rates(tiers)
    return p_vec, e_part, e_idle


def test_hetero_engine_matches_reference(task):
    """Scan-fused heterogeneous campaign == per-node Python oracle on shared
    RNG streams: equal convergence rounds, *bitwise* per-node ledgers and
    AoI trackers, identical presence accounting — with per-node p, per-node
    energy rates, and churn all active."""
    fl = _fl()
    p_vec, e_part, e_idle = _per_node_setup()
    churn = ChurnConfig(arrival=0.3, departure=0.25)
    opt = sgd(0.1)

    res = run_campaigns(fl, *task.campaign_args(), opt, p_vec[None, :],
                        energy_rates_j=(e_part[None, :], e_idle[None, :]),
                        churn=churn)
    ref = run_heterogeneous_reference(fl, *task.campaign_args(), opt, p_vec,
                                      energy_rates_j=(e_part, e_idle),
                                      churn=churn)
    assert int(res.rounds[0]) == ref.rounds
    assert bool(res.converged[0]) == ref.converged
    np.testing.assert_array_equal(np.asarray(res.ledger.per_node_j[0]),
                                  np.asarray(ref.ledger.per_node_j))
    np.testing.assert_array_equal(
        np.asarray(res.ledger.participation_counts[0]),
        np.asarray(ref.ledger.participation_counts))
    np.testing.assert_array_equal(np.asarray(res.aoi.cum_age[0]),
                                  np.asarray(ref.aoi.cum_age))
    np.testing.assert_array_equal(np.asarray(res.aoi.tracked[0]),
                                  np.asarray(ref.aoi.tracked))
    np.testing.assert_array_equal(np.asarray(res.present_counts[0]),
                                  np.asarray(ref.present_counts))
    np.testing.assert_array_equal(np.asarray(res.present_final[0]),
                                  np.asarray(ref.present_final))
    np.testing.assert_allclose(np.asarray(res.acc_history[0][:ref.rounds]),
                               np.asarray(ref.acc_history),
                               rtol=1e-9, atol=1e-12)


def test_symmetric_reduction_bitwise(task):
    """A (B, N) campaign with constant rows, scalar-equivalent per-node
    rates, and zero churn reproduces the symmetric (PR 3) engine bitwise."""
    fl = _fl(seed=0, max_rounds=10)
    opt = sgd(0.1)
    ps = jnp.asarray([0.3, 0.7], jnp.float32)
    base = run_campaigns(fl, *task.campaign_args(), opt, ps)

    # constant-row (B, N) matrix
    rows = run_campaigns(fl, *task.campaign_args(), opt,
                         jnp.broadcast_to(ps[:, None], (2, N)))
    # per-node rate vectors that all equal the default EnergyParams
    ep = EnergyParams()
    rates = (jnp.full((1, N), ep.e_participant_j),
             jnp.full((1, N), ep.e_idle_j))
    rated = run_campaigns(fl, *task.campaign_args(), opt,
                          jnp.broadcast_to(ps[:, None], (2, N)),
                          energy_rates_j=rates)
    # zero-churn ChurnConfig (presence logic active but inert)
    churned = run_campaigns(fl, *task.campaign_args(), opt,
                            jnp.broadcast_to(ps[:, None], (2, N)),
                            churn=ChurnConfig())

    for other in (rows, rated, churned):
        np.testing.assert_array_equal(np.asarray(base.ledger.per_node_j),
                                      np.asarray(other.ledger.per_node_j))
        np.testing.assert_array_equal(
            np.asarray(base.ledger.participation_counts),
            np.asarray(other.ledger.participation_counts))
        np.testing.assert_array_equal(np.asarray(base.acc_history),
                                      np.asarray(other.acc_history))
        np.testing.assert_array_equal(np.asarray(base.aoi.cum_age),
                                      np.asarray(other.aoi.cum_age))
        np.testing.assert_array_equal(np.asarray(base.rounds),
                                      np.asarray(other.rounds))
    # inert churn still reports full presence
    np.testing.assert_array_equal(
        np.asarray(churned.present_counts),
        np.asarray(np.broadcast_to(np.asarray(base.rounds)[:, None],
                                   (2, N))))
    assert bool(jnp.all(churned.present_final))


def test_churn_accounting_invariants(task):
    """Departed nodes accrue idle-only energy, never participate, and their
    AoI is frozen; presence counts stay within realized rounds."""
    fl = _fl(seed=2, max_rounds=15, target_acc=1.01)  # never converges
    opt = sgd(0.1)
    p_vec = jnp.full((N,), 0.8, jnp.float32)
    ep = EnergyParams()
    # nodes 0-1 depart at round 0 and never return; the rest are stable
    departure = jnp.asarray([1.0, 1.0] + [0.0] * (N - 2))
    churn = ChurnConfig(arrival=0.0, departure=departure[None, :])
    res = run_campaigns(fl, *task.campaign_args(), opt, p_vec[None, :],
                        churn=churn)
    rounds = int(res.rounds[0])
    assert rounds == fl.max_rounds

    per_node_j = np.asarray(res.ledger.per_node_j[0])
    counts = np.asarray(res.ledger.participation_counts[0])
    # departed: idle-only energy, zero participation, frozen AoI
    np.testing.assert_allclose(per_node_j[:2], rounds * ep.e_idle_j)
    assert np.all(counts[:2] == 0)
    np.testing.assert_array_equal(np.asarray(res.aoi.tracked[0])[:2], 0)
    np.testing.assert_array_equal(np.asarray(res.aoi.cum_age[0])[:2], 0.0)
    np.testing.assert_array_equal(np.asarray(res.per_node_aoi[0])[:2], 0.0)
    np.testing.assert_array_equal(np.asarray(res.present_counts[0])[:2], 0)
    assert not bool(jnp.any(res.present_final[0][:2]))
    # survivors: counted every round, energy strictly above the idle floor
    np.testing.assert_array_equal(np.asarray(res.present_counts[0])[2:],
                                  rounds)
    assert np.all(per_node_j[2:] > rounds * ep.e_idle_j)
    assert np.all(counts[2:] > 0)
    # fleet energy decomposes exactly into participant/idle rates
    want = (counts * ep.e_participant_j
            + (rounds - counts) * ep.e_idle_j)
    np.testing.assert_allclose(per_node_j, want)


def test_uniform_mcs_channel_rates_reduce_bitwise(task):
    """A campaign metered at channel-derived per-node rates with a
    *uniform* MCS map equals the constant-rate campaign bitwise — the
    channel-energy seam is a pure generalization."""
    fl = _fl(seed=0, max_rounds=10)
    opt = sgd(0.1)
    ps = jnp.asarray([0.3, 0.7], jnp.float32)
    ep = EnergyParams()
    base = run_campaigns(fl, *task.campaign_args(), opt, ps, energy=ep)

    e_part, e_idle = channel_energy_rates(
        jnp.full((N,), ep.comm.bits_per_symbol_per_sc), ep)
    rated = run_campaigns(fl, *task.campaign_args(), opt, ps,
                          energy_rates_j=(e_part[None, :], e_idle[None, :]))
    np.testing.assert_array_equal(np.asarray(base.ledger.per_node_j),
                                  np.asarray(rated.ledger.per_node_j))
    np.testing.assert_array_equal(np.asarray(base.acc_history),
                                  np.asarray(rated.acc_history))
    np.testing.assert_array_equal(np.asarray(base.rounds),
                                  np.asarray(rated.rounds))

    # a genuinely heterogeneous channel map changes only the metering:
    # masks/accuracies are untouched, energy shifts toward the weak links
    e2_part, e2_idle = channel_energy_rates(
        jnp.asarray(np.linspace(1.0, 10.0, N)), ep)
    skewed = run_campaigns(fl, *task.campaign_args(), opt, ps,
                           energy_rates_j=(e2_part[None, :],
                                           e2_idle[None, :]))
    np.testing.assert_array_equal(np.asarray(base.acc_history),
                                  np.asarray(skewed.acc_history))
    np.testing.assert_array_equal(
        np.asarray(base.ledger.participation_counts),
        np.asarray(skewed.ledger.participation_counts))
    assert float(jnp.sum(skewed.ledger.per_node_j)) > 0.0


def test_deadline_miss_zero_reduces_bitwise(task):
    """miss = 0 deadline config == the deadline-free engine bitwise
    (masks, ledger, AoI, accuracies), with all-zero straggler counts."""
    fl = _fl(seed=0, max_rounds=10)
    opt = sgd(0.1)
    ps = jnp.asarray([0.3, 0.7], jnp.float32)
    base = run_campaigns(fl, *task.campaign_args(), opt, ps)
    dead = run_campaigns(fl, *task.campaign_args(), opt, ps,
                         deadline=DeadlineConfig(miss=0.0))
    np.testing.assert_array_equal(np.asarray(base.ledger.per_node_j),
                                  np.asarray(dead.ledger.per_node_j))
    np.testing.assert_array_equal(
        np.asarray(base.ledger.participation_counts),
        np.asarray(dead.ledger.participation_counts))
    np.testing.assert_array_equal(np.asarray(base.acc_history),
                                  np.asarray(dead.acc_history))
    np.testing.assert_array_equal(np.asarray(base.aoi.cum_age),
                                  np.asarray(dead.aoi.cum_age))
    np.testing.assert_array_equal(np.asarray(base.rounds),
                                  np.asarray(dead.rounds))
    np.testing.assert_array_equal(np.asarray(dead.straggler_counts), 0)
    # and the deadline-free result reports zero stragglers by construction
    np.testing.assert_array_equal(np.asarray(base.straggler_counts), 0)


def test_deadline_engine_matches_reference(task):
    """Straggler model engine == Python oracle on shared RNG streams:
    bitwise ledgers (attempts charged), AoI (delivered-only resets),
    straggler counts, with churn active simultaneously."""
    fl = _fl(max_rounds=10, target_acc=1.01)  # never converges
    opt = sgd(0.1)
    p_vec, e_part, e_idle = _per_node_setup()
    churn = ChurnConfig(arrival=0.3, departure=0.25)
    dead = DeadlineConfig(miss=jnp.asarray(np.linspace(0.0, 0.6, N)))

    res = run_campaigns(fl, *task.campaign_args(), opt, p_vec[None, :],
                        energy_rates_j=(e_part[None, :], e_idle[None, :]),
                        churn=churn, deadline=dead)
    ref = run_heterogeneous_reference(fl, *task.campaign_args(), opt, p_vec,
                                      energy_rates_j=(e_part, e_idle),
                                      churn=churn, deadline=dead)
    assert int(res.rounds[0]) == ref.rounds
    np.testing.assert_array_equal(np.asarray(res.ledger.per_node_j[0]),
                                  np.asarray(ref.ledger.per_node_j))
    np.testing.assert_array_equal(
        np.asarray(res.ledger.participation_counts[0]),
        np.asarray(ref.ledger.participation_counts))
    np.testing.assert_array_equal(np.asarray(res.aoi.cum_age[0]),
                                  np.asarray(ref.aoi.cum_age))
    np.testing.assert_array_equal(np.asarray(res.aoi.tracked[0]),
                                  np.asarray(ref.aoi.tracked))
    np.testing.assert_array_equal(np.asarray(res.straggler_counts[0]),
                                  np.asarray(ref.straggler_counts))
    np.testing.assert_allclose(np.asarray(res.acc_history[0][:ref.rounds]),
                               np.asarray(ref.acc_history),
                               rtol=1e-9, atol=1e-12)
    # node 0 has miss=0: it can never straggle; ledger participation counts
    # include straggler attempts (they trained and transmitted)
    assert int(res.straggler_counts[0][0]) == 0
    counts = np.asarray(res.ledger.participation_counts[0])
    stragglers = np.asarray(res.straggler_counts[0])
    assert np.all(stragglers <= counts)


def test_run_campaigns_rate_validation(task):
    fl = _fl()
    with pytest.raises(ValueError, match="per-scenario"):
        run_campaigns(fl, *task.campaign_args(), sgd(0.1),
                      jnp.asarray([0.5], jnp.float32),
                      energy_rates_j=(jnp.ones((N,)), 1.0))
    # B == N: a 1-D rate vector is ambiguous (per-scenario vs per-node)
    with pytest.raises(ValueError, match="ambiguous"):
        run_campaigns(fl, *task.campaign_args(), sgd(0.1),
                      jnp.full((N,), 0.5, jnp.float32),
                      energy_rates_j=(jnp.ones((N,)), jnp.ones((N,))))
    with pytest.raises(ValueError, match="n_clients"):
        run_campaigns(fl, *task.campaign_args(), sgd(0.1),
                      jnp.ones((1, N + 1), jnp.float32))


def test_pad_shards_rejects_empty():
    from repro.data.partition import pad_shards
    with pytest.raises(ValueError, match="empty"):
        pad_shards([np.arange(4), np.arange(0)])
    assert pad_shards([np.arange(4), np.arange(2)]).shape == (2, 4)


# ---- controller heterogeneous front end ------------------------------------

N_GAME = 8


@pytest.fixture(scope="module")
def hetero_ctrl():
    return ParticipationController(
        n_nodes=N_GAME, gamma=0.2, cost=6.0,
        duration_model=theoretical_duration(N_GAME))


def test_controller_heterogeneous_ne_certified(hetero_ctrl):
    """2-D (costs, gammas) dispatch returns certified (B, N) asymmetric
    NEs, and the worst NE never undercuts the best one's social cost."""
    rng = np.random.default_rng(0)
    costs = jnp.asarray(rng.uniform(1.0, 8.0, (3, N_GAME)))
    gammas = jnp.full((3, N_GAME), 0.2)
    kw = dict(damping=0.6, max_iters=300)
    dur = hetero_ctrl.duration_model

    p_ne = hetero_ctrl.solve_batched(gammas, costs, mode="ne", **kw)
    assert p_ne.shape == (3, N_GAME)
    dev = verify_equilibrium_batched(costs, gammas, dur, p_ne)
    assert float(jnp.max(dev)) <= 1e-3

    p_worst = hetero_ctrl.solve_batched(gammas, costs, mode="ne_worst", **kw)
    c_ne = social_cost_batched(costs, dur, p_ne)
    c_worst = social_cost_batched(costs, dur, p_worst)
    assert bool(jnp.all(c_ne <= c_worst + 1e-9))

    p_plan = hetero_ctrl.solve_batched(gammas, costs, mode="centralized",
                                       **kw)
    c_plan = social_cost_batched(costs, dur, p_plan)
    assert bool(jnp.all(c_plan <= c_ne + 1e-9))

    p_fix = hetero_ctrl.solve_batched(gammas, costs, mode="fixed")
    np.testing.assert_allclose(np.asarray(p_fix), hetero_ctrl.fixed_p)


def test_controller_heterogeneous_mechanism_improves(hetero_ctrl):
    """The uniform-γ* mechanism's induced NE costs no more (socially) than
    the selfish NE on a stratifying identical fleet."""
    costs = jnp.full((1, N_GAME), 6.0)
    gammas = jnp.full((1, N_GAME), 0.2)
    kw = dict(damping=0.6, max_iters=300)
    dur = hetero_ctrl.duration_model
    p_ne = hetero_ctrl.solve_batched(gammas, costs, mode="ne", **kw)
    p_mech = hetero_ctrl.solve_batched(gammas, costs, mode="mechanism",
                                       coarse=8, **kw)
    assert p_mech.shape == (1, N_GAME)
    # the dispatch forwards coarse (regression: it used to drop it)
    direct = hetero_ctrl.solve_batched_heterogeneous(
        gammas, costs, "mechanism", coarse=8, **kw)
    np.testing.assert_array_equal(np.asarray(p_mech), np.asarray(direct))
    # the AoI reward lifts fleet-wide participation
    assert float(jnp.mean(p_mech)) > float(jnp.mean(p_ne))
    c_ne = float(social_cost_batched(costs, dur, p_ne)[0])
    c_mech = float(social_cost_batched(costs, dur, p_mech)[0])
    plan = hetero_ctrl.solve_batched(gammas, costs, mode="centralized", **kw)
    c_plan = float(social_cost_batched(costs, dur, plan)[0])
    # induced PoA within the controller's target of the planner
    assert c_mech / c_plan <= hetero_ctrl.target_poa + 0.05


def test_controller_heterogeneous_rejects_bad_shapes(hetero_ctrl):
    with pytest.raises(ValueError, match="n_nodes"):
        hetero_ctrl.solve_batched(jnp.zeros((2, N_GAME + 1)), 1.0)
    with pytest.raises(TypeError, match="solver_kwargs"):
        hetero_ctrl.solve_batched(0.0, jnp.asarray([1.0, 2.0]),
                                  mode="ne", damping=0.5)
