"""Task-factory seam tests: regression pin, oracle parity, kernel backends.

Three layers of pinning (ISSUE 8):

1. ``test_synthetic_mlp_unchanged`` — the hand-rolled MLP task is rebuilt
   here verbatim from its pre-factory definition and must drive the engine
   to **bitwise** identical campaigns through the :class:`FLTask` seam.
2. Engine-vs-reference: each model family (transformer, resnet, rwkv,
   hybrid/ssm) wrapped by :func:`model_task` must match the kept-verbatim
   Python reference loop at B=1.
3. Kernel backends: ``backend="pallas"`` (interpret mode on CPU) must match
   ``backend="ref"`` to 2e-6 after a full local-training round, and through
   an end-to-end non-iid churn campaign.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.data.partition import dirichlet_partition, sharded_client_arrays
from repro.data.synthetic import SyntheticCifar
from repro.federated.campaign import ChurnConfig, run_campaigns
from repro.federated.client import local_train
from repro.federated.simulation import (FLConfig, run_simulation,
                                        run_simulation_reference)
from repro.federated.tasks import FLTask, model_task, synthetic_mlp_task
from repro.optim.sgd import sgd

FL = FLConfig(n_clients=3, local_steps=2, batch_per_client=2, max_rounds=2,
              seed=0)
OPT = sgd(lr=0.05)


def _tiny(name: str, **over) -> "ModelConfig":
    cfg = ARCHITECTURES[name].reduced()
    if cfg.ssm is not None and "d_model" in over:
        over.setdefault("ssm", dataclasses.replace(cfg.ssm, head_dim=16))
    return dataclasses.replace(cfg, **over)


def _transformer_cfg():
    return _tiny("stablelm-3b", n_layers=1, d_model=32, n_heads=2,
                 n_kv_heads=2, head_dim=16, d_ff=64, vocab=64)


def _rwkv_cfg():
    return _tiny("rwkv6-3b", n_layers=1, d_model=32, vocab=64)


def _hybrid_cfg():
    return _tiny("hymba-1.5b", n_layers=2, d_model=32, n_heads=2,
                 n_kv_heads=1, head_dim=16, d_ff=64, vocab=64)


MODEL_CFGS = {
    "transformer": _transformer_cfg,
    "rwkv": _rwkv_cfg,
    "hybrid": _hybrid_cfg,
    "resnet": lambda: ARCHITECTURES["resnet18-cifar"].reduced(),
}
# families whose training path routes through repro.kernels.ops under a
# kernel scope (resnet is plain jnp: no kernel sites)
KERNEL_BACKED = ["transformer", "rwkv", "hybrid"]


def _legacy_mlp_task() -> FLTask:
    """The pre-factory synthetic MLP task, kept verbatim as the pin."""
    image_shape, hidden, noise, val_size, data_seed = (8, 8, 3), 16, 3.0, 128, 0
    data = SyntheticCifar(noise=noise, image_shape=image_shape)
    d = int(np.prod(image_shape))

    def init_params(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (d, hidden)) * d ** -0.5,
                "b1": jnp.zeros(hidden),
                "w2": jax.random.normal(k2, (hidden, 10)) * hidden ** -0.5,
                "b2": jnp.zeros(10)}

    def fwd(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss_fn(p, b):
        lp = jax.nn.log_softmax(fwd(p, b["images"]))
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1))

    def eval_fn(p, b):
        return jnp.mean(jnp.argmax(fwd(p, b["images"]), -1) == b["labels"])

    def client_data(cid, rnd, n, steps):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(data_seed), cid), rnd)
        return jax.vmap(lambda k: data.batch(k, n))(
            jax.random.split(key, steps))

    return FLTask(data=data, init_params=init_params, loss_fn=loss_fn,
                  eval_fn=eval_fn, client_data=client_data,
                  val_batch=data.val_set(val_size))


def test_synthetic_mlp_unchanged():
    """MLP campaigns are bitwise-stable through the task-factory seam."""
    fl = FLConfig(n_clients=4, local_steps=2, batch_per_client=4,
                  max_rounds=3, seed=0)
    ps = np.array([0.5, 0.9])
    new = run_campaigns(fl, *synthetic_mlp_task().campaign_args(), OPT, ps)
    old = run_campaigns(fl, *_legacy_mlp_task().campaign_args(), OPT, ps)
    np.testing.assert_array_equal(np.asarray(new.acc_history),
                                  np.asarray(old.acc_history))
    np.testing.assert_array_equal(np.asarray(new.energy_wh),
                                  np.asarray(old.energy_wh))
    np.testing.assert_array_equal(np.asarray(new.k_history),
                                  np.asarray(old.k_history))


@pytest.mark.parametrize("family", sorted(MODEL_CFGS))
def test_engine_matches_reference_oracle(family):
    """B=1 scan engine == kept-verbatim Python loop for every model family."""
    task = model_task(MODEL_CFGS[family](), 8, val_size=8)
    eng = run_simulation(FL, *task.campaign_args(), OPT, p=0.8)
    ref = run_simulation_reference(FL, *task.campaign_args(), OPT, p=0.8)
    np.testing.assert_array_equal(np.asarray(eng.acc_history).ravel(),
                                  np.asarray(ref.acc_history).ravel())
    assert eng.rounds == ref.rounds
    np.testing.assert_allclose(eng.energy_wh, ref.energy_wh, rtol=1e-6)


@pytest.mark.parametrize("family", KERNEL_BACKED)
def test_pallas_matches_ref_one_round(family):
    """Pallas fwd (interpret) + oracle-linearized bwd stays within 2e-6 of
    the jnp reference path across a full local-training round."""
    cfg = MODEL_CFGS[family]()
    t_ref = model_task(cfg, 8, backend="ref", val_size=8)
    t_pal = model_task(cfg, 8, backend="pallas", val_size=8)
    p0 = t_ref.init_params(jax.random.PRNGKey(0))
    batches = t_ref.client_data(0, 0, 2, 2)
    p_ref, l_ref = local_train(t_ref.loss_fn, p0, batches, OPT)
    p_pal, l_pal = local_train(t_pal.loss_fn, p0, batches, OPT)
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                               atol=2e-6, rtol=0)
    for kp, (a, b) in zip(
            jax.tree_util.tree_flatten_with_path(p_ref)[0],
            zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_pal))):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-6, rtol=0,
                                   err_msg=f"param {jax.tree_util.keystr(kp[0])}")


@pytest.mark.parametrize("family", ["transformer", "resnet"])
def test_end_to_end_noniid_churn(family):
    """B=8 scenarios, dirichlet shards, churn on — pallas == ref <= 2e-6."""
    fl = FLConfig(n_clients=4, local_steps=2, batch_per_client=2,
                  max_rounds=2, seed=0)
    churn = ChurnConfig(arrival=0.3, departure=0.1)
    ps = np.linspace(0.3, 0.95, 8)
    hist = {}
    for backend in ["ref", "pallas"]:
        task = model_task(MODEL_CFGS[family](), 8, backend=backend,
                          partition="dirichlet", alpha=1.0, n_clients=4,
                          dataset_size=256, val_size=16, data_seed=3)
        out = run_campaigns(fl, *task.campaign_args(), OPT, ps, churn=churn)
        assert np.asarray(out.acc_history).shape == (8, fl.max_rounds)
        assert np.all(np.isfinite(np.asarray(out.acc_history)))
        assert np.all(np.isfinite(np.asarray(out.energy_wh)))
        hist[backend] = np.asarray(out.acc_history)
    np.testing.assert_allclose(hist["pallas"], hist["ref"], atol=2e-6, rtol=0)


def test_noniid_shards_are_client_disjoint():
    """Dirichlet client_data samples only from the client's own shard."""
    data = SyntheticCifar(n_classes=10, seed=3)
    arrays = data.dataset(256)
    labels = np.asarray(arrays["labels"])
    parts = dirichlet_partition(labels, 4, alpha=0.3, seed=3)
    cb = sharded_client_arrays(
        {k: np.asarray(v) for k, v in arrays.items()}, parts, seed=3)
    for cid in range(4):
        batch = cb(cid, 0, 8, 2)
        allowed = set(labels[parts[cid]].tolist())
        got = set(np.asarray(batch["labels"]).ravel().tolist())
        assert got <= allowed
