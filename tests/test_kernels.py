"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401  (enables x64 — the campaign params dtype)
from repro.kernels import ref
from repro.kernels.fedavg_agg import fedavg_agg
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.ssm_scan import ssm_scan

TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 128, 4, 4, 64),
    (2, 256, 8, 2, 64),
    (1, 192, 6, 1, 32),       # ragged seq + MQA
    (2, 96, 4, 4, 128),       # ragged, wide head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, s, h, kv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (b, s, h, d), dtype)
    k = _rand(ks[1], (b, s, kv, d), dtype)
    v = _rand(ks[2], (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_attention_sliding_window(window):
    b, s, h, d = 1, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (b, s, h, d), jnp.float32)
    k = _rand(ks[1], (b, s, h, d), jnp.float32)
    v = _rand(ks[2], (b, s, h, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_noncausal():
    b, s, h, d = 2, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (_rand(kk, (b, s, h, d), jnp.float32) for kk in ks)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("b,s,h,d,bt", [
    (1, 64, 2, 32, 16),
    (2, 100, 3, 64, 32),      # ragged time
    (1, 32, 1, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(b, s, h, d, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = _rand(ks[0], (b, s, h, d), dtype, 0.5)
    k = _rand(ks[1], (b, s, h, d), dtype, 0.5)
    v = _rand(ks[2], (b, s, h, d), dtype, 0.5)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) \
        .astype(dtype) * 0.5 + 0.45
    u = _rand(ks[4], (h, d), dtype, 0.1)
    out, st = rwkv6_scan(r, k, v, w, u, block_t=bt, interpret=True)
    want, st_want = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_want),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("bsz,s,din,n,bt,bd", [
    (1, 48, 32, 8, 16, 32),
    (2, 64, 50, 16, 32, 32),   # ragged channels
    (1, 100, 32, 4, 32, 16),   # ragged time
])
def test_ssm_scan(bsz, s, din, n, bt, bd):
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    x = _rand(ks[0], (bsz, s, din), jnp.float32)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, din)))
    a_log = _rand(ks[2], (din, n), jnp.float32, 0.5)
    b = _rand(ks[3], (bsz, s, n), jnp.float32)
    c = _rand(ks[4], (bsz, s, n), jnp.float32)
    d_skip = _rand(ks[5], (din,), jnp.float32)
    y, h = ssm_scan(x, delta, a_log, b, c, d_skip, block_t=bt, block_d=bd,
                    interpret=True)
    y_want, h_want = ref.ssm_scan_ref(x, delta, a_log, b, c, d_skip)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_want), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_want), atol=2e-4)


@pytest.mark.parametrize("n,p,bp", [
    (4, 1000, 256),     # ragged P: last tile 232 wide
    (50, 4096, 2048),   # exact multiple of block_p
    (7, 999, 512),      # ragged P, odd client count
    (1, 777, 256),      # N = 1: mean degenerates to the lone client
    (3, 100, 2048),     # P < block_p: single shrunken tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_agg(n, p, bp, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    g = _rand(ks[0], (p,), dtype)
    cf = _rand(ks[1], (n, p), dtype)
    mask = jax.random.bernoulli(ks[2], 0.5, (n,))
    out = fedavg_agg(g, cf, mask, block_p=bp, interpret=True)
    want = ref.fedavg_agg_ref(g, cf, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_fedavg_agg_float64_inputs():
    """x64 campaign params pass through the fp32 kernel to fp32 accuracy."""
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    g = jax.random.normal(ks[0], (1000,), jnp.float64)
    cf = jax.random.normal(ks[1], (6, 1000), jnp.float64)
    mask = jax.random.bernoulli(ks[2], 0.5, (6,))
    out = fedavg_agg(g, cf, mask, block_p=256, interpret=True)
    assert out.dtype == jnp.float64
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.fedavg_agg_ref(g, cf, mask)),
                               atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("n", [1, 5])
def test_fedavg_agg_empty_round_keeps_global(n):
    g = jnp.arange(100.0)
    cf = jnp.ones((n, 100))
    out = fedavg_agg(g, cf, jnp.zeros((n,), bool), block_p=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g))


def test_fedavg_pytree_wrapper():
    from repro.federated.server import fedavg_merge
    from repro.kernels.ops import fedavg_merge_pallas
    key = jax.random.PRNGKey(6)
    g = {"a": jax.random.normal(key, (13, 7)), "b": jnp.ones((5,))}
    c = jax.tree.map(lambda x: jnp.stack([x + i for i in range(4)]), g)
    mask = jnp.asarray([1, 0, 1, 1], bool)
    want = fedavg_merge(g, c, mask)
    got = fedavg_merge_pallas(g, c, mask)
    for k in g:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=1e-5)


@pytest.mark.parametrize("b,n,bb", [
    (1, 1, 8),      # degenerate single node
    (5, 8, 2),      # ragged batch: last tile half-full
    (4, 50, 4),     # paper fleet size, exact tiling
    (3, 17, 8),     # batch < block_b: single shrunken tile
])
def test_poibin_dft_kernel(b, n, bb):
    from repro.kernels.poibin_dft import poibin_dft
    rng = np.random.default_rng(b * 100 + n)
    p = jnp.asarray(rng.uniform(0.0, 1.0, (b, n)))
    p = p.at[0, 0].set(0.0)        # corners: deconvolution degenerates
    if n > 1:
        p = p.at[0, 1].set(1.0)
    pmf, loo = poibin_dft(p, block_b=bb, interpret=True)
    want_pmf, want_loo = ref.poibin_dft_ref(p)
    assert pmf.shape == (b, n + 1) and loo.shape == (b, n, n + 1)
    np.testing.assert_allclose(np.asarray(pmf), np.asarray(want_pmf),
                               atol=2e-6)
    np.testing.assert_allclose(np.asarray(loo), np.asarray(want_loo),
                               atol=2e-6)
    # pmf-only variant (the social-cost path) agrees with the fused one
    pmf_only = poibin_dft(p, block_b=bb, with_loo=False, interpret=True)
    np.testing.assert_allclose(np.asarray(pmf_only), np.asarray(pmf),
                               atol=1e-7)


def test_poibin_dft_kernel_float32_inputs():
    """fp32 in -> fp32 out, same kernel arithmetic."""
    from repro.kernels.poibin_dft import poibin_dft
    p = jnp.asarray([[0.25, 0.75, 0.5]], jnp.float32)
    pmf, loo = poibin_dft(p, interpret=True)
    assert pmf.dtype == jnp.float32 and loo.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(pmf[0]),
                               np.asarray(ref.poibin_dft_ref(p)[0][0]),
                               atol=2e-6)


def test_flash_attention_integrated_in_model():
    """Model forward with runtime.ATTN_IMPL='flash' matches the reference
    path end to end (stablelm: plain causal; hymba: sliding-window)."""
    from repro.configs import ARCHITECTURES
    from repro.models import runtime
    from repro.models import transformer as T
    from repro.models import hybrid as H
    from repro.models.registry import get_model

    for name, fwd in (("stablelm-3b", lambda cfg, p, t: T.forward(cfg, p, t)[0]),
                      ("hymba-1.5b", lambda cfg, p, t: H.forward(cfg, p, t))):
        cfg = ARCHITECTURES[name].reduced()
        api = get_model(cfg)
        params, _ = api.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                    cfg.vocab)
        runtime.ATTN_IMPL = "reference"
        ref_out = fwd(cfg, params, tokens)
        try:
            runtime.ATTN_IMPL = "flash"
            flash_out = fwd(cfg, params, tokens)
        finally:
            runtime.ATTN_IMPL = "reference"
        np.testing.assert_allclose(np.asarray(flash_out),
                                   np.asarray(ref_out), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("t,d,v,bt,bv", [
    (64, 32, 100, 16, 32),
    (100, 48, 257, 32, 64),     # ragged tokens + ragged vocab
    (128, 64, 512, 128, 512),   # single-tile fast path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ce(t, d, v, bt, bv, dtype):
    from repro.kernels.fused_ce import fused_ce
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    h = _rand(ks[0], (t, d), dtype)
    w = _rand(ks[1], (d, v), dtype, d ** -0.5)
    lab = jax.random.randint(ks[2], (t,), 0, v)
    out = fused_ce(h, w, lab, block_t=bt, block_v=bv, interpret=True)
    want = ref.fused_ce_ref(h.astype(jnp.float32), w.astype(jnp.float32),
                            lab)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-2 if dtype == jnp.bfloat16 else 3e-5,
                               rtol=3e-2 if dtype == jnp.bfloat16 else 3e-5)


def test_fused_ce_matches_model_loss():
    """Fused CE reproduces the model's lm_loss on a reduced config."""
    from repro.configs import ARCHITECTURES
    from repro.kernels.ops import cross_entropy
    from repro.models import layers as L
    from repro.models import transformer as T
    from repro.models.registry import get_model

    cfg = ARCHITECTURES["phi4-mini-3.8b"].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    want = float(api.loss(params, {"tokens": tokens, "labels": labels}))
    # recompute via hidden states + fused kernel
    logits, _ = T.forward(cfg, params, tokens)
    del logits
    # reconstruct final hidden: forward without the head
    x = T._embed(cfg, params, tokens)
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    x, _, _ = T._scan_blocks(cfg, params["layers"], x, pos, 0, None, False)
    x = L.apply_norm(cfg, params["ln_f"], x)
    nll = cross_entropy(x.reshape(-1, cfg.d_model).astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32),
                        labels.reshape(-1), block_t=16, block_v=128)
    got = float(jnp.mean(nll))
    assert abs(got - want) < 2e-4, (got, want)
