"""Hypothesis fuzz over the sweep-service request schema.

The totality property the serving layer leans on: **any** payload either
parses into a typed request or raises a typed
:class:`~repro.serve.RequestError` — never any other exception — and every
valid request survives the ``parse → to_dict → parse`` round trip. Since
every traced shape and static argument downstream derives from validated
fields, this is also the "malformed payloads never become trace-time
crashes" guarantee (the deterministic rejection table lives in
``tests/test_serve.py``).
"""
import math

import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die, without it
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve import (SCHEMA, CalibrateRequest, RequestError,  # noqa: E402
                         parse_request)

_scalar = st.one_of(st.none(), st.booleans(), st.integers(),
                    st.floats(allow_nan=True, allow_infinity=True),
                    st.text(max_size=8))
_jsonish = st.recursive(
    _scalar,
    lambda inner: st.one_of(st.lists(inner, max_size=4),
                            st.dictionaries(st.text(max_size=8), inner,
                                            max_size=5)),
    max_leaves=12)


@given(payload=_jsonish)
@settings(max_examples=200)
def test_fuzz_arbitrary_payloads_never_crash(payload):
    """Total validation: any junk either parses or raises RequestError."""
    try:
        req = parse_request(payload)
    except RequestError as e:
        assert e.code and e.message
    else:
        assert parse_request(req.to_dict()) == req


_kinds = st.sampled_from(["ne_solve", "calibrate", "campaign"])


@given(kind=_kinds, payload=st.dictionaries(
    st.sampled_from(["costs", "gammas", "n_nodes", "cost", "p", "grid",
                     "rounds", "dur", "seed", "max_iters", "id", "tol"]),
    _jsonish, max_size=6))
@settings(max_examples=200)
def test_fuzz_kindful_payloads_never_crash(kind, payload):
    """Junk targeted at real field names is still totally validated."""
    try:
        req = parse_request({"schema": SCHEMA, "kind": kind, **payload})
    except RequestError as e:
        assert e.code and e.message
    else:
        assert parse_request(req.to_dict()) == req


_costs = st.lists(st.floats(min_value=0.0, max_value=10.0,
                            allow_nan=False, allow_infinity=False),
                  min_size=1, max_size=20)


@given(costs=_costs,
       gamma=st.floats(min_value=0.0, max_value=50.0, allow_nan=False),
       max_iters=st.integers(min_value=1, max_value=2000),
       verify_grid=st.integers(min_value=2, max_value=1025))
@settings(max_examples=100)
def test_fuzz_valid_ne_fields_round_trip(costs, gamma, max_iters,
                                         verify_grid):
    req = parse_request({"schema": SCHEMA, "kind": "ne_solve",
                         "costs": costs, "gammas": gamma,
                         "max_iters": max_iters,
                         "verify_grid": verify_grid})
    assert req.n == len(costs)
    assert all(math.isfinite(c) for c in req.costs)
    assert parse_request(req.to_dict()) == req


@given(n=st.integers(min_value=2, max_value=512),
       grid=st.integers(min_value=2, max_value=1025),
       gamma_max=st.floats(min_value=1e-3, max_value=100.0,
                           allow_nan=False))
@settings(max_examples=100)
def test_fuzz_valid_calibrate_fields_round_trip(n, grid, gamma_max):
    req = parse_request({"schema": SCHEMA, "kind": "calibrate",
                         "n_nodes": n, "cost": 0.1, "grid": grid,
                         "gamma_max": gamma_max})
    assert isinstance(req, CalibrateRequest) and req.n == n
    assert parse_request(req.to_dict()) == req


@given(rows=st.integers(min_value=1, max_value=500),
       max_batch=st.sampled_from([1, 2, 4, 8, 16, 32, 64]))
@settings(max_examples=100)
def test_fuzz_bucket_ladder_invariants(rows, max_batch):
    """Rung covers the rows, stays on the ladder, chunks cover exactly."""
    from repro.serve import batch_rung, chunk_rows
    rung = batch_rung(min(rows, max_batch), max_batch=max_batch)
    assert rung >= min(rows, max_batch)
    assert rung <= max_batch and (rung & (rung - 1)) == 0
    chunks = chunk_rows(rows, max_batch=max_batch)
    assert sum(chunks) == rows
    assert all(1 <= c <= max_batch for c in chunks)
