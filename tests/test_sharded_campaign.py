"""Sharded-vs-unsharded equivalence suite (the tentpole contract).

Two pins, mirroring PR 5's ``backend="ref"`` contract style:

* ``mesh=None`` (and its degenerate cousin, a 1-device mesh) stays
  bitwise-equal to the PR 6 engines — these cases run in the tier-1 suite
  on a single device;
* on a faked 8-device mesh (``XLA_FLAGS=--xla_force_host_platform_
  device_count=8`` — the dedicated multi-device CI job) the sharded
  engines reproduce the single-device results: bitwise for the
  ledger/masks/NE profiles (per-scenario programs are independent, so
  GSPMD introduces no cross-scenario reductions), ≤2e-6 for merged
  params, including batch sizes not divisible by the device count.

The hypothesis property sweeps random (B, N, device_count) triples through
the NE engine; per-example device counts only exceed 1 when the process
actually has the devices.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

import repro.core  # noqa: F401  (enables x64)
from repro.core.asymmetric_batched import poa_report, solve_heterogeneous
from repro.core.controller import ParticipationController
from repro.core.duration import paper_duration_model
from repro.federated.campaign import build_campaign, run_campaigns
from repro.federated.simulation import FLConfig
from repro.federated.tasks import synthetic_mlp_task
from repro.obs import EventSink, ObsConfig
from repro.optim import sgd

DEVICES = jax.device_count()
multidevice = pytest.mark.skipif(
    DEVICES < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_"
                        "device_count=8 (multi-device CI job)")


def data_mesh(k: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:k]), ("data",))


def _dur(n: int):
    return dataclasses.replace(paper_duration_model(), n_nodes=n)


def _scenarios(b: int, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    costs = jnp.asarray(rng.uniform(0.3, 3.0, (b, n)))
    gammas = jnp.asarray(rng.uniform(0.0, 2.0, (b, n)))
    return costs, gammas


def _assert_campaigns_equal(a, b):
    """Bitwise over every accounting output (the ledger/mask contract)."""
    for name in ("k_history", "rounds", "converged_at", "acc_history",
                 "energy_wh", "per_node_aoi", "participation_rate"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=name)
    for la, lb in zip(jax.tree.leaves(a.ledger), jax.tree.leaves(b.ledger)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# tier-1 (single-device) pins
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def campaign_task():
    task = synthetic_mlp_task()
    fl = FLConfig(n_clients=5, local_steps=1, batch_per_client=8,
                  max_rounds=6, target_acc=0.73, seed=3)
    ps = jnp.asarray([0.3, 0.55, 0.8], jnp.float32)
    base = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps)
    return task, fl, ps, base


def test_one_device_mesh_campaign_is_bitwise(campaign_task):
    """A trivial 1-device mesh resolves to a replicated spec — the program
    must equal the mesh=None engine bit for bit (the mesh=None default
    itself is pinned by the whole pre-existing suite)."""
    task, fl, ps, base = campaign_task
    res = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                        mesh=data_mesh(1))
    _assert_campaigns_equal(res, base)


def test_one_device_mesh_ne_engine_is_bitwise():
    costs, gammas, dur = *_scenarios(7, 6), _dur(6)
    ref = poa_report(costs, gammas, dur)
    sh = poa_report(costs, gammas, dur, mesh=data_mesh(1))
    np.testing.assert_array_equal(np.asarray(ref.solution.p),
                                  np.asarray(sh.solution.p))
    for name in ("deviation", "ne_cost", "opt_p", "opt_cost", "poa"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, name)),
                                      np.asarray(getattr(sh, name)),
                                      err_msg=name)


def test_mesh_rejects_pallas_backend():
    costs, gammas, dur = *_scenarios(4, 5), _dur(5)
    sol = solve_heterogeneous(costs, gammas, dur)
    from repro.core.asymmetric_batched import verify_equilibrium_batched
    with pytest.raises(ValueError, match="ref backend"):
        verify_equilibrium_batched(costs, gammas, dur, sol.p,
                                   backend="pallas", mesh=data_mesh(1))


# ---------------------------------------------------------------------------
# 8-device equivalence (multi-device CI job)
# ---------------------------------------------------------------------------

@multidevice
@pytest.mark.parametrize("batch", [8, 11])
def test_campaign_8dev_matches_single_device(campaign_task, batch):
    """Divisible (8) and padded (11) batches over 8 devices; every
    accounting output bitwise, merged params to 2e-6."""
    task, fl, _, _ = campaign_task
    ps = jnp.linspace(0.25, 0.85, batch).astype(jnp.float32)
    ref = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps)
    sh = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                       mesh=data_mesh(8))
    _assert_campaigns_equal(sh, ref)


@multidevice
def test_campaign_8dev_param_leaves_close(campaign_task):
    """Raw engine outputs: merged model params within 2e-6 of the
    single-device run (8 scenarios land one-per-device)."""
    task, fl, _, _ = campaign_task
    ps = jnp.broadcast_to(
        jnp.linspace(0.3, 0.8, 8, dtype=jnp.float32)[:, None],
        (8, fl.n_clients))
    seeds = jnp.full((8,), fl.seed, jnp.uint32)
    rates = (jnp.full((8,), 1.0), jnp.full((8,), 0.1))
    args = (fl, *task.campaign_args(), sgd(0.15))
    ref_out = build_campaign(*args)(ps, seeds, *rates)
    sh_out = build_campaign(*args, mesh=data_mesh(8))(ps, seeds, *rates)
    for a, b in zip(jax.tree.leaves(ref_out["params"]),
                    jax.tree.leaves(sh_out["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6)


@multidevice
def test_campaign_8dev_obs_padding_and_dispatch(campaign_task):
    """B=11 over 8 devices pads 5 replica lanes: events must carry only the
    11 real scenario ids, metrics must match the unsharded stream bitwise,
    and the merge call-site dispatch counter must count the trace once —
    not once per device replica."""
    from repro.kernels import ops as kernel_ops

    task, fl, _, _ = campaign_task
    ps = jnp.linspace(0.25, 0.85, 11).astype(jnp.float32)

    with EventSink() as sink:
        obs = ObsConfig(enabled=True, events=True, sink=sink)
        kernel_ops.reset_dispatch_stats()
        sh = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                           mesh=data_mesh(8), obs=obs)
        jax.block_until_ready(sh.acc_history)
        sink.flush()
        stats = kernel_ops.dispatch_stats()
        evs = sink.events
    assert stats["server.fedavg_merge"] == {"ref": 1}
    rounds = [e for e in evs if e["event"] == "round"]
    finals = [e for e in evs if e["event"] == "campaign"]
    assert len(rounds) == 11 * fl.max_rounds
    assert len(finals) == 11
    assert sorted({e["scenario"] for e in rounds}) == list(range(11))

    with EventSink() as sink2:
        obs2 = ObsConfig(enabled=True, events=True, sink=sink2)
        ref = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                            obs=obs2)
        jax.block_until_ready(ref.acc_history)
        sink2.flush()
    assert len(sink2.events) == len(evs)
    for a, b in zip(jax.tree.leaves(sh.metrics), jax.tree.leaves(ref.metrics)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@multidevice
def test_ne_8dev_matches_single_device():
    costs, gammas, dur = *_scenarios(13, 8, seed=1), _dur(8)
    ref = solve_heterogeneous(costs, gammas, dur)
    sh = solve_heterogeneous(costs, gammas, dur, mesh=data_mesh(8))
    np.testing.assert_array_equal(np.asarray(ref.p), np.asarray(sh.p))
    np.testing.assert_array_equal(np.asarray(ref.converged),
                                  np.asarray(sh.converged))
    np.testing.assert_array_equal(np.asarray(ref.iters), np.asarray(sh.iters))
    rep_ref = poa_report(costs, gammas, dur)
    rep_sh = poa_report(costs, gammas, dur, mesh=data_mesh(8))
    for name in ("deviation", "ne_cost", "opt_p", "opt_cost", "poa"):
        np.testing.assert_array_equal(np.asarray(getattr(rep_ref, name)),
                                      np.asarray(getattr(rep_sh, name)),
                                      err_msg=name)


@multidevice
def test_controller_8dev_passthrough():
    n = 6
    costs, gammas, dur = *_scenarios(9, n, seed=2), _dur(n)
    ctrl = ParticipationController(n_nodes=n, gamma=1.0, cost=1.5,
                                   duration_model=dur)
    for mode in ("ne", "ne_worst", "centralized"):
        ref = ctrl.solve_batched_heterogeneous(gammas, costs, mode)
        sh = ctrl.solve_batched_heterogeneous(gammas, costs, mode,
                                              mesh=data_mesh(8))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(sh),
                                      err_msg=mode)
