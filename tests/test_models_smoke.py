"""Per-architecture smoke tests: REDUCED variant (<=2 layers, d_model<=512,
<=4 experts), forward + the full training direction (loss, grads, optimizer
steps, remat on/off) on CPU; output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models.registry import get_model, param_count
from repro.optim import adamw
from repro.optim.base import apply_updates

ARCH_NAMES = sorted(ARCHITECTURES)
# largest reduced variants (MLA+MoE / ViT frontend): slow-marked for the
# multi-step training tests so default tier-1 stays fast
HEAVY = {"deepseek-v2-236b", "internvl2-26b"}


def _arch_params(names=ARCH_NAMES):
    return [pytest.param(n, marks=pytest.mark.slow) if n in HEAVY
            else n for n in names]


B, S = 2, 16


def _batch(cfg, key):
    if cfg.family == "vision":
        return {"images": jax.random.normal(key, (B, 32, 32, 3),
                                            jnp.float32),
                "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                             (B,), 0, cfg.vocab)}
    tk = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tk, "labels": jnp.roll(tk, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.n_patches, cfg.d_frontend))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.n_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_bounds(name):
    cfg = ARCHITECTURES[name].reduced()
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_train_step(name):
    cfg = ARCHITECTURES[name].reduced()
    api = get_model(cfg)
    params, specs = api.init(jax.random.PRNGKey(0))
    assert param_count(params) > 0
    # specs mirror params structure
    assert set(specs.keys()) == set(params.keys())

    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(lambda p: api.loss(p, batch))(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    finite = jax.tree.map(lambda g: bool(jnp.all(jnp.isfinite(
        g.astype(jnp.float32)))), grads)
    assert all(jax.tree.leaves(finite)), name

    opt = adamw(1e-3)
    updates, _ = opt.update(grads, opt.init(params), params)
    new_params = apply_updates(params, updates)
    loss2 = api.loss(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("name", _arch_params())
def test_train_step_reduces_loss(name):
    """A few SGD steps on a fixed batch must reduce the loss."""
    cfg = ARCHITECTURES[name].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    opt = adamw(3e-3)
    state = opt.init(params)
    loss_fn = jax.jit(jax.value_and_grad(lambda p: api.loss(p, batch)))
    first = None
    for _ in range(5):
        loss, grads = loss_fn(params)
        first = first if first is not None else float(loss)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    final = float(api.loss(params, batch))
    assert final < first, (name, first, final)


@pytest.mark.parametrize("name", _arch_params())
def test_remat_matches_no_remat(name):
    """Training direction: loss AND grads agree with/without checkpointing."""
    cfg = ARCHITECTURES[name].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l0, g0 = jax.value_and_grad(
        lambda p: api.loss(p, batch, remat=False))(params)
    l1, g1 = jax.value_and_grad(
        lambda p: api.loss(p, batch, remat=True))(params)
    assert float(l0) == pytest.approx(float(l1), rel=1e-5)
    for (kp, a), b in zip(jax.tree_util.tree_flatten_with_path(g0)[0],
                          jax.tree.leaves(g1)):
        np.testing.assert_allclose(
            np.asarray(b, np.float32), np.asarray(a, np.float32),
            rtol=1e-4, atol=1e-5,
            err_msg=f"{name} grad {jax.tree_util.keystr(kp)}")


def test_moe_capacity_drops_are_bounded():
    """Router aux loss is finite and dispatch keeps most tokens at cf=1.25."""
    cfg = ARCHITECTURES["olmoe-1b-7b"].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    from repro.models import transformer as T
    logits, aux = T.forward(cfg, params, batch["tokens"])
    assert np.isfinite(float(aux))
    assert logits.shape == (B, S, cfg.vocab)


def test_resnet18_paper_size():
    from repro.models import resnet
    p = resnet.init_resnet18(jax.random.PRNGKey(0))
    n = resnet.param_count(p)
    # paper: 11,181,642 — structural match within 0.2%
    assert abs(n - 11_181_642) / 11_181_642 < 0.002
