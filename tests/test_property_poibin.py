"""Hypothesis property tests for the Poisson-Binomial stack.

Invariants pinned here (across random p vectors *including* the p ∈ {0, 1}
corners, which the generic float strategy rarely lands on exactly):

* the DFT closed form (paper eq. 9) agrees with the O(N²) convolution
  recursion oracle;
* every pmf sums to 1;
* the mean equals Σ p_i;
* leave-one-out deconvolution inverts convolution (both directions), the
  identity the batched heterogeneous engine's O(N) Gauss-Seidel step rests
  on;
* the batched Pallas DFT kernel (``repro.kernels.poibin_dft``, interpret
  mode) and its jnp oracle (``repro.kernels.ref.poibin_dft_ref``) both
  reproduce ``poibin_pmf`` / ``poibin_pmf_loo`` — the kernel to fp32
  tolerance, the oracle to float64 tightness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die, without it
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core  # noqa: F401  (enables x64)
from repro.core.poibin import (poibin_convolve, poibin_mean, poibin_pmf,
                               poibin_pmf_batched, poibin_pmf_loo,
                               poibin_pmf_loo_all, poibin_pmf_recursive)

# Probabilities with the corners (and the deconvolution direction switch at
# 1/2) explicitly over-weighted: plain floats(0, 1) almost never draws them.
prob = st.one_of(st.sampled_from([0.0, 0.5, 1.0]),
                 st.floats(0.0, 1.0, allow_nan=False))
prob_vectors = st.lists(prob, min_size=1, max_size=24)


@settings(max_examples=40, deadline=None)
@given(prob_vectors)
def test_dft_matches_recursive_oracle(p):
    dft = np.asarray(poibin_pmf(jnp.asarray(p)))
    rec = np.asarray(poibin_pmf_recursive(jnp.asarray(p)))
    np.testing.assert_allclose(dft, rec, atol=1e-8)


@settings(max_examples=40, deadline=None)
@given(prob_vectors)
def test_pmf_sums_to_one(p):
    for pmf in (poibin_pmf(jnp.asarray(p)),
                poibin_pmf_recursive(jnp.asarray(p))):
        pmf = np.asarray(pmf)
        assert pmf.shape == (len(p) + 1,)
        assert np.all(pmf >= -1e-12)
        assert abs(pmf.sum() - 1.0) < 1e-9


@settings(max_examples=40, deadline=None)
@given(prob_vectors)
def test_mean_equals_sum_of_p(p):
    k = np.arange(len(p) + 1)
    want = float(poibin_mean(jnp.asarray(p)))
    for pmf in (poibin_pmf(jnp.asarray(p)),
                poibin_pmf_recursive(jnp.asarray(p))):
        assert float(k @ np.asarray(pmf)) == pytest.approx(want, abs=1e-9)


@settings(max_examples=40, deadline=None)
@given(prob_vectors, st.data())
def test_loo_deconvolution_inverts_convolution(p, data):
    """Dividing node i's [1-p_i, p_i] factor out of the full pmf recovers
    the pmf of the other nodes; folding it back recovers the full pmf."""
    i = data.draw(st.integers(0, len(p) - 1), label="node")
    full = poibin_pmf_recursive(jnp.asarray(p))
    loo = poibin_pmf_loo(full, p[i])
    rest = poibin_pmf_recursive(jnp.asarray(p[:i] + p[i + 1:]))
    np.testing.assert_allclose(np.asarray(loo[:-1]), np.asarray(rest),
                               atol=1e-9)
    assert float(loo[-1]) == 0.0
    back = poibin_convolve(loo, p[i])
    np.testing.assert_allclose(np.asarray(back), np.asarray(full),
                               atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(prob_vectors)
def test_poibin_kernel_pinned_to_scalar_functions(p):
    """The Pallas kernel (interpret mode) reproduces ``poibin_pmf`` and
    ``poibin_pmf_loo`` on a (1, N) batch — including p ∈ {0, 1}, where the
    deconvolution degenerates to a copy/shift."""
    from repro.kernels import ops

    p_mat = jnp.asarray([p])
    pmf_k, loo_k = ops.poibin(p_mat)                     # pallas, fp32
    want_pmf = poibin_pmf(p_mat[0])
    want_loo = jax.vmap(poibin_pmf_loo, in_axes=(None, 0))(want_pmf,
                                                           p_mat[0])
    np.testing.assert_allclose(np.asarray(pmf_k[0]), np.asarray(want_pmf),
                               atol=2e-6)
    np.testing.assert_allclose(np.asarray(loo_k[0]), np.asarray(want_loo),
                               atol=2e-6)
    # pmf-only kernel variant agrees with the fused one
    np.testing.assert_allclose(np.asarray(ops.poibin_pmf(p_mat)),
                               np.asarray(pmf_k), atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(prob_vectors)
def test_poibin_kernel_oracle_pinned_to_scalar_functions(p):
    """The self-contained jnp oracle in ``kernels.ref`` states the same math
    as ``core.poibin`` — drift between the two layers fails here."""
    from repro.kernels import ref

    p_mat = jnp.asarray([p])
    pmf_o, loo_o = ref.poibin_dft_ref(p_mat)
    np.testing.assert_allclose(np.asarray(pmf_o[0]),
                               np.asarray(poibin_pmf(p_mat[0])), atol=1e-12)
    want_loo = jax.vmap(poibin_pmf_loo, in_axes=(None, 0))(pmf_o[0],
                                                           p_mat[0])
    np.testing.assert_allclose(np.asarray(loo_o[0]), np.asarray(want_loo),
                               atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(st.lists(prob_vectors.filter(lambda v: len(v) >= 2), min_size=1,
                max_size=4))
def test_poibin_batched_dispatchers(rows):
    """The core batched entry points: ref backend bitwise-equals the vmapped
    scalar functions; pallas backend matches to fp32 tolerance; ragged
    batches exercise the kernel's batch-tile padding."""
    n = min(len(r) for r in rows)
    p_mat = jnp.asarray([r[:n] for r in rows])
    pmf_ref = poibin_pmf_batched(p_mat)                  # default: ref
    np.testing.assert_array_equal(np.asarray(pmf_ref),
                                  np.asarray(jax.vmap(poibin_pmf)(p_mat)))
    pmf_rec, loo_ref = poibin_pmf_loo_all(p_mat)
    np.testing.assert_array_equal(
        np.asarray(pmf_rec),
        np.asarray(jax.vmap(poibin_pmf_recursive)(p_mat)))
    pmf_pal, loo_pal = poibin_pmf_loo_all(p_mat, backend="pallas")
    np.testing.assert_allclose(np.asarray(pmf_pal), np.asarray(pmf_rec),
                               atol=2e-6)
    np.testing.assert_allclose(np.asarray(loo_pal), np.asarray(loo_ref),
                               atol=2e-6)


@settings(max_examples=40, deadline=None)
@given(prob_vectors, prob)
def test_convolve_step_extends_recursion(p, q):
    """poibin_convolve(·, q) is exactly one step of the recursion: folding a
    new node q into pmf(p) equals pmf(p + [q])."""
    base = poibin_pmf_recursive(jnp.asarray(p))
    padded = jnp.concatenate([base, jnp.zeros((1,), base.dtype)])
    got = poibin_convolve(padded, q)
    want = poibin_pmf_recursive(jnp.asarray(p + [q]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)
