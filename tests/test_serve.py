"""Request-level harness for the scenario-sweep service.

Three layers, mirroring the serving stack:

* **Schema** — golden request/response round-trips
  (``parse_request(req.to_dict()) == req``, canonical serialization) and
  a typed-error table for every malformation class.
* **Typed errors** — a rejection table covering every malformation class
  (the hypothesis fuzz over the same surface lives in
  ``tests/test_property_serve.py``, following the repo's property-suite
  convention).
* **Queue path** — end-to-end through :class:`repro.serve.SweepService`:
  enqueue order vs. result order, mixed request families interleaved in
  one queue, malformed payloads surfacing as ``ok=False`` responses
  mid-stream, and drain-on-shutdown (nothing left queued, sink flushed).
"""
import json

import pytest

from repro.serve import (KINDS, SCHEMA, DurationSpec, RequestError,
                         SweepService, parse_request)


# ---------------------------------------------------------------------------
# golden round-trips
# ---------------------------------------------------------------------------

GOLDEN = [
    {"schema": SCHEMA, "kind": "ne_solve",
     "costs": [0.05, 0.1, 0.2], "gammas": [1.5, 1.0, 2.0]},
    {"schema": SCHEMA, "kind": "ne_solve", "costs": [0.3, 0.3],
     "gammas": 0.7, "dur": {"d_inf": 20.0, "slope": 4.0},
     "damping": 0.4, "max_iters": 50, "tol": 1e-6, "verify_grid": 16,
     "id": "req-1"},
    {"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1, 0.2],
     "dur": {"table": [10.0, 8.0, 7.5]}},
    {"schema": SCHEMA, "kind": "calibrate", "n_nodes": 6, "cost": 0.1},
    {"schema": SCHEMA, "kind": "calibrate", "n_nodes": 4, "cost": 0.0,
     "gamma0": 0.5, "target_poa": 1.2, "gamma_max": 2.0, "grid": 5,
     "ne_grid": 64, "opt_grid": 64, "id": 7},
    {"schema": SCHEMA, "kind": "campaign", "p": 0.5},
    {"schema": SCHEMA, "kind": "campaign", "p": [0.2, 0.9], "n_clients": 2,
     "rounds": 3, "seed": 11, "e_participant_j": 40.0, "e_idle_j": 1.0},
]


@pytest.mark.parametrize("payload", GOLDEN,
                         ids=lambda p: f"{p['kind']}-{len(p)}f")
def test_golden_round_trip(payload):
    req = parse_request(payload)
    wire = req.to_dict()
    # canonical: defaults materialized, re-parse is the identity
    assert parse_request(wire) == req
    assert parse_request(wire).to_dict() == wire
    # the wire form is plain JSON
    assert json.loads(json.dumps(wire)) == wire
    assert wire["kind"] in KINDS


def test_scalar_broadcast_is_canonicalized():
    """Scalar gammas/p expand to per-node tuples at parse time."""
    req = parse_request({"schema": SCHEMA, "kind": "ne_solve",
                         "costs": [0.1, 0.2, 0.3], "gammas": 1.5})
    assert req.gammas == (1.5, 1.5, 1.5)
    camp = parse_request({"schema": SCHEMA, "kind": "campaign", "p": 0.4,
                          "n_clients": 3})
    assert camp.p == (0.4, 0.4, 0.4)


def test_duration_spec_table_round_trip():
    req = parse_request({"schema": SCHEMA, "kind": "ne_solve",
                         "costs": [0.1, 0.2],
                         "dur": {"table": [9.0, 8.0, 7.0]}})
    assert req.dur == DurationSpec(table=(9.0, 8.0, 7.0))
    assert req.dur.to_dict() == {"table": [9.0, 8.0, 7.0]}
    # hashable: the service caches materialized tables per (spec, n)
    assert hash(req.dur) == hash(DurationSpec(table=(9.0, 8.0, 7.0)))


# ---------------------------------------------------------------------------
# typed errors
# ---------------------------------------------------------------------------

BAD = [
    (42, "bad_request", None),
    ([1, 2], "bad_request", None),
    ({"schema": "repro.serve/v0", "kind": "ne_solve", "costs": [0.1]},
     "bad_schema", "schema"),
    ({"schema": SCHEMA, "kind": "teleport"}, "bad_kind", "kind"),
    ({"schema": SCHEMA}, "bad_kind", "kind"),
    ({"schema": SCHEMA, "kind": "ne_solve"}, "missing_field", "costs"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1],
      "surprise": 1}, "unknown_field", "surprise"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": "cheap"},
     "bad_type", "costs"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": []},
     "bad_value", "costs"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1, float("nan")]},
     "bad_value", "costs"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1, -0.5]},
     "bad_value", "costs"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1],
      "gammas": [1.0, 2.0]}, "bad_value", "gammas"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1],
      "damping": True}, "bad_type", "damping"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1],
      "max_iters": 10**9}, "too_large", "max_iters"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1] * 513},
     "too_large", "costs"),
    ({"schema": SCHEMA, "kind": "ne_solve", "costs": [0.1, 0.2],
      "dur": {"table": [1.0, 2.0]}}, "bad_value", "table"),
    ({"schema": SCHEMA, "kind": "calibrate", "cost": 0.1},
     "missing_field", "n_nodes"),
    ({"schema": SCHEMA, "kind": "calibrate", "n_nodes": 6, "cost": 0.1,
      "grid": -3}, "bad_value", "grid"),
    ({"schema": SCHEMA, "kind": "calibrate", "n_nodes": 6, "cost": 0.1,
      "grid": 2.5}, "bad_type", "grid"),
    ({"schema": SCHEMA, "kind": "calibrate", "n_nodes": 6, "cost": 0.1,
      "target_poa": 1.0}, "bad_value", "target_poa"),
    ({"schema": SCHEMA, "kind": "campaign", "p": 0.0}, "bad_value", "p"),
    ({"schema": SCHEMA, "kind": "campaign", "p": [0.5, 1.5],
      "n_clients": 2}, "bad_value", "p"),
    ({"schema": SCHEMA, "kind": "campaign", "p": 0.5, "rounds": 100000},
     "too_large", "rounds"),
    ({"schema": SCHEMA, "kind": "campaign", "p": 0.5, "id": True},
     "bad_type", "id"),
]


@pytest.mark.parametrize("payload,code,field", BAD,
                         ids=[f"{i}-{c}" for i, (_, c, _f) in enumerate(BAD)])
def test_typed_rejections(payload, code, field):
    with pytest.raises(RequestError) as exc:
        parse_request(payload)
    assert exc.value.code == code
    assert exc.value.field == field
    body = exc.value.to_dict()
    assert body["code"] == code and body["message"]
    assert json.loads(json.dumps(body)) == body


# ---------------------------------------------------------------------------
# queue path end-to-end (small shapes; compiles are shared via the
# module-scoped service)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def svc():
    from repro.federated.tasks import synthetic_mlp_task
    from repro.optim import sgd
    service = SweepService(max_batch=8,
                           task=synthetic_mlp_task(image_shape=(4, 4, 1),
                                                   hidden=4, val_size=32),
                           opt=sgd(0.15))
    yield service
    service.close()


def _ne(i, n=3):
    return {"schema": SCHEMA, "kind": "ne_solve", "id": f"ne-{i}",
            "costs": [0.05 * (j + 1) for j in range(n)], "gammas": 1.0}


def _cal(i):
    return {"schema": SCHEMA, "kind": "calibrate", "id": f"cal-{i}",
            "n_nodes": 4, "cost": 0.1, "grid": 3, "gamma_max": 2.0,
            "ne_grid": 32, "opt_grid": 32}


def test_mixed_families_one_queue(svc):
    """Interleaved families batch per family; ids map results back."""
    payloads = [_ne(0), _cal(0), _ne(1), _cal(1), _ne(2)]
    rids = [svc.submit(p) for p in payloads]
    assert rids == sorted(rids)
    resps = svc.poll()
    assert [r.rid for r in resps] != rids  # grouped: not submit order
    assert sorted(r.rid for r in resps) == rids
    by_id = {r.id: r for r in resps}
    assert set(by_id) == {"ne-0", "ne-1", "ne-2", "cal-0", "cal-1"}
    for r in resps:
        assert r.ok and r.bucket and r.latency_us > 0
        assert r.queue_us <= r.latency_us
    # one dispatch per family: same bucket label within a family
    assert len({by_id[f"ne-{i}"].bucket for i in range(3)}) == 1
    assert len({by_id[f"cal-{i}"].bucket for i in range(2)}) == 1


def test_enqueue_order_preserved_within_family(svc):
    reqs = [_ne(i) for i in range(5)]
    rids = [svc.submit(p) for p in reqs]
    resps = svc.poll()
    assert [r.rid for r in resps] == rids  # single family: FIFO
    assert [r.id for r in resps] == [f"ne-{i}" for i in range(5)]


def test_malformed_mid_stream_becomes_error_response(svc):
    payloads = [_ne(0), {"schema": SCHEMA, "kind": "teleport"}, _ne(1),
                {"schema": SCHEMA, "kind": "ne_solve", "costs": []}]
    resps = svc.serve(payloads)
    ok = [r for r in resps if r.ok]
    bad = [r for r in resps if not r.ok]
    assert len(ok) == 2 and len(bad) == 2
    assert {b.error["code"] for b in bad} == {"bad_kind", "bad_value"}
    assert all(b.result is None for b in bad)


def test_drain_on_shutdown(tmp_path):
    """serve() drains everything; close() flushes the sink's JSONL."""
    from repro.obs import EventSink
    path = tmp_path / "serve_events.jsonl"
    with EventSink(path) as sink:
        with SweepService(max_batch=4, sink=sink) as service:
            resps = service.serve([_ne(i) for i in range(3)])
            assert len(resps) == 3 and all(r.ok for r in resps)
            assert service.poll() == []  # nothing left queued
            stats = service.stats()
    assert stats["requests"]["completed"] == 3
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    events = [rec["event"] for rec in lines]
    assert events.count("serve.request") == 3
    assert events.count("serve.complete") == 3
    assert "serve.dispatch" in events
    seqs = [rec["seq"] for rec in lines]
    assert seqs == sorted(seqs)


def test_campaign_request_end_to_end(svc):
    resp, = svc.serve([{"schema": SCHEMA, "kind": "campaign",
                        "p": [0.5, 0.8], "n_clients": 2, "rounds": 2,
                        "seed": 1}])
    assert resp.ok and resp.kind == "campaign"
    res = resp.result
    assert res["rounds"] <= 2 and res["energy_wh"] > 0
    assert 0.0 <= res["participation_rate"] <= 1.0
    assert isinstance(res["converged"], bool)


def test_stats_shape(svc):
    svc.serve([_ne(0)])
    stats = svc.stats()
    assert stats["requests"]["completed"] >= 1
    assert 0.0 <= stats["cache"]["hit_rate"] <= 1.0
    assert 0.0 <= stats["padding_overhead"] < 1.0
    assert stats["latency"]["p50_us"] > 0
    for bucket_stats in stats["compile"].values():
        assert bucket_stats["compile_s"] >= 0
        assert bucket_stats["calls"] >= 1
    # JSON-able end to end (the BENCH artifact path)
    json.dumps(stats)


def test_workload_generator_is_deterministic_and_parseable():
    from repro.serve.workload import synthetic_workload
    w1 = synthetic_workload(50, seed=3)
    w2 = synthetic_workload(50, seed=3)
    assert w1 == w2
    parsed = rejected = 0
    for payload in w1:
        try:
            parse_request(payload)
            parsed += 1
        except RequestError:
            rejected += 1
    assert parsed + rejected == 50 and parsed > rejected
