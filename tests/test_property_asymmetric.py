"""Hypothesis property tests for the batched heterogeneous-NE engine.

Invariants pinned here on random games:

* the batched engine reproduces the seed scalar Gauss-Seidel loop
  (``best_response_dynamics_reference``) on small games;
* every converged scenario in a vmapped batch is a certified NE
  (max profitable unilateral deviation ≤ 1e-4);
* identical-node batches reproduce the symmetric ``solve_symmetric_ne``
  equilibrium;
* participation is weakly decreasing in cost (free-rider stratification).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die, without it
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core as C
from repro.core.asymmetric import (HeterogeneousGame,
                                   best_response_dynamics_reference)
from repro.core.asymmetric_batched import (solve_heterogeneous,
                                           verify_equilibrium_batched)
from repro.core.game import solve_symmetric_ne
from repro.core.utility import UtilityParams
from helpers import assert_heterogeneous_ne

seeds = st.integers(0, 2 ** 31 - 1)


def _dur(n):
    return C.theoretical_duration(n_nodes=n, d_inf=30.0, slope=6.0)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.floats(0.5, 8.0), st.floats(0.1, 1.0), seeds)
def test_batched_matches_scalar_reference(n, cost_hi, gamma, seed):
    rng = np.random.default_rng(seed)
    dur = _dur(n)
    costs = jnp.asarray(rng.uniform(0.1, cost_hi, n))
    gammas = jnp.full((n,), gamma)
    game = HeterogeneousGame(costs=costs, gammas=gammas, dur=dur)
    p_ref, conv_ref, _ = best_response_dynamics_reference(game, damping=0.6,
                                                          max_iters=150)
    sol = solve_heterogeneous(costs, gammas, dur, damping=0.6, max_iters=150)
    p_new, conv_new, _ = sol.single()
    assert conv_new == conv_ref
    if conv_ref:
        np.testing.assert_allclose(np.asarray(p_new), np.asarray(p_ref),
                                   atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.floats(0.1, 1.0), seeds)
def test_vmapped_batch_is_certified(gamma, seed):
    n, b = 6, 8
    rng = np.random.default_rng(seed)
    dur = _dur(n)
    costs = jnp.asarray(rng.uniform(0.1, 10.0, (b, n)))
    gammas = jnp.full((b, n), gamma)
    sol = solve_heterogeneous(costs, gammas, dur, damping=0.6, max_iters=300)
    dev = verify_equilibrium_batched(costs, gammas, dur, sol.p)
    conv = np.asarray(sol.converged)
    assert conv.any()  # γ > 0 keeps best responses continuous: these settle
    assert np.all(np.asarray(dev)[conv] <= 1e-4)


@settings(max_examples=8, deadline=None)
@given(st.floats(0.6, 1.0), st.floats(2.0, 5.0))
def test_identical_nodes_reproduce_symmetric_ne(gamma, cost):
    """In the region where the symmetric NE is stable under Gauss-Seidel
    (γ ≥ 0.6, moderate c), identical nodes land on the symmetric
    ``solve_symmetric_ne`` equilibrium. Outside it the dynamics can settle
    on *certified asymmetric* equilibria among identical nodes — see
    ``test_asymmetric_batched.test_identical_nodes_can_stratify``."""
    n = 20
    dur = _dur(n)
    costs = jnp.full((n,), cost)
    gammas = jnp.full((n,), gamma)
    sol = solve_heterogeneous(costs, gammas, dur, damping=0.6, max_iters=300)
    p, conv, _ = sol.single()
    if not conv:
        return
    assert_heterogeneous_ne(costs, gammas, dur, p, tol=1e-3)
    assert float(jnp.max(p) - jnp.min(p)) < 5e-3  # stays symmetric
    sym = solve_symmetric_ne(UtilityParams(gamma=gamma, cost=cost, n_nodes=n),
                             dur, grid_size=400)
    assert any(abs(float(jnp.mean(p)) - s) < 0.05 for s in sym), (
        float(jnp.mean(p)), sym)


@settings(max_examples=10, deadline=None)
@given(st.floats(0.1, 1.0), st.floats(1.0, 12.0), seeds)
def test_participation_weakly_decreasing_in_cost(gamma, cost_hi, seed):
    n = 8
    rng = np.random.default_rng(seed)
    dur = _dur(n)
    costs = jnp.asarray(np.sort(rng.uniform(0.1, cost_hi, n)))
    gammas = jnp.full((n,), gamma)
    sol = solve_heterogeneous(costs, gammas, dur, damping=0.6, max_iters=300)
    p, conv, _ = sol.single()
    if not conv:
        return
    assert bool(jnp.all(jnp.diff(p) <= 1e-6)), np.asarray(p)
    assert_heterogeneous_ne(costs, gammas, dur, p)
