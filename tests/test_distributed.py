"""Regression tests for the repaired cluster-FedAvg layer.

Both seed bugs are pinned here:

* ``make_cluster_round`` used to call ``opt.init(p)`` inside every round
  and drop the updated state — Adam's moments reset each round. The fix
  threads a per-client stacked ``opt_state`` through and returns it.
* ``fedavg_allreduce_merge`` accumulated every leaf in ``float32``,
  downcasting f64 leaves. The fix accumulates in
  ``promote_types(leaf_dtype, float32)``.

All tests run on a 1-device ``("data",)`` mesh — the clients-per-device
block generalization means one device legitimately hosts all N clients, so
the shard_map path runs in the tier-1 suite (the 8-device versions run in
the multi-device CI job via ``tests/test_sharded_campaign.py``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

import repro.core  # noqa: F401  (enables x64)
from repro.federated.distributed import (fedavg_allreduce_merge,
                                         init_cluster_opt_state,
                                         make_cluster_round)
from repro.federated.server import fedavg_merge
from repro.optim import adamw
from repro.optim.base import apply_updates


def _mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _stacked_updates(g, n):
    return jax.tree.map(lambda x: jnp.stack([x * (i + 1) for i in range(n)]),
                        g)


# ---------------------------------------------------------------------------
# fedavg_allreduce_merge: dtype-preserving accumulation
# ---------------------------------------------------------------------------

def test_merge_f64_leaves_keep_f64_precision():
    """f64 leaves merge at f64 precision — the old f32 downcast loses ~1e-7."""
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (16, 8), jnp.float64)}
    upd = _stacked_updates(g, 4)
    mask = jnp.asarray([1, 0, 1, 1], bool)
    got = fedavg_allreduce_merge(g, upd, mask, _mesh(), ("data",))
    assert got["w"].dtype == jnp.float64
    exact = (upd["w"][0] + upd["w"][2] + upd["w"][3]) / 3.0
    # Exact-mean agreement far below f32 resolution: the old
    # astype(float32) accumulation sat at ~1e-7 here.
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(exact),
                               rtol=0, atol=1e-12)


def test_merge_mixed_dtypes_match_server_reference():
    """f64/f32/bf16 leaves agree with server.fedavg_merge (f64 exceeds it)."""
    key = jax.random.PRNGKey(1)
    g = {"w64": jax.random.normal(key, (8, 4), jnp.float64),
         "w32": jax.random.normal(key, (6,), jnp.float32),
         "b16": jnp.ones((8,), jnp.bfloat16)}
    upd = _stacked_updates(g, 4)
    mask = jnp.asarray([1, 1, 0, 1], bool)
    got = fedavg_allreduce_merge(g, upd, mask, _mesh(), ("data",))
    want = fedavg_merge(g, upd, mask)
    for k in g:
        assert got[k].dtype == g[k].dtype
        np.testing.assert_allclose(
            np.asarray(got[k], np.float64), np.asarray(want[k], np.float64),
            rtol=0, atol=1e-6)
    # f32/bf16 leaves accumulate in f32 like the reference: bitwise on one
    # device (same op order), so the repair changed nothing below f64.
    np.testing.assert_array_equal(np.asarray(got["w32"]),
                                  np.asarray(want["w32"]))
    np.testing.assert_array_equal(np.asarray(got["b16"], np.float32),
                                  np.asarray(want["b16"], np.float32))


def test_merge_empty_round_returns_global():
    g = {"w": jnp.linspace(0.0, 1.0, 10)}
    upd = _stacked_updates(g, 4)
    mask = jnp.zeros((4,), bool)
    got = fedavg_allreduce_merge(g, upd, mask, _mesh(), ("data",))
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(g["w"]))


def test_merge_rejects_indivisible_client_axis():
    class FakeMesh:
        axis_names = ("data",)
        shape = {"data": 2}

    g = {"w": jnp.zeros((3,))}
    upd = _stacked_updates(g, 3)
    with pytest.raises(ValueError, match="split evenly"):
        fedavg_allreduce_merge(g, upd, jnp.ones((3,), bool),
                               FakeMesh(), ("data",))


# ---------------------------------------------------------------------------
# make_cluster_round: optimizer state threads across rounds
# ---------------------------------------------------------------------------

def _quadratic_task(n_clients, rounds, seed=0):
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (5, 3), jnp.float64)}

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    rng = np.random.default_rng(seed + 1)
    batches = [{"x": jnp.asarray(rng.normal(size=(n_clients, 8, 5))),
                "y": jnp.asarray(rng.normal(size=(n_clients, 8, 3)))}
               for _ in range(rounds)]
    masks = [jnp.asarray(rng.random(n_clients) < 0.8, bool)
             for _ in range(rounds)]
    return params, loss_fn, batches, masks


def test_cluster_round_threads_adam_state_3_rounds():
    """3 rounds of the cluster engine == explicit sequential per-client Adam.

    The sequential reference keeps one persistent Adam state per client and
    re-initializes nothing — exactly what the seed engine failed to do.
    """
    n, rounds = 4, 3
    params, loss_fn, batches, masks = _quadratic_task(n, rounds)
    opt = adamw(1e-2)
    round_fn = make_cluster_round(loss_fn, opt, _mesh())

    p_eng = params
    st_eng = init_cluster_opt_state(opt, params, n)
    for b, m in zip(batches, masks):
        p_eng, st_eng, losses = round_fn(p_eng, st_eng, b, m)
        assert losses.shape == (n,)

    p_ref = params
    states = [opt.init(params) for _ in range(n)]
    for b, m in zip(batches, masks):
        client_params = []
        for i in range(n):
            bi = jax.tree.map(lambda leaf: leaf[i], b)
            _, grads = jax.value_and_grad(loss_fn)(p_ref, bi)
            updates, states[i] = opt.update(grads, states[i], p_ref)
            client_params.append(apply_updates(p_ref, updates))
        # Exact f64 masked mean (server.fedavg_merge accumulates in f32,
        # which the repaired f64 merge legitimately out-resolves).
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *client_params)
        mf = jnp.asarray(m, jnp.float64)
        p_ref = jax.tree.map(
            lambda g_leaf, c: jnp.where(
                jnp.sum(mf) > 0,
                jnp.tensordot(mf, c, axes=1) / jnp.maximum(jnp.sum(mf), 1e-9),
                g_leaf),
            p_ref, stacked)

    np.testing.assert_allclose(np.asarray(p_eng["w"]), np.asarray(p_ref["w"]),
                               rtol=0, atol=1e-12)
    # The returned state really advanced: step counters hit `rounds` and the
    # moments moved off zero (the seed bug left both at their init values).
    stepped = [leaf for path, leaf in
               jax.tree_util.tree_leaves_with_path(st_eng)
               if "step" in str(path)]
    assert stepped and all(int(s[0]) == rounds for s in stepped)


def test_cluster_round_state_reset_regression():
    """Re-init-ing the state each round (the seed bug) changes the result."""
    n, rounds = 4, 3
    params, loss_fn, batches, masks = _quadratic_task(n, rounds, seed=7)
    opt = adamw(1e-2)
    round_fn = make_cluster_round(loss_fn, opt, _mesh())

    p_fixed = params
    st = init_cluster_opt_state(opt, params, n)
    for b, m in zip(batches, masks):
        p_fixed, st, _ = round_fn(p_fixed, st, b, m)

    p_buggy = params
    for b, m in zip(batches, masks):
        st0 = init_cluster_opt_state(opt, params, n)   # the seed behaviour
        p_buggy, _, _ = round_fn(p_buggy, st0, b, m)

    assert float(jnp.max(jnp.abs(p_fixed["w"] - p_buggy["w"]))) > 1e-6
