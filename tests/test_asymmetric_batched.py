"""Batched heterogeneous-equilibrium engine vs the scalar seed oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.asymmetric import (HeterogeneousGame,
                                   best_response_dynamics,
                                   best_response_dynamics_reference,
                                   planner_coordinate_descent,
                                   verify_equilibrium,
                                   verify_equilibrium_reference)
from repro.core.asymmetric_batched import (P_MIN, best_response_given_slope,
                                           planner_batched, poa_report,
                                           social_cost_batched,
                                           solve_heterogeneous,
                                           verify_equilibrium_batched)
from repro.core.poibin import (poibin_convolve, poibin_pmf_loo,
                               poibin_pmf_recursive)
from helpers import assert_heterogeneous_ne

N = 10


@pytest.fixture(scope="module")
def dur():
    return C.theoretical_duration(n_nodes=N, d_inf=35.0, slope=8.0)


@pytest.fixture(scope="module")
def game(dur):
    costs = jnp.asarray(np.linspace(0.5, 12.0, N))
    gammas = jnp.full((N,), 0.6)
    return HeterogeneousGame(costs=costs, gammas=gammas, dur=dur)


# ---- engine vs the eager seed loop ----------------------------------------

def test_engine_matches_reference_loop(game):
    p_ref, conv_ref, it_ref = best_response_dynamics_reference(game,
                                                               damping=0.6)
    p_new, conv_new, it_new = best_response_dynamics(game, damping=0.6)
    assert conv_ref and conv_new
    assert it_ref == it_new
    np.testing.assert_allclose(np.asarray(p_new), np.asarray(p_ref),
                               atol=1e-12)


def test_verify_matches_reference(game):
    p, conv, _ = best_response_dynamics(game, damping=0.6)
    assert conv
    dev_ref = verify_equilibrium_reference(game, p)
    dev_new = verify_equilibrium(game, p)
    assert dev_new == pytest.approx(dev_ref, abs=1e-9)
    assert dev_new <= 1e-4


def test_planner_matches_reference_fixed_point(game):
    """The jitted corner-selection planner lands on the same profile as the
    scalar grid-argmin (the social cost is linear per coordinate)."""
    p, conv, _ = best_response_dynamics(game, damping=0.6)
    assert conv
    p_opt = planner_coordinate_descent(game, p)
    cost_opt = float(game.social_cost(p_opt))
    assert cost_opt <= float(game.social_cost(p)) + 1e-9
    # every coordinate is a corner
    opt = np.asarray(p_opt)
    assert np.all((np.abs(opt - P_MIN) < 1e-12) | (np.abs(opt - 1.0) < 1e-12))


# ---- batching --------------------------------------------------------------

def test_vmapped_batch_all_certified(dur):
    rng = np.random.default_rng(3)
    b = 16
    costs = jnp.asarray(rng.uniform(0.5, 12.0, (b, N)))
    gammas = jnp.asarray(rng.uniform(0.2, 1.0, (b, N)))
    sol = solve_heterogeneous(costs, gammas, dur, damping=0.6, max_iters=300)
    assert bool(jnp.all(sol.converged))
    dev = verify_equilibrium_batched(costs, gammas, dur, sol.p)
    assert float(jnp.max(dev)) <= 1e-4
    # batch rows are independent: row i solved alone gives the same profile
    one = solve_heterogeneous(costs[3], gammas[3], dur, damping=0.6,
                              max_iters=300)
    np.testing.assert_allclose(np.asarray(one.p[0]), np.asarray(sol.p[3]),
                               atol=1e-12)


def test_poa_report_invariants(dur):
    rng = np.random.default_rng(4)
    b = 8
    costs = jnp.asarray(rng.uniform(0.5, 10.0, (b, N)))
    gammas = jnp.asarray(rng.uniform(0.3, 0.9, (b, N)))
    rep = poa_report(costs, gammas, dur, damping=0.6, max_iters=300)
    assert bool(jnp.all(rep.solution.converged))
    assert float(jnp.max(rep.deviation)) <= 1e-4
    # planner descent from the NE can only lower the cost → PoA ≥ 1
    assert bool(jnp.all(rep.poa >= 1.0 - 1e-9))
    np.testing.assert_allclose(
        np.asarray(rep.ne_cost),
        np.asarray(social_cost_batched(costs, dur, rep.solution.p)))


def test_batched_duration_tables(dur):
    """A (B, N+1) stack of per-scenario duration tables vmaps through."""
    d_tab = dur.table()
    tabs = jnp.stack([d_tab, d_tab * 1.5])
    costs = jnp.asarray(np.linspace(0.5, 8.0, N))
    sol = solve_heterogeneous(jnp.stack([costs, costs]),
                              jnp.full((2, N), 0.6), tabs, damping=0.6)
    assert bool(jnp.all(sol.converged))
    base = solve_heterogeneous(costs, jnp.full((N,), 0.6), d_tab, damping=0.6)
    np.testing.assert_allclose(np.asarray(sol.p[0]), np.asarray(base.p[0]),
                               atol=1e-12)
    # scaling d(k) raises the stakes of coordination → some profile change
    assert float(jnp.max(jnp.abs(sol.p[1] - sol.p[0]))) > 1e-6


def test_shape_validation(dur):
    with pytest.raises(ValueError):
        solve_heterogeneous(jnp.ones((2, N)), jnp.ones((3, N)), dur)
    with pytest.raises(ValueError):
        solve_heterogeneous(jnp.ones((N,)), jnp.ones((N,)),
                            jnp.ones((N + 5,)))


# ---- free-rider stratification & helper certification ----------------------

def test_participation_monotone_in_cost_batched(dur):
    rng = np.random.default_rng(5)
    costs = jnp.asarray(np.sort(rng.uniform(0.5, 12.0, (6, N)), axis=1))
    gammas = jnp.full((6, N), 0.6)
    sol = solve_heterogeneous(costs, gammas, dur, damping=0.6, max_iters=300)
    assert bool(jnp.all(sol.converged))
    assert bool(jnp.all(jnp.diff(sol.p, axis=1) <= 1e-6))
    for i in range(6):
        assert_heterogeneous_ne(costs[i], gammas[i], dur, sol.p[i])


def test_identical_nodes_can_stratify(dur):
    """Beyond-paper observation: for identical nodes outside the symmetric
    equilibrium's Gauss-Seidel stability region (here: weak incentive, high
    cost), the dynamics settle on a *certified asymmetric* NE — free-rider
    stratification emerges spontaneously without any cost heterogeneity."""
    costs = jnp.full((N,), 6.0)
    gammas = jnp.full((N,), 0.2)
    sol = solve_heterogeneous(costs, gammas, dur, damping=0.6, max_iters=300)
    p, conv, _ = sol.single()
    assert conv
    assert float(jnp.max(p) - jnp.min(p)) > 0.3  # genuinely stratified
    assert_heterogeneous_ne(costs, gammas, dur, p)


# ---- best-response closed form (division-guard regression) -----------------

def test_best_response_a_to_zero_limit():
    """Regression: the a → 0⁻ limit of the interior BR is p = 1, and the
    two-sided division guard keeps a = 0 exactly on the same value (the old
    one-sided `where(a < 0, a, -1e-9)` pushed a huge 2e9·γ `prod` through
    the a ≥ 0 branch)."""
    gamma = jnp.asarray(0.6)
    cost = jnp.asarray(0.0)
    # slope == cost → a == 0 exactly
    assert float(best_response_given_slope(jnp.asarray(0.0), cost,
                                           gamma)) == 1.0
    # approach from below: BR must be continuous into the limit
    for a in [-1e-12, -1e-9, -1e-6]:
        br = float(best_response_given_slope(jnp.asarray(a), cost, gamma))
        assert br == pytest.approx(1.0, abs=1e-3), a
    # and well inside the interior branch the stationary point is exact:
    # a = -2γ/(p(2-p)) at p = 0.5 → p* recovers 0.5
    a = -2.0 * 0.6 / (0.5 * 1.5)
    br = float(best_response_given_slope(jnp.asarray(a), cost, gamma))
    assert br == pytest.approx(0.5, abs=1e-12)


def test_best_response_gamma_zero_bang_bang():
    cost = jnp.asarray(0.0)
    zero = jnp.asarray(0.0)
    assert float(best_response_given_slope(jnp.asarray(2.0), cost,
                                           zero)) == 1.0
    assert float(best_response_given_slope(jnp.asarray(-2.0), cost,
                                           zero)) == P_MIN
    # exact indifference resolves to P_MIN like the scalar seed
    assert float(best_response_given_slope(jnp.asarray(0.0), cost,
                                           zero)) == P_MIN


def test_best_response_is_finite_everywhere():
    slopes = jnp.asarray([-1e6, -10.0, -1e-9, 0.0, 1e-9, 10.0, 1e6])
    for g in [0.0, 1e-9, 0.6, 5.0]:
        for c in [0.0, 2.0, 60.0]:
            br = best_response_given_slope(slopes, jnp.asarray(c),
                                           jnp.asarray(g))
            assert bool(jnp.all(jnp.isfinite(br)))
            assert bool(jnp.all((br >= P_MIN) & (br <= 1.0)))


# ---- leave-one-out deconvolution ------------------------------------------

def test_loo_deconvolution_inverts_convolution():
    rng = np.random.default_rng(6)
    p = jnp.asarray(rng.uniform(0, 1, 17))
    f = poibin_pmf_recursive(p)
    for i in [0, 5, 16]:
        loo = poibin_pmf_loo(f, p[i])
        rest = poibin_pmf_recursive(jnp.delete(p, i))
        np.testing.assert_allclose(np.asarray(loo[:-1]), np.asarray(rest),
                                   atol=1e-12)
        np.testing.assert_allclose(np.asarray(poibin_convolve(loo, p[i])),
                                   np.asarray(f), atol=1e-12)


def test_loo_deconvolution_corners():
    p = jnp.asarray([0.0, 1.0, 0.5, 0.25])
    f = poibin_pmf_recursive(p)
    for i in range(4):
        loo = poibin_pmf_loo(f, p[i])
        rest = poibin_pmf_recursive(jnp.delete(p, i))
        np.testing.assert_allclose(np.asarray(loo[:-1]), np.asarray(rest),
                                   atol=1e-14)
