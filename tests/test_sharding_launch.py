"""Sharding rules engine + dry-run plumbing + multi-device numerics.

The multi-device test spawns a subprocess with
``--xla_force_host_platform_device_count=8`` (jax locks device count at
first init, and the main test process must keep seeing 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.sharding import (DECODE_RULES, TRAIN_RULES, Rules,
                                   resolve_one, resolve_specs)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh_1d():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_resolve_divisible_and_relaxed():
    mesh = _mesh_1d()  # sizes 1 -> everything replicates but records nothing
    rules = Rules(table=dict(TRAIN_RULES.table))
    spec = resolve_one((1024, 16, 64), ("embed", "heads", "head"), mesh, rules)
    assert spec == P()


def test_resolve_uses_first_divisible_candidate():
    # fake 4x2 mesh from 1 device repeated is illegal; instead test the
    # divisibility logic through a pure-Python mesh stub
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    rules = Rules(table={"heads": ["model", None], "batch": [("data",)]})
    spec = resolve_one((6, 8), ("heads", "batch"), FakeMesh(), rules)
    # heads=6 divisible by model=2 -> sharded; batch=8 by data=4 -> sharded
    assert spec == P("model", "data")
    spec2 = resolve_one((5, 7), ("heads", "batch"), FakeMesh(), rules)
    assert spec2 == P()
    assert any("heads" in r for r in rules.relaxations)


def test_no_mesh_axis_reuse_within_array():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}

    rules = Rules(table={"a": ["model"], "b": ["model"]})
    spec = resolve_one((8, 8), ("a", "b"), FakeMesh(), rules)
    assert spec == P("model")  # second dim must not reuse 'model'


def test_pod_axis_filtered_on_single_pod():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = Rules(table={"batch": [("pod", "data")]})
    spec = resolve_one((256, 128), ("batch", "seq"), FakeMesh(), rules)
    assert spec == P("data")


def test_dryrun_artifacts_exist_and_pass():
    """The background sweep must have produced 78 OK artifacts (39 pairs x
    2 meshes). This asserts the committed artifacts, not a recompile."""
    art_dir = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(art_dir):
        pytest.skip("dry-run artifacts not generated yet")
    files = [f for f in os.listdir(art_dir) if f.endswith(".json")]
    assert len(files) >= 78, f"expected >= 78 artifacts, got {len(files)}"
    bad = []
    for f in files:
        with open(os.path.join(art_dir, f)) as fh:
            d = json.load(fh)
        if not d.get("ok"):
            bad.append(f)
    assert not bad, f"failed dry-runs: {bad}"


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = textwrap.dedent("""
      %all-reduce.1 = f32[128,256] all-reduce(f32[128,256] %x), replica_groups={}
      %ag = bf16[16,512] all-gather(bf16[16,64] %y), dimensions={1}
      %rs.2 = f32[64] reduce-scatter(f32[512] %z), dimensions={0}
      %add.3 = f32[128] add(f32[128] %a, f32[128] %b)
    """)
    res = parse_collectives(hlo)
    assert res["all-reduce"]["count"] == 1
    assert res["all-reduce"]["bytes"] == 128 * 256 * 4
    assert res["all-gather"]["count"] == 1
    assert res["all-gather"]["bytes"] == 16 * 512 * 2
    assert res["reduce-scatter"]["count"] == 1
    assert res["reduce-scatter"]["bytes"] == 64 * 4


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """pjit train step on a 4x2 CPU mesh == single-device numerics."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import ARCHITECTURES
        from repro.models.registry import get_model
        from repro.launch.sharding import TRAIN_RULES, Rules, resolve_specs
        from repro.launch.dryrun import make_train_step, _build_param_specs
        from repro.optim import adamw

        cfg = ARCHITECTURES["stablelm-3b"].reduced()
        api = get_model(cfg)
        params, specs = api.init(jax.random.PRNGKey(0))
        opt = adamw(1e-3)
        opt_state = opt.init(params)
        tk = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
        batch = {"tokens": tk, "labels": jnp.roll(tk, -1, 1)}
        step = make_train_step(api, opt)

        # single device reference
        ref_params, ref_opt, ref_loss = jax.jit(step)(params, opt_state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = Rules(table=dict(TRAIN_RULES.table))
        param_sh = resolve_specs(params, specs, mesh, rules)
        opt_sh = {"m": param_sh, "v": param_sh,
                  "step": NamedSharding(mesh, P())}
        batch_sh = {k: NamedSharding(mesh, P("data")) for k in batch}
        jitted = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh,
                                        NamedSharding(mesh, P())))
        with mesh:
            sh_params, sh_opt, sh_loss = jitted(params, opt_state, batch)
        np.testing.assert_allclose(float(sh_loss), float(ref_loss),
                                   rtol=2e-4, atol=2e-4)
        err = max(float(jnp.abs(a.astype(jnp.float32) -
                                b.astype(jnp.float32)).max())
                  for a, b in zip(jax.tree.leaves(ref_params),
                                  jax.tree.leaves(sh_params)))
        assert err < 3e-2, err   # bf16 params; collective reduction order
        print("SHARDED_OK", float(sh_loss), err)
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout


@pytest.mark.slow
def test_shard_map_fedavg_merge_matches_reference():
    """shard_map psum merge across an 8-way data axis == the dense-tree
    reference merge (subprocess: needs 8 CPU devices)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.federated.distributed import fedavg_allreduce_merge
        from repro.federated.server import fedavg_merge

        mesh = jax.make_mesh((8,), ("data",))
        key = jax.random.PRNGKey(0)
        g = {"w": jax.random.normal(key, (16, 8)),
             "b": jnp.ones((8,), jnp.bfloat16)}
        upd = jax.tree.map(
            lambda x: jnp.stack([x * (i + 1) for i in range(8)]), g)
        for mask_bits in ([1,0,1,0,1,1,0,1], [0]*8, [1]*8):
            mask = jnp.asarray(mask_bits, bool)
            want = fedavg_merge(g, upd, mask)
            with mesh:
                got = fedavg_allreduce_merge(g, upd, mask, mesh, ("data",))
            for k in g:
                np.testing.assert_allclose(
                    np.asarray(got[k], np.float32),
                    np.asarray(want[k], np.float32), atol=2e-2)
        print("SHARDMAP_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDMAP_OK" in out.stdout
