"""Shared pytest configuration.

Registers deterministic hypothesis profiles so the property suite behaves
the same on every CI run:

* ``ci`` — derandomized (fixed example database-free seed), CI-sized
  ``max_examples``, no deadline (JAX compile times would trip it). Loaded
  automatically when ``$CI`` is set; CI also pins it explicitly via
  ``HYPOTHESIS_PROFILE=ci``.
* ``dev`` — the local default: random seeds, same deadline settings.

Note: per-test ``@settings(...)`` decorators override only the keys they
set; ``derandomize`` comes from the active profile either way.
"""
from __future__ import annotations

import os

try:
    from hypothesis import settings
except ImportError:  # hypothesis is an optional test dep (importorskip)
    pass
else:
    settings.register_profile("ci", max_examples=25, derandomize=True,
                              deadline=None, print_blob=True)
    settings.register_profile("dev", deadline=None)
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:
        settings.load_profile(_profile)
    elif os.environ.get("CI"):
        settings.load_profile("ci")
    else:
        settings.load_profile("dev")
