"""Poisson-Binomial pmf (paper eq. 9) and expected duration (eq. 8)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401  (enables x64)
from repro.core.poibin import (expected_duration, poibin_mean, poibin_pmf,
                               poibin_pmf_recursive, symmetric_pmf)


def brute_force_pmf(p):
    n = len(p)
    pmf = np.zeros(n + 1)
    for bits in itertools.product([0, 1], repeat=n):
        prob = 1.0
        for b, pi in zip(bits, p):
            prob *= pi if b else (1 - pi)
        pmf[sum(bits)] += prob
    return pmf


@pytest.mark.parametrize("p", [
    [0.5], [0.2, 0.8], [0.1, 0.5, 0.9], [0.3, 0.3, 0.3, 0.3],
    [0.05, 0.2, 0.45, 0.7, 0.99],
])
def test_pmf_matches_brute_force(p):
    got = np.asarray(poibin_pmf(jnp.asarray(p)))
    want = brute_force_pmf(p)
    np.testing.assert_allclose(got, want, atol=1e-12)


def test_pmf_matches_recursion_large_n():
    rng = np.random.default_rng(0)
    p = rng.uniform(0.01, 0.99, size=50)
    dft = np.asarray(poibin_pmf(jnp.asarray(p)))
    rec = np.asarray(poibin_pmf_recursive(jnp.asarray(p)))
    np.testing.assert_allclose(dft, rec, atol=1e-10)


def test_pmf_normalizes_and_mean():
    p = jnp.asarray([0.12, 0.5, 0.77, 0.3, 0.9, 0.05])
    pmf = poibin_pmf(p)
    assert float(jnp.sum(pmf)) == pytest.approx(1.0, abs=1e-12)
    mean = float(jnp.sum(pmf * jnp.arange(7)))
    assert mean == pytest.approx(float(poibin_mean(p)), abs=1e-10)


def test_symmetric_is_binomial():
    from scipy import stats
    n, p = 50, 0.37
    got = np.asarray(symmetric_pmf(jnp.asarray(p), n))
    want = stats.binom.pmf(np.arange(n + 1), n, p)
    np.testing.assert_allclose(got, want, atol=1e-10)


def test_expected_duration_monte_carlo():
    rng = np.random.default_rng(1)
    p = jnp.asarray(rng.uniform(0.1, 0.9, size=12))
    d_of_k = jnp.asarray(100.0 / (1.0 + np.arange(13)))
    analytic = float(expected_duration(p, d_of_k))
    draws = rng.random((200_000, 12)) < np.asarray(p)
    k = draws.sum(axis=1)
    mc = float(np.mean(np.asarray(d_of_k)[k]))
    assert analytic == pytest.approx(mc, rel=2e-2)


def test_gradient_flows_through_pmf():
    def f(p):
        return expected_duration(p, jnp.arange(4.0))

    g = jax.grad(f)(jnp.asarray([0.3, 0.5, 0.7]))
    assert np.all(np.isfinite(np.asarray(g)))
    # E[D] = E[k] here, so gradient wrt each p_i is exactly 1
    np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-8)
