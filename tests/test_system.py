"""End-to-end behaviour tests for the paper's system.

The full pipeline: game solve -> controller picks p -> FL simulation runs
under that p with energy metering -> distributed solution costs more energy
than the centralized one (the paper's headline), and the AoI incentive
closes most of the gap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.controller import ParticipationController, RooflineClock
from repro.core.duration import paper_duration_model
from repro.core.poibin import expected_duration
from repro.core.energy import expected_task_energy
from repro.federated.simulation import FLConfig, run_simulation
from repro.data.synthetic import SyntheticCifar
from repro.optim import sgd


@pytest.fixture(scope="module")
def dur():
    return paper_duration_model()


def _expected_energy_wh(p: float, ctrl: ParticipationController) -> float:
    n = ctrl.n_nodes
    ed = expected_duration(jnp.full((n,), p), ctrl.duration_model.table())
    return float(expected_task_energy(jnp.full((n,), p), ed,
                                      ctrl.energy_params)) / 3600.0


def test_tragedy_of_the_commons_energy_gap(dur):
    """NE (selfish) participation wastes energy vs the centralized optimum —
    the paper's core claim, evaluated through the full model stack."""
    common = dict(n_nodes=50, gamma=0.0, cost=3.0)
    ne = ParticipationController(mode="ne_worst", **common)
    opt = ParticipationController(mode="centralized", **common)
    p_ne, p_opt = ne.participation_probability(), \
        opt.participation_probability()
    assert p_ne < p_opt
    e_ne, e_opt = _expected_energy_wh(p_ne, ne), _expected_energy_wh(p_opt, opt)
    assert e_ne > e_opt          # selfishness costs energy
    # paper: >= 28% loss at the no-incentive NE; we assert a positive gap
    assert (e_ne - e_opt) / e_opt > 0.05


def test_aoi_incentive_recovers_most_of_the_gap(dur):
    c = 3.0
    ne0 = ParticipationController(n_nodes=50, gamma=0.0, cost=c,
                                  mode="ne_worst")
    ne1 = ParticipationController(n_nodes=50, gamma=0.6, cost=c,
                                  mode="ne_worst")
    opt = ParticipationController(n_nodes=50, gamma=0.0, cost=c,
                                  mode="centralized")
    e0 = _expected_energy_wh(ne0.participation_probability(), ne0)
    e1 = _expected_energy_wh(ne1.participation_probability(), ne1)
    eo = _expected_energy_wh(opt.participation_probability(), opt)
    assert e1 < e0               # incentive reduces waste
    assert (e1 - eo) < (e0 - eo)


def test_controller_driven_simulation(dur):
    """The controller's p drives an actual FL run; realized participation
    tracks the game's solution."""
    data = SyntheticCifar(noise=2.5)

    def init_params(key):
        k1, k2 = jax.random.split(key)
        d = 32 * 32 * 3
        return {"w1": jax.random.normal(k1, (d, 32)) * d ** -0.5,
                "b1": jnp.zeros(32),
                "w2": jax.random.normal(k2, (32, 10)) * 32 ** -0.5,
                "b2": jnp.zeros(10)}

    def fwd(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss_fn(p, b):
        lp = jax.nn.log_softmax(fwd(p, b["images"]))
        return -jnp.mean(jnp.take_along_axis(lp, b["labels"][:, None], 1))

    def eval_fn(p, b):
        return jnp.mean(jnp.argmax(fwd(p, b["images"]), -1) == b["labels"])

    def client_data(cid, rnd, n, steps):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(0), cid), rnd)
        return jax.vmap(lambda k: data.batch(k, n))(
            jax.random.split(key, steps))

    ctrl = ParticipationController(n_nodes=50, gamma=0.6, cost=2.0, mode="ne")
    p = ctrl.participation_probability()
    fl = FLConfig(n_clients=50, local_steps=2, batch_per_client=8,
                  max_rounds=30, target_acc=0.73)
    res = run_simulation(fl, init_params, loss_fn, eval_fn, client_data,
                         data.val_set(256), sgd(0.05), p=p, controller=ctrl)
    assert res.converged
    assert abs(res.participation_rate - p) < 0.15


def test_roofline_clock_feeds_energy_model():
    """Datacenter path: dry-run FLOPs -> T_train -> controller energy."""
    clock = RooflineClock(flops_per_step=5e15, hbm_bytes_per_step=2e13,
                          steps_per_round=10, chips=256)
    assert clock.t_train_s > 0
    ctrl = ParticipationController(n_nodes=50, gamma=0.0, cost=1.0)
    ctrl2 = ctrl.with_roofline(clock)
    assert ctrl2.energy_params.p_hw_w == pytest.approx(256 * 170.0)
    assert ctrl2.energy_params.t_train_s <= ctrl2.energy_params.t_round_s
    # energy ordering still holds
    assert ctrl2.energy_params.e_participant_j > ctrl2.energy_params.e_idle_j


def test_paper_constants_are_wired():
    """Table I constants flow through the stack unchanged."""
    from repro.core.comm80211ax import PAPER_COMM
    from repro.core.energy import EnergyParams, PAPER_MODEL_BYTES
    assert PAPER_COMM.tx_power_dbm == 9.0
    assert PAPER_COMM.n_subcarriers == 234
    assert PAPER_COMM.contention_window == 15
    ep = EnergyParams()
    assert ep.p_idle_w == 96.85
    assert ep.t_round_s == 10.0
    assert PAPER_MODEL_BYTES == pytest.approx(44.73e6)
    assert C.PAPER_N_CLIENTS == 50
