"""Energy model (eqs. 1-7), 802.11ax airtime, AoI (eq. 10)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401
from repro.core.aoi import expected_aoi, simulate_aoi
from repro.core.comm80211ax import (PAPER_COMM, airtime_model,
                                    airtime_model_batched)
from repro.core.energy import (EnergyLedger, EnergyParams, PAPER_MODEL_BYTES,
                               calibrate_from_table, channel_energy_rates,
                               expected_round_energy, round_energy,
                               task_energy)


def test_airtime_scales_with_payload():
    a1 = airtime_model(1e6)
    a2 = airtime_model(2e6)
    assert a2["t_tx_s"] > a1["t_tx_s"]
    # asymptotically linear
    assert a2["t_data_s"] == pytest.approx(2 * a1["t_data_s"], rel=1e-3)


def test_airtime_reasonable_goodput():
    """20 MHz 802.11ax single stream: goodput well below PHY peak, above 50."""
    a = airtime_model(PAPER_MODEL_BYTES)
    assert 50 < a["goodput_mbps"] < 300
    # uploading 44.73 MB takes seconds, not ms or hours
    assert 1.0 < a["t_tx_s"] < 30.0


def test_tx_power_dbm_conversion():
    a = airtime_model(1e6, PAPER_COMM)
    assert a["tx_power_w"] == pytest.approx(10 ** (9 / 10) * 1e-3)


def test_round_energy_decomposition():
    ep = EnergyParams()
    n = 10
    mask = jnp.asarray([1, 0, 1, 0, 0, 0, 0, 0, 0, 0], bool)
    e = float(round_energy(mask, ep))
    want = 2 * ep.e_participant_j + 8 * ep.e_idle_j
    assert e == pytest.approx(want)


def test_expected_round_energy_is_linear_in_p():
    ep = EnergyParams()
    p = jnp.full((50,), 0.5)
    mid = float(expected_round_energy(p, ep))
    lo = float(expected_round_energy(jnp.zeros(50), ep))
    hi = float(expected_round_energy(jnp.ones(50), ep))
    assert mid == pytest.approx(0.5 * (lo + hi), rel=1e-12)


def test_participant_energy_exceeds_idle():
    ep = EnergyParams()
    assert ep.e_participant_j > ep.e_idle_j
    assert ep.e_tx_j > 0


def test_ledger_accumulates():
    ep = EnergyParams()
    led = EnergyLedger.create(4)
    m1 = jnp.asarray([1, 1, 0, 0], bool)
    m2 = jnp.asarray([1, 0, 0, 0], bool)
    led = led.record_round(m1, ep).record_round(m2, ep)
    assert int(led.rounds) == 2
    np.testing.assert_array_equal(np.asarray(led.participation_counts),
                                  [2, 1, 0, 0])
    want = float(round_energy(m1, ep) + round_energy(m2, ep))
    assert float(led.total_j) == pytest.approx(want)


def test_calibration_matches_table_scale():
    """Calibrated params reproduce Table II(b) energies within ~12%."""
    from repro.core.duration import PAPER_TABLE_II
    ep = calibrate_from_table()
    assert 100 < ep.p_hw_w < 500     # a plausible GPU-node training power
    tab = PAPER_TABLE_II
    pred = tab[:, 1] * (50 * ep.e_idle_j
                        + 50 * tab[:, 0] * (ep.e_participant_j - ep.e_idle_j)
                        ) / 3600.0
    rel = np.abs(pred - tab[:, 3]) / tab[:, 3]
    assert float(np.median(rel)) < 0.12


def test_task_energy_sums_rounds():
    e = task_energy(jnp.asarray([1.0, 2.0, 3.5]))
    assert float(e) == pytest.approx(6.5)


def _random_masks(key, rounds, n):
    return jax.random.bernoulli(key, 0.4, (rounds, n))


def test_ledger_wh_additivity_over_round_batches():
    """Ledger(A ++ B) == Ledger(A) continued with B, and its totals are the
    sums of two fresh per-batch ledgers — Wh accounting is associative."""
    ep = EnergyParams()
    n = 6
    ma = _random_masks(jax.random.PRNGKey(0), 5, n)
    mb = _random_masks(jax.random.PRNGKey(1), 7, n)

    def fold(led, masks):
        for m in masks:
            led = led.record_round(m, ep)
        return led

    joint = fold(EnergyLedger.create(n), jnp.concatenate([ma, mb]))
    contin = fold(fold(EnergyLedger.create(n), ma), mb)
    np.testing.assert_allclose(np.asarray(joint.per_node_j),
                               np.asarray(contin.per_node_j))
    assert int(joint.rounds) == int(contin.rounds) == 12
    led_a = fold(EnergyLedger.create(n), ma)
    led_b = fold(EnergyLedger.create(n), mb)
    assert float(joint.total_wh) == pytest.approx(
        float(led_a.total_wh) + float(led_b.total_wh), rel=1e-12)
    np.testing.assert_array_equal(
        np.asarray(joint.participation_counts),
        np.asarray(led_a.participation_counts
                   + led_b.participation_counts))


def test_ledger_participant_idle_split_matches_mask_sums():
    """per_node_j decomposes exactly into counts·E_part + idle·E_idle."""
    ep = EnergyParams()
    n = 9
    masks = _random_masks(jax.random.PRNGKey(3), 11, n)
    led = EnergyLedger.create(n)
    for m in masks:
        led = led.record_round(m, ep)
    counts = np.asarray(masks).sum(axis=0)
    np.testing.assert_array_equal(np.asarray(led.participation_counts),
                                  counts)
    want = counts * ep.e_participant_j + (11 - counts) * ep.e_idle_j
    np.testing.assert_allclose(np.asarray(led.per_node_j), want, rtol=1e-12)


def test_ledger_works_as_scan_carry():
    """The ledger is a pytree: jitted lax.scan over masks == eager fold, and
    flatten/unflatten round-trips (the campaign engine's carry contract)."""
    ep = EnergyParams()
    n = 5
    masks = _random_masks(jax.random.PRNGKey(4), 8, n)

    @jax.jit
    def scan_ledger(masks):
        def step(led, mask):
            return led.record_round_j(mask, ep.e_participant_j,
                                      ep.e_idle_j), led.rounds
        return jax.lax.scan(step, EnergyLedger.create(n), masks)

    scanned, round_trace = scan_ledger(masks)
    eager = EnergyLedger.create(n)
    for m in masks:
        eager = eager.record_round(m, ep)
    np.testing.assert_allclose(np.asarray(scanned.per_node_j),
                               np.asarray(eager.per_node_j))
    assert int(scanned.rounds) == int(eager.rounds) == 8
    np.testing.assert_array_equal(np.asarray(round_trace), np.arange(8))

    leaves, treedef = jax.tree.flatten(scanned)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    for a, b in zip(jax.tree.leaves(rebuilt), jax.tree.leaves(scanned)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(rebuilt.total_wh) == float(scanned.total_wh)


# --- batched airtime vs the scalar oracle ---------------------------------

# MCS ladder (BPSK 1/2 → 1024-QAM 5/6 → the paper's 10-bit default) ×
# payloads hitting every A-MPDU fragmentation branch: empty, sub-symbol,
# sub-A-MPDU, one-bit-under, *exact* multiples (rem == 0 — the float divmod
# remainder trap), just-over, and the paper's ResNet-18 update.
_MCS_GRID = [1.0, 2.0, 4.0, 25.0 / 3.0, 10.0]
_MPDU_BYTES = PAPER_COMM.a_mpdu_max_bits / 8.0
_PAYLOAD_GRID = [0.0, 1.0, 100.0, _MPDU_BYTES - 1, _MPDU_BYTES,
                 _MPDU_BYTES + 1, 2 * _MPDU_BYTES, PAPER_MODEL_BYTES]
_AIRTIME_KEYS = ["t_tx_s", "t_data_s", "t_overhead_s", "n_ampdu",
                 "goodput_mbps", "tx_power_w", "e_tx_wh"]


def test_airtime_batched_matches_scalar_oracle_elementwise():
    """airtime_model_batched == the verbatim scalar oracle, ≤ 1e-12 rel,
    on the full MCS × payload grid evaluated as one batched call."""
    for mcs in _MCS_GRID:
        params = dataclasses.replace(PAPER_COMM, bits_per_symbol_per_sc=mcs)
        batched = airtime_model_batched(
            jnp.asarray(_PAYLOAD_GRID), jnp.asarray(mcs))
        for j, payload in enumerate(_PAYLOAD_GRID):
            ref = airtime_model(payload, params)
            for k in _AIRTIME_KEYS:
                got = batched[k] if k == "tx_power_w" else float(batched[k][j])
                assert got == pytest.approx(ref[k], rel=1e-12, abs=1e-300), (
                    mcs, payload, k)


def test_airtime_batched_zero_payload_edge():
    """payload_bytes = 0: no data symbols, one (empty) TXOP of overhead,
    zero goodput — and no NaN/Inf anywhere (the guarded divisions)."""
    out = airtime_model_batched(jnp.asarray([0.0]))
    assert float(out["t_data_s"][0]) == 0.0
    assert float(out["n_ampdu"][0]) == 1.0
    assert float(out["t_overhead_s"][0]) > 0.0
    assert float(out["goodput_mbps"][0]) == 0.0
    for k in ("t_tx_s", "t_data_s", "goodput_mbps", "e_tx_wh"):
        assert np.isfinite(np.asarray(out[k])).all(), k


def test_airtime_batched_exact_ampdu_multiple_has_no_ghost_frame():
    """At an exact A-MPDU multiple the remainder path must contribute
    nothing: the where-gated remainder frame would otherwise still charge
    a MAC-header symbol for a zero-bit fragment."""
    one = airtime_model_batched(jnp.asarray([_MPDU_BYTES]))
    two = airtime_model_batched(jnp.asarray([2 * _MPDU_BYTES]))
    assert float(two["t_data_s"][0]) == pytest.approx(
        2 * float(one["t_data_s"][0]), rel=1e-12)
    assert float(two["n_ampdu"][0]) == 2.0


def test_airtime_batched_broadcasts_and_jits():
    """(N,) MCS × scalar payload broadcasts; the whole model is jittable
    and per-node airtimes decrease with link quality."""
    mcs = jnp.asarray([1.0, 2.0, 4.0, 25.0 / 3.0, 10.0])
    fn = jax.jit(lambda b: airtime_model_batched(PAPER_MODEL_BYTES, b))
    out = fn(mcs)
    t = np.asarray(out["t_tx_s"])
    assert t.shape == (5,)
    assert np.all(np.diff(t) < 0)  # better MCS → shorter airtime


def test_channel_energy_rates_uniform_reduces_to_scalar():
    """A uniform-MCS channel map reproduces the scalar EnergyParams rates
    bitwise — the seam the campaign-level reduction pin rests on."""
    ep = EnergyParams()
    e_part, e_idle = channel_energy_rates(
        jnp.full((7,), ep.comm.bits_per_symbol_per_sc), ep)
    np.testing.assert_array_equal(np.asarray(e_part),
                                  np.full(7, ep.e_participant_j))
    np.testing.assert_array_equal(np.asarray(e_idle),
                                  np.full(7, ep.e_idle_j))


def test_channel_energy_rates_worse_channel_costs_more():
    ep = EnergyParams()
    e_part, e_idle = channel_energy_rates(jnp.asarray([1.0, 4.0, 10.0]), ep)
    assert np.all(np.diff(np.asarray(e_part)) < 0)
    np.testing.assert_array_equal(np.asarray(e_idle),
                                  np.full(3, ep.e_idle_j))
    assert np.all(np.asarray(e_part) > np.asarray(e_idle))


def test_aoi_closed_form():
    for p in [0.1, 0.5, 0.9]:
        assert float(expected_aoi(jnp.asarray(p))) == pytest.approx(
            1.0 / p - 0.5)


def test_aoi_matches_simulation():
    p = 0.35
    sim = float(simulate_aoi(p, 400_000, jax.random.PRNGKey(0)))
    assert sim == pytest.approx(1.0 / p - 0.5, rel=3e-2)
