"""Energy model (eqs. 1-7), 802.11ax airtime, AoI (eq. 10)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401
from repro.core.aoi import expected_aoi, simulate_aoi
from repro.core.comm80211ax import PAPER_COMM, airtime_model
from repro.core.energy import (EnergyLedger, EnergyParams, PAPER_MODEL_BYTES,
                               calibrate_from_table, expected_round_energy,
                               round_energy, task_energy)


def test_airtime_scales_with_payload():
    a1 = airtime_model(1e6)
    a2 = airtime_model(2e6)
    assert a2["t_tx_s"] > a1["t_tx_s"]
    # asymptotically linear
    assert a2["t_data_s"] == pytest.approx(2 * a1["t_data_s"], rel=1e-3)


def test_airtime_reasonable_goodput():
    """20 MHz 802.11ax single stream: goodput well below PHY peak, above 50."""
    a = airtime_model(PAPER_MODEL_BYTES)
    assert 50 < a["goodput_mbps"] < 300
    # uploading 44.73 MB takes seconds, not ms or hours
    assert 1.0 < a["t_tx_s"] < 30.0


def test_tx_power_dbm_conversion():
    a = airtime_model(1e6, PAPER_COMM)
    assert a["tx_power_w"] == pytest.approx(10 ** (9 / 10) * 1e-3)


def test_round_energy_decomposition():
    ep = EnergyParams()
    n = 10
    mask = jnp.asarray([1, 0, 1, 0, 0, 0, 0, 0, 0, 0], bool)
    e = float(round_energy(mask, ep))
    want = 2 * ep.e_participant_j + 8 * ep.e_idle_j
    assert e == pytest.approx(want)


def test_expected_round_energy_is_linear_in_p():
    ep = EnergyParams()
    p = jnp.full((50,), 0.5)
    mid = float(expected_round_energy(p, ep))
    lo = float(expected_round_energy(jnp.zeros(50), ep))
    hi = float(expected_round_energy(jnp.ones(50), ep))
    assert mid == pytest.approx(0.5 * (lo + hi), rel=1e-12)


def test_participant_energy_exceeds_idle():
    ep = EnergyParams()
    assert ep.e_participant_j > ep.e_idle_j
    assert ep.e_tx_j > 0


def test_ledger_accumulates():
    ep = EnergyParams()
    led = EnergyLedger.create(4)
    m1 = jnp.asarray([1, 1, 0, 0], bool)
    m2 = jnp.asarray([1, 0, 0, 0], bool)
    led = led.record_round(m1, ep).record_round(m2, ep)
    assert int(led.rounds) == 2
    np.testing.assert_array_equal(np.asarray(led.participation_counts),
                                  [2, 1, 0, 0])
    want = float(round_energy(m1, ep) + round_energy(m2, ep))
    assert float(led.total_j) == pytest.approx(want)


def test_calibration_matches_table_scale():
    """Calibrated params reproduce Table II(b) energies within ~12%."""
    from repro.core.duration import PAPER_TABLE_II
    ep = calibrate_from_table()
    assert 100 < ep.p_hw_w < 500     # a plausible GPU-node training power
    tab = PAPER_TABLE_II
    pred = tab[:, 1] * (50 * ep.e_idle_j
                        + 50 * tab[:, 0] * (ep.e_participant_j - ep.e_idle_j)
                        ) / 3600.0
    rel = np.abs(pred - tab[:, 3]) / tab[:, 3]
    assert float(np.median(rel)) < 0.12


def test_task_energy_sums_rounds():
    e = task_energy(jnp.asarray([1.0, 2.0, 3.5]))
    assert float(e) == pytest.approx(6.5)


def test_aoi_closed_form():
    for p in [0.1, 0.5, 0.9]:
        assert float(expected_aoi(jnp.asarray(p))) == pytest.approx(
            1.0 / p - 0.5)


def test_aoi_matches_simulation():
    p = 0.35
    sim = float(simulate_aoi(p, 400_000, jax.random.PRNGKey(0)))
    assert sim == pytest.approx(1.0 / p - 0.5, rel=3e-2)
