"""Checkpoint round-trip + synthetic data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.synthetic import SyntheticCifar, SyntheticLM


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "opt": {"m": jnp.zeros((2, 3)), "step": jnp.asarray(7)}}
    save_checkpoint(str(tmp_path), 7, tree, metadata={"arch": "x"})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, meta = restore_checkpoint(str(tmp_path), like)
    assert meta["step"] == 7 and meta["arch"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_retention(tmp_path):
    tree = {"w": jnp.zeros((2,))}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep=3)
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 3
    assert latest_step(str(tmp_path)) == 5


def test_synthetic_cifar_deterministic():
    d = SyntheticCifar()
    b1 = d.client_batch(3, 5, 8)
    b2 = d.client_batch(3, 5, 8)
    np.testing.assert_array_equal(np.asarray(b1["images"]),
                                  np.asarray(b2["images"]))
    b3 = d.client_batch(4, 5, 8)
    assert not np.array_equal(np.asarray(b1["images"]),
                              np.asarray(b3["images"]))


def test_synthetic_cifar_learnable_signal():
    """Templates are separable: nearest-template classify >> chance."""
    d = SyntheticCifar(noise=0.8)
    batch = d.batch(jax.random.PRNGKey(1), 256)
    t = d._templates().reshape(10, -1)
    x = batch["images"].reshape(256, -1)
    pred = jnp.argmax(x @ t.T - 0.5 * jnp.sum(t * t, axis=1), axis=1)
    acc = float(jnp.mean(pred == batch["labels"]))
    assert acc > 0.9


def test_synthetic_lm_predictable():
    d = SyntheticLM(vocab=64, order_weight=0.9)
    batch = d.batch(jax.random.PRNGKey(0), 4, 128)
    assert batch["tokens"].shape == (4, 128)
    assert batch["labels"].shape == (4, 128)
    # labels are the next-token stream: shifted alignment
    np.testing.assert_array_equal(np.asarray(batch["tokens"][:, 1:]),
                                  np.asarray(batch["labels"][:, :-1]))


def test_iid_partition_covers_all():
    parts = iid_partition(1003, 7, seed=1)
    allidx = np.concatenate(parts)
    assert len(allidx) == 1003
    assert len(np.unique(allidx)) == 1003
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1


def test_dirichlet_partition_skewed():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 5000)
    parts = dirichlet_partition(labels, 8, alpha=0.1, seed=0)
    assert sum(len(p) for p in parts) == 5000
    # heavy skew: some client has a dominant class
    props = []
    for p in parts:
        if len(p) == 0:
            continue
        counts = np.bincount(labels[p], minlength=10)
        props.append(counts.max() / max(counts.sum(), 1))
    assert max(props) > 0.5
