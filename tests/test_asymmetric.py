"""Beyond-paper heterogeneous-node game (core/asymmetric.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.asymmetric import (HeterogeneousGame, best_response_dynamics,
                                   planner_coordinate_descent)
from helpers import assert_heterogeneous_ne, max_heterogeneous_deviation


@pytest.fixture(scope="module")
def game():
    n = 10
    dur = C.theoretical_duration(n_nodes=n, d_inf=35.0, slope=8.0)
    costs = jnp.asarray(np.linspace(0.5, 12.0, n))
    gammas = jnp.full((n,), 0.6)
    return HeterogeneousGame(costs=costs, gammas=gammas, dur=dur)


def test_br_dynamics_converge_to_exact_ne(game):
    p, conv, iters = best_response_dynamics(game, damping=0.6)
    assert conv, iters
    assert_heterogeneous_ne(game.costs, game.gammas, game.dur, p)


def test_participation_monotone_in_cost(game):
    """Cheaper nodes participate (weakly) more — free-rider stratification."""
    p, conv, _ = best_response_dynamics(game, damping=0.6)
    assert conv
    assert bool(jnp.all(jnp.diff(p) <= 1e-6))


def test_reduces_to_symmetric_case():
    """Identical nodes: the asymmetric solver finds the symmetric NE."""
    n = 50
    dur = C.paper_duration_model()
    g = HeterogeneousGame(costs=jnp.full((n,), 2.0),
                          gammas=jnp.full((n,), 0.6), dur=dur)
    p, conv, _ = best_response_dynamics(g, damping=0.6, max_iters=300)
    assert conv
    assert max_heterogeneous_deviation(g.costs, g.gammas, g.dur, p) <= 1e-4
    spread = float(jnp.max(p) - jnp.min(p))
    assert spread < 5e-3
    from repro.core.game import solve_symmetric_ne
    from repro.core.utility import UtilityParams
    sym = solve_symmetric_ne(UtilityParams(gamma=0.6, cost=2.0, n_nodes=n),
                             dur)
    assert any(abs(float(jnp.mean(p)) - s) < 0.05 for s in sym), (
        float(jnp.mean(p)), sym)


def test_heterogeneous_poa_ge_one(game):
    """PoA vs the heterogeneity-aware planner (coordinate descent from the
    NE can only lower the social cost, so PoA >= 1 and is meaningful)."""
    p, conv, _ = best_response_dynamics(game, damping=0.6)
    assert conv
    ne_cost = float(game.social_cost(p))
    p_opt = planner_coordinate_descent(game, p)
    opt = float(game.social_cost(p_opt))
    assert ne_cost >= opt - 1e-6
    assert opt <= ne_cost


def test_asymmetric_ne_beats_uniform_planner(game):
    """With heterogeneous costs a common-p planner is suboptimal — the
    stratified NE can undercut it (observed: 536.7 vs 564.3). This is a
    beyond-paper finding: uniform participation policies leave energy on
    the table once node costs differ."""
    p, conv, _ = best_response_dynamics(game, damping=0.6)
    assert conv
    ne_cost = float(game.social_cost(p))
    grid = jnp.linspace(1e-3, 1.0, 200)
    uniform_opt = min(float(game.social_cost(jnp.full((game.n,), float(q))))
                      for q in grid)
    het_opt = float(game.social_cost(planner_coordinate_descent(game, p)))
    assert het_opt <= uniform_opt  # heterogeneous planner dominates uniform
