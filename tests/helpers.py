"""Shared test helpers: Nash-equilibrium certification.

Several test files used to carry their own ad-hoc copy of the same check —
"no unilateral deviation on a grid is profitable". This module is the one
implementation, for both game flavors:

* :func:`max_symmetric_deviation` — symmetric game (everyone at p*): the
  best profitable deviation of one node over an action grid, via the O(N)
  Binomial decomposition in ``symmetric_player_utility``.
* :func:`max_heterogeneous_deviation` — heterogeneous profile: delegates to
  the jitted vectorized certifier in :mod:`repro.core.asymmetric_batched`.

Both return the *gain* of the best deviation (≤ tol certifies an NE); the
``assert_*`` wrappers fail with the offending numbers in the message.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.asymmetric_batched import verify_equilibrium_batched
from repro.core.duration import DurationModel
from repro.core.game import P_MIN
from repro.core.utility import UtilityParams, symmetric_player_utility

NE_TOL = 1e-4


def max_symmetric_deviation(
    p_star: float,
    params: UtilityParams,
    dur: DurationModel,
    grid: int = 256,
) -> float:
    """Max profitable unilateral deviation from the symmetric profile p*."""
    p_star = jnp.asarray(p_star)
    gridv = jnp.linspace(P_MIN, 1.0, grid)
    u_eq = symmetric_player_utility(p_star, p_star, params, dur)
    u_dev = jax.vmap(
        lambda q: symmetric_player_utility(q, p_star, params, dur))(gridv)
    return float(jnp.max(u_dev) - u_eq)


def max_heterogeneous_deviation(
    costs: jax.Array,
    gammas: jax.Array,
    dur: DurationModel,
    p: jax.Array,
    grid: int = 64,
) -> float:
    """Max profitable unilateral deviation from a heterogeneous profile.

    Single-game helper: the unpack below raises if a batch sneaks in
    (certifying only scenario 0 of a batch would be silently wrong).
    """
    (dev,) = verify_equilibrium_batched(costs, gammas, dur, jnp.asarray(p),
                                        grid=grid)
    return float(dev)


def assert_symmetric_ne(p_star, params, dur, tol: float = NE_TOL,
                        grid: int = 256) -> None:
    gain = max_symmetric_deviation(p_star, params, dur, grid=grid)
    assert gain <= tol, (
        f"profitable deviation {gain:.3e} > {tol:.1e} from symmetric "
        f"p*={float(p_star):.6f} (gamma={params.gamma}, c={params.cost})")


def assert_heterogeneous_ne(costs, gammas, dur, p, tol: float = NE_TOL,
                            grid: int = 64) -> None:
    gain = max_heterogeneous_deviation(costs, gammas, dur, p, grid=grid)
    assert gain <= tol, (
        f"profitable deviation {gain:.3e} > {tol:.1e} from profile "
        f"{[round(float(x), 4) for x in jnp.asarray(p)]}")
