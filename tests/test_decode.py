"""Decode-path correctness: serve_step parity with teacher-forced forward,
ring-buffer windows, MLA absorbed decode vs expanded prefill.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import hybrid as H
from repro.models import rwkv as R
from repro.models import transformer as T
from repro.models.registry import get_model

B, S = 2, 12


def _decode_all(api, cfg, params, tokens, cache, ring=False):
    outs = []
    for i in range(tokens.shape[1]):
        lg, cache = api.serve_step(params, cache, tokens[:, i:i + 1],
                                   jnp.asarray(i, jnp.int32), ring=ring)
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1)


def _drop_free(cfg):
    """Capacity-based MoE drops depend on which tokens are co-batched, so
    teacher-forced prefill and one-token decode only agree exactly in the
    drop-free regime (capacity_factor high enough). Parity tests pin that
    regime; capacity-drop behaviour itself is covered in test_models_smoke.
    """
    import dataclasses
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


@pytest.mark.parametrize("name", ["stablelm-3b", "phi4-mini-3.8b", "gemma-2b",
                                  "olmoe-1b-7b", "minicpm3-4b",
                                  "deepseek-v2-236b"])
def test_transformer_decode_parity(name):
    cfg = _drop_free(ARCHITECTURES[name].reduced())
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = T.forward(cfg, params, tokens)
    cache, _ = api.init_cache(B, S, False)
    dec = _decode_all(api, cfg, params, tokens, cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_rwkv_decode_parity():
    cfg = ARCHITECTURES["rwkv6-3b"].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = R.forward(cfg, params, tokens)
    state, _ = api.init_cache(B, 0, False)
    dec = _decode_all(api, cfg, params, tokens, state)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)


def test_hybrid_decode_parity():
    cfg = ARCHITECTURES["hymba-1.5b"].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = H.forward(cfg, params, tokens)
    cache, _ = api.init_cache(B, cfg.sliding_window, True)
    dec = _decode_all(api, cfg, params, tokens, cache, ring=True)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4)


def test_ring_cache_matches_full_cache_within_window():
    """A ring buffer of size W produces the same logits as a full cache when
    the model's attention is windowed to W."""
    import dataclasses
    cfg = ARCHITECTURES["stablelm-3b"].reduced()   # sliding_window=64 reduced
    w = cfg.sliding_window
    assert w > 0
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    total = w + 8   # exceed the window so eviction happens
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, total), 0,
                                cfg.vocab)
    ring_cache, _ = api.init_cache(B, w, True)
    ring_dec = _decode_all(api, cfg, params, tokens, ring_cache, ring=True)
    # reference: full forward with windowed mask
    full, _ = T.forward(cfg, params, tokens, window=w)
    np.testing.assert_allclose(np.asarray(ring_dec), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_whisper_decode_parity():
    from repro.models import encdec
    cfg = ARCHITECTURES["whisper-tiny"].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.n_frames, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    enc_out = encdec.encode(cfg, params, frames)
    full = encdec.decode_train(cfg, params, tokens, enc_out)
    cache, _ = api.init_cache(B, S, False)
    cache = encdec.warm_cache(cfg, params, cache, frames)
    outs = []
    for i in range(S):
        lg, cache = api.serve_step(params, cache, tokens[:, i:i + 1],
                                   jnp.asarray(i, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    # whisper decode uses a wrapped sinusoid table at pos<2048 — identical here
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_mla_absorbed_equals_expanded():
    """The absorbed MLA decode (latent-space scores) must equal the expanded
    formulation on the same cache content."""
    cfg = _drop_free(ARCHITECTURES["deepseek-v2-236b"].reduced())
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = T.forward(cfg, params, tokens)   # expanded path
    cache, _ = api.init_cache(B, S, False)
    dec = _decode_all(api, cfg, params, tokens, cache)  # absorbed path
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_mla_ring_cache_eviction():
    """MLA ring buffer (long_500k path) matches windowed full forward past
    the eviction point — the compressed-latent analogue of the GQA test."""
    import dataclasses
    cfg = ARCHITECTURES["minicpm3-4b"].reduced()
    w = cfg.sliding_window
    assert w > 0 and cfg.attn == "mla"
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    total = w + 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, total), 0,
                                cfg.vocab)
    ring_cache, _ = api.init_cache(B, w, True)
    ring_dec = _decode_all(api, cfg, params, tokens, ring_cache, ring=True)
    full, _ = T.forward(cfg, params, tokens, window=w)
    np.testing.assert_allclose(np.asarray(ring_dec), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_per_slot_positions_independent_rows():
    """Rows at different depths decode as if alone (continuous batching
    invariant, checked at the serve_step level)."""
    cfg = ARCHITECTURES["gemma-2b"].reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)

    # reference: row 1 decoded alone, 8 steps
    cache1, _ = api.init_cache(1, 16, False)
    ref = []
    for i in range(8):
        lg, cache1 = api.serve_step(params, cache1, toks[1:2, i:i + 1],
                                    jnp.asarray(i, jnp.int32))
        ref.append(lg[0, 0])

    # batched: row 0 starts 3 ticks late; per-slot positions diverge
    cache, _ = api.init_cache(2, 16, False)
    got = []
    pos = np.array([0, 0], np.int32)
    for i in range(8):
        t0 = toks[0:1, max(i - 3, 0):max(i - 3, 0) + 1]
        t1 = toks[1:2, i:i + 1]
        tk = jnp.concatenate([t0, t1], axis=0)
        lg, cache = api.serve_step(params, cache, tk, jnp.asarray(pos))
        got.append(lg[1, 0])
        pos = pos + np.array([1 if i >= 3 else 0, 1], np.int32) \
            if False else pos + np.array([int(i >= 3) or 1, 1], np.int32)
    # note: row 0's position bookkeeping is irrelevant to row 1's output
    np.testing.assert_allclose(np.asarray(jnp.stack(got)),
                               np.asarray(jnp.stack(ref)),
                               atol=2e-4, rtol=2e-4)
