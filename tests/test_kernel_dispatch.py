"""Backend dispatch (`repro.kernels.ops`) + hot-path wiring tests.

The dispatch contract this file pins:

* resolution precedence: explicit ``backend=`` > ``set_backend`` override >
  ``REPRO_KERNEL_BACKEND`` env var > the call site's default;
* ``backend="ref"`` at the campaign/game call sites is **bitwise** the
  pre-dispatch behaviour (same program, not just close);
* ``backend="pallas"`` (interpret mode on CPU) matches the references to
  tight tolerance end to end — through ``fedavg_merge``, the campaign
  engine, and the heterogeneous-game certifier/social cost.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401  (enables x64)
from repro.core.asymmetric_batched import (social_cost_batched,
                                           solve_heterogeneous,
                                           verify_equilibrium_batched)
from repro.core.duration import theoretical_duration
from repro.federated.campaign import run_campaigns
from repro.federated.server import fedavg_merge
from repro.federated.simulation import FLConfig
from repro.federated.tasks import synthetic_mlp_task
from repro.kernels import ops, ref
from repro.optim import sgd


@pytest.fixture(autouse=True)
def _clean_backend_state(monkeypatch):
    """Each test starts with no override and no env pin."""
    monkeypatch.delenv(ops.ENV_VAR, raising=False)
    ops.set_backend(None)
    yield
    ops.set_backend(None)


# ---------------------------------------------------------------------------
# resolution precedence
# ---------------------------------------------------------------------------

def test_resolution_defaults():
    assert ops.resolve_backend() == "pallas"
    assert ops.resolve_backend(default="ref") == "ref"
    assert ops.use_pallas()


def test_explicit_argument_wins(monkeypatch):
    monkeypatch.setenv(ops.ENV_VAR, "ref")
    ops.set_backend("ref")
    assert ops.resolve_backend("pallas") == "pallas"


def test_set_backend_beats_env(monkeypatch):
    monkeypatch.setenv(ops.ENV_VAR, "pallas")
    ops.set_backend("ref")
    assert ops.resolve_backend() == "ref"
    assert not ops.use_pallas()


def test_env_beats_default(monkeypatch):
    monkeypatch.setenv(ops.ENV_VAR, "ref")
    assert ops.resolve_backend() == "ref"
    assert ops.resolve_backend(default="pallas") == "ref"


def test_backend_scope_restores():
    prev = ops.set_backend("pallas")
    assert prev is None
    with ops.backend_scope("ref"):
        assert ops.resolve_backend() == "ref"
    assert ops.resolve_backend() == "pallas"


def test_invalid_backend_rejected(monkeypatch):
    with pytest.raises(ValueError):
        ops.resolve_backend("mosaic")
    with pytest.raises(ValueError):
        ops.set_backend("tpu")


def test_invalid_env_warns_once_and_falls_back(monkeypatch, capsys):
    """A typo'd env var warns on stderr (once) and is ignored — no raise."""
    monkeypatch.setenv(ops.ENV_VAR, "bogus")
    monkeypatch.setattr(ops, "_env_warned", False)
    assert ops.resolve_backend() == "pallas"
    assert ops.resolve_backend(default="ref") == "ref"
    err = capsys.readouterr().err
    assert err.count("ignoring REPRO_KERNEL_BACKEND='bogus'") == 1
    # override still beats the (ignored) env value
    ops.set_backend("ref")
    assert ops.resolve_backend() == "ref"


def test_dispatch_stats_counts_per_site_and_backend():
    ops.reset_dispatch_stats()
    ops.resolve_backend(site="ops.fedavg")
    ops.resolve_backend("ref", site="ops.fedavg")
    ops.resolve_backend("ref", site="ops.fedavg")
    ops.resolve_backend(default="ref", site="server.fedavg_merge")
    ops.resolve_backend()                      # no site: not counted
    stats = ops.dispatch_stats()
    assert stats == {"ops.fedavg": {"pallas": 1, "ref": 2},
                     "server.fedavg_merge": {"ref": 1}}
    ops.reset_dispatch_stats()
    assert ops.dispatch_stats() == {}


def test_model_wrappers_pin_to_reference():
    """backend='ref' on a model-kernel wrapper returns the jnp oracle."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (1, 64, 2, 32), jnp.float32)
               for kk in ks)
    got = ops.attention(q, k, v, backend="ref")
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# fedavg_merge dispatch
# ---------------------------------------------------------------------------

def _param_trees(n=4):
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (13, 7)),          # float64 under x64
         "b": jnp.ones((5,), jnp.float32),
         "h": jnp.ones((3,), jnp.bfloat16)}
    c = jax.tree.map(lambda x: jnp.stack([x + i for i in range(n)]), g)
    return g, c


def test_fedavg_merge_ref_is_bitwise_default():
    g, c = _param_trees()
    mask = jnp.asarray([1, 0, 1, 1], bool)
    default = fedavg_merge(g, c, mask)
    explicit = fedavg_merge(g, c, mask, backend="ref")
    for k in g:
        np.testing.assert_array_equal(np.asarray(default[k], np.float32),
                                      np.asarray(explicit[k], np.float32))


def test_fedavg_merge_pallas_parity_mixed_dtypes():
    g, c = _param_trees()
    mask = jnp.asarray([1, 0, 1, 1], bool)
    want = fedavg_merge(g, c, mask)
    got = fedavg_merge(g, c, mask, backend="pallas")
    for k in g:
        assert got[k].dtype == g[k].dtype
        np.testing.assert_allclose(np.asarray(got[k], np.float32),
                                   np.asarray(want[k], np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_fedavg_merge_pallas_weights():
    g, c = _param_trees()
    mask = jnp.asarray([1, 1, 0, 1], bool)
    w = jnp.asarray([0.1, 2.0, 5.0, 0.7])
    want = fedavg_merge(g, c, mask, w)
    got = fedavg_merge(g, c, mask, w, backend="pallas")
    for k in g:
        np.testing.assert_allclose(np.asarray(got[k], np.float32),
                                   np.asarray(want[k], np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_fedavg_merge_pallas_single_client_and_empty_round():
    g, c = _param_trees(n=1)
    np.testing.assert_allclose(
        np.asarray(fedavg_merge(g, c, jnp.ones((1,), bool),
                                backend="pallas")["w"], np.float32),
        np.asarray(c["w"][0], np.float32), atol=1e-6)
    # all-zero mask: previous global wins, exactly
    out = fedavg_merge(g, c, jnp.zeros((1,), bool), backend="pallas")
    np.testing.assert_allclose(np.asarray(out["w"], np.float32),
                               np.asarray(g["w"], np.float32), atol=1e-6)


def test_fedavg_merge_env_pin(monkeypatch):
    """REPRO_KERNEL_BACKEND=pallas flips the default-'ref' call site."""
    g, c = _param_trees()
    mask = jnp.asarray([0, 1, 1, 0], bool)
    monkeypatch.setenv(ops.ENV_VAR, "pallas")
    got = fedavg_merge(g, c, mask)
    want = ops.fedavg_merge_pallas(g, c, mask)
    for k in g:
        np.testing.assert_array_equal(np.asarray(got[k], np.float32),
                                      np.asarray(want[k], np.float32))


# ---------------------------------------------------------------------------
# campaign engine dispatch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_campaign():
    task = synthetic_mlp_task()
    fl = FLConfig(n_clients=5, local_steps=1, batch_per_client=8,
                  max_rounds=8, target_acc=0.73, seed=3)
    ps = jnp.asarray([0.35, 0.8], jnp.float32)
    return task, fl, ps


def test_campaign_backend_ref_bitwise(small_campaign):
    task, fl, ps = small_campaign
    res = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps)
    res_ref = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                            backend="ref")
    np.testing.assert_array_equal(np.asarray(res.acc_history),
                                  np.asarray(res_ref.acc_history))
    np.testing.assert_array_equal(np.asarray(res.ledger.per_node_j),
                                  np.asarray(res_ref.ledger.per_node_j))


def test_campaign_backend_pallas_parity(small_campaign):
    task, fl, ps = small_campaign
    res = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps)
    res_pal = run_campaigns(fl, *task.campaign_args(), sgd(0.15), ps,
                            backend="pallas")
    # RNG streams untouched by the merge backend: masks/ledger identical
    np.testing.assert_array_equal(np.asarray(res.k_history),
                                  np.asarray(res_pal.k_history))
    np.testing.assert_array_equal(np.asarray(res.ledger.per_node_j),
                                  np.asarray(res_pal.ledger.per_node_j))
    np.testing.assert_array_equal(np.asarray(res.rounds),
                                  np.asarray(res_pal.rounds))
    np.testing.assert_allclose(np.asarray(res.acc_history),
                               np.asarray(res_pal.acc_history),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# heterogeneous-game dispatch
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def het_batch():
    n = 6
    dur = theoretical_duration(n)
    costs = jnp.asarray([[1.0] * n, [6.0] * n, [3.5] * n])
    gammas = jnp.full((3, n), 0.2)
    sol = solve_heterogeneous(costs, gammas, dur, damping=0.6,
                              max_iters=300)
    return costs, gammas, dur, sol.p


def test_verify_backend_ref_bitwise(het_batch):
    costs, gammas, dur, p = het_batch
    np.testing.assert_array_equal(
        np.asarray(verify_equilibrium_batched(costs, gammas, dur, p)),
        np.asarray(verify_equilibrium_batched(costs, gammas, dur, p,
                                              backend="ref")))


def test_verify_backend_pallas_parity(het_batch):
    costs, gammas, dur, p = het_batch
    dev_ref = verify_equilibrium_batched(costs, gammas, dur, p)
    dev_pal = verify_equilibrium_batched(costs, gammas, dur, p,
                                         backend="pallas")
    np.testing.assert_allclose(np.asarray(dev_pal), np.asarray(dev_ref),
                               atol=1e-5)


def test_social_cost_backend_parity(het_batch):
    costs, _, dur, p = het_batch
    sc_ref = social_cost_batched(costs, dur, p)
    np.testing.assert_array_equal(
        np.asarray(sc_ref),
        np.asarray(social_cost_batched(costs, dur, p, backend="ref")))
    sc_pal = social_cost_batched(costs, dur, p, backend="pallas")
    np.testing.assert_allclose(np.asarray(sc_pal), np.asarray(sc_ref),
                               rtol=1e-5)
