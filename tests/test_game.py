"""NE solver, centralized optimum, PoA (paper eqs. 11-13 + §IV claims)."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.duration import paper_duration_model
from repro.core.game import (best_response, centralized_optimum, own_marginal,
                             solve_game, solve_symmetric_ne)
from repro.core.utility import UtilityParams, symmetric_player_utility
from helpers import assert_symmetric_ne


@pytest.fixture(scope="module")
def dur():
    return paper_duration_model()


def test_ne_is_root_of_marginal(dur):
    up = UtilityParams(gamma=0.6, cost=2.0, n_nodes=50)
    nes = solve_symmetric_ne(up, dur)
    phi = own_marginal(up, dur)
    for p in nes:
        if 0.002 < p < 0.999:  # interior
            assert abs(float(phi(jnp.asarray(p)))) < 1e-4


def test_ne_no_profitable_deviation(dur):
    """Certify the solved equilibria: no profitable unilateral deviation,
    neither on the shared certification grid nor at the golden-refined
    global best response."""
    up = UtilityParams(gamma=0.6, cost=2.0, n_nodes=50)
    nes = solve_symmetric_ne(up, dur)
    assert nes
    for p_star in nes:
        assert_symmetric_ne(p_star, up, dur)
        u_eq = float(symmetric_player_utility(jnp.asarray(p_star),
                                              jnp.asarray(p_star), up, dur))
        br, u_br = best_response(p_star, up, dur)
        assert u_br <= u_eq + 1e-6, (p_star, br, u_br, u_eq)


def test_centralized_beats_ne_cost(dur):
    from repro.core.utility import social_cost
    up = UtilityParams(gamma=0.0, cost=2.0, n_nodes=50)
    sol = solve_game(up, dur)
    for c_ne in sol.ne_costs:
        assert c_ne >= sol.opt_cost - 1e-9
    assert sol.poa >= 1.0


def test_poa_increases_with_cost(dur):
    poas = []
    for c in [0.5, 2.0, 8.0]:
        sol = solve_game(UtilityParams(gamma=0.0, cost=c, n_nodes=50), dur)
        poas.append(sol.poa)
    assert poas[0] <= poas[1] <= poas[2]


def test_incentive_improves_poa(dur):
    """Paper Fig. 6: AoI incentive keeps PoA lower at matched cost."""
    c = 3.0
    no_inc = solve_game(UtilityParams(gamma=0.0, cost=c, n_nodes=50), dur)
    inc = solve_game(UtilityParams(gamma=0.6, cost=c, n_nodes=50), dur)
    assert inc.poa <= no_inc.poa + 1e-9


def test_incentive_raises_participation(dur):
    """Paper Fig. 4: with gamma=0.6 the NE participation is higher."""
    c = 3.0
    ne0 = solve_symmetric_ne(UtilityParams(gamma=0.0, cost=c, n_nodes=50), dur)
    ne1 = solve_symmetric_ne(UtilityParams(gamma=0.6, cost=c, n_nodes=50), dur)
    assert max(ne1) >= max(ne0)


def test_paper_claims_band(dur):
    """Quantitative reproduction bands for the §IV headline numbers."""
    # centralized optimum near p ~ 0.61 (paper) — accept 0.55..0.75
    opt_p, _ = centralized_optimum(UtilityParams(gamma=0.0, cost=0.0,
                                                 n_nodes=50), dur)
    assert 0.55 <= opt_p <= 0.75, opt_p
    # the tragedy basin: low-participation NE around p ~ 0.24 at small c
    sol = solve_game(UtilityParams(gamma=0.0, cost=1.5, n_nodes=50), dur)
    assert sol.equilibria and min(sol.equilibria) < 0.35
    # PoA ~ 1.28 (paper) at the small-c operating point — accept 1.1..1.5
    assert 1.1 <= sol.poa <= 1.5, sol.poa
    # with the AoI incentive the NE keeps p high and PoA near 1
    sol_inc = solve_game(UtilityParams(gamma=0.6, cost=1.5, n_nodes=50), dur)
    assert max(sol_inc.equilibria) > 0.45
    assert sol_inc.poa < sol.poa


def test_collapse_at_high_cost(dur):
    """Tragedy of the Commons: p -> 0 as c grows without incentive."""
    sol = solve_game(UtilityParams(gamma=0.0, cost=60.0, n_nodes=50), dur)
    assert min(sol.equilibria) <= 0.01


def test_incentive_never_collapses(dur):
    """Paper: NE with incentive 'never reaches p = 0'."""
    sol = solve_game(UtilityParams(gamma=0.6, cost=60.0, n_nodes=50), dur)
    assert max(sol.equilibria) > 0.01
